"""Pallas TPU kernel for the Mamba-2 SSD chunked scan.

TPU adaptation of the SSD algorithm (arXiv:2405.21060):
* The chunk axis is the sequential grid dimension; the inter-chunk
  recurrent state (p, n) lives in VMEM scratch and persists across grid
  steps — the TPU analogue of the GPU kernel's persistent-CTA carry.
* All four inner products are expressed as (chunk x n/p) matmuls so the
  quadratic *dual* form runs on the MXU; with chunk/p/n multiples of 128
  every matmul is systolic-aligned. The elementwise decay algebra runs on
  the VPU in fp32.
* One (batch, head) pair per grid row keeps the working set
  (4·chunk·max(p,n) fp32) comfortably inside VMEM.

Outputs y per position and the final state (for prefill -> decode
handoff), exactly matching ``ref.ssd_reference``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, A_ref, B_ref, C_ref, D_ref,
                y_ref, state_ref, s_scr, *, chunk: int):
    c = pl.program_id(2)
    nc = pl.num_programs(2)

    @pl.when(c == 0)
    def _init():
        s_scr[...] = jnp.zeros_like(s_scr)

    x = x_ref[0, 0, 0].astype(jnp.float32)       # (chunk, p)
    dt = dt_ref[0, 0, 0].astype(jnp.float32)     # (chunk,)
    A = A_ref[0].astype(jnp.float32)             # scalar
    Bm = B_ref[0, 0, 0].astype(jnp.float32)      # (chunk, n)
    Cm = C_ref[0, 0, 0].astype(jnp.float32)      # (chunk, n)
    D = D_ref[0].astype(jnp.float32)

    dA = dt * A                                  # (chunk,)
    cum = jnp.cumsum(dA)                         # inclusive
    # L[i, j] = exp(cum_i - cum_j) for j <= i else 0
    li = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    lj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    Lmat = jnp.where(lj <= li, jnp.exp(cum[:, None] - cum[None, :]), 0.0)

    xdt = x * dt[:, None]                        # (chunk, p)
    # intra-chunk dual form: (C B^T ⊙ L) @ (dt·x)
    scores = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    y = jax.lax.dot_general(scores * Lmat, xdt, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    # inter-chunk: contribution of the carried state
    state = s_scr[...]                           # (p, n)
    y_off = jax.lax.dot_general(Cm, state, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
    y = y + y_off * jnp.exp(cum)[:, None]
    y = y + x * D
    y_ref[0, 0, 0] = y.astype(y_ref.dtype)

    # state update: s' = exp(cum_end)·s + Σ_j exp(cum_end - cum_j)·dt_j x_j B_j^T
    decay = jnp.exp(cum[-1] - cum)               # (chunk,)
    upd = jax.lax.dot_general(xdt * decay[:, None], Bm,
                              (((0,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)
    s_scr[...] = state * jnp.exp(cum[-1]) + upd

    @pl.when(c == nc - 1)
    def _emit_state():
        state_ref[0, 0] = s_scr[...]


def _ssd_extend_kernel(s0_ref, x_ref, dt_ref, A_ref, B_ref, C_ref, D_ref,
                       y_ref, state_ref, s_scr):
    t = pl.program_id(2)
    nt = pl.num_programs(2)

    @pl.when(t == 0)
    def _init():
        s_scr[...] = s0_ref[0, 0].astype(jnp.float32)

    x = x_ref[0, 0].astype(jnp.float32)          # (1, p)
    dt = dt_ref[0, 0, 0].astype(jnp.float32)     # scalar
    A = A_ref[0].astype(jnp.float32)
    Bv = B_ref[0, 0].astype(jnp.float32)         # (1, n)
    Cv = C_ref[0, 0].astype(jnp.float32)         # (1, n)
    D = D_ref[0].astype(jnp.float32)

    # one ssd_decode_step, bitwise: s' = exp(dt·A)·s + (dt·x) B^T,
    # y = C s'^T (+ D·x)
    dA = jnp.exp(dt * A)
    xdt = x * dt                                 # (1, p)
    upd = jax.lax.dot_general(xdt, Bv, (((0,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)
    s = s_scr[...] * dA + upd                    # (p, n)
    y = jax.lax.dot_general(Cv, s, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)
    y_ref[0, 0] = (y + x * D).astype(y_ref.dtype)
    s_scr[...] = s

    @pl.when(t == nt - 1)
    def _emit_state():
        state_ref[0, 0] = s_scr[...]


def ssd_extend_pallas(state, x, dt, A, B, C, D=None, *, interpret=False):
    """Same contract as ``ref.ssd_extend_reference``: multi-token
    sequential recurrence from an explicit initial state. The token axis
    is the sequential grid dimension; the (p, n) state lives in VMEM
    scratch across grid steps, seeded from ``state`` at t == 0."""
    b, T, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    rep = h // g
    if D is None:
        D = jnp.zeros((h,), jnp.float32)

    xk = x.transpose(0, 2, 1, 3)                 # (b, h, T, p)
    dtk = dt.transpose(0, 2, 1)                  # (b, h, T)
    Bk = B.transpose(0, 2, 1, 3)                 # (b, g, T, n)
    Ck = C.transpose(0, 2, 1, 3)

    grid = (b, h, T)
    y, final = pl.pallas_call(
        _ssd_extend_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, p, n), lambda bi, hi, ti: (bi, hi, 0, 0)),
            pl.BlockSpec((1, 1, 1, p), lambda bi, hi, ti: (bi, hi, ti, 0)),
            pl.BlockSpec((1, 1, 1), lambda bi, hi, ti: (bi, hi, ti)),
            pl.BlockSpec((1,), lambda bi, hi, ti: (hi,)),
            pl.BlockSpec((1, 1, 1, n),
                         lambda bi, hi, ti, rep=rep: (bi, hi // rep, ti, 0)),
            pl.BlockSpec((1, 1, 1, n),
                         lambda bi, hi, ti, rep=rep: (bi, hi // rep, ti, 0)),
            pl.BlockSpec((1,), lambda bi, hi, ti: (hi,)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, 1, p), lambda bi, hi, ti: (bi, hi, ti, 0)),
            pl.BlockSpec((1, 1, p, n), lambda bi, hi, ti: (bi, hi, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, h, T, p), jnp.float32),
            jax.ShapeDtypeStruct((b, h, p, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        interpret=interpret,
    )(state.astype(jnp.float32), xk, dtk, jnp.asarray(A, jnp.float32),
      Bk, Ck, jnp.asarray(D, jnp.float32))

    return y.transpose(0, 2, 1, 3), final


def ssd_pallas(x, dt, A, B, C, D=None, *, chunk=64, initial_state=None,
               interpret=False):
    """Same contract as ``ref.ssd_reference``; initial_state must be None
    (the model's prefill path always starts from zero state)."""
    assert initial_state is None, "pallas path starts from zero state"
    b, l, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    assert l % chunk == 0
    nc = l // chunk
    rep = h // g
    if D is None:
        D = jnp.zeros((h,), jnp.float32)

    # layout: chunk-major per (batch, head)
    xk = x.transpose(0, 2, 1, 3).reshape(b, h, nc, chunk, p)
    dtk = dt.transpose(0, 2, 1).reshape(b, h, nc, chunk)
    Bk = B.transpose(0, 2, 1, 3).reshape(b, g, nc, chunk, n)
    Ck = C.transpose(0, 2, 1, 3).reshape(b, g, nc, chunk, n)

    grid = (b, h, nc)
    kernel = functools.partial(_ssd_kernel, chunk=chunk)
    y, state = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, 1, chunk, p),
                         lambda bi, hi, ci: (bi, hi, ci, 0, 0)),
            pl.BlockSpec((1, 1, 1, chunk),
                         lambda bi, hi, ci: (bi, hi, ci, 0)),
            pl.BlockSpec((1,), lambda bi, hi, ci, rep=rep: (hi,)),
            pl.BlockSpec((1, 1, 1, chunk, n),
                         lambda bi, hi, ci, rep=rep: (bi, hi // rep, ci, 0, 0)),
            pl.BlockSpec((1, 1, 1, chunk, n),
                         lambda bi, hi, ci, rep=rep: (bi, hi // rep, ci, 0, 0)),
            pl.BlockSpec((1,), lambda bi, hi, ci: (hi,)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, 1, chunk, p),
                         lambda bi, hi, ci: (bi, hi, ci, 0, 0)),
            pl.BlockSpec((1, 1, p, n), lambda bi, hi, ci: (bi, hi, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, h, nc, chunk, p), jnp.float32),
            jax.ShapeDtypeStruct((b, h, p, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        interpret=interpret,
    )(xk, dtk, jnp.asarray(A, jnp.float32), Bk, Ck,
      jnp.asarray(D, jnp.float32))

    y = y.reshape(b, h, l, p).transpose(0, 2, 1, 3)
    return y, state
