"""Pure-jnp oracle for the Mamba-2 SSD (state-space dual) chunked scan.

Shapes follow the Mamba-2 paper (arXiv:2405.21060):
  x  : (b, l, h, p)   inputs split into h heads of dim p
  dt : (b, l, h)      positive step sizes (softplus already applied)
  A  : (h,)           negative per-head decay rates
  B,C: (b, l, g, n)   input/output projections, g groups (h % g == 0)
Returns y: (b, l, h, p) and the final state (b, h, p, n).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax


def segsum(x):
    """x: (..., T) -> (..., T, T) with out[..., i, j] = sum_{j<s<=i} x[s]
    (lower-triangular; -inf above the diagonal so exp() masks it)."""
    T = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    out = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((T, T), bool), k=0)
    return jnp.where(mask, out, -jnp.inf)


def ssd_reference(x, dt, A, B, C, D=None, *, chunk=64, initial_state=None):
    b, l, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    assert l % chunk == 0, f"seq {l} not divisible by chunk {chunk}"
    nc = l // chunk
    rep = h // g

    f32 = jnp.float32
    x, dt = x.astype(f32), dt.astype(f32)
    A, B, C = A.astype(f32), B.astype(f32), C.astype(f32)

    Bh = jnp.repeat(B, rep, axis=2)                     # (b, l, h, n)
    Ch = jnp.repeat(C, rep, axis=2)

    xr = x.reshape(b, nc, chunk, h, p)
    dtr = dt.reshape(b, nc, chunk, h)
    Br = Bh.reshape(b, nc, chunk, h, n)
    Cr = Ch.reshape(b, nc, chunk, h, n)

    dA = jnp.einsum("bcsh,h->bchs", dtr, A)             # (b, nc, h, chunk)
    dA_cum = jnp.cumsum(dA, axis=-1)
    L = jnp.exp(segsum(dA))                             # (b, nc, h, c, c)
    xdt = xr * dtr[..., None]                           # (b, nc, c, h, p)

    # intra-chunk (dual / quadratic form — MXU-friendly)
    Y_diag = jnp.einsum("bclhn,bcshn,bchls,bcshp->bclhp", Cr, Br, L, xdt)

    # per-chunk end states
    decay_states = jnp.exp(dA_cum[..., -1:] - dA_cum)   # (b, nc, h, c)
    states = jnp.einsum("bcshn,bchs,bcshp->bchpn", Br, decay_states, xdt)

    # inter-chunk recurrence
    chunk_decay = jnp.exp(dA_cum[..., -1])              # (b, nc, h)
    if initial_state is None:
        init = jnp.zeros((b, h, p, n), f32)
    else:
        init = initial_state.astype(f32)

    def step(s, inp):
        st, dec = inp
        return s * dec[..., None, None] + st, s         # emit pre-chunk state

    states_t = jnp.moveaxis(states, 1, 0)
    decay_t = jnp.moveaxis(chunk_decay, 1, 0)
    final, prev = lax.scan(step, init, (states_t, decay_t))
    prev = jnp.moveaxis(prev, 0, 1)                     # (b, nc, h, p, n)

    # inter-chunk contribution to outputs
    state_decay_out = jnp.exp(dA_cum)                   # (b, nc, h, c)
    Y_off = jnp.einsum("bclhn,bchpn,bchl->bclhp", Cr, prev, state_decay_out)

    y = (Y_diag + Y_off).reshape(b, l, h, p)
    if D is not None:
        y = y + x.reshape(b, l, h, p) * D.astype(f32)[None, None, :, None]
    return y, final


def ssd_extend_reference(state, x, dt, A, B, C, D=None):
    """Multi-token sequential recurrence from an explicit initial state.

    state: (b, h, p, n); x: (b, T, h, p); dt: (b, T, h); B, C: (b, T, g, n).
    Returns (y: (b, T, h, p), final_state: (b, h, p, n)).

    Exactly T applications of ``ssd_decode_step`` — bitwise, not just
    numerically: extending by [t1, t2] chunks equals extending by
    [t1 + t2] equals t1+t2 single decode steps. This per-token
    compositionality is the invariant the serving engine's chunked
    admission relies on for SSM stacks (the chunked dual form in
    ``ssd_reference``/``ssd_pallas`` is faster for long prefills but its
    float reduction order changes with the chunking).
    """
    xs = (jnp.moveaxis(x, 1, 0), jnp.moveaxis(dt, 1, 0),
          jnp.moveaxis(B, 1, 0), jnp.moveaxis(C, 1, 0))

    def step(s, inp):
        xi, dti, Bi, Ci = inp
        y, s = ssd_decode_step(s, xi, dti, A, Bi, Ci, D)
        return s, y

    final, ys = lax.scan(step, state.astype(jnp.float32), xs)
    return jnp.moveaxis(ys, 0, 1), final


def ssd_decode_step(state, x, dt, A, B, C, D=None):
    """Single-token recurrence.
    state: (b, h, p, n); x: (b, h, p); dt: (b, h); B, C: (b, g, n)."""
    f32 = jnp.float32
    h = x.shape[1]
    g = B.shape[1]
    rep = h // g
    x, dt = x.astype(f32), dt.astype(f32)
    Bh = jnp.repeat(B.astype(f32), rep, axis=1)          # (b, h, n)
    Ch = jnp.repeat(C.astype(f32), rep, axis=1)
    dA = jnp.exp(dt * A.astype(f32)[None])               # (b, h)
    new_state = state.astype(f32) * dA[..., None, None] + \
        jnp.einsum("bhp,bhn->bhpn", x * dt[..., None], Bh)
    y = jnp.einsum("bhpn,bhn->bhp", new_state, Ch)
    if D is not None:
        y = y + x * D.astype(f32)[None, :, None]
    return y, new_state
