"""Public entry points for the SSD scan.

``ssd``/``ssd_step`` dispatch to the Pallas TPU kernel when requested (and
validated via interpret mode in tests) or to the pure-jnp oracle — which is
also what multi-pod dry-runs lower, since Pallas CPU lowering is not
representative of TPU codegen.
"""
from __future__ import annotations

import jax

from repro.kernels.ssd_scan import ref as _ref

_USE_PALLAS = False  # toggled by repro.kernels.set_backend


def set_use_pallas(flag: bool) -> None:
    global _USE_PALLAS
    _USE_PALLAS = flag


def ssd(x, dt, A, B, C, D=None, *, chunk=64, initial_state=None,
        use_pallas=None):
    use = _USE_PALLAS if use_pallas is None else use_pallas
    if use:
        from repro.kernels.ssd_scan import kernel as _k
        return _k.ssd_pallas(x, dt, A, B, C, D, chunk=chunk,
                             initial_state=initial_state, interpret=True)
    return _ref.ssd_reference(x, dt, A, B, C, D, chunk=chunk,
                              initial_state=initial_state)


def ssd_step(state, x, dt, A, B, C, D=None):
    return _ref.ssd_decode_step(state, x, dt, A, B, C, D)
