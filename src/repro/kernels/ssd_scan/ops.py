"""Public entry points for the SSD scan.

``ssd``/``ssd_extend``/``ssd_step`` dispatch to the Pallas TPU kernel or
to the pure-jnp oracle via ``kernels.dispatch`` (backend default +
``REPRO_FORCE_REF``/``REPRO_FORCE_PALLAS`` env overrides); the oracle is
also what multi-pod dry-runs lower, since Pallas CPU lowering is not
representative of TPU codegen.
"""
from __future__ import annotations

import warnings

import jax

from repro.kernels import dispatch
from repro.kernels.ssd_scan import ref as _ref


def set_use_pallas(flag: bool) -> None:
    """Deprecated no-op shim. The module-scoped ssd-only override is
    retired: implementation choice goes through ``kernels.dispatch``
    like every other op — pass ``use_pallas=`` per call, or set
    ``REPRO_FORCE_REF``/``REPRO_FORCE_PALLAS`` process-wide."""
    warnings.warn(
        "ssd_scan.ops.set_use_pallas is deprecated and has no effect; "
        "pass use_pallas= per call or use the REPRO_FORCE_* env vars "
        "(kernels.dispatch).", DeprecationWarning, stacklevel=2)


def ssd(x, dt, A, B, C, D=None, *, chunk=64, initial_state=None,
        use_pallas=None):
    use, interpret = dispatch.resolve(use_pallas)
    if use and initial_state is None:
        from repro.kernels.ssd_scan import kernel as _k
        return _k.ssd_pallas(x, dt, A, B, C, D, chunk=chunk,
                             initial_state=None, interpret=interpret)
    return _ref.ssd_reference(x, dt, A, B, C, D, chunk=chunk,
                              initial_state=initial_state)


def ssd_extend(state, x, dt, A, B, C, D=None, *, use_pallas=None):
    """Multi-token sequential recurrence from an explicit state — the
    serving engine's chunked-admission / speculative-verify form.
    Bitwise equal to T applications of ``ssd_step`` on both paths."""
    use, interpret = dispatch.resolve(use_pallas)
    if use:
        from repro.kernels.ssd_scan import kernel as _k
        return _k.ssd_extend_pallas(state, x, dt, A, B, C, D,
                                    interpret=interpret)
    return _ref.ssd_extend_reference(state, x, dt, A, B, C, D)


def ssd_step(state, x, dt, A, B, C, D=None):
    return _ref.ssd_decode_step(state, x, dt, A, B, C, D)
