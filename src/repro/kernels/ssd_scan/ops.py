"""Public entry points for the SSD scan.

``ssd``/``ssd_step`` dispatch to the Pallas TPU kernel or to the
pure-jnp oracle via ``kernels.dispatch`` (backend default +
``REPRO_FORCE_REF``/``REPRO_FORCE_PALLAS`` env overrides); the oracle is
also what multi-pod dry-runs lower, since Pallas CPU lowering is not
representative of TPU codegen.
"""
from __future__ import annotations

import jax

from repro.kernels import dispatch
from repro.kernels.ssd_scan import ref as _ref

_SSD_OVERRIDE = None   # module-scoped legacy toggle; None = defer to dispatch


def set_use_pallas(flag: bool) -> None:
    """Legacy ssd-only toggle: pins this module's implementation choice
    without touching the process-wide dispatch (REPRO_FORCE_REF still
    wins — it exists to bisect kernel bugs)."""
    global _SSD_OVERRIDE
    _SSD_OVERRIDE = bool(flag)


def ssd(x, dt, A, B, C, D=None, *, chunk=64, initial_state=None,
        use_pallas=None):
    if use_pallas is None:
        use_pallas = _SSD_OVERRIDE
    use, interpret = dispatch.resolve(use_pallas)
    if use:
        from repro.kernels.ssd_scan import kernel as _k
        return _k.ssd_pallas(x, dt, A, B, C, D, chunk=chunk,
                             initial_state=initial_state,
                             interpret=interpret)
    return _ref.ssd_reference(x, dt, A, B, C, D, chunk=chunk,
                              initial_state=initial_state)


def ssd_step(state, x, dt, A, B, C, D=None):
    return _ref.ssd_decode_step(state, x, dt, A, B, C, D)
