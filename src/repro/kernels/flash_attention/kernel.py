"""Pallas TPU flash-attention kernel (prefill / training path).

TPU adaptation of the classic GPU algorithm:
* Q/K/V tiles are staged HBM->VMEM by ``BlockSpec`` (the analogue of the
  GPU's shared-memory staging, but driven by the sequential grid).
* The score matmul and the PV matmul hit the MXU; tiles default to
  (128, 128) so both matmul dims are systolic-array aligned.
* The KV loop is the *last* grid dimension — on TPU the grid is executed
  sequentially on a core, so the online-softmax running state (m, l, acc)
  lives in VMEM scratch and persists across KV iterations; output is
  written once on the final iteration.
* Causal tiles above the diagonal are skipped with ``pl.when`` (no VMEM
  traffic, no MXU work), halving compute for long sequences.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30
_LANES = 128  # TPU lane width: scratch last-dims padded to this


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, causal: bool, window: int,
                  bq: int, bk: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # Tile-level skip: strictly-above-diagonal (causal) or fully outside
    # the sliding window.
    q_lo, q_hi = iq * bq, iq * bq + bq - 1
    k_lo = ik * bk
    live = jnp.asarray(True)
    if causal:
        live = jnp.logical_and(live, k_lo <= q_hi)
    if window:
        live = jnp.logical_and(live, (ik * bk + bk - 1) > q_lo - window)

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)            # (bq, hd)
        k = k_ref[0, 0].astype(jnp.float32)            # (bk, hd)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if causal or window:
            qpos = q_lo + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            kpos = k_lo + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            mask = jnp.ones((bq, bk), bool)
            if causal:
                mask &= kpos <= qpos
            if window:
                mask &= kpos > qpos - window
            s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[:, 0:1]                          # (bq, 1)
        l_prev = l_scr[:, 0:1]
        m_cur = jnp.max(s, axis=1, keepdims=True)       # (bq, 1)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)                          # (bq, bk)
        alpha = jnp.exp(m_prev - m_new)                 # (bq, 1)
        l_new = alpha * l_prev + jnp.sum(p, axis=1, keepdims=True)
        pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        acc_scr[...] = acc_scr[...] * alpha + pv
        m_scr[...] = jnp.broadcast_to(m_new, m_scr.shape)
        l_scr[...] = jnp.broadcast_to(l_new, l_scr.shape)

    @pl.when(ik == nk - 1)
    def _finalize():
        l = l_scr[:, 0:1]
        l = jnp.where(l == 0.0, 1.0, l)                 # fully-masked rows
        o_ref[0, 0] = (acc_scr[...] / l).astype(o_ref.dtype)


def _flash_gqa_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                      scale: float, causal: bool, window: int,
                      bq: int, bk: int, G: int):
    """GQA-native: one grid row covers a whole KV-head group — the K/V
    tiles are staged into VMEM ONCE for all G query heads (G× less KV
    HBM traffic than head-expanded MHA, the same win the decode kernel
    exploits)."""
    iq = pl.program_id(1)
    ik = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_lo, q_hi = iq * bq, iq * bq + bq - 1
    k_lo = ik * bk
    live = jnp.asarray(True)
    if causal:
        live = jnp.logical_and(live, k_lo <= q_hi)
    if window:
        live = jnp.logical_and(live, (ik * bk + bk - 1) > q_lo - window)

    @pl.when(live)
    def _compute():
        q = q_ref[0].astype(jnp.float32).reshape(G * bq, -1)   # (G·bq, hd)
        k = k_ref[0].astype(jnp.float32)                       # (bk, hd)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if causal or window:
            rows = jax.lax.broadcasted_iota(jnp.int32, (G * bq, bk), 0)
            qpos = q_lo + jnp.mod(rows, bq)
            kpos = k_lo + jax.lax.broadcasted_iota(jnp.int32,
                                                   (G * bq, bk), 1)
            mask = jnp.ones((G * bq, bk), bool)
            if causal:
                mask &= kpos <= qpos
            if window:
                mask &= kpos > qpos - window
            s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[:, 0:1]
        l_prev = l_scr[:, 0:1]
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = jnp.broadcast_to(
            alpha * l_prev + jnp.sum(p, axis=1, keepdims=True), l_scr.shape)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = jnp.broadcast_to(m_new, m_scr.shape)

    @pl.when(ik == nk - 1)
    def _finalize():
        l = l_scr[:, 0:1]
        l = jnp.where(l == 0.0, 1.0, l)
        hd = o_ref.shape[-1]
        o_ref[0] = (acc_scr[...] / l).reshape(G, bq, hd).astype(o_ref.dtype)


def flash_attention_gqa_pallas(q, k, v, *, causal=True, window=0,
                               bq=128, bk=128, interpret=False):
    """q: (B, Hq, L, hd); k, v: (B, Hkv, L, hd) — no head expansion."""
    B, Hq, Lq, hd = q.shape
    Hkv, Lk = k.shape[1], k.shape[2]
    G = Hq // Hkv
    bq = min(bq, Lq)
    bk = min(bk, Lk)
    assert Lq % bq == 0 and Lk % bk == 0
    # regroup: (B·Hkv, G, L, hd) so one grid row shares the KV tiles
    qg = q.reshape(B, Hkv, G, Lq, hd).reshape(B * Hkv, G, Lq, hd)
    kg = k.reshape(B * Hkv, Lk, hd)
    vg = v.reshape(B * Hkv, Lk, hd)
    grid = (B * Hkv, Lq // bq, Lk // bk)

    kernel = functools.partial(_flash_gqa_kernel, scale=1.0 / (hd ** 0.5),
                               causal=causal, window=window, bq=bq, bk=bk,
                               G=G)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, G, bq, hd), lambda b, i, j: (b, 0, i, 0)),
            pl.BlockSpec((1, bk, hd), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, hd), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, G, bq, hd), lambda b, i, j: (b, 0, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * Hkv, G, Lq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G * bq, _LANES), jnp.float32),
            pltpu.VMEM((G * bq, _LANES), jnp.float32),
            pltpu.VMEM((G * bq, hd), jnp.float32),
        ],
        interpret=interpret,
    )(qg, kg, vg)
    return out.reshape(B, Hq, Lq, hd)


def flash_attention_pallas(q, k, v, *, causal=True, window=0,
                           bq=128, bk=128, interpret=False):
    """q, k, v: (B, H, L, hd) (same head count — GQA expanded by ops.py)."""
    B, H, Lq, hd = q.shape
    Lk = k.shape[2]
    bq = min(bq, Lq)
    bk = min(bk, Lk)
    assert Lq % bq == 0 and Lk % bk == 0, (Lq, bq, Lk, bk)
    grid = (B, H, Lq // bq, Lk // bk)

    kernel = functools.partial(
        _flash_kernel, scale=1.0 / (hd ** 0.5), causal=causal,
        window=window, bq=bq, bk=bk)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, i, j: (b, h, j, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, i, j: (b, h, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, hd),
                               lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Lq, hd), q.dtype),
        scratch_shapes=[
            # online-softmax running state, persists across the KV grid dim
            pltpu.VMEM((bq, _LANES), jnp.float32),   # running max m
            pltpu.VMEM((bq, _LANES), jnp.float32),   # running denom l
            pltpu.VMEM((bq, hd), jnp.float32),       # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)
