"""jit'd wrapper: GQA layout plumbing around the flash-attention kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import dispatch
from repro.kernels.flash_attention import ref as _ref
from repro.kernels.flash_attention.kernel import (
    flash_attention_gqa_pallas, flash_attention_pallas)


def mha_attention(q, k, v, *, causal=True, window=0, use_pallas=None,
                  interpret=None, bq=128, bk=128):
    """q, k, v: (B, H/Hkv, L, hd) per-head layout. The Pallas path is
    GQA-native (no head expansion — KV tiles staged once per group).
    ``use_pallas=None`` defers to ``kernels.dispatch`` (backend +
    REPRO_FORCE_REF)."""
    use_pallas, interpret = dispatch.resolve(use_pallas, interpret)
    Hq, Hkv = q.shape[1], k.shape[1]
    if use_pallas:
        if Hkv != Hq:
            return flash_attention_gqa_pallas(
                q, k, v, causal=causal, window=window, bq=bq, bk=bk,
                interpret=interpret)
        return flash_attention_pallas(q, k, v, causal=causal, window=window,
                                      bq=bq, bk=bk, interpret=interpret)
    if Hkv != Hq:
        rep = Hq // Hkv
        k = jnp.repeat(k, rep, axis=1)
        v = jnp.repeat(v, rep, axis=1)
    return _ref.attention_reference(q, k, v, causal=causal, window=window)


def gqa_flash(q, k, v, *, causal=True, window=0, **kw):
    """(B, L, H, hd) model layout -> kernel layout and back."""
    qt = jnp.transpose(q, (0, 2, 1, 3))
    kt = jnp.transpose(k, (0, 2, 1, 3))
    vt = jnp.transpose(v, (0, 2, 1, 3))
    out = mha_attention(qt, kt, vt, causal=causal, window=window, **kw)
    return jnp.transpose(out, (0, 2, 1, 3))
