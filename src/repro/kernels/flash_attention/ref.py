"""Pure-jnp oracle for flash attention (per-head layout).

q, k, v: (B, H, L, hd). Causal, optional sliding window.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def attention_reference(q, k, v, *, causal=True, window=0):
    B, H, Lq, hd = q.shape
    Lk = k.shape[2]
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / jnp.sqrt(hd)
    qpos = jnp.arange(Lq)[:, None]
    kpos = jnp.arange(Lk)[None, :]
    mask = jnp.ones((Lq, Lk), bool)
    if causal:
        mask &= kpos <= qpos
    if window:
        mask &= kpos > qpos - window
    s = jnp.where(mask[None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p,
                      v.astype(jnp.float32)).astype(q.dtype)
