"""jit'd wrapper for decode attention against the model's cache layout."""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels import dispatch
from repro.kernels.decode_attention import ref as _ref
from repro.kernels.decode_attention.kernel import (
    decode_attention_pallas, paged_decode_attention_pallas)


def cached_decode_attention(q, k_cache, v_cache, pos, q_pos, *, window=0,
                            use_pallas=None, interpret=None, bk=128):
    """Model layout: q (B, T, Hq, hd) — T = 1 for plain decode, T > 1 for
    multi-query rows (speculative verify / chunked-prefill extend);
    k/v cache (B, S, Hkv, hd); pos (B, S); q_pos (B,) base position
    (query t sits at ``q_pos + t``) or (B, T) explicit per-query absolute
    positions. Returns (B, T, Hq, hd). ``use_pallas=None`` defers to
    ``kernels.dispatch``.
    """
    use_pallas, interpret = dispatch.resolve(use_pallas, interpret)
    T = q.shape[1]
    if q_pos.ndim == 1:
        q_pos = q_pos[:, None] + jnp.arange(T, dtype=q_pos.dtype)[None]
    kh = jnp.transpose(k_cache, (0, 2, 1, 3))        # (B, Hkv, S, hd)
    vh = jnp.transpose(v_cache, (0, 2, 1, 3))
    if use_pallas:
        out = decode_attention_pallas(q, kh, vh, pos, q_pos, window=window,
                                      bk=bk, interpret=interpret)
    else:
        out = _ref.decode_attention_reference(q, kh, vh, pos, q_pos,
                                              window=window)
    return out


def paged_decode_attention(q, k_pool, v_pool, block_table, pos, q_pos, *,
                           window=0, use_pallas=None, interpret=None):
    """Paged-cache layout (``layers.make_paged_kv_cache``): q (B, T, Hq,
    hd); k/v pool (P + 1, ps, Hkv, hd) with the trash page last;
    block_table (B, NB) int32; pos (B, S = NB * ps); q_pos (B,) base or
    (B, T) explicit per-query positions. The Pallas path fetches pages
    through a scalar-prefetch block-table index map (no contiguous
    gather); the reference gathers the logical view and defers to the
    dense oracle. Returns (B, T, Hq, hd)."""
    use_pallas, interpret = dispatch.resolve(use_pallas, interpret)
    T = q.shape[1]
    if q_pos.ndim == 1:
        q_pos = q_pos[:, None] + jnp.arange(T, dtype=q_pos.dtype)[None]
    if use_pallas:
        return paged_decode_attention_pallas(q, k_pool, v_pool, block_table,
                                             pos, q_pos, window=window,
                                             interpret=interpret)
    return _ref.paged_decode_attention_reference(q, k_pool, v_pool,
                                                 block_table, pos, q_pos,
                                                 window=window)
