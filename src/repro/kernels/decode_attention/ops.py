"""jit'd wrapper for decode attention against the model's cache layout."""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels import dispatch
from repro.kernels.decode_attention import ref as _ref
from repro.kernels.decode_attention.kernel import decode_attention_pallas


def cached_decode_attention(q, k_cache, v_cache, pos, step, *, window=0,
                            use_pallas=None, interpret=None, bk=128):
    """Model layout: q (B, 1, Hq, hd); k/v cache (B, S, Hkv, hd);
    pos (B, S); step (B,) = query absolute position. Returns (B, 1, Hq, hd).
    ``use_pallas=None`` defers to ``kernels.dispatch``.
    """
    use_pallas, interpret = dispatch.resolve(use_pallas, interpret)
    qh = q[:, 0]                                     # (B, Hq, hd)
    kh = jnp.transpose(k_cache, (0, 2, 1, 3))        # (B, Hkv, S, hd)
    vh = jnp.transpose(v_cache, (0, 2, 1, 3))
    if use_pallas:
        out = decode_attention_pallas(qh, kh, vh, pos, step, window=window,
                                      bk=bk, interpret=interpret)
    else:
        out = _ref.decode_attention_reference(qh, kh, vh, pos, step,
                                              window=window)
    return out[:, None]
