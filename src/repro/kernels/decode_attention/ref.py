"""Oracle for GQA decode attention over a (ring-buffer) cache.

q: (B, Hq, hd) — one new token per sequence — or (B, T, Hq, hd) for
multi-query rows (speculative verify / chunked-prefill extend: T new
tokens per sequence attending the same per-slot cache region)
k, v: (B, Hkv, S, hd) — cache in per-head layout
pos: (B, S) absolute position stored in each slot (-1 = empty)
q_pos: (B,) absolute position of the (single) query token, or (B, T)
per-query absolute positions in the multi-query form
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def decode_attention_reference(q, k, v, pos, q_pos, *, window=0):
    squeeze = q.ndim == 3
    if squeeze:
        q, q_pos = q[:, None], q_pos[:, None]
    B, T, Hq, hd = q.shape
    Hkv = k.shape[1]
    rep = Hq // Hkv
    kf = jnp.repeat(k, rep, axis=1).astype(jnp.float32)   # (B, Hq, S, hd)
    vf = jnp.repeat(v, rep, axis=1).astype(jnp.float32)
    s = jnp.einsum("bthd,bhsd->bths", q.astype(jnp.float32), kf) \
        / jnp.sqrt(hd)
    valid = (pos[:, None, :] >= 0) & (pos[:, None, :] <= q_pos[..., None])
    if window:
        valid &= pos[:, None, :] > (q_pos[..., None] - window)
    s = jnp.where(valid[:, :, None, :], s, NEG_INF)       # (B, T, Hq, S)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bths,bhsd->bthd", p, vf).astype(q.dtype)
    return out[:, 0] if squeeze else out
