"""Oracle for single-token GQA decode attention over a (ring-buffer) cache.

q: (B, Hq, hd) — one new token per sequence
k, v: (B, Hkv, S, hd) — cache in per-head layout
pos: (B, S) absolute position stored in each slot (-1 = empty)
q_pos: (B,) absolute position of the query token
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def decode_attention_reference(q, k, v, pos, q_pos, *, window=0):
    B, Hq, hd = q.shape
    Hkv = k.shape[1]
    rep = Hq // Hkv
    kf = jnp.repeat(k, rep, axis=1).astype(jnp.float32)
    vf = jnp.repeat(v, rep, axis=1).astype(jnp.float32)
    s = jnp.einsum("bhd,bhsd->bhs", q.astype(jnp.float32), kf) \
        / jnp.sqrt(hd)
    valid = (pos >= 0) & (pos <= q_pos[:, None])
    if window:
        valid &= pos > (q_pos[:, None] - window)
    s = jnp.where(valid[:, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhs,bhsd->bhd", p, vf).astype(q.dtype)
