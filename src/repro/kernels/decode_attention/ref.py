"""Oracle for GQA decode attention over a (ring-buffer) cache.

q: (B, Hq, hd) — one new token per sequence — or (B, T, Hq, hd) for
multi-query rows (speculative verify / chunked-prefill extend: T new
tokens per sequence attending the same per-slot cache region)
k, v: (B, Hkv, S, hd) — cache in per-head layout
pos: (B, S) absolute position stored in each slot (-1 = empty)
q_pos: (B,) absolute position of the (single) query token, or (B, T)
per-query absolute positions in the multi-query form
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def paged_decode_attention_reference(q, k_pool, v_pool, block_table, pos,
                                     q_pos, *, window=0):
    """Paged-cache oracle: K/V live in a page pool and each sequence maps
    logical blocks to pages via its block-table row. Gathers the pool
    into the contiguous logical view, then defers to the dense oracle —
    positions backed by the trash page (last pool index) carry junk that
    ``pos == -1`` masks off.

    k_pool, v_pool: (P + 1, ps, Hkv, hd); block_table: (B, NB) int32;
    pos: (B, S) with S = NB * ps; q/q_pos as in the dense oracle."""
    B, NB = block_table.shape
    ps, Hkv, hd = k_pool.shape[1], k_pool.shape[2], k_pool.shape[3]
    k = k_pool[block_table].reshape(B, NB * ps, Hkv, hd)
    v = v_pool[block_table].reshape(B, NB * ps, Hkv, hd)
    kh = jnp.transpose(k, (0, 2, 1, 3))               # (B, Hkv, S, hd)
    vh = jnp.transpose(v, (0, 2, 1, 3))
    return decode_attention_reference(q, kh, vh, pos, q_pos, window=window)


def decode_attention_reference(q, k, v, pos, q_pos, *, window=0):
    squeeze = q.ndim == 3
    if squeeze:
        q, q_pos = q[:, None], q_pos[:, None]
    B, T, Hq, hd = q.shape
    Hkv = k.shape[1]
    rep = Hq // Hkv
    kf = jnp.repeat(k, rep, axis=1).astype(jnp.float32)   # (B, Hq, S, hd)
    vf = jnp.repeat(v, rep, axis=1).astype(jnp.float32)
    s = jnp.einsum("bthd,bhsd->bths", q.astype(jnp.float32), kf) \
        / jnp.sqrt(hd)
    valid = (pos[:, None, :] >= 0) & (pos[:, None, :] <= q_pos[..., None])
    if window:
        valid &= pos[:, None, :] > (q_pos[..., None] - window)
    s = jnp.where(valid[:, :, None, :], s, NEG_INF)       # (B, T, Hq, S)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bths,bhsd->bthd", p, vf).astype(q.dtype)
    return out[:, 0] if squeeze else out
