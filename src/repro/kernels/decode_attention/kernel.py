"""Pallas TPU decode-attention kernel (flash-decoding style).

One (or a few) query tokens attend over a long KV cache. TPU adaptation:
* The KV sequence is the sequential grid dimension; each step stages one
  (bk, hd) K/V tile into VMEM and updates the online-softmax state held in
  VMEM scratch — the cache itself never leaves HBM more than once.
* GQA is exploited: all G query heads of a KV group are processed together
  as the "matrix" side of the MXU matmuls, so the arithmetic intensity per
  KV byte is G× that of per-head decode — this kernel is the
  memory-roofline workhorse for ``decode_32k``/``long_500k``.
* Multi-query rows (speculative verify / chunked-prefill extend): the T
  query tokens of a row share the same KV region, so they fold into the
  MXU row dimension alongside the G group heads — R = T·G rows per KV
  group, each with its own absolute position for masking. Arithmetic
  intensity per KV byte grows another T×, which is what makes a prefill
  chunk nearly free next to the decode it is fused with.
* Ring-buffer validity (slot position array) and the sliding window are
  applied as masks from a position tile, so the same kernel serves full
  and windowed caches.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30
_LANES = 128


def _decode_kernel(q_ref, k_ref, v_ref, pos_ref, qpos_ref, o_ref,
                   m_scr, l_scr, acc_scr, *, scale: float, window: int,
                   bk: int, R: int):
    ik = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32)                  # (R, hd)
    k = k_ref[0, 0].astype(jnp.float32)               # (bk, hd)
    v = v_ref[0, 0].astype(jnp.float32)
    pos = pos_ref[0]                                  # (bk,)
    q_pos = qpos_ref[0]                               # (R,) per-row position

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    valid = (pos[None, :] >= 0) & (pos[None, :] <= q_pos[:, None])
    if window:
        valid &= pos[None, :] > (q_pos[:, None] - window)
    s = jnp.where(valid, s, NEG_INF)                  # (R, bk)

    m_prev = m_scr[:, 0:1]
    l_prev = l_scr[:, 0:1]
    m_cur = jnp.max(s, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_scr[...] = jnp.broadcast_to(alpha * l_prev
                                  + jnp.sum(p, axis=1, keepdims=True),
                                  l_scr.shape)
    acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_scr[...] = jnp.broadcast_to(m_new, m_scr.shape)

    @pl.when(ik == nk - 1)
    def _finalize():
        l = l_scr[:, 0:1]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0] = (acc_scr[...] / l).astype(o_ref.dtype)


def _paged_decode_kernel(bt_ref, q_ref, k_ref, v_ref, pos_ref, qpos_ref,
                         o_ref, m_scr, l_scr, acc_scr, *, scale: float,
                         window: int, bk: int, R: int):
    # the block table is consumed by the index maps (scalar prefetch);
    # inside the body the K/V tile is already the right page
    del bt_ref
    _decode_kernel(q_ref, k_ref, v_ref, pos_ref, qpos_ref, o_ref,
                   m_scr, l_scr, acc_scr, scale=scale, window=window,
                   bk=bk, R=R)


def paged_decode_attention_pallas(q, k_pool, v_pool, block_table, pos, q_pos,
                                  *, window=0, interpret=False):
    """Paged variant: identical online-softmax body, but the KV grid
    dimension walks *logical blocks* and the K/V tile for step j is
    fetched from pool page ``block_table[seq, j]`` via a scalar-prefetch
    index map — the gather never materialises a contiguous copy of the
    cache. The KV tile size is the page size, so one grid step stages
    exactly one page.

    q: (B, T, Hq, hd) (or (B, Hq, hd) single-query); k_pool, v_pool:
    (P + 1, ps, Hkv, hd) — last pool index is the trash page unallocated
    block-table entries point at (its junk is masked by ``pos == -1``);
    block_table: (B, NB) int32; pos: (B, S = NB * ps); q_pos: (B,) or
    (B, T)."""
    squeeze = q.ndim == 3
    if squeeze:
        q, q_pos = q[:, None], q_pos[:, None]
    B, T, Hq, hd = q.shape
    ps, Hkv = k_pool.shape[1], k_pool.shape[2]
    NB = block_table.shape[1]
    G = Hq // Hkv
    R = T * G
    qg = q.reshape(B, T, Hkv, G, hd).transpose(0, 2, 1, 3, 4) \
          .reshape(B * Hkv, R, hd)
    kg = jnp.transpose(k_pool, (2, 0, 1, 3))          # (Hkv, P+1, ps, hd)
    vg = jnp.transpose(v_pool, (2, 0, 1, 3))
    posg = jnp.repeat(pos, Hkv, axis=0)               # (B*Hkv, S)
    qpos_r = jnp.repeat(q_pos.astype(jnp.int32), G, axis=1)   # (B, R)
    qposg = jnp.repeat(qpos_r, Hkv, axis=0)           # (B*Hkv, R)

    grid = (B * Hkv, 1, NB)
    kernel = functools.partial(_paged_decode_kernel, scale=1.0 / (hd ** 0.5),
                               window=window, bk=ps, R=R)
    # grid index b covers (sequence, kv head): seq = b // Hkv, head =
    # b % Hkv — matching the dense kernel's B*Hkv regrouping. Index maps
    # receive the scalar-prefetch operands *after* the grid indices
    # (the kernel body receives them first).
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, R, hd), lambda b, h, j, bt: (b, 0, 0)),
            pl.BlockSpec((1, 1, ps, hd),
                         lambda b, h, j, bt: (b % Hkv, bt[b // Hkv, j], 0, 0)),
            pl.BlockSpec((1, 1, ps, hd),
                         lambda b, h, j, bt: (b % Hkv, bt[b // Hkv, j], 0, 0)),
            pl.BlockSpec((1, ps), lambda b, h, j, bt: (b, j)),
            pl.BlockSpec((1, R), lambda b, h, j, bt: (b, 0)),
        ],
        out_specs=pl.BlockSpec((1, R, hd), lambda b, h, j, bt: (b, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((R, _LANES), jnp.float32),
            pltpu.VMEM((R, _LANES), jnp.float32),
            pltpu.VMEM((R, hd), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B * Hkv, R, hd), q.dtype),
        interpret=interpret,
    )(block_table.astype(jnp.int32), qg, kg, vg, posg, qposg)
    out = out.reshape(B, Hkv, T, G, hd).transpose(0, 2, 1, 3, 4) \
             .reshape(B, T, Hq, hd)
    return out[:, 0] if squeeze else out


def decode_attention_pallas(q, k, v, pos, q_pos, *, window=0, bk=128,
                            interpret=False):
    """q: (B, Hq, hd) single-query or (B, T, Hq, hd) multi-query rows;
    k, v: (B, Hkv, S, hd); pos: (B, S); q_pos: (B,) or (B, T) matching q."""
    squeeze = q.ndim == 3
    if squeeze:
        q, q_pos = q[:, None], q_pos[:, None]
    B, T, Hq, hd = q.shape
    Hkv, S = k.shape[1], k.shape[2]
    G = Hq // Hkv
    R = T * G
    bk = min(bk, S)
    assert S % bk == 0, (S, bk)
    # regroup q to (B*Hkv, T*G, hd) so one grid step covers a KV group:
    # row r of a group is query token r // G, group head r % G
    qg = q.reshape(B, T, Hkv, G, hd).transpose(0, 2, 1, 3, 4) \
          .reshape(B * Hkv, R, hd)
    kg = k.reshape(B * Hkv, 1, S, hd)
    vg = v.reshape(B * Hkv, 1, S, hd)
    posg = jnp.repeat(pos, Hkv, axis=0)               # (B*Hkv, S)
    qpos_r = jnp.repeat(q_pos.astype(jnp.int32), G, axis=1)   # (B, R)
    qposg = jnp.repeat(qpos_r, Hkv, axis=0)           # (B*Hkv, R)

    grid = (B * Hkv, 1, S // bk)
    kernel = functools.partial(_decode_kernel, scale=1.0 / (hd ** 0.5),
                               window=window, bk=bk, R=R)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, R, hd), lambda b, h, j: (b, 0, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, j: (b, h, j, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, j: (b, h, j, 0)),
            pl.BlockSpec((1, bk), lambda b, h, j: (b, j)),
            pl.BlockSpec((1, R), lambda b, h, j: (b, 0)),
        ],
        out_specs=pl.BlockSpec((1, R, hd), lambda b, h, j: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B * Hkv, R, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((R, _LANES), jnp.float32),
            pltpu.VMEM((R, _LANES), jnp.float32),
            pltpu.VMEM((R, hd), jnp.float32),
        ],
        interpret=interpret,
    )(qg, kg, vg, posg, qposg)
    out = out.reshape(B, Hkv, T, G, hd).transpose(0, 2, 1, 3, 4) \
             .reshape(B, T, Hq, hd)
    return out[:, 0] if squeeze else out
