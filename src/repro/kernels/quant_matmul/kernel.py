"""Pallas TPU kernel: fused dequantize-matmul for int8/int4 weights.

Weight-only quantized decode is bandwidth-bound: the win is moving the
weight matrix HBM -> VMEM at 1 byte (int8) or 0.5 bytes (int4 packed)
per element instead of 2-4, and never materializing a dequantized copy
in HBM. Each grid step streams an ``(bm, K)`` activation tile and a
``(K, bn)`` quantized weight tile into VMEM; nibble unpacking, scaling
and the MXU matmul all happen on-chip, with the f32 accumulator scaled
in the epilogue (int8, per-channel) or per group before accumulation
(int4, group-wise).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _qmm_int8_kernel(x_ref, q_ref, s_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)                 # (bm, K)
    q = q_ref[...].astype(jnp.float32)                 # (K, bn)
    acc = jnp.dot(x, q, preferred_element_type=jnp.float32)
    s = s_ref[...].astype(jnp.float32)                 # (bn,)
    o_ref[...] = (acc * s[None, :]).astype(o_ref.dtype)


def _qmm_int4_kernel(x_ref, p_ref, s_ref, o_ref, *, group_size: int):
    x = x_ref[...].astype(jnp.float32)                 # (bm, K)
    p32 = p_ref[...].astype(jnp.int32)                 # (K//2, bn) packed
    lo = (p32 << 28) >> 28                             # sign-extended nibbles
    hi = (p32 << 24) >> 28
    K = x.shape[1]
    bn = p32.shape[1]
    q = jnp.stack([lo, hi], axis=1).reshape(K, bn).astype(jnp.float32)
    s = s_ref[...].astype(jnp.float32)                 # (ng, bn)
    w = (q.reshape(K // group_size, group_size, bn)
         * s[:, None, :]).reshape(K, bn)               # dequant in VMEM only
    o_ref[...] = jnp.dot(x, w,
                         preferred_element_type=jnp.float32
                         ).astype(o_ref.dtype)


def quant_matmul_int8_pallas(x, q, scale, *, bm=128, bn=128,
                             interpret=False):
    """x: (M, K); q: (K, N) int8; scale: (N,) -> (M, N) in x.dtype."""
    M, K = x.shape
    N = q.shape[1]
    bm, bn = min(bm, M), min(bn, N)
    assert M % bm == 0 and N % bn == 0, (M, bm, N, bn)
    return pl.pallas_call(
        _qmm_int8_kernel,
        grid=(M // bm, N // bn),
        in_specs=[
            pl.BlockSpec((bm, K), lambda i, j: (i, 0)),
            pl.BlockSpec((K, bn), lambda i, j: (0, j)),
            pl.BlockSpec((bn,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), x.dtype),
        interpret=interpret,
    )(x, q, scale)


def quant_matmul_int4_pallas(x, q4, scale, *, bm=128, bn=128,
                             interpret=False):
    """x: (M, K); q4: (K//2, N) packed int8; scale: (ng, N) -> (M, N)."""
    M, K = x.shape
    N = q4.shape[1]
    ng = scale.shape[0]
    assert K % ng == 0 and K == 2 * q4.shape[0], (K, ng, q4.shape)
    bm, bn = min(bm, M), min(bn, N)
    assert M % bm == 0 and N % bn == 0, (M, bm, N, bn)
    kernel = functools.partial(_qmm_int4_kernel, group_size=K // ng)
    return pl.pallas_call(
        kernel,
        grid=(M // bm, N // bn),
        in_specs=[
            pl.BlockSpec((bm, K), lambda i, j: (i, 0)),
            pl.BlockSpec((K // 2, bn), lambda i, j: (0, j)),
            pl.BlockSpec((ng, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), x.dtype),
        interpret=interpret,
    )(x, q4, scale)
