"""Dispatch wrapper: QTensor-aware matmul over arbitrary-rank inputs.

``quant_matmul(x, qt)`` is what ``models.layers.linear`` routes through
when a projection weight is quantized. Implementation choice defers to
``kernels.dispatch`` (reference off-TPU — interpret-safe everywhere,
identical math; fused Pallas kernel on TPU, which requires
tile-divisible shapes).
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels import dispatch
from repro.kernels.quant_matmul import ref as _ref
from repro.kernels.quant_matmul.kernel import (quant_matmul_int4_pallas,
                                               quant_matmul_int8_pallas)


def quant_matmul(x, qt, *, use_pallas=None, interpret=None, bm=128,
                 bn=128):
    """x: (..., K) activations; qt: QTensor dict for a (K, N) weight.
    Returns (..., N) in x.dtype."""
    use_pallas, interpret = dispatch.resolve(use_pallas, interpret)
    lead = x.shape[:-1]
    K = x.shape[-1]
    x2 = x.reshape(-1, K)
    scale = jnp.asarray(qt["scale"])
    if "q" in qt:
        q = jnp.asarray(qt["q"])
        if use_pallas:
            y = quant_matmul_int8_pallas(x2, q, scale, bm=bm, bn=bn,
                                         interpret=interpret)
        else:
            y = _ref.quant_matmul_int8_reference(x2, q, scale)
        N = q.shape[1]
    else:
        q4 = jnp.asarray(qt["q4"])
        if use_pallas:
            y = quant_matmul_int4_pallas(x2, q4, scale, bm=bm, bn=bn,
                                         interpret=interpret)
        else:
            y = _ref.quant_matmul_int4_reference(x2, q4, scale)
        N = q4.shape[1]
    return y.reshape(lead + (N,))
