"""Oracle for the fused dequantize-matmul.

int8 per-channel exploits the scale algebra: ``x @ (q * s[None, :]) ==
(x @ q) * s[None, :]``, so dequantization is a free epilogue on the
accumulator. int4 group-wise needs the per-group contraction before the
scale can be applied: ``y = sum_g (x_g @ q_g) * s_g``.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.quant.qtensor import unpack_int4


def quant_matmul_int8_reference(x, q, scale):
    """x: (M, K) float; q: (K, N) int8; scale: (N,) f32 -> (M, N)."""
    acc = jnp.dot(x.astype(jnp.float32), q.astype(jnp.float32),
                  preferred_element_type=jnp.float32)
    return (acc * scale[None, :].astype(jnp.float32)).astype(x.dtype)


def quant_matmul_int4_reference(x, q4, scale):
    """x: (M, K) float; q4: (K//2, N) packed int8; scale: (ng, N) f32."""
    qf = unpack_int4(q4).astype(jnp.float32)          # (K, N)
    K, N = qf.shape
    ng = scale.shape[0]
    gs = K // ng
    xg = x.astype(jnp.float32).reshape(-1, ng, gs)
    qg = qf.reshape(ng, gs, N)
    partial = jnp.einsum("mgk,gkn->mgn", xg, qg,
                         preferred_element_type=jnp.float32)
    y = jnp.sum(partial * scale[None].astype(jnp.float32), axis=1)
    return y.astype(x.dtype)
