"""Central Pallas-vs-reference dispatch for every kernel op.

Each ``kernels/*/ops.py`` wrapper used to hard-code
``use_pallas=False, interpret=True`` defaults; this module is now the
single place that decides which implementation runs. Precedence, highest
first:

* **sharded fallback** — when a tensor-parallel activation context is
  active (``distribution.sharding`` model axis > 1), every op routes to
  the jnp reference, even over an explicit ``use_pallas=True``. Pallas
  kernels are single-device programs whose block specs assume the full
  (unsharded) head/feature dims; under GSPMD partitioning they would
  either force an all-gather of their operands or fail outright inside
  ``shard_map``. The jnp reference partitions like any other XLA op, so
  falling back per-op keeps the whole step program partitionable;
* ``REPRO_FORCE_REF=1`` forces the jnp reference everywhere, overriding
  even an explicit ``use_pallas=True`` (debugging / bisecting a kernel
  regression without touching call sites);
* ``REPRO_FORCE_PALLAS=1`` forces the Pallas path the same way — it
  overrides an explicit ``use_pallas=False`` (in interpret mode off-TPU,
  so it still runs — the kernel-validation CI mode). When both force
  envs are set, ``REPRO_FORCE_REF`` wins: the reference path is the
  ground truth the Pallas path is validated against;
* explicit ``use_pallas=True/False`` at a call site;
* otherwise the backend decides: Pallas compiled on TPU, reference
  elsewhere (Pallas CPU lowering is interpret-only and not
  representative of TPU codegen, so it is never the silent default).

``interpret`` follows the backend rule: compiled on TPU, interpret mode
everywhere else, unless the caller pins it.
"""
from __future__ import annotations

import os
from typing import Optional, Tuple

import jax

_FORCE_REF_ENV = "REPRO_FORCE_REF"
_FORCE_PALLAS_ENV = "REPRO_FORCE_PALLAS"


def _env_true(name: str) -> bool:
    return os.environ.get(name, "").strip().lower() not in ("", "0", "false")


def backend() -> str:
    return jax.default_backend()


def sharded_ref_fallback() -> bool:
    """True when ops should take the reference path because activations
    are tensor-parallel right now (an ``activation_sharding`` context
    with a model axis > 1 is active — the serving engine and launchers
    enter one around every sharded program they trace)."""
    from repro.distribution.sharding import model_axis_size
    return model_axis_size() > 1


def use_pallas_default() -> bool:
    """The implementation choice when the call site does not pin one."""
    if _env_true(_FORCE_REF_ENV):
        return False
    if _env_true(_FORCE_PALLAS_ENV):
        return True
    return backend() == "tpu"


def interpret_default() -> bool:
    """Pallas interpret mode: real codegen on TPU, interpreter elsewhere."""
    return backend() != "tpu"


def resolve(use_pallas: Optional[bool] = None,
            interpret: Optional[bool] = None) -> Tuple[bool, bool]:
    """Resolve the (use_pallas, interpret) pair for one op call.

    ``None`` means "let the backend decide"; explicit booleans are
    honoured as-is unless a higher-precedence rule applies (see the
    module docstring): the sharded fallback, then ``REPRO_FORCE_REF``,
    then ``REPRO_FORCE_PALLAS`` — the two force envs are symmetric, and
    REF wins when both are set.
    """
    if sharded_ref_fallback():
        up = False
    elif _env_true(_FORCE_REF_ENV):
        up = False
    elif _env_true(_FORCE_PALLAS_ENV):
        up = True
    elif use_pallas is None:
        up = use_pallas_default()
    else:
        up = bool(use_pallas)
    it = interpret_default() if interpret is None else bool(interpret)
    return up, it
