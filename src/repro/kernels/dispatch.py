"""Central Pallas-vs-reference dispatch for every kernel op.

Each ``kernels/*/ops.py`` wrapper used to hard-code
``use_pallas=False, interpret=True`` defaults; this module is now the
single place that decides which implementation runs:

* explicit ``use_pallas=True/False`` at a call site always wins;
* ``REPRO_FORCE_REF=1`` in the environment forces the jnp reference
  everywhere (debugging / bisecting a kernel regression);
* ``REPRO_FORCE_PALLAS=1`` forces the Pallas path (in interpret mode
  off-TPU, so it still runs — the kernel-validation CI mode);
* otherwise the backend decides: Pallas compiled on TPU, reference
  elsewhere (Pallas CPU lowering is interpret-only and not
  representative of TPU codegen, so it is never the silent default).

``interpret`` follows the same rule: compiled on TPU, interpret mode
everywhere else, unless the caller pins it.
"""
from __future__ import annotations

import os
from typing import Optional, Tuple

import jax

_FORCE_REF_ENV = "REPRO_FORCE_REF"
_FORCE_PALLAS_ENV = "REPRO_FORCE_PALLAS"


def _env_true(name: str) -> bool:
    return os.environ.get(name, "").strip().lower() not in ("", "0", "false")


def backend() -> str:
    return jax.default_backend()


def use_pallas_default() -> bool:
    """The implementation choice when the call site does not pin one."""
    if _env_true(_FORCE_REF_ENV):
        return False
    if _env_true(_FORCE_PALLAS_ENV):
        return True
    return backend() == "tpu"


def interpret_default() -> bool:
    """Pallas interpret mode: real codegen on TPU, interpreter elsewhere."""
    return backend() != "tpu"


def resolve(use_pallas: Optional[bool] = None,
            interpret: Optional[bool] = None) -> Tuple[bool, bool]:
    """Resolve the (use_pallas, interpret) pair for one op call.

    ``None`` means "let the backend decide"; explicit booleans are
    honoured as-is (except ``REPRO_FORCE_REF``, which overrides even an
    explicit ``use_pallas=True`` — it exists to bisect kernel bugs
    without touching call sites).
    """
    if _env_true(_FORCE_REF_ENV):
        up = False
    elif use_pallas is None:
        up = use_pallas_default()
    else:
        up = bool(use_pallas)
    it = interpret_default() if interpret is None else bool(interpret)
    return up, it
