"""Pallas TPU kernel: fused residual-add + RMSNorm.

Bandwidth-bound fusion: the unfused HLO reads the residual stream twice
(add, then norm) and writes the intermediate back to HBM; the fused kernel
streams one (bn, d) tile through VMEM, does add + reduce + scale on the
VPU in fp32, and writes both the normed output and the updated residual —
1 read + 2 writes instead of 2 reads + 3 writes per element.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, res_ref, scale_ref, y_ref, newres_ref, *,
                    eps: float):
    x = x_ref[...].astype(jnp.float32)
    r = res_ref[...].astype(jnp.float32)
    s = scale_ref[...].astype(jnp.float32)
    t = x + r
    var = jnp.mean(t * t, axis=-1, keepdims=True)
    y = t * jax.lax.rsqrt(var + eps) * s[None, :]
    y_ref[...] = y.astype(y_ref.dtype)
    newres_ref[...] = t.astype(newres_ref.dtype)


def fused_rmsnorm_pallas(x, residual, scale, *, eps=1e-5, bn=128,
                         interpret=False):
    N, d = x.shape
    bn = min(bn, N)
    assert N % bn == 0
    kernel = functools.partial(_rmsnorm_kernel, eps=eps)
    return pl.pallas_call(
        kernel,
        grid=(N // bn,),
        in_specs=[
            pl.BlockSpec((bn, d), lambda i: (i, 0)),
            pl.BlockSpec((bn, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=[pl.BlockSpec((bn, d), lambda i: (i, 0)),
                   pl.BlockSpec((bn, d), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((N, d), x.dtype),
                   jax.ShapeDtypeStruct((N, d), x.dtype)],
        interpret=interpret,
    )(x, residual, scale)
