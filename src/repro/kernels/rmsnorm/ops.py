"""jit'd wrapper for fused residual + RMSNorm."""
from __future__ import annotations

from repro.kernels import dispatch
from repro.kernels.rmsnorm import ref as _ref
from repro.kernels.rmsnorm.kernel import fused_rmsnorm_pallas


def fused_rmsnorm(x, residual, scale, *, eps=1e-5, use_pallas=None,
                  interpret=None, bn=128):
    use_pallas, interpret = dispatch.resolve(use_pallas, interpret)
    if use_pallas:
        return fused_rmsnorm_pallas(x, residual, scale, eps=eps, bn=bn,
                                    interpret=interpret)
    return _ref.fused_rmsnorm_reference(x, residual, scale, eps=eps)
