"""Oracle for fused residual-add + RMSNorm."""
from __future__ import annotations

import jax.numpy as jnp
from jax import lax


def fused_rmsnorm_reference(x, residual, scale, eps=1e-5):
    """y = rmsnorm(x + residual) * scale; also returns the new residual
    stream (x + residual). x, residual: (N, d)."""
    r = x.astype(jnp.float32) + residual.astype(jnp.float32)
    var = jnp.mean(r * r, axis=-1, keepdims=True)
    y = r * lax.rsqrt(var + eps) * scale.astype(jnp.float32)
    return y.astype(x.dtype), r.astype(x.dtype)
