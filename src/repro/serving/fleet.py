"""Fault-tolerant multi-replica serving fleet.

:class:`Fleet` runs N :class:`~repro.serving.engine.Engine` replicas
behind the engine's own ``submit`` / ``tick`` / ``poll`` facade and
turns one fragile engine into a service that survives replica failure:

* **Health model** — each replica carries a liveness state machine
  (``healthy → degraded → dead``, plus ``draining → drained`` for
  rolling restarts) driven by tick progress and a step-wall EWMA: a
  tick whose wall blows past ``degrade_factor ×`` the EWMA marks the
  replica degraded (routed around, still serving); ``hang_ticks``
  consecutive ticks with work but zero progress — or past the optional
  ``tick_budget_s`` watchdog — declare it dead.
* **Failover by replay** — the fleet keeps a request journal (the
  original prompt plus every token already delivered). When a replica
  dies, its in-flight requests are reconstructed from the journal and
  re-submitted to a survivor as ``prompt + delivered_tokens`` with the
  remaining token budget — the same teacher-forced replay the engine's
  own preemption resume uses, so greedy output is token-identical to an
  undisturbed run and **no request is silently lost**.
* **Routing** — ``serving/router.py``: prefix-affinity first (follow-ups
  land on the replica holding their prefix pages), healthy before
  degraded, least-loaded fallback, and a per-replica circuit breaker
  that sheds to the fleet queue while open.
* **Hedging** — an unstarted request that has waited longer than the
  fleet's observed p99 TTFT (or ``hedge_delay_s``) is duplicated to a
  second replica; the first copy to produce a token is *bound* and the
  loser cancelled through the idempotent ``Engine.cancel``. Dedup is
  structural: tokens are only ever copied from the bound assignment, so
  every token is delivered exactly once.
* **Drain / rejoin** — ``drain(rid)`` stops new dispatches and lets the
  replica finish its streams (``draining → drained``); ``rejoin(rid)``
  rebuilds a fresh engine in place (also how a dead replica returns).

Fleet fault sites (registered into the ``serving/faults.py``
catalogue): ``replica_crash`` (kill replica ``/rid`` at fleet tick
``@n``), ``replica_hang`` (the replica stops making progress until the
watchdog declares it dead), ``router_drop`` (a routed submit is lost in
flight; the fleet's probe notices the journal entry missing from the
replica and re-dispatches). The same seeded ``Faults`` schedule drives
engine-level sites inside every replica, so one chaos string exercises
the whole stack deterministically (``benchmarks/check_fleet.py``).
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

import numpy as np

from repro.serving import faults as faults_mod
from repro.serving import telemetry
from repro.serving.engine import Engine
from repro.serving.request import Request, Response

__all__ = ["Fleet", "Replica", "HEALTHY", "DEGRADED", "DEAD",
           "DRAINING", "DRAINED", "FLEET_SITES"]

# replica health states (gauge encoding in parentheses)
HEALTHY = "healthy"      # (0) full service
DEGRADED = "degraded"    # (1) serving, routed around when possible
DEAD = "dead"            # (2) failed over, awaiting rejoin
DRAINING = "draining"    # (3) no new work, finishing its streams
DRAINED = "drained"      # (4) empty and parked, awaiting rejoin
_HEALTH_CODE = {HEALTHY: 0, DEGRADED: 1, DEAD: 2, DRAINING: 3,
                DRAINED: 4}

FLEET_SITES = ("replica_crash", "replica_hang", "router_drop")
for _s in FLEET_SITES:
    faults_mod.register_site(_s)


class Replica:
    """One engine plus its health bookkeeping."""

    def __init__(self, rid: int, engine: Engine):
        self.rid = rid
        self.engine: Optional[Engine] = engine
        self.state = HEALTHY
        self.ewma_s: Optional[float] = None   # per-tick wall EWMA
        self.ticks = 0
        self.stall_strikes = 0    # consecutive no-progress ticks
        self.overruns = 0         # wall-budget blowouts (lifetime)
        self.hung = False         # replica_hang fault in effect
        self.death_reason = ""

    @property
    def alive(self) -> bool:
        return self.state in (HEALTHY, DEGRADED, DRAINING)

    @property
    def routable(self) -> bool:
        return self.state in (HEALTHY, DEGRADED)


@dataclass
class _Assignment:
    """One copy of a request living on one replica."""
    rid: int
    base: int                 # fleet tokens already delivered at dispatch
    dispatched_s: float
    hedge: bool = False
    dropped: bool = False     # lost/cancelled/failed-over: ignore it


@dataclass
class _Entry:
    """Journal record: everything needed to replay the request."""
    req: Request
    resp: Response
    assigns: List[_Assignment] = field(default_factory=list)
    bound: Optional[int] = None   # rid whose copy owns the output stream

    @property
    def live(self) -> List[_Assignment]:
        return [a for a in self.assigns if not a.dropped]


class Fleet:
    """N engine replicas behind one ``submit``/``tick``/``poll`` facade
    (see module docstring for the resilience model).

    ``engine_kwargs`` is forwarded to every replica's ``Engine(...)``;
    ``faults`` (schedule, spec string, or ``None`` for the environment
    default) drives fleet sites here and engine sites inside every
    replica; ``trace=True`` gives each replica a tracing recorder and
    enables the merged multi-process ``export_trace``."""

    def __init__(self, model, params, *, replicas: int = 2,
                 engine_kwargs: Optional[Dict[str, Any]] = None,
                 hedge: bool = False,
                 hedge_delay_s: Optional[float] = None,
                 hedge_min_wait_s: float = 0.05,
                 ewma_alpha: float = 0.3, degrade_factor: float = 4.0,
                 hang_ticks: int = 5,
                 tick_budget_s: Optional[float] = None,
                 max_outstanding: Optional[int] = None,
                 affinity_tokens: int = 16,
                 breaker_threshold: int = 3, breaker_cooldown: int = 8,
                 faults: Any = None, trace: bool = False):
        from repro.serving.router import Router

        if replicas < 1:
            raise ValueError(f"fleet needs >= 1 replica, got {replicas}")
        self._t0 = time.perf_counter()
        self.model, self.params = model, params
        self.engine_kwargs = dict(engine_kwargs or {})
        self.engine_kwargs.pop("recorder", None)
        self.trace = bool(trace)

        if faults is None:
            faults = faults_mod.from_env()
        elif isinstance(faults, str):
            faults = faults_mod.Faults.parse(faults)
        self.faults = faults or faults_mod.NoFaults()

        self.hedge = bool(hedge)
        self.hedge_delay_s = hedge_delay_s
        self.hedge_min_wait_s = float(hedge_min_wait_s)
        self.ewma_alpha = float(ewma_alpha)
        self.degrade_factor = float(degrade_factor)
        self.hang_ticks = max(1, int(hang_ticks))
        self.tick_budget_s = tick_budget_s

        self.router = Router(affinity_tokens=affinity_tokens,
                             breaker_threshold=breaker_threshold,
                             breaker_cooldown=breaker_cooldown)
        self.metrics = telemetry.MetricsRegistry()
        self._c = {name: self.metrics.counter(name) for name in (
            "dispatches", "failovers", "requests_migrated",
            "hedges_issued", "hedges_won", "hedges_wasted",
            "router_drops", "redispatches", "replica_deaths",
            "drains", "rejoins", "fleet_timeouts",
            "fleet_cancellations", "fleet_errors")}
        self._ttft = self.metrics.histogram("fleet_ttft_s")
        self.metrics.add_collector(self.router.stats)
        if self.faults.enabled:
            self.metrics.add_collector(self.faults.stats)

        self.replicas: List[Replica] = [
            Replica(rid, self._new_engine()) for rid in range(replicas)]
        self._entries: Dict[int, _Entry] = {}
        self.queue: deque = deque()       # uids awaiting dispatch
        self._ticks = 0
        self._starved = 0                 # ticks with work but no capacity
        self._events: List[Dict[str, Any]] = []   # fleet trace lane
        if max_outstanding is None:
            mb = int(self.engine_kwargs.get("max_batch", 8))
            max_outstanding = 2 * mb
        self.max_outstanding = max(1, int(max_outstanding))
        self._refresh_gauges()

    # ---------------------------------------------------------------- #
    # construction / lifecycle
    # ---------------------------------------------------------------- #
    def _new_engine(self) -> Engine:
        return Engine(self.model, self.params,
                      faults=self.faults if self.faults.enabled
                      else faults_mod.NoFaults(),
                      recorder=self.trace, **self.engine_kwargs)

    def _event(self, name: str, **args) -> None:
        self._events.append({"ts": time.perf_counter(), "name": name,
                             "args": args})

    def replica(self, rid: int) -> Replica:
        if not 0 <= rid < len(self.replicas):
            raise ValueError(f"no replica {rid} "
                             f"(fleet size {len(self.replicas)})")
        return self.replicas[rid]

    def drain(self, rid: int) -> None:
        """Stop routing new work to ``rid``; its live streams finish in
        place, then the replica parks as ``drained`` (rolling-restart
        half one; ``rejoin`` is half two)."""
        r = self.replica(rid)
        if not r.alive:
            raise ValueError(f"replica {rid} is {r.state}: cannot drain")
        if r.state != DRAINING:
            r.state = DRAINING
            self._c["drains"].inc()
            self._event("drain", rid=rid)
            self._refresh_gauges()

    def rejoin(self, rid: int) -> None:
        """Bring a dead/drained replica back with a **fresh** engine
        (rolling-restart semantics: old cache state is gone, the breaker
        closes, affinity hints for it were already dropped)."""
        r = self.replica(rid)
        if r.alive and r.state != DRAINING:
            raise ValueError(f"replica {rid} is {r.state}: nothing to "
                             "rejoin")
        r.engine = self._new_engine()
        r.state = HEALTHY
        r.ewma_s, r.ticks = None, 0
        r.stall_strikes, r.hung, r.death_reason = 0, False, ""
        self.router.breaker(rid).reset()
        self._c["rejoins"].inc()
        self._event("rejoin", rid=rid)
        self._refresh_gauges()

    def _kill(self, rid: int, why: str) -> None:
        r = self.replicas[rid]
        if r.state == DEAD:
            return
        r.state = DEAD
        r.death_reason = why
        self._c["replica_deaths"].inc()
        self.router.breaker(rid).force_open()
        self.router.forget_replica(rid)
        self._event("replica_dead", rid=rid, why=why)
        self._failover(rid)
        self._refresh_gauges()

    # ---------------------------------------------------------------- #
    # public request API (mirrors Engine)
    # ---------------------------------------------------------------- #
    @property
    def responses(self) -> Dict[int, Response]:
        return {uid: e.resp for uid, e in self._entries.items()}

    @property
    def has_work(self) -> bool:
        return any(not e.resp.finished for e in self._entries.values())

    def submit(self, req: Request) -> None:
        """Validate and journal a request; dispatch happens on the next
        ``tick``. Raises ``ValueError`` for malformed requests (same
        host-boundary contract as ``Engine.submit``)."""
        prompt = np.asarray(req.prompt)
        if prompt.ndim != 1 or prompt.size == 0:
            raise ValueError(f"request {req.uid}: prompt must be a "
                             f"non-empty 1-D token array, got shape "
                             f"{prompt.shape}")
        if prompt.dtype.kind not in "iu":
            raise ValueError(f"request {req.uid}: prompt must hold "
                             f"integer token ids, got {prompt.dtype}")
        if req.max_new_tokens <= 0:
            raise ValueError(f"request {req.uid}: max_new_tokens must "
                             f"be positive, got {req.max_new_tokens}")
        if req.deadline_s is not None and req.deadline_s <= 0:
            raise ValueError(f"request {req.uid}: deadline_s must be "
                             f"positive, got {req.deadline_s}")
        old = self._entries.get(req.uid)
        if old is not None and not old.resp.finished:
            raise ValueError(f"request uid {req.uid} is already in "
                             "flight")
        req.submitted_s = time.perf_counter()
        self._entries[req.uid] = _Entry(
            req=req, resp=Response(uid=req.uid,
                                   prompt_len=int(prompt.size)))
        self.queue.append(req.uid)

    def cancel(self, uid: int) -> bool:
        """Cancel in any live state (idempotent: unknown/finished uids
        return ``False``). Live copies on replicas are cancelled through
        ``Engine.cancel``; tokens already delivered stay in the
        response."""
        e = self._entries.get(uid)
        if e is None or e.resp.finished:
            return False
        if uid in self.queue:
            self.queue.remove(uid)
        for a in e.live:
            r = self.replicas[a.rid]
            if r.alive and r.engine is not None:
                r.engine.cancel(uid)
            a.dropped = True
        self._finish(e, "cancelled")
        self._c["fleet_cancellations"].inc()
        return True

    # ---------------------------------------------------------------- #
    # the tick pipeline
    # ---------------------------------------------------------------- #
    def tick(self, steps: Optional[int] = None) -> int:
        """Advance the fleet: sweep fleet-queue deadlines, fire fleet
        faults, detect lost dispatches, fail over dead replicas'
        journal entries, dispatch + hedge, tick every live replica
        (wall-timed for the health model), harvest tokens, settle
        drains. Returns total engine steps made this tick."""
        self._ticks += 1
        now = time.perf_counter()
        self._sweep_queue_deadlines(now)
        self._fire_fleet_faults()
        self.router.tick()
        self._probe_drops()
        self._dispatch_pass(now)
        made = self._tick_replicas(steps)
        self._harvest()
        self._settle_drains()
        self._starvation_valve()
        self._refresh_gauges()
        return made

    def poll(self) -> Dict[int, Response]:
        """Harvest without advancing: copy any freshly produced tokens
        out of the replicas into the fleet responses."""
        self._harvest()
        return self.responses

    def run(self, max_steps: int = 100_000,
            sync_every: Optional[int] = None) -> Dict[int, Response]:
        steps = 0
        while self.has_work and steps < max_steps:
            steps += max(1, self.tick(sync_every))
        return self.responses

    # -- deadline sweep (fleet queue: never admitted anywhere) -------- #
    def _sweep_queue_deadlines(self, now: float) -> None:
        for uid in [u for u in self.queue
                    if self._entries[u].req.deadline_abs() <= now]:
            self.queue.remove(uid)
            e = self._entries[uid]
            self._finish(e, "timeout")
            self._c["fleet_timeouts"].inc()

    # -- fleet fault sites ------------------------------------------- #
    def _fire_fleet_faults(self) -> None:
        if not self.faults.enabled:
            return
        spec = self.faults.fire("replica_crash", step=self._ticks)
        if spec is not None:
            rid = spec.slot if spec.slot is not None else next(
                (r.rid for r in self.replicas if r.alive), None)
            if rid is not None and self.replicas[rid].alive:
                self._event("fault_replica_crash", rid=rid,
                            tick=self._ticks)
                self._kill(rid, "crash")
        spec = self.faults.fire("replica_hang", step=self._ticks)
        if spec is not None:
            rid = spec.slot if spec.slot is not None else next(
                (r.rid for r in self.replicas if r.alive), None)
            if rid is not None and self.replicas[rid].alive:
                self.replicas[rid].hung = True
                self._event("fault_replica_hang", rid=rid,
                            tick=self._ticks)

    # -- lost-dispatch probe ----------------------------------------- #
    def _probe_drops(self) -> None:
        """A dispatch can be lost in flight (``router_drop``): the
        journal says the request lives on replica ``rid`` but the
        replica has never heard of the uid. Drop the assignment and
        requeue at the front (re-dispatch, not re-arrival)."""
        for uid, e in self._entries.items():
            if e.resp.finished:
                continue
            for a in e.live:
                r = self.replicas[a.rid]
                if not r.alive or r.engine is None:
                    continue
                if uid not in r.engine.responses:
                    a.dropped = True
                    self._c["router_drops"].inc()
                    if e.bound == a.rid:
                        e.bound = None
                    if not e.live and uid not in self.queue:
                        self.queue.appendleft(uid)
                        self._c["redispatches"].inc()
                        self._event("redispatch", uid=uid, rid=a.rid)

    # -- failover ----------------------------------------------------- #
    def _failover(self, rid: int) -> None:
        """Reconstruct the dead replica's in-flight requests from the
        journal: every unfinished entry whose only live copy was on
        ``rid`` goes back to the *front* of the fleet queue and will be
        re-dispatched as prompt + delivered tokens (resume-by-replay —
        greedy output stays token-identical)."""
        moved = 0
        for uid, e in self._entries.items():
            if e.resp.finished:
                continue
            touched = False
            for a in e.live:
                if a.rid == rid:
                    a.dropped = True
                    touched = True
            if not touched:
                continue
            if e.bound == rid:
                e.bound = None       # a surviving hedge may now bind
            if not e.live and uid not in self.queue:
                self.queue.appendleft(uid)
                e.req.preemptions += 1
                moved += 1
        if moved:
            self._c["requests_migrated"].inc(moved)
        self._c["failovers"].inc()
        self._event("failover", rid=rid, migrated=moved)

    # -- dispatch + hedging ------------------------------------------- #
    def _outstanding(self, rid: int) -> int:
        return sum(1 for e in self._entries.values()
                   if not e.resp.finished
                   for a in e.live if a.rid == rid)

    def _candidates(self) -> List[tuple]:
        cands = []
        for r in self.replicas:
            if not r.routable or r.engine is None:
                continue
            out = self._outstanding(r.rid)
            if out >= self.max_outstanding:
                continue
            rank = 0 if r.state == HEALTHY else 1
            cands.append((r.rid, rank, out + len(r.engine.queue)))
        return cands

    def _dispatch(self, e: _Entry, rid: int, hedge: bool) -> bool:
        """Submit one copy of the journal entry to replica ``rid``,
        replaying any already-delivered tokens as prompt suffix."""
        r = self.replicas[rid]
        delivered = len(e.resp.tokens)
        prompt = np.asarray(e.req.prompt)
        if delivered:
            prompt = np.concatenate(
                [prompt, np.asarray(e.resp.tokens, prompt.dtype)])
        now = time.perf_counter()
        remaining = e.req.deadline_abs() - now
        if remaining <= 0:
            self._finish(e, "timeout")
            self._c["fleet_timeouts"].inc()
            return False
        copy = Request(
            uid=e.req.uid, prompt=prompt,
            max_new_tokens=e.req.max_new_tokens - delivered,
            eos_id=e.req.eos_id, embeddings=e.req.embeddings,
            deadline_s=None if e.req.deadline_s is None else remaining,
            priority=e.req.priority)
        if self.faults.enabled and not hedge and self.faults.fire(
                "router_drop", step=self._ticks) is not None:
            # the submit is lost in flight: journal says rid, replica
            # never hears of it — the probe notices and re-dispatches
            e.assigns.append(_Assignment(rid=rid, base=delivered,
                                         dispatched_s=now, hedge=hedge))
            self._event("router_drop", uid=e.req.uid, rid=rid)
            return True
        try:
            r.engine.submit(copy)
        except ValueError as err:
            # a replay that no longer fits this replica (or malformed
            # growth) must not wedge the fleet: fail the request loudly
            self._finish(e, "error")
            self._c["fleet_errors"].inc()
            self._event("dispatch_error", uid=e.req.uid, rid=rid,
                        err=str(err))
            return False
        e.assigns.append(_Assignment(rid=rid, base=delivered,
                                     dispatched_s=now, hedge=hedge))
        self.router.note_dispatch(e.req.prompt, rid)
        self._c["dispatches"].inc()
        return True

    def _dispatch_pass(self, now: float) -> None:
        guard = len(self.queue)
        while self.queue and guard > 0:
            guard -= 1
            uid = self.queue[0]
            e = self._entries[uid]
            if e.resp.finished:
                self.queue.popleft()
                continue
            rid = self.router.route(
                e.req.prompt, self._candidates(),
                exclude=[a.rid for a in e.live])
            if rid is None:
                break                 # no capacity / breakers open: wait
            self.queue.popleft()
            self._dispatch(e, rid, hedge=False)
        if self.hedge:
            self._hedge_pass(now)

    def _hedge_delay(self) -> float:
        if self.hedge_delay_s is not None:
            return self.hedge_delay_s
        if len(self._ttft.samples) >= 8:
            return max(self.hedge_min_wait_s,
                       telemetry.percentile(self._ttft.samples, 99))
        return self.hedge_min_wait_s

    def _hedge_pass(self, now: float) -> None:
        delay = self._hedge_delay()
        for uid, e in self._entries.items():
            if e.resp.finished or e.bound is not None or e.resp.tokens:
                continue
            live = e.live
            if len(live) != 1 or live[0].hedge:
                continue
            if now - live[0].dispatched_s < delay:
                continue
            rid = self.router.route(e.req.prompt, self._candidates(),
                                    exclude=[live[0].rid])
            if rid is None:
                continue
            if self._dispatch(e, rid, hedge=True):
                self._c["hedges_issued"].inc()
                self._event("hedge", uid=uid, rid=rid)

    # -- replica ticking + health ------------------------------------- #
    def _tick_replicas(self, steps: Optional[int]) -> int:
        made = 0
        for r in self.replicas:
            if not r.alive or r.engine is None:
                continue
            if r.hung:
                # a wedged worker never returns from its tick: the
                # watchdog sees work pending and zero progress
                if r.engine.has_work or self._outstanding(r.rid):
                    self._strike(r)
                continue
            had_work = r.engine.has_work
            t0 = time.perf_counter()
            n = r.engine.tick(steps)
            wall = time.perf_counter() - t0
            made += n
            r.ticks += 1
            self._health_update(r, wall, had_work, n)
        return made

    def _strike(self, r: Replica) -> None:
        r.stall_strikes += 1
        if r.stall_strikes >= self.hang_ticks:
            self._kill(r.rid, "hang")

    def _health_update(self, r: Replica, wall: float, had_work: bool,
                       n_steps: int) -> None:
        if had_work and n_steps == 0:
            self._strike(r)
            if not r.alive:
                return
        else:
            r.stall_strikes = 0
        if self.tick_budget_s is not None and wall > self.tick_budget_s:
            r.overruns += 1
            self._strike(r)
            if not r.alive:
                return
        prev = r.ewma_s
        a = self.ewma_alpha
        r.ewma_s = wall if prev is None else a * wall + (1 - a) * prev
        if prev is None or r.ticks < 3 or not had_work:
            return
        if wall > self.degrade_factor * prev:
            r.overruns += 1
            if r.state == HEALTHY:
                r.state = DEGRADED
                self._event("degraded", rid=r.rid, wall_s=round(wall, 6))
        elif r.state == DEGRADED and wall <= self.degrade_factor * prev:
            r.state = HEALTHY
            self._event("recovered", rid=r.rid)

    # -- harvest (exactly-once token delivery) ------------------------- #
    def _harvest(self) -> None:
        for uid, e in self._entries.items():
            if e.resp.finished:
                continue
            order = sorted(e.live, key=lambda a: a.hedge)  # primary 1st
            for a in order:
                if e.bound is not None and a.rid != e.bound:
                    continue
                r = self.replicas[a.rid]
                if not r.alive or r.engine is None:
                    continue
                er = r.engine.responses.get(uid)
                if er is None:
                    continue
                # alignment: this copy regenerated fleet tokens [base:],
                # so only tokens past what the fleet already delivered
                # are new. Greedy replay makes the overlap identical.
                new = er.tokens[len(e.resp.tokens) - a.base:]
                if new:
                    if e.bound is None:
                        self._bind(e, a)
                    if e.bound == a.rid:
                        first = not e.resp.tokens
                        e.resp.tokens.extend(new)
                        if first and not e.req.first_token_s:
                            e.req.first_token_s = time.perf_counter()
                            self._ttft.observe(e.req.first_token_s
                                               - e.req.submitted_s)
                if er.finished and (e.bound in (None, a.rid)):
                    self._settle_terminal(e, a, er)
                if e.resp.finished:
                    break

    def _bind(self, e: _Entry, winner: _Assignment) -> None:
        """First token wins: this copy owns the output stream from now
        on; every other live copy is cancelled (idempotent) and
        dropped — tokens can never be delivered twice."""
        e.bound = winner.rid
        if winner.hedge:
            self._c["hedges_won"].inc()
            self._event("hedge_won", uid=e.req.uid, rid=winner.rid)
        for a in e.live:
            if a is winner:
                continue
            r = self.replicas[a.rid]
            if r.alive and r.engine is not None:
                r.engine.cancel(e.req.uid)
            a.dropped = True
            if a.hedge:
                self._c["hedges_wasted"].inc()

    def _settle_terminal(self, e: _Entry, a: _Assignment,
                         er: Response) -> None:
        reason = er.finish_reason
        if reason in ("eos", "length"):
            if e.bound is None:
                self._bind(e, a)
            if e.bound == a.rid:
                self._finish(e, reason)
                self.router.breaker(a.rid).record_success()
            return
        if reason == "cancelled":
            a.dropped = True         # our own loser-cancel echoing back
            return
        # error / timeout on this copy: drop it; another live copy may
        # still win, otherwise the failure is the request's outcome
        a.dropped = True
        if e.bound == a.rid:
            e.bound = None
        if reason == "error":
            self.router.breaker(a.rid).record_failure()
        if not e.live:
            self._finish(e, reason)
            self._c["fleet_errors" if reason == "error"
                    else "fleet_timeouts"].inc()

    def _finish(self, e: _Entry, reason: str) -> None:
        e.resp.finished = True
        e.resp.finish_reason = reason
        e.req.finished_s = time.perf_counter()
        self._event("finish", uid=e.req.uid, reason=reason)

    # -- drain / starvation ------------------------------------------- #
    def _settle_drains(self) -> None:
        for r in self.replicas:
            if r.state != DRAINING or r.engine is None:
                continue
            if not r.engine.has_work and not self._outstanding(r.rid):
                r.state = DRAINED
                self._event("drained", rid=r.rid)

    def _starvation_valve(self) -> None:
        """Terminal backstop: when no routable replica exists, queued
        work can never be served — after ``hang_ticks`` such ticks the
        fleet fails the stuck requests loudly (finish_reason ``error``)
        instead of spinning forever. If *nothing* is alive, in-flight
        entries are unrecoverable too."""
        routable = any(r.routable for r in self.replicas)
        alive = any(r.alive for r in self.replicas)
        stuck = bool(self.queue) or (not alive and self.has_work)
        if routable or not stuck:
            self._starved = 0
            return
        self._starved += 1
        if self._starved < self.hang_ticks:
            return
        doomed = [self._entries[u] for u in list(self.queue)]
        self.queue.clear()
        if not alive:
            doomed += [e for e in self._entries.values()
                       if not e.resp.finished]
        for e in doomed:
            if not e.resp.finished:
                self._finish(e, "error")
                self._c["fleet_errors"].inc()

    # ---------------------------------------------------------------- #
    # stats / steady-state / tracing
    # ---------------------------------------------------------------- #
    def _refresh_gauges(self) -> None:
        for r in self.replicas:
            self.metrics.gauge(f"replica_{r.rid}_health").set(
                _HEALTH_CODE[r.state])
        self.metrics.gauge("fleet_queue_depth").set(len(self.queue))
        self.metrics.gauge("fleet_inflight").set(
            sum(1 for e in self._entries.values()
                if not e.resp.finished and e.live))
        self.metrics.gauge("replicas_routable").set(
            sum(1 for r in self.replicas if r.routable))

    def reset_stats(self) -> None:
        """Fleet analogue of ``Engine.reset_stats``: drop finished
        journal entries and fleet metrics, and reset every live replica
        (which also **arms each recompile watchdog** — the steady-state
        boundary for chaos gates)."""
        self.metrics.reset()
        self.router.affinity_hits = 0
        self.router.sheds = 0
        for uid in [u for u, e in self._entries.items()
                    if e.resp.finished]:
            del self._entries[uid]
        self._events.clear()
        for r in self.replicas:
            if r.alive and r.engine is not None:
                r.engine.reset_stats()
        self._refresh_gauges()

    def mark_steady(self) -> None:
        for r in self.replicas:
            if r.alive and r.engine is not None:
                r.engine.mark_steady()

    def steady_compiles(self) -> Dict[int, int]:
        """Per-replica steady-state compile counts (the no-recompile
        gate, per replica)."""
        out: Dict[int, int] = {}
        for r in self.replicas:
            if r.engine is not None:
                out[r.rid] = int(r.engine.metrics.snapshot()["counters"]
                                 .get("steady_compiles", 0))
        return out

    def latency_stats(self) -> Dict[str, Any]:
        """Fleet summary: fleet counters/gauges, fleet TTFT
        percentiles, and each replica's own ``latency_stats`` under
        ``replica_{rid}``."""
        snap = self.metrics.snapshot()
        stats: Dict[str, Any] = dict(snap["counters"])
        stats.update({f"gauge_{k}": v for k, v in snap["gauges"].items()})
        telemetry.pct_stats(stats, "fleet_ttft_ms", self._ttft.samples,
                            (50, 95, 99))
        n_fin = sum(1 for e in self._entries.values() if e.resp.finished)
        stats["n_finished"] = n_fin
        for r in self.replicas:
            if r.engine is not None:
                stats[f"replica_{r.rid}"] = r.engine.latency_stats()
            stats[f"replica_{r.rid}_state"] = r.state
        return stats

    def export_trace(self, path: Optional[str] = None) -> Dict[str, Any]:
        """Merged Chrome trace: one process lane per replica (pid
        ``100 + rid``) plus a fleet lane (pid 99) of orchestration
        instants (health transitions, failovers, hedges, drains).
        Requires ``Fleet(..., trace=True)``."""
        from repro.serving.tracing import merge_chrome_traces
        parts = []
        for r in self.replicas:
            exp = getattr(r.engine, "recorder", None)
            exp = getattr(exp, "export_chrome_trace", None)
            if r.engine is None or exp is None:
                continue
            off = (r.engine.recorder.t0 - self._t0) * 1e6
            parts.append((f"replica {r.rid}", 100 + r.rid, exp(), off))
        if not parts:
            raise RuntimeError("export_trace needs Fleet(..., "
                               "trace=True)")
        fleet_events = [
            {"name": ev["name"], "ph": "i",
             "ts": round((ev["ts"] - self._t0) * 1e6, 1),
             "pid": 99, "tid": 0, "s": "t", "args": ev["args"]}
            for ev in self._events]
        return merge_chrome_traces(parts, extra=fleet_events,
                                   extra_label="fleet", extra_pid=99,
                                   path=path)
