"""Replica selection for the serving fleet: prefix affinity, load, breakers.

The :class:`Router` answers one question for `serving/fleet.py`: *which
replica should serve this request now?* Its policy, in priority order:

1. **Prefix affinity** — a request whose prompt head was already served
   by some replica routes back to it (the replica's paged prefix cache
   holds those KV pages, so admission aliases instead of recomputing).
   The affinity key is the first ``affinity_tokens`` prompt tokens; the
   map is written on every successful dispatch.
2. **Health ranking** — healthy replicas are preferred over degraded
   ones; dead/draining replicas and replicas whose circuit breaker is
   open are never candidates (the fleet sheds their traffic back to the
   fleet queue instead of piling onto a failing endpoint).
3. **Least-loaded fallback** — among equally-ranked candidates, the one
   with the fewest outstanding streams wins (ties break on replica id,
   keeping routing deterministic for a deterministic arrival order).

The :class:`CircuitBreaker` is the standard three-state machine
(closed → open on ``failure_threshold`` consecutive failures → half-open
after ``cooldown_ticks`` fleet ticks → closed again on one success,
reopened on one failure). The fleet records a failure when a replica
stream errors or the replica dies, and a success on every normal
completion.
"""
from __future__ import annotations

from typing import Dict, Iterable, List, Optional, Sequence, Tuple

__all__ = ["CircuitBreaker", "Router"]


class CircuitBreaker:
    """Per-replica failure breaker, advanced by fleet ticks (not wall
    time: ticks are the fleet's deterministic clock)."""

    CLOSED, OPEN, HALF_OPEN = "closed", "open", "half_open"

    def __init__(self, failure_threshold: int = 3,
                 cooldown_ticks: int = 8):
        self.failure_threshold = max(1, int(failure_threshold))
        self.cooldown_ticks = max(1, int(cooldown_ticks))
        self.state = self.CLOSED
        self.failures = 0          # consecutive failures while closed
        self._cooldown = 0
        self.opens = 0             # lifetime open transitions

    def record_failure(self) -> None:
        if self.state == self.HALF_OPEN:
            self._trip()
            return
        self.failures += 1
        if self.state == self.CLOSED \
                and self.failures >= self.failure_threshold:
            self._trip()

    def record_success(self) -> None:
        self.failures = 0
        if self.state == self.HALF_OPEN:
            self.state = self.CLOSED

    def force_open(self) -> None:
        """Trip unconditionally (replica declared dead)."""
        self._trip()

    def _trip(self) -> None:
        if self.state != self.OPEN:
            self.opens += 1
        self.state = self.OPEN
        self._cooldown = self.cooldown_ticks
        self.failures = 0

    def tick(self) -> None:
        """One fleet tick elapsed: an open breaker cools toward
        half-open (one probe request allowed through)."""
        if self.state == self.OPEN:
            self._cooldown -= 1
            if self._cooldown <= 0:
                self.state = self.HALF_OPEN

    @property
    def allows(self) -> bool:
        return self.state != self.OPEN

    def reset(self) -> None:
        """Back to closed (replica rejoined with a fresh engine)."""
        self.state = self.CLOSED
        self.failures = 0
        self._cooldown = 0


class Router:
    """Prefix-affinity + least-loaded replica selection (policy above).

    The router is pure host bookkeeping: the fleet passes it candidate
    ``(rid, rank, load)`` tuples each dispatch (rank 0 = healthy,
    1 = degraded; dead/draining replicas are never offered) and it
    returns the chosen rid or ``None`` when every candidate's breaker
    is open."""

    def __init__(self, affinity_tokens: int = 16,
                 breaker_threshold: int = 3, breaker_cooldown: int = 8):
        self.affinity_tokens = max(1, int(affinity_tokens))
        self._breakers: Dict[int, CircuitBreaker] = {}
        self._bt = breaker_threshold
        self._bc = breaker_cooldown
        # affinity key (prompt-head tuple) -> rid of last dispatch
        self.affinity: Dict[Tuple[int, ...], int] = {}
        self.affinity_hits = 0
        self.sheds = 0             # dispatches refused (breakers open)

    def breaker(self, rid: int) -> CircuitBreaker:
        b = self._breakers.get(rid)
        if b is None:
            b = self._breakers[rid] = CircuitBreaker(self._bt, self._bc)
        return b

    def tick(self) -> None:
        for b in self._breakers.values():
            b.tick()

    def key(self, prompt) -> Tuple[int, ...]:
        return tuple(int(t) for t in prompt[: self.affinity_tokens])

    def route(self, prompt,
              candidates: Sequence[Tuple[int, int, int]],
              exclude: Iterable[int] = ()) -> Optional[int]:
        """Pick a replica for ``prompt`` from ``candidates`` (tuples of
        ``(rid, rank, load)``), skipping ``exclude`` (rids that already
        hold a live copy of this request — hedges and failover must land
        elsewhere). Returns ``None`` when nothing is routable."""
        excl = set(exclude)
        open_cands = [(rid, rank, load) for rid, rank, load in candidates
                      if rid not in excl and self.breaker(rid).allows]
        if not open_cands:
            if any(rid not in excl for rid, _, _ in candidates):
                self.sheds += 1
            return None
        key = self.key(prompt)
        want = self.affinity.get(key)
        if want is not None:
            for rid, _, _ in open_cands:
                if rid == want:
                    self.affinity_hits += 1
                    return rid
        rid = min(open_cands, key=lambda c: (c[1], c[2], c[0]))[0]
        return rid

    def note_dispatch(self, prompt, rid: int) -> None:
        """Record where this prompt head now lives (its prefix pages)."""
        self.affinity[self.key(prompt)] = rid

    def forget_replica(self, rid: int) -> None:
        """Drop affinity entries for a dead/rebuilt replica — its prefix
        pages are gone, so the hint would only mislead."""
        self.affinity = {k: v for k, v in self.affinity.items()
                         if v != rid}

    def stats(self) -> Dict[str, float]:
        out: Dict[str, float] = {
            "affinity_hits": self.affinity_hits,
            "affinity_entries": len(self.affinity),
            "router_sheds": self.sheds,
        }
        for rid, b in sorted(self._breakers.items()):
            out[f"breaker_{rid}_state"] = b.state
            out[f"breaker_{rid}_opens"] = b.opens
        return out
