"""Deterministic, seeded fault injection for the serving stack.

Resilience claims are untestable without a way to *cause* the failures
they guard against. This module is the single fault switchboard: a
registry of named **sites** — places in the engine, transport and
checkpoint layers that ask "should I fail here?" — driven by a seeded
schedule so every chaos run is reproducible bit-for-bit.

Design follows the ``telemetry.Recorder`` pattern: the default
(:class:`NoFaults`) is a no-op whose ``enabled`` flag short-circuits
every hook to one attribute read, so an engine built without faults has
bit-identical programs, outputs and compiled-program counts to one built
with them (asserted in ``tests/test_chaos.py``). Injection never changes
*program shapes*: the NaN site, for example, fires through the engine's
always-present ``poison`` input rather than a recompiled variant.

Fault sites
-----------
``page_alloc``           one KV page-pool allocation reports exhaustion
                         (the engine degrades: prefix reclaim, then
                         preemptive requeue, never a crash mid-decode);
``nan_logits``           slot ``k``'s sampler logits are poisoned to NaN
                         at engine step ``n`` (the on-device guard must
                         contain it to that slot);
``slow_step``            ``delay_s`` of host stall before a step
                         dispatch (exercises deadline enforcement);
``transport_drop``       one ``Transport.fetch``/``push`` attempt fails
                         (exercises retry + backoff);
``transport_latency``    ``delay_s`` added to a transfer's modelled
                         seconds (exercises timeouts);
``truncated_checkpoint`` a just-written checkpoint loses its tail
                         (``truncate_file``; exercises fail-fast load
                         validation).

Other layers register their own sites into the same catalogue via
:func:`register_site` — ``serving/fleet.py`` adds ``replica_crash``
(a replica dies between ticks), ``replica_hang`` (a replica stops
making tick progress) and ``router_drop`` (a routed submit is lost
before reaching the replica). Unknown site names raise ``ValueError``
naming the nearest registered site.

Usage::

    faults = (Faults(seed=0)
              .on("nan_logits", step=12, slot=1)
              .on("page_alloc", step=30, times=2))
    eng = Engine(model, params, faults=faults)

or via the environment (picked up when ``Engine(faults=None)``)::

    REPRO_FAULTS="nan_logits@12/1,page_alloc@30x2,slow_step@5+0.05"

Grammar: comma-separated ``site[@step][/slot][xN][+delay][%prob]``.
"""
from __future__ import annotations

import dataclasses
import os
import re
from pathlib import Path
from typing import Any, Dict, List, Optional

import numpy as np

__all__ = ["FaultSpec", "NoFaults", "Faults", "SITES", "register_site",
           "known_sites", "truncate_file", "from_env", "ENV_VAR"]

ENV_VAR = "REPRO_FAULTS"

#: The registered site catalogue. Core sites live here; layers that add
#: their own sites (the fleet's ``replica_crash``/``replica_hang``/
#: ``router_drop``) call :func:`register_site` at import, so every
#: schedule — string, builder or env — validates against one list and a
#: typo like ``nan_logit`` fails fast naming the nearest known site.
SITES = {
    "page_alloc", "nan_logits", "slow_step",
    "transport_drop", "transport_latency", "truncated_checkpoint",
}


def register_site(name: str) -> str:
    """Add a fault site to the catalogue (idempotent). Subsystems that
    fire their own sites register them at import so ``Faults.parse``
    and ``Faults.on`` validate against the full set."""
    if not re.fullmatch(r"[a-z][a-z0-9_]*", name):
        raise ValueError(f"bad fault site name {name!r} "
                         "(want lowercase_snake_case)")
    SITES.add(name)
    return name


def known_sites() -> frozenset:
    """Snapshot of the currently registered site catalogue."""
    return frozenset(SITES)


def _unknown_site_error(name: str) -> ValueError:
    import difflib
    near = difflib.get_close_matches(name, sorted(SITES), n=1, cutoff=0.5)
    hint = f"; did you mean {near[0]!r}?" if near else ""
    return ValueError(f"unknown fault site {name!r}{hint} "
                      f"(registered sites: {sorted(SITES)})")


@dataclasses.dataclass
class FaultSpec:
    """One scheduled fault. ``step``/``attempt``/``op`` are *filters*
    (``None`` = match any call of the site); ``slot`` and ``delay_s``
    are *payloads* the firing site consumes; ``times`` bounds how often
    the spec fires (-1 = unlimited) and ``p`` makes firing probabilistic
    against the registry's seeded stream.

    ``step`` matches *at or after*: the engine's step counter can
    advance by more than one per dispatch round (a fused admission
    chunk rides the same round as the decode/spec step), so an exact
    value may never be observed — the spec fires on the first site
    call whose step is >= the scheduled one, bounded by ``times``."""
    site: str
    step: Optional[int] = None      # engine-step filter
    attempt: Optional[int] = None   # transport-attempt filter
    op: Optional[str] = None        # transport op filter ("fetch"/"push")
    slot: Optional[int] = None      # payload: target batch slot
    delay_s: float = 0.0            # payload: injected stall seconds
    times: int = 1                  # max firings (-1 = unlimited)
    p: float = 1.0                  # per-eligible-call fire probability
    fired: int = 0

    def __post_init__(self):
        if self.site not in SITES:
            raise _unknown_site_error(self.site)

    @property
    def exhausted(self) -> bool:
        return self.times >= 0 and self.fired >= self.times

    def matches(self, ctx: Dict[str, Any]) -> bool:
        if self.step is not None:
            got = ctx.get("step")
            if got is None or got < self.step:
                return False
        for key in ("attempt", "op"):
            want = getattr(self, key)
            if want is not None and ctx.get(key) != want:
                return False
        return True


class NoFaults:
    """The default: nothing ever fires. ``enabled`` is the hot-path
    short-circuit (one attribute read per site check)."""
    enabled = False

    def fire(self, site: str, **ctx) -> Optional[FaultSpec]:
        return None

    def stats(self) -> Dict[str, float]:
        return {}


class Faults(NoFaults):
    """A seeded fault schedule. ``fire(site, **ctx)`` returns the first
    matching, non-exhausted :class:`FaultSpec` (consuming one of its
    ``times``) or ``None``. All randomness (the ``p < 1`` dice) comes
    from one seeded generator, and the engine calls sites in a fixed
    host order — identical schedules replay identically."""
    enabled = True

    def __init__(self, seed: int = 0,
                 specs: Optional[List[FaultSpec]] = None):
        self.seed = int(seed)
        self.specs: List[FaultSpec] = list(specs or [])
        self._rng = np.random.default_rng(self.seed)
        self.fired_total = 0
        self.fired_by_site: Dict[str, int] = {}

    def on(self, site: str, **kw) -> "Faults":
        """Schedule a fault (chainable): ``Faults().on("nan_logits",
        step=12, slot=1).on("page_alloc", times=2)``."""
        self.specs.append(FaultSpec(site=site, **kw))
        return self

    def fire(self, site: str, **ctx) -> Optional[FaultSpec]:
        for spec in self.specs:
            if spec.site != site or spec.exhausted \
                    or not spec.matches(ctx):
                continue
            if spec.p < 1.0 and self._rng.random() >= spec.p:
                continue
            spec.fired += 1
            self.fired_total += 1
            self.fired_by_site[site] = self.fired_by_site.get(site, 0) + 1
            return spec
        return None

    def stats(self) -> Dict[str, float]:
        out: Dict[str, float] = {"faults_fired_total": self.fired_total}
        for site, n in sorted(self.fired_by_site.items()):
            out[f"faults_fired_{site}"] = n
        return out

    # ---------------------------------------------------------------- #
    @classmethod
    def parse(cls, text: str, seed: int = 0) -> "Faults":
        """Parse the compact schedule grammar (see module docstring):
        comma-separated ``site[@step][/slot][xN][+delay][%prob]``."""
        f = cls(seed=seed)
        pat = re.compile(
            r"^(?P<site>[a-z][a-z0-9_]*)"
            r"(?:@(?P<step>\d+))?"
            r"(?:/(?P<slot>\d+))?"
            r"(?:x(?P<times>-?\d+))?"
            r"(?:\+(?P<delay>[0-9.]+))?"
            r"(?:%(?P<p>[0-9.]+))?$")
        for entry in filter(None, (e.strip() for e in text.split(","))):
            m = pat.match(entry)
            if m is None:
                raise ValueError(f"bad fault spec {entry!r} (grammar: "
                                 "site[@step][/slot][xN][+delay][%prob])")
            g = m.groupdict()
            f.on(g["site"],
                 step=None if g["step"] is None else int(g["step"]),
                 slot=None if g["slot"] is None else int(g["slot"]),
                 times=1 if g["times"] is None else int(g["times"]),
                 delay_s=float(g["delay"] or 0.0),
                 p=float(g["p"] or 1.0))
        return f


def from_env(env: Optional[Dict[str, str]] = None):
    """Resolve the ambient fault schedule: ``REPRO_FAULTS`` parsed when
    set (``REPRO_FAULTS_SEED`` seeds it), else the :class:`NoFaults`
    singleton-ish default."""
    e = os.environ if env is None else env
    text = e.get(ENV_VAR, "")
    if not text:
        return NoFaults()
    return Faults.parse(text, seed=int(e.get(ENV_VAR + "_SEED", "0")))


def truncate_file(path, keep_frac: float = 0.5) -> int:
    """The ``truncated_checkpoint`` fault's effect: chop a file to
    ``keep_frac`` of its bytes (simulating a crash mid-write / partial
    transfer). Returns the new size."""
    p = Path(path)
    size = p.stat().st_size
    keep = max(0, int(size * keep_frac))
    with open(p, "r+b") as fh:
        fh.truncate(keep)
    return keep
