"""Batched serving engine with continuous-batching-lite slot scheduling.

A fixed number of batch *slots* share one batched KV/SSM cache; each slot
runs an independent sequence at its own offset (per-row ``step`` in the
cache). When a sequence finishes, the next queued request is prefilled
(batch=1) and its cache written into the free slot — the decode batch never
drains. This is the serving analogue the paper's Fig. 3 measures: stable,
predictable per-token latency under a stream of differently-sized requests.
"""
from __future__ import annotations

import collections
import time
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.model import Model
from repro.serving.request import Request, Response
from repro.serving.sampler import Sampler


def _write_slot(batched, one, b: int):
    """Write a batch=1 cache pytree into slot ``b`` of a batched cache.
    All cache leaves carry batch on axis 1 (axis 0 is the scanned
    layer/block axis)."""
    return jax.tree.map(lambda full, x: full.at[:, b].set(x[:, 0]),
                        batched, one)


class Engine:
    def __init__(self, model: Model, params, *, max_batch: int = 8,
                 cache_len: int = 512, sampler: Optional[Sampler] = None,
                 seed: int = 0):
        self.model = model
        self.params = params
        self.max_batch = max_batch
        self.cache_len = cache_len
        self.sampler = sampler or Sampler()
        self.key = jax.random.PRNGKey(seed)

        self.queue: collections.deque[Request] = collections.deque()
        self.slots: List[Optional[Request]] = [None] * max_batch
        self.responses: Dict[int, Response] = {}
        self.remaining = np.zeros(max_batch, np.int64)
        self.tokens = jnp.zeros((max_batch, 1), jnp.int32)
        self.cache = model.make_cache(max_batch, cache_len)
        self.step_times: List[float] = []

        self._decode = jax.jit(model.decode_step)
        self._prefill_cache: Dict[Any, Any] = {}

    # ------------------------------------------------------------ #
    def submit(self, req: Request) -> None:
        req.submitted_s = time.perf_counter()
        self.queue.append(req)
        self.responses[req.uid] = Response(uid=req.uid,
                                           prompt_len=len(req.prompt))

    def _prefill_one(self, req: Request):
        L = len(req.prompt)
        kcache = ("pf", L, req.embeddings is not None)
        if kcache not in self._prefill_cache:
            self._prefill_cache[kcache] = jax.jit(self.model.prefill)
        fn = self._prefill_cache[kcache]
        batch = {"tokens": jnp.asarray(req.prompt, jnp.int32)[None]}
        if req.embeddings is not None:
            batch["embeddings"] = jnp.asarray(req.embeddings)[None]
        cache1 = self.model.make_cache(1, self.cache_len)
        logits, cache1 = fn(self.params, batch, cache1)
        return logits, cache1

    def _fill_free_slots(self) -> None:
        for b in range(self.max_batch):
            if self.slots[b] is not None or not self.queue:
                continue
            req = self.queue.popleft()
            req.started_s = time.perf_counter()
            logits, cache1 = self._prefill_one(req)
            self.cache = _write_slot(self.cache, cache1, b)
            self.key, sk = jax.random.split(self.key)
            first = self.sampler(sk, logits[:, -1].astype(jnp.float32))
            tok = int(first[0])
            resp = self.responses[req.uid]
            resp.tokens.append(tok)
            if req.max_new_tokens <= 1 or (req.eos_id is not None
                                           and tok == req.eos_id):
                resp.finished = True
                req.finished_s = time.perf_counter()
                continue  # slot stays free
            self.tokens = self.tokens.at[b, 0].set(first[0])
            self.slots[b] = req
            self.remaining[b] = req.max_new_tokens - 1

    # ------------------------------------------------------------ #
    def step(self) -> None:
        """One batched decode step across all active slots."""
        t0 = time.perf_counter()
        logits, self.cache = self._decode(self.params, self.tokens,
                                          self.cache)
        self.key, sk = jax.random.split(self.key)
        nxt = self.sampler(sk, logits[:, -1].astype(jnp.float32))
        nxt = np.asarray(nxt)
        self.tokens = jnp.asarray(nxt[:, None])
        self.step_times.append(time.perf_counter() - t0)

        for b, req in enumerate(self.slots):
            if req is None:
                continue
            tok = int(nxt[b])
            resp = self.responses[req.uid]
            resp.tokens.append(tok)
            self.remaining[b] -= 1
            done = self.remaining[b] <= 0 or (req.eos_id is not None
                                              and tok == req.eos_id)
            if done:
                resp.finished = True
                req.finished_s = time.perf_counter()
                self.slots[b] = None

    @property
    def active(self) -> int:
        return sum(s is not None for s in self.slots)

    def run(self, max_steps: int = 100_000) -> Dict[int, Response]:
        steps = 0
        while (self.queue or self.active) and steps < max_steps:
            self._fill_free_slots()
            if self.active:
                self.step()
            steps += 1
        return self.responses

    # ------------------------------------------------------------ #
    def latency_stats(self) -> Dict[str, float]:
        ts = np.asarray(self.step_times[1:] or [0.0])  # drop compile step
        finished = [r for r in self.responses.values() if r.finished]
        return {
            "decode_ms_mean": float(ts.mean() * 1e3),
            "decode_ms_p50": float(np.percentile(ts, 50) * 1e3),
            "decode_ms_p99": float(np.percentile(ts, 99) * 1e3),
            "n_finished": len(finished),
            "tokens_generated": sum(r.n_generated for r in finished),
        }
