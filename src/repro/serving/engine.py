"""Batched serving engine v2: bucketed prefill + fused on-device decode,
with optional speculative decoding (fused draft–verify step).

A fixed number of batch *slots* share one batched KV/SSM cache; each slot
runs an independent sequence at its own per-row ``step`` offset. When a
sequence finishes, the next queued request is prefilled straight into the
free slot and the decode batch never drains — the serving analogue the
paper's Fig. 3 measures (stable per-token latency under a stream of
differently-sized requests). See ``docs/serving.md`` for the lifecycle
diagram and invariants.

What v2 changes over the first engine:

* **Bucketed prefill** — prompts are right-padded to power-of-two length
  buckets, so the prefill jit cache holds O(log cache_len) entries instead
  of one per distinct prompt length. Causality makes right padding free:
  valid positions attend only to valid positions, the model masks padded
  cache slots (``pos = -1``) and sets the per-row ``step`` to the true
  length (``batch["length"]``).
* **Slot-direct prefill** — the jitted prefill slices slot ``b`` out of the
  batched cache, runs the model, samples the first token, and writes the
  slot back with ``dynamic_update_slice`` — all inside one XLA program. No
  host-side batch=1 cache materialisation, no tree-mapped copy.
* **Fused decode step** — ``decode_step -> logits -> sample -> bookkeeping``
  is one jitted, cache-donating program. ``remaining``/``eos``/``active``
  live on device; steady-state decode performs **zero** host<->device token
  transfers. Every ``sync_every`` steps the host harvests each occupied
  slot's new token column (sliced on device, one bounded transfer per
  slot) and detects finishes by replaying the device's stop conditions.
* **Speculative decoding** (``Engine(draft=..., spec_gamma=...)``) — each
  decode step becomes one fused draft–verify program: the draft proposes
  γ tokens autoregressively, the target scores all γ+1 positions in a
  single masked multi-token forward (``Model.verify_step``), and
  rejection sampling accepts a prefix + resamples the first rejection on
  device. Both caches roll back to the accepted depth via the per-row
  ``step`` offsets (``Model.rollback``). The step emits a *variable*
  number of tokens but stays static-shaped: a fixed (B, γ+1) token block
  plus a per-slot accepted-count, so the zero-host-sync invariant and the
  ``_poll``/``_harvest`` contract are unchanged.
"""
from __future__ import annotations

import collections
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.models.model import Model
from repro.serving.request import Request, Response
from repro.serving.sampler import Sampler

MIN_BUCKET = 8


def bucket_length(n: int, cap: int, lo: int = MIN_BUCKET) -> int:
    """Smallest power-of-two >= n (floored at ``lo``), capped at ``cap``.
    The cap keeps the last bucket exactly the cache length even when that
    is not a power of two (e.g. cache_len=48 -> buckets 8, 16, 32, 48)."""
    b = max(lo, 1 << max(0, n - 1).bit_length())
    return min(b, cap)


class Engine:
    def __init__(self, model: Model, params, *, max_batch: int = 8,
                 cache_len: int = 512, sampler: Optional[Sampler] = None,
                 seed: int = 0, sync_every: int = 8,
                 donate: Optional[bool] = None,
                 kv_cache_dtype: str = "",
                 draft: Any = None, spec_gamma: int = 0):
        """``params`` may be a quantized tree (``quant.quantize_params``):
        projections route through the fused dequantize-matmul inside the
        same jitted prefill/decode programs, nothing else changes.

        ``kv_cache_dtype="int8"`` stores K/V as int8 with per-(slot, head)
        scales — quantize-on-write in the cache update, dequantize-in-
        attention on read — halving KV bytes per decode step (the
        memory-roofline cost at long cache lengths). "" keeps the model's
        own setting (``cfg.kv_quant``).

        ``draft`` enables speculative decoding: a self-draft spec string
        (``"int8@1"`` — see ``quant.self_draft``), an explicit
        ``(draft_model, draft_params)`` pair, or None to follow
        ``cfg.draft``. ``spec_gamma`` is the number of draft tokens
        proposed per step (0 follows ``cfg.spec_gamma``, defaulting to 4
        once a draft is configured). Requires attention-backed caches
        (``Model.supports_speculative``) on both models."""
        if kv_cache_dtype not in ("", "int8"):
            raise ValueError(f"unsupported kv_cache_dtype "
                             f"{kv_cache_dtype!r} (use '' or 'int8')")
        if kv_cache_dtype == "int8" and not model.cfg.kv_quant:
            from repro.models.model import build
            model = build(model.cfg.replace(kv_quant=True))
        self.model = model
        self.params = params
        self.kv_cache_dtype = "int8" if model.cfg.kv_quant else \
            model.cfg.dtype
        self.max_batch = max_batch
        self.cache_len = cache_len
        self.sampler = sampler or Sampler()
        self.sync_every = max(1, sync_every)
        cfg = model.cfg
        # actual KV ring length (make_cache caps at the sliding window)
        self.kv_len = min(cache_len, cfg.sliding_window) \
            if cfg.sliding_window else cache_len
        # vlm prompts carry a frontend prefix in the same cache rows
        self._prefix = cfg.frontend.n_tokens \
            if (cfg.frontend is not None and cfg.family == "vlm") else 0
        # MoE routing shares a capacity budget across the whole sequence,
        # so padding tokens could steal capacity from valid ones: for MoE
        # models keep the masked slot-reset prefill but pad nothing
        # (bucket = exact length; more jit entries, exact routing)
        self._pad_buckets = cfg.moe is None
        # XLA ignores donation on CPU (and warns); only donate elsewhere
        self._donate = (jax.default_backend() != "cpu") if donate is None \
            else donate

        # host-side scheduling state
        self.queue: collections.deque[Request] = collections.deque()
        self.slots: List[Optional[Request]] = [None] * max_batch
        self.requests: Dict[int, Request] = {}
        self.responses: Dict[int, Response] = {}
        self.step_times: List[float] = []

        # device-resident decode state (never read back in steady state)
        self.key = jax.random.PRNGKey(seed)
        self.tokens = jnp.zeros((max_batch, 1), jnp.int32)
        self.prev = jnp.zeros((max_batch, 1), jnp.int32)   # spec: token
        # preceding the pending one (the draft cache lags by one position)
        self.remaining = jnp.zeros((max_batch,), jnp.int32)
        self.active = jnp.zeros((max_batch,), bool)
        self.eos = jnp.full((max_batch,), -1, jnp.int32)
        self.cache = model.make_cache(max_batch, cache_len)

        # per-step sampled-token trace: device arrays, harvested lazily.
        # Plain decode appends (B,) token vectors; speculative decode
        # appends ((B, gamma+1) block, (B,) emit-count) pairs.
        self._trace: List[Any] = []
        self._trace_base = 0                      # global step of _trace[0]
        self._slot_start = [0] * max_batch        # global step per slot
        self._steps = 0

        # --- speculative decoding ------------------------------------- #
        draft_src = draft if draft is not None else (cfg.draft or None)
        gamma = spec_gamma or cfg.spec_gamma
        if draft_src is not None and gamma == 0:
            gamma = 4
        if gamma and draft_src is None:
            raise ValueError("spec_gamma set but no draft configured "
                             "(pass draft=... or set cfg.draft)")
        self.spec_gamma = gamma if draft_src is not None else 0
        self._draft_model: Optional[Model] = None
        self._draft_params = None
        self.draft_cache = None
        self._spec_emitted = 0         # harvested tokens over spec steps
        self._spec_active_steps = 0    # (step, active slot) pairs harvested
        if self.spec_gamma:
            if not model.supports_speculative:
                raise ValueError(
                    "speculative decoding requires attention-backed "
                    f"caches; target family {cfg.family!r} has none")
            if isinstance(draft_src, str):
                from repro.quant.self_draft import make_self_draft
                dmodel, dparams = make_self_draft(model, params, draft_src)
            else:
                dmodel, dparams = draft_src
            if not dmodel.supports_speculative:
                raise ValueError(
                    "draft model must support per-row cache rollback "
                    f"(attention-backed); family {dmodel.cfg.family!r}")
            if self.spec_gamma + 1 > self.kv_len:
                raise ValueError(
                    f"spec_gamma={self.spec_gamma} needs a verify window "
                    f"of {self.spec_gamma + 1} <= kv ring {self.kv_len}")
            self._draft_model = dmodel
            self._draft_params = dparams
            self.draft_cache = dmodel.make_cache(max_batch, cache_len)
            # a spec step emits up to gamma+1 tokens per slot, so polls
            # must come ~(gamma+1)x as often to keep the post-finish
            # overshoot (device decoding an already-finished slot until
            # the next poll) the same number of *tokens* as plain decode
            self.sync_every = max(1, self.sync_every
                                  // (self.spec_gamma + 1))

        self._step_fn = self._build_spec_step() if self.spec_gamma \
            else self._build_step()
        self._prefill_jits: Dict[Tuple, Any] = {}

    # ------------------------------------------------------------ #
    # jitted programs
    # ------------------------------------------------------------ #
    def _build_step(self):
        """Fused decode: model step + sampling + slot bookkeeping, with the
        cache and decode state donated so XLA updates them in place."""
        model, sampler = self.model, self.sampler

        def step(params, cache, tokens, remaining, active, eos, key):
            logits, cache = model.decode_step(params, tokens, cache)
            key, sk = jax.random.split(key)
            nxt = sampler(sk, logits[:, -1].astype(jnp.float32))   # (B,)
            done = active & ((remaining <= 1) | (nxt == eos))
            new_active = active & ~done
            remaining = jnp.where(active, remaining - 1, remaining)
            return nxt[:, None], cache, remaining, new_active, key

        donate = (1, 2, 3, 4) if self._donate else ()
        return jax.jit(step, donate_argnums=donate)

    def _build_spec_step(self):
        """One fused draft–verify–accept program (static shapes):

        1. the draft proposes gamma tokens autoregressively. Its cache
           *lags the committed depth by one position* (see below), so the
           first proposal comes from a 2-token verify window
           ``[prev, pending]`` and the remaining gamma-1 from single-token
           decodes — gamma draft forwards total, and the draft cache
           never develops holes on full acceptance;
        2. the target scores all gamma+1 positions in one masked
           multi-token forward (``verify_step``) at each row's own offset;
        3. ``sampler.speculative`` accepts a per-row prefix and resamples
           the first rejection (greedy: emitted prefix == target argmax,
           so output is token-identical to non-speculative decode);
        4. both caches roll their per-row ``step`` back via
           ``Model.rollback`` — target to the committed depth, draft to
           committed-1 — and stored keys beyond it stay causally
           invisible;
        5. slot bookkeeping mirrors the plain step with a variable emit
           count ``n_emit in [1, gamma+1]`` per row.

        Lag invariant: entering a step with committed depth C, the target
        cache holds positions < C and the draft cache positions < C-1;
        ``prev`` is the token at C-1 and ``tokens`` the pending one at C.
        The draft's verify window rewrites C-1 and C, decodes write
        C+1..C+gamma-1, and the last proposal is *never* written — its
        position is re-consumed by the next step's verify window, so full
        acceptance leaves no hole.
        """
        model, sampler = self.model, self.sampler
        draft, gamma = self._draft_model, self.spec_gamma

        def spec(params, dparams, cache, dcache, tokens, prev, remaining,
                 active, eos, key):
            B = tokens.shape[0]
            # 1) draft proposals (and their full logit rows, for the
            #    stochastic accept ratio p/q)
            window = jnp.concatenate([prev, tokens], axis=1)   # (B, 2)
            dl, dcache = draft.verify_step(dparams, window, dcache)
            d_toks, d_logits = [], []
            cur_logits = dl[:, -1].astype(jnp.float32)
            for i in range(gamma):
                key, sk = jax.random.split(key)
                t = sampler(sk, cur_logits)
                d_toks.append(t)
                d_logits.append(cur_logits)
                if i + 1 < gamma:
                    dl, dcache = draft.decode_step(dparams, t[:, None],
                                                   dcache)
                    cur_logits = dl[:, -1].astype(jnp.float32)
            draft_tokens = jnp.stack(d_toks, axis=1)          # (B, g)
            draft_logits = jnp.stack(d_logits, axis=1)        # (B, g, V)

            # 2) one masked multi-token target forward over
            #    [pending, draft_0..draft_{g-1}]
            seq = jnp.concatenate([tokens, draft_tokens], axis=1)
            t_logits, cache = model.verify_step(params, seq, cache)

            # 3) accept prefix + resample first rejection (on device)
            key, sk = jax.random.split(key)
            block, n_acc = sampler.speculative(
                sk, draft_tokens, draft_logits,
                t_logits.astype(jnp.float32))
            n_emit = jnp.where(active, n_acc + 1, 0)          # (B,)

            # 4) per-row rollback to the accepted depth. verify advanced
            #    the target by gamma+1; the committed depth is
            #    old_step + 1 + n_acc (pending + accepted drafts), i.e.
            #    current - gamma + n_acc. The draft sits at committed-1.
            steps_now = model.cache_steps(cache)              # (B,)
            committed = steps_now - gamma + n_acc
            cache = model.rollback(cache, committed)
            dcache = draft.rollback(dcache, committed - 1)

            # 5) bookkeeping (same stop conditions as the plain step,
            #    with a variable emit count)
            idx = jnp.arange(gamma + 1)[None, :]
            emitted = idx < n_emit[:, None]
            eos_hit = jnp.any(emitted & (block == eos[:, None]), axis=1)
            done = active & ((remaining <= n_emit) | eos_hit)
            new_active = active & ~done
            remaining = jnp.where(
                active, jnp.maximum(remaining - n_emit, 0), remaining)
            bidx = jnp.arange(B)
            last = block[bidx, jnp.maximum(n_emit, 1) - 1]
            nxt = jnp.where(active, last, tokens[:, 0])
            # token preceding the new pending one: the last accepted
            # draft, or the old pending token when nothing was accepted
            new_prev = jnp.where(
                n_acc > 0, block[bidx, jnp.maximum(n_acc, 1) - 1],
                tokens[:, 0])
            new_prev = jnp.where(active, new_prev, prev[:, 0])
            return (nxt[:, None], new_prev[:, None], block, n_emit,
                    cache, dcache, remaining, new_active, key)

        donate = (2, 3, 4, 5, 6, 7) if self._donate else ()
        return jax.jit(spec, donate_argnums=donate)

    def _get_prefill(self, bucket: int, masked: bool, has_emb: bool,
                     for_draft: bool = False):
        """One compiled program per (bucket length, masked, embeddings,
        target-or-draft) signature — the jit cache is O(log cache_len),
        not O(#lengths)."""
        kf = (bucket, masked, has_emb, for_draft)
        if kf in self._prefill_jits:
            return self._prefill_jits[kf]
        model = self._draft_model if for_draft else self.model
        sampler = self.sampler

        def prefill(params, tokens, length, emb, b, cache, key):
            cache1 = jax.tree.map(
                lambda t: lax.dynamic_slice_in_dim(t, b, 1, axis=1), cache)
            batch = {"tokens": tokens}
            if emb is not None:
                batch["embeddings"] = emb
            if masked:
                batch["length"] = length
            logits, cache1 = model.prefill(params, batch, cache1)
            first = sampler(key, logits[:, -1].astype(jnp.float32))  # (1,)
            cache = jax.tree.map(
                lambda full, u: lax.dynamic_update_slice_in_dim(
                    full, u, b, axis=1), cache, cache1)
            return first, cache

        donate = (5,) if self._donate else ()
        fn = jax.jit(prefill, donate_argnums=donate)
        self._prefill_jits[kf] = fn
        return fn

    # ------------------------------------------------------------ #
    # scheduling
    # ------------------------------------------------------------ #
    def submit(self, req: Request) -> None:
        req.submitted_s = time.perf_counter()
        self.queue.append(req)
        self.requests[req.uid] = req
        self.responses[req.uid] = Response(uid=req.uid,
                                           prompt_len=len(req.prompt))

    def _fill_free_slots(self) -> None:
        for b in range(self.max_batch):
            if self.slots[b] is not None or not self.queue:
                continue
            req = self.queue.popleft()
            req.started_s = time.perf_counter()
            L = len(req.prompt)
            # prompts longer than the KV ring (sliding-window caches) fall
            # back to exact-length ring prefill, which rewrites the full row
            cap = self.kv_len - self._prefix
            masked = L <= cap
            Lb = bucket_length(L, cap) if (masked and self._pad_buckets) \
                else L
            toks = np.zeros((1, Lb), np.int32)
            toks[0, :L] = np.asarray(req.prompt, np.int32)
            emb = None
            if req.embeddings is not None:
                emb = jnp.asarray(req.embeddings)[None]
            self.key, sk = jax.random.split(self.key)
            fn = self._get_prefill(Lb, masked, emb is not None)
            first, self.cache = fn(self.params, jnp.asarray(toks),
                                   jnp.asarray([L], jnp.int32), emb,
                                   jnp.int32(b), self.cache, sk)
            # the only per-request host sync: the first sampled token
            tok = int(first[0])
            req.first_token_s = time.perf_counter()
            resp = self.responses[req.uid]
            resp.tokens.append(tok)
            if req.max_new_tokens <= 1 or (req.eos_id is not None
                                           and tok == req.eos_id):
                resp.finished = True
                resp.finish_reason = "eos" if (
                    req.eos_id is not None and tok == req.eos_id) \
                    else "length"
                req.finished_s = time.perf_counter()
                continue  # slot stays free
            if self.spec_gamma:
                # the draft needs the prompt context too: same bucketed
                # prefill into the draft's own batched cache, but only up
                # to L-1 tokens — the draft cache lags the committed
                # depth by one (the last prompt token becomes ``prev``
                # and is re-consumed by the first draft verify window).
                # Its sampled token is discarded.
                self.key, sk = jax.random.split(self.key)
                if masked:
                    dtoks, dlen, dLb = toks, L - 1, Lb
                else:  # exact-length ring fallback (L-1 >= kv ring)
                    dtoks, dlen, dLb = toks[:, :L - 1], L - 1, L - 1
                dfn = self._get_prefill(dLb, masked, emb is not None,
                                        for_draft=True)
                _, self.draft_cache = dfn(
                    self._draft_params, jnp.asarray(dtoks),
                    jnp.asarray([dlen], jnp.int32), emb, jnp.int32(b),
                    self.draft_cache, sk)
                self.prev = self.prev.at[b, 0].set(int(req.prompt[-1]))
            self.tokens = self.tokens.at[b, 0].set(tok)
            self.remaining = self.remaining.at[b].set(
                req.max_new_tokens - 1)
            self.active = self.active.at[b].set(True)
            self.eos = self.eos.at[b].set(
                -1 if req.eos_id is None else int(req.eos_id))
            self.slots[b] = req
            self._slot_start[b] = self._steps

    # ------------------------------------------------------------ #
    # decode
    # ------------------------------------------------------------ #
    def step(self) -> None:
        """One batched decode step (plain or speculative). Pure device
        work: tokens, finish flags, and counters all stay on device;
        nothing is transferred."""
        t0 = time.perf_counter()
        if self.spec_gamma:
            (self.tokens, self.prev, block, n_emit, self.cache,
             self.draft_cache, self.remaining, self.active,
             self.key) = self._step_fn(
                self.params, self._draft_params, self.cache,
                self.draft_cache, self.tokens, self.prev, self.remaining,
                self.active, self.eos, self.key)
            self._trace.append((block, n_emit))
        else:
            (self.tokens, self.cache, self.remaining, self.active,
             self.key) = self._step_fn(self.params, self.cache,
                                       self.tokens, self.remaining,
                                       self.active, self.eos, self.key)
            self._trace.append(self.tokens[:, 0])
        self._steps += 1
        self.step_times.append(time.perf_counter() - t0)

    def _poll(self) -> None:
        """The periodic host sync: harvest each occupied slot's new token
        block (one bounded transfer per slot, sliced on device) and prune
        the trace. Only the unconsumed suffix of the trace is ever
        stacked, so poll cost is bounded by the tokens produced since the
        previous poll — it does not grow with trace (or sequence) length.
        Finish detection replays the device's own stop conditions on the
        harvested tokens, so host and device slot state agree by
        construction."""
        if not self._trace:
            return
        occupied = [(b, self._slot_start[b] - self._trace_base)
                    for b, r in enumerate(self.slots) if r is not None]
        starts = [s for _, s in occupied if s < len(self._trace)]
        if starts:
            lo = min(starts)
            suffix = self._trace[lo:]
            jax.block_until_ready(suffix[-1])
            # host-side stacking: each entry is a bounded (B,)/(B, g+1)
            # transfer. A device-side jnp.stack here would trigger one
            # XLA compile per distinct suffix length — a recurring
            # ~100ms latency spike that dwarfed the transfers it saved.
            if self.spec_gamma:
                blocks = np.stack([np.asarray(t) for t, _ in suffix])
                counts = np.stack([np.asarray(c) for _, c in suffix])
            else:
                blocks = np.stack([np.asarray(t) for t in suffix])[..., None]
                counts = None
            for b, start in occupied:
                s = start - lo
                if s >= blocks.shape[0]:
                    continue                               # armed post-trace
                blk = blocks[s:, b]                        # (T', W)
                if counts is None:
                    col = [int(t) for t in blk[:, 0]]
                else:
                    cnt = counts[s:, b]                    # (T',)
                    self._spec_emitted += int(cnt.sum())
                    self._spec_active_steps += int((cnt > 0).sum())
                    col = [int(t) for row, c in zip(blk, cnt)
                           for t in row[:c]]
                self._harvest(b, col)
        # every occupied slot has now consumed the whole trace
        keep_from = min((self._slot_start[b] for b, r
                         in enumerate(self.slots) if r is not None),
                        default=self._steps)
        drop = keep_from - self._trace_base
        if drop > 0:
            del self._trace[:drop]
            self._trace_base = keep_from

    def _harvest(self, b: int, col: List[int]) -> None:
        """Append slot ``b``'s sampled tokens host-side. The device kept
        decoding after the slot finished (it only learns at the next poll),
        so cut the column at the true stop condition — the same condition
        the fused step applied on device."""
        req = self.slots[b]
        resp = self.responses[req.uid]
        done = False
        for tok in col:
            tok = int(tok)
            resp.tokens.append(tok)
            if (req.eos_id is not None and tok == req.eos_id):
                resp.finish_reason = "eos"
                done = True
                break
            if len(resp.tokens) >= req.max_new_tokens:
                resp.finish_reason = "length"
                done = True
                break
        if done:
            resp.finished = True
            req.finished_s = time.perf_counter()
            self.slots[b] = None
        else:
            self._slot_start[b] = self._steps              # all consumed

    @property
    def active_slots(self) -> int:
        return sum(s is not None for s in self.slots)

    def run(self, max_steps: int = 100_000,
            sync_every: Optional[int] = None) -> Dict[int, Response]:
        k = self.sync_every if sync_every is None else max(1, sync_every)
        steps = 0
        while (self.queue or self.active_slots) and steps < max_steps:
            self._fill_free_slots()
            if not self.active_slots:
                continue  # whole queue finished at prefill (max_new <= 1)
            t0 = time.perf_counter()
            n0 = len(self.step_times)
            for _ in range(k):
                first_ever = self._steps == 0
                self.step()
                steps += 1
                if first_ever:
                    # isolate the fused-step compile in step_times[0]
                    # (latency_stats drops it) so burst averaging below
                    # never smears it over steady-state entries
                    jax.block_until_ready(self.tokens)
                    self.step_times[-1] = time.perf_counter() - t0
                    t0 = time.perf_counter()
                    n0 = len(self.step_times)
                if steps >= max_steps:
                    break
            jax.block_until_ready(self.tokens)
            # burst-average: per-step dispatch time plus its share of sync
            if len(self.step_times) > n0:
                dt = (time.perf_counter() - t0) / (len(self.step_times)
                                                   - n0)
                for i in range(n0, len(self.step_times)):
                    self.step_times[i] = dt
            self._poll()
        self._poll()   # partial tokens for interrupted slots
        return self.responses

    # ------------------------------------------------------------ #
    def latency_stats(self) -> Dict[str, float]:
        ts = np.asarray(self.step_times[1:] or [0.0])  # drop compile step
        finished = [r for r in self.responses.values() if r.finished]
        ttft = [r.first_token_s - r.submitted_s
                for r in self.requests.values() if r.first_token_s]
        stats = {
            "decode_ms_mean": float(ts.mean() * 1e3),
            "decode_ms_p50": float(np.percentile(ts, 50) * 1e3),
            "decode_ms_p99": float(np.percentile(ts, 99) * 1e3),
            "ttft_ms_mean": float(np.mean(ttft) * 1e3) if ttft else 0.0,
            "n_finished": len(finished),
            "tokens_generated": sum(r.n_generated for r in finished),
            "prefill_jit_entries": len(self._prefill_jits),
            "decode_steps": self._steps,
        }
        if self.spec_gamma:
            # every harvested (step, active slot) pair emitted 1 + n_acc
            # tokens; acceptance rate = mean(n_acc) / gamma
            n = max(self._spec_active_steps, 1)
            stats["spec_gamma"] = self.spec_gamma
            stats["spec_tokens_per_step"] = self._spec_emitted / n
            stats["spec_acceptance_rate"] = \
                (self._spec_emitted - self._spec_active_steps) \
                / (self.spec_gamma * n)
        return stats
