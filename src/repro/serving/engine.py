"""Batched serving engine v3: continuous batching — bucketed prefill,
fused on-device decode, chunked prefill fused into the decode step,
shared-prefix KV reuse, and optional speculative decoding.

A fixed number of batch *slots* share one batched KV/SSM cache; each slot
runs an independent sequence at its own per-row ``step`` offset. When a
sequence finishes, the next queued request is admitted into the free slot
and the decode batch never drains — the serving analogue the paper's
Fig. 3 measures (stable per-token latency under a stream of
differently-sized requests). See ``docs/serving.md`` for the lifecycle
diagram and invariants.

What v3 changes over v2 (PR 1/3):

* **Fused mixed step (Sarathi-style chunked prefill)** — with
  ``prefill_chunk > 0``, a long prompt no longer monopolises the engine:
  every step is a single jitted, cache-donating program that decodes all
  active slots AND advances at most ``prefill_chunk`` tokens of one
  admitting request, via ``Model.extend_into_cache`` (per-row lengths:
  decode rows advance by 1, the admitting row by the chunk, idle rows by
  0). Decode never stalls behind prefill, so tail inter-token latency
  stays flat when long prompts arrive — the knob trades first-token
  latency of the admitting request for ITL of everyone else.
* **Shared-prefix KV reuse** — ``prefix_cache_tokens > 0`` keeps a
  host-side trie of recently admitted prompt prefixes (chunk-aligned;
  LRU-evicted under a token budget) whose device KV slices are
  materialised into a fresh slot with one on-device
  ``dynamic_update_slice`` copy; chunked prefill resumes after the reused
  prefix. Shared system prompts and few-shot headers cost one HBM copy
  instead of recomputation (``serving/prefix_cache.py``).
* **Percentile latency stats** — ``latency_stats`` now reports
  p50/p95/p99 TTFT and inter-token latency over per-request samples, the
  tail metrics ``benchmarks/bench_load.py`` tracks under Poisson load.

With ``paged=True`` the per-slot contiguous KV rings are replaced by a
fixed pool of fixed-size pages with per-slot block tables
(``serving/paged_kv.py``): KV memory scales with live tokens instead of
``max_batch x cache_len``, prefix-cache hits alias pages (refcount bump,
zero KV copies — the materialize/extract programs never run) and
admission applies backpressure when the pool is short. The decode /
mixed / speculative step programs are unchanged in shape; they write
through the block table via the paged attention path in
``models/layers.py``.

Chunked admission is the ONLY admission path: every family — dense,
MoE (dense routing in cached modes), SSM (sequential ``ssd_extend``
recurrence), hybrid, VLM (the frontend prefix enters as one embedding
chunk) and encoder–decoder (cross-attention memory encoded once at
admission, decoder ring chunked like any other) — flows through
``Model.extend_into_cache``. The v2 monolithic slot-direct prefill is
gone; ``prefill_chunk=0`` now means a single max-size chunk (the whole
prompt in one fused extend), not a separate program. The
``fallback_admissions`` counter observes any admission that cannot take
the fused path — structurally zero for every supported family, and
asserted zero by ``benchmarks/check_families.py``.

Retained from v2 (see the sections below and docs/serving.md): the
fused donated decode step with zero steady-state host<->device traffic,
the bounded ``_poll``/``_harvest`` trace contract, and the fused
draft–verify speculative step (``draft=``/``spec_gamma=``; chunked
admission then runs as its own extend program right before the spec
step, advancing target and draft caches in lockstep with the draft one
position behind). ``draft="ngram"`` replaces the draft model with a
prompt-lookup drafter (``serving/ngram_draft.py``) that proposes from
the request's own token history — no draft cache, works for every
family, and recurrent targets commit speculation through the rollback-
and-replay flow (``Model.rollback_needs_replay``).

Telemetry (``docs/observability.md``): every host-side stat lives in
one ``serving/telemetry.MetricsRegistry`` (``Engine.metrics``) —
counters (tokens emitted, steps by kind, admissions, spec
accept/emit), gauges sampled at each poll (active slots, free pages,
KV bytes per live token), bounded-reservoir histograms (TTFT, ITL) and
the per-step wall/kind series ``latency_stats()`` is built on. Request
lifecycles route through a ``Recorder`` (no-op by default; pass
``recorder=True`` for a ``serving/tracing.Tracer`` and export a
Perfetto-loadable Chrome trace with ``Engine.export_trace(path)``).
Every jitted program is watched for XLA compiles: after
``reset_stats()``/``mark_steady()`` arms the watchdog, a steady-state
compile raises ``telemetry.RecompileWarning`` and increments the
``steady_compiles`` counter CI fails on. ``trace_dir=`` additionally
captures a ``jax.profiler`` device trace over a short step window.

Resilience (``docs/robustness.md``): requests carry ``deadline_s`` and
``priority``; ``Engine.cancel(uid)`` and per-poll deadline enforcement
finish streams with ``finish_reason`` "cancelled"/"timeout", releasing
their slot and pages immediately. Under slot or page pressure the
engine preempts a victim (lowest priority, then latest deadline) and
requeues it; on re-admission the generated prefix is replayed through
the chunked-extend path, so the resumed stream's output is identical
to an unpreempted run. An on-device NaN/inf guard at every sampler
boundary contains a poisoned slot to a ``finish_reason="error"``
finish while the rest of the fused batch continues. All of it is
exercised by the deterministic fault registry in ``serving/faults.py``
(``Engine(faults=...)`` / ``REPRO_FAULTS``), a zero-overhead no-op by
default.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import NamedSharding, PartitionSpec

from repro.models.model import Model
from repro.serving import faults as faults_mod
from repro.serving import paged_kv, telemetry
from repro.serving.prefix_cache import PrefixCache
from repro.serving.request import Request, Response
from repro.serving.sampler import Sampler

#: Sentinel "token" the fused steps emit for a slot whose sampler logits
#: were not finite (NaN/inf): the on-device guard deactivates only that
#: row, and the host harvest turns the sentinel into finish_reason
#: "error" without appending it. Real token ids are >= 0 and the no-EOS
#: sentinel is -1, so -2 is unambiguous.
ERR_TOKEN = -2


def _guarded_sample(sampler, key, logits):
    """NaN/inf containment at the sampler boundary. Rows whose logits
    are not finite emit :data:`ERR_TOKEN` instead of sampling garbage
    (argmax/categorical over NaN is undefined) and the caller marks only
    those rows done — the rest of the fused batch is unaffected (samples
    are per-row functions of per-row logits). Finite rows are
    bit-identical to an unguarded call: the ``where`` masks select the
    original logits elementwise."""
    bad = ~jnp.all(jnp.isfinite(logits), axis=-1)                # (B,)
    safe = jnp.where(bad[:, None], 0.0, logits)
    nxt = jnp.where(bad, jnp.int32(ERR_TOKEN), sampler(key, safe))
    return nxt, bad


def _finite_rows(logits):
    """Replace non-finite logit rows with zeros (draft-side guard: the
    proposals sampled from a poisoned draft row are garbage, but the
    target verify rejects them — zeroing just keeps the sampling and
    accept-ratio math well-defined)."""
    ok = jnp.all(jnp.isfinite(logits), axis=-1, keepdims=True)
    return jnp.where(ok, logits, 0.0)


@dataclasses.dataclass
class _Admission:
    """One in-flight chunked admission: the effective token stream
    enters the cache ``prefill_chunk`` tokens per fused step, starting
    at ``base`` (> 0 when a prefix-cache hit pre-populated the slot).
    ``tokens`` is the prompt plus — when resuming a preempted request —
    the ``n_done`` tokens it had already generated: replaying them
    through the same extend path makes the resumed stream token-
    identical to an unpreempted run (greedy)."""
    req: Request
    slot: int
    base: int
    length: int
    tokens: np.ndarray = None
    n_done: int = 0
    resumed: bool = False


class Engine:
    def __init__(self, model: Model, params, *, max_batch: int = 8,
                 cache_len: int = 512, sampler: Optional[Sampler] = None,
                 seed: int = 0, sync_every: int = 8,
                 donate: Optional[bool] = None,
                 kv_cache_dtype: str = "",
                 draft: Any = None, spec_gamma: int = 0,
                 prefill_chunk: Optional[int] = None,
                 prefix_cache_tokens: Optional[int] = None,
                 mesh: Any = None,
                 paged: bool = False, page_size: int = 16,
                 num_pages: Optional[int] = None,
                 faults: Any = None,
                 recorder: Any = None, trace_dir: str = "",
                 profile_steps: int = 8):
        """``params`` may be a quantized tree (``quant.quantize_params``):
        projections route through the fused dequantize-matmul inside the
        same jitted prefill/decode programs, nothing else changes.

        ``kv_cache_dtype="int8"`` stores K/V as int8 with per-(slot, head)
        scales — quantize-on-write in the cache update, dequantize-in-
        attention on read — halving KV bytes per decode step (the
        memory-roofline cost at long cache lengths). "" keeps the model's
        own setting (``cfg.kv_quant``).

        ``draft`` enables speculative decoding: a self-draft spec string
        (``"int8@1"`` — see ``quant.self_draft``), the string
        ``"ngram"`` for the family-agnostic prompt-lookup drafter
        (``serving/ngram_draft.py`` — no draft model or cache; proposals
        come from the request's own token history), an explicit
        ``(draft_model, draft_params)`` pair, or None to follow
        ``cfg.draft``. ``spec_gamma`` is the number of draft tokens
        proposed per step (0 follows ``cfg.spec_gamma``, defaulting to 4
        once a draft is configured). Model drafts require caches that
        rewind without replay on both sides (attention-backed); the
        n-gram drafter serves every family — recurrent targets commit
        accepted tokens through checkpoint-restore + replay
        (``Model.rollback_needs_replay``).

        ``prefill_chunk`` sizes the chunked admission path — the ONLY
        admission path: each engine step decodes every active slot and
        advances at most this many prompt tokens of one admitting
        request through the fused mixed step. None follows
        ``cfg.prefill_chunk``; 0 means a single max-size chunk (the
        whole prompt enters through one fused extend — there is no
        separate monolithic prefill program). Every family supports the
        extend path; requests carrying frontend embeddings admit their
        prefix through one embedding chunk (VLM) or a one-shot encode
        of the cross-attention memory (encdec) before the token chunks.

        ``prefix_cache_tokens`` (with chunked prefill, non-speculative)
        caps the shared-prefix KV reuse budget in tokens; None follows
        ``cfg.prefix_cache_tokens``, 0 disables.

        ``mesh`` enables tensor-parallel sharded serving: a
        ``jax.sharding.Mesh`` with ("data", "model") axes, a spec string
        ("auto" = all local devices on the model axis, "dp,mp" e.g.
        "2,4" — see ``launch.mesh.make_serving_mesh``), or None to
        follow ``cfg.mesh`` ("" / "none" disables). Params are placed by
        ``param_shardings`` (attention/MLP weights split over the model
        axis), the KV cache by ``cache_shardings`` (heads on model,
        slots on data), decode state by ``batch_shardings``; every
        jitted program is built with explicit in/out shardings so
        donation still updates the cache in place and no per-step
        re-layout occurs. Host-side state (queue, trie, sampler knobs)
        stays replicated/host-resident. Pallas kernel ops fall back to
        their jnp references under a model axis > 1
        (``kernels.dispatch``).

        ``paged=True`` replaces the per-slot contiguous KV rings with a
        fixed pool of ``num_pages`` pages of ``page_size`` tokens each
        (``serving/paged_kv.py``): HBM scales with live tokens, prefix
        hits become block-table aliases (zero KV copies) and admission
        applies backpressure instead of assuming worst-case capacity.
        Requires a paged cache layout (attention-only stacks — SSM
        recurrent state has no per-position storage to page) and
        token-only prompts that fit the KV ring — ``submit`` rejects
        anything else. ``num_pages=None`` sizes the pool for capacity
        parity with the contiguous layout plus provisioning headroom.
        Composes with int8 KV, speculative decoding (the draft cache
        stays contiguous), chunked admission and mesh sharding.

        ``faults`` enables deterministic fault injection
        (``serving/faults.py``): a ``Faults`` schedule, a spec string
        for ``Faults.parse`` (``"nan_logits@12/1,page_alloc@30"``), or
        None to follow the ``REPRO_FAULTS`` env var. The default is the
        zero-overhead ``NoFaults`` no-op: programs, outputs and
        ``program_cache_sizes()`` are bit-identical with it (the NaN
        site injects through the always-present ``poison`` input, never
        a recompiled program variant).

        ``recorder`` enables request-lifecycle tracing: ``True`` builds
        a ``serving/tracing.Tracer`` (export with
        ``Engine.export_trace(path)``), or pass any
        ``telemetry.Recorder`` instance. None/False keeps the no-op
        default — host bookkeeping only, zero per-step device work, and
        greedy outputs / compiled-program counts bit-identical either
        way (the metrics registry itself is always on; it is pure host
        state). ``trace_dir`` additionally captures a ``jax.profiler``
        device trace of ``profile_steps`` engine steps (the window
        starts at step 1, after the first compile).
        """
        if kv_cache_dtype not in ("", "int8"):
            raise ValueError(f"unsupported kv_cache_dtype "
                             f"{kv_cache_dtype!r} (use '' or 'int8')")
        if kv_cache_dtype == "int8" and not model.cfg.kv_quant:
            from repro.models.model import build
            model = build(model.cfg.replace(kv_quant=True))
        self.model = model
        self.params = params
        self.kv_cache_dtype = "int8" if model.cfg.kv_quant else \
            model.cfg.dtype
        self.max_batch = max_batch
        self.cache_len = cache_len
        self.sampler = sampler or Sampler()
        self.sync_every = max(1, sync_every)
        cfg = model.cfg
        # actual KV ring length (make_cache caps at the sliding window)
        self.kv_len = min(cache_len, cfg.sliding_window) \
            if cfg.sliding_window else cache_len
        # vlm prompts carry a frontend prefix in the same cache rows
        self._prefix = cfg.frontend.n_tokens \
            if (cfg.frontend is not None and cfg.family == "vlm") else 0
        # XLA ignores donation on CPU (and warns); only donate elsewhere
        self._donate = (jax.default_backend() != "cpu") if donate is None \
            else donate

        # --- tensor-parallel serving mesh ------------------------------ #
        mesh_src = cfg.mesh if mesh is None else mesh
        if isinstance(mesh_src, str):
            if mesh_src in ("", "none", "off"):
                mesh_src = None
            else:
                from repro.launch.mesh import make_serving_mesh
                mesh_src = make_serving_mesh(mesh_src)
        self.mesh = mesh_src
        self._param_sh = self._cache_sh = self._draft_param_sh = None
        self._draft_cache_sh = self._tok_sh = self._vec_sh = None
        self._repl = None
        if self.mesh is not None:
            from repro.distribution import sharding as _SH
            from repro.launch.mesh import batch_axes
            self._SH = _SH
            self._b_axes = batch_axes(self.mesh) or ("data",)
            self._act_rules = _SH.default_activation_rules(
                batch_axes=self._b_axes)
            self._repl = NamedSharding(self.mesh, PartitionSpec())
            # params placed once, by path rules; programs then pin the
            # same shardings via in_shardings so no call ever re-lays
            # them out
            self._param_sh = _SH.param_shardings(self.params, self.mesh)
            self.params = jax.device_put(self.params, self._param_sh)

        # --- telemetry -------------------------------------------------- #
        # the registry is the single host-side stats store: counters,
        # gauges, histograms and the aligned per-step series that
        # latency_stats()/benchmarks read (step_times/step_kinds below
        # are live views into it). The recorder is the request-lifecycle
        # event sink: a no-op by default, a tracing.Tracer on request.
        self.metrics = telemetry.MetricsRegistry()
        if recorder is True:
            from repro.serving.tracing import Tracer
            recorder = Tracer()
        self.recorder: telemetry.Recorder = recorder or telemetry.Recorder()
        self._watchdog = telemetry.CompileWatchdog(self.metrics,
                                                   self.recorder)
        self._step_series = self.metrics.get_series("step_wall_s")
        self._kind_series = self.metrics.get_series("step_kind")
        self._kinds_base = 0           # global step of step_kinds[0]
        self._c_tokens = self.metrics.counter("tokens_emitted")
        self._c_steps = self.metrics.counter("steps_total", persist=True)
        self._c_admissions = self.metrics.counter("chunked_admissions")
        # admissions that could not take the fused chunked path. The
        # refactor that retired the monolithic prefill made this
        # structurally zero for every supported family — the counter
        # (and its trace instant) exists so any reintroduced bypass is
        # observable, and benchmarks/check_families.py gates on it.
        self._c_fallback = self.metrics.counter("fallback_admissions")
        self._c_spec_emitted = self.metrics.counter("spec_tokens_emitted")
        self._c_spec_steps = self.metrics.counter("spec_active_steps")
        self._h_ttft = self.metrics.histogram("ttft_s")
        self._h_itl = self.metrics.histogram("itl_s")
        self._c_preempt = self.metrics.counter("preemptions")
        self._c_timeout = self.metrics.counter("timeouts")
        self._c_cancel = self.metrics.counter("cancellations")
        self._c_faults = self.metrics.counter("faults_injected")
        self._c_errors = self.metrics.counter("slot_errors")
        self._trace_dir = trace_dir
        self._profile_steps = max(1, int(profile_steps))
        self._prof_on = self._prof_done = False
        self._prof_base = 0
        self._kv_nbytes = None         # lazy: KV bytes of the cache tree

        # --- fault injection (docs/robustness.md) --------------------- #
        # deterministic seeded schedule; the default NoFaults is a
        # zero-overhead no-op (same contract as the Recorder)
        if faults is None:
            faults = faults_mod.from_env()
        elif isinstance(faults, str):
            faults = faults_mod.Faults.parse(faults)
        elif faults is False:
            faults = faults_mod.NoFaults()
        self.faults = faults
        if self.faults.enabled:
            self.metrics.add_collector(self.faults.stats)

        # host-side scheduling state
        self.queue: collections.deque[Request] = collections.deque()
        self.slots: List[Optional[Request]] = [None] * max_batch
        self.requests: Dict[int, Request] = {}
        self.responses: Dict[int, Response] = {}
        self._deadline_armed = False   # any live request has deadline_s

        # device-resident decode state (never read back in steady state)
        self.key = jax.random.PRNGKey(seed)
        self.tokens = jnp.zeros((max_batch, 1), jnp.int32)
        self.prev = jnp.zeros((max_batch, 1), jnp.int32)   # spec: token
        # preceding the pending one (the draft cache lags by one position)
        self.remaining = jnp.zeros((max_batch,), jnp.int32)
        self.active = jnp.zeros((max_batch,), bool)
        self.eos = jnp.full((max_batch,), -1, jnp.int32)
        # fault-poison lane: an always-present additive input to every
        # step program's sampler logits (0.0 = exact identity for finite
        # values). The nan_logits site sets one row to NaN for one step;
        # because it is a program *input*, injection never recompiles
        # and a fault-free engine's programs are bit-identical.
        self.poison = self._poison_zero = jnp.zeros((max_batch,),
                                                    jnp.float32)

        # --- paged KV cache ------------------------------------------- #
        self.paged = bool(paged)
        self.page_size = int(page_size)
        self._paged: Optional[paged_kv.PagedKVState] = None
        self._depth_ub = [0] * max_batch   # per-slot provisioned depth:
        # an upper bound on the device's committed depth, advanced ahead
        # of each dispatched step and corrected at every poll
        if self.paged:
            if not model.supports_paged:
                raise ValueError(
                    "paged KV requires the extend path (attention-only "
                    f"stacks); family {cfg.family!r} has none")
            n_blk = paged_kv.num_blocks(self.kv_len, self.page_size)
            # default: capacity parity with the contiguous layout, plus
            # headroom for provisioning drift (depth upper bounds run
            # ahead of the harvested truth between polls)
            self.num_pages = int(num_pages) if num_pages \
                else max_batch * n_blk + 2 * max_batch
            if self.num_pages < n_blk:
                # one full-length stream must always fit once the pool
                # drains, else admission backpressure can never clear
                raise ValueError(
                    f"num_pages={self.num_pages} cannot hold one full "
                    f"stream ({n_blk} blocks of {self.page_size} tokens)")
            self._paged = paged_kv.PagedKVState(
                max_batch, self.kv_len, self.page_size, self.num_pages)
            self.cache = model.make_paged_cache(
                max_batch, cache_len, page_size=self.page_size,
                num_pages=self.num_pages)
        else:
            self.num_pages = 0
            self.cache = model.make_cache(max_batch, cache_len)
        if self.mesh is not None:
            # KV cache: heads on the model axis, slots (batch) on data;
            # decode state: leading batch dim on data; PRNG key replicated
            self._cache_sh = self._SH.cache_shardings(
                self.cache, self.mesh, self._b_axes)
            self.cache = jax.device_put(self.cache, self._cache_sh)
            self._tok_sh = self._SH.batch_shardings(self.tokens, self.mesh,
                                                    self._b_axes)
            self._vec_sh = self._SH.batch_shardings(self.remaining,
                                                    self.mesh, self._b_axes)
            self.tokens = jax.device_put(self.tokens, self._tok_sh)
            self.prev = jax.device_put(self.prev, self._tok_sh)
            self.remaining = jax.device_put(self.remaining, self._vec_sh)
            self.active = jax.device_put(self.active, self._vec_sh)
            self.eos = jax.device_put(self.eos, self._vec_sh)
            self._poison_zero = jax.device_put(self._poison_zero,
                                               self._vec_sh)
            self.poison = self._poison_zero
            self.key = jax.device_put(self.key, self._repl)

        # per-step sampled-token trace: device arrays, harvested lazily.
        # Plain decode appends (B,) token vectors; mixed/spec/admission
        # steps append ((B, W) block, (B,) emit-count) pairs (W = 1 for
        # mixed and admission entries, gamma+1 for speculative entries).
        self._trace: List[Any] = []
        self._trace_base = 0                      # global step of _trace[0]
        self._slot_start = [0] * max_batch        # global step per slot
        self._steps = 0
        self._step_wall: List[float] = []         # per-step wall clock (for
        # inter-token gaps; assigned at burst sync, padded for raw
        # step(), pruned with the trace — _step_wall_base is the global
        # step index of entry 0)
        self._step_wall_base = 0
        self._await_first: List[Request] = []     # chunked admissions whose
        # first token exists on device but has no host timestamp yet
        self._drop_compile_step = True            # step_times[0] is compile

        # --- speculative decoding ------------------------------------- #
        draft_src = draft if draft is not None else (cfg.draft or None)
        gamma = spec_gamma or cfg.spec_gamma
        if draft_src is not None and gamma == 0:
            gamma = 4
        if gamma and draft_src is None:
            raise ValueError("spec_gamma set but no draft configured "
                             "(pass draft=... or set cfg.draft)")
        self.spec_gamma = gamma if draft_src is not None else 0
        self._ngram = isinstance(draft_src, str) \
            and draft_src.partition("@")[0] == "ngram"
        self._draft_model: Optional[Model] = None
        self._draft_params = None
        self.draft_cache = None
        self.hist = self.hist_len = None   # ngram drafter token history
        self._hist_sh = None
        if self.spec_gamma:
            if self.spec_gamma + 1 > self.kv_len:
                raise ValueError(
                    f"spec_gamma={self.spec_gamma} needs a verify window "
                    f"of {self.spec_gamma + 1} <= kv ring {self.kv_len}")
            if self._ngram:
                # family-agnostic prompt-lookup drafter: proposals come
                # from each slot's own effective token stream, kept on
                # device so the spec step stays sync-free. Sized for the
                # longest stream worth matching against; longer streams
                # keep their most recent window (serving/ngram_draft.py)
                H = 2 * self.kv_len
                self.hist = jnp.full((max_batch, H), -1, jnp.int32)
                self.hist_len = jnp.zeros((max_batch,), jnp.int32)
            else:
                if isinstance(draft_src, str):
                    from repro.quant.self_draft import make_self_draft
                    dmodel, dparams = make_self_draft(model, params,
                                                      draft_src)
                else:
                    dmodel, dparams = draft_src
                if model.rollback_needs_replay \
                        or dmodel.rollback_needs_replay:
                    raise ValueError(
                        "model-draft speculation requires caches that "
                        "rewind without replay on both sides (attention-"
                        f"backed); families {cfg.family!r} / "
                        f"{dmodel.cfg.family!r} carry recurrent state — "
                        "use draft='ngram' instead")
                if model.encode_memory is not None:
                    raise ValueError(
                        "model-draft speculation is not wired for "
                        "encoder-decoder stacks (the draft would need "
                        "its own cross-attention memory per request) — "
                        "use draft='ngram' instead")
                self._draft_model = dmodel
                self._draft_params = dparams
                self.draft_cache = dmodel.make_cache(max_batch, cache_len)
            if self.mesh is not None and self._draft_model is not None:
                # same rules as the target: the self-draft's params are
                # (slices of) the target's, so they shard identically
                self._draft_param_sh = self._SH.param_shardings(
                    self._draft_params, self.mesh)
                self._draft_params = jax.device_put(self._draft_params,
                                                    self._draft_param_sh)
                self._draft_cache_sh = self._SH.cache_shardings(
                    self.draft_cache, self.mesh, self._b_axes)
                self.draft_cache = jax.device_put(self.draft_cache,
                                                  self._draft_cache_sh)
            if self.mesh is not None and self._ngram:
                self._hist_sh = self._SH.batch_shardings(
                    self.hist, self.mesh, self._b_axes)
                self.hist = jax.device_put(self.hist, self._hist_sh)
                self.hist_len = jax.device_put(self.hist_len,
                                               self._vec_sh)
            # a spec step emits up to gamma+1 tokens per slot, so polls
            # must come ~(gamma+1)x as often to keep the post-finish
            # overshoot (device decoding an already-finished slot until
            # the next poll) the same number of *tokens* as plain decode
            self.sync_every = max(1, self.sync_every
                                  // (self.spec_gamma + 1))

        # --- continuous batching (the one admission path) -------------- #
        chunk = cfg.prefill_chunk if prefill_chunk is None \
            else prefill_chunk
        # 0 / unset = a single max-size chunk per admission: the whole
        # prompt enters through one fused extend. There is no separate
        # monolithic prefill program — every family admits through the
        # chunked path.
        self.prefill_chunk = min(int(chunk), self.kv_len) if chunk \
            else self.kv_len
        pct = cfg.prefix_cache_tokens if prefix_cache_tokens is None \
            else prefix_cache_tokens
        # prefix reuse stores target-cache slices only; in spec mode the
        # draft cache would still need recomputation, so it is disabled.
        # The extract/materialize slot programs slice KV rings, so the
        # trie is attention-only-stack scoped (recurrent state and
        # encoder memory have no per-position KV slices to share).
        self.prefix_cache: Optional[PrefixCache] = None
        if pct and not self.spec_gamma and model.supports_paged:
            if self.paged:
                # entries are page-index lists; bucketing on the page
                # size makes every hit a whole-page alias, and eviction
                # drops the entry's page references (the pages outlive
                # it while any live slot still aliases them)
                self.prefix_cache = PrefixCache(
                    pct, self.page_size,
                    on_evict=lambda e: self._paged.release_pages(e["kv"]))
            else:
                self.prefix_cache = PrefixCache(pct, self.prefill_chunk)
        self._admit: Optional[_Admission] = None

        if self.spec_gamma:
            self._step_fn = self._build_ngram_spec_step() if self._ngram \
                else self._build_spec_step()
        else:
            self._step_fn = self._build_step()
        self._mixed_fn = None          # fused decode+chunk, built lazily
        self._admit_chunk_fn = None    # spec-mode chunk program, lazy
        self._slot_jits: Dict[Tuple, Any] = {}   # reset/materialize/extract
        # live component stats surface through snapshot() collectors
        if self.prefix_cache is not None:
            self.metrics.add_collector(self.prefix_cache.stats)
        if self.paged:
            self.metrics.add_collector(self._paged.stats)

    # ------------------------------------------------------------ #
    # host-side step series (live views into the metrics registry)
    # ------------------------------------------------------------ #
    @property
    def step_times(self) -> List[float]:
        """Per-step wall clock, aligned with ``step_kinds``. The list is
        the registry's ``step_wall_s`` series storage itself — appends
        and in-place rewrites (burst averaging) hit the same object."""
        return self._step_series.values

    @step_times.setter
    def step_times(self, v) -> None:
        self._step_series.values[:] = list(v)

    @property
    def step_kinds(self) -> List[str]:
        """"plain"|"mixed"|"admit"|"spec" per step, aligned with
        ``step_times`` — lets benchmarks separate steady decode from
        steps that also carried admission work."""
        return self._kind_series.values

    @step_kinds.setter
    def step_kinds(self, v) -> None:
        self._kind_series.values[:] = list(v)

    def _record_step(self, kind: str) -> None:
        """One engine step happened: advance the global counter and the
        registry's per-kind counters + aligned kind series (the wall
        entry is appended by ``step()`` once timing is known)."""
        self._kind_series.append(kind)
        self.metrics.counter("steps_" + kind).inc()
        self._c_steps.inc()
        self._steps += 1

    # ------------------------------------------------------------ #
    # jitted programs
    # ------------------------------------------------------------ #
    def _jit(self, fn, donate=(), in_sh=None, out_sh=None, name=""):
        """``jax.jit`` with the engine's mesh wiring. Off-mesh this is a
        plain jit. On a mesh, every program gets explicit
        ``in_shardings``/``out_shardings`` (donated buffers keep their
        layout, so the cache is updated in place and nothing is
        re-laid-out between steps) and is *traced* inside the
        activation-rules context — ``shard_activation`` call sites in
        the models become real constraints and ``kernels.dispatch``
        routes Pallas ops to their partitionable jnp references.

        Every program is wrapped by the recompile watchdog: a call that
        grows the jit cache records a compile event (program ``name``,
        elapsed wall) into the registry, and — once the engine is
        steady (``reset_stats``/``mark_steady``) — raises a
        ``telemetry.RecompileWarning``."""
        if self.mesh is None:
            return self._watch(jax.jit(fn, donate_argnums=donate), name)
        jitted = jax.jit(fn, donate_argnums=donate,
                         in_shardings=in_sh, out_shardings=out_sh)
        mesh, rules = self.mesh, self._act_rules
        from repro.distribution.sharding import activation_sharding

        def wrapped(*args):
            with activation_sharding(mesh, rules):
                return jitted(*args)
        wrapped._jit = jitted        # compile-count introspection (tests)
        return self._watch(wrapped, name)

    def _watch(self, fn, name: str):
        """Recompile-watchdog wrapper: detect compiles by jit-cache
        growth around each call (a compile blocks the dispatching call,
        so its wall time is the observed elapsed). Adds two cache-size
        probes and two clock reads per call — host-only, no effect on
        the compiled programs themselves."""
        inner = getattr(fn, "_jit", fn)
        probe = getattr(inner, "_cache_size", None)
        if probe is None:            # jax without cache introspection
            return fn
        watchdog = self._watchdog

        def watched(*args):
            before = probe()
            t0 = time.perf_counter()
            out = fn(*args)
            if probe() > before:
                t1 = time.perf_counter()
                watchdog.record(name or getattr(fn, "__name__", "jit"),
                                t1 - t0, self._steps, t1)
            return out
        watched._jit = inner         # program_cache_sizes introspection
        return watched

    def program_cache_sizes(self) -> Dict[str, int]:
        """Compiled-specialization count per fused-step program. Under a
        mesh this is the no-recompile guard: steady-state serving must
        keep each program at one entry — a growing count means some
        input's sharding/layout is churning step to step."""
        out: Dict[str, int] = {}
        for name, fn in (("step", self._step_fn),
                         ("mixed", self._mixed_fn),
                         ("admit_chunk", self._admit_chunk_fn)):
            if fn is None:
                continue
            inner = getattr(fn, "_jit", fn)
            if hasattr(inner, "_cache_size"):
                out[name] = inner._cache_size()
        return out

    def _build_step(self):
        """Fused decode: model step + sampling + slot bookkeeping, with the
        cache and decode state donated so XLA updates them in place.

        Paged engines decode through a masked T=1 ``extend_into_cache``
        (bit-identical per row to ``decode_step``) so rows the device
        already finished neither scatter into pages nor advance their
        step — page provisioning stays an upper bound on real writes."""
        model, sampler = self.model, self.sampler

        if self.paged:
            def step(params, cache, tokens, remaining, active, eos, key,
                     poison):
                logits, cache = model.extend_into_cache(
                    params, tokens, cache, active.astype(jnp.int32),
                    last_only=True)
                key, sk = jax.random.split(key)
                nxt, bad = _guarded_sample(
                    sampler, sk,
                    logits[:, 0].astype(jnp.float32) + poison[:, None])
                done = active & (bad | (remaining <= 1) | (nxt == eos))
                new_active = active & ~done
                remaining = jnp.where(active, remaining - 1, remaining)
                new_tokens = jnp.where(active, nxt, tokens[:, 0])
                return (new_tokens[:, None], cache, remaining, new_active,
                        key)
        else:
            def step(params, cache, tokens, remaining, active, eos, key,
                     poison):
                logits, cache = model.decode_step(params, tokens, cache)
                key, sk = jax.random.split(key)
                nxt, bad = _guarded_sample(                        # (B,)
                    sampler, sk,
                    logits[:, -1].astype(jnp.float32) + poison[:, None])
                done = active & (bad | (remaining <= 1) | (nxt == eos))
                new_active = active & ~done
                remaining = jnp.where(active, remaining - 1, remaining)
                return nxt[:, None], cache, remaining, new_active, key

        donate = (1, 2, 3, 4) if self._donate else ()
        in_sh = out_sh = None
        if self.mesh is not None:
            r, tok, vec = self._repl, self._tok_sh, self._vec_sh
            in_sh = (self._param_sh, self._cache_sh, tok, vec, vec, vec, r,
                     vec)
            out_sh = (tok, self._cache_sh, vec, vec, r)
        return self._jit(step, donate, in_sh, out_sh, name="step")

    @staticmethod
    def _slot_extend(model, params, cache, slot, chunk, n, last_only=True,
                     paged=False):
        """Slot-direct chunk extend inside a jitted program: slice the
        admitting slot out of the batched cache (batch axis 1 under the
        block axis), advance it by ``n`` of the chunk's C tokens at
        batch 1, and write it back with ``dynamic_update_slice`` — the
        chunk costs C tokens at batch 1, NOT B·C. (An earlier design ran
        a (B, C) matrix through one extend; every decode row then paid
        the chunk's sequence length through all matmuls and tail ITL got
        *worse* than the stall baseline it was meant to fix.)

        Paged caches share their page pools across slots: only the
        per-slot leaves (block table / pos / step) are sliced and written
        back; the pools pass through whole and the chunk's KV scatters
        into them through the sliced block-table row."""
        if paged:
            def slc(node):
                return {k: (v if k in paged_kv.POOL_KEYS else
                            lax.dynamic_slice_in_dim(v, slot, 1, axis=1))
                        for k, v in node.items()}
            cache1 = paged_kv.walk_attn(cache, slc)
            logits, cache1 = model.extend_into_cache(
                params, chunk[None, :], cache1, n[None],
                last_only=last_only)

            def merge(full, upd):
                return {k: (upd[k] if k in paged_kv.POOL_KEYS else
                            lax.dynamic_update_slice_in_dim(
                                full[k], upd[k], slot, axis=1))
                        for k in full}
            return logits, paged_kv.walk_attn2(cache, cache1, merge)
        cache1 = jax.tree.map(
            lambda t: lax.dynamic_slice_in_dim(t, slot, 1, axis=1), cache)
        logits, cache1 = model.extend_into_cache(
            params, chunk[None, :], cache1, n[None], last_only=last_only)
        cache = jax.tree.map(
            lambda full, u: lax.dynamic_update_slice_in_dim(
                full, u, slot, axis=1), cache, cache1)
        return logits, cache

    def _build_mixed_step(self):
        """One fused decode + prefill-chunk program (static shapes):

        1. all active slots decode one token (a masked T=1
           ``extend_into_cache`` — bit-identical per row to the plain
           step, but the admitting and idle rows advance by 0 so nothing
           is speculated into a half-filled slot);
        2. the admitting slot is sliced out, advanced by up to
           ``prefill_chunk`` prompt tokens at batch 1, and written back
           (``_slot_extend``);
        3. one sampler call over each row's last-valid logits gives the
           decode rows their next token and — when the chunk completes
           the prompt (``a_last``) — the admitting row its *first*
           token, arming it on device (tokens/remaining/active/eos rows
           written in-program, no host round-trip).

        Emitted tokens flow through the same trace/poll contract as
        plain decode (W = 1 blocks with a per-row emit count)."""
        model, sampler = self.model, self.sampler
        is_paged = self.paged

        def mixed(params, cache, tokens, remaining, active, eos, key,
                  chunk, a_slot, a_len, a_last, a_rem, a_eos, poison):
            B = tokens.shape[0]
            bidx = jnp.arange(B)
            is_admit = bidx == a_slot
            dec_logits, cache = model.extend_into_cache(
                params, tokens, cache, active.astype(jnp.int32),
                last_only=True)
            ch_logits, cache = self._slot_extend(
                model, params, cache, a_slot, chunk, a_len,
                paged=is_paged)
            logits = jnp.where(is_admit[:, None], ch_logits[0, 0][None],
                               dec_logits[:, 0])
            key, sk = jax.random.split(key)
            nxt, bad = _guarded_sample(                         # (B,)
                sampler, sk, logits.astype(jnp.float32) + poison[:, None])
            arm = is_admit & a_last
            emit = active | arm
            done = emit & (bad | (jnp.where(arm, a_rem, remaining) <= 1)
                           | (nxt == jnp.where(arm, a_eos, eos)))
            new_active = emit & ~done
            new_remaining = jnp.where(
                arm, a_rem - 1,
                jnp.where(active, remaining - 1, remaining))
            new_eos = jnp.where(arm, a_eos, eos)
            new_tokens = jnp.where(emit, nxt, tokens[:, 0])
            return (new_tokens[:, None], nxt[:, None],
                    emit.astype(jnp.int32), cache, new_remaining,
                    new_active, new_eos, key)

        donate = (1, 2, 3, 4, 5) if self._donate else ()
        in_sh = out_sh = None
        if self.mesh is not None:
            r, tok, vec = self._repl, self._tok_sh, self._vec_sh
            in_sh = (self._param_sh, self._cache_sh, tok, vec, vec, vec,
                     r, r, r, r, r, r, r, vec)
            out_sh = (tok, tok, vec, self._cache_sh, vec, vec, vec, r)
        return self._jit(mixed, donate, in_sh, out_sh, name="mixed")

    def _build_admit_chunk(self):
        """Spec-mode chunk program: advance one admitting request by up to
        C prompt tokens in the target cache and (one position behind) in
        the draft cache — both slot-direct at batch 1 — arming the slot
        on completion. Dispatched right before the fused spec step, so
        admission never stalls speculative decode of the other slots.
        The draft consumes the same chunk capped at L-1 total (its cache
        lags the committed depth by one: the last prompt token becomes
        ``prev`` and is re-consumed by the first draft verify window)."""
        model, draft = self.model, self._draft_model
        sampler = self.sampler
        is_paged = self.paged

        def admit(params, dparams, cache, dcache, tokens, prev, remaining,
                  active, eos, key, chunk, a_slot, a_len, d_len, a_last,
                  a_rem, a_eos, a_prev, poison):
            B = tokens.shape[0]
            bidx = jnp.arange(B)
            is_admit = bidx == a_slot
            logits, cache = self._slot_extend(
                model, params, cache, a_slot, chunk, a_len,
                paged=is_paged)
            _, dcache = self._slot_extend(
                draft, dparams, dcache, a_slot, chunk, d_len)
            key, sk = jax.random.split(key)
            nxt, bad = _guarded_sample(                          # (1,)
                sampler, sk,
                logits[:, 0].astype(jnp.float32) + poison[a_slot])
            arm = is_admit & a_last
            done = arm & (bad[0] | (a_rem <= 1) | (nxt[0] == a_eos))
            new_active = active | (arm & ~done)
            new_remaining = jnp.where(arm, a_rem - 1, remaining)
            new_eos = jnp.where(arm, a_eos, eos)
            new_tokens = jnp.where(arm, nxt[0], tokens[:, 0])
            new_prev = jnp.where(arm, a_prev, prev[:, 0])
            return (new_tokens[:, None], new_prev[:, None],
                    new_tokens[:, None], arm.astype(jnp.int32), cache,
                    dcache, new_remaining, new_active, new_eos, key)

        donate = (2, 3, 4, 5, 6, 7, 8) if self._donate else ()
        in_sh = out_sh = None
        if self.mesh is not None:
            r, tok, vec = self._repl, self._tok_sh, self._vec_sh
            in_sh = (self._param_sh, self._draft_param_sh, self._cache_sh,
                     self._draft_cache_sh, tok, tok, vec, vec, vec, r,
                     r, r, r, r, r, r, r, r, vec)
            out_sh = (tok, tok, tok, vec, self._cache_sh,
                      self._draft_cache_sh, vec, vec, vec, r)
        return self._jit(admit, donate, in_sh, out_sh, name="admit_chunk")

    def _build_spec_step(self):
        """One fused draft–verify–accept program (static shapes):

        1. the draft proposes gamma tokens autoregressively. Its cache
           *lags the committed depth by one position* (see below), so the
           first proposal comes from a 2-token verify window
           ``[prev, pending]`` and the remaining gamma-1 from single-token
           decodes — gamma draft forwards total, and the draft cache
           never develops holes on full acceptance;
        2. the target scores all gamma+1 positions in one masked
           multi-token forward (``verify_step``) at each row's own offset;
        3. ``sampler.speculative`` accepts a per-row prefix and resamples
           the first rejection (greedy: emitted prefix == target argmax,
           so output is token-identical to non-speculative decode);
        4. both caches roll their per-row ``step`` back via
           ``Model.rollback`` — target to the committed depth, draft to
           committed-1 — and stored keys beyond it stay causally
           invisible;
        5. slot bookkeeping mirrors the plain step with a variable emit
           count ``n_emit in [1, gamma+1]`` per row.

        Lag invariant: entering a step with committed depth C, the target
        cache holds positions < C and the draft cache positions < C-1;
        ``prev`` is the token at C-1 and ``tokens`` the pending one at C.
        The draft's verify window rewrites C-1 and C, decodes write
        C+1..C+gamma-1, and the last proposal is *never* written — its
        position is re-consumed by the next step's verify window, so full
        acceptance leaves no hole.

        Every forward is an ``extend_into_cache`` masked by ``active``:
        inactive rows neither write keys nor advance their ``step``.
        Active rows are bit-identical either way (attention is per-row),
        but an *admitting* slot — mid-chunked-prefill while its
        neighbours keep speculating — must not have garbage speculated
        into the row between its chunks.
        """
        model, sampler = self.model, self.sampler
        draft, gamma = self._draft_model, self.spec_gamma

        def spec(params, dparams, cache, dcache, tokens, prev, remaining,
                 active, eos, key, poison):
            B = tokens.shape[0]
            act1 = active.astype(jnp.int32)
            # 1) draft proposals (and their full logit rows, for the
            #    stochastic accept ratio p/q). _finite_rows keeps a
            #    NaN-poisoned draft row well-defined — the target verify
            #    is the authority and simply rejects its proposals
            window = jnp.concatenate([prev, tokens], axis=1)   # (B, 2)
            dl, dcache = draft.extend_into_cache(dparams, window, dcache,
                                                 2 * act1)
            d_toks, d_logits = [], []
            cur_logits = _finite_rows(dl[:, -1].astype(jnp.float32))
            for i in range(gamma):
                key, sk = jax.random.split(key)
                t = sampler(sk, cur_logits)
                d_toks.append(t)
                d_logits.append(cur_logits)
                if i + 1 < gamma:
                    dl, dcache = draft.extend_into_cache(
                        dparams, t[:, None], dcache, act1)
                    cur_logits = _finite_rows(
                        dl[:, -1].astype(jnp.float32))
            draft_tokens = jnp.stack(d_toks, axis=1)          # (B, g)
            draft_logits = jnp.stack(d_logits, axis=1)        # (B, g, V)

            # 2) one masked multi-token target forward over
            #    [pending, draft_0..draft_{g-1}]
            seq = jnp.concatenate([tokens, draft_tokens], axis=1)
            t_logits, cache = model.extend_into_cache(
                params, seq, cache, (gamma + 1) * act1)

            # 3) accept prefix + resample first rejection (on device).
            #    A row whose target logits are not finite emits the
            #    single ERR_TOKEN sentinel (n_acc forced to 0) and is
            #    marked done below — containment mirrors _guarded_sample
            t32 = t_logits.astype(jnp.float32) + poison[:, None, None]
            bad = active & ~jnp.all(jnp.isfinite(t32), axis=(1, 2))
            key, sk = jax.random.split(key)
            block, n_acc = sampler.speculative(
                sk, draft_tokens, draft_logits,
                jnp.where(bad[:, None, None], 0.0, t32))
            block = jnp.where(bad[:, None], jnp.int32(ERR_TOKEN), block)
            n_acc = jnp.where(bad, 0, n_acc)
            n_emit = jnp.where(active, n_acc + 1, 0)          # (B,)

            # 4) per-row rollback to the accepted depth. verify advanced
            #    active targets by gamma+1; the committed depth is
            #    old_step + 1 + n_acc (pending + accepted drafts), i.e.
            #    current - gamma + n_acc. The draft sits at committed-1.
            #    Inactive rows did not move and must not be rolled.
            steps_now = model.cache_steps(cache)              # (B,)
            committed = jnp.where(active, steps_now - gamma + n_acc,
                                  steps_now)
            cache = model.rollback(cache, committed)
            dcache = draft.rollback(
                dcache, jnp.where(active, committed - 1,
                                  draft.cache_steps(dcache)))

            # 5) bookkeeping (same stop conditions as the plain step,
            #    with a variable emit count)
            idx = jnp.arange(gamma + 1)[None, :]
            emitted = idx < n_emit[:, None]
            eos_hit = jnp.any(emitted & (block == eos[:, None]), axis=1)
            done = active & (bad | (remaining <= n_emit) | eos_hit)
            new_active = active & ~done
            remaining = jnp.where(
                active, jnp.maximum(remaining - n_emit, 0), remaining)
            bidx = jnp.arange(B)
            last = block[bidx, jnp.maximum(n_emit, 1) - 1]
            nxt = jnp.where(active, last, tokens[:, 0])
            # token preceding the new pending one: the last accepted
            # draft, or the old pending token when nothing was accepted
            new_prev = jnp.where(
                n_acc > 0, block[bidx, jnp.maximum(n_acc, 1) - 1],
                tokens[:, 0])
            new_prev = jnp.where(active, new_prev, prev[:, 0])
            return (nxt[:, None], new_prev[:, None], block, n_emit,
                    cache, dcache, remaining, new_active, key)

        donate = (2, 3, 4, 5, 6, 7) if self._donate else ()
        in_sh = out_sh = None
        if self.mesh is not None:
            r, tok, vec = self._repl, self._tok_sh, self._vec_sh
            in_sh = (self._param_sh, self._draft_param_sh, self._cache_sh,
                     self._draft_cache_sh, tok, tok, vec, vec, vec, r,
                     vec)
            # tok's (batch, None) spec also covers the (B, gamma+1) block
            out_sh = (tok, tok, tok, vec, self._cache_sh,
                      self._draft_cache_sh, vec, vec, r)
        return self._jit(spec, donate, in_sh, out_sh, name="spec_step")

    def _build_ngram_admit_chunk(self):
        """n-gram-mode chunk program: advance one admitting request by up
        to C prompt tokens in the target cache (slot-direct at batch 1),
        arming the slot on completion — the drafter has no cache, so
        unlike the model-draft variant there is no lagging draft extend.
        The armed first token is appended to the slot's history row on
        device (it is part of the stream the drafter matches against)."""
        model, sampler = self.model, self.sampler
        is_paged = self.paged

        def admit(params, cache, tokens, hist, hist_len, remaining,
                  active, eos, key, chunk, a_slot, a_len, a_last, a_rem,
                  a_eos, poison):
            B = tokens.shape[0]
            H = hist.shape[1]
            bidx = jnp.arange(B)
            is_admit = bidx == a_slot
            logits, cache = self._slot_extend(
                model, params, cache, a_slot, chunk, a_len,
                paged=is_paged)
            key, sk = jax.random.split(key)
            nxt, bad = _guarded_sample(                          # (1,)
                sampler, sk,
                logits[:, 0].astype(jnp.float32) + poison[a_slot])
            arm = is_admit & a_last
            done = arm & (bad[0] | (a_rem <= 1) | (nxt[0] == a_eos))
            new_active = active | (arm & ~done)
            new_remaining = jnp.where(arm, a_rem - 1, remaining)
            new_eos = jnp.where(arm, a_eos, eos)
            new_tokens = jnp.where(arm, nxt[0], tokens[:, 0])
            wpos = jnp.where(a_last, hist_len[a_slot], H)   # H -> dropped
            hist = hist.at[a_slot, wpos].set(nxt[0], mode="drop")
            hist_len = jnp.where(
                is_admit & a_last,
                jnp.minimum(hist_len + 1, H), hist_len)
            return (new_tokens[:, None], new_tokens[:, None],
                    arm.astype(jnp.int32), cache, hist, hist_len,
                    new_remaining, new_active, new_eos, key)

        donate = (1, 2, 3, 4, 5, 6, 7) if self._donate else ()
        in_sh = out_sh = None
        if self.mesh is not None:
            r, tok, vec = self._repl, self._tok_sh, self._vec_sh
            in_sh = (self._param_sh, self._cache_sh, tok, self._hist_sh,
                     vec, vec, vec, vec, r, r, r, r, r, r, r, vec)
            out_sh = (tok, tok, vec, self._cache_sh, self._hist_sh, vec,
                      vec, vec, vec, r)
        return self._jit(admit, donate, in_sh, out_sh,
                         name="ngram_admit_chunk")

    def _build_ngram_spec_step(self):
        """One fused propose–verify–accept program with the prompt-lookup
        drafter (``serving/ngram_draft.py``) in place of a draft model:

        1. ``ngram_propose`` matches each row's recent history suffix
           against its own stream and proposes the gamma tokens that
           followed the most recent earlier occurrence (deterministic —
           no draft forward, no draft cache, no lag bookkeeping);
        2. the target scores all gamma+1 positions in one masked extend,
           exactly like the model-draft spec step;
        3. ``sampler.speculative`` accepts a per-row prefix against the
           drafter's one-hot proposal distribution (greedy output is
           token-identical to plain decode by the same argument: the
           emitted prefix is the target argmax);
        4. rollback is family-aware: attention-backed targets rewind
           ``step`` to the committed depth; recurrent targets
           (``Model.rollback_needs_replay``) restore the pre-verify
           checkpoint and *replay* the accepted prefix through the same
           extend — state after replay is bit-identical to having never
           speculated (tests/test_families.py);
        5. the emitted block is appended to the history rows on device,
           growing the drafter's corpus as the stream generates.
        """
        model, sampler = self.model, self.sampler
        gamma = self.spec_gamma
        vocab = self.model.cfg.vocab
        replay = self.model.rollback_needs_replay
        from repro.serving.ngram_draft import ngram_propose

        def spec(params, cache, tokens, hist, hist_len, remaining,
                 active, eos, key, poison):
            B = tokens.shape[0]
            H = hist.shape[1]
            act1 = active.astype(jnp.int32)
            # 1) proposals from each row's own emitted stream
            draft_tokens, draft_logits = ngram_propose(
                hist, hist_len, gamma=gamma, vocab=vocab)
            seq = jnp.concatenate([tokens, draft_tokens], axis=1)

            # 2) one masked multi-token target forward
            t_logits, cache = model.extend_into_cache(
                params, seq, cache, (gamma + 1) * act1)

            # 3) accept prefix + resample first rejection (on device);
            #    NaN/inf containment mirrors the model-draft step
            t32 = t_logits.astype(jnp.float32) + poison[:, None, None]
            bad = active & ~jnp.all(jnp.isfinite(t32), axis=(1, 2))
            key, sk = jax.random.split(key)
            block, n_acc = sampler.speculative(
                sk, draft_tokens, draft_logits,
                jnp.where(bad[:, None, None], 0.0, t32))
            block = jnp.where(bad[:, None], jnp.int32(ERR_TOKEN), block)
            n_acc = jnp.where(bad, 0, n_acc)
            n_emit = jnp.where(active, n_acc + 1, 0)          # (B,)

            # 4) family-aware rollback to the committed depth
            steps_now = model.cache_steps(cache)              # (B,)
            committed = jnp.where(active, steps_now - gamma + n_acc,
                                  steps_now)
            if replay:
                # recurrent state restores the checkpoint taken before
                # the verify advance, then re-absorbs exactly the
                # accepted prefix (pending + n_acc drafts). Attention
                # sub-caches in a hybrid stack rewrite the same K/V at
                # the same slots — bitwise a no-op for them.
                pre = jnp.where(active, steps_now - (gamma + 1),
                                steps_now)
                cache = model.rollback(cache, pre)
                _, cache = model.extend_into_cache(
                    params, seq, cache, jnp.where(active, n_acc + 1, 0),
                    last_only=True)
            else:
                cache = model.rollback(cache, committed)

            # 5) bookkeeping + history append
            idx = jnp.arange(gamma + 1)[None, :]
            emitted = idx < n_emit[:, None]
            eos_hit = jnp.any(emitted & (block == eos[:, None]), axis=1)
            done = active & (bad | (remaining <= n_emit) | eos_hit)
            new_active = active & ~done
            remaining = jnp.where(
                active, jnp.maximum(remaining - n_emit, 0), remaining)
            bidx = jnp.arange(B)
            last = block[bidx, jnp.maximum(n_emit, 1) - 1]
            nxt = jnp.where(active, last, tokens[:, 0])
            wpos = jnp.where(emitted & active[:, None],
                             hist_len[:, None] + idx, H)   # H -> dropped
            hist = hist.at[bidx[:, None], wpos].set(block, mode="drop")
            hist_len = jnp.minimum(hist_len + n_emit, H)
            return (nxt[:, None], block, n_emit, cache, hist, hist_len,
                    remaining, new_active, key)

        donate = (1, 2, 3, 4, 5, 6) if self._donate else ()
        in_sh = out_sh = None
        if self.mesh is not None:
            r, tok, vec = self._repl, self._tok_sh, self._vec_sh
            in_sh = (self._param_sh, self._cache_sh, tok, self._hist_sh,
                     vec, vec, vec, vec, r, vec)
            out_sh = (tok, tok, vec, self._cache_sh, self._hist_sh, vec,
                      vec, vec, r)
        return self._jit(spec, donate, in_sh, out_sh,
                         name="ngram_spec_step")

    def _get_embed_chunk(self, for_draft: bool = False):
        """VLM admission program: the request's frontend embeddings
        enter the admitting slot through the same masked extend as text
        — one embedding chunk (static length ``frontend.n_tokens``)
        before the token chunks, slot-direct at batch 1."""
        jkey = ("embed_chunk", for_draft)
        if jkey in self._slot_jits:
            return self._slot_jits[jkey]
        model = self._draft_model if for_draft else self.model

        def fn(params, emb, cache, b):
            cache1 = jax.tree.map(
                lambda t: lax.dynamic_slice_in_dim(t, b, 1, axis=1), cache)
            _, cache1 = model.extend_into_cache(params, None, cache1,
                                                embeddings=emb)
            return jax.tree.map(
                lambda full, u: lax.dynamic_update_slice_in_dim(
                    full, u, b, axis=1), cache, cache1)

        donate = (2,) if self._donate else ()
        in_sh = out_sh = None
        if self.mesh is not None:
            r = self._repl
            cache_sh = self._draft_cache_sh if for_draft else self._cache_sh
            in_sh = (self._draft_param_sh if for_draft else self._param_sh,
                     r, cache_sh, r)
            out_sh = cache_sh
        jitted = self._jit(fn, donate, in_sh, out_sh,
                           name=f"embed_chunk{'[d]' if for_draft else ''}")
        self._slot_jits[jkey] = jitted
        return jitted

    def _get_encode_fn(self):
        """Encoder–decoder admission program: encode the request's
        frontend frames once (``Model.encode_memory``) and write the
        per-layer cross-attention KV rows into the admitting slot. The
        memory is prefill-frozen — every later chunk and decode step
        reads it untouched, so the one-shot encode replaces the whole
        encoder half of the old monolithic prefill."""
        jkey = ("encode", 0)
        if jkey in self._slot_jits:
            return self._slot_jits[jkey]
        model = self.model

        def fn(params, frames, cache, b):
            xk, xv = model.encode_memory(params, frames)
            out = dict(cache)
            out["xk"] = lax.dynamic_update_slice_in_dim(
                cache["xk"], xk.astype(cache["xk"].dtype), b, axis=1)
            out["xv"] = lax.dynamic_update_slice_in_dim(
                cache["xv"], xv.astype(cache["xv"].dtype), b, axis=1)
            return out

        donate = (2,) if self._donate else ()
        in_sh = out_sh = None
        if self.mesh is not None:
            in_sh = (self._param_sh, self._repl, self._cache_sh,
                     self._repl)
            out_sh = self._cache_sh
        jitted = self._jit(fn, donate, in_sh, out_sh, name="encode")
        self._slot_jits[jkey] = jitted
        return jitted

    # ------------------------------------------------------------ #
    # slot programs (chunked admission + prefix reuse)
    # ------------------------------------------------------------ #
    def _walk_attn(self, node, fn):
        """Apply ``fn`` to every attention sub-cache dict (identified by
        its ``pos`` row). Non-attention nodes — SSM recurrent state
        dicts, the encdec cross-attention memory arrays — pass through
        untouched; callers that must also reset them walk separately
        (``_get_slot_fn('reset')``)."""
        if not isinstance(node, dict):
            return node
        if "pos" in node:
            return fn(node)
        return {k: self._walk_attn(v, fn) for k, v in node.items()}

    def _get_slot_fn(self, kind: str, P=0):
        """reset / materialize / extract programs for one slot row, jitted
        per (kind, length). Lengths are bucketed chunk multiples, so the
        jit cache stays small: extract is keyed on the stored prefix
        length, materialize on the hit length Q alone — a partial hit of
        a longer stored entry is sliced to Q eagerly in
        ``_start_chunked`` before reaching the program (exact by
        causality: K/V at p depends only on tokens <= p)."""
        jkey = (kind, P)
        if jkey in self._slot_jits:
            return self._slot_jits[jkey]

        def pos_row(node, b, upto):
            nb, _, S = node["pos"].shape
            ar = jnp.arange(S, dtype=jnp.int32)
            row = jnp.where(ar < upto, ar, -1)[None, None, :]
            out = dict(node)
            out["pos"] = lax.dynamic_update_slice(
                node["pos"], jnp.broadcast_to(row, (nb, 1, S)), (0, b, 0))
            out["step"] = lax.dynamic_update_slice(
                node["step"], jnp.full((nb, 1), upto, jnp.int32), (0, b))
            return out

        if kind == "reset":
            def fn(cache, b):
                # erase slot b: every position empty, depth 0 — a recycled
                # slot carries no stale keys from the previous occupant.
                # With P > 0 (the paged prefix-alias path) the first P
                # positions are stamped valid instead: the slot's block
                # table already points at fully-written shared pages, so
                # only the pos/step metadata needs populating. Non-
                # attention state is zeroed outright: SSM recurrent nodes
                # (state + checkpoints + step) and the encdec cross-
                # attention memory rows have no positional masking to
                # hide a previous occupant behind
                def walk(node):
                    if not isinstance(node, dict):
                        return node.at[:, b].set(0)
                    if "pos" in node:
                        return pos_row(node, b, P)
                    if "conv" in node and "ssm" in node:
                        return {k2: v2.at[:, b].set(0)
                                for k2, v2 in node.items()}
                    return {k2: walk(v2) for k2, v2 in node.items()}
                return walk(cache)
        elif kind == "materialize":
            def fn(cache, kv, b):
                # walk cache and entry trees in lockstep: write the P
                # stored K/V (+scale) positions, then stamp pos/step for
                # a slot whose first P positions are now populated
                def walk(c, e):
                    if isinstance(c, dict) and "pos" in c:
                        out = dict(c)
                        for k2, part in e.items():
                            idx = (0, b, 0) + (0,) * (c[k2].ndim - 3)
                            out[k2] = lax.dynamic_update_slice(
                                c[k2], part, idx)
                        return pos_row(out, b, P)
                    return {k2: walk(v2, e[k2]) for k2, v2 in c.items()}
                return walk(cache, kv)
        elif kind == "extract":
            def fn(cache, b):
                def ext(node):
                    out = {}
                    for k2 in ("k", "v", "k_scale", "v_scale"):
                        if k2 in node:
                            sl = lax.dynamic_slice_in_dim(node[k2], b, 1,
                                                          axis=1)
                            out[k2] = lax.slice_in_dim(sl, 0, P, axis=2)
                    return out
                return self._walk_attn(cache, ext)
        else:
            raise ValueError(kind)

        donate = (0,) if (self._donate and kind != "extract") else ()
        in_sh = out_sh = None
        if self.mesh is not None:
            r = self._repl
            if kind == "reset":
                in_sh, out_sh = (self._cache_sh, r), self._cache_sh
            elif kind == "materialize":
                in_sh = (self._cache_sh, self._kv_slice_shardings(P), r)
                out_sh = self._cache_sh
            else:  # extract: the stored slice keeps the cache's layout,
                # so a later materialize of the same entry is copy-only
                in_sh = (self._cache_sh, r)
                out_sh = self._kv_slice_shardings(P)
        jitted = self._jit(fn, donate, in_sh, out_sh,
                           name=f"{kind}[{P}]")
        self._slot_jits[jkey] = jitted
        return jitted

    def _kv_slice_shardings(self, P: int):
        """``cache_shardings`` for the (nb, 1, P, heads, hd) KV-slice
        pytree the extract/materialize slot programs exchange with the
        prefix cache — heads stay on the model axis, the single batch
        row is replicated."""
        def ext(node):
            out = {}
            for k2 in ("k", "v", "k_scale", "v_scale"):
                if k2 in node:
                    out[k2] = jax.ShapeDtypeStruct(
                        node[k2].shape[:1] + (1, P) + node[k2].shape[3:],
                        node[k2].dtype)
            return out
        shapes = self._walk_attn(self.cache, ext)
        return self._SH.cache_shardings(shapes, self.mesh, self._b_axes)

    # ------------------------------------------------------------ #
    # paged provisioning (host allocator <-> device page pools)
    # ------------------------------------------------------------ #
    def _provision(self, slot: int, start: int, n: int) -> bool:
        """Make the pages behind positions [start, start+n) of ``slot``
        privately writable before a dispatched step (allocate missing
        pages, CoW-split shared ones). Exhaustion — real or injected via
        the ``page_alloc`` fault site — degrades instead of crashing
        (docs/robustness.md): reclaim LRU prefix entries, then poll (a
        finished slot may be sitting on pages), then preempt-and-requeue
        the lowest-priority victim; only a pool that genuinely cannot
        hold the live set raises. Returns False when degradation polled
        or preempted: the poll's shrink may have reclaimed headroom
        provisioned for *other* slots this round, so callers must
        rebuild their provisioning pass."""
        clean, polled = True, False
        while True:
            forced = self.faults.enabled and self._fire(
                "page_alloc", step=self._steps, slot=slot)
            if not forced:
                try:
                    copies = self._paged.prepare_write(slot, start, n)
                    break
                except paged_kv.PagePoolExhausted:
                    pass
            if self.prefix_cache is not None \
                    and self.prefix_cache.drop_lru():
                continue
            clean = False
            if not polled:
                polled = True
                self._poll()
                continue
            if self._preempt_one(exclude={slot}):
                continue
            if forced:
                # the injected exhaustion outlived every degradation
                # rung; unlike a real one it freed nothing, so consult
                # the actual pool before declaring the ladder dead
                try:
                    copies = self._paged.prepare_write(slot, start, n)
                    break
                except paged_kv.PagePoolExhausted:
                    pass
            raise RuntimeError(
                f"KV page pool exhausted mid-decode (slot {slot}, "
                f"positions [{start}, {start + n})) with no resumable "
                f"victim to preempt")
        if copies:
            self._copy_pages(copies)
        return clean

    def _copy_pages(self, copies) -> None:
        """Copy-on-write splits: duplicate the shared pool pages on
        device *before* the write that would have mutated them through
        an alias (one jitted gather/scatter per split count)."""
        src = jnp.asarray([s for s, _ in copies], jnp.int32)
        dst = jnp.asarray([d for _, d in copies], jnp.int32)
        self.cache = self._get_page_copy(len(copies))(self.cache, src, dst)

    def _get_page_copy(self, k: int):
        jkey = ("pagecopy", k)
        if jkey in self._slot_jits:
            return self._slot_jits[jkey]

        def fn(cache, src, dst):
            def cp(node):
                out = dict(node)
                for k2 in paged_kv.POOL_KEYS:
                    if k2 in node:
                        out[k2] = node[k2].at[:, dst].set(node[k2][:, src])
                return out
            return self._walk_attn(cache, cp)

        donate = (0,) if self._donate else ()
        in_sh = out_sh = None
        if self.mesh is not None:
            in_sh = (self._cache_sh, self._repl, self._repl)
            out_sh = self._cache_sh
        jitted = self._jit(fn, donate, in_sh, out_sh,
                           name=f"pagecopy[{k}]")
        self._slot_jits[jkey] = jitted
        return jitted

    def _push_block_tables(self) -> None:
        """Sync the host-authoritative block tables into every attention
        sub-cache's ``bt`` leaf (dirty-flagged). The host copy is a tiny
        int32 array; the next jitted step places it on device (and, on a
        mesh, to the bt sharding) as a normal input upload."""
        if not self._paged.dirty:
            return
        bt = self._paged.block_tables

        def setbt(node):
            out = dict(node)
            out["bt"] = np.broadcast_to(bt[None], out["bt"].shape)
            return out
        self.cache = self._walk_attn(self.cache, setbt)
        self._paged.dirty = False

    def _admit_fits(self, req: Request) -> bool:
        """Paged admission backpressure: admit only when the pool can
        hold the whole prompt plus the first decode write (conservative:
        a prefix hit would need less). Reclaims LRU prefix entries
        first; on failure the request simply stays queued (FIFO order is
        preserved — nothing behind it is admitted either)."""
        if not self.paged:
            return True
        need = self._eff_len(req)
        while not self._paged.can_admit(need):
            if self.prefix_cache is None \
                    or not self.prefix_cache.drop_lru():
                return False
        return True

    def _get_mixed(self):
        if self._mixed_fn is None:
            self._mixed_fn = self._build_mixed_step()
        return self._mixed_fn

    def _get_admit_chunk(self):
        if self._admit_chunk_fn is None:
            self._admit_chunk_fn = self._build_ngram_admit_chunk() \
                if self._ngram else self._build_admit_chunk()
        return self._admit_chunk_fn

    # ------------------------------------------------------------ #
    # scheduling
    # ------------------------------------------------------------ #
    def submit(self, req: Request) -> None:
        """Validate and enqueue. Malformed requests raise ``ValueError``
        here with the violated constraint spelled out — never a shape
        error deep inside a jitted program or a silently wedged slot."""
        self._validate(req)
        req.submitted_s = time.perf_counter()
        if req.deadline_s is not None:
            self._deadline_armed = True
        if self.recorder.enabled:
            self.recorder.on_submit(req)
        self.queue.append(req)
        self.requests[req.uid] = req
        self.responses[req.uid] = Response(uid=req.uid,
                                           prompt_len=len(req.prompt))

    def _validate(self, req: Request) -> None:
        prompt = np.asarray(req.prompt)
        if prompt.ndim != 1 or prompt.size == 0:
            raise ValueError(
                f"request {req.uid}: prompt must be a non-empty 1-D "
                f"token array, got shape {prompt.shape}")
        if prompt.dtype.kind not in "iu":
            raise ValueError(
                f"request {req.uid}: prompt must hold integer token "
                f"ids, got dtype {prompt.dtype}")
        if req.max_new_tokens <= 0:
            raise ValueError(
                f"request {req.uid}: max_new_tokens must be positive, "
                f"got {req.max_new_tokens}")
        if req.deadline_s is not None and req.deadline_s <= 0:
            raise ValueError(
                f"request {req.uid}: deadline_s must be positive, got "
                f"{req.deadline_s}")
        old = self.responses.get(req.uid)
        if old is not None and not old.finished:
            raise ValueError(
                f"request uid {req.uid} is already in flight")
        L = int(prompt.size)
        cap = self.kv_len - self._prefix
        if L > cap and (self.paged or not self.model.cfg.sliding_window):
            # a sliding-window ring legitimately serves longer prompts:
            # chunks wrap the ring and the window mask hides overwritten
            # context. Full-attention and paged caches cannot — the
            # overwrite would silently drop attended positions
            raise ValueError(
                f"request {req.uid}: prompt of {L} tokens exceeds the "
                f"KV capacity of {cap} (cache_len={self.cache_len}"
                + (f" minus a {self._prefix}-token frontend prefix"
                   if self._prefix else "")
                + "); raise cache_len or shorten the prompt")
        if self.model.encode_memory is not None \
                and req.embeddings is None:
            raise ValueError(
                f"request {req.uid}: encoder-decoder serving requires "
                "frontend frame embeddings on every request (the "
                "cross-attention memory is encoded at admission)")
        if req.embeddings is not None:
            fe = self.model.cfg.frontend
            if fe is None:
                raise ValueError(
                    f"request {req.uid}: embeddings were supplied but "
                    "the model has no frontend to consume them")
            if self.paged:
                raise ValueError(
                    "paged KV serving is token-only: frontend "
                    "embeddings have no paged admission program")
            emb = np.asarray(req.embeddings)
            if emb.shape != (fe.n_tokens, fe.d_embed):
                raise ValueError(
                    f"request {req.uid}: embeddings must have shape "
                    f"({fe.n_tokens}, {fe.d_embed}) to match the "
                    f"frontend, got {emb.shape}")

    def _free_slot(self) -> Optional[int]:
        admitting = self._admit.slot if self._admit is not None else -1
        for b in range(self.max_batch):
            if self.slots[b] is None and b != admitting:
                return b
        return None

    def _eff_len(self, req: Request) -> int:
        """Length of the request's *effective* token stream: the prompt
        plus any tokens generated before a preemption (replayed through
        admission on resume)."""
        resp = self.responses.get(req.uid)
        if resp is None or resp.finished:
            return len(req.prompt)
        return len(req.prompt) + len(resp.tokens)

    def _fill_free_slots(self) -> None:
        """Admission scheduler (FIFO head): every request starts a
        chunked admission (at most one in flight — 'advance one
        admitting request per step'). A head-of-queue request that
        outranks a live stream may preempt it when the slot table or
        page pool is short — the victim requeues right *behind* the
        displacing request (never ahead: that would livelock) and
        resumes later with its output unchanged."""
        while self.queue:
            if self._admit is not None:
                return                # one chunked admission at a time
            req = self.queue[0]
            b = self._free_slot()
            if b is None:
                if self._outranked(req) and self._preempt_one(
                        below=req.priority, requeue_pos=1):
                    continue
                return
            if self.model.extend_into_cache is None:
                # defensively unreachable: every family builds the
                # extend path (``Model.supports_extend`` is universally
                # True since the admission unification). Counted and
                # traced so a facade regression is observable —
                # ``fallback_admissions`` is asserted zero by the family
                # gate (benchmarks/check_families.py) — then contained
                # as a per-request error instead of wedging the queue
                self.queue.popleft()
                self._c_fallback.inc()
                if self.recorder.enabled:
                    self.recorder.on_admission(req, b, 0, "fallback")
                self._finish_request(req, "error", time.perf_counter())
                continue
            if not self._admit_fits(req):
                # page backpressure: the head waits, unless it
                # outranks a live stream whose pages can serve it
                if self._outranked(req) and self._preempt_one(
                        below=req.priority, requeue_pos=1):
                    continue
                return
            self.queue.popleft()
            self._start_chunked(req, b)

    def _outranked(self, req: Request) -> bool:
        """Cheap pre-check (no device sync) for priority displacement:
        some occupied slot runs at strictly lower priority than ``req``.
        A chunked admission in flight blocks displacement — the head
        could not admit into the freed slot anyway until it drains."""
        if self._admit is not None:
            return False
        return any(r is not None and r.priority < req.priority
                   for r in self.slots)

    def _start_chunked(self, req: Request, b: int) -> None:
        """Begin a chunked admission: probe the prefix cache, then either
        materialise the hit into slot ``b`` (one on-device
        dynamic_update_slice copy) or reset the slot row; the fused mixed
        step takes it from there, ``prefill_chunk`` tokens per step.

        A preempted request re-admits through this same path: its
        effective stream is the prompt plus the tokens it had already
        generated, replayed chunk by chunk — teacher-forcing the model
        through its own earlier output, so the token sampled on arming
        (and every one after) matches the unpreempted run."""
        req.started_s = req.started_s or time.perf_counter()
        done = self.responses[req.uid].tokens
        eff = np.asarray(req.prompt, np.int32)
        if done:
            eff = np.concatenate([eff, np.asarray(done, np.int32)])
        adm = _Admission(req=req, slot=b, base=0, length=len(eff),
                         tokens=eff, n_done=len(done),
                         resumed=bool(done))
        base, kv, ent_len = 0, None, 0
        if self.prefix_cache is not None and req.embeddings is None:
            # embeddings requests never touch the prefix cache: the
            # token stream alone does not key the slot's content (two
            # requests with identical prompts but different frames
            # would alias), so neither lookup nor publication applies
            kv, ent_len, base = self.prefix_cache.lookup(eff)
            adm.base = base
        bb = jnp.int32(b)
        if self._ngram:
            # seed the drafter's corpus with the effective stream (tail-
            # truncated to the history capacity): prompt n-grams are the
            # richest match source for the first generated tokens
            H = int(self.hist.shape[1])
            n = min(len(eff), H)
            row = np.full((H,), -1, np.int32)
            row[:n] = eff[-n:]
            self.hist = self.hist.at[b].set(jnp.asarray(row))
            self.hist_len = self.hist_len.at[b].set(n)
        if self.paged:
            # a prefix hit is a page alias: point the fresh slot's block
            # table at the entry's pages (host refcount bump — zero KV
            # copies, no materialize program) and stamp pos/step for the
            # covered positions; a partial hit just takes fewer pages
            self._paged.release_slot(b)
            if kv is not None:
                self._paged.alias_prefix(b, kv[:base // self.page_size])
            self.cache = self._get_slot_fn(
                "reset", base if kv is not None else 0)(self.cache, bb)
            if self._draft_model is not None:
                self.draft_cache = self._get_slot_fn("reset")(
                    self.draft_cache, bb)
            self._depth_ub[b] = base
            self._admit = adm
            if self.recorder.enabled:
                self.recorder.on_admission(req, b, base, "chunked")
            return
        if kv is not None:
            if base < ent_len:
                # partial hit: take the first Q positions of the longer
                # stored entry eagerly, so the materialize program is
                # keyed on the hit length alone
                kv = jax.tree.map(lambda t: t[:, :, :base], kv)
            self.cache = self._get_slot_fn("materialize", base)(
                self.cache, kv, bb)
        else:
            self.cache = self._get_slot_fn("reset")(self.cache, bb)
            if self._draft_model is not None:
                self.draft_cache = self._get_slot_fn("reset")(
                    self.draft_cache, bb)
        if req.embeddings is not None:
            emb = jnp.asarray(req.embeddings)[None]
            if self.model.encode_memory is not None:
                # encdec: one-shot encode of the frontend frames; the
                # per-layer cross KV rows land in the slot and stay
                # frozen for the request's whole lifetime
                self.cache = self._get_encode_fn()(
                    self.params, emb, self.cache, bb)
            else:
                # vlm: the frontend prefix enters through the same
                # masked extend as text — one embedding chunk before
                # the token chunks
                self.cache = self._get_embed_chunk()(
                    self.params, emb, self.cache, bb)
                if self._draft_model is not None:
                    self.draft_cache = self._get_embed_chunk(True)(
                        self._draft_params, emb, self.draft_cache, bb)
        self._admit = adm
        if self.recorder.enabled:
            self.recorder.on_admission(req, b, base, "chunked")

    # ------------------------------------------------------------ #
    # lifecycle control: cancel / deadlines / preempt-and-requeue
    # (docs/robustness.md)
    # ------------------------------------------------------------ #
    def cancel(self, uid: int) -> bool:
        """Cancel a request in any live state — queued, mid-chunked-
        admission, or actively decoding. Tokens already produced stay in
        the response; the slot and (paged) its pages are released
        immediately and ``finish_reason`` reads ``"cancelled"``. Returns
        True if the request was live, False when it is unknown or had
        already finished."""
        req = self.requests.get(uid)
        resp = self.responses.get(uid)
        if req is None or resp is None or resp.finished:
            return False
        now = time.perf_counter()
        if req in self.queue:
            self.queue.remove(req)
            self._finish_request(req, "cancelled", now)
            self._c_cancel.inc()
            return True
        if self._admit is not None and self._admit.req.uid == uid:
            self._abort_admission("cancelled", now)
            self._c_cancel.inc()
            return True
        for b, r in enumerate(self.slots):
            if r is not None and r.uid == uid:
                self._poll()       # commit tokens already produced...
                if resp.finished:  # ...which may have finished it first
                    return False
                self._release_active_slot(b)
                self._finish_request(req, "cancelled",
                                     time.perf_counter())
                self._c_cancel.inc()
                return True
        return False

    def _finish_request(self, req: Request, reason: str,
                        now: float) -> None:
        resp = self.responses[req.uid]
        resp.finished = True
        resp.finish_reason = reason
        req.finished_s = now
        if self.recorder.enabled:
            self.recorder.on_finish(req, reason, now)

    def _release_active_slot(self, b: int) -> None:
        """Host+device teardown of an occupied slot, keeping its
        harvested tokens: deactivate the device row (masked steps then
        neither write KV nor advance it), detach the request, and — when
        paged — return its pages to the pool immediately."""
        self.active = self.active.at[b].set(False)
        self.slots[b] = None
        self._slot_start[b] = self._steps
        if self.paged:
            self._paged.release_slot(b)
            self._depth_ub[b] = 0

    def _abort_admission(self, reason: str, now: float) -> None:
        """Tear down the in-flight chunked admission (its slot was never
        attached, so only provisioned pages need releasing)."""
        adm, self._admit = self._admit, None
        if self.paged:
            self._paged.release_slot(adm.slot)
            self._depth_ub[adm.slot] = 0
        self._finish_request(adm.req, reason, now)

    def _enforce_deadlines(self, include_active: bool = True) -> None:
        """Finish every request past its absolute deadline with
        ``finish_reason="timeout"`` (keeping partial tokens). Runs at
        tick boundaries: before admission with ``include_active=False``
        (queued/admitting only — an active slot may hold tokens not yet
        harvested) and right after each poll with the full sweep."""
        now = time.perf_counter()
        for req in [r for r in self.queue if r.deadline_abs() <= now]:
            self.queue.remove(req)
            self._finish_request(req, "timeout", now)
            self._c_timeout.inc()
        if self._admit is not None \
                and self._admit.req.deadline_abs() <= now:
            self._abort_admission("timeout", now)
            self._c_timeout.inc()
        if not include_active:
            return
        for b, r in enumerate(self.slots):
            if r is not None and r.deadline_abs() <= now:
                self._release_active_slot(b)
                self._finish_request(r, "timeout", now)
                self._c_timeout.inc()

    def _select_victim(self, exclude=(),
                       below: Optional[int] = None) -> Optional[int]:
        """Pick the slot to preempt: lowest priority first, then latest
        deadline (no deadline counts as latest — most slack), then
        lowest slot index. Only streams that can actually resume qualify
        (the effective stream must still fit the KV ring with room to
        decode). ``below`` restricts victims to priorities strictly
        below it (priority-displacement admission)."""
        best = None
        for b, r in enumerate(self.slots):
            if r is None or b in exclude:
                continue
            if below is not None and r.priority >= below:
                continue
            if self._eff_len(r) + 1 > self.kv_len - self._prefix:
                continue           # too long to replay: not resumable
            key = (r.priority, -r.deadline_abs(), b)
            if best is None or key < best[0]:
                best = (key, b)
        return None if best is None else best[1]

    def _preempt_one(self, exclude=(), below: Optional[int] = None,
                     requeue_pos: int = 0) -> bool:
        """Preempt-and-requeue one victim stream. Polls first so every
        token the device already produced is committed, then releases
        the victim's slot and pages and requeues it (position 0 = queue
        front; 1 = right behind a displacing higher-priority head). On
        re-admission the generated prefix is replayed, so the resumed
        stream's output is identical to an unpreempted run (greedy).
        Returns False when no resumable victim exists."""
        self._poll()
        b = self._select_victim(exclude=exclude, below=below)
        if b is None:
            return False
        req = self.slots[b]
        self._release_active_slot(b)
        req.preemptions += 1
        self._c_preempt.inc()
        if self.recorder.enabled:
            self.recorder.on_preempt(req, b, time.perf_counter())
        pos = min(requeue_pos, len(self.queue))
        if pos <= 0:
            self.queue.appendleft(req)
        else:
            self.queue.insert(pos, req)
        return True

    def _fire(self, site: str, **ctx):
        """Ask the fault registry whether ``site`` should fail here
        (None when nothing is scheduled). Fired faults count into the
        ``faults_injected`` counter and the recorder's fault lane."""
        spec = self.faults.fire(site, **ctx)
        if spec is not None:
            self._c_faults.inc()
            if self.recorder.enabled:
                self.recorder.on_fault(site, self._steps,
                                       time.perf_counter())
        return spec

    def _set_poison(self, b: int) -> None:
        """Arm the ``nan_logits`` fault: poison row ``b``'s sampler
        logits for the next dispatched step. Input-only — the step
        programs never recompile."""
        self.poison = self._poison_zero.at[b % self.max_batch].set(
            float("nan"))

    def _clear_poison(self) -> None:
        self.poison = self._poison_zero

    # ------------------------------------------------------------ #
    # decode
    # ------------------------------------------------------------ #
    def step(self) -> None:
        """One engine step (plain, mixed, or speculative — plus, in spec
        mode, the admission chunk program). Pure device work: tokens,
        finish flags, and counters all stay on device; nothing is
        transferred."""
        t0 = time.perf_counter()
        n0 = self._steps
        poisoned = False
        if self.faults.enabled:
            spec = self._fire("slow_step", step=self._steps)
            if spec is not None and spec.delay_s > 0:
                time.sleep(spec.delay_s)
            spec = self._fire("nan_logits", step=self._steps)
            if spec is not None:
                self._set_poison(spec.slot or 0)
                poisoned = True
        if self._admit is None and self.queue \
                and self.model.extend_into_cache is not None:
            # pipeline the next admission mid-burst: the head-of-queue
            # request starts its chunked admission without waiting for
            # the burst boundary
            b = self._free_slot()
            if b is not None and self._admit_fits(self.queue[0]):
                self._start_chunked(self.queue.popleft(), b)
        adm = self._admit
        if self.spec_gamma:
            if adm is not None:
                self._step_admit_chunk(adm)
                if self.active_slots:
                    self._step_spec()
            else:
                self._step_spec()
        elif adm is not None:
            self._step_mixed(adm)
        else:
            self._step_plain()
        if poisoned:
            self._clear_poison()
        made = self._steps - n0
        dt = (time.perf_counter() - t0) / max(made, 1)
        for _ in range(made):
            self.step_times.append(dt)

    def _provision_decode_rows(self, per_row: int) -> bool:
        """Provision ``per_row`` decode writes for every occupied slot
        (an upper bound — rows the device already finished write
        nothing; the poll's shrink reclaims the overshoot). A degraded
        ``_provision`` (poll/preempt inside its ladder) may have shrunk
        headroom provisioned earlier in the same pass, so one False
        aborts the round; callers loop until a round runs clean."""
        for b, r in enumerate(self.slots):
            if r is not None:
                if not self._provision(b, self._depth_ub[b], per_row):
                    return False
                self._depth_ub[b] += per_row
        return True

    def _step_plain(self) -> None:
        if self.paged:
            while not self._provision_decode_rows(1):
                pass
            self._push_block_tables()
        (self.tokens, self.cache, self.remaining, self.active,
         self.key) = self._step_fn(self.params, self.cache,
                                   self.tokens, self.remaining,
                                   self.active, self.eos, self.key,
                                   self.poison)
        self._trace.append(self.tokens[:, 0])
        self._record_step("plain")

    def _step_spec(self) -> None:
        if self.paged:
            # a spec step writes up to gamma+1 positions per active row
            # (verify window); rollback keeps the committed prefix and
            # the poll's shrink drops pages past it
            while not self._provision_decode_rows(self.spec_gamma + 1):
                pass
            self._push_block_tables()
        if self._ngram:
            (self.tokens, block, n_emit, self.cache, self.hist,
             self.hist_len, self.remaining, self.active,
             self.key) = self._step_fn(
                self.params, self.cache, self.tokens, self.hist,
                self.hist_len, self.remaining, self.active, self.eos,
                self.key, self.poison)
        else:
            (self.tokens, self.prev, block, n_emit, self.cache,
             self.draft_cache, self.remaining, self.active,
             self.key) = self._step_fn(
                self.params, self._draft_params, self.cache,
                self.draft_cache, self.tokens, self.prev, self.remaining,
                self.active, self.eos, self.key, self.poison)
        self._trace.append((block, n_emit))
        self._record_step("spec")

    def _chunk_args(self, adm: _Admission) -> Tuple[np.ndarray, int, bool]:
        C = self.prefill_chunk
        n = min(C, adm.length - adm.base)
        chunk = np.zeros((C,), np.int32)
        chunk[:n] = adm.tokens[adm.base:adm.base + n]
        return chunk, n, adm.base + n >= adm.length

    def _step_mixed(self, adm: _Admission) -> None:
        """Dispatch the fused decode + prefill-chunk program."""
        chunk, n, last = self._chunk_args(adm)
        req = adm.req
        if self.paged:
            while True:
                if not self._provision_decode_rows(1):
                    continue
                if self._provision(adm.slot, adm.base, n):
                    break
            self._depth_ub[adm.slot] = adm.base + n
            self._push_block_tables()
        (self.tokens, block, n_emit, self.cache, self.remaining,
         self.active, self.eos, self.key) = self._get_mixed()(
            self.params, self.cache, self.tokens, self.remaining,
            self.active, self.eos, self.key, jnp.asarray(chunk),
            jnp.int32(adm.slot), jnp.int32(n), jnp.asarray(bool(last)),
            jnp.int32(req.max_new_tokens - adm.n_done),
            jnp.int32(-1 if req.eos_id is None else int(req.eos_id)),
            self.poison)
        self._trace.append((block, n_emit))
        if self.recorder.enabled:
            self.recorder.on_chunk(req, adm.slot, adm.base, adm.base + n,
                                   bool(last))
        adm.base += n
        if last:
            self._complete_admission(adm)
        self._record_step("mixed")

    def _step_admit_chunk(self, adm: _Admission) -> None:
        """Dispatch the spec-mode admission chunk program (target +
        lagging draft for model drafts; target + history append for the
        n-gram drafter), then let the spec step decode the other
        slots."""
        chunk, n, last = self._chunk_args(adm)
        req = adm.req
        if self.paged:
            # target chunk only — the draft cache stays contiguous; the
            # spec step dispatched right after provisions decode rows
            # (including a slot this chunk just armed)
            while not self._provision(adm.slot, adm.base, n):
                pass
            self._depth_ub[adm.slot] = adm.base + n
            self._push_block_tables()
        if self._ngram:
            (self.tokens, block, n_emit, self.cache, self.hist,
             self.hist_len, self.remaining, self.active, self.eos,
             self.key) = self._get_admit_chunk()(
                self.params, self.cache, self.tokens, self.hist,
                self.hist_len, self.remaining, self.active, self.eos,
                self.key, jnp.asarray(chunk), jnp.int32(adm.slot),
                jnp.int32(n), jnp.asarray(bool(last)),
                jnp.int32(req.max_new_tokens - adm.n_done),
                jnp.int32(-1 if req.eos_id is None else int(req.eos_id)),
                self.poison)
        else:
            d_n = max(0, min(n, adm.length - 1 - adm.base))
            (self.tokens, self.prev, block, n_emit, self.cache,
             self.draft_cache, self.remaining, self.active, self.eos,
             self.key) = self._get_admit_chunk()(
                self.params, self._draft_params, self.cache,
                self.draft_cache, self.tokens, self.prev, self.remaining,
                self.active, self.eos, self.key, jnp.asarray(chunk),
                jnp.int32(adm.slot), jnp.int32(n), jnp.int32(d_n),
                jnp.asarray(bool(last)),
                jnp.int32(req.max_new_tokens - adm.n_done),
                jnp.int32(-1 if req.eos_id is None else int(req.eos_id)),
                jnp.int32(int(adm.tokens[-1])), self.poison)
        self._trace.append((block, n_emit))
        if self.recorder.enabled:
            self.recorder.on_chunk(req, adm.slot, adm.base, adm.base + n,
                                   bool(last))
        adm.base += n
        if last:
            self._complete_admission(adm)
        self._record_step("admit")

    def _complete_admission(self, adm: _Admission) -> None:
        """The chunk just dispatched covers the end of the prompt: the
        device sampled the first token and armed the slot in-program.
        Host-side: attach the request to the slot (its trace entries
        start at this step), queue the TTFT stamp for the next sync, and
        snapshot the prompt's prefix KV for reuse before any decode step
        can wrap the ring over it."""
        b = adm.slot
        self.slots[b] = adm.req
        self._slot_start[b] = self._steps
        self._await_first.append(adm.req)
        self._c_admissions.inc()
        self._admit = None
        # resumed admissions skip publication: their prompt prefix was
        # published (if wanted) on first admission, and the effective
        # stream's tail is request-specific output, not a shared prefix.
        # Embeddings requests skip it too — the token stream alone does
        # not key the slot's content (see _start_chunked)
        if self.prefix_cache is not None and not adm.resumed \
                and adm.req.embeddings is None:
            P = self.prefix_cache.wants(adm.req.prompt)
            if P and P <= self.kv_len:
                if self.paged:
                    # publication is a refcount pin on the slot's own
                    # pages — no extract program, no KV movement
                    pages = self._paged.snapshot_prefix(b, P)
                    self.prefix_cache.insert(adm.req.prompt, P, pages)
                else:
                    kv = self._get_slot_fn("extract", P)(self.cache,
                                                         jnp.int32(b))
                    self.prefix_cache.insert(adm.req.prompt, P, kv)

    def _stamp_first_tokens(self, now: float) -> None:
        for req in self._await_first:
            if not req.first_token_s:
                req.first_token_s = now
                self._h_ttft.observe(now - req.submitted_s)
                if self.recorder.enabled:
                    self.recorder.on_first_token(req, now)
        self._await_first.clear()

    def _poll(self) -> None:
        """The periodic host sync: harvest each occupied slot's new token
        block (one bounded transfer per entry, sliced on device) and
        prune the trace. Only the unconsumed suffix of the trace is ever
        touched, so poll cost is bounded by the tokens produced since the
        previous poll — it does not grow with trace (or sequence) length.
        Finish detection replays the device's own stop conditions on the
        harvested tokens, so host and device slot state agree by
        construction."""
        if not self._trace:
            self._sample_occupancy()
            return
        occupied = [(b, self._slot_start[b] - self._trace_base)
                    for b, r in enumerate(self.slots) if r is not None]
        starts = [s for _, s in occupied if s < len(self._trace)]
        if starts:
            lo = min(starts)
            suffix = self._trace[lo:]
            jax.block_until_ready(suffix[-1])
            # host-side conversion, entry by entry: each is a bounded
            # (B,)/(B, W) transfer. A device-side jnp.stack here would
            # trigger one XLA compile per distinct suffix length — a
            # recurring ~100ms latency spike that dwarfed the transfers
            # it saved. Entries are heterogeneous (plain (B,) vectors,
            # mixed/admission W=1 pairs, speculative W=gamma+1 pairs),
            # so they are normalised to (block, count) per entry.
            host = []
            for t in suffix:
                if isinstance(t, tuple):
                    host.append((np.asarray(t[0]), np.asarray(t[1])))
                else:
                    host.append((np.asarray(t)[:, None], None))
            for b, start in occupied:
                s = start - lo
                if s >= len(host):
                    continue                           # armed post-trace
                col: List[int] = []
                gaps: List[Optional[float]] = []
                for off in range(s, len(host)):
                    blk, cnt = host[off]
                    g = self._trace_base + lo + off    # global step index
                    w = g - self._step_wall_base
                    gap = None
                    if 0 < w < len(self._step_wall):
                        gap = self._step_wall[w] - self._step_wall[w - 1]
                    if cnt is None:
                        col.append(int(blk[b, 0]))
                        gaps.append(gap)
                        continue
                    c = int(cnt[b])
                    if self.spec_gamma \
                            and blk.shape[1] == self.spec_gamma + 1:
                        self._c_spec_emitted.inc(c)
                        self._c_spec_steps.inc(int(c > 0))
                    for tok in blk[b, :c]:
                        col.append(int(tok))
                        gaps.append(gap / c if gap is not None else None)
                self._harvest(b, col, gaps)
        # every occupied slot has now consumed the whole trace
        keep_from = min((self._slot_start[b] for b, r
                         in enumerate(self.slots) if r is not None),
                        default=self._steps)
        drop = keep_from - self._trace_base
        if drop > 0:
            del self._trace[:drop]
            self._trace_base = keep_from
        # prune wall stamps consumed by every slot (keep one entry before
        # the oldest live step: its gap needs the predecessor's stamp)
        wdrop = keep_from - 1 - self._step_wall_base
        if wdrop > 0:
            del self._step_wall[:wdrop]
            self._step_wall_base = keep_from - 1
        if self.paged:
            # the harvested trace reveals each live slot's true committed
            # depth (prompt + generated - 1 pending): release the pages
            # the provisioning upper bound ran ahead by
            for b, r in enumerate(self.slots):
                if r is not None:
                    nt = len(self.responses[r.uid].tokens)
                    if nt:
                        depth = len(r.prompt) + nt - 1
                        self._paged.shrink(b, depth)
                        self._depth_ub[b] = depth
            if __debug__:
                entries = None
                if self.prefix_cache is not None:
                    entries = [e["kv"] for e
                               in self.prefix_cache._entries.values()]
                self._paged.check_invariants(entries)
        self._sample_occupancy()

    def _sample_occupancy(self) -> None:
        """Refresh the poll-time gauges (live occupancy, pool pressure,
        KV bytes per live token) and feed the recorder's counter lanes.
        Host arithmetic only — the page allocator and slot table are
        host-authoritative, nothing is read back from device."""
        m = self.metrics
        active = self.active_slots
        m.gauge("active_slots").set(active)
        m.gauge("queue_depth").set(len(self.queue))
        pool: Dict[str, float] = {}
        if self.paged:
            ps = self._paged.stats()
            pool["kv_pages_live"] = ps["kv_pages_live"]
            pool["kv_pages_free"] = ps["kv_pages_free"]
            m.gauge("kv_pages_free").set(ps["kv_pages_free"])
            live_tok = ps["kv_pages_live"] * self.page_size
        else:
            live_tok = sum(
                len(r.prompt) + len(self.responses[r.uid].tokens)
                for r in self.slots if r is not None)
        if live_tok:
            if self._kv_nbytes is None:
                self._kv_nbytes = sum(
                    x.size * x.dtype.itemsize
                    for x in jax.tree.leaves(self.cache))
            m.gauge("kv_bytes_per_live_token").set(
                self._kv_nbytes / live_tok)
        if self.recorder.enabled:
            self.recorder.on_poll(time.perf_counter(), active, pool)

    def _harvest(self, b: int, col: List[int],
                 gaps: Optional[List[Optional[float]]] = None) -> None:
        """Append slot ``b``'s sampled tokens host-side. The device kept
        decoding after the slot finished (it only learns at the next poll),
        so cut the column at the true stop condition — the same condition
        the fused step applied on device. ``gaps`` carries each token's
        inter-step wall gap for the ITL percentile stats (the first token
        of a request is TTFT, not ITL, and is skipped)."""
        req = self.slots[b]
        resp = self.responses[req.uid]
        done = False
        if gaps is None:
            gaps = [None] * len(col)
        n0 = len(resp.tokens)
        for tok, gap in zip(col, gaps):
            tok = int(tok)
            if tok == ERR_TOKEN:
                # the on-device NaN/inf guard tripped for this row: the
                # sentinel is not a real token — finish with "error";
                # everything harvested before it stands
                resp.finish_reason = "error"
                self._c_errors.inc()
                done = True
                break
            if resp.tokens and gap is not None:
                self._h_itl.observe(gap)
            resp.tokens.append(tok)
            if (req.eos_id is not None and tok == req.eos_id):
                resp.finish_reason = "eos"
                done = True
                break
            if len(resp.tokens) >= req.max_new_tokens:
                resp.finish_reason = "length"
                done = True
                break
        appended = len(resp.tokens) - n0
        if appended:
            self._c_tokens.inc(appended)
            if self.recorder.enabled:
                self.recorder.on_emit(req, b, appended,
                                      time.perf_counter())
        if done:
            resp.finished = True
            req.finished_s = time.perf_counter()
            if self.recorder.enabled:
                self.recorder.on_finish(req, resp.finish_reason,
                                        req.finished_s)
            self.slots[b] = None
            if self.paged:
                # the stream's pages return to the free list; pages a
                # prefix entry pinned stay live through the entry's own
                # references until it is evicted
                self._paged.release_slot(b)
                self._depth_ub[b] = 0
        else:
            self._slot_start[b] = self._steps              # all consumed

    @property
    def active_slots(self) -> int:
        return sum(s is not None for s in self.slots)

    @property
    def has_work(self) -> bool:
        return bool(self.queue or self.active_slots
                    or self._admit is not None)

    def tick(self, steps: Optional[int] = None) -> int:
        """Advance the engine by one admission pass, one burst of up to
        ``steps`` fused steps (default ``sync_every``), and one poll.
        Returns the number of steps run — the open-loop driving primitive
        for callers that interleave submissions with service
        (``benchmarks/bench_load.py``); ``run`` is a drain loop on top."""
        k = self.sync_every if steps is None else max(1, steps)
        if self._deadline_armed:
            self._enforce_deadlines(include_active=False)
        self._fill_free_slots()
        if not (self.active_slots or self._admit is not None):
            self._poll()
            if self._deadline_armed:
                self._enforce_deadlines()
            return 0
        t0 = t_begin = time.perf_counter()
        # steps run outside tick (raw .step() calls) have no wall stamp;
        # backfill so gap indexing stays aligned with the step counter
        while len(self._step_wall) + self._step_wall_base < self._steps:
            self._step_wall.append(t0)
        n0 = len(self.step_times)
        ran0 = self._steps
        while self._steps - ran0 < k:
            first_ever = self._steps == 0
            before = len(self.step_times)
            self.step()
            if first_ever:
                # isolate the fused-step compile in its own step_times
                # entries (latency_stats drops the first) so burst
                # averaging below never smears it over steady state
                jax.block_until_ready(self.tokens)
                now = time.perf_counter()
                made = len(self.step_times) - before
                for i in range(before, len(self.step_times)):
                    self.step_times[i] = (now - t0) / made
                self._step_wall.extend([now] * made)
                t0 = now
                n0 = len(self.step_times)
        jax.block_until_ready(self.tokens)
        t1 = time.perf_counter()
        m = len(self.step_times) - n0
        if m > 0:
            # burst-average: per-step dispatch time plus its share of sync
            dt = (t1 - t0) / m
            for i in range(n0, len(self.step_times)):
                self.step_times[i] = dt
            for i in range(m):
                self._step_wall.append(t0 + dt * (i + 1))
        if self.recorder.enabled and self._steps > ran0:
            # finalised per-step spans for the trace's steps lane: each
            # step ends at its wall stamp and starts at its
            # predecessor's (the burst entry for the first)
            spans = []
            for g in range(ran0, self._steps):
                w = g - self._step_wall_base
                start = self._step_wall[w - 1] if w > 0 else t_begin
                spans.append((start, self._step_wall[w],
                              self.step_kinds[g - self._kinds_base]))
            self.recorder.on_steps(spans)
        self._stamp_first_tokens(t1)
        self._poll()
        if self._deadline_armed:
            self._enforce_deadlines()
        self._maybe_profile()
        return self._steps - ran0

    def run(self, max_steps: int = 100_000,
            sync_every: Optional[int] = None) -> Dict[int, Response]:
        k = self.sync_every if sync_every is None else max(1, sync_every)
        steps = 0
        while self.has_work and steps < max_steps:
            made = self.tick(min(k, max_steps - steps))
            steps += made
            if made == 0 and not self.has_work:
                break
        self._poll()   # partial tokens for interrupted slots
        self._stop_profile()
        return self.responses

    def reset_stats(self) -> None:
        """Forget timing and finished-request history (compiled programs,
        cache state and prefix-cache *entries* are kept) — for benchmarks
        that warm an engine up and then measure a fresh stream. Also
        *arms* the recompile watchdog: the warm-then-measure boundary is
        where steady state begins, so any later XLA compile raises
        ``telemetry.RecompileWarning``."""
        self.metrics.reset()
        self._kinds_base = self._steps
        self._drop_compile_step = False
        for uid in [u for u, r in self.responses.items() if r.finished]:
            del self.responses[uid]
            del self.requests[uid]
        if self.prefix_cache is not None:
            pc = self.prefix_cache
            pc.hits = pc.misses = pc.hit_tokens = pc.evictions = 0
        if self.paged:
            pk = self._paged
            pk.alias_pages = pk.cow_splits = pk.pages_released = 0
        self._watchdog.arm()

    def mark_steady(self) -> None:
        """Arm the recompile watchdog without touching stats: every
        later XLA compile is treated as a steady-state regression
        (structured ``RecompileWarning`` + ``steady_compiles`` counter).
        ``reset_stats()`` arms it implicitly."""
        self._watchdog.arm()

    # ------------------------------------------------------------ #
    # trace / profiler export
    # ------------------------------------------------------------ #
    def export_trace(self, path: Optional[str] = None) -> Dict[str, Any]:
        """Export the recorded request-lifecycle trace as a Chrome
        trace-event object (written as JSON to ``path`` when given) —
        see ``serving/tracing.py`` for the lane layout. Requires a
        tracing recorder (``Engine(..., recorder=True)``)."""
        exp = getattr(self.recorder, "export_chrome_trace", None)
        if exp is None:
            raise RuntimeError(
                "export_trace needs a tracing recorder: build the "
                "engine with recorder=True (or a tracing.Tracer)")
        return exp(path)

    def _maybe_profile(self) -> None:
        """Drive the optional ``jax.profiler`` device-trace window
        (``trace_dir=``): start after the first step (so the first
        compile doesn't dominate the capture), stop after
        ``profile_steps`` steps. Failures (profiler unavailable,
        directory not writable) disable the capture, never the run."""
        if not self._trace_dir or self._prof_done:
            return
        if not self._prof_on:
            if self._steps >= 1:
                try:
                    jax.profiler.start_trace(self._trace_dir)
                    self._prof_on = True
                    self._prof_base = self._steps
                except Exception:
                    self._prof_done = True
        elif self._steps - self._prof_base >= self._profile_steps:
            self._stop_profile()

    def _stop_profile(self) -> None:
        if self._prof_on:
            try:
                jax.block_until_ready(self.tokens)
                jax.profiler.stop_trace()
            except Exception:
                pass
            self._prof_on = False
            self._prof_done = True

    # ------------------------------------------------------------ #
    @staticmethod
    def _pct_stats(stats: Dict[str, float], prefix: str, samples,
                   pcts: Tuple[int, ...]) -> None:
        """Delegates to :func:`telemetry.pct_stats` — the one percentile
        implementation (same keys, same empty-sample omission contract);
        kept as a method for callers that reach it through the engine."""
        telemetry.pct_stats(stats, prefix, samples, pcts)

    def latency_stats(self) -> Dict[str, float]:
        """Latency summary. The ``decode_ms_*`` / ``ttft_ms_*`` /
        ``itl_ms_*`` keys are present only when the corresponding stream
        has at least one sample — a fresh (or reset) engine reports the
        counters alone."""
        drop = 1 if self._drop_compile_step else 0
        finished = [r for r in self.responses.values() if r.finished]
        stats: Dict[str, float] = {
            "n_finished": len(finished),
            "tokens_generated": sum(r.n_generated for r in finished),
            "fallback_admissions": self._c_fallback.value,
            "decode_steps": self._steps,
            "prefill_chunk": self.prefill_chunk,
            "chunked_admissions": self._c_admissions.value,
            "preemptions": self._c_preempt.value,
            "timeouts": self._c_timeout.value,
            "cancellations": self._c_cancel.value,
            "slot_errors": self._c_errors.value,
            "faults_injected": self._c_faults.value,
        }
        telemetry.pct_stats(stats, "decode_ms", self.step_times[drop:],
                            (50, 99))
        telemetry.pct_stats(stats, "ttft_ms", self._h_ttft.values,
                            (50, 95, 99))
        telemetry.pct_stats(stats, "itl_ms", self._h_itl.values,
                            (50, 95, 99))
        if self.prefix_cache is not None:
            stats.update(self.prefix_cache.stats())
        if self.paged:
            stats.update(self._paged.stats())
        if self.spec_gamma:
            # every harvested (step, active slot) pair emitted 1 + n_acc
            # tokens; acceptance rate = mean(n_acc) / gamma
            emitted = self._c_spec_emitted.value
            steps = self._c_spec_steps.value
            n = max(steps, 1)
            stats["spec_gamma"] = self.spec_gamma
            stats["spec_tokens_per_step"] = emitted / n
            stats["spec_acceptance_rate"] = \
                (emitted - steps) / (self.spec_gamma * n)
        return stats
