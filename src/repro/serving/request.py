"""Request/response dataclasses for the serving engine."""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import List, Optional

import numpy as np


@dataclass(eq=False)
class Request:
    # eq=False: identity equality. The generated __eq__ would compare the
    # numpy prompt fields and raise "truth value is ambiguous" the moment
    # two distinct Request objects meet in a container operation
    # (deque.remove/`in` during cancel or preemptive requeue).
    uid: int
    prompt: np.ndarray              # (L,) int32 token ids
    max_new_tokens: int = 32
    eos_id: Optional[int] = None
    embeddings: Optional[np.ndarray] = None  # vlm/audio frontend output

    # --- lifecycle control (serving resilience) ------------------- #
    deadline_s: Optional[float] = None  # wall-clock budget from submit;
    # enforced at poll boundaries: an expired request finishes with
    # finish_reason "timeout" (keeping any tokens already produced)
    priority: int = 0               # higher preempts lower when slots or
    # KV pages run short (victim = lowest priority, then latest deadline)

    submitted_s: float = 0.0
    started_s: float = 0.0          # prefill dispatched
    first_token_s: float = 0.0      # first token available on host
    finished_s: float = 0.0
    preemptions: int = 0            # times evicted-and-requeued; resumed
    # streams replay their generated prefix, so output is unaffected

    def deadline_abs(self) -> float:
        """Absolute ``perf_counter`` deadline (+inf when none)."""
        if self.deadline_s is None:
            return float("inf")
        return self.submitted_s + self.deadline_s


#: Finish reasons a Response can carry. "eos"/"length" are the normal
#: completions; the rest are resilience outcomes (docs/robustness.md).
FINISH_REASONS = ("eos", "length", "cancelled", "timeout", "error")


@dataclass
class Response:
    uid: int
    tokens: List[int] = field(default_factory=list)
    finished: bool = False
    prompt_len: int = 0
    finish_reason: str = ""         # one of FINISH_REASONS, or ""
    # "" while still running. "cancelled"/"timeout"/"error" responses
    # keep the tokens produced before the event (partial output).

    @property
    def n_generated(self) -> int:
        return len(self.tokens)

    @property
    def ok(self) -> bool:
        """Finished normally (eos or length budget)."""
        return self.finished and self.finish_reason in ("eos", "length")
