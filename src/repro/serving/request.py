"""Request/response dataclasses for the serving engine."""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import List, Optional

import numpy as np


@dataclass
class Request:
    uid: int
    prompt: np.ndarray              # (L,) int32 token ids
    max_new_tokens: int = 32
    eos_id: Optional[int] = None
    embeddings: Optional[np.ndarray] = None  # vlm/audio frontend output

    submitted_s: float = 0.0
    started_s: float = 0.0          # prefill dispatched
    first_token_s: float = 0.0      # first token available on host
    finished_s: float = 0.0


@dataclass
class Response:
    uid: int
    tokens: List[int] = field(default_factory=list)
    finished: bool = False
    prompt_len: int = 0
    finish_reason: str = ""         # "eos" | "length" | "" (still running)

    @property
    def n_generated(self) -> int:
        return len(self.tokens)
