"""Serving telemetry: the unified metrics registry, shared percentile
helpers, the recorder interface, and the recompile watchdog.

The engine used to keep ad-hoc host-side lists (``step_times``,
``step_kinds``, ``_spec_emitted``, per-request ITL dicts) and rebuild
``latency_stats()`` from them by hand; benchmarks grew their own copies
of the percentile math. This module centralises all of it:

* :class:`MetricsRegistry` — named counters, gauges, bounded-reservoir
  histograms, and aligned series. The engine owns one registry and
  every stat it reports (``latency_stats()``, bench snapshots, the
  serve driver's periodic summary) is derived from it. Components that
  already keep their own counters (``PrefixCache``, ``PagedKVState``)
  are attached as *collectors*: ``snapshot()`` pulls their live
  ``stats()`` dicts without double-counting.
* :func:`pct_stats` / :func:`percentile` — the one percentile
  implementation (same keys, same empty-sample omission contract as
  PR 5: a stream with no samples contributes *no* keys, never a
  fabricated 0.0).
* :class:`Recorder` — the request-lifecycle event interface. The base
  class is the no-op default: every hook is ``pass``, ``enabled`` is
  False, and the engine's disabled path does zero per-step device work
  and no per-event allocation beyond the call itself.
  ``serving/tracing.Tracer`` is the recording implementation.
* :class:`CompileWatchdog` + :class:`RecompileWarning` — every XLA
  compile observed through ``Engine._jit`` is recorded (program name,
  elapsed wall); once the watchdog is *armed* (``Engine.reset_stats``
  after warmup, or ``Engine.mark_steady()``), any further compile is a
  steady-state recompile: a structured warning at runtime and a
  ``steady_compiles`` counter benchmarks fail CI on. This turns the
  test-only ``program_cache_sizes()`` guard into an always-on signal.

Everything here is host-side and cheap: no jax imports, no device work.
"""
from __future__ import annotations

import warnings
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

__all__ = [
    "percentile", "pct_stats",
    "Counter", "Gauge", "Histogram", "Series", "MetricsRegistry",
    "Recorder", "RecompileWarning", "CompileWatchdog",
]


# --------------------------------------------------------------------- #
# percentile math (the single implementation)
# --------------------------------------------------------------------- #
def percentile(samples: Sequence[float], p: float) -> float:
    """Linear-interpolation percentile over raw samples (the numpy
    default — the same basis every stats key in this repo has always
    used). Raises on an empty sample set: callers decide the empty
    contract (``pct_stats`` omits keys)."""
    return float(np.percentile(np.asarray(samples, np.float64), p))


def pct_stats(stats: Dict[str, float], prefix: str, samples,
              pcts: Tuple[int, ...]) -> None:
    """Add ``{prefix}_mean`` / ``{prefix}_p{p}`` keys (in ms, samples in
    seconds) for one latency stream — only when it actually produced
    samples. An empty stream contributes *no* keys (rather than
    fabricated 0.0 latencies that would poison benchmark artifacts):
    consumers treat a missing key as "no data"."""
    arr = np.asarray(samples, np.float64)
    if arr.size == 0:
        return
    stats[f"{prefix}_mean"] = float(arr.mean() * 1e3)
    for p in pcts:
        stats[f"{prefix}_p{p}"] = float(np.percentile(arr, p) * 1e3)


# --------------------------------------------------------------------- #
# metric primitives
# --------------------------------------------------------------------- #
class Counter:
    """Monotonic counter. ``persist=True`` survives ``registry.reset()``
    (e.g. total compiles — warmup history must not be erasable by a
    benchmark's stats reset)."""
    __slots__ = ("value", "persist")

    def __init__(self, persist: bool = False):
        self.value = 0
        self.persist = persist

    def inc(self, n: int = 1) -> None:
        self.value += n

    def reset(self) -> None:
        if not self.persist:
            self.value = 0


class Gauge:
    """Last-sampled value (active slots, free pages, ...)."""
    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)

    def reset(self) -> None:
        self.value = 0.0


class Histogram:
    """Bounded-reservoir sample store (Vitter's algorithm R past the
    cap, deterministic seed): percentiles are exact until ``cap``
    samples, an unbiased reservoir estimate beyond — memory stays O(cap)
    over unbounded serving runs."""
    __slots__ = ("cap", "samples", "count", "_rng", "_seed")

    def __init__(self, cap: int = 8192, seed: int = 0):
        self.cap = int(cap)
        self.samples: List[float] = []
        self.count = 0
        self._seed = seed
        self._rng = np.random.default_rng(seed)

    def observe(self, v: float) -> None:
        self.count += 1
        if len(self.samples) < self.cap:
            self.samples.append(float(v))
            return
        j = int(self._rng.integers(0, self.count))
        if j < self.cap:
            self.samples[j] = float(v)

    @property
    def values(self) -> List[float]:
        return self.samples

    def summary(self, pcts: Tuple[int, ...] = (50, 95, 99)
                ) -> Dict[str, float]:
        out: Dict[str, float] = {"count": self.count}
        if self.samples:
            arr = np.asarray(self.samples, np.float64)
            out["mean"] = float(arr.mean())
            out["max"] = float(arr.max())
            for p in pcts:
                out[f"p{p}"] = float(np.percentile(arr, p))
        return out

    def reset(self) -> None:
        self.samples = []
        self.count = 0
        self._rng = np.random.default_rng(self._seed)


class Series:
    """Aligned append-only store — the registry home of per-step records
    whose *order* matters (step wall times aligned with step kinds, the
    compile log). ``values`` is the live list: the engine mutates it in
    place (burst averaging rewrites entries), so it is the same object
    across reads."""
    __slots__ = ("values",)

    def __init__(self):
        self.values: List[Any] = []

    def append(self, v: Any) -> None:
        self.values.append(v)

    def __len__(self) -> int:
        return len(self.values)

    def reset(self) -> None:
        self.values.clear()


class MetricsRegistry:
    """Named metric store with get-or-create accessors. ``snapshot()``
    renders everything JSON-serializable (the ``BENCH_*.json``
    ``telemetry`` section and the serve driver's JSONL records);
    ``reset()`` clears non-persistent state (the
    ``Engine.reset_stats()`` contract: forget timing history, keep
    compiled-program facts)."""

    def __init__(self):
        self.counters: Dict[str, Counter] = {}
        self.gauges: Dict[str, Gauge] = {}
        self.histograms: Dict[str, Histogram] = {}
        self.series: Dict[str, Series] = {}
        self._collectors: List[Callable[[], Dict[str, Any]]] = []

    # -- get-or-create ------------------------------------------------ #
    def counter(self, name: str, persist: bool = False) -> Counter:
        c = self.counters.get(name)
        if c is None:
            c = self.counters[name] = Counter(persist=persist)
        return c

    def gauge(self, name: str) -> Gauge:
        g = self.gauges.get(name)
        if g is None:
            g = self.gauges[name] = Gauge()
        return g

    def histogram(self, name: str, cap: int = 8192) -> Histogram:
        h = self.histograms.get(name)
        if h is None:
            h = self.histograms[name] = Histogram(cap=cap)
        return h

    def get_series(self, name: str) -> Series:
        s = self.series.get(name)
        if s is None:
            s = self.series[name] = Series()
        return s

    def add_collector(self, fn: Callable[[], Dict[str, Any]]) -> None:
        """Attach a live stats source (e.g. ``PrefixCache.stats``):
        called at every ``snapshot()`` and merged under ``collected``.
        Collectors own their counters — the registry never copies or
        resets them."""
        self._collectors.append(fn)

    # -- output -------------------------------------------------------- #
    def snapshot(self) -> Dict[str, Any]:
        snap: Dict[str, Any] = {
            "counters": {k: c.value for k, c in sorted(
                self.counters.items())},
            "gauges": {k: g.value for k, g in sorted(self.gauges.items())},
            "histograms": {k: h.summary() for k, h in sorted(
                self.histograms.items())},
            "series": {},
        }
        for k, s in sorted(self.series.items()):
            vals = s.values
            if vals and all(isinstance(v, (int, float)) for v in vals):
                arr = np.asarray(vals, np.float64)
                snap["series"][k] = {
                    "count": len(vals), "mean": float(arr.mean()),
                    "p50": float(np.percentile(arr, 50)),
                    "p99": float(np.percentile(arr, 99)),
                    "max": float(arr.max())}
            else:
                snap["series"][k] = {"count": len(vals),
                                     "values": list(vals[-64:])}
        collected: Dict[str, Any] = {}
        for fn in self._collectors:
            collected.update(fn())
        snap["collected"] = collected
        return snap

    def reset(self) -> None:
        for group in (self.counters, self.gauges, self.histograms,
                      self.series):
            for m in group.values():
                m.reset()


# --------------------------------------------------------------------- #
# recorder interface (no-op default)
# --------------------------------------------------------------------- #
class Recorder:
    """Request-lifecycle event sink. This base class *is* the disabled
    path: every hook is a no-op and ``enabled`` is False, so the engine
    skips the (tiny) host work of assembling event payloads that need
    it. ``serving/tracing.Tracer`` subclasses it to build Chrome-trace
    timelines. All timestamps are ``time.perf_counter()`` seconds."""
    enabled = False

    def on_submit(self, req) -> None:
        pass

    def on_admission(self, req, slot: int, base: int, kind: str) -> None:
        """Request leaves the queue: ``kind`` is "chunked" (the fused
        mixed path every admission takes; ``base`` > 0 on a
        prefix-cache hit) or "fallback" (defensive-only: a stack with
        no ``extend_into_cache``, counted and rejected)."""

    def on_chunk(self, req, slot: int, lo: int, hi: int,
                 last: bool) -> None:
        """One admission chunk ``prompt[lo:hi)`` dispatched."""

    def on_first_token(self, req, ts: float) -> None:
        pass

    def on_emit(self, req, slot: int, n: int, ts: float) -> None:
        """``n`` tokens of ``req`` harvested at a poll."""

    def on_finish(self, req, reason: str, ts: float) -> None:
        pass

    def on_preempt(self, req, slot: int, ts: float) -> None:
        """``req`` evicted from ``slot`` and requeued (it will resume by
        replaying its generated prefix — docs/robustness.md)."""

    def on_fault(self, site: str, step: int, ts: float) -> None:
        """A scheduled fault fired at ``site`` (serving/faults.py)."""

    def on_steps(self, spans: List[Tuple[float, float, str]]) -> None:
        """Finalised step timings for one burst: (start, end, kind)."""

    def on_poll(self, ts: float, active: int,
                stats: Dict[str, float]) -> None:
        """Periodic host sync: live occupancy / pool sample."""

    def on_compile(self, name: str, elapsed_s: float, steady: bool,
                   ts: float) -> None:
        pass


# --------------------------------------------------------------------- #
# recompile watchdog
# --------------------------------------------------------------------- #
class RecompileWarning(UserWarning):
    """A jitted engine program compiled a new specialization after the
    engine was marked steady — in serving, a silent latency cliff
    (~100ms+ per occurrence) that ``program_cache_sizes()`` could only
    catch in tests. Carries the program name and observed elapsed wall
    (trace + compile, measured around the dispatch call)."""

    def __init__(self, program: str, elapsed_s: float, step: int):
        self.program = program
        self.elapsed_s = elapsed_s
        self.step = step
        super().__init__(
            f"steady-state XLA recompile of {program!r} at engine step "
            f"{step} ({elapsed_s * 1e3:.1f} ms) — an input's "
            f"shape/layout/sharding is churning; see "
            f"docs/observability.md#recompile-watchdog")


class CompileWatchdog:
    """Records every XLA compile observed by ``Engine._jit`` wrappers
    into the registry (``compiles_total`` / ``steady_compiles``
    persistent counters plus a ``compiles`` series of per-event dicts)
    and raises :class:`RecompileWarning` for compiles after ``arm()``.

    Warmup compiles are expected (first call of every program); a
    *steady-state* compile is always a regression. Arming is explicit:
    ``Engine.reset_stats()`` (the warm-then-measure benchmark contract)
    or ``Engine.mark_steady()``."""

    def __init__(self, registry: MetricsRegistry,
                 recorder: Optional[Recorder] = None):
        self.registry = registry
        self.recorder = recorder or Recorder()
        self.steady = False
        self._total = registry.counter("compiles_total", persist=True)
        self._steady_c = registry.counter("steady_compiles", persist=True)
        self._log = registry.get_series("compiles")

    def arm(self) -> None:
        self.steady = True

    def record(self, name: str, elapsed_s: float, step: int,
               ts: float) -> None:
        self._total.inc()
        self._log.append({"program": name,
                          "elapsed_ms": round(elapsed_s * 1e3, 3),
                          "step": step, "steady": self.steady})
        self.recorder.on_compile(name, elapsed_s, self.steady, ts)
        if self.steady:
            self._steady_c.inc()
            warnings.warn(RecompileWarning(name, elapsed_s, step),
                          stacklevel=3)
