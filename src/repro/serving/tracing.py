"""Request-lifecycle tracing: Chrome trace-event export for serving runs.

:class:`Tracer` is the recording :class:`~repro.serving.telemetry.Recorder`
implementation: the engine feeds it span events for every request
(enqueued → admission chunks → first token → per-poll emissions →
finished/evicted), finalised per-step timings, poll-time pool samples
and compile events, all host-side with monotonic
(``time.perf_counter``) timestamps. ``export_chrome_trace`` renders the
collected run as Chrome trace-event JSON — open it at ``ui.perfetto.dev``
(or ``chrome://tracing``) and the run reads as:

* one lane per batch **slot** (``slot 0..B-1``): a complete span per
  request occupying it, with instants for admission chunks, the first
  token, and each poll's token emissions;
* a **queue** lane: per-request wait between ``submit`` and admission;
* a **steps** lane: one slice per fused engine step, named by kind
  (``plain`` / ``mixed`` / ``admit`` / ``spec``);
* a **compiles** lane: every XLA compile with its elapsed wall
  (steady-state ones flagged — the recompile watchdog's signal);
* counter tracks for **active slots** and **page-pool occupancy**
  (live/free pages), sampled at every poll.

Timestamps are microseconds relative to tracer construction (the
engine's, when built with ``recorder=True``). The tracer is pure host
bookkeeping: it never touches device state, so a traced run's greedy
outputs and compiled-program counts are bit-identical to an untraced
one (asserted in ``tests/test_telemetry.py``).
"""
from __future__ import annotations

import json
import time
from typing import Any, Dict, List, Optional, Tuple

from repro.serving.telemetry import Recorder

__all__ = ["Tracer", "validate_chrome_trace", "complete_spans",
           "merge_chrome_traces"]

# fixed thread-lane ids (slot lanes are 1..max_batch)
QUEUE_TID = 0
STEP_TID = 900
COMPILE_TID = 901
FAULT_TID = 902
_PID = 1


class Tracer(Recorder):
    enabled = True

    def __init__(self):
        self.t0 = time.perf_counter()
        # uid -> lifecycle record (insertion order = submit order)
        self.requests: Dict[int, Dict[str, Any]] = {}
        self.steps: List[Tuple[float, float, str]] = []
        self.polls: List[Tuple[float, int, Dict[str, float]]] = []
        self.compiles: List[Tuple[float, str, float, bool]] = []
        self.faults: List[Tuple[float, str, int]] = []

    # -- Recorder hooks ------------------------------------------------ #
    def on_submit(self, req) -> None:
        self.requests[req.uid] = {
            "uid": req.uid, "prompt_len": len(req.prompt),
            "submitted": time.perf_counter(), "admitted": None,
            "slot": None, "kind": "", "base": 0, "chunks": [],
            "first_token": None, "emits": [], "finished": None,
            "reason": "", "generated": 0, "preempts": []}

    def on_admission(self, req, slot: int, base: int, kind: str) -> None:
        r = self.requests.get(req.uid)
        if r is None:
            return
        r["admitted"] = time.perf_counter()
        r["slot"] = slot
        r["kind"] = kind
        r["base"] = base

    def on_chunk(self, req, slot: int, lo: int, hi: int,
                 last: bool) -> None:
        r = self.requests.get(req.uid)
        if r is not None:
            r["chunks"].append((time.perf_counter(), lo, hi, last))

    def on_first_token(self, req, ts: float) -> None:
        r = self.requests.get(req.uid)
        if r is not None and r["first_token"] is None:
            r["first_token"] = ts

    def on_emit(self, req, slot: int, n: int, ts: float) -> None:
        r = self.requests.get(req.uid)
        if r is not None and n:
            r["emits"].append((ts, n))
            r["generated"] += n

    def on_finish(self, req, reason: str, ts: float) -> None:
        r = self.requests.get(req.uid)
        if r is not None:
            r["finished"] = ts
            r["reason"] = reason

    def on_preempt(self, req, slot: int, ts: float) -> None:
        r = self.requests.get(req.uid)
        if r is not None:
            r["preempts"].append((ts, slot))

    def on_fault(self, site: str, step: int, ts: float) -> None:
        self.faults.append((ts, site, step))

    def on_steps(self, spans: List[Tuple[float, float, str]]) -> None:
        self.steps.extend(spans)

    def on_poll(self, ts: float, active: int,
                stats: Dict[str, float]) -> None:
        self.polls.append((ts, active, dict(stats)))

    def on_compile(self, name: str, elapsed_s: float, steady: bool,
                   ts: float) -> None:
        self.compiles.append((ts, name, elapsed_s, steady))

    # -- export -------------------------------------------------------- #
    def _us(self, t: float) -> float:
        return round((t - self.t0) * 1e6, 1)

    def export_chrome_trace(self, path: Optional[str] = None
                            ) -> Dict[str, Any]:
        """Render the collected run as a Chrome trace-event object
        (``{"traceEvents": [...]}``); write JSON to ``path`` when given.
        Requests still running (or never admitted) at export time get an
        open-ended span cut at "now" with reason ``evicted``."""
        now = time.perf_counter()
        ev: List[Dict[str, Any]] = []

        def meta(tid: int, name: str) -> None:
            ev.append({"name": "thread_name", "ph": "M", "ts": 0,
                       "pid": _PID, "tid": tid,
                       "args": {"name": name}})

        ev.append({"name": "process_name", "ph": "M", "ts": 0,
                   "pid": _PID, "tid": 0,
                   "args": {"name": "serving engine"}})
        meta(QUEUE_TID, "queue")
        slots = sorted({r["slot"] for r in self.requests.values()
                       if r["slot"] is not None})
        for b in slots:
            meta(1 + b, f"slot {b}")
        meta(STEP_TID, "steps")
        meta(COMPILE_TID, "compiles")
        if self.faults:
            meta(FAULT_TID, "faults")

        for r in self.requests.values():
            uid = r["uid"]
            adm = r["admitted"]
            end = r["finished"] if r["finished"] is not None else now
            # queue lane: submit -> admission (or still waiting)
            ev.append({"name": f"queue u{uid}", "ph": "X",
                       "ts": self._us(r["submitted"]),
                       "dur": max(0.0, round(
                           ((adm if adm is not None else end)
                            - r["submitted"]) * 1e6, 1)),
                       "pid": _PID, "tid": QUEUE_TID,
                       "args": {"uid": uid,
                                "prompt_len": r["prompt_len"]}})
            if adm is None:
                continue
            tid = 1 + r["slot"]
            # the request's complete span on its slot lane
            ev.append({"name": f"req {uid}", "ph": "X",
                       "ts": self._us(adm),
                       "dur": max(0.0, round((end - adm) * 1e6, 1)),
                       "pid": _PID, "tid": tid,
                       "args": {"uid": uid,
                                "prompt_len": r["prompt_len"],
                                "admission": r["kind"],
                                "prefix_reused": r["base"],
                                "generated": r["generated"],
                                "preemptions": len(r["preempts"]),
                                "finish": r["reason"] or "evicted"}})
            for (t, pslot) in r["preempts"]:
                ev.append({"name": "preempt", "ph": "i",
                           "ts": self._us(t), "pid": _PID,
                           "tid": 1 + pslot, "s": "t",
                           "args": {"uid": uid}})
            for (t, lo, hi, last) in r["chunks"]:
                ev.append({"name": f"chunk {lo}:{hi}", "ph": "i",
                           "ts": self._us(t), "pid": _PID, "tid": tid,
                           "s": "t",
                           "args": {"uid": uid, "last": bool(last)}})
            if r["first_token"] is not None:
                ev.append({"name": "first_token", "ph": "i",
                           "ts": self._us(r["first_token"]),
                           "pid": _PID, "tid": tid, "s": "t",
                           "args": {"uid": uid}})
            for (t, n) in r["emits"]:
                ev.append({"name": f"emit {n}", "ph": "i",
                           "ts": self._us(t), "pid": _PID, "tid": tid,
                           "s": "t", "args": {"uid": uid, "n": n}})
            if r["finished"] is not None:
                ev.append({"name": f"finish:{r['reason']}", "ph": "i",
                           "ts": self._us(r["finished"]), "pid": _PID,
                           "tid": tid, "s": "t", "args": {"uid": uid}})

        for (start, end, kind) in self.steps:
            ev.append({"name": kind, "ph": "X", "ts": self._us(start),
                       "dur": max(0.0, round((end - start) * 1e6, 1)),
                       "pid": _PID, "tid": STEP_TID})
        for (t, site, step) in self.faults:
            ev.append({"name": f"fault {site}", "ph": "i",
                       "ts": self._us(t), "pid": _PID, "tid": FAULT_TID,
                       "s": "t", "args": {"site": site, "step": step}})
        for (t, name, elapsed, steady) in self.compiles:
            ev.append({"name": f"compile {name}", "ph": "X",
                       "ts": self._us(max(t, self.t0)),
                       "dur": round(elapsed * 1e6, 1),
                       "pid": _PID, "tid": COMPILE_TID,
                       "args": {"steady": bool(steady)}})
        for (t, active, stats) in self.polls:
            ev.append({"name": "active_slots", "ph": "C",
                       "ts": self._us(t), "pid": _PID,
                       "args": {"active": active}})
            if "kv_pages_live" in stats:
                ev.append({"name": "page_pool", "ph": "C",
                           "ts": self._us(t), "pid": _PID,
                           "args": {"live": stats["kv_pages_live"],
                                    "free": stats["kv_pages_free"]}})

        trace = {"traceEvents": ev, "displayTimeUnit": "ms"}
        if path:
            with open(path, "w") as f:
                json.dump(trace, f)
        return trace


# --------------------------------------------------------------------- #
# multi-process merge (fleet serving)
# --------------------------------------------------------------------- #
def merge_chrome_traces(parts, extra=None, extra_label: str = "fleet",
                        extra_pid: int = 99,
                        path: Optional[str] = None) -> Dict[str, Any]:
    """Merge per-replica Chrome traces into one multi-process trace.

    ``parts`` is a list of ``(label, pid, trace, offset_us)`` tuples:
    every event in ``trace`` is rewritten onto process ``pid`` (named
    ``label``) and shifted by ``offset_us`` — each replica tracer's
    timestamps are relative to its own construction, so the caller
    (``serving/fleet.py``) passes the tracer-epoch offset that aligns
    them on one fleet clock. ``extra`` is an optional list of
    ready-made events for an orchestration lane on ``extra_pid``
    (health transitions, failovers, hedges). Rejoined replicas carry a
    fresh tracer; the merge simply reflects whatever each current
    tracer recorded."""
    ev: List[Dict[str, Any]] = []
    for label, pid, trace, offset_us in parts:
        ev.append({"name": "process_name", "ph": "M", "ts": 0,
                   "pid": pid, "tid": 0, "args": {"name": label}})
        for e in trace.get("traceEvents", ()):
            if e.get("ph") == "M" and e.get("name") == "process_name":
                continue
            e2 = dict(e)
            e2["pid"] = pid
            if e.get("ph") != "M":
                e2["ts"] = round(e.get("ts", 0) + offset_us, 1)
            ev.append(e2)
    if extra:
        ev.append({"name": "process_name", "ph": "M", "ts": 0,
                   "pid": extra_pid, "tid": 0,
                   "args": {"name": extra_label}})
        ev.extend(extra)
    merged = {"traceEvents": ev, "displayTimeUnit": "ms"}
    if path:
        with open(path, "w") as f:
            json.dump(merged, f)
    return merged


# --------------------------------------------------------------------- #
# validation (tests + CI)
# --------------------------------------------------------------------- #
_PHASES = {"X", "i", "C", "M"}


def validate_chrome_trace(trace: Any) -> List[str]:
    """Structural validation of a Chrome trace-event object (or a path
    to one): returns a list of problems, empty when the trace is
    loadable by Perfetto / chrome://tracing. Used by
    ``tests/test_telemetry.py`` and the CI telemetry check."""
    if isinstance(trace, str):
        with open(trace) as f:
            trace = json.load(f)
    errs: List[str] = []
    if not isinstance(trace, dict) or "traceEvents" not in trace:
        return ["not a dict with a 'traceEvents' key"]
    events = trace["traceEvents"]
    if not isinstance(events, list) or not events:
        return ["'traceEvents' is not a non-empty list"]
    try:
        json.dumps(trace)
    except (TypeError, ValueError) as e:
        errs.append(f"not JSON-serializable: {e}")
    for i, e in enumerate(events):
        where = f"event {i}"
        if not isinstance(e, dict):
            errs.append(f"{where}: not an object")
            continue
        if not isinstance(e.get("name"), str) or not e["name"]:
            errs.append(f"{where}: missing/empty 'name'")
        ph = e.get("ph")
        if ph not in _PHASES:
            errs.append(f"{where}: bad phase {ph!r}")
        if not isinstance(e.get("ts"), (int, float)):
            errs.append(f"{where}: missing numeric 'ts'")
        if not isinstance(e.get("pid"), int):
            errs.append(f"{where}: missing integer 'pid'")
        if ph == "X":
            d = e.get("dur")
            if not isinstance(d, (int, float)) or d < 0:
                errs.append(f"{where}: 'X' event needs dur >= 0")
        if ph == "C" and not isinstance(e.get("args"), dict):
            errs.append(f"{where}: counter event needs numeric args")
    return errs


def complete_spans(trace: Dict[str, Any], prefix: str = "req "
                   ) -> Dict[str, Dict[str, Any]]:
    """Complete ('X') events whose name starts with ``prefix``, keyed by
    name — the per-request span lookup tests assert on."""
    return {e["name"]: e for e in trace.get("traceEvents", ())
            if e.get("ph") == "X" and str(e.get("name", "")
                                          ).startswith(prefix)}
