"""Paged KV cache: host-side page allocator, per-slot block tables and
copy-on-write prefix sharing.

The contiguous engine sizes every slot to the worst case — ``max_batch x
cache_len`` tokens of KV live in HBM whether or not anyone is using them
— and the shared-prefix trie must *copy* KV into a fresh slot on every
hit. This module replaces the per-slot rings with a fixed pool of
fixed-size pages plus a per-slot *block table* mapping logical KV blocks
to pool pages, so

* HBM scales with **live tokens** (pages are allocated as positions are
  written and released when a stream finishes), and
* a shared-prefix hit is a **page alias**: the new slot's block table
  points at the donor's pages with a refcount bump — zero KV copies,
  subsuming the trie's materialise/extract slot programs.

Split of responsibilities
-------------------------
Device side (``models/layers.py``): every attention sub-cache carries
``kp``/``vp`` page pools of shape ``(num_pages + 1, page_size, Hkv, hd)``
(plus int8 scale pools), a per-slot block table ``bt (B, n_blocks)``,
and the same dense ``pos (B, S)`` / ``step (B,)`` metadata as the
contiguous layout (``S = n_blocks * page_size``). Reads gather
``kp[bt]`` into the contiguous logical view; writes scatter through the
table. Pool index ``num_pages`` is a **trash page**: unallocated block
entries point at it, so gathers stay in-bounds (junk is masked by
``pos == -1``) and writes masked off by the engine land there harmlessly.

Host side (this module): ``PageAllocator`` owns the free list and
refcounts; ``PagedKVState`` owns the block tables and the slot
lifecycle — provisioning pages ahead of each dispatched step
(``prepare_write``, which also performs the copy-on-write split when a
to-be-written page is shared), aliasing prefix pages on a hit
(``alias_prefix``), pinning them when an entry is published
(``snapshot_prefix``), and releasing on finish/shrink. The host state is
authoritative; the device block table is just its pushed copy.

Invariants (asserted by ``check_invariants`` and fuzzed in
``tests/test_paged_kv.py``):

* **Conservation**: live pages + free pages == pool size after every op.
* **No double free**: releasing a page with refcount 0 raises.
* **CoW isolation**: a page reachable from two owners is never handed
  out for writing — ``prepare_write`` splits it first, so writes through
  one alias are never visible through the other.
* **Determinism**: the free list is a LIFO stack and every op is
  host-ordered, so identical op sequences yield identical block tables
  (prefill/decode replays hit identical pages — bit-equal caches).
"""
from __future__ import annotations

from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

__all__ = [
    "PagePoolExhausted",
    "PageAllocator",
    "PagedKVState",
    "walk_attn",
    "walk_attn2",
    "POOL_KEYS",
    "num_blocks",
]

# Cache-dict keys whose leading (post-scan) axis is the page pool rather
# than the batch. Everything else in an attention sub-cache (bt / pos /
# step) is per-slot and is sliced on the batch axis by the engine.
POOL_KEYS = ("kp", "vp", "kp_scale", "vp_scale")


def num_blocks(kv_len: int, page_size: int) -> int:
    return -(-int(kv_len) // int(page_size))


class PagePoolExhausted(RuntimeError):
    """Raised by ``PageAllocator.alloc`` when the free list is empty.

    The engine catches this at admission (backpressure: the request
    stays queued) and turns it into a hard error mid-decode (a live
    slot must never be corrupted by a failed write)."""


# --------------------------------------------------------------------- #
# tree walkers (shared with the engine)
# --------------------------------------------------------------------- #
def walk_attn(node, fn):
    """Apply ``fn`` to every attention sub-cache (dict containing "pos")
    in a nested dict tree, rebuilding the tree."""
    if isinstance(node, dict):
        if "pos" in node:
            return fn(node)
        return {k: walk_attn(v, fn) for k, v in node.items()}
    return node


def walk_attn2(a, b, fn):
    """Lockstep variant: ``fn(node_a, node_b)`` on paired sub-caches."""
    if isinstance(a, dict):
        if "pos" in a:
            return fn(a, b)
        return {k: walk_attn2(v, b[k], fn) for k, v in a.items()}
    return a


# --------------------------------------------------------------------- #
# allocator
# --------------------------------------------------------------------- #
class PageAllocator:
    """Refcounted free-list page allocator.

    The free list is a LIFO stack initialised so the first allocations
    hand out pages 0, 1, 2, ... — deterministic given the op sequence.
    """

    def __init__(self, num_pages: int):
        assert num_pages > 0
        self.num_pages = int(num_pages)
        self._free: List[int] = list(range(self.num_pages - 1, -1, -1))
        self.refcount = np.zeros(self.num_pages, dtype=np.int32)

    # ------------------------------------------------------------ #
    def alloc(self) -> int:
        if not self._free:
            raise PagePoolExhausted(
                f"KV page pool exhausted ({self.num_pages} pages, 0 free)")
        page = self._free.pop()
        self.refcount[page] = 1
        return page

    def retain(self, page: int) -> None:
        if self.refcount[page] <= 0:
            raise AssertionError(f"retain of unallocated page {page}")
        self.refcount[page] += 1

    def release(self, page: int) -> None:
        if self.refcount[page] <= 0:
            raise AssertionError(f"double free of page {page}")
        self.refcount[page] -= 1
        if self.refcount[page] == 0:
            self._free.append(page)

    # ------------------------------------------------------------ #
    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def live_pages(self) -> int:
        return self.num_pages - len(self._free)

    def check(self) -> None:
        """Conservation + free-list consistency. O(pool); called by the
        property tests after every op and by the engine under
        ``__debug__`` at poll boundaries."""
        free = set(self._free)
        assert len(free) == len(self._free), "duplicate page in free list"
        assert len(free) + int(np.sum(self.refcount > 0)) == self.num_pages, \
            "page conservation violated (live + free != pool)"
        assert np.all(self.refcount >= 0), "negative refcount"
        for p in free:
            assert self.refcount[p] == 0, f"free page {p} has refcount"


# --------------------------------------------------------------------- #
# per-slot block tables + lifecycle
# --------------------------------------------------------------------- #
class PagedKVState:
    """Host-authoritative block tables and page lifecycle for one engine.

    One instance serves *all* layers: the engine keeps every layer's
    block table identical (all layers of one stream occupy the same
    logical positions), so a single host table is broadcast to each
    attention sub-cache's ``bt`` leaf on push. Page indices refer to each
    layer's own pool — "page 7" is page 7 of every layer's ``kp``/``vp``.
    """

    def __init__(self, max_batch: int, kv_len: int, page_size: int,
                 num_pages: int):
        assert page_size > 0
        self.page_size = int(page_size)
        self.n_blocks = num_blocks(kv_len, page_size)
        self.logical_len = self.n_blocks * self.page_size
        self.num_pages = int(num_pages)
        self.sentinel = self.num_pages          # the trash page's pool index
        self.alloc = PageAllocator(num_pages)
        self.block_tables = np.full((max_batch, self.n_blocks),
                                    self.sentinel, dtype=np.int32)
        self.dirty = True       # device bt out of date (force initial push)
        # counters surfaced via Engine.latency_stats
        self.alias_pages = 0    # prefix-hit pages aliased (zero-copy reuse)
        self.cow_splits = 0     # shared pages split before a write
        self.pages_released = 0

    # ------------------------------------------------------------ #
    def _blocks_for(self, start: int, n: int) -> List[int]:
        """Logical block ids touched by writes at positions
        [start, start + n), ring-mapped mod the logical length."""
        if n <= 0:
            return []
        blocks = []
        seen = set()
        for p in range(start, start + n):
            b = (p % self.logical_len) // self.page_size
            if b not in seen:
                seen.add(b)
                blocks.append(b)
        return blocks

    def prepare_write(self, slot: int, start: int, n: int
                      ) -> List[Tuple[int, int]]:
        """Make every page touched by positions [start, start+n) of
        ``slot`` privately writable: allocate missing pages and
        CoW-split shared ones. Returns ``(src, dst)`` page pairs the
        caller must copy on device **before** dispatching the write.
        Raises :class:`PagePoolExhausted` without mutating state if the
        pool cannot cover the request (the caller may reclaim + retry).
        """
        bt = self.block_tables[slot]
        blocks = self._blocks_for(start, n)
        need = sum(1 for b in blocks
                   if bt[b] == self.sentinel
                   or self.alloc.refcount[bt[b]] > 1)
        if need > self.alloc.free_pages:
            raise PagePoolExhausted(
                f"need {need} pages for slot {slot}, "
                f"only {self.alloc.free_pages} free")
        copies: List[Tuple[int, int]] = []
        for b in blocks:
            cur = int(bt[b])
            if cur == self.sentinel:
                bt[b] = self.alloc.alloc()
                self.dirty = True
            elif self.alloc.refcount[cur] > 1:
                new = self.alloc.alloc()
                copies.append((cur, new))
                self.alloc.release(cur)
                bt[b] = new
                self.cow_splits += 1
                self.dirty = True
        return copies

    # ------------------------------------------------------------ #
    def alias_prefix(self, slot: int, pages: Sequence[int]) -> None:
        """Point ``slot``'s leading blocks at ``pages`` (a prefix-cache
        hit): refcount bumps only, no KV movement. The slot must be
        empty (freshly reset)."""
        bt = self.block_tables[slot]
        assert all(int(p) == self.sentinel for p in bt), \
            "alias_prefix into a non-empty slot"
        assert len(pages) <= self.n_blocks
        for i, p in enumerate(pages):
            self.alloc.retain(int(p))
            bt[i] = int(p)
        self.alias_pages += len(pages)
        if pages:
            self.dirty = True

    def snapshot_prefix(self, slot: int, n_tokens: int) -> List[int]:
        """Pin the pages holding ``slot``'s first ``n_tokens`` positions
        for publication as a prefix-cache entry (refcount bump; the
        entry owns one reference per page until evicted)."""
        assert n_tokens % self.page_size == 0, \
            "prefix entries must be page-aligned"
        k = n_tokens // self.page_size
        pages = [int(p) for p in self.block_tables[slot, :k]]
        assert all(p != self.sentinel for p in pages), \
            "snapshot of unallocated blocks"
        for p in pages:
            self.alloc.retain(p)
        return pages

    def release_pages(self, pages: Sequence[int]) -> None:
        """Drop one reference per page (prefix-entry eviction)."""
        for p in pages:
            self.alloc.release(int(p))
        self.pages_released += len(pages)

    # ------------------------------------------------------------ #
    def release_slot(self, slot: int) -> None:
        """Stream finished/evicted: release every page the slot holds."""
        bt = self.block_tables[slot]
        n = 0
        for b in range(self.n_blocks):
            if bt[b] != self.sentinel:
                self.alloc.release(int(bt[b]))
                bt[b] = self.sentinel
                n += 1
        if n:
            self.pages_released += n
            self.dirty = True

    def shrink(self, slot: int, depth: int) -> None:
        """Release pages past the slot's true depth (the engine
        provisions an upper bound ahead of dispatch and corrects here
        once the harvested trace reveals where the stream actually
        stopped). No-op once the ring has wrapped."""
        if depth >= self.logical_len:
            return
        bt = self.block_tables[slot]
        first_unused = num_blocks(max(depth, 0), self.page_size)
        n = 0
        for b in range(first_unused, self.n_blocks):
            if bt[b] != self.sentinel:
                self.alloc.release(int(bt[b]))
                bt[b] = self.sentinel
                n += 1
        if n:
            self.pages_released += n
            self.dirty = True

    # ------------------------------------------------------------ #
    def pages_for(self, n_tokens: int) -> int:
        """Pages needed to hold ``n_tokens`` positions from 0."""
        return num_blocks(min(n_tokens, self.logical_len), self.page_size)

    def can_admit(self, n_tokens: int, aliased: int = 0) -> bool:
        """Conservative admission check: room for the prompt plus the
        first decode write, minus blocks served by a prefix alias."""
        need = self.pages_for(n_tokens + 1) - int(aliased)
        return need <= self.alloc.free_pages

    # ------------------------------------------------------------ #
    @property
    def free_pages(self) -> int:
        return self.alloc.free_pages

    @property
    def live_pages(self) -> int:
        return self.alloc.live_pages

    def check_invariants(
            self, entry_pages: Optional[Sequence[Sequence[int]]] = None
    ) -> None:
        """Allocator conservation plus table/refcount agreement: every
        page's refcount equals the number of block-table cells plus
        prefix-entry references (``entry_pages``) pointing at it."""
        self.alloc.check()
        refs = np.zeros(self.num_pages, dtype=np.int64)
        for row in self.block_tables:
            for p in row:
                if p != self.sentinel:
                    refs[p] += 1
        for pages in (entry_pages or ()):
            for p in pages:
                refs[int(p)] += 1
        assert np.array_equal(refs, self.alloc.refcount.astype(np.int64)), \
            "refcounts disagree with block-table + entry references"

    def stats(self) -> Dict[str, float]:
        return {
            "kv_pages_total": self.num_pages,
            "kv_page_size": self.page_size,
            "kv_pages_live": self.live_pages,
            "kv_pages_free": self.free_pages,
            "kv_alias_pages": self.alias_pages,
            "kv_cow_splits": self.cow_splits,
            "kv_pages_released": self.pages_released,
        }
