"""Shared-prefix KV reuse: a host-side trie over token prefixes whose
values are device-resident KV slices.

Requests arriving with a shared head (system prompts, few-shot headers)
should not recompute it: after a prompt is admitted through the chunked
prefill path, the engine snapshots the KV of its first ``P`` positions
(``P`` = the largest prefill-chunk multiple ``<= L - 1``) and inserts it
here. A later prompt that starts with the same ``P`` tokens gets the
slice materialised into its fresh slot with one on-device
``dynamic_update_slice`` copy and resumes chunked prefill at offset
``P`` — reuse costs one HBM copy instead of ``P`` tokens of compute.

Invariants (relied on by the engine, asserted in
``tests/test_continuous_batching.py``):

* **Bucketed entry lengths.** Every stored (and served) prefix length is
  a power-of-two multiple of ``prefill_chunk`` (C, 2C, 4C, ...), so a
  hit always resumes on a chunk boundary and every length-keyed program
  (extract, materialise, the eager partial-hit slice) draws from an
  O(log(cache_len / chunk)) set the engine can warm up front — the same
  bucketing argument as the prefill jit cache.
* **Partial-entry lookup.** A prompt need not match a whole stored
  entry: ``lookup`` walks the trie to the deepest matched node, rounds
  down to a chunk boundary Q (``<= len(prompt) - 1``: at least one token
  must remain to produce the first-token logits), and serves the first Q
  tokens of *any* entry passing through that node — K/V at position p
  depends only on tokens ``<= p`` (causality), so the slice is exact.
  Prompts sharing just a system header hit even though every stored
  entry continues past it.
* **Token-budget LRU.** Total stored tokens never exceed
  ``capacity_tokens``; insertion evicts least-recently-used entries
  (lookup hits refresh recency). Entries larger than the whole budget
  are never stored.
* **Bit-fidelity.** Entries hold the exact cache leaves (including int8
  KV payloads and their scales), so a hit's slot state is bit-identical
  to recomputing the prefix — greedy outputs cannot diverge.
"""
from __future__ import annotations

import collections
from typing import Any, Callable, Dict, List, Optional, Tuple


class _Node:
    __slots__ = ("children", "entry_key")

    def __init__(self):
        self.children: Dict[int, "_Node"] = {}
        self.entry_key = None           # set iff a stored prefix ends here


class PrefixCache:
    def __init__(self, capacity_tokens: int, chunk: int,
                 on_evict: Optional[Callable[[Dict], None]] = None):
        """``on_evict(entry)`` fires when an entry leaves the cache —
        the paged engine uses it to release the entry's page
        references (the pages themselves outlive the entry while any
        live slot still aliases them)."""
        assert chunk > 0
        self.capacity = int(capacity_tokens)
        self.chunk = int(chunk)
        self.on_evict = on_evict
        self.root = _Node()
        # key (tuple of ids) -> {"kv": device pytree, "length": P}
        self._entries: "collections.OrderedDict[Tuple[int, ...], Dict]" = \
            collections.OrderedDict()
        self.tokens = 0                 # total stored tokens
        self.hits = 0
        self.misses = 0
        self.hit_tokens = 0             # prompt tokens served from cache
        self.evictions = 0

    def __len__(self) -> int:
        return len(self._entries)

    # ------------------------------------------------------------ #
    def lookup(self, prompt) -> Tuple[Optional[Any], int, int]:
        """Longest chunk-aligned stored prefix of ``prompt`` shorter than
        the prompt. Returns ``(kv pytree, entry length, hit length Q)``
        — the caller materialises the first Q positions of the entry —
        or ``(None, 0, 0)``. A hit refreshes the donor entry's LRU
        recency."""
        node = self.root
        depth = 0
        limit = len(prompt) - 1
        for tok in prompt:
            if depth >= limit:
                break
            nxt = node.children.get(int(tok))
            if nxt is None:
                break
            node = nxt
            depth += 1
        Q = self.bucket(depth)
        key = self._entry_through(self.root, prompt, Q) if Q else None
        if key is None:
            self.misses += 1
            return None, 0, 0
        entry = self._entries[key]
        self._entries.move_to_end(key)
        self.hits += 1
        self.hit_tokens += Q
        return entry["kv"], entry["length"], Q

    def _entry_through(self, root: _Node, prompt, Q: int):
        """Any entry whose key starts with ``prompt[:Q]`` (every live
        trie node lies on the path of at least one entry, so the search
        below the depth-Q node always terminates)."""
        node = root
        for tok in prompt[:Q]:
            node = node.children.get(int(tok))
            if node is None:
                return None
        stack = [node]
        while stack:
            n = stack.pop()
            if n.entry_key is not None and len(n.entry_key) >= Q:
                return n.entry_key
            stack.extend(n.children.values())
        return None

    # ------------------------------------------------------------ #
    def bucket(self, n: int) -> int:
        """Largest power-of-two chunk multiple <= n (0 if n < chunk)."""
        if n < self.chunk:
            return 0
        return self.chunk << ((n // self.chunk).bit_length() - 1)

    def wants(self, prompt) -> int:
        """The prefix length ``insert`` would store for this prompt:
        the largest bucket <= len(prompt) - 1 that fits the token
        budget and is not already *covered*. 0 = nothing to store (the
        caller skips the device-side KV extraction entirely).

        Covered means any stored entry passes through ``prompt[:P]`` —
        not just an exact-key match. Partial-entry lookup serves the
        first Q positions of any such entry, so storing ``prompt[:P]``
        again would be fully redundant; the old exact-key check missed
        this, and every prompt whose hit came from a *longer* entry
        re-extracted and re-stored a prefix of it, wasting a prefill
        bucket entry's worth of token budget until eviction."""
        P = self.bucket(len(prompt) - 1)
        if not P or P > self.capacity:
            return 0
        if self._entry_through(self.root, prompt, P) is not None:
            return 0
        return P

    def insert(self, prompt, P: int, kv) -> None:
        """Store ``kv`` (the device KV slice of prompt[:P]) and evict
        LRU entries past the token budget."""
        key = tuple(int(t) for t in prompt[:P])
        if not P or key in self._entries:
            return
        node = self.root
        for tok in key:
            node = node.children.setdefault(tok, _Node())
        node.entry_key = key
        self._entries[key] = {"kv": kv, "length": P}
        self.tokens += P
        while self.tokens > self.capacity and len(self._entries) > 1:
            self._evict_lru(keep=key)

    def drop_lru(self) -> bool:
        """Evict the least-recently-used entry unconditionally (the
        paged engine's free-list reclaim under page pressure). Returns
        False when the cache is empty."""
        if not self._entries:
            return False
        self._evict_lru()
        return True

    def _evict_lru(self, keep=None) -> None:
        for key in self._entries:
            if key != keep:
                break
        else:
            return
        entry = self._entries.pop(key)
        self.tokens -= entry["length"]
        self.evictions += 1
        if self.on_evict is not None:
            self.on_evict(entry)
        # unlink from the trie and prune now-empty nodes
        path: List[Tuple[_Node, int]] = []
        node = self.root
        for tok in key:
            path.append((node, tok))
            node = node.children[tok]
        node.entry_key = None
        for parent, tok in reversed(path):
            child = parent.children[tok]
            if child.children or child.entry_key is not None:
                break
            del parent.children[tok]

    # ------------------------------------------------------------ #
    def stats(self) -> Dict[str, float]:
        return {
            "prefix_entries": len(self._entries),
            "prefix_tokens": self.tokens,
            "prefix_hits": self.hits,
            "prefix_misses": self.misses,
            "prefix_hit_tokens": self.hit_tokens,
            "prefix_evictions": self.evictions,
        }
