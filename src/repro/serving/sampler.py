"""Token samplers: greedy / temperature / top-k, jit-friendly."""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class Sampler:
    temperature: float = 0.0   # 0 = greedy
    top_k: int = 0             # 0 = full distribution

    def __call__(self, key, logits):
        """logits: (B, V) f32 -> token ids (B,) int32."""
        if self.temperature == 0.0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        logits = logits / self.temperature
        if self.top_k:
            vals, idx = jax.lax.top_k(logits, self.top_k)
            choice = jax.random.categorical(key, vals)
            return jnp.take_along_axis(idx, choice[:, None],
                                       axis=-1)[:, 0].astype(jnp.int32)
        return jax.random.categorical(key, logits).astype(jnp.int32)
