"""Token samplers: greedy / temperature / top-k / top-p.

The sampler is a frozen dataclass of *static* knobs so the serving engine
can close over it inside ``jax.jit`` — the whole ``decode_step -> logits ->
next token`` chain compiles into one XLA program and sampled tokens never
leave the device (engine v2's fused decode step).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

NEG_INF = -1e30


@dataclass(frozen=True)
class Sampler:
    temperature: float = 0.0   # 0 = greedy
    top_k: int = 0             # 0 = full distribution
    top_p: float = 1.0         # 1 = no nucleus truncation

    def __call__(self, key, logits):
        """logits: (B, V) f32 -> token ids (B,) int32. ``key`` is unused
        (but accepted) for greedy decoding so call sites are uniform."""
        if self.temperature == 0.0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        logits = logits / self.temperature
        if self.top_p < 1.0:
            logits = self._nucleus(logits)
        if self.top_k:
            vals, idx = jax.lax.top_k(logits, self.top_k)
            choice = jax.random.categorical(key, vals)
            return jnp.take_along_axis(idx, choice[:, None],
                                       axis=-1)[:, 0].astype(jnp.int32)
        return jax.random.categorical(key, logits).astype(jnp.int32)

    def _nucleus(self, logits):
        """Mask logits outside the smallest set with cumulative prob >=
        top_p (the highest-probability token always survives)."""
        sorted_logits = jnp.sort(logits, axis=-1)[:, ::-1]
        probs = jax.nn.softmax(sorted_logits, axis=-1)
        cum = jnp.cumsum(probs, axis=-1)
        # keep tokens whose *preceding* cumulative mass is < top_p; the
        # top token is kept unconditionally (top_p <= 0 = top-1)
        keep_sorted = ((cum - probs) < self.top_p).at[:, 0].set(True)
        cutoff = jnp.min(jnp.where(keep_sorted, sorted_logits, jnp.inf),
                         axis=-1, keepdims=True)
        return jnp.where(logits >= cutoff, logits, NEG_INF)
