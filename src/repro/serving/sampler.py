"""Token samplers: greedy / temperature / top-k / top-p, plus the
speculative-decoding accept/resample rule.

The sampler is a frozen dataclass of *static* knobs so the serving engine
can close over it inside ``jax.jit`` — the whole ``decode_step -> logits ->
next token`` chain compiles into one XLA program and sampled tokens never
leave the device (engine v2's fused decode step). ``speculative`` extends
that contract to the fused draft–verify step: acceptance, the first-
rejection resample and the bonus token are all computed on device.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

NEG_INF = -1e30


@dataclass(frozen=True)
class Sampler:
    temperature: float = 0.0   # 0 = greedy
    top_k: int = 0             # 0 = full distribution
    top_p: float = 1.0         # 1 = no nucleus truncation

    def __call__(self, key, logits):
        """logits: (B, V) f32 -> token ids (B,) int32. ``key`` is unused
        (but accepted) for greedy decoding so call sites are uniform."""
        if self.temperature == 0.0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        logits = logits / self.temperature
        if self.top_p < 1.0:
            logits = self._nucleus(logits)
        if self.top_k:
            vals, idx = self._topk(logits)
            choice = jax.random.categorical(key, vals)
            return jnp.take_along_axis(idx, choice[:, None],
                                       axis=-1)[:, 0].astype(jnp.int32)
        return jax.random.categorical(key, logits).astype(jnp.int32)

    def _topk(self, logits):
        """THE top-k selection rule: ``lax.top_k``, which breaks ties at
        the k-th value by lowest index. Every path that restricts to k
        tokens (``__call__`` sampling, ``filtered_logits`` masking) must
        select through this one function — when the k-th value is tied,
        "all entries >= kth" keeps more than k tokens and the speculative
        accept/resample distribution q/p would disagree with what the
        engine actually samples."""
        return jax.lax.top_k(logits, self.top_k)

    def filtered_logits(self, logits):
        """The post-knob logits over the *full* vocab: temperature scaling
        then nucleus then top-k masking (masked entries at NEG_INF), so
        ``softmax(filtered_logits(l))`` is exactly the distribution
        ``__call__`` samples from — including at ties: the surviving set
        is the *same k entries* ``_topk`` selects, scattered back into
        the full vocab, not "every logit >= the k-th value". Accepts any
        leading shape (..., V). Greedy (temperature 0) has no
        finite-temperature distribution; callers special-case it."""
        assert self.temperature != 0.0
        lead = logits.shape[:-1]
        logits = logits.reshape(-1, logits.shape[-1]) / self.temperature
        if self.top_p < 1.0:
            logits = self._nucleus(logits)
        if self.top_k:
            vals, idx = self._topk(logits)
            rows = jnp.arange(logits.shape[0])[:, None]
            logits = jnp.full_like(logits, NEG_INF).at[rows, idx].set(vals)
        return logits.reshape(lead + (-1,))

    def speculative(self, key, draft_tokens, draft_logits, target_logits):
        """Speculative-decoding accept/resample (Leviathan et al. 2023),
        vectorised over the batch and fully on device.

        draft_tokens: (B, G) int32 proposals sampled from the draft;
        draft_logits: (B, G, V) the draft logits those were sampled from;
        target_logits: (B, G+1, V) target logits at the same positions
        (position G is the bonus position after all G proposals).

        Returns ``(block, n_acc)``: ``block`` (B, G+1) int32 where the
        first ``n_acc[b] + 1`` entries of row b are the tokens to emit —
        the accepted draft prefix followed by the resampled first
        rejection (or the bonus token when everything was accepted).

        Greedy: accept while the draft matches the target argmax, so the
        emitted prefix is *exactly* the target's greedy continuation —
        speculative greedy output is token-identical to the baseline.
        Stochastic: accept token x with prob min(1, p(x)/q(x)); resample
        the first rejection from norm(max(p - q, 0)), which makes every
        emitted token an exact sample from the target distribution.
        """
        B, G = draft_tokens.shape
        if self.temperature == 0.0:
            block = jnp.argmax(target_logits, axis=-1).astype(jnp.int32)
            acc = draft_tokens == block[:, :G]                   # (B, G)
            n_acc = jnp.sum(jnp.cumprod(acc.astype(jnp.int32), axis=1),
                            axis=1)                              # (B,)
            return block, n_acc

        p = jax.nn.softmax(self.filtered_logits(target_logits), axis=-1)
        q = jax.nn.softmax(self.filtered_logits(draft_logits), axis=-1)
        ku, kr = jax.random.split(key)
        p_d = jnp.take_along_axis(p[:, :G], draft_tokens[..., None],
                                  axis=-1)[..., 0]               # (B, G)
        q_d = jnp.take_along_axis(q, draft_tokens[..., None],
                                  axis=-1)[..., 0]
        u = jax.random.uniform(ku, (B, G))
        acc = u * q_d < p_d          # u < p/q without dividing by q=0
        n_acc = jnp.sum(jnp.cumprod(acc.astype(jnp.int32), axis=1), axis=1)
        # residual distribution per position: norm(max(p - q, 0)); the
        # bonus position (no draft) resamples from p itself (q := 0).
        q_pad = jnp.concatenate([q, jnp.zeros_like(p[:, :1])], axis=1)
        resid = jnp.maximum(p - q_pad, 0.0)
        # p == q exactly (or numerically) -> residual is empty; any
        # token from p is then a valid "resample"
        empty = jnp.sum(resid, axis=-1, keepdims=True) <= 0.0
        resid = jnp.where(empty, p, resid)
        r = jax.random.categorical(
            kr, jnp.log(jnp.maximum(resid, 1e-30)))              # (B, G+1)
        d_pad = jnp.concatenate(
            [draft_tokens, jnp.zeros((B, 1), jnp.int32)], axis=1)
        idx = jnp.arange(G + 1)[None, :]
        block = jnp.where(idx < n_acc[:, None], d_pad,
                          r.astype(jnp.int32))
        return block, n_acc

    def _nucleus(self, logits):
        """Mask logits outside the smallest set with cumulative prob >=
        top_p (the highest-probability token always survives)."""
        sorted_logits = jnp.sort(logits, axis=-1)[:, ::-1]
        probs = jax.nn.softmax(sorted_logits, axis=-1)
        cum = jnp.cumsum(probs, axis=-1)
        # keep tokens whose *preceding* cumulative mass is < top_p; the
        # top token is kept unconditionally (top_p <= 0 = top-1)
        keep_sorted = ((cum - probs) < self.top_p).at[:, 0].set(True)
        cutoff = jnp.min(jnp.where(keep_sorted, sorted_logits, jnp.inf),
                         axis=-1, keepdims=True)
        return jnp.where(logits >= cutoff, logits, NEG_INF)
