"""Prompt-lookup (n-gram) speculative drafter: family-agnostic draft
proposals with no draft model, no draft cache, and no second forward.

The drafter keeps a per-slot token *history* — the request's effective
stream (prompt, then everything emitted), resident on device so the
fused spec step stays sync-free. To propose, it matches the most recent
``n``-gram of each row against earlier occurrences in the row's own
stream and proposes the tokens that followed the most recent match
(descending ``n``, so the longest context wins). Natural-language and
code streams repeat themselves enough that this simple lookup draws
multi-token accepts from the verify step with *zero* draft FLOPs —
which is exactly what makes it the universal drafter: SSM and hybrid
targets whose recurrent caches cannot host a lagging draft model
(``Model.rollback_needs_replay``), MoE and encoder–decoder stacks, all
speculate through the same target-side verify/accept/rollback machinery
(``engine._build_ngram_spec_step``).

Proposals are deterministic functions of the history, so greedy decoding
is token-identical to plain decode: ``sampler.speculative`` emits the
target argmax prefix regardless of what the drafter proposed — the
drafter only decides *how many* positions verify per step, never which
tokens commit. For stochastic sampling the drafter's distribution is the
one-hot of its proposal, so the standard accept ratio ``p/q`` reduces to
accepting with the target's own probability of the proposed token.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["ngram_propose"]


def ngram_propose(hist, hist_len, *, gamma: int, vocab: int,
                  max_n: int = 3):
    """Propose ``gamma`` draft tokens per row from the row's own stream.

    Args:
      hist: (B, H) int32 — per-slot token history, front-filled, ``-1``
        past ``hist_len`` (the engine seeds it with the effective stream
        at admission and appends every emitted block).
      hist_len: (B,) int32 — valid prefix length of each row.
      gamma: number of tokens to propose.
      vocab: vocabulary size (for the one-hot proposal distribution).
      max_n: longest context n-gram to try (descending to 1).

    Returns:
      ``(draft_tokens, draft_logits)`` — (B, gamma) int32 proposals and
      (B, gamma, vocab) f32 one-hot logits (0 on the proposal, -1e9
      elsewhere), the shapes ``sampler.speculative`` expects from a
      model draft.

    Matching: for each ``n`` from ``max_n`` down to 1, row ``b``'s
    context is its last ``n`` valid tokens; a window at ``j`` matches
    when ``hist[b, j:j+n]`` equals the context and a continuation exists
    strictly before the context itself (``j + n <= hist_len - 1`` — the
    trivial self-match at ``j = hist_len - n`` is thereby excluded).
    The *most recent* match wins and proposals start at its
    continuation, clamped to the last valid position (so a match near
    the stream's end degrades into repeat-last rather than reading the
    ``-1`` fill). Rows with no match at any ``n`` propose repeat-last —
    a cheap guess that costs nothing when rejected.
    """
    B, H = hist.shape
    l = hist_len                                               # (B,)
    last = jnp.maximum(l - 1, 0)
    j_idx = jnp.arange(H, dtype=jnp.int32)                     # (H,)
    found = jnp.zeros((B,), bool)
    start = last                                  # fallback: repeat-last
    for n in range(max_n, 0, -1):
        cpos = l[:, None] - n + jnp.arange(n)[None, :]         # (B, n)
        ctx = jnp.take_along_axis(hist, jnp.maximum(cpos, 0), axis=1)
        ok = jnp.ones((B, H), bool)
        for k in range(n):
            # shifted[:, j] = hist[:, j+k]; the roll wrap past H-1 is
            # unreachable under the j + n <= l-1 validity bound below
            ok = ok & (jnp.roll(hist, -k, axis=1) == ctx[:, k][:, None])
        ok = ok & (j_idx[None, :] + n <= l[:, None] - 1) \
                & (l[:, None] >= n + 1)
        j = jnp.max(jnp.where(ok, j_idx[None, :], -1), axis=1)  # (B,)
        hit = (j >= 0) & ~found
        start = jnp.where(hit, j + n, start)
        found = found | hit
    pos = jnp.minimum(start[:, None] + jnp.arange(gamma)[None, :],
                      last[:, None])                           # (B, g)
    draft = jnp.maximum(jnp.take_along_axis(hist, pos, axis=1), 0)
    oh = jax.nn.one_hot(draft, vocab, dtype=jnp.float32)
    return draft, jnp.where(oh > 0, 0.0, -1e9)
