"""HLO text analysis: collective traffic extraction.

``compiled.cost_analysis()`` has no collective-bytes term, so we parse the
optimized (post-SPMD) HLO and sum result-shape bytes per collective op
kind. The module is the per-device program, so totals are per-device.
"""
from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
# e.g.:  %ag = bf16[4,128]{1,0} all-gather(...)
_OP_RE = re.compile(
    r"=\s*((?:\([^=]*?\))|(?:[a-z0-9]+\[[0-9,]*\][^\s]*))\s+"
    r"(all-reduce|all-gather-start|all-gather|reduce-scatter|all-to-all|"
    r"collective-permute-start|collective-permute)\b")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Per-device bytes moved by each collective kind (result shapes),
    plus op counts under ``n_<kind>`` keys."""
    out: Dict[str, int] = defaultdict(int)
    for m in _OP_RE.finditer(hlo_text):
        shape_str, kind = m.group(1), m.group(2)
        kind = kind.replace("-start", "")
        out[kind] += _shape_bytes(shape_str)
        out[f"n_{kind}"] += 1
    return dict(out)


def total_collective_bytes(stats: Dict[str, int]) -> int:
    return sum(v for k, v in stats.items() if not k.startswith("n_"))


def op_histogram(hlo_text: str, top: int = 20) -> Dict[str, int]:
    """Crude fusion-name histogram — useful for spotting remat recompute
    (duplicate op stems) when iterating on §Perf."""
    counts: Dict[str, int] = defaultdict(int)
    for m in re.finditer(r"^\s*(?:ROOT\s+)?%?([a-z][a-z0-9_.-]*)\s*=",
                         hlo_text, re.M):
        stem = m.group(1).split(".")[0]
        counts[stem] += 1
    return dict(sorted(counts.items(), key=lambda kv: -kv[1])[:top])
