"""Sharding rules: logical activation names and parameter-path rules.

Models call :func:`shard_activation` with a logical name; when an
``ActivationRules`` context is active (set by the launcher), this applies
``lax.with_sharding_constraint``. Outside a mesh context it is a no-op, so
model code stays pure and CPU tests are unaffected.
"""
from __future__ import annotations

import re
import threading
from contextlib import contextmanager
from typing import Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_ctx = threading.local()


# --------------------------------------------------------------------- #
# activation rules
# --------------------------------------------------------------------- #
# logical name -> PartitionSpec builder(batch_axes, model_axis)
def default_activation_rules(batch_axes=("data",), model_axis="model",
                             seq_axis=None):
    b = tuple(batch_axes)
    batch = b if len(b) > 1 else b[0]
    return {
        # (B, L, D)
        "act_btd": P(batch, seq_axis, None),
        # (B, L, H, hd)
        "act_heads": P(batch, seq_axis, model_axis, None),
        # (B, L, V)
        "logits": P(batch, seq_axis, model_axis),
        # MoE dispatch (E, C, d)
        "moe_expert": P(model_axis, None, None),
        # grouped MoE dispatch (G, E, C, d): groups on data, experts on model
        "moe_expert_grouped": P(batch, model_axis, None, None),
        # KV cache (B, S, Hkv, hd)
        "kv_cache": P(batch, seq_axis, model_axis, None),
        # SSM state (B, nh, p, n)
        "ssm_state": P(batch, model_axis, None, None),
    }


class ActivationRules:
    def __init__(self, mesh: Mesh, rules: dict):
        self.mesh = mesh
        self.rules = rules


@contextmanager
def activation_sharding(mesh: Mesh, rules: dict):
    prev = getattr(_ctx, "rules", None)
    _ctx.rules = ActivationRules(mesh, rules)
    try:
        yield
    finally:
        _ctx.rules = prev


def current_rules() -> Optional[ActivationRules]:
    return getattr(_ctx, "rules", None)


def model_axis_size(axis: str = "model") -> int:
    """Size of the model axis in the active ``ActivationRules`` mesh (1
    when no context is active or the mesh has no such axis). Lets code
    outside the model stack — e.g. ``kernels.dispatch`` — ask "are
    activations tensor-parallel right now?" without threading the mesh
    through every call site."""
    ctx = current_rules()
    if ctx is None:
        return 1
    sizes = dict(zip(ctx.mesh.axis_names, ctx.mesh.devices.shape))
    return int(sizes.get(axis, 1))


def shard_activation(x, name: str):
    ctx = current_rules()
    if ctx is None or name not in ctx.rules:
        return x
    spec = ctx.rules[name]
    # Drop constraint if rank mismatch (e.g. flattened activations).
    if hasattr(x, "ndim") and len(spec) != x.ndim:
        return x
    # Replicate non-divisible dims (same fit rule as param/cache
    # shardings): an uneven constraint — e.g. 4 KV heads on an 8-way
    # model axis — would fight the fitted cache/param shardings and
    # force involuntary resharding inside the step program.
    axis_sizes = dict(zip(ctx.mesh.axis_names, ctx.mesh.devices.shape))
    fixed, _ = _fit_spec(tuple(spec), x.shape, axis_sizes)
    try:
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(ctx.mesh, P(*fixed)))
    except ValueError:
        return x


# --------------------------------------------------------------------- #
# parameter rules (path-pattern -> PartitionSpec)
# --------------------------------------------------------------------- #
# Patterns are matched against '/'-joined pytree paths, first match wins.
# Each rule value is a spec or a LIST of candidate specs — the first whose
# sharded dims all divide evenly is used (e.g. expert-parallel MoE falls
# back to tensor-parallel experts when E % mesh_model != 0).
# None entries in the spec mean replicated on that dim.
def default_param_rules(model_axis="model", zero_axis=None):
    m = model_axis
    rules = [
        # embeddings / unembedding: shard vocab
        (r".*embed.*/table", (m, None)),
        (r".*lm_head/w", (None, m)),
        # attention
        (r".*attn.*/wq/w", (None, m)),
        (r".*attn.*/wk/w", (None, m)),
        (r".*attn.*/wv/w", (None, m)),
        (r".*attn.*/wo/w", (m, None)),
        (r".*attn.*/w[qkv]/b", (m,)),
        # dense MLP: d_ff on model
        (r".*mlp/wi/w", (None, m)),
        (r".*mlp/wg/w", (None, m)),
        (r".*mlp/wo/w", (m, None)),
        # MoE: experts on model axis (expert parallelism); tensor-parallel
        # experts (d_expert on model) when E doesn't divide the axis
        (r".*moe/router/w", (None, None)),
        (r".*moe/w[ig]$", [(m, None, None), (None, None, m)]),
        (r".*moe/wo$", [(m, None, None), (None, m, None)]),
        (r".*moe/shared/wi/w", (None, m)),
        (r".*moe/shared/wg/w", (None, m)),
        (r".*moe/shared/wo/w", (m, None)),
        # SSM: inner dim on model; fall back to the input dim when the
        # packed projection width doesn't divide (e.g. 256-way flat axis)
        (r".*ssm/in_proj/w", [(None, m), (m, None)]),
        (r".*ssm/out_proj/w", (m, None)),
        (r".*ssm/conv_w", (m, None)),
        (r".*ssm/conv_b", (m,)),
        (r".*ssm/norm/scale", (m,)),
        # frontend projector
        (r".*frontend_proj/w", (None, m)),
        # norms and scalars: replicated
        (r".*", None),
    ]
    return rules


def _path_str(path) -> str:
    parts = []
    for pk in path:
        if hasattr(pk, "key"):
            parts.append(str(pk.key))
        elif hasattr(pk, "idx"):
            parts.append(str(pk.idx))
        else:
            parts.append(str(pk))
    return "/".join(parts)


def _axis_size(ax, axis_sizes) -> int:
    return int(np.prod([axis_sizes[a] for a in
                        (ax if isinstance(ax, tuple) else (ax,))]))


def _fit_spec(spec, shape, axis_sizes):
    """Pad a spec to rank; returns (fixed_spec, fully_ok). Non-divisible
    sharded dims are replicated (fully_ok=False so candidates can fall
    through)."""
    if spec is None:
        return (None,) * len(shape), True
    spec = tuple(spec)
    if len(spec) < len(shape):
        spec = (None,) * (len(shape) - len(spec)) + spec
    elif len(spec) > len(shape):
        return (None,) * len(shape), False
    fixed, ok = [], True
    for dim, ax in enumerate(spec):
        if ax is None:
            fixed.append(None)
        elif shape[dim] % _axis_size(ax, axis_sizes) == 0:
            fixed.append(ax)
        else:
            fixed.append(None)
            ok = False
    return tuple(fixed), ok


def spec_for_path(path_str: str, shape, rules, axis_sizes) -> P:
    qspec = _qtensor_spec(path_str, shape, rules, axis_sizes)
    if qspec is not None:
        return qspec
    for pat, spec in rules:
        if re.fullmatch(pat, path_str):
            candidates = spec if isinstance(spec, list) else [spec]
            fallback = None
            for cand in candidates:
                fixed, ok = _fit_spec(cand, shape, axis_sizes)
                if ok:
                    return P(*fixed)
                if fallback is None:
                    fallback = fixed
            return P(*fallback)
    return P()


def add_zero_sharding(specs_tree, shapes_tree, mesh: Mesh,
                      zero_axes=("data",)):
    """ZeRO-style: additionally shard each leaf's largest still-replicated
    dim over ``zero_axes`` (used for optimizer state / fsdp params)."""
    axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    z = int(np.prod([axis_sizes[a] for a in zero_axes]))
    zax = zero_axes if len(zero_axes) > 1 else zero_axes[0]

    def one(sharding, leaf):
        shape = leaf.shape
        spec = list(sharding.spec) + [None] * (len(shape) - len(sharding.spec))
        best, best_size = None, 0
        for dim in range(len(shape)):
            if spec[dim] is None and shape[dim] % z == 0 \
                    and shape[dim] > best_size:
                best, best_size = dim, shape[dim]
        if best is not None:
            spec[best] = zax
        return NamedSharding(mesh, P(*spec))

    return jax.tree.map(one, specs_tree, shapes_tree)


def cache_shardings(cache_shapes, mesh: Mesh, batch_axes=("data",),
                    *, seq_axis=None, model_axis="model"):
    """Decode/prefill cache sharding. Leaves are recognised by their cache
    key: k/v/xk/xv (nb, B, S, H, hd), pos (nb, B, S), step (nb, B),
    conv (nb, B, K-1, C), ssm (nb, B, nh, p, n). ``seq_axis`` shards the
    KV sequence dim instead of batch for batch=1 long-context decode."""
    axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    b = tuple(batch_axes)
    batch = b if len(b) > 1 else b[0]
    m = model_axis
    # if the sequence axis uses the model axis (KV-sequence sharding for
    # decode — §Perf), the heads dim must not also use it
    seq_axes = (seq_axis if isinstance(seq_axis, tuple)
                else ((seq_axis,) if seq_axis else ()))
    heads = None if model_axis in seq_axes else m
    by_name = {
        "k": (None, batch, seq_axis, heads, None),
        "v": (None, batch, seq_axis, heads, None),
        "xk": (None, batch, seq_axis, heads, None),
        "xv": (None, batch, seq_axis, heads, None),
        "pos": (None, batch, seq_axis),
        "step": (None, batch),
        "k_scale": (None, batch, seq_axis, heads),
        "v_scale": (None, batch, seq_axis, heads),
        "conv": (None, batch, None, m),
        "ssm": (None, batch, m, None, None),
        # paged KV: pools (nb, P+1, ps, Hkv, hd) shard heads on the model
        # axis (pages are shared across the batch so neither the page nor
        # batch axis applies); block tables (nb, B, NB) follow the batch
        "kp": (None, None, None, heads, None),
        "vp": (None, None, None, heads, None),
        "kp_scale": (None, None, None, heads),
        "vp_scale": (None, None, None, heads),
        "bt": (None, batch, None),
    }

    def one(path, leaf):
        name = _path_str(path).split("/")[-1]
        spec = by_name.get(name)
        if spec is None:
            return NamedSharding(mesh, P())
        fixed, _ = _fit_spec(spec, leaf.shape, axis_sizes)
        return NamedSharding(mesh, P(*fixed))

    return jax.tree_util.tree_map_with_path(one, cache_shapes)


def batch_shardings(batch_shapes, mesh: Mesh, batch_axes=("data",)):
    """Host batch: shard the leading (global batch) dim."""
    axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    b = tuple(batch_axes)
    batch = b if len(b) > 1 else b[0]

    def one(leaf):
        spec = (batch,) + (None,) * (len(leaf.shape) - 1)
        fixed, _ = _fit_spec(spec, leaf.shape, axis_sizes)
        return NamedSharding(mesh, P(*fixed))

    return jax.tree.map(one, batch_shapes)


def _qtensor_spec(path_str: str, shape, rules, axis_sizes) -> Optional[P]:
    """Spec for a quantized-weight leaf (``quant.quantize_params`` replaces
    a linear's ``w`` with a ``{"q"|"q4", "scale"}`` dict, so paths gain a
    trailing component the ``.../w`` rules don't see).

    * ``.../w/q`` and ``.../w/q4`` keep the weight's own spec — ``q`` has
      ``w``'s shape and ``q4`` only halves the K dim (divisibility is
      re-checked against the actual leaf shape);
    * ``.../w/scale`` is per-output-channel (int8: (..., N); int4:
      (..., n_groups, N)): shard the last dim iff the weight rule shards
      its last (output) dim, replicate everything else.

    Returns None for leaves that are not QTensor components."""
    head, _, last = path_str.rpartition("/")
    if not head.endswith("/w"):
        return None
    if last in ("q", "q4"):
        return spec_for_path(head, shape, rules, axis_sizes)
    if last == "scale":
        for pat, spec in rules:
            if re.fullmatch(pat, head):
                cand = (spec[0] if isinstance(spec, list) else spec)
                out_ax = cand[-1] if cand else None
                fixed, _ = _fit_spec((None,) * (len(shape) - 1) + (out_ax,),
                                     shape, axis_sizes)
                return P(*fixed)
        return P()
    return None


def param_shardings(params_tree, mesh: Mesh, rules=None):
    """Map a (shaped) param pytree to NamedShardings via path rules.

    Dims whose size is not divisible by the mesh axis are replicated.
    Quantized trees (QTensor ``q``/``q4``/``scale`` leaves under a ``w``)
    inherit the weight's own rule, so a quantized model shards the same
    way its full-precision parent does."""
    rules = rules or default_param_rules()
    axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))

    def one(path, leaf):
        spec = spec_for_path(_path_str(path), leaf.shape, rules, axis_sizes)
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(one, params_tree)
