"""Synthetic data pipeline.

Offline container -> no real corpora; instead a *learnable* synthetic
language: a fixed random first-order Markov chain over the vocabulary with
low entropy. A model that trains correctly drives loss well below the
unigram entropy, which the end-to-end example asserts. Includes packing
(concatenate docs to fixed-length rows) and an infinite batch iterator.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import numpy as np


@dataclasses.dataclass
class MarkovLM:
    vocab: int
    branching: int = 8          # out-degree per state -> entropy ~= log(branching)
    seed: int = 0

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        self.next_tokens = rng.integers(
            0, self.vocab, size=(self.vocab, self.branching))
        probs = rng.dirichlet(np.ones(self.branching), size=self.vocab)
        self.next_probs = probs

    def sample(self, rng: np.random.Generator, length: int) -> np.ndarray:
        out = np.empty(length, np.int64)
        tok = int(rng.integers(self.vocab))
        for i in range(length):
            out[i] = tok
            j = rng.choice(self.branching, p=self.next_probs[tok])
            tok = int(self.next_tokens[tok, j])
        return out

    def entropy_bound(self) -> float:
        """Per-token conditional entropy (nats) — the loss floor."""
        ent = -np.sum(self.next_probs * np.log(self.next_probs + 1e-12),
                      axis=1)
        return float(np.mean(ent))


def pack_documents(docs, seq_len: int) -> np.ndarray:
    """Concatenate token streams and cut into (N, seq_len) rows."""
    flat = np.concatenate(docs)
    n = len(flat) // seq_len
    return flat[: n * seq_len].reshape(n, seq_len)


def synthetic_batches(vocab: int, batch: int, seq_len: int, *,
                      seed: int = 0, branching: int = 8,
                      frontend: Optional[dict] = None
                      ) -> Iterator[Dict[str, np.ndarray]]:
    """Infinite iterator of {'tokens': (B, L) int32 [, 'embeddings']}."""
    lm = MarkovLM(vocab, branching=branching, seed=seed)
    rng = np.random.default_rng(seed + 1)
    while True:
        toks = np.stack([lm.sample(rng, seq_len) for _ in range(batch)])
        out = {"tokens": toks.astype(np.int32)}
        if frontend is not None:
            out["embeddings"] = rng.normal(
                0, 1, size=(batch, frontend["n_tokens"], frontend["d_embed"])
            ).astype(np.float32)
        yield out


def batches_for(cfg, batch: int, seq_len: int, seed: int = 0):
    """Shape-aware iterator for a ModelConfig (handles vlm/audio fronts)."""
    fe = cfg.frontend
    if fe is not None and cfg.family == "vlm":
        seq_len = seq_len - fe.n_tokens
    frontend = None if fe is None else {"n_tokens": fe.n_tokens,
                                        "d_embed": fe.d_embed}
    return synthetic_batches(cfg.vocab, batch, seq_len, seed=seed,
                             frontend=frontend)
