"""QTensor: the quantized-weight leaf format.

A QTensor is a plain dict pytree (so it flows through jit, scan over
stacked block params, and the npz checkpointing unchanged):

* int8, symmetric per-channel::

      {"q":  int8 (..., K, N),        # round(w / scale)
       "scale": f32 (..., N)}         # max|w| over K, per output column

* int4, symmetric group-wise along K, two values packed per byte::

      {"q4": int8 (..., K//2, N),     # row 2i in the low nibble of
                                      # byte i, row 2i+1 in the high
       "scale": f32 (..., n_groups, N)}

The precision is encoded **structurally** (key ``q`` vs ``q4``), never as
an array, so dispatch is a Python dict-key check that stays static under
tracing. Leading axes (the scanned block axis of stacked layer params)
are carried through: quantization is always over the last two dims
``(K, N) = (d_in, d_out)``.

int4 uses the symmetric range [-7, 7] (not -8) so dequantization is an
exact ``q * scale`` with no zero-point.
"""
from __future__ import annotations

import numpy as np

import jax.numpy as jnp

QTENSOR_KEYS = ("q", "q4")
_EPS = 1e-8


def is_qtensor(x) -> bool:
    return isinstance(x, dict) and "scale" in x \
        and any(k in x for k in QTENSOR_KEYS)


def qtensor_bits(qt) -> int:
    return 4 if "q4" in qt else 8


# --------------------------------------------------------------------- #
# int4 packing: two signed nibbles per int8 byte, paired along K
# --------------------------------------------------------------------- #
def pack_int4(q):
    """q: int (..., K, N) with values in [-8, 7], K even ->
    int8 (..., K//2, N); row 2i in the low nibble, row 2i+1 in the high."""
    K = q.shape[-2]
    assert K % 2 == 0, f"int4 packing needs even K, got {K}"
    pairs = q.astype(jnp.int32).reshape(q.shape[:-2] + (K // 2, 2,
                                                        q.shape[-1]))
    lo, hi = pairs[..., 0, :], pairs[..., 1, :]
    byte = ((hi & 0xF) << 4) | (lo & 0xF)
    return jnp.where(byte >= 128, byte - 256, byte).astype(jnp.int8)


def unpack_int4(packed):
    """int8 (..., K//2, N) -> int32 (..., K, N), sign-extended nibbles."""
    p32 = packed.astype(jnp.int32)
    lo = (p32 << 28) >> 28
    hi = (p32 << 24) >> 28
    Kp, N = packed.shape[-2], packed.shape[-1]
    both = jnp.stack([lo, hi], axis=-2)            # (..., K//2, 2, N)
    return both.reshape(packed.shape[:-2] + (2 * Kp, N))


# --------------------------------------------------------------------- #
# quantize / dequantize one weight
# --------------------------------------------------------------------- #
def quantize_tensor(w, bits: int = 8, group_size: int = 32):
    """w: float (..., K, N) -> QTensor dict.

    int8: per-(output-)channel scale over the full K axis.
    int4: group-wise scale over ``group_size`` rows of K (clamped to a
    divisor of K; falls back to one group if nothing divides).
    """
    wf = jnp.asarray(w, jnp.float32)
    K = wf.shape[-2]
    if bits == 8:
        scale = jnp.maximum(jnp.max(jnp.abs(wf), axis=-2) / 127.0, _EPS)
        q = jnp.clip(jnp.round(wf / scale[..., None, :]), -127, 127)
        return {"q": q.astype(jnp.int8), "scale": scale}
    if bits == 4:
        assert K % 2 == 0, f"int4 needs even d_in, got {K}"
        gs = group_size
        while K % gs:
            gs -= 1                                 # largest divisor <= gs
        ng = K // gs
        wg = wf.reshape(wf.shape[:-2] + (ng, gs, wf.shape[-1]))
        scale = jnp.maximum(jnp.max(jnp.abs(wg), axis=-2) / 7.0, _EPS)
        q = jnp.clip(jnp.round(wg / scale[..., None, :]), -7, 7)
        q = q.reshape(wf.shape).astype(jnp.int32)
        return {"q4": pack_int4(q), "scale": scale}
    raise ValueError(f"unsupported bits={bits}")


def dequantize_tensor(qt, dtype=jnp.float32):
    """QTensor dict -> dense float array (..., K, N)."""
    scale = jnp.asarray(qt["scale"], jnp.float32)
    if "q" in qt:
        w = jnp.asarray(qt["q"]).astype(jnp.float32) * scale[..., None, :]
        return w.astype(dtype)
    q = unpack_int4(jnp.asarray(qt["q4"])).astype(jnp.float32)
    ng, gs = scale.shape[-2], q.shape[-2] // scale.shape[-2]
    wg = q.reshape(q.shape[:-2] + (ng, gs, q.shape[-1]))
    w = (wg * scale[..., None, :]).reshape(q.shape)
    return w.astype(dtype)


def qtensor_nbytes(qt) -> int:
    """Stored bytes (values + scales)."""
    return sum(int(np.prod(v.shape)) * np.dtype(v.dtype).itemsize
               for v in qt.values())
