"""Weight-sharing self-draft construction for speculative decoding.

The edge-deployment story (PAPER.md / arXiv:1805.05995) rules out
shipping a second draft checkpoint to the device; instead the draft is
*derived* from the target's own parameters:

* **precision**: ``int8`` / ``int4`` reuse PR 2's post-training
  quantization — the draft streams a fraction of the target's weight
  bytes per proposed token (the memory-roofline cost of decode);
  ``fp`` keeps the target's own precision (layer-skip-only draft).
* **depth**: ``@k`` keeps only the first ``k`` scan blocks of the
  stacked block params (plus the shared embed/ln_f/lm_head) — the
  stacked-scan layout makes this a single ``t[:k]`` tree-map, no
  re-initialisation. A truncated stack is a classic self-speculative
  draft (Draft&Verify / LayerSkip): early blocks already concentrate
  most next-token information, and whatever they get wrong the verify
  pass rejects, so output quality is untouched.

Spec grammar (``cfg.draft`` / ``Engine(draft=...)`` / ``--draft``):
``"<prec>[@<blocks>]"`` with prec in {fp, int8, int4}, e.g. ``"int8"``
(full depth, quantized) or ``"int8@1"`` (first block only, quantized —
what the "spec" config variant uses at half depth).
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax

from repro.quant.params import quantize_params

_PRECISIONS = ("fp", "int8", "int4")


def parse_draft_spec(spec: str) -> Tuple[str, Optional[int]]:
    """"int8@1" -> ("int8", 1); "fp" -> ("fp", None = full depth)."""
    prec, _, blocks = spec.partition("@")
    if prec == "ngram":
        # the prompt-lookup drafter is not a self-draft: it has no
        # params to derive. The engine intercepts the spec before ever
        # reaching this parser (serving/ngram_draft.py)
        raise ValueError(
            "draft spec 'ngram' selects the prompt-lookup drafter, "
            "which has no self-draft parameters — pass it to "
            "Engine(draft='ngram') / --draft ngram, not to "
            "make_self_draft")
    if prec not in _PRECISIONS:
        raise ValueError(f"draft precision {prec!r} not in {_PRECISIONS} "
                         f"(spec {spec!r})")
    nb = None
    if blocks:
        nb = int(blocks)
        if nb < 1:
            raise ValueError(f"draft depth must be >= 1, got {spec!r}")
    return prec, nb


def make_self_draft(model, params, spec: str = ""):
    """Derive (draft_model, draft_params) from the target model + params.

    ``spec`` defaults to ``model.cfg.draft``. The draft params *share*
    every leaf they can with the target (embeddings, norms, and — for
    full-depth fp drafts — everything): quantized leaves are new int
    buffers by construction, but no float weight is ever copied.
    Already-quantized targets (served with ``cfg.quant``) pass through
    unchanged — ``quantize_params`` skips QTensor leaves — so an int8
    target with an ``int8`` draft spec shares the quantized tree too.
    """
    from repro.models.model import build
    from repro.models.transformer import block_spec, n_blocks

    cfg = model.cfg
    spec = spec or cfg.draft
    if not spec:
        raise ValueError("empty draft spec (set cfg.draft or pass spec=)")
    prec, nb = parse_draft_spec(spec)
    nb_total = n_blocks(cfg)
    nb = nb_total if nb is None else min(nb, nb_total)

    if nb < nb_total:
        # unroll the (shallow) draft stack: for a 1-2 block draft the
        # lax.scan loop/slicing machinery costs more per decode than the
        # blocks themselves on small configs; same math either way
        dcfg = cfg.replace(name=f"{cfg.name}-draft-{spec}",
                           n_layers=nb * len(block_spec(cfg)),
                           draft="", spec_gamma=0,
                           unroll_layers=nb <= 2 or cfg.unroll_layers)
        dmodel = build(dcfg)
        dparams = dict(params)
        dparams["blocks"] = jax.tree.map(lambda t: t[:nb],
                                         params["blocks"])
    else:
        dmodel = build(cfg.replace(draft="", spec_gamma=0))
        dparams = params

    if prec in ("int8", "int4"):
        bits = 8 if prec == "int8" else 4
        dparams = quantize_params(dparams, bits=bits,
                                  group_size=cfg.quant_group)
    return dmodel, dparams
