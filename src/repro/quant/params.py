"""Param-tree quantization: walk the nested-dict param trees and replace
eligible projection weights with QTensor dicts.

Eligibility is structural: every ``init_linear`` weight sits at key
``"w"`` inside its own sub-dict, so quantizing ``{"w": array}`` leaves
covers q/k/v/o projections, MLP and shared-expert projections, SSM
in/out projections, enc-dec cross-attention, the frontend projector and
the LM head — across every stack — while leaving norms, biases, conv
kernels, embeddings (``"table"``, a lookup not a matmul) and the stacked
MoE expert einsum weights (``wi``/``wg``/``wo`` arrays, routed through
einsum not ``linear``) in full precision. Router weights are skipped by
default: a flipped top-k there changes *which* expert runs, a much
larger error than quantizing the expert itself.

Because stacked block params carry a leading scan axis, quantization
treats the last two dims as ``(d_in, d_out)`` and broadcasts over the
rest; ``lax.scan`` then slices ``q``/``scale`` per block exactly like it
sliced the dense weight.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import numpy as np

import jax.numpy as jnp

from repro.quant.qtensor import (dequantize_tensor, is_qtensor,
                                 qtensor_nbytes, quantize_tensor)

SKIP_KEYS = ("router",)


def _eligible(val, min_size: int) -> bool:
    return hasattr(val, "shape") and hasattr(val, "dtype") \
        and jnp.issubdtype(jnp.asarray(val).dtype, jnp.floating) \
        and val.ndim >= 2 and int(np.prod(val.shape[-2:])) >= min_size


def quantize_params(params, bits: int = 8, group_size: int = 32,
                    min_size: int = 0, skip: Tuple[str, ...] = SKIP_KEYS):
    """Replace eligible ``{"w": array}`` leaves with QTensor dicts.

    ``bits``: 8 (per-channel) or 4 (group-wise packed; odd d_in leaves
    fall back to int8). ``min_size``: smallest (d_in * d_out) worth
    quantizing. ``skip``: sub-tree keys left untouched.
    """
    if bits not in (8, 4):
        raise ValueError(f"bits must be 8 or 4, got {bits}")

    def walk(node):
        if not isinstance(node, dict) or is_qtensor(node):
            return node
        out = {}
        for k, v in node.items():
            if k in skip:
                out[k] = v
            elif k == "w" and _eligible(v, min_size):
                b = bits if (bits == 8 or v.shape[-2] % 2 == 0) else 8
                out[k] = quantize_tensor(v, bits=b, group_size=group_size)
            elif isinstance(v, dict):
                out[k] = walk(v)
            else:
                out[k] = v
        return out

    return walk(params)


def dequantize_params(params, dtype=None):
    """Inverse walk: QTensor leaves -> dense arrays (jit-safe, so it can
    run inside a compiled program — dequantize-on-the-fly deployment)."""
    def walk(node):
        if is_qtensor(node):
            return dequantize_tensor(node, dtype or jnp.float32)
        if isinstance(node, dict):
            return {k: walk(v) for k, v in node.items()}
        return node
    return walk(params)


def quantize_for_cfg(params, cfg):
    """The single ``cfg.quant`` knob: '' -> identity, 'int8'/'int4' ->
    quantized tree with ``cfg.quant_group`` group size."""
    if not cfg.quant:
        return params
    bits = {"int8": 8, "int4": 4}[cfg.quant]
    return quantize_params(params, bits=bits, group_size=cfg.quant_group)


# --------------------------------------------------------------------- #
# accounting
# --------------------------------------------------------------------- #
def quantized_stats(params) -> Dict[str, int]:
    """Bytes of the projection ("w") weights — dense or quantized — plus
    leaf counts and the whole-tree total, for the bench's bytes report."""
    import jax
    stats = {"weight_bytes": 0, "n_quantized": 0, "n_dense": 0,
             "total_bytes": sum(
                 int(np.prod(x.shape)) * np.dtype(x.dtype).itemsize
                 for x in jax.tree.leaves(params))}

    def walk(node):
        if is_qtensor(node):
            stats["weight_bytes"] += qtensor_nbytes(node)
            stats["n_quantized"] += 1
            return
        if isinstance(node, dict):
            for k, v in node.items():
                if k == "w" and not isinstance(v, dict) \
                        and hasattr(v, "shape"):
                    stats["weight_bytes"] += int(np.prod(v.shape)) \
                        * np.dtype(v.dtype).itemsize
                    stats["n_dense"] += 1
                elif isinstance(v, dict):
                    walk(v)

    walk(params)
    return stats


# --------------------------------------------------------------------- #
# save / load (npz round-trip through the existing checkpointing)
# --------------------------------------------------------------------- #
def save_quantized(path, qparams, extra: Optional[dict] = None) -> str:
    """QTensor trees are plain nested dicts, so the content-addressed npz
    checkpoint handles them as-is; tag the manifest for tooling."""
    from repro.training.checkpoints import save_pytree
    meta = {"format": "qtensor"}
    meta.update(extra or {})
    return save_pytree(path, qparams, extra=meta)


def load_quantized(path, verify: bool = True):
    from repro.training.checkpoints import load_pytree
    return load_pytree(path, verify=verify)
