"""Post-training quantization subsystem (edge deployment).

The paper's follow-up (arXiv:1805.05995) makes model compression an
explicit step of deploying composed services on edge devices; this
package provides the repo's weight + KV-cache quantization:

* ``qtensor``  — the on-device quantized tensor format (``QTensor`` dict
  pytrees: symmetric per-channel int8, group-wise packed int4) with
  pack/unpack and quantize/dequantize primitives.
* ``params``   — whole-param-tree quantization (walks the nested-dict
  param trees produced by ``models/``), save/load round-trip through the
  existing npz checkpointing, and byte accounting.
* ``self_draft`` — weight-sharing speculative-decoding drafts derived
  from the target's own params (precision via PTQ, depth via slicing
  the stacked scan blocks); consumed by ``serving.Engine(draft=...)``.

Quantized projections route through ``kernels/quant_matmul`` via
``models.layers.linear`` (structural dispatch: a ``{"q"| "q4", "scale"}``
dict where a weight array used to be), so every stack — transformer,
SSM, MoE, enc-dec — works quantized without model changes. The int8
KV-cache lives in ``models.layers.make_kv_cache(quant=True)`` and is
switched from serving via ``Engine(kv_cache_dtype="int8")``.
"""
from repro.quant.qtensor import (QTENSOR_KEYS, dequantize_tensor,
                                 is_qtensor, pack_int4, qtensor_bits,
                                 qtensor_nbytes, quantize_tensor,
                                 unpack_int4)
from repro.quant.params import (dequantize_params, load_quantized,
                                quantize_for_cfg, quantize_params,
                                quantized_stats, save_quantized)
from repro.quant.self_draft import make_self_draft, parse_draft_spec

__all__ = [
    "QTENSOR_KEYS", "dequantize_tensor", "is_qtensor", "pack_int4",
    "qtensor_bits", "qtensor_nbytes", "quantize_tensor", "unpack_int4",
    "dequantize_params", "load_quantized", "quantize_for_cfg",
    "quantize_params", "quantized_stats", "save_quantized",
    "make_self_draft", "parse_draft_spec",
]
