"""granite-moe-3b-a800m: 40 experts top-8
[hf:ibm-granite/granite-3.0-1b-a400m-base family]."""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="granite-moe-3b-a800m", family="moe",
    n_layers=32, d_model=1536, n_heads=24, n_kv_heads=8,
    d_ff=512, vocab=49155, rope=True,
    moe=MoEConfig(n_experts=40, top_k=8, d_expert=512),
    source="hf:ibm-granite/granite-3.0-1b-a400m-base",
)
