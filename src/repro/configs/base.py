"""Configuration schema for the repro framework.

Every assigned architecture is expressed as a frozen ``ModelConfig``; input
shapes are ``ShapeConfig``. Configs are pure data — building a model from a
config happens in :mod:`repro.models.model`.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple

import jax.numpy as jnp


@dataclass(frozen=True)
class MoEConfig:
    """Mixture-of-experts FFN configuration."""

    n_experts: int                  # routed experts
    top_k: int
    d_expert: int                   # hidden dim of each routed expert
    n_shared: int = 0               # always-on shared experts
    d_shared: int = 0               # hidden dim of the shared expert block
    capacity_factor: float = 1.25   # tokens-per-expert capacity multiplier
    router_jitter: float = 0.0
    moe_every: int = 1              # 1 = every layer is MoE; 2 = alternate
    aux_loss_weight: float = 0.01   # load-balance auxiliary loss
    group_routing: bool = False     # route within per-row token groups
                                    # (data-local; kills the global gather)


@dataclass(frozen=True)
class SSMConfig:
    """Mamba-2 (SSD) mixer configuration."""

    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    n_groups: int = 1
    chunk: int = 256                # SSD chunk length (dual form)

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def n_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclass(frozen=True)
class EncoderConfig:
    """Encoder stack for encoder–decoder models (same d_model as decoder)."""

    n_layers: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    # Encoder consumes frontend embeddings; no embedding table of its own.


@dataclass(frozen=True)
class FrontendConfig:
    """Modality frontend STUB (the one allowed carve-out).

    ``input_specs`` provides precomputed frame/patch embeddings of shape
    ``(batch, n_tokens, d_embed)``; a learned linear projector maps
    ``d_embed -> d_model``.
    """

    kind: str                       # "vision" | "audio"
    n_tokens: int                   # patches / frames per example
    d_embed: int                    # embedding dim produced by the stub


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                     # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0               # 0 -> d_model // n_heads
    rope: bool = True
    rope_theta: float = 10_000.0
    qkv_bias: bool = False
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    attn_every: int = 0             # hybrid: one attn layer per this many
    sliding_window: int = 0         # 0 = full attention
    attn_block: int = 0             # >0: chunked causal attention (skip
                                    # above-diagonal blocks, flash-style)
    kv_quant: bool = False          # int8 KV cache (per-slot-head scales)
    quant: str = ""                 # weight-only PTQ: "" | "int8" | "int4"
                                    # (the single knob quantize_for_cfg and
                                    # the edge variant key off)
    quant_group: int = 32           # int4 group size along d_in
    use_decode_kernel: bool = False  # route cached decode attention through
                                     # kernels/decode_attention (Pallas-ready
                                     # layout; reference path by default)
    prefill_chunk: int = 0          # continuous batching: fuse at most
                                    # this many prompt tokens of one
                                    # admitting request into every decode
                                    # step (Sarathi-style chunked prefill;
                                    # 0 = a single max-size chunk — the
                                    # whole prompt in one fused extend,
                                    # which stalls decode for its
                                    # duration). Engine knob mirror:
                                    # Engine(prefill_chunk=...)
    prefix_cache_tokens: int = 0    # shared-prefix KV reuse budget in
                                    # tokens (LRU trie of chunk-aligned
                                    # prompt prefixes; 0 = off). Requires
                                    # prefill_chunk > 0
    mesh: str = ""                  # tensor-parallel serving mesh spec:
                                    # "" = single-device; "auto" = all
                                    # local devices on the model axis;
                                    # "dp,mp" (e.g. "2,4") = explicit
                                    # (data, model) axis sizes. Engine
                                    # knob mirror: Engine(mesh=...)
    draft: str = ""                 # speculative-decoding draft spec:
                                    # "" = off; "<prec>[@<blocks>]" builds a
                                    # weight-sharing self-draft from the
                                    # target's own params, prec in
                                    # fp|int8|int4, @k = first k scan blocks
                                    # (e.g. "int8@1"); see quant.self_draft
    spec_gamma: int = 0             # draft tokens proposed per spec step
                                    # (0 = no speculative decoding)
    encoder: Optional[EncoderConfig] = None
    frontend: Optional[FrontendConfig] = None
    dtype: str = "bfloat16"         # activation dtype
    param_dtype: str = "bfloat16"
    remat: bool = False             # activation checkpointing per layer/block
    unroll_layers: bool = False     # python-unroll the layer stack (exact
                                    # cost analysis; used by calibration)
    source: str = ""                # citation for the architecture

    # ------------------------------------------------------------------ #
    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def act_dtype(self):
        return jnp.dtype(self.dtype)

    @property
    def p_dtype(self):
        return jnp.dtype(self.param_dtype)

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def has_decoder_cache(self) -> bool:
        return True  # all assigned families are autoregressive decoders

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # Reduced variant used by smoke tests (2 layers, d_model<=512, <=4 experts)
    def reduced(self) -> "ModelConfig":
        d_model = min(self.d_model, 256)
        n_heads = min(self.n_heads, 4)
        if n_heads:
            n_kv = max(1, min(self.n_kv_heads, n_heads))
            while n_heads % n_kv:
                n_kv -= 1
        else:
            n_kv = 0  # attention-free (ssm)
        kw = dict(
            name=self.name + "-reduced",
            n_layers=2,
            d_model=d_model,
            n_heads=n_heads,
            n_kv_heads=n_kv,
            d_ff=min(self.d_ff, 512) or 0,
            vocab=min(self.vocab, 1024),
            head_dim=(d_model // n_heads) if n_heads else 1,
            dtype="float32",
            param_dtype="float32",
        )
        if self.moe is not None:
            kw["moe"] = dataclasses.replace(
                self.moe,
                n_experts=min(self.moe.n_experts, 4),
                top_k=min(self.moe.top_k, 2),
                d_expert=min(self.moe.d_expert, 128),
                n_shared=min(self.moe.n_shared, 1),
                d_shared=min(self.moe.d_shared, 128),
            )
        if self.ssm is not None:
            kw["ssm"] = dataclasses.replace(
                self.ssm, d_state=32, head_dim=32, chunk=32)
        if self.encoder is not None:
            kw["encoder"] = EncoderConfig(
                n_layers=2, n_heads=n_heads, n_kv_heads=n_kv,
                d_ff=min(self.encoder.d_ff, 512))
        if self.frontend is not None:
            kw["frontend"] = dataclasses.replace(
                self.frontend, n_tokens=16, d_embed=64)
        if self.attn_every:
            kw["n_layers"] = self.attn_every  # one full super-block
        return self.replace(**kw)


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    mode: str                       # train | prefill | decode

    @property
    def is_decode(self) -> bool:
        return self.mode == "decode"


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


def param_count(cfg: ModelConfig) -> int:
    """Analytic parameter count (used for 6ND model-FLOPs in roofline)."""
    d, hd = cfg.d_model, cfg.hd
    emb = cfg.vocab * d * (1 if cfg.tie_embeddings else 2)

    def attn_params() -> int:
        p = d * (cfg.n_heads * hd) + 2 * d * (cfg.n_kv_heads * hd) \
            + (cfg.n_heads * hd) * d
        if cfg.qkv_bias:
            p += (cfg.n_heads + 2 * cfg.n_kv_heads) * hd
        return p

    def mlp_params(d_ff: int) -> int:
        return 3 * d * d_ff  # gated SwiGLU

    def moe_params(m: MoEConfig) -> Tuple[int, int]:
        total = m.n_experts * 3 * d * m.d_expert + d * m.n_experts
        active = m.top_k * 3 * d * m.d_expert + d * m.n_experts
        if m.n_shared:
            shared = 3 * d * (m.d_shared or m.d_expert * m.n_shared)
            total += shared
            active += shared
        return total, active

    def ssm_params(s: SSMConfig) -> int:
        d_in = s.d_inner(d)
        nh = s.n_heads(d)
        # in_proj -> [z, x, B, C, dt], conv, out_proj, A, D, dt_bias, norm
        proj_out = 2 * d_in + 2 * s.n_groups * s.d_state + nh
        return d * proj_out + (d_in + 2 * s.n_groups * s.d_state) * s.d_conv \
            + d_in * d + 3 * nh + d_in

    total = emb
    per_layer_norms = 2 * d
    if cfg.family in ("dense", "vlm"):
        total += cfg.n_layers * (attn_params() + mlp_params(cfg.d_ff)
                                 + per_layer_norms)
    elif cfg.family == "moe":
        mt, _ = moe_params(cfg.moe)
        total += cfg.n_layers * (attn_params() + mt + per_layer_norms)
    elif cfg.family == "ssm":
        total += cfg.n_layers * (ssm_params(cfg.ssm) + per_layer_norms // 2)
    elif cfg.family == "hybrid":
        n_attn = cfg.n_layers // cfg.attn_every
        n_ssm = cfg.n_layers - n_attn
        total += n_attn * attn_params() + n_ssm * ssm_params(cfg.ssm)
        if cfg.moe is not None:
            mt, _ = moe_params(cfg.moe)
            n_moe = cfg.n_layers // max(1, cfg.moe.moe_every)
            total += n_moe * mt + (cfg.n_layers - n_moe) * mlp_params(cfg.d_ff)
        else:
            total += cfg.n_layers * mlp_params(cfg.d_ff)
        total += cfg.n_layers * per_layer_norms
    elif cfg.family == "encdec":
        enc = cfg.encoder
        enc_hd = d // enc.n_heads
        enc_attn = d * (enc.n_heads * enc_hd) + 2 * d * (enc.n_kv_heads * enc_hd) \
            + (enc.n_heads * enc_hd) * d
        total += enc.n_layers * (enc_attn + mlp_params(enc.d_ff) + per_layer_norms)
        # decoder: self-attn + cross-attn + mlp
        total += cfg.n_layers * (2 * attn_params() + mlp_params(cfg.d_ff)
                                 + 3 * d)
    if cfg.frontend is not None:
        total += cfg.frontend.d_embed * d
    return total


def active_param_count(cfg: ModelConfig) -> int:
    """Active params per token (MoE uses top-k experts only)."""
    if cfg.moe is None:
        return param_count(cfg)
    full = param_count(cfg)
    m = cfg.moe
    d = cfg.d_model
    per_moe_layer_total = m.n_experts * 3 * d * m.d_expert
    per_moe_layer_active = m.top_k * 3 * d * m.d_expert
    if cfg.family == "moe":
        n_moe = cfg.n_layers
    else:
        n_moe = cfg.n_layers // max(1, m.moe_every)
    return full - n_moe * (per_moe_layer_total - per_moe_layer_active)
