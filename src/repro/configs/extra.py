"""Stretch architectures beyond the assigned ten (same public pool).

These exercise the existing family machinery with different regimes:
mixtral-8x7b (few large experts vs qwen2-moe's many small) and a
gemma2-9b-class dense model (global sliding window — every layer SWA).
Selectable via ``get_arch`` but kept OUT of ``ARCHS`` so the mandated
10x4 dry-run grid stays exactly as assigned.
"""
from repro.configs.base import ModelConfig, MoEConfig

EXTRA_ARCHS = {
    "mixtral-8x7b": ModelConfig(
        name="mixtral-8x7b", family="moe",
        n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
        d_ff=14336, vocab=32000, rope=True,
        moe=MoEConfig(n_experts=8, top_k=2, d_expert=14336),
        source="arXiv:2401.04088",
    ),
    "gemma2-9b-class": ModelConfig(
        name="gemma2-9b-class", family="dense",
        n_layers=42, d_model=3584, n_heads=16, n_kv_heads=8,
        d_ff=14336, vocab=256128, rope=True, head_dim=256,
        sliding_window=4096,   # windowed attention as the default regime
        tie_embeddings=True,
        source="arXiv:2408.00118",
    ),
}
