"""internlm2-20b: dense GQA decoder [arXiv:2403.17297]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internlm2-20b", family="dense",
    n_layers=48, d_model=6144, n_heads=48, n_kv_heads=8,
    d_ff=16384, vocab=92544, rope=True,
    sliding_window=0,  # long_500k uses the swa variant (see variants)
    source="arXiv:2403.17297",
)
