"""seamless-m4t-medium: audio encoder-decoder backbone [arXiv:2308.11596].

The speech frontend (mel + conv) is a stub; the encoder consumes
precomputed frame embeddings.
"""
from repro.configs.base import EncoderConfig, FrontendConfig, ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium", family="encdec",
    n_layers=12, d_model=1024, n_heads=16, n_kv_heads=16,
    d_ff=4096, vocab=256206, rope=True,
    encoder=EncoderConfig(n_layers=12, n_heads=16, n_kv_heads=16, d_ff=4096),
    frontend=FrontendConfig(kind="audio", n_tokens=1024, d_embed=1024),
    source="arXiv:2308.11596",
)
