"""mamba2-780m: attention-free SSD [arXiv:2405.21060]."""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-780m", family="ssm",
    n_layers=48, d_model=1536, n_heads=0, n_kv_heads=0,
    d_ff=0, vocab=50280, rope=False, head_dim=1,
    ssm=SSMConfig(d_state=128, d_conv=4, expand=2, head_dim=64,
                  n_groups=1, chunk=256),
    source="arXiv:2405.21060",
)
