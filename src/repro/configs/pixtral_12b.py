"""pixtral-12b: ViT frontend stub + mistral-nemo-class decoder
[hf:mistralai/Pixtral-12B-2409]."""
from repro.configs.base import FrontendConfig, ModelConfig

CONFIG = ModelConfig(
    name="pixtral-12b", family="vlm",
    n_layers=40, d_model=5120, n_heads=32, n_kv_heads=8,
    d_ff=14336, vocab=131072, rope=True, head_dim=160,
    frontend=FrontendConfig(kind="vision", n_tokens=1024, d_embed=1024),
    source="hf:mistralai/Pixtral-12B-2409",
)
