"""jamba-1.5-large-398b: hybrid Mamba+attention 1:7 interleave with MoE
16e top-2 every other layer [arXiv:2403.19887]."""
from repro.configs.base import ModelConfig, MoEConfig, SSMConfig

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b", family="hybrid",
    n_layers=72, d_model=8192, n_heads=64, n_kv_heads=8,
    d_ff=24576, vocab=65536, rope=False,  # Jamba uses no positional encoding
    attn_every=8,
    moe=MoEConfig(n_experts=16, top_k=2, d_expert=24576, moe_every=2),
    ssm=SSMConfig(d_state=128, d_conv=4, expand=2, head_dim=128,
                  n_groups=1, chunk=256),
    source="arXiv:2403.19887",
)
