"""Architecture registry: --arch <id> resolves through ``ARCHS``."""
from repro.configs.base import (EncoderConfig, FrontendConfig, ModelConfig,
                                MoEConfig, SHAPES, ShapeConfig, SSMConfig,
                                active_param_count, param_count)

from repro.configs.internlm2_20b import CONFIG as _internlm2
from repro.configs.seamless_m4t_medium import CONFIG as _seamless
from repro.configs.starcoder2_15b import CONFIG as _starcoder2
from repro.configs.qwen2_5_14b import CONFIG as _qwen25
from repro.configs.qwen2_moe_a2_7b import CONFIG as _qwen2moe
from repro.configs.pixtral_12b import CONFIG as _pixtral
from repro.configs.llama3_2_1b import CONFIG as _llama32
from repro.configs.granite_moe_3b_a800m import CONFIG as _granite
from repro.configs.mamba2_780m import CONFIG as _mamba2
from repro.configs.jamba_1_5_large_398b import CONFIG as _jamba

from repro.configs.extra import EXTRA_ARCHS

ARCHS = {c.name: c for c in [
    _internlm2, _seamless, _starcoder2, _qwen25, _qwen2moe,
    _pixtral, _llama32, _granite, _mamba2, _jamba,
]}


def get_arch(name: str, *, variant: str = "") -> ModelConfig:
    """Resolve an architecture id, optionally with "+"-composable variant
    suffixes (applied left to right).

    variants: "swa" -> sliding-window attention (window 4096) for
    sub-quadratic long-context decode on dense archs; "reduced" -> smoke
    config; "edge" -> the edge-deployment profile (int4 weight-only
    quantization + int8 KV cache — what fits a memory-bound local
    device), e.g. ``get_arch("llama3.2-1b", variant="edge")`` or
    ``"reduced+edge"`` for the smoke-sized edge model; "spec" ->
    speculative decoding with an int8 half-depth self-draft at
    gamma=4 (``cfg.draft`` / ``cfg.spec_gamma``), e.g.
    ``"reduced+spec"`` for the smoke-sized speculative server;
    "continuous" -> continuous batching (chunked prefill fused into the
    decode step, ``prefill_chunk=64``, plus an 8k-token shared-prefix KV
    reuse budget), e.g. ``"reduced+continuous"`` or ``"edge+continuous"``
    for the edge profile that also never stalls decode behind a long
    prompt; "sharded" -> tensor-parallel serving over every local
    device (``cfg.mesh="auto"``: weights, KV heads and decode state
    sharded over a ("data", "model") mesh — how a 15B-398B config fits
    device memory at all), e.g. ``"reduced+sharded"`` or
    ``"sharded+continuous"``; pick an explicit layout with
    ``serve.py --mesh dp,mp``.
    """
    cfg = ARCHS.get(name) or EXTRA_ARCHS[name]
    for v in filter(None, variant.split("+")):
        if v == "swa":
            cfg = cfg.replace(name=cfg.name + "-swa", sliding_window=4096)
        elif v == "reduced":
            cfg = cfg.reduced()
        elif v == "edge":
            cfg = cfg.replace(name=cfg.name + "-edge", quant="int4",
                              kv_quant=True)
        elif v == "sharded":
            cfg = cfg.replace(name=cfg.name + "-sharded", mesh="auto")
        elif v == "continuous":
            cfg = cfg.replace(name=cfg.name + "-cont",
                              prefill_chunk=cfg.prefill_chunk or 64,
                              prefix_cache_tokens=cfg.prefix_cache_tokens
                              or 8192)
        elif v == "spec":
            # half-depth int8 self-draft: weight-sharing, no second
            # checkpoint — the edge-deployment speculative profile
            from repro.models.transformer import n_blocks
            nb = max(1, n_blocks(cfg) // 2)
            cfg = cfg.replace(name=cfg.name + "-spec",
                              draft=f"int8@{nb}", spec_gamma=4)
        else:
            raise ValueError(f"unknown variant {v!r}")
    return cfg
