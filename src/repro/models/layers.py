"""Core neural-net layers: norms, RoPE, GQA attention, gated MLP, embeddings.

Pure-functional style: each layer is an ``init_*`` returning a param pytree
and an ``apply`` function. Layer stacks are scanned (params stacked on a
leading layer axis) so HLO size is depth-independent.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.distribution.sharding import shard_activation

NEG_INF = -1e30


# --------------------------------------------------------------------- #
# init helpers
# --------------------------------------------------------------------- #
def _normal(key, shape, dtype, scale=0.02):
    return (scale * jax.random.normal(key, shape, jnp.float32)).astype(dtype)


def init_linear(key, d_in, d_out, dtype, bias=False):
    p = {"w": _normal(key, (d_in, d_out), dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def linear(p, x):
    """Dense or quantized projection. Quantization is structural: when
    ``quant.quantize_params`` has replaced ``p["w"]`` with a QTensor dict
    (``{"q"|"q4", "scale"}``), the matmul routes through the fused
    dequantize-matmul op — the dict-key check is static under tracing, so
    every stack (attention, MLP, SSM projections, enc-dec, frontend, LM
    head) works quantized with no caller changes."""
    w = p["w"]
    if isinstance(w, dict):
        from repro.kernels.quant_matmul.ops import quant_matmul
        y = quant_matmul(x, w)
    else:
        y = x @ w
    if "b" in p:
        y = y + p["b"]
    return y


def init_rms_norm(d, dtype):
    return {"scale": jnp.ones((d,), dtype)}


def rms_norm(p, x, eps=1e-5):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)).astype(x.dtype)


def init_embedding(key, vocab, d, dtype):
    return {"table": _normal(key, (vocab, d), dtype, scale=0.02)}


def embed(p, ids):
    return jnp.take(p["table"], ids, axis=0)


def unembed(p, x):
    return x @ p["table"].T


# --------------------------------------------------------------------- #
# RoPE
# --------------------------------------------------------------------- #
def rope_cos_sin(positions, head_dim, theta):
    """positions: int array (...,) -> cos/sin of shape (..., head_dim/2)."""
    half = head_dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    angles = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(angles), jnp.sin(angles)


def apply_rope(x, positions, theta):
    """x: (B, L, H, hd), positions: (L,) or (B, L)."""
    cos, sin = rope_cos_sin(positions, x.shape[-1], theta)
    cos, sin = cos[..., None, :], sin[..., None, :]   # head axis
    while cos.ndim < x.ndim:                          # leading batch axis
        cos, sin = cos[None], sin[None]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------- #
# attention core (GQA, causal / sliding-window / cross)
# --------------------------------------------------------------------- #
def gqa_attention(q, k, v, *, q_positions=None, k_positions=None,
                  causal=True, window=0, k_valid=None):
    """Grouped-query attention.

    q: (B, Lq, Hq, hd); k, v: (B, Lk, Hkv, hd). Hq % Hkv == 0.
    q_positions: (Lq,) or (B, Lq) absolute positions of the queries.
    k_positions: (Lk,) or (B, Lk) absolute positions of the keys.
    window: 0 = full; else keys with kpos < qpos - window + 1 are masked.
    k_valid: optional (B, Lk) or (Lk,) bool mask of valid cache slots.
    """
    B, Lq, Hq, hd = q.shape
    Lk, Hkv = k.shape[1], k.shape[2]
    group = Hq // Hkv
    # keep operands in model dtype; accumulate on the MXU in f32
    # (avoids converting/duplicating the whole KV cache to f32 in HBM)
    qg = q.reshape(B, Lq, Hkv, group, hd)
    scores = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k,
                        preferred_element_type=jnp.float32) / jnp.sqrt(hd)

    mask = None
    if causal or window:
        if q_positions is None:
            q_positions = jnp.arange(Lq)
        if k_positions is None:
            k_positions = jnp.arange(Lk)
        qp = q_positions if q_positions.ndim == 2 else q_positions[None]
        kp = k_positions if k_positions.ndim == 2 else k_positions[None]
        m = kp[:, None, :] <= qp[:, :, None] if causal else \
            jnp.ones((1, Lq, Lk), bool)
        if window:
            m = m & (kp[:, None, :] > qp[:, :, None] - window)
        mask = m
    if k_valid is not None:
        kv = k_valid if k_valid.ndim == 2 else k_valid[None]
        valid = kv[:, None, :]
        mask = valid if mask is None else (mask & valid)
    if mask is not None:
        scores = jnp.where(mask[:, None, None, :, :], scores, NEG_INF)

    probs = jax.nn.softmax(scores, axis=-1)          # f32
    out = jnp.einsum("bhgqk,bkhd->bqhgd", probs.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, Lq, Hq, hd).astype(q.dtype)


def chunked_causal_attention(q, k, v, *, block, positions=None, window=0):
    """Block-tiled causal attention (the jnp analogue of the Pallas flash
    kernel's above-diagonal tile skipping): query block i only attends to
    the KV prefix it can see, so score FLOPs and live memory are ~halved
    (and window-bounded under SWA). q: (B, L, Hq, hd); k, v: (B, L, Hkv, hd).
    """
    B, L, Hq, hd = q.shape
    block = min(block, L)
    assert L % block == 0, (L, block)
    nq = L // block
    if positions is None:
        positions = jnp.arange(L)
    outs = []
    for i in range(nq):
        q_blk = q[:, i * block:(i + 1) * block]
        q_pos = positions[i * block:(i + 1) * block]
        start = 0
        if window:
            start = max(0, (i * block - window + 1) // block) * block
        end = (i + 1) * block
        out = gqa_attention(q_blk, k[:, start:end], v[:, start:end],
                            q_positions=q_pos,
                            k_positions=positions[start:end],
                            causal=True, window=window)
        outs.append(out)
    return jnp.concatenate(outs, axis=1)


# --------------------------------------------------------------------- #
# attention block with KV cache
# --------------------------------------------------------------------- #
def init_attention(key, cfg: ModelConfig, *, n_heads=None, n_kv_heads=None):
    n_heads = n_heads or cfg.n_heads
    n_kv_heads = n_kv_heads or cfg.n_kv_heads
    d, hd = cfg.d_model, cfg.hd
    ks = jax.random.split(key, 4)
    return {
        "wq": init_linear(ks[0], d, n_heads * hd, cfg.p_dtype, cfg.qkv_bias),
        "wk": init_linear(ks[1], d, n_kv_heads * hd, cfg.p_dtype, cfg.qkv_bias),
        "wv": init_linear(ks[2], d, n_kv_heads * hd, cfg.p_dtype, cfg.qkv_bias),
        "wo": init_linear(ks[3], n_heads * hd, d, cfg.p_dtype),
    }


def make_kv_cache(batch, length, n_kv_heads, hd, dtype, quant=False):
    """Cache pytree. ``pos`` holds the absolute position stored in each
    slot (-1 = empty) enabling both full and ring-buffer (sliding window)
    use; ``step`` is each sequence's token count — per batch row, so a
    serving engine can run sequences at different offsets in one batch.
    quant=True stores K/V as int8 with per-(slot, head) scales — halves
    the memory-roofline cost of long-cache decode."""
    c = {
        "k": jnp.zeros((batch, length, n_kv_heads, hd),
                       jnp.int8 if quant else dtype),
        "v": jnp.zeros((batch, length, n_kv_heads, hd),
                       jnp.int8 if quant else dtype),
        "pos": jnp.full((batch, length), -1, jnp.int32),
        "step": jnp.zeros((batch,), jnp.int32),
    }
    if quant:
        c["k_scale"] = jnp.zeros((batch, length, n_kv_heads), jnp.float32)
        c["v_scale"] = jnp.zeros((batch, length, n_kv_heads), jnp.float32)
    return c


def make_paged_kv_cache(batch, length, n_kv_heads, hd, dtype, *, page_size,
                        num_pages, quant=False):
    """Paged cache pytree (see ``serving/paged_kv.py``): K/V live in a
    fixed pool of ``num_pages`` pages of ``page_size`` positions shared
    by all slots, and each slot maps logical blocks to pool pages via
    its block-table row ``bt``. Pool index ``num_pages`` is a trash
    page: unallocated ``bt`` entries point at it so gathers stay
    in-bounds (junk masked by ``pos == -1``) and masked-off writes land
    there harmlessly. ``pos``/``step`` keep the contiguous layout's
    dense per-slot shape — causal masking, rollback and ring semantics
    are unchanged; only K/V storage is paged."""
    nb = -(-int(length) // int(page_size))
    S = nb * int(page_size)
    c = {
        "kp": jnp.zeros((num_pages + 1, page_size, n_kv_heads, hd),
                        jnp.int8 if quant else dtype),
        "vp": jnp.zeros((num_pages + 1, page_size, n_kv_heads, hd),
                        jnp.int8 if quant else dtype),
        "bt": jnp.full((batch, nb), num_pages, jnp.int32),
        "pos": jnp.full((batch, S), -1, jnp.int32),
        "step": jnp.zeros((batch,), jnp.int32),
    }
    if quant:
        c["kp_scale"] = jnp.zeros((num_pages + 1, page_size, n_kv_heads),
                                  jnp.float32)
        c["vp_scale"] = jnp.zeros((num_pages + 1, page_size, n_kv_heads),
                                  jnp.float32)
    return c


def _quantize_kv(x):
    """x: (..., hd) -> (int8 values, per-vector scale)."""
    scale = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1) / 127.0
    scale = jnp.maximum(scale, 1e-8)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]),
                 -127, 127).astype(jnp.int8)
    return q, scale


def _dequantize_kv(q, scale, dtype):
    return (q.astype(jnp.float32) * scale[..., None]).astype(dtype)


def paged_kv_view(cache, dtype):
    """Gather the page pool through the block table into the contiguous
    logical view ``(B, S, Hkv, hd)`` (dequantized if int8). Positions
    backed by the trash page hold junk; callers mask with ``pos == -1``.
    Gather-then-dequantize is elementwise-identical to the contiguous
    layout's dequantize, so the view is bit-equal to what a contiguous
    cache would hold at the same logical positions."""
    B, NB = cache["bt"].shape
    ps = cache["kp"].shape[1]
    k = cache["kp"][cache["bt"]]                       # (B, NB, ps, H, hd)
    v = cache["vp"][cache["bt"]]
    k = k.reshape(B, NB * ps, *k.shape[3:])
    v = v.reshape(B, NB * ps, *v.shape[3:])
    if "kp_scale" in cache:
        ksc = cache["kp_scale"][cache["bt"]].reshape(B, NB * ps, -1)
        vsc = cache["vp_scale"][cache["bt"]].reshape(B, NB * ps, -1)
        k = _dequantize_kv(k, ksc, dtype)
        v = _dequantize_kv(v, vsc, dtype)
    return k, v


def _paged_attend(q, k, v, cfg, cache, pos, slots, window):
    """Shared paged write+read behind cached decode and extend: scatter
    the new K/V through the block table, then attend against the updated
    cache. ``pos``/``slots``: (B, T); masked-off entries carry
    ``slots == S`` (their K/V scatters to the trash page and their
    ``pos`` write drops). Returns (attn out, updated cache dict without
    ``step``). The host engine guarantees every targeted page is
    allocated and unshared (CoW) before dispatch."""
    B = q.shape[0]
    S = cache["pos"].shape[1]
    ps = cache["kp"].shape[1]
    trash = cache["kp"].shape[0] - 1
    blk = jnp.clip(slots, 0, S - 1) // ps              # (B, T)
    page = jnp.take_along_axis(cache["bt"], blk, axis=1)
    page = jnp.where(slots < S, page, trash)
    off = jnp.clip(slots, 0, S - 1) % ps
    out = dict(cache)
    quant = "kp_scale" in cache
    if quant:
        k_store, k_sc = _quantize_kv(k)
        v_store, v_sc = _quantize_kv(v)
        out["kp_scale"] = cache["kp_scale"].at[page, off].set(k_sc)
        out["vp_scale"] = cache["vp_scale"].at[page, off].set(v_sc)
    else:
        k_store, v_store = k, v
    out["kp"] = cache["kp"].at[page, off].set(k_store)
    out["vp"] = cache["vp"].at[page, off].set(v_store)
    bidx = jnp.arange(B)[:, None]
    out["pos"] = cache["pos"].at[bidx, slots].set(pos.astype(jnp.int32),
                                                  mode="drop")
    if cfg.use_decode_kernel and not quant:
        from repro.kernels.decode_attention.ops import paged_decode_attention
        y = paged_decode_attention(q, out["kp"], out["vp"], out["bt"],
                                   out["pos"], pos, window=window)
    else:
        k_read, v_read = paged_kv_view(out, q.dtype)
        y = gqa_attention(q, k_read, v_read, q_positions=pos,
                          k_positions=out["pos"], causal=True, window=window,
                          k_valid=out["pos"] >= 0)
    return y, out


def attention_block(p, x, cfg: ModelConfig, *, cache=None, positions=None,
                    window=None):
    """Self-attention. x: (B, L, d).

    * cache=None: full-sequence (train/prefill without cache), causal.
    * cache given and L==1: single-token decode; writes slot ``step % S``
      (ring buffer when S < total positions, i.e. sliding window).
    Returns (y, new_cache).
    """
    B, L, d = x.shape
    hd = cfg.hd
    window = cfg.sliding_window if window is None else window
    q = linear(p["wq"], x).reshape(B, L, -1, hd)
    k = linear(p["wk"], x).reshape(B, L, -1, hd)
    v = linear(p["wv"], x).reshape(B, L, -1, hd)

    if cache is None:
        if positions is None:
            positions = jnp.arange(L)
        if cfg.rope:
            q = apply_rope(q, positions, cfg.rope_theta)
            k = apply_rope(k, positions, cfg.rope_theta)
        if cfg.attn_block and L > cfg.attn_block:
            y = chunked_causal_attention(q, k, v, block=cfg.attn_block,
                                         positions=positions, window=window)
        else:
            y = gqa_attention(q, k, v, q_positions=positions,
                              k_positions=positions, causal=True,
                              window=window)
        return linear(p["wo"], y.reshape(B, L, -1)), None

    # --- cached decode (L == 1) -------------------------------------- #
    S = cache["pos"].shape[1]
    step = cache["step"]                       # (B,) per-sequence position
    pos = step[:, None]                        # (B, 1)
    if cfg.rope:
        q = apply_rope(q, pos, cfg.rope_theta)
        k = apply_rope(k, pos, cfg.rope_theta)
    # decode-shaped activation rules: heads stay split over the model
    # axis through the cache update + attention, matching the KV cache's
    # own sharding (cache_shardings) so nothing re-lays-out per step
    q = shard_activation(q, "act_heads")
    k = shard_activation(k, "act_heads")
    v = shard_activation(v, "act_heads")
    slot = jnp.mod(step, S)                    # (B,)
    if "bt" in cache:                          # paged layout
        y, new_cache = _paged_attend(q, k, v, cfg, cache, pos,
                                     slot[:, None], window)
        new_cache["step"] = step + 1
        return linear(p["wo"], y.reshape(B, L, -1)), new_cache
    bidx = jnp.arange(B)
    quant = "k_scale" in cache
    if quant:
        kq, ks = _quantize_kv(k[:, 0])
        vq, vs = _quantize_kv(v[:, 0])
        new_k = cache["k"].at[bidx, slot].set(kq)
        new_v = cache["v"].at[bidx, slot].set(vq)
        new_ks = cache["k_scale"].at[bidx, slot].set(ks)
        new_vs = cache["v_scale"].at[bidx, slot].set(vs)
        k_read = _dequantize_kv(new_k, new_ks, q.dtype)
        v_read = _dequantize_kv(new_v, new_vs, q.dtype)
    else:
        new_k = cache["k"].at[bidx, slot].set(k[:, 0])
        new_v = cache["v"].at[bidx, slot].set(v[:, 0])
        k_read, v_read = new_k, new_v
    new_pos = cache["pos"].at[bidx, slot].set(step)
    k_valid = new_pos >= 0                     # (B, S)
    if cfg.use_decode_kernel and not quant:
        from repro.kernels.decode_attention.ops import \
            cached_decode_attention
        y = cached_decode_attention(q, k_read, v_read, new_pos, step,
                                    window=window)
    else:
        y = gqa_attention(q, k_read, v_read,
                          q_positions=pos,
                          k_positions=new_pos,
                          causal=True, window=window, k_valid=k_valid)
    new_cache = {"k": new_k, "v": new_v, "pos": new_pos, "step": step + 1}
    if quant:
        new_cache["k_scale"] = new_ks
        new_cache["v_scale"] = new_vs
    return linear(p["wo"], y.reshape(B, L, -1)), new_cache


def extend_into_cache(p, x, cfg: ModelConfig, cache, *, lengths=None,
                      window=None):
    """Masked multi-token cached decode at per-row offsets — the shared
    forward behind speculative verify, chunked prefill, and the serving
    engine's fused mixed (decode + prefill-chunk) step. x: (B, T, d);
    every row sits at its own ``step`` offset and advances by
    ``lengths[b] <= T`` tokens (``lengths=None`` = all rows advance by T,
    the speculative-verify case). Keys/values of the first ``lengths[b]``
    positions are written at ring slots ``(step + t) % S`` in one masked
    scatter (rows beyond their length scatter out of bounds and are
    dropped), then attention runs with per-row query positions
    ``step + t`` against the updated cache — the same position/validity
    masking the bucketed prefill uses. Outputs at positions ``t >=
    lengths[b]`` are garbage by construction; callers discard them
    (``transformer.last_valid``).

    Rollback contract (speculative decoding): the caller may later reduce
    ``step`` to ``step + accepted`` without touching ``pos`` — entries
    beyond the new depth carry positions larger than any future query's
    until the exact decode step that overwrites their slot (same absolute
    position -> same ring slot), so causal masking alone keeps them
    invisible. Returns (y, new_cache with step += lengths).
    """
    B, T, d = x.shape
    hd = cfg.hd
    window = cfg.sliding_window if window is None else window
    step = cache["step"]                                   # (B,)
    pos = step[:, None] + jnp.arange(T, dtype=step.dtype)[None]   # (B, T)
    q = linear(p["wq"], x).reshape(B, T, -1, hd)
    k = linear(p["wk"], x).reshape(B, T, -1, hd)
    v = linear(p["wv"], x).reshape(B, T, -1, hd)
    if cfg.rope:
        q = apply_rope(q, pos, cfg.rope_theta)
        k = apply_rope(k, pos, cfg.rope_theta)
    # same decode-shaped head sharding as the single-token step (the
    # extend path is the multi-token cached decode)
    q = shard_activation(q, "act_heads")
    k = shard_activation(k, "act_heads")
    v = shard_activation(v, "act_heads")
    S = cache["pos"].shape[1]
    if T > S:
        raise ValueError(f"extend window T={T} exceeds cache length S={S}")
    slots = jnp.mod(pos, S)                                # (B, T) distinct
    if lengths is not None:
        # rows advance by lengths[b] < T: send the tail out of bounds so
        # the scatter drops it — cache and pos stay untouched there
        valid = jnp.arange(T)[None, :] < lengths[:, None]  # (B, T)
        slots = jnp.where(valid, slots, S)
    if "bt" in cache:                                      # paged layout
        y, new_cache = _paged_attend(q, k, v, cfg, cache, pos, slots, window)
        inc = T if lengths is None else lengths.astype(step.dtype)
        new_cache["step"] = step + inc
        return linear(p["wo"], y.reshape(B, T, -1)), new_cache
    bidx = jnp.arange(B)[:, None]
    quant = "k_scale" in cache
    if quant:
        kq, ksc = _quantize_kv(k)
        vq, vsc = _quantize_kv(v)
        new_k = cache["k"].at[bidx, slots].set(kq, mode="drop")
        new_v = cache["v"].at[bidx, slots].set(vq, mode="drop")
        new_ks = cache["k_scale"].at[bidx, slots].set(ksc, mode="drop")
        new_vs = cache["v_scale"].at[bidx, slots].set(vsc, mode="drop")
        k_read = _dequantize_kv(new_k, new_ks, q.dtype)
        v_read = _dequantize_kv(new_v, new_vs, q.dtype)
    else:
        new_k = cache["k"].at[bidx, slots].set(k, mode="drop")
        new_v = cache["v"].at[bidx, slots].set(v, mode="drop")
        k_read, v_read = new_k, new_v
    new_pos = cache["pos"].at[bidx, slots].set(pos.astype(jnp.int32),
                                               mode="drop")
    k_valid = new_pos >= 0                                 # (B, S)
    if cfg.use_decode_kernel and not quant:
        from repro.kernels.decode_attention.ops import \
            cached_decode_attention
        y = cached_decode_attention(q, k_read, v_read, new_pos, pos,
                                    window=window)
    else:
        y = gqa_attention(q, k_read, v_read, q_positions=pos,
                          k_positions=new_pos, causal=True, window=window,
                          k_valid=k_valid)
    inc = T if lengths is None else lengths.astype(step.dtype)
    new_cache = {"k": new_k, "v": new_v, "pos": new_pos, "step": step + inc}
    if quant:
        new_cache["k_scale"] = new_ks
        new_cache["v_scale"] = new_vs
    return linear(p["wo"], y.reshape(B, T, -1)), new_cache


def verify_into_cache(p, x, cfg: ModelConfig, cache, *, window=None):
    """Speculative-decoding verify forward: every row advances by the full
    window T. Kept as the historical name; ``extend_into_cache`` is the
    general per-row-length form."""
    return extend_into_cache(p, x, cfg, cache, lengths=None, window=window)


def prefill_into_cache(p, x, cfg: ModelConfig, cache, *, window=None,
                       length=None):
    """Prefill L tokens and populate the cache (cache length >= L for full
    attention; == window for SWA). Returns (y, cache).

    ``length``: optional (B,) int32 count of *valid* tokens per row when
    ``x`` is right-padded to a bucket length (serving engine's bucketed
    prefill). Because padding is on the right and attention is causal, the
    valid prefix's outputs are unaffected by padding; we only have to (a)
    mark padded cache slots empty (``pos = -1``) and (b) set ``step`` to the
    true length. With ``length`` given, the *entire* ``pos`` row is
    rewritten, so a recycled batch slot carries no stale keys from the
    previous occupant.
    """
    B, L, _ = x.shape
    hd = cfg.hd
    window = cfg.sliding_window if window is None else window
    if "bt" in cache:
        raise NotImplementedError(
            "paged caches are populated through chunked admission "
            "(extend_into_cache), not monolithic prefill")
    positions = jnp.arange(L)
    q = linear(p["wq"], x).reshape(B, L, -1, hd)
    k = linear(p["wk"], x).reshape(B, L, -1, hd)
    v = linear(p["wv"], x).reshape(B, L, -1, hd)
    if cfg.rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    if cfg.attn_block and L > cfg.attn_block:
        y = chunked_causal_attention(q, k, v, block=cfg.attn_block,
                                     positions=positions, window=window)
    else:
        y = gqa_attention(q, k, v, q_positions=positions,
                          k_positions=positions, causal=True, window=window)
    S = cache["k"].shape[1]
    quant = "k_scale" in cache
    if quant:
        k_store, k_sc = _quantize_kv(k)
        v_store, v_sc = _quantize_kv(v)
    else:
        k_store, v_store = k, v
    if length is not None and S < L:
        raise NotImplementedError(
            "length-masked prefill requires cache length >= padded length "
            f"(got S={S} < L={L}); use exact-length prefill for long "
            "prompts under sliding-window caches")
    if length is not None:
        new_cache = {"step": length.astype(jnp.int32)}
    else:
        new_cache = {"step": jnp.full((B,), L, jnp.int32)}
    if S >= L:
        new_cache["k"] = lax.dynamic_update_slice(cache["k"], k_store,
                                                  (0, 0, 0, 0))
        new_cache["v"] = lax.dynamic_update_slice(cache["v"], v_store,
                                                  (0, 0, 0, 0))
        if length is not None:
            # full-row rewrite: valid prefix gets its position, padding and
            # any stale entries from a previous slot occupant get -1
            slot_ids = jnp.arange(S, dtype=jnp.int32)[None, :]
            new_cache["pos"] = jnp.where(slot_ids < length[:, None],
                                         slot_ids, -1)
        else:
            row_pos = jnp.broadcast_to(positions.astype(jnp.int32), (B, L))
            new_cache["pos"] = lax.dynamic_update_slice(cache["pos"],
                                                        row_pos, (0, 0))
        if quant:
            new_cache["k_scale"] = lax.dynamic_update_slice(
                cache["k_scale"], k_sc, (0, 0, 0))
            new_cache["v_scale"] = lax.dynamic_update_slice(
                cache["v_scale"], v_sc, (0, 0, 0))
    else:  # keep last S tokens, aligned to ring-buffer slots
        tail_pos = positions[L - S:]
        slots = jnp.mod(tail_pos, S)
        new_cache["k"] = cache["k"].at[:, slots].set(k_store[:, L - S:])
        new_cache["v"] = cache["v"].at[:, slots].set(v_store[:, L - S:])
        new_cache["pos"] = cache["pos"].at[:, slots].set(
            jnp.broadcast_to(tail_pos.astype(jnp.int32), (B, S)))
        if quant:
            new_cache["k_scale"] = cache["k_scale"].at[:, slots].set(
                k_sc[:, L - S:])
            new_cache["v_scale"] = cache["v_scale"].at[:, slots].set(
                v_sc[:, L - S:])
    return linear(p["wo"], y.reshape(B, L, -1)), new_cache


def cross_attention_block(p, x, memory, cfg: ModelConfig):
    """Encoder–decoder cross attention; memory: (B, S, d)."""
    B, L, _ = x.shape
    hd = cfg.hd
    q = linear(p["wq"], x).reshape(B, L, -1, hd)
    k = linear(p["wk"], memory).reshape(B, memory.shape[1], -1, hd)
    v = linear(p["wv"], memory).reshape(B, memory.shape[1], -1, hd)
    y = gqa_attention(q, k, v, causal=False, window=0)
    return linear(p["wo"], y.reshape(B, L, -1))


# --------------------------------------------------------------------- #
# gated MLP (SwiGLU)
# --------------------------------------------------------------------- #
def init_mlp(key, d, d_ff, dtype):
    ks = jax.random.split(key, 3)
    return {
        "wi": init_linear(ks[0], d, d_ff, dtype),
        "wg": init_linear(ks[1], d, d_ff, dtype),
        "wo": init_linear(ks[2], d_ff, d, dtype),
    }


def mlp(p, x):
    return linear(p["wo"], jax.nn.silu(linear(p["wg"], x)) * linear(p["wi"], x))
