"""Unified model facade: ``build(cfg)`` returns a ``Model`` exposing
init / train_loss / prefill / decode_step / make_cache / input_specs for
every assigned family. This is the object the service layer wraps."""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import encdec as ED
from repro.models import transformer as T


def lm_loss(logits, targets, mask=None):
    """Mean next-token cross entropy. logits: (B, L, V) f32."""
    logp = jax.nn.log_softmax(logits, axis=-1)
    ll = jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    if mask is None:
        return -jnp.mean(ll)
    mask = mask.astype(jnp.float32)
    return -jnp.sum(ll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


@dataclasses.dataclass(frozen=True)
class Model:
    """Facade contract (what the serving engine relies on):

    * ``make_cache(batch, cache_len)`` leaves are ``[blocks, batch, ...]``
      with a *per-row* ``step`` in attention sub-caches, so slots at
      different sequence depths share one batched cache.
    * ``prefill(params, batch, cache)`` accepts an optional
      ``batch["length"]`` (B,) int32 of valid text tokens when
      ``batch["tokens"]`` is right-padded to a bucket length; the cache is
      written only for valid positions and logits are taken at the last
      valid position per row.
    * ``decode_step(params, token, cache)`` advances every row by one token
      at that row's own offset.
    * ``extend_into_cache(params, tokens, cache, lengths, last_only)``
      is the unified masked multi-token cached forward at per-row
      offsets, supported by EVERY family: row b consumes
      ``tokens[b, :lengths[b]]`` and advances its cache step by
      ``lengths[b]`` (0 = untouched; lengths=None = all rows advance by
      T). Speculative verify, chunked prefill and the serving engine's
      fused mixed (decode + prefill-chunk) step all share this one code
      path. Attention rings use the masked scatter, SSM mixers the
      sequential ``ssd_extend`` recurrence, encdec the decoder ring with
      prefill-frozen cross-attention memory.
    * ``verify_step(params, tokens, cache)`` is extend with the full
      window (every row advances by T) — the speculative-decoding verify
      pass — and ``rollback(cache, steps)`` moves every sub-cache back
      to the accepted depth. Attention caches rewind by rewriting
      ``step`` (causal masking hides the speculated tail until its slots
      are rewritten); SSM sub-caches restore the checkpoint taken before
      the most recent advance, so when ``rollback_needs_replay`` is set
      the caller must roll back to the *pre-verify* depth and re-extend
      the accepted tokens (the engine's replay flow).
    * ``encode_memory(params, frames)`` (encdec only) encodes frontend
      frames once and returns the per-layer cross-attention KV rows the
      engine writes into a batch slot at admission.
    """

    cfg: ModelConfig
    init: Callable[[jax.Array], Any]
    train_loss: Callable[..., Any]        # (params, batch) -> (loss, metrics)
    prefill: Callable[..., Any]           # (params, batch, cache) -> (logits, cache)
    decode_step: Callable[..., Any]       # (params, token, cache) -> (logits, cache)
    make_cache: Callable[..., Any]        # (batch, cache_len) -> cache pytree
    cache_steps: Callable[..., Any] = lambda cache: None  # cache -> (B,) depths
    verify_step: Optional[Callable[..., Any]] = None  # (params, tokens (B,T), cache)
    rollback: Optional[Callable[..., Any]] = None     # (cache, steps (B,)) -> cache
    extend_into_cache: Optional[Callable[..., Any]] = None
    # (params, tokens (B,T), cache, lengths (B,), last_only) -> (logits, cache)
    make_paged_cache: Optional[Callable[..., Any]] = None
    # (batch, cache_len, *, page_size, num_pages) -> paged cache pytree
    encode_memory: Optional[Callable[..., Any]] = None
    # (params, frames (B, T_src, d_embed)) -> (xk, xv) per-layer cross KV
    rollback_needs_replay: bool = False
    # True for stacks with recurrent (SSM) state: rollback restores the
    # pre-advance checkpoint, so speculative accept must re-extend the
    # accepted tokens instead of just rewinding ``step``

    @property
    def supports_paged(self) -> bool:
        """Paged KV pools are attention-only — SSM recurrent state has
        no per-position storage to page."""
        return self.make_paged_cache is not None

    @property
    def supports_speculative(self) -> bool:
        return self.verify_step is not None

    @property
    def supports_extend(self) -> bool:
        """Whether the stack supports the per-row-length multi-token
        cached forward (chunked prefill / fused mixed step). True for
        every family — this is the one admission path the engine has."""
        return self.extend_into_cache is not None

    def cache_len(self, shape: ShapeConfig) -> int:
        if self.cfg.sliding_window:
            return min(shape.seq_len, self.cfg.sliding_window)
        return shape.seq_len

    def input_specs(self, shape: ShapeConfig) -> Dict[str, Any]:
        """ShapeDtypeStruct stand-ins for one step at the given shape."""
        cfg = self.cfg
        B = shape.global_batch
        i32 = jnp.int32
        sds = jax.ShapeDtypeStruct
        fe = cfg.frontend

        if shape.mode == "train":
            L_tok = shape.seq_len - (fe.n_tokens if fe and cfg.family == "vlm"
                                     else 0)
            batch = {"tokens": sds((B, L_tok), i32)}
            if fe is not None:
                batch["embeddings"] = sds((B, fe.n_tokens, fe.d_embed),
                                          cfg.act_dtype)
            return {"batch": batch}

        if shape.mode == "prefill":
            L_tok = shape.seq_len - (fe.n_tokens if fe and cfg.family == "vlm"
                                     else 0)
            batch = {"tokens": sds((B, L_tok), i32)}
            if fe is not None:
                batch["embeddings"] = sds((B, fe.n_tokens, fe.d_embed),
                                          cfg.act_dtype)
            cache = jax.eval_shape(
                lambda: self.make_cache(B, self.cache_len(shape)))
            return {"batch": batch, "cache": cache}

        # decode: one token against a cache of seq_len
        cache = jax.eval_shape(
            lambda: self.make_cache(B, self.cache_len(shape)))
        return {"token": sds((B, 1), i32), "cache": cache}


# --------------------------------------------------------------------- #
def build(cfg: ModelConfig) -> Model:
    if cfg.family == "encdec":
        return _build_encdec(cfg)
    return _build_decoder(cfg)


def _build_decoder(cfg: ModelConfig) -> Model:
    fe = cfg.frontend

    def train_loss(params, batch):
        tokens = batch["tokens"]
        emb = batch.get("embeddings") if fe is not None else None
        logits, aux = T.forward_train(params, cfg, tokens, emb)
        P = fe.n_tokens if (fe is not None and cfg.family == "vlm") else 0
        text_logits = logits[:, P:][:, :-1]
        loss = lm_loss(text_logits, tokens[:, 1:]) + aux
        return loss, {"lm_loss": loss - aux, "aux_loss": aux}

    def prefill_fn(params, batch, cache):
        emb = batch.get("embeddings") if fe is not None else None
        length = batch.get("length")
        if length is not None and emb is not None and cfg.family == "vlm":
            # length counts text tokens; the cache also holds the frontend
            # prefix, so the total valid depth includes it
            length = length + fe.n_tokens
        return T.prefill(params, cfg, batch["tokens"], cache, emb,
                         length=length)

    def decode_fn(params, token, cache):
        return T.decode_step(params, cfg, token, cache)

    def make_cache(batch, cache_len, dtype=None):
        return T.make_cache(cfg, batch, cache_len, dtype)

    def verify_fn(params, tokens, cache):
        return T.verify_step(params, cfg, tokens, cache)

    def extend_fn(params, tokens, cache, lengths=None, last_only=False,
                  embeddings=None):
        return T.extend_step(params, cfg, tokens, cache, lengths=lengths,
                             last_only=last_only, embeddings=embeddings)

    def make_paged(batch, cache_len, *, page_size, num_pages, dtype=None):
        return T.make_paged_cache(cfg, batch, cache_len,
                                  page_size=page_size, num_pages=num_pages,
                                  dtype=dtype)

    # extend/verify/rollback are universal; paged pools stay attention-
    # only (SSM recurrent state has no per-position storage to page).
    # Recurrent mixers roll back by checkpoint restore, which commits
    # speculation through the engine's replay flow.
    attn_only = all(m == "attn" for m, _ in T.block_spec(cfg))
    has_ssm = any(m == "ssm" for m, _ in T.block_spec(cfg))

    return Model(cfg=cfg, init=lambda k: T.init_transformer(k, cfg),
                 train_loss=train_loss, prefill=prefill_fn,
                 decode_step=decode_fn, make_cache=make_cache,
                 cache_steps=T.cache_steps,
                 verify_step=verify_fn,
                 rollback=T.set_cache_steps,
                 extend_into_cache=extend_fn,
                 make_paged_cache=make_paged if attn_only else None,
                 rollback_needs_replay=has_ssm)


def _build_encdec(cfg: ModelConfig) -> Model:
    fe = cfg.frontend

    def train_loss(params, batch):
        logits, aux = ED.forward_train(params, cfg, batch["tokens"],
                                       batch["embeddings"])
        loss = lm_loss(logits[:, :-1], batch["tokens"][:, 1:]) + aux
        return loss, {"lm_loss": loss - aux, "aux_loss": aux}

    def prefill_fn(params, batch, cache):
        return ED.prefill(params, cfg, batch["tokens"], cache,
                          batch["embeddings"], length=batch.get("length"))

    def decode_fn(params, token, cache):
        return ED.decode_step(params, cfg, token, cache)

    def make_cache(batch, cache_len, dtype=None):
        return ED.make_encdec_cache(cfg, batch, cache_len, fe.n_tokens,
                                    dtype)

    def cache_steps(cache):
        return cache["self"]["step"][0]

    def extend_fn(params, tokens, cache, lengths=None, last_only=False):
        return ED.extend_step(params, cfg, tokens, cache, lengths=lengths,
                              last_only=last_only)

    def verify_fn(params, tokens, cache):
        return ED.extend_step(params, cfg, tokens, cache)

    def encode_memory(params, frames):
        memory = ED.encode(params, cfg, frames)
        return ED.cross_kv_all(params, cfg, memory)

    return Model(cfg=cfg, init=lambda k: ED.init_encdec(k, cfg),
                 train_loss=train_loss, prefill=prefill_fn,
                 decode_step=decode_fn, make_cache=make_cache,
                 cache_steps=cache_steps,
                 verify_step=verify_fn,
                 rollback=T.set_cache_steps,
                 extend_into_cache=extend_fn,
                 encode_memory=encode_memory)
