"""Explicit expert-parallel MoE with shard_map + jax.lax collectives.

The pjit paths in :mod:`repro.models.moe` let GSPMD *infer* the collective
schedule; this module pins it down by hand — the production-grade variant
where the communication pattern is part of the program, not a partitioner
choice:

* experts are sharded over the ``model`` axis (E_local per rank);
* tokens are data-sharded and replicated across ``model`` (the framework's
  standard activation layout), so each rank routes the same tokens,
  computes ONLY its local experts' contributions, and a single
  ``lax.psum`` over ``model`` combines — one deterministic collective per
  MoE layer, which is the information-theoretic minimum for this layout.

Numerically identical to ``moe.moe_block`` (same router, same capacity
semantics per local expert).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models.layers import mlp

try:  # jax>=0.6 moved shard_map to the top level
    shard_map = jax.shard_map
except AttributeError:  # pragma: no cover
    from jax.experimental.shard_map import shard_map


def _shard_map_unchecked(*args, **kw):
    """shard_map without replication checking, across the jax rename
    (check_rep -> check_vma in jax 0.6)."""
    import inspect
    params = inspect.signature(shard_map).parameters
    flag = "check_vma" if "check_vma" in params else "check_rep"
    kw[flag] = False
    return shard_map(*args, **kw)


def _local_expert_pass(router_w, wi, wg, wo, x, *, cfg: ModelConfig,
                       axis: str, n_shards: int, data_axes=("data",)):
    """Per-rank body. x: (B_loc, L, d) — same tokens on every model rank.
    wi/wg/wo: (E_loc, …) this rank's experts."""
    m = cfg.moe
    E, k = m.n_experts, m.top_k
    E_loc = E // n_shards
    rank = lax.axis_index(axis)
    lo = rank * E_loc

    B, L, d = x.shape
    T = B * L
    xf = x.reshape(T, d)

    logits = (xf.astype(jnp.float32) @ router_w)
    probs = jax.nn.softmax(logits, axis=-1)                   # (T, E)
    gate_vals, gate_idx = lax.top_k(probs, k)
    gate_vals = gate_vals / jnp.sum(gate_vals, -1, keepdims=True)

    # keep only assignments to THIS rank's experts; foreign ones get the
    # sentinel id E_loc so they sort to the end and never claim capacity
    A = T * k
    eid = gate_idx.reshape(A) - lo                             # (A,)
    mine = (eid >= 0) & (eid < E_loc)
    eid_sort = jnp.where(mine, eid, E_loc)
    gate_of = jnp.where(mine, gate_vals.reshape(A), 0.0)
    token_of = jnp.arange(A, dtype=jnp.int32) // k

    order = jnp.argsort(eid_sort)
    eid_sorted = eid_sort[order]
    bounds = jnp.searchsorted(eid_sorted, jnp.arange(E_loc + 1))
    counts = (bounds[1:] - bounds[:-1]).astype(jnp.int32)      # (E_loc,)
    offsets = bounds[:-1].astype(jnp.int32)

    from repro.models.moe import _capacity
    C = _capacity(T, m)
    slot = jnp.arange(C, dtype=jnp.int32)
    slot_idx = jnp.clip(offsets[:, None] + slot[None, :], 0, A - 1)
    slot_valid = slot[None, :] < counts[:, None]               # (E_loc, C)
    a_idx = order[slot_idx]
    tok_idx = token_of[a_idx]
    gates = jnp.where(slot_valid, gate_of[a_idx], 0.0)

    xe = xf[tok_idx]
    xe = jnp.where(slot_valid[..., None], xe, 0).astype(cfg.act_dtype)
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, wg)) \
        * jnp.einsum("ecd,edf->ecf", xe, wi)
    ye = jnp.einsum("ecf,efd->ecd", h, wo).astype(jnp.float32)
    ye = ye * gates[..., None]

    y_partial = jnp.zeros((T, d), jnp.float32).at[
        tok_idx.reshape(-1)].add(ye.reshape(-1, d))

    # load-balance aux — exact global quantities: counts psum'd over both
    # the expert (model) and token (data) axes; router-prob mean over data
    me = lax.pmean(jnp.mean(probs, axis=0), data_axes)
    local_counts = jnp.zeros((E,), jnp.float32).at[
        jnp.where(mine, eid + lo, 0)].add(jnp.where(mine, 1.0, 0.0))
    counts_all = lax.psum(local_counts, (axis,) + tuple(data_axes))
    n_data = lax.psum(jnp.ones((), jnp.float32), data_axes)
    aux = m.aux_loss_weight * E * jnp.sum(
        counts_all / (T * n_data * k) * me)

    # ONE deterministic collective: combine expert contributions
    y = lax.psum(y_partial, axis)
    return y.reshape(B, L, d).astype(x.dtype), aux


def moe_block_shard_map(p, x, cfg: ModelConfig, mesh, *,
                        axis: str = "model", data_axes=("data",)):
    """Drop-in for ``moe.moe_block`` under an explicit mesh."""
    m = cfg.moe
    n_shards = mesh.shape[axis]
    assert m.n_experts % n_shards == 0, (m.n_experts, n_shards)
    b = tuple(data_axes)
    batch = b if len(b) > 1 else b[0]

    body = functools.partial(_local_expert_pass, cfg=cfg, axis=axis,
                             n_shards=n_shards, data_axes=b)
    fn = _shard_map_unchecked(
        body, mesh=mesh,
        in_specs=(P(), P(axis, None, None), P(axis, None, None),
                  P(axis, None, None), P(batch, None, None)),
        out_specs=(P(batch, None, None), P()),
    )
    y, aux = fn(p["router"]["w"].astype(jnp.float32), p["wi"], p["wg"],
                p["wo"], x)
    if m.n_shared:
        y = y + mlp(p["shared"], x).astype(x.dtype)
    return y, aux
