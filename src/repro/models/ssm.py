"""Mamba-2 (SSD) mixer block: projections, causal conv, gated norm, and the
SSD scan (chunked dual form for train/prefill, recurrent step for decode)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.models.layers import _normal, init_linear, linear, rms_norm
from repro.kernels.ssd_scan import ops as ssd_ops


def _dims(cfg: ModelConfig):
    s = cfg.ssm
    d_in = s.d_inner(cfg.d_model)
    nh = s.n_heads(cfg.d_model)
    conv_dim = d_in + 2 * s.n_groups * s.d_state
    return s, d_in, nh, conv_dim


def init_ssm(key, cfg: ModelConfig):
    s, d_in, nh, conv_dim = _dims(cfg)
    ks = jax.random.split(key, 4)
    d_proj = 2 * d_in + 2 * s.n_groups * s.d_state + nh
    return {
        "in_proj": init_linear(ks[0], cfg.d_model, d_proj, cfg.p_dtype),
        "conv_w": _normal(ks[1], (conv_dim, s.d_conv), cfg.p_dtype, 0.5),
        "conv_b": jnp.zeros((conv_dim,), cfg.p_dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, nh)).astype(jnp.float32),
        "D": jnp.ones((nh,), jnp.float32),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "norm": {"scale": jnp.ones((d_in,), cfg.p_dtype)},
        "out_proj": init_linear(ks[3], d_in, cfg.d_model, cfg.p_dtype),
    }


def make_ssm_cache(batch, cfg: ModelConfig, dtype):
    s, d_in, nh, conv_dim = _dims(cfg)
    return {
        "conv": jnp.zeros((batch, s.d_conv - 1, conv_dim), dtype),
        "ssm": jnp.zeros((batch, nh, s.head_dim, s.d_state), jnp.float32),
    }


def _causal_conv(x, w, b):
    """Depthwise causal conv. x: (B, L, Cc), w: (Cc, K)."""
    K = w.shape[1]
    L = x.shape[1]
    xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = sum(xp[:, i:i + L] * w[None, None, :, i] for i in range(K))
    return out + b


def _split_proj(zxbcdt, cfg: ModelConfig):
    s, d_in, nh, conv_dim = _dims(cfg)
    z = zxbcdt[..., :d_in]
    xBC = zxbcdt[..., d_in:d_in + conv_dim]
    dt = zxbcdt[..., d_in + conv_dim:]
    return z, xBC, dt


def _split_xbc(xBC, cfg: ModelConfig):
    s, d_in, nh, _ = _dims(cfg)
    gn = s.n_groups * s.d_state
    x = xBC[..., :d_in]
    B = xBC[..., d_in:d_in + gn]
    C = xBC[..., d_in + gn:]
    return x, B, C


def ssm_block(p, u, cfg: ModelConfig, *, cache=None, return_cache=False,
              length=None):
    """u: (B, L, d). cache=None -> full sequence (chunked SSD); pass
    ``return_cache=True`` during prefill to also get the decode cache.
    cache given and L==1 -> recurrent decode step. Returns (y, new_cache).

    ``length``: optional (B,) int32 valid-token count when ``u`` is
    right-padded (bucketed prefill). Padded positions get ``dt = 0`` —
    decay 1, zero input — so the recurrent state after ``length`` tokens is
    exactly the unpadded state, and the conv tail is gathered from the last
    valid inputs rather than the padding."""
    s, d_in, nh, conv_dim = _dims(cfg)
    Bsz, L, _ = u.shape
    zxbcdt = linear(p["in_proj"], u)
    z, xBC, dt = _split_proj(zxbcdt, cfg)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    if length is not None:
        valid = jnp.arange(L)[None, :] < length[:, None]      # (B, L)
        dt = dt * valid[..., None]
    A = -jnp.exp(p["A_log"])

    if cache is None:
        xBC_raw = xBC
        xBC = jax.nn.silu(_causal_conv(xBC, p["conv_w"], p["conv_b"]))
        x, Bc, Cc = _split_xbc(xBC, cfg)
        xh = x.reshape(Bsz, L, nh, s.head_dim)
        Bg = Bc.reshape(Bsz, L, s.n_groups, s.d_state)
        Cg = Cc.reshape(Bsz, L, s.n_groups, s.d_state)
        # pad to a chunk multiple; dt=0 on padding -> decay 1, zero input,
        # so outputs and final state are unaffected
        chunk = min(s.chunk, max(16, 1 << (L - 1).bit_length()))
        pad = (-L) % chunk
        if pad:
            zp = lambda a: jnp.pad(a, [(0, 0), (0, pad)]
                                   + [(0, 0)] * (a.ndim - 2))
            xh, Bg, Cg, dt = zp(xh), zp(Bg), zp(Cg), zp(dt)
        y, final_state = ssd_ops.ssd(xh, dt, A, Bg, Cg, p["D"],
                                     chunk=chunk)
        y = y[:, :L]
        y = y.reshape(Bsz, L, d_in).astype(u.dtype)
        if return_cache:
            K = s.d_conv
            if length is not None:
                # last K-1 *valid* inputs per row; indices before the start
                # of the sequence read as zeros (same as fresh-cache pad)
                idx = length[:, None] - (K - 1) + jnp.arange(K - 1)[None, :]
                in_range = idx >= 0                           # (B, K-1)
                g = jnp.take_along_axis(
                    xBC_raw, jnp.clip(idx, 0, L - 1)[..., None], axis=1)
                tail = jnp.where(in_range[..., None], g, 0)
            else:
                tail = xBC_raw[:, max(0, L - (K - 1)):]
                if tail.shape[1] < K - 1:
                    tail = jnp.pad(
                        tail, ((0, 0), (K - 1 - tail.shape[1], 0), (0, 0)))
            new_cache = {"conv": tail.astype(u.dtype), "ssm": final_state}
        else:
            new_cache = None
    else:
        # single-token recurrence (L == 1)
        xBC1 = xBC[:, 0]                                  # (B, Cc)
        conv_full = jnp.concatenate([cache["conv"], xBC1[:, None]], axis=1)
        wc = p["conv_w"].astype(jnp.float32)              # (Cc, K)
        conv_out = jnp.einsum("bkc,ck->bc",
                              conv_full.astype(jnp.float32),
                              wc) + p["conv_b"].astype(jnp.float32)
        xBC1 = jax.nn.silu(conv_out)
        x, Bc, Cc = _split_xbc(xBC1, cfg)
        xh = x.reshape(Bsz, nh, s.head_dim)
        Bg = Bc.reshape(Bsz, s.n_groups, s.d_state)
        Cg = Cc.reshape(Bsz, s.n_groups, s.d_state)
        y1, new_state = ssd_ops.ssd_step(cache["ssm"], xh, dt[:, 0], A,
                                         Bg, Cg, p["D"])
        y = y1.reshape(Bsz, 1, d_in).astype(u.dtype)
        new_cache = {"conv": conv_full[:, 1:].astype(cache["conv"].dtype),
                     "ssm": new_state}

    # gated RMSNorm (Mamba-2): norm(y * silu(z))
    y = rms_norm(p["norm"], y * jax.nn.silu(z.astype(jnp.float32)).astype(u.dtype),
                 cfg.norm_eps)
    return linear(p["out_proj"], y), new_cache
