"""Mamba-2 (SSD) mixer block: projections, causal conv, gated norm, and the
SSD scan (chunked dual form for train/prefill, recurrent step for decode)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.models.layers import _normal, init_linear, linear, rms_norm
from repro.kernels.ssd_scan import ops as ssd_ops


def _dims(cfg: ModelConfig):
    s = cfg.ssm
    d_in = s.d_inner(cfg.d_model)
    nh = s.n_heads(cfg.d_model)
    conv_dim = d_in + 2 * s.n_groups * s.d_state
    return s, d_in, nh, conv_dim


def init_ssm(key, cfg: ModelConfig):
    s, d_in, nh, conv_dim = _dims(cfg)
    ks = jax.random.split(key, 4)
    d_proj = 2 * d_in + 2 * s.n_groups * s.d_state + nh
    return {
        "in_proj": init_linear(ks[0], cfg.d_model, d_proj, cfg.p_dtype),
        "conv_w": _normal(ks[1], (conv_dim, s.d_conv), cfg.p_dtype, 0.5),
        "conv_b": jnp.zeros((conv_dim,), cfg.p_dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, nh)).astype(jnp.float32),
        "D": jnp.ones((nh,), jnp.float32),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "norm": {"scale": jnp.ones((d_in,), cfg.p_dtype)},
        "out_proj": init_linear(ks[3], d_in, cfg.d_model, cfg.p_dtype),
    }


def make_ssm_cache(batch, cfg: ModelConfig, dtype):
    """Decode/extend cache for one mixer. ``step`` is the per-row depth
    (tokens absorbed into the state); the ``*_ckpt`` leaves hold the
    state as it was *before* the most recent advance — the restore point
    ``rollback`` returns to when speculation rejects drafts (recurrent
    state cannot be rewound by causal masking the way a KV ring can)."""
    s, d_in, nh, conv_dim = _dims(cfg)
    conv = jnp.zeros((batch, s.d_conv - 1, conv_dim), dtype)
    ssm = jnp.zeros((batch, nh, s.head_dim, s.d_state), jnp.float32)
    step = jnp.zeros((batch,), jnp.int32)
    return {"conv": conv, "ssm": ssm, "step": step,
            "conv_ckpt": conv, "ssm_ckpt": ssm, "step_ckpt": step}


def _causal_conv(x, w, b):
    """Depthwise causal conv. x: (B, L, Cc), w: (Cc, K)."""
    K = w.shape[1]
    L = x.shape[1]
    xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = sum(xp[:, i:i + L] * w[None, None, :, i] for i in range(K))
    return out + b


def _split_proj(zxbcdt, cfg: ModelConfig):
    s, d_in, nh, conv_dim = _dims(cfg)
    z = zxbcdt[..., :d_in]
    xBC = zxbcdt[..., d_in:d_in + conv_dim]
    dt = zxbcdt[..., d_in + conv_dim:]
    return z, xBC, dt


def _split_xbc(xBC, cfg: ModelConfig):
    s, d_in, nh, _ = _dims(cfg)
    gn = s.n_groups * s.d_state
    x = xBC[..., :d_in]
    B = xBC[..., d_in:d_in + gn]
    C = xBC[..., d_in + gn:]
    return x, B, C


def ssm_block(p, u, cfg: ModelConfig, *, cache=None, return_cache=False,
              length=None, mode=None):
    """u: (B, L, d). cache=None -> full sequence (chunked SSD); pass
    ``return_cache=True`` during prefill to also get the decode cache.
    cache given and L==1 -> recurrent decode step. cache given and
    ``mode="extend"`` -> multi-token cached recurrence at per-row
    offsets (the serving engine's chunked admission / speculative
    verify): every row advances by ``length[b] <= L`` tokens through
    the sequential ``ssd_extend`` form, masked positions are exact
    identity steps (dt = 0 -> decay 1, zero input) and the conv tail is
    gathered from the last valid inputs, so a length-0 row's cache is
    bit-untouched and chunked extends compose bitwise with a single
    whole-prompt extend. Returns (y, new_cache).

    ``length``: optional (B,) int32 valid-token count when ``u`` is
    right-padded (bucketed prefill). Padded positions get ``dt = 0`` —
    decay 1, zero input — so the recurrent state after ``length`` tokens is
    exactly the unpadded state, and the conv tail is gathered from the last
    valid inputs rather than the padding."""
    s, d_in, nh, conv_dim = _dims(cfg)
    Bsz, L, _ = u.shape
    zxbcdt = linear(p["in_proj"], u)
    z, xBC, dt = _split_proj(zxbcdt, cfg)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    if length is not None:
        valid = jnp.arange(L)[None, :] < length[:, None]      # (B, L)
        dt = dt * valid[..., None]
    A = -jnp.exp(p["A_log"])

    if cache is None:
        xBC_raw = xBC
        xBC = jax.nn.silu(_causal_conv(xBC, p["conv_w"], p["conv_b"]))
        x, Bc, Cc = _split_xbc(xBC, cfg)
        xh = x.reshape(Bsz, L, nh, s.head_dim)
        Bg = Bc.reshape(Bsz, L, s.n_groups, s.d_state)
        Cg = Cc.reshape(Bsz, L, s.n_groups, s.d_state)
        # pad to a chunk multiple; dt=0 on padding -> decay 1, zero input,
        # so outputs and final state are unaffected
        chunk = min(s.chunk, max(16, 1 << (L - 1).bit_length()))
        pad = (-L) % chunk
        if pad:
            zp = lambda a: jnp.pad(a, [(0, 0), (0, pad)]
                                   + [(0, 0)] * (a.ndim - 2))
            xh, Bg, Cg, dt = zp(xh), zp(Bg), zp(Cg), zp(dt)
        y, final_state = ssd_ops.ssd(xh, dt, A, Bg, Cg, p["D"],
                                     chunk=chunk)
        y = y[:, :L]
        y = y.reshape(Bsz, L, d_in).astype(u.dtype)
        if return_cache:
            K = s.d_conv
            if length is not None:
                # last K-1 *valid* inputs per row; indices before the start
                # of the sequence read as zeros (same as fresh-cache pad)
                idx = length[:, None] - (K - 1) + jnp.arange(K - 1)[None, :]
                in_range = idx >= 0                           # (B, K-1)
                g = jnp.take_along_axis(
                    xBC_raw, jnp.clip(idx, 0, L - 1)[..., None], axis=1)
                tail = jnp.where(in_range[..., None], g, 0)
            else:
                tail = xBC_raw[:, max(0, L - (K - 1)):]
                if tail.shape[1] < K - 1:
                    tail = jnp.pad(
                        tail, ((0, 0), (K - 1 - tail.shape[1], 0), (0, 0)))
            lens = (length if length is not None
                    else jnp.full((Bsz,), L, jnp.int32))
            tail = tail.astype(u.dtype)
            # fresh stream: the checkpoint is the state itself (there is
            # nothing earlier to restore to)
            new_cache = {"conv": tail, "ssm": final_state,
                         "step": lens.astype(jnp.int32),
                         "conv_ckpt": tail, "ssm_ckpt": final_state,
                         "step_ckpt": lens.astype(jnp.int32)}
        else:
            new_cache = None
    elif mode == "extend":
        # multi-token cached recurrence at per-row offsets. The conv
        # stream is [cached tail | raw new inputs]; token t's depthwise
        # window is conv_in[t : t+K], so positions < length[b] only ever
        # see valid inputs, and the new tail (last K-1 valid inputs)
        # is conv_in[length[b] : length[b]+K-1] — for length 0 that is
        # the old tail, bit-for-bit.
        K = s.d_conv
        step = cache["step"]
        conv_in = jnp.concatenate([cache["conv"], xBC], axis=1)
        widx = jnp.arange(L)[:, None] + jnp.arange(K)[None, :]   # (L, K)
        win = conv_in[:, widx]                                   # (B,L,K,Cc)
        conv_out = jnp.einsum("blkc,ck->blc", win.astype(jnp.float32),
                              p["conv_w"].astype(jnp.float32))
        xc = jax.nn.silu(conv_out + p["conv_b"].astype(jnp.float32))
        x, Bc, Cc = _split_xbc(xc, cfg)
        xh = x.reshape(Bsz, L, nh, s.head_dim)
        Bg = Bc.reshape(Bsz, L, s.n_groups, s.d_state)
        Cg = Cc.reshape(Bsz, L, s.n_groups, s.d_state)
        y, new_state = ssd_ops.ssd_extend(cache["ssm"], xh, dt, A,
                                          Bg, Cg, p["D"])
        y = y.reshape(Bsz, L, d_in).astype(u.dtype)
        lens = (length if length is not None
                else jnp.full((Bsz,), L, jnp.int32))
        tidx = lens[:, None] + jnp.arange(K - 1)[None, :]        # (B, K-1)
        tail = jnp.take_along_axis(conv_in, tidx[..., None], axis=1)
        new_cache = {"conv": tail.astype(cache["conv"].dtype),
                     "ssm": new_state,
                     "step": step + lens.astype(step.dtype),
                     "conv_ckpt": cache["conv"], "ssm_ckpt": cache["ssm"],
                     "step_ckpt": step}
    else:
        # single-token recurrence (L == 1)
        xBC1 = xBC[:, 0]                                  # (B, Cc)
        conv_full = jnp.concatenate([cache["conv"], xBC1[:, None]], axis=1)
        wc = p["conv_w"].astype(jnp.float32)              # (Cc, K)
        conv_out = jnp.einsum("bkc,ck->bc",
                              conv_full.astype(jnp.float32),
                              wc) + p["conv_b"].astype(jnp.float32)
        xBC1 = jax.nn.silu(conv_out)
        x, Bc, Cc = _split_xbc(xBC1, cfg)
        xh = x.reshape(Bsz, nh, s.head_dim)
        Bg = Bc.reshape(Bsz, s.n_groups, s.d_state)
        Cg = Cc.reshape(Bsz, s.n_groups, s.d_state)
        y1, new_state = ssd_ops.ssd_step(cache["ssm"], xh, dt[:, 0], A,
                                         Bg, Cg, p["D"])
        y = y1.reshape(Bsz, 1, d_in).astype(u.dtype)
        new_cache = {"conv": conv_full[:, 1:].astype(cache["conv"].dtype),
                     "ssm": new_state,
                     "step": cache["step"] + 1,
                     "conv_ckpt": cache["conv"], "ssm_ckpt": cache["ssm"],
                     "step_ckpt": cache["step"]}

    # gated RMSNorm (Mamba-2): norm(y * silu(z))
    y = rms_norm(p["norm"], y * jax.nn.silu(z.astype(jnp.float32)).astype(u.dtype),
                 cfg.norm_eps)
    return linear(p["out_proj"], y), new_cache
