"""Encoder–decoder backbone (seamless-m4t class): bidirectional encoder over
frontend frame embeddings + autoregressive text decoder with cross-attention.

The audio frontend (mel + conv feature extractor) is a STUB per the brief:
``input_specs()`` supplies precomputed frame embeddings (B, T_src, d_embed).
"""
from __future__ import annotations

import functools
from typing import Any, Dict

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.distribution.sharding import shard_activation
from repro.models import layers as L


# --------------------------------------------------------------------- #
# init
# --------------------------------------------------------------------- #
def _init_enc_layer(key, cfg: ModelConfig):
    e = cfg.encoder
    ks = jax.random.split(key, 2)
    return {
        "ln1": L.init_rms_norm(cfg.d_model, cfg.p_dtype),
        "attn": L.init_attention(ks[0], cfg, n_heads=e.n_heads,
                                 n_kv_heads=e.n_kv_heads),
        "ln2": L.init_rms_norm(cfg.d_model, cfg.p_dtype),
        "mlp": L.init_mlp(ks[1], cfg.d_model, e.d_ff, cfg.p_dtype),
    }


def _init_dec_layer(key, cfg: ModelConfig):
    ks = jax.random.split(key, 3)
    return {
        "ln1": L.init_rms_norm(cfg.d_model, cfg.p_dtype),
        "self_attn": L.init_attention(ks[0], cfg),
        "ln_x": L.init_rms_norm(cfg.d_model, cfg.p_dtype),
        "cross_attn": L.init_attention(ks[1], cfg),
        "ln2": L.init_rms_norm(cfg.d_model, cfg.p_dtype),
        "mlp": L.init_mlp(ks[2], cfg.d_model, cfg.d_ff, cfg.p_dtype),
    }


def init_encdec(key, cfg: ModelConfig):
    ks = jax.random.split(key, 5)
    enc_keys = jax.random.split(ks[0], cfg.encoder.n_layers)
    dec_keys = jax.random.split(ks[1], cfg.n_layers)
    return {
        "frontend_proj": L.init_linear(ks[2], cfg.frontend.d_embed,
                                       cfg.d_model, cfg.p_dtype),
        "enc_layers": jax.vmap(lambda k: _init_enc_layer(k, cfg))(enc_keys),
        "enc_ln": L.init_rms_norm(cfg.d_model, cfg.p_dtype),
        "embed": L.init_embedding(ks[3], cfg.vocab, cfg.d_model, cfg.p_dtype),
        "dec_layers": jax.vmap(lambda k: _init_dec_layer(k, cfg))(dec_keys),
        "ln_f": L.init_rms_norm(cfg.d_model, cfg.p_dtype),
        "lm_head": L.init_linear(ks[4], cfg.d_model, cfg.vocab, cfg.p_dtype),
    }


# --------------------------------------------------------------------- #
# encoder
# --------------------------------------------------------------------- #
def encode(params, cfg: ModelConfig, frames):
    """frames: (B, T_src, d_embed) -> memory (B, T_src, d)."""
    x = L.linear(params["frontend_proj"], frames).astype(cfg.act_dtype)
    x = shard_activation(x, "act_btd")
    e = cfg.encoder
    hd = cfg.d_model // e.n_heads

    def body(x, lp):
        h = L.rms_norm(lp["ln1"], x, cfg.norm_eps)
        B, T, _ = h.shape
        q = L.linear(lp["attn"]["wq"], h).reshape(B, T, -1, hd)
        k = L.linear(lp["attn"]["wk"], h).reshape(B, T, -1, hd)
        v = L.linear(lp["attn"]["wv"], h).reshape(B, T, -1, hd)
        pos = jnp.arange(T)
        if cfg.rope:
            q = L.apply_rope(q, pos, cfg.rope_theta)
            k = L.apply_rope(k, pos, cfg.rope_theta)
        y = L.gqa_attention(q, k, v, causal=False)          # bidirectional
        x = x + L.linear(lp["attn"]["wo"], y.reshape(B, T, -1))
        h = L.rms_norm(lp["ln2"], x, cfg.norm_eps)
        x = x + L.mlp(lp["mlp"], h)
        return shard_activation(x, "act_btd"), None

    if cfg.unroll_layers:
        nl = jax.tree.leaves(params["enc_layers"])[0].shape[0]
        for i in range(nl):
            lp = jax.tree.map(lambda t: t[i], params["enc_layers"])
            x, _ = body(x, lp)
    else:
        x, _ = lax.scan(body, x, params["enc_layers"])
    return L.rms_norm(params["enc_ln"], x, cfg.norm_eps)


# --------------------------------------------------------------------- #
# decoder
# --------------------------------------------------------------------- #
def _cross_kv(lp, memory, cfg: ModelConfig):
    B, S, _ = memory.shape
    k = L.linear(lp["cross_attn"]["wk"], memory).reshape(B, S, -1, cfg.hd)
    v = L.linear(lp["cross_attn"]["wv"], memory).reshape(B, S, -1, cfg.hd)
    return k, v


def _dec_block(lp, x, cfg: ModelConfig, *, mode, cache=None, memory=None,
               length=None):
    """One decoder layer. cache: {'self': kv_cache, 'xk': ..., 'xv': ...}.
    ``length``: optional (B,) valid-token counts for right-padded prefill."""
    new_cache: Dict[str, Any] = {}
    h = L.rms_norm(lp["ln1"], x, cfg.norm_eps)
    if mode == "train":
        y, _ = L.attention_block(lp["self_attn"], h, cfg)
    elif mode == "prefill":
        y, nc = L.prefill_into_cache(lp["self_attn"], h, cfg, cache["self"],
                                     length=length)
        new_cache["self"] = nc
    elif mode == "extend":
        # per-row-length masked extend of the decoder ring — the same
        # path every other family uses for chunked admission and
        # speculative verify; the cross-attention memory (xk/xv) was
        # frozen at admission and passes through untouched
        y, nc = L.extend_into_cache(lp["self_attn"], h, cfg, cache["self"],
                                    lengths=length)
        new_cache["self"] = nc
    else:
        y, nc = L.attention_block(lp["self_attn"], h, cfg,
                                  cache=cache["self"])
        new_cache["self"] = nc
    x = x + y

    h = L.rms_norm(lp["ln_x"], x, cfg.norm_eps)
    if mode in ("decode", "extend"):
        xk, xv = cache["xk"], cache["xv"]
        new_cache["xk"], new_cache["xv"] = xk, xv
    else:
        xk, xv = _cross_kv(lp, memory, cfg)
        if mode == "prefill":
            new_cache["xk"], new_cache["xv"] = xk, xv
    B, Lq = h.shape[:2]
    q = L.linear(lp["cross_attn"]["wq"], h).reshape(B, Lq, -1, cfg.hd)
    y = L.gqa_attention(q, xk, xv, causal=False)
    x = x + L.linear(lp["cross_attn"]["wo"], y.reshape(B, Lq, -1))

    h = L.rms_norm(lp["ln2"], x, cfg.norm_eps)
    x = x + L.mlp(lp["mlp"], h)
    x = shard_activation(x, "act_btd")
    return x, (new_cache or None)


def make_encdec_cache(cfg: ModelConfig, batch: int, cache_len: int,
                      src_len: int, dtype=None):
    """``cfg.kv_quant`` stores the growing self-attention KV ring as int8;
    the cross-attention memory keys (xk/xv, written once at prefill and
    bounded by src_len) stay in model dtype."""
    dtype = dtype or cfg.act_dtype
    one = {
        "self": L.make_kv_cache(batch, cache_len, cfg.n_kv_heads, cfg.hd,
                                dtype, quant=cfg.kv_quant),
        "xk": jnp.zeros((batch, src_len, cfg.n_kv_heads, cfg.hd), dtype),
        "xv": jnp.zeros((batch, src_len, cfg.n_kv_heads, cfg.hd), dtype),
    }
    return jax.tree.map(
        lambda x: jnp.broadcast_to(x, (cfg.n_layers,) + x.shape), one)


def _scan_dec(params, x, cfg, *, mode, cache=None, memory=None, length=None):
    fn = functools.partial(_dec_block, cfg=cfg, mode=mode, memory=memory,
                           length=length)
    if cfg.remat:
        fn = jax.checkpoint(fn)
    if cfg.unroll_layers:
        nl = jax.tree.leaves(params["dec_layers"])[0].shape[0]
        new_caches = []
        for i in range(nl):
            lp = jax.tree.map(lambda t: t[i], params["dec_layers"])
            c = None if cache is None else \
                jax.tree.map(lambda t: t[i], cache)
            x, nc = fn(lp, x) if mode == "train" else fn(lp, x, cache=c)
            if nc is not None:
                new_caches.append(nc)
        new_cache = None if not new_caches else \
            jax.tree.map(lambda *xs: jnp.stack(xs), *new_caches)
        return x, new_cache
    if mode == "train":
        def body(x, lp):
            x, _ = fn(lp, x)
            return x, None
        x, _ = lax.scan(body, x, params["dec_layers"])
        return x, None

    def body(x, xs):
        lp, c = xs
        x, nc = fn(lp, x, cache=c)
        return x, nc
    x, new_cache = lax.scan(body, x, (params["dec_layers"], cache))
    return x, new_cache


def _logits(params, cfg, x):
    x = L.rms_norm(params["ln_f"], x, cfg.norm_eps)
    return shard_activation(
        L.linear(params["lm_head"], x).astype(jnp.float32), "logits")


def forward_train(params, cfg: ModelConfig, tokens, embeddings):
    """embeddings: (B, T_src, d_embed) audio frames; tokens: (B, L)."""
    memory = encode(params, cfg, embeddings)
    x = L.embed(params["embed"], tokens).astype(cfg.act_dtype)
    x = shard_activation(x, "act_btd")
    x, _ = _scan_dec(params, x, cfg, mode="train", memory=memory)
    return _logits(params, cfg, x), jnp.zeros((), jnp.float32)


def prefill(params, cfg: ModelConfig, tokens, cache, embeddings,
            length=None):
    from repro.models.transformer import last_valid
    memory = encode(params, cfg, embeddings)
    x = L.embed(params["embed"], tokens).astype(cfg.act_dtype)
    x = shard_activation(x, "act_btd")
    x, new_cache = _scan_dec(params, x, cfg, mode="prefill", cache=cache,
                             memory=memory, length=length)
    return _logits(params, cfg, last_valid(x, length)), new_cache


def decode_step(params, cfg: ModelConfig, token, cache):
    x = L.embed(params["embed"], token).astype(cfg.act_dtype)
    x, new_cache = _scan_dec(params, x, cfg, mode="decode", cache=cache)
    return _logits(params, cfg, x), new_cache


def extend_step(params, cfg: ModelConfig, tokens, cache, lengths=None,
                last_only=False):
    """Masked multi-token cached decoder forward at per-row offsets —
    the decoder-side twin of ``transformer.extend_step``. The cache must
    already hold the cross-attention memory (``cross_kv_all`` written at
    admission); only the self-attention ring advances."""
    from repro.models.transformer import last_valid
    x = L.embed(params["embed"], tokens).astype(cfg.act_dtype)
    x = shard_activation(x, "act_btd")
    x, new_cache = _scan_dec(params, x, cfg, mode="extend", cache=cache,
                             length=lengths)
    if last_only:
        x = last_valid(x, lengths)
    return _logits(params, cfg, x), new_cache


def cross_kv_all(params, cfg: ModelConfig, memory):
    """Per-layer cross-attention keys/values over an encoded memory.
    memory: (B, S, d) -> (xk, xv) each (n_layers, B, S, n_kv_heads, hd)
    — exactly the ``xk``/``xv`` leaves of ``make_encdec_cache``, so the
    serving engine can encode once at admission and write the rows
    straight into a batch slot."""
    def body(carry, lp):
        k, v = _cross_kv(lp, memory, cfg)
        return carry, (k, v)
    _, (ks, vs) = lax.scan(body, None, params["dec_layers"])
    return ks, vs
