"""Decoder stacks for all assigned families (dense / moe / ssm / hybrid /
vlm), built scan-over-layers so HLO size is depth-independent.

A *block* is the scan unit: one sublayer for homogeneous stacks, or a
super-block (e.g. Jamba's [1 attn + 7 mamba] with alternating MoE/MLP FFNs)
for hybrids. Params for all blocks are stacked on a leading axis via
``jax.vmap`` over init keys.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.distribution.sharding import shard_activation
from repro.models import layers as L
from repro.models import moe as M
from repro.models import ssm as S


# --------------------------------------------------------------------- #
# block structure
# --------------------------------------------------------------------- #
def block_spec(cfg: ModelConfig) -> List[Tuple[str, Optional[str]]]:
    """Returns [(mixer, ffn)] per sublayer of the scan unit."""
    if cfg.family in ("dense", "vlm"):
        return [("attn", "mlp")]
    if cfg.family == "moe":
        return [("attn", "moe")]
    if cfg.family == "ssm":
        return [("ssm", None)]
    if cfg.family == "hybrid":
        every = max(1, cfg.moe.moe_every) if cfg.moe else 0
        spec = []
        for i in range(cfg.attn_every):
            mixer = "attn" if i == 0 else "ssm"
            ffn = "moe" if (cfg.moe and i % every == 0) else "mlp"
            spec.append((mixer, ffn))
        return spec
    raise ValueError(f"unknown family {cfg.family}")


def n_blocks(cfg: ModelConfig) -> int:
    k = len(block_spec(cfg))
    assert cfg.n_layers % k == 0, (cfg.n_layers, k)
    return cfg.n_layers // k


# --------------------------------------------------------------------- #
# init
# --------------------------------------------------------------------- #
def init_block(key, cfg: ModelConfig):
    spec = block_spec(cfg)
    p: Dict[str, Any] = {}
    keys = jax.random.split(key, len(spec))
    for i, (mixer, ffn) in enumerate(spec):
        sk = jax.random.split(keys[i], 4)
        sub: Dict[str, Any] = {"ln1": L.init_rms_norm(cfg.d_model, cfg.p_dtype)}
        if mixer == "attn":
            sub["attn"] = L.init_attention(sk[0], cfg)
        else:
            sub["ssm"] = S.init_ssm(sk[1], cfg)
        if ffn is not None:
            sub["ln2"] = L.init_rms_norm(cfg.d_model, cfg.p_dtype)
            if ffn == "mlp":
                sub["mlp"] = L.init_mlp(sk[2], cfg.d_model, cfg.d_ff,
                                        cfg.p_dtype)
            else:
                sub["moe"] = M.init_moe(sk[3], cfg)
        p[f"sub{i}"] = sub
    return p


def init_stack(key, cfg: ModelConfig):
    nb = n_blocks(cfg)
    keys = jax.random.split(key, nb)
    return jax.vmap(lambda k: init_block(k, cfg))(keys)


def init_transformer(key, cfg: ModelConfig):
    ks = jax.random.split(key, 4)
    p = {
        "embed": L.init_embedding(ks[0], cfg.vocab, cfg.d_model, cfg.p_dtype),
        "blocks": init_stack(ks[1], cfg),
        "ln_f": L.init_rms_norm(cfg.d_model, cfg.p_dtype),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = L.init_linear(ks[2], cfg.d_model, cfg.vocab,
                                     cfg.p_dtype)
    if cfg.frontend is not None:
        p["frontend_proj"] = L.init_linear(ks[3], cfg.frontend.d_embed,
                                           cfg.d_model, cfg.p_dtype)
    return p


# --------------------------------------------------------------------- #
# cache
# --------------------------------------------------------------------- #
def _block_cache(cfg: ModelConfig, batch: int, cache_len: int, dtype):
    spec = block_spec(cfg)
    c: Dict[str, Any] = {}
    for i, (mixer, _) in enumerate(spec):
        if mixer == "attn":
            S_len = min(cache_len, cfg.sliding_window) if cfg.sliding_window \
                else cache_len
            c[f"sub{i}"] = L.make_kv_cache(batch, S_len, cfg.n_kv_heads,
                                           cfg.hd, dtype,
                                           quant=cfg.kv_quant)
        else:
            c[f"sub{i}"] = S.make_ssm_cache(batch, cfg, dtype)
    return c


def make_cache(cfg: ModelConfig, batch: int, cache_len: int, dtype=None):
    """Stacked (per-block) decode cache pytree.

    Layout contract (relied on by the serving engine): every leaf carries
    the scanned block axis first and the batch axis second, i.e.
    ``[n_blocks, batch, ...]``, and attention sub-caches keep a *per-row*
    ``step`` offset — batch slot ``b`` can sit at any sequence depth
    independently of its neighbours, so one batched cache serves requests
    of different lengths."""
    dtype = dtype or cfg.act_dtype
    one = _block_cache(cfg, batch, cache_len, dtype)
    nb = n_blocks(cfg)
    return jax.tree.map(lambda x: jnp.broadcast_to(x, (nb,) + x.shape), one)


def make_paged_cache(cfg: ModelConfig, batch: int, cache_len: int, *,
                     page_size: int, num_pages: int, dtype=None):
    """Paged decode cache (see ``layers.make_paged_kv_cache`` /
    ``serving/paged_kv.py``): same ``[n_blocks, batch, ...]`` layout
    contract as ``make_cache`` for ``pos``/``step``/``bt`` leaves, while
    the K/V pool leaves carry ``[n_blocks, num_pages + 1, ...]`` — the
    pool replaces the per-slot ring as the storage axis. Attention-only
    stacks (SSM recurrent state has no paged analogue)."""
    if any(mixer != "attn" for mixer, _ in block_spec(cfg)):
        raise NotImplementedError(
            f"paged KV caches require attention-only stacks; family "
            f"{cfg.family!r} has SSM mixers")
    dtype = dtype or cfg.act_dtype
    spec = block_spec(cfg)
    S_len = min(cache_len, cfg.sliding_window) if cfg.sliding_window \
        else cache_len
    one = {f"sub{i}": L.make_paged_kv_cache(batch, S_len, cfg.n_kv_heads,
                                            cfg.hd, dtype,
                                            page_size=page_size,
                                            num_pages=num_pages,
                                            quant=cfg.kv_quant)
           for i in range(len(spec))}
    nb = n_blocks(cfg)
    return jax.tree.map(lambda x: jnp.broadcast_to(x, (nb,) + x.shape), one)


def cache_steps(cache):
    """Per-slot sequence depth (B,) from the first sub-cache that tracks
    one (every mixer does: attention rings and SSM recurrent state both
    carry a per-row ``step``)."""
    for sub in cache.values():
        if isinstance(sub, dict) and "step" in sub:
            return sub["step"][0]
    return None


# --------------------------------------------------------------------- #
# block apply
# --------------------------------------------------------------------- #
def apply_block(bp, x, cfg: ModelConfig, *, mode: str, cache=None,
                length=None):
    """mode: 'train' | 'prefill' | 'decode' | 'extend'. Returns
    (x, new_cache, aux). ``length``: optional (B,) counts — for 'prefill'
    the valid-token count of right-padded rows (bucketed serving
    prefill); for 'extend' the per-row advance (rows move by length[b]
    <= T tokens, None = all rows advance by T). 'extend' is the masked
    multi-token cached decode shared by speculative verify, chunked
    prefill and the engine's fused mixed step; every mixer supports it
    (attention via the masked ring scatter, SSM via the sequential
    ``ssd_extend`` recurrence with identity steps past each row's
    length). MoE FFNs route *densely* (per-token, capacity-free) in the
    cached serving modes so chunk padding and batch composition cannot
    distort expert assignment — see ``moe.moe_block``."""
    spec = block_spec(cfg)
    aux = jnp.zeros((), jnp.float32)
    new_cache: Dict[str, Any] = {}
    for i, (mixer, ffn) in enumerate(spec):
        sp = bp[f"sub{i}"]
        h = L.rms_norm(sp["ln1"], x, cfg.norm_eps)
        if mixer == "attn":
            if mode == "train":
                y, nc = L.attention_block(sp["attn"], h, cfg)
            elif mode == "prefill":
                y, nc = L.prefill_into_cache(sp["attn"], h, cfg,
                                             cache[f"sub{i}"],
                                             length=length)
            elif mode == "extend":
                y, nc = L.extend_into_cache(sp["attn"], h, cfg,
                                            cache[f"sub{i}"],
                                            lengths=length)
            else:
                y, nc = L.attention_block(sp["attn"], h, cfg,
                                          cache=cache[f"sub{i}"])
        else:
            if mode == "train":
                y, nc = S.ssm_block(sp["ssm"], h, cfg)
            elif mode == "prefill":
                y, nc = S.ssm_block(sp["ssm"], h, cfg, return_cache=True,
                                    length=length)
            elif mode == "extend":
                y, nc = S.ssm_block(sp["ssm"], h, cfg,
                                    cache=cache[f"sub{i}"],
                                    length=length, mode="extend")
            else:
                y, nc = S.ssm_block(sp["ssm"], h, cfg,
                                    cache=cache[f"sub{i}"])
        if nc is not None:
            new_cache[f"sub{i}"] = nc
        x = x + y
        x = shard_activation(x, "act_btd")
        if ffn is not None:
            h = L.rms_norm(sp["ln2"], x, cfg.norm_eps)
            if ffn == "mlp":
                y = L.mlp(sp["mlp"], h)
            else:
                y, moe_aux = M.moe_block(sp["moe"], h, cfg,
                                         dense=mode in ("decode", "extend"))
                aux = aux + moe_aux
            x = x + y
            x = shard_activation(x, "act_btd")
    return x, (new_cache or None), aux


# --------------------------------------------------------------------- #
# full forward passes
# --------------------------------------------------------------------- #
def _scan_blocks(params, x, cfg: ModelConfig, *, mode: str, cache=None,
                 length=None):
    block_fn = functools.partial(apply_block, cfg=cfg, mode=mode,
                                 length=length)
    if cfg.remat:
        block_fn = jax.checkpoint(block_fn)

    if cfg.unroll_layers:
        nb = jax.tree.leaves(params["blocks"])[0].shape[0]
        aux = jnp.zeros((), jnp.float32)
        new_caches = []
        for i in range(nb):
            bp = jax.tree.map(lambda t: t[i], params["blocks"])
            c = None if cache is None else \
                jax.tree.map(lambda t: t[i], cache)
            x, nc, a = block_fn(bp, x, cache=c)
            aux = aux + a
            if nc is not None:
                new_caches.append(nc)
        new_cache = None if not new_caches else \
            jax.tree.map(lambda *xs: jnp.stack(xs), *new_caches)
        return x, new_cache, aux

    if mode == "train":
        def body(carry, bp):
            x, aux = carry
            x, _, a = block_fn(bp, x)
            return (x, aux + a), None
        (x, aux), _ = lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                               params["blocks"])
        return x, None, aux

    def body(carry, xs):
        x, aux = carry
        bp, c = xs
        x, nc, a = block_fn(bp, x, cache=c)
        return (x, aux + a), nc
    (x, aux), new_cache = lax.scan(
        body, (x, jnp.zeros((), jnp.float32)), (params["blocks"], cache))
    return x, new_cache, aux


def embed_inputs(params, cfg: ModelConfig, tokens=None, embeddings=None):
    """tokens: (B, Lt) ids; embeddings: (B, Le, d_embed) frontend stub
    output (VLM patches / audio frames). Returns (B, L, d)."""
    parts = []
    if embeddings is not None:
        parts.append(L.linear(params["frontend_proj"], embeddings))
    if tokens is not None:
        parts.append(L.embed(params["embed"], tokens))
    x = parts[0] if len(parts) == 1 else jnp.concatenate(parts, axis=1)
    return x.astype(cfg.act_dtype)


def logits_from(params, cfg: ModelConfig, x):
    x = L.rms_norm(params["ln_f"], x, cfg.norm_eps)
    if cfg.tie_embeddings:
        out = L.unembed(params["embed"], x)
    else:
        out = L.linear(params["lm_head"], x)
    return shard_activation(out.astype(jnp.float32), "logits")


def forward_train(params, cfg: ModelConfig, tokens, embeddings=None):
    """Returns (logits, aux_loss)."""
    x = embed_inputs(params, cfg, tokens, embeddings)
    x = shard_activation(x, "act_btd")
    x, _, aux = _scan_blocks(params, x, cfg, mode="train")
    return logits_from(params, cfg, x), aux


def last_valid(x, length):
    """x: (B, L, d); length: (B,) valid counts -> (B, 1, d) at the last
    valid position per row (the whole-sequence last position if None)."""
    if length is None:
        return x[:, -1:]
    idx = jnp.clip(length - 1, 0, x.shape[1] - 1).astype(jnp.int32)
    return jnp.take_along_axis(x, idx[:, None, None], axis=1)


def prefill(params, cfg: ModelConfig, tokens, cache, embeddings=None,
            length=None):
    """Populates cache; returns (last-valid-position logits, cache).

    ``length``: optional (B,) total valid positions (frontend tokens +
    text) when inputs are right-padded to a bucket length. Right padding +
    causal attention means valid positions are computed identically to an
    unpadded call; padded cache slots are marked empty (pos = -1) and the
    per-row ``step`` offset is set to ``length`` so decode resumes at the
    true depth. One caveat: MoE routing shares an expert-capacity budget
    across all (incl. padded) tokens, so padded prefill of MoE stacks is
    only capacity-approximate — the serving engine therefore pads only
    MoE-free models (exact for dense/ssm/hybrid-no-moe/vlm/encdec)."""
    x = embed_inputs(params, cfg, tokens, embeddings)
    x = shard_activation(x, "act_btd")
    x, new_cache, _ = _scan_blocks(params, x, cfg, mode="prefill",
                                   cache=cache, length=length)
    return logits_from(params, cfg, last_valid(x, length)), new_cache


def decode_step(params, cfg: ModelConfig, token, cache):
    """token: (B, 1) ids. Returns (logits (B, 1, V), new_cache)."""
    x = embed_inputs(params, cfg, token)
    x = shard_activation(x, "act_btd")
    x, new_cache, _ = _scan_blocks(params, x, cfg, mode="decode",
                                   cache=cache)
    return logits_from(params, cfg, x), new_cache


def extend_step(params, cfg: ModelConfig, tokens, cache, lengths=None,
                last_only=False, embeddings=None):
    """Masked multi-token cached forward at per-row offsets — the unified
    extend path behind speculative verify, chunked prefill, and the
    serving engine's fused mixed step. tokens: (B, T) ids; ``lengths``:
    optional (B,) per-row advance (row b consumes tokens[b, :lengths[b]]
    and its cache step moves by lengths[b]; 0 = row untouched; None = all
    rows advance by T). Returns (logits, new_cache) — logits (B, T, V)
    where ``logits[:, i]`` is the distribution after consuming
    tokens[:, :i+1], or (B, 1, V) at each row's last valid position when
    ``last_only`` (saves the (T-1)·V unembed when only the next-token
    distribution is needed, e.g. a prefill chunk). ``embeddings``:
    optional (B, T, d_embed) frontend output admitted *instead of*
    tokens (a VLM/audio prefix chunk flowing through the same masked
    extend as text)."""
    x = embed_inputs(params, cfg, tokens, embeddings)
    x = shard_activation(x, "act_btd")
    x, new_cache, _ = _scan_blocks(params, x, cfg, mode="extend",
                                   cache=cache, length=lengths)
    if last_only:
        x = last_valid(x, lengths)
    return logits_from(params, cfg, x), new_cache


def verify_step(params, cfg: ModelConfig, tokens, cache):
    """Speculative-decoding verify: score T tokens per row in one masked
    multi-token forward at each row's own cache offset. tokens: (B, T)
    ids — [pending token, draft proposals]. Returns (logits (B, T, V),
    new_cache with step += T); ``logits[:, i]`` is the target
    distribution after consuming tokens[:, :i+1]."""
    return extend_step(params, cfg, tokens, cache)


def set_cache_steps(cache, steps):
    """Per-row cache rollback: move every sub-cache to depth ``steps``
    (B,), family-aware.

    * Attention sub-caches (``pos`` leaf): rewrite ``step`` (leaves are
      (n_blocks, B)). ``pos`` entries beyond the new depth are left in
      place — causal masking keeps them invisible until the decode step
      that overwrites their ring slot (see ``layers.verify_into_cache``).
    * SSM sub-caches (``conv``/``ssm`` leaves): recurrent state cannot
      be rewound by masking, so rows with ``steps < step`` restore the
      ``*_ckpt`` snapshot taken before the most recent advance. The
      caller must target that snapshot's depth (the engine rolls back to
      the pre-verify depth and *replays* accepted tokens through
      ``extend_step`` — see ``Model.rollback_needs_replay``).

    Rows where ``steps`` equals the current depth are untouched
    bit-for-bit on both.
    """
    steps = steps.astype(jnp.int32)

    def walk(node):
        if not isinstance(node, dict):
            return node
        if "conv" in node and "ssm" in node:              # SSM sub-cache
            tgt = jnp.broadcast_to(steps[None, :], node["step"].shape)
            back = tgt < node["step"]                     # (n_blocks, B)

            def sel(cur, ck):
                m = back.reshape(back.shape + (1,) * (cur.ndim - back.ndim))
                return jnp.where(m, ck, cur)

            return {"conv": sel(node["conv"], node["conv_ckpt"]),
                    "ssm": sel(node["ssm"], node["ssm_ckpt"]),
                    "step": jnp.where(back, tgt, node["step"]),
                    "conv_ckpt": node["conv_ckpt"],
                    "ssm_ckpt": node["ssm_ckpt"],
                    "step_ckpt": node["step_ckpt"]}
        out = {}
        for k, v in node.items():
            if k == "step":
                out[k] = jnp.broadcast_to(steps[None, :], v.shape)
            else:
                out[k] = walk(v)
        return out

    return walk(cache)
