"""Mixture-of-Experts FFN with sort-based capacity dispatch.

Design notes (TPU adaptation):
* We deliberately avoid the classic one-hot ``dispatch @ combine`` einsum —
  its dispatch tensor adds O(T·E·C·d) *artificial* matmul FLOPs that dwarf
  the real expert compute and poison the roofline. Instead tokens are
  sorted by expert id (argsort + gather), processed as (E, capacity)
  padded blocks through a batched expert matmul (MXU-friendly), and
  scattered back with their gate weights. HLO FLOPs are then proportional
  to *active* expert compute, matching the 6·N_active·D model.
* Experts are sharded on the ``model`` mesh axis (expert parallelism);
  the gather/scatter become collective traffic that XLA lowers to
  all-gather / reduce-scatter (baseline) — §Perf explores an explicit
  shard_map all-to-all schedule.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig, MoEConfig
from repro.distribution.sharding import shard_activation
from repro.models.layers import init_linear, init_mlp, linear, mlp, _normal


def init_moe(key, cfg: ModelConfig):
    m = cfg.moe
    d = cfg.d_model
    ks = jax.random.split(key, 5)
    p = {
        "router": {"w": _normal(ks[0], (d, m.n_experts), jnp.float32)},
        # stacked experts: (E, d, d_expert) etc.
        "wi": _normal(ks[1], (m.n_experts, d, m.d_expert), cfg.p_dtype),
        "wg": _normal(ks[2], (m.n_experts, d, m.d_expert), cfg.p_dtype),
        "wo": _normal(ks[3], (m.n_experts, m.d_expert, d), cfg.p_dtype),
    }
    if m.n_shared:
        d_shared = m.d_shared or m.n_shared * m.d_expert
        p["shared"] = init_mlp(ks[4], d, d_shared, cfg.p_dtype)
    return p


def _capacity(n_tokens: int, m: MoEConfig) -> int:
    cap = int(n_tokens * m.top_k * m.capacity_factor / m.n_experts)
    return max(8, ((cap + 7) // 8) * 8)  # pad to VPU sublane multiple


def moe_block(p, x, cfg: ModelConfig, *, dense=False):
    """x: (B, L, d) -> (y, aux_loss).

    Baseline: sort-based top-k dispatch over the GLOBAL token stream
    (one argsort over B·L tokens — under pjit this makes GSPMD gather the
    full activation stream across the data axis every MoE layer).

    ``moe.group_routing=True``: route within each batch row instead —
    the sort, gather, and scatter all stay data-local, so the only
    cross-device traffic is the expert einsum itself (§Perf iteration).

    ``dense=True`` (the serving decode/extend modes): capacity-free
    per-token routing via ``_route_dense`` — every token's output
    depends only on that token, never on what else shares the batch or
    how much right-padding a chunk carries. This is what makes chunked
    admission, per-row-length masked extends, and continuous batch
    composition *deterministic* for MoE stacks: no expert-capacity
    budget shared across rows means no routing distortion from padding
    or co-scheduled requests. Costs compute on all experts, which at
    serving token counts (B·T small) is matmul-bound anyway.
    """
    m = cfg.moe
    B, L, d = x.shape
    if dense:
        y, aux = _route_dense(p, x.reshape(B * L, d), cfg)
        y = y.reshape(B, L, d)
    elif m.group_routing and L > 1:
        y, aux = _route_grouped(p, x, cfg)      # (B, L, d)
        y = shard_activation(y, "act_btd")
    else:
        y, aux = _route_tokens(p, x.reshape(B * L, d), cfg)
        y = y.reshape(B, L, d)
    if m.n_shared:
        y = y + mlp(p["shared"], x).astype(x.dtype)
    return y, aux


def _route_dense(p, xf, cfg: ModelConfig):
    """Capacity-free top-k routing: run every expert on every token and
    combine with a gate-masked sum. xf: (T, d) -> (T, d).

    Per-token deterministic and batch-independent by construction — the
    property the serving engine's chunked/masked extend paths need (a
    padded or inactive row contributes garbage only to its *own* output,
    which callers discard). No aux loss: serving never trains."""
    m = cfg.moe
    T, d = xf.shape
    E, k = m.n_experts, m.top_k

    logits = (xf.astype(jnp.float32) @ p["router"]["w"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)                    # (T, E)
    gate_vals, gate_idx = lax.top_k(probs, k)                  # (T, k)
    gate_vals = gate_vals / jnp.sum(gate_vals, -1, keepdims=True)
    gates = jnp.zeros((T, E), jnp.float32).at[
        jnp.arange(T)[:, None], gate_idx].set(gate_vals)       # (T, E)

    xe = xf.astype(cfg.act_dtype)
    h = jax.nn.silu(jnp.einsum("td,edf->tef", xe, p["wg"])) \
        * jnp.einsum("td,edf->tef", xe, p["wi"])
    ye = jnp.einsum("tef,efd->ted", h, p["wo"])                # (T, E, d)
    y = jnp.einsum("ted,te->td", ye.astype(jnp.float32), gates)
    return y.astype(xf.dtype), jnp.zeros((), jnp.float32)


def _route_grouped(p, x, cfg: ModelConfig):
    """Grouped dispatch with an EXPLICIT group axis (one group per batch
    row) so GSPMD keeps groups on ``data`` and experts on ``model``
    end-to-end: the sort/gather/scatter are data-local and the only
    cross-device traffic is the combine all-reduce over the model axis."""
    m = cfg.moe
    G, T, d = x.shape                                          # groups = B
    E, k = m.n_experts, m.top_k
    xf = x.astype(jnp.float32)

    logits = jnp.einsum("gtd,de->gte", xf, p["router"]["w"])
    probs = jax.nn.softmax(logits, axis=-1)                    # (G, T, E)
    gate_vals, gate_idx = lax.top_k(probs, k)                  # (G, T, k)
    gate_vals = gate_vals / jnp.sum(gate_vals, -1, keepdims=True)

    me = jnp.mean(probs, axis=(0, 1))                          # (E,)
    counts_all = jnp.sum(
        jax.nn.one_hot(gate_idx, E, dtype=jnp.float32), axis=(0, 1, 2))
    aux_loss = m.aux_loss_weight * E * jnp.sum(
        counts_all / (G * T * k) * me)

    A = T * k
    expert_of = gate_idx.reshape(G, A)
    gate_of = gate_vals.reshape(G, A)
    order = jnp.argsort(expert_of, axis=-1)                    # (G, A)
    expert_sorted = jnp.take_along_axis(expert_of, order, axis=-1)
    # per-group expert counts via binary search on the sorted ids
    bounds = jax.vmap(
        lambda s: jnp.searchsorted(s, jnp.arange(E + 1)))(expert_sorted)
    counts = (bounds[:, 1:] - bounds[:, :-1]).astype(jnp.int32)  # (G, E)
    offsets = bounds[:, :-1].astype(jnp.int32)

    C = _capacity(T, m)
    slot = jnp.arange(C, dtype=jnp.int32)
    slot_idx = offsets[:, :, None] + slot[None, None, :]       # (G, E, C)
    slot_valid = slot[None, None, :] < counts[:, :, None]
    slot_idx = jnp.clip(slot_idx, 0, A - 1)
    a_idx = jnp.take_along_axis(order, slot_idx.reshape(G, -1),
                                axis=-1).reshape(G, E, C)
    tok_idx = a_idx // k                                       # (G, E, C)
    gates = jnp.where(
        slot_valid,
        jnp.take_along_axis(gate_of, a_idx.reshape(G, -1),
                            axis=-1).reshape(G, E, C), 0.0)

    xe = jnp.take_along_axis(
        x, tok_idx.reshape(G, E * C, 1), axis=1).reshape(G, E, C, d)
    xe = jnp.where(slot_valid[..., None], xe, 0).astype(cfg.act_dtype)
    xe = shard_activation(xe, "moe_expert_grouped")

    h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", xe, p["wg"])) \
        * jnp.einsum("gecd,edf->gecf", xe, p["wi"])
    ye = jnp.einsum("gecf,efd->gecd", h, p["wo"])              # (G, E, C, d)
    ye = shard_activation(ye, "moe_expert_grouped")

    # ---- combine by GATHER (scatters partition poorly under GSPMD):
    # invert the sort permutation to find each assignment's (e, c) slot,
    # gather its expert output, weight by the gate, and sum over k.
    inv = jnp.argsort(order, axis=-1)                          # (G, A)
    c_of = inv - jnp.take_along_axis(offsets, expert_of, axis=-1)
    flat = jnp.clip(expert_of * C + c_of, 0, E * C - 1)        # (G, A)
    a_valid = (c_of < C)[..., None]
    contrib = jnp.take_along_axis(
        ye.reshape(G, E * C, d), flat[..., None], axis=1)      # (G, A, d)
    contrib = jnp.where(a_valid, contrib, 0).astype(jnp.float32)
    contrib = contrib * gate_of[..., None]
    y = jnp.sum(contrib.reshape(G, T, k, d), axis=2)
    return y.astype(x.dtype), aux_loss


def _route_tokens(p, xf, cfg: ModelConfig):
    """Top-k dispatch over a flat token group xf: (T, d) -> (T, d)."""
    m = cfg.moe
    T, d = xf.shape
    E, k = m.n_experts, m.top_k

    logits = (xf.astype(jnp.float32) @ p["router"]["w"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)                    # (T, E)
    gate_vals, gate_idx = lax.top_k(probs, k)                  # (T, k)
    gate_vals = gate_vals / jnp.sum(gate_vals, -1, keepdims=True)

    # ---- load-balance auxiliary loss (Switch-style) ----------------- #
    me = jnp.mean(probs, axis=0)                               # (E,)
    assign_frac = jnp.zeros((E,), jnp.float32).at[gate_idx.reshape(-1)].add(
        1.0 / (T * k))
    aux_loss = m.aux_loss_weight * E * jnp.sum(assign_frac * me)

    # ---- sort assignments by expert --------------------------------- #
    A = T * k
    expert_of = gate_idx.reshape(A)                            # (A,)
    token_of = jnp.arange(A, dtype=jnp.int32) // k
    gate_of = gate_vals.reshape(A)
    order = jnp.argsort(expert_of)                             # stable
    expert_sorted = expert_of[order]
    counts = jnp.bincount(expert_of, length=E)                 # (E,)
    offsets = jnp.concatenate([jnp.zeros((1,), counts.dtype),
                               jnp.cumsum(counts)[:-1]])

    C = _capacity(T, m)
    # slot (e, c) -> index into the sorted assignment list
    slot_idx = offsets[:, None] + jnp.arange(C, dtype=counts.dtype)[None, :]
    slot_valid = jnp.arange(C)[None, :] < counts[:, None]      # (E, C)
    slot_idx = jnp.clip(slot_idx, 0, A - 1)
    a_idx = order[slot_idx]                                    # (E, C)
    # guard: a slot is only valid if its assignment really belongs here
    slot_valid = slot_valid & (expert_sorted[slot_idx]
                               == jnp.arange(E)[:, None])
    tok_idx = token_of[a_idx]                                  # (E, C)
    gates = jnp.where(slot_valid, gate_of[a_idx], 0.0)         # (E, C)

    xe = xf[tok_idx]                                           # (E, C, d)
    xe = jnp.where(slot_valid[..., None], xe, 0).astype(cfg.act_dtype)
    xe = shard_activation(xe, "moe_expert")

    # ---- batched expert MLP (SwiGLU) -------------------------------- #
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, p["wg"])) \
        * jnp.einsum("ecd,edf->ecf", xe, p["wi"])
    ye = jnp.einsum("ecf,efd->ecd", h, p["wo"])                # (E, C, d)

    # ---- combine ----------------------------------------------------- #
    ye = ye.astype(jnp.float32) * gates[..., None]
    y = jnp.zeros((T, d), jnp.float32).at[tok_idx.reshape(-1)].add(
        ye.reshape(-1, d))
    return y.astype(xf.dtype), aux_loss
