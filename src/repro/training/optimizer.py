"""AdamW with gradient clipping and cosine LR schedule (no optax — built
from scratch per the brief). Optimizer state mirrors the param pytree, so
the same sharding rules apply (and ZeRO-style sharding just re-shards it).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp


def cosine_schedule(base_lr: float, warmup: int, total: int,
                    min_frac: float = 0.1) -> Callable[[Any], Any]:
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = base_lr * step / jnp.maximum(warmup, 1)
        t = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0, 1)
        cos = base_lr * (min_frac + (1 - min_frac)
                         * 0.5 * (1 + jnp.cos(jnp.pi * t)))
        return jnp.where(step < warmup, warm, cos)
    return lr


def constant_schedule(base_lr: float):
    return lambda step: jnp.asarray(base_lr, jnp.float32)


@dataclass(frozen=True)
class AdamW:
    lr: Callable[[Any], Any]
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.01
    clip_norm: float = 1.0

    def init(self, params):
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {"m": jax.tree.map(zeros, params),
                "v": jax.tree.map(zeros, params),
                "step": jnp.zeros((), jnp.int32)}

    def update(self, grads, state, params) -> Tuple[Any, Any]:
        step = state["step"] + 1
        gnorm = global_norm(grads)
        scale = jnp.minimum(1.0, self.clip_norm / (gnorm + 1e-9))
        grads = jax.tree.map(lambda g: g.astype(jnp.float32) * scale, grads)

        m = jax.tree.map(lambda m, g: self.b1 * m + (1 - self.b1) * g,
                         state["m"], grads)
        v = jax.tree.map(lambda v, g: self.b2 * v + (1 - self.b2) * g * g,
                         state["v"], grads)
        sf = jnp.asarray(step, jnp.float32)
        bc1 = 1 - self.b1 ** sf
        bc2 = 1 - self.b2 ** sf
        lr = self.lr(step)

        def upd(p, m, v):
            mh = m / bc1
            vh = v / bc2
            delta = mh / (jnp.sqrt(vh) + self.eps) \
                + self.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * delta).astype(p.dtype)

        new_params = jax.tree.map(upd, params, m, v)
        return new_params, {"m": m, "v": v, "step": step}


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(sum(leaves))
