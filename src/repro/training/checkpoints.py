"""Pytree checkpointing: .npz payload + JSON manifest, content-addressed.

Containers are restricted to nested dicts (all our param trees are), so the
tree is reconstructible from '/'-joined leaf paths without pickling.
"""
from __future__ import annotations

import hashlib
import json
import os
from pathlib import Path
from typing import Any, Dict

import jax
import numpy as np


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out[key] = np.asarray(leaf)
    return out


def _unflatten(flat: Dict[str, np.ndarray]) -> Any:
    tree: Dict[str, Any] = {}
    for key, val in flat.items():
        parts = key.split("/")
        node = tree
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = val
    return tree


def tree_hash(tree) -> str:
    h = hashlib.sha256()
    flat = _flatten(tree)
    for key in sorted(flat):
        arr = flat[key]
        h.update(key.encode())
        h.update(str(arr.shape).encode())
        h.update(str(arr.dtype).encode())
        h.update(np.ascontiguousarray(arr).tobytes())
    return h.hexdigest()


def save_pytree(path: os.PathLike, tree, extra: dict | None = None) -> str:
    """Writes <path>.npz and <path>.json; returns the content hash."""
    path = Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    flat = _flatten(tree)
    np.savez(str(path) + ".npz", **flat)
    digest = tree_hash(tree)
    manifest = {"hash": digest,
                "leaves": {k: {"shape": list(v.shape), "dtype": str(v.dtype)}
                           for k, v in flat.items()}}
    manifest.update(extra or {})
    with open(str(path) + ".json", "w") as f:
        json.dump(manifest, f, indent=1)
    return digest


def load_pytree(path: os.PathLike, verify: bool = True):
    path = Path(path)
    with np.load(str(path) + ".npz") as z:
        flat = {k: z[k] for k in z.files}
    tree = _unflatten(flat)
    if verify and Path(str(path) + ".json").exists():
        with open(str(path) + ".json") as f:
            manifest = json.load(f)
        if manifest.get("hash") and manifest["hash"] != tree_hash(tree):
            raise IOError(f"checkpoint {path}: content hash mismatch")
    return tree


def save_train_state(path, step: int, params, opt_state, extra=None):
    meta = {"step": int(step)}
    meta.update(extra or {})
    save_pytree(Path(path) / "params", params, extra=meta)
    save_pytree(Path(path) / "opt_state", opt_state, extra=meta)


def load_train_state(path):
    params = load_pytree(Path(path) / "params")
    opt_state = load_pytree(Path(path) / "opt_state")
    with open(Path(path) / "params.json") as f:
        step = json.load(f).get("step", 0)
    return step, params, opt_state
