"""Pytree checkpointing: .npz payload + JSON manifest, content-addressed.

Containers are restricted to nested dicts (all our param trees are), so the
tree is reconstructible from '/'-joined leaf paths without pickling.

Resilience (docs/robustness.md): saves are **atomic** — payload and
manifest are written to temp files and ``os.replace``d into place, so a
crash mid-write leaves either the previous checkpoint or none, never a
half-written one a later load would trust. Loads **fail fast** with
:class:`CheckpointError`: a truncated/corrupt archive, a manifest whose
leaf inventory disagrees with the payload (missing/extra leaves, shape
or dtype drift), or a content-hash mismatch all name the checkpoint and
the violated constraint instead of surfacing as a shape error deep in
the first training step (regression-tested against the
``truncated_checkpoint`` fault site in ``repro.serving.faults``).
"""
from __future__ import annotations

import hashlib
import json
import os
from pathlib import Path
from typing import Any, Dict

import jax
import numpy as np


class CheckpointError(IOError):
    """A checkpoint failed to load: truncated/corrupt payload, manifest
    mismatch, or content-hash mismatch."""


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out[key] = np.asarray(leaf)
    return out


def _unflatten(flat: Dict[str, np.ndarray]) -> Any:
    tree: Dict[str, Any] = {}
    for key, val in flat.items():
        parts = key.split("/")
        node = tree
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = val
    return tree


def tree_hash(tree) -> str:
    h = hashlib.sha256()
    flat = _flatten(tree)
    for key in sorted(flat):
        arr = flat[key]
        h.update(key.encode())
        h.update(str(arr.shape).encode())
        h.update(str(arr.dtype).encode())
        h.update(np.ascontiguousarray(arr).tobytes())
    return h.hexdigest()


def _atomic_write(path: Path, write_fn) -> None:
    """Write through a same-directory temp file + ``os.replace`` so the
    destination is only ever absent, the old version, or complete."""
    tmp = path.parent / f".{path.name}.tmp-{os.getpid()}"
    try:
        write_fn(tmp)
        os.replace(tmp, path)
    finally:
        if tmp.exists():
            tmp.unlink()


def save_pytree(path: os.PathLike, tree, extra: dict | None = None) -> str:
    """Writes <path>.npz and <path>.json atomically; returns the
    content hash."""
    path = Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    flat = _flatten(tree)
    def _write_npz(tmp: Path) -> None:
        with tmp.open("wb") as fh:
            np.savez(fh, **flat)
    _atomic_write(Path(str(path) + ".npz"), _write_npz)
    digest = tree_hash(tree)
    manifest = {"hash": digest,
                "leaves": {k: {"shape": list(v.shape), "dtype": str(v.dtype)}
                           for k, v in flat.items()}}
    manifest.update(extra or {})
    _atomic_write(Path(str(path) + ".json"),
                  lambda tmp: tmp.write_text(json.dumps(manifest, indent=1)))
    return digest


def _validate_manifest(path: Path, manifest: dict,
                       flat: Dict[str, np.ndarray]) -> None:
    leaves = manifest.get("leaves")
    if not isinstance(leaves, dict):
        return                      # pre-manifest checkpoint: hash-only
    missing = sorted(set(leaves) - set(flat))
    extra = sorted(set(flat) - set(leaves))
    if missing or extra:
        raise CheckpointError(
            f"checkpoint {path}: payload leaves disagree with manifest "
            f"(missing={missing[:3]}, unexpected={extra[:3]})")
    for key, want in leaves.items():
        arr = flat[key]
        if list(arr.shape) != list(want.get("shape", [])):
            raise CheckpointError(
                f"checkpoint {path}: leaf {key!r} has shape "
                f"{list(arr.shape)}, manifest says {want.get('shape')}")
        if str(arr.dtype) != want.get("dtype"):
            raise CheckpointError(
                f"checkpoint {path}: leaf {key!r} has dtype "
                f"{arr.dtype}, manifest says {want.get('dtype')}")


def load_pytree(path: os.PathLike, verify: bool = True):
    path = Path(path)
    try:
        with np.load(str(path) + ".npz") as z:
            flat = {k: z[k] for k in z.files}
    except FileNotFoundError:
        raise
    except Exception as e:         # truncated zip, bad member, ...
        raise CheckpointError(
            f"checkpoint {path}: payload unreadable "
            f"(truncated or corrupt archive): {e}") from e
    tree = _unflatten(flat)
    if verify and Path(str(path) + ".json").exists():
        try:
            with open(str(path) + ".json") as f:
                manifest = json.load(f)
        except ValueError as e:
            raise CheckpointError(
                f"checkpoint {path}: manifest unreadable: {e}") from e
        _validate_manifest(path, manifest, flat)
        if manifest.get("hash") and manifest["hash"] != tree_hash(tree):
            raise CheckpointError(
                f"checkpoint {path}: content hash mismatch")
    return tree


def save_train_state(path, step: int, params, opt_state, extra=None):
    meta = {"step": int(step)}
    meta.update(extra or {})
    save_pytree(Path(path) / "params", params, extra=meta)
    save_pytree(Path(path) / "opt_state", opt_state, extra=meta)


def load_train_state(path):
    params = load_pytree(Path(path) / "params")
    opt_state = load_pytree(Path(path) / "opt_state")
    with open(Path(path) / "params.json") as f:
        step = json.load(f).get("step", 0)
    return step, params, opt_state
