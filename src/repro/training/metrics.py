"""JSONL metrics logging for train/serve drivers (production hygiene:
machine-readable run logs next to human console output)."""
from __future__ import annotations

import json
import time
from pathlib import Path
from typing import Any, Dict, Optional


class MetricsLogger:
    def __init__(self, path: Optional[str] = None, *, run_name: str = "",
                 echo: bool = False):
        self.path = Path(path) if path else None
        self.run_name = run_name
        self.echo = echo
        self._t0 = time.perf_counter()
        if self.path:
            self.path.parent.mkdir(parents=True, exist_ok=True)
            self._fh = open(self.path, "a")
        else:
            self._fh = None

    def log(self, kind: str, **fields: Any) -> Dict[str, Any]:
        rec = {"ts": round(time.perf_counter() - self._t0, 4),
               "run": self.run_name, "kind": kind}
        for k, v in fields.items():
            rec[k] = float(v) if hasattr(v, "item") else v
        if self._fh:
            self._fh.write(json.dumps(rec) + "\n")
            self._fh.flush()
        if self.echo:
            print(rec)
        return rec

    def close(self):
        if self._fh:
            self._fh.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()


def read_jsonl(path) -> list:
    with open(path) as f:
        return [json.loads(line) for line in f if line.strip()]
