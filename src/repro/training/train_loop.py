"""Train-step builder and driver loop.

``make_train_step`` returns the pure function the launcher jits (and the
dry-run lowers): state/batch in, state/metrics out. Microbatching
(gradient accumulation) happens *inside* the step via lax.scan so the
compiled program is one XLA module.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Any, Callable, Dict, Iterator, Optional

import jax
import jax.numpy as jnp

from repro.models.model import Model
from repro.training.optimizer import AdamW, global_norm


def make_train_step(model: Model, opt: AdamW, *, microbatch: int = 0,
                    unroll_micro: bool = False):
    """microbatch: if >0, split the global batch into chunks of this many
    examples and accumulate grads with a scan (activation memory saver).
    unroll_micro unrolls that scan (used by dry-run cost calibration so
    XLA cost analysis sees every iteration)."""

    loss_fn = model.train_loss

    def grads_of(params, batch):
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, batch)
        return loss, metrics, grads

    def step(state, batch):
        params = state["params"]
        if microbatch:
            B = jax.tree.leaves(batch)[0].shape[0]
            n = B // microbatch
            stacked = jax.tree.map(
                lambda x: x.reshape((n, microbatch) + x.shape[1:]), batch)

            def body(acc, mb):
                loss, metrics, grads = grads_of(params, mb)
                acc_loss, acc_grads = acc
                return (acc_loss + loss,
                        jax.tree.map(jnp.add, acc_grads, grads)), metrics

            zero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                params)
            (loss, grads), metrics = jax.lax.scan(
                body, (jnp.zeros((), jnp.float32), zero), stacked,
                unroll=n if unroll_micro else 1)
            loss = loss / n
            grads = jax.tree.map(lambda g: g / n, grads)
            metrics = jax.tree.map(lambda m: m[-1], metrics)
        else:
            loss, metrics, grads = grads_of(params, batch)

        new_params, new_opt = opt.update(grads, state["opt"], params)
        new_state = {"params": new_params, "opt": new_opt,
                     "step": state["step"] + 1}
        metrics = dict(metrics)
        metrics.update(loss=loss, grad_norm=global_norm(grads),
                       lr=opt.lr(new_opt["step"]))
        return new_state, metrics

    return step


def init_train_state(model: Model, opt: AdamW, key) -> Dict[str, Any]:
    params = model.init(key)
    return {"params": params, "opt": opt.init(params),
            "step": jnp.zeros((), jnp.int32)}


def train(model: Model, opt: AdamW, data: Iterator, *, steps: int,
          key=None, log_every: int = 10, state=None,
          callback: Optional[Callable] = None):
    """CPU-runnable driver used by examples/tests."""
    key = key if key is not None else jax.random.PRNGKey(0)
    if state is None:
        state = init_train_state(model, opt, key)
    step_fn = jax.jit(make_train_step(model, opt))
    history = []
    t0 = time.perf_counter()
    for i in range(steps):
        batch = next(data)
        state, metrics = step_fn(state, batch)
        if i % log_every == 0 or i == steps - 1:
            m = {k: float(v) for k, v in metrics.items()}
            m["step"] = i
            m["wall_s"] = time.perf_counter() - t0
            history.append(m)
            if callback:
                callback(m)
    return state, history
