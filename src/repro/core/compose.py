"""Composition primitives — the paper's "construct new services from
existing ones". Sequential connection is the primary primitive (paper §3);
we add parallel, ensemble, routing, and batch-mapping combinators, and an
explicit set of adapter services.

Composed services FUSE: the combinator returns one pure ``fn`` over the
combined params pytree, so ``jit`` compiles the whole pipeline into a single
XLA program — on TPU, composition has no host round-trip (the on-fabric
analogue of the paper eliminating the cloud round-trip)."""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.core.compat import CompositionError, check_composable
from repro.core.service import (Service, Signature, TensorSpec,
                                spec_tree_of)


# --------------------------------------------------------------------- #
# sequential connection (the paper's primary primitive)
# --------------------------------------------------------------------- #
def seq(*services: Service, name: Optional[str] = None) -> Service:
    assert len(services) >= 2
    for a, b in zip(services, services[1:]):
        check_composable(a, b)
    name = name or "_then_".join(s.name for s in services)
    params = {f"stage{i}": s.params for i, s in enumerate(services)}
    fns = [s.fn for s in services]

    def fn(p, x):
        for i, f in enumerate(fns):
            x = f(p[f"stage{i}"], x)
        return x

    sig = Signature(services[0].signature.inputs,
                    services[-1].signature.outputs)
    return Service(name=name, fn=fn, signature=sig, params=params,
                   description=f"sequential composition of "
                               f"{[s.name for s in services]}",
                   metadata={"combinator": "seq",
                             "stages": [s.name for s in services]})


# --------------------------------------------------------------------- #
# parallel: independent services over a dict of inputs
# --------------------------------------------------------------------- #
def parallel(named: Dict[str, Service], *, name: Optional[str] = None) -> Service:
    name = name or "par_" + "_".join(named)
    params = {k: s.params for k, s in named.items()}
    fns = {k: s.fn for k, s in named.items()}

    def fn(p, xs):
        return {k: f(p[k], xs[k]) for k, f in fns.items()}

    sig = Signature({k: s.signature.inputs for k, s in named.items()},
                    {k: s.signature.outputs for k, s in named.items()})
    return Service(name=name, fn=fn, signature=sig, params=params,
                   metadata={"combinator": "parallel",
                             "stages": list(named)})


# --------------------------------------------------------------------- #
# ensemble: same input to N services, combine outputs
# --------------------------------------------------------------------- #
def ensemble(services: Sequence[Service], combine: str = "mean",
             *, name: Optional[str] = None) -> Service:
    s0 = services[0]
    for s in services[1:]:
        errs = []
        from repro.core.compat import unify
        errs += unify(s0.signature.inputs, s.signature.inputs,
                      where=f"ensemble inputs {s0.name} vs {s.name}")
        errs += unify(s0.signature.outputs, s.signature.outputs,
                      where=f"ensemble outputs {s0.name} vs {s.name}")
        if errs:
            raise CompositionError("; ".join(errs))
    name = name or "ens_" + "_".join(s.name for s in services)
    params = {f"member{i}": s.params for i, s in enumerate(services)}
    fns = [s.fn for s in services]

    def fn(p, x):
        outs = [f(p[f"member{i}"], x) for i, f in enumerate(fns)]
        if combine == "mean":
            return jax.tree.map(lambda *ys: sum(ys) / len(ys), *outs)
        if combine == "sum":
            return jax.tree.map(lambda *ys: sum(ys), *outs)
        if combine == "stack":
            return jax.tree.map(lambda *ys: jnp.stack(ys), *outs)
        raise ValueError(combine)

    out_sig = s0.signature.outputs
    if combine == "stack":
        out_sig = jax.tree.map(
            lambda t: TensorSpec((len(services),) + t.shape, t.dtype),
            out_sig)
    return Service(name=name, fn=fn,
                   signature=Signature(s0.signature.inputs, out_sig),
                   params=params,
                   metadata={"combinator": "ensemble", "combine": combine,
                             "stages": [s.name for s in services]})


# --------------------------------------------------------------------- #
# route: data-dependent branch selection (lax.switch -> stays on device)
# --------------------------------------------------------------------- #
def route(selector: Service, branches: Sequence[Service],
          *, name: Optional[str] = None) -> Service:
    """selector maps the input to an int32 scalar branch index; all branches
    must share input/output signatures."""
    from repro.core.compat import unify
    s0 = branches[0]
    for s in branches[1:]:
        errs = unify(s0.signature.outputs, s.signature.outputs,
                     where=f"route {s0.name} vs {s.name}")
        if errs:
            raise CompositionError("; ".join(errs))
    name = name or "route_" + "_".join(s.name for s in branches)
    params = {"selector": selector.params,
              **{f"branch{i}": s.params for i, s in enumerate(branches)}}
    bfns = [s.fn for s in branches]
    sel_fn = selector.fn

    def fn(p, x):
        idx = sel_fn(p["selector"], x)
        idx = jnp.asarray(idx, jnp.int32).reshape(())
        return jax.lax.switch(
            idx, [lambda x, i=i, f=f: f(p[f"branch{i}"], x)
                  for i, f in enumerate(bfns)], x)

    return Service(name=name, fn=fn,
                   signature=Signature(s0.signature.inputs,
                                       s0.signature.outputs),
                   params=params,
                   metadata={"combinator": "route",
                             "stages": [s.name for s in branches]})


# --------------------------------------------------------------------- #
# map_batch: lift a per-example service over a leading batch axis
# --------------------------------------------------------------------- #
def map_batch(service: Service, *, name: Optional[str] = None) -> Service:
    name = name or f"vmap_{service.name}"
    fn = jax.vmap(service.fn, in_axes=(None, 0))
    sig = Signature(
        jax.tree.map(lambda t: TensorSpec((-1,) + t.shape, t.dtype),
                     service.signature.inputs),
        jax.tree.map(lambda t: TensorSpec((-1,) + t.shape, t.dtype),
                     service.signature.outputs))
    return Service(name=name, fn=fn, signature=sig, params=service.params,
                   metadata={"combinator": "map_batch",
                             "stages": [service.name]})


# --------------------------------------------------------------------- #
# adapters: stateless glue services
# --------------------------------------------------------------------- #
def adapter(name: str, f: Callable[[Any], Any], in_spec, out_spec) -> Service:
    return Service(name=name, fn=lambda _p, x: f(x),
                   signature=Signature(in_spec, out_spec),
                   metadata={"combinator": "adapter"})


def cast_adapter(in_spec, dtype) -> Service:
    out_spec = jax.tree.map(
        lambda t: TensorSpec(t.shape, str(jnp.dtype(dtype))), in_spec)
    return adapter(f"cast_{dtype}",
                   lambda x: jax.tree.map(lambda a: a.astype(dtype), x),
                   in_spec, out_spec)


def select_adapter(in_spec, key: str) -> Service:
    """Pick one field out of a dict output."""
    return adapter(f"select_{key}", lambda x: x[key], in_spec, in_spec[key])
