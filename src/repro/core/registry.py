"""The *zoo*: a content-addressed service repository.

The paper pulls models from GitHub Gist and caches locally; here the
repository is a directory tree (the transport is pluggable — a remote repo
is just another root), with:

  <root>/<name>/<version>/manifest.json      service metadata + signature
  <root>/<name>/<version>/params.npz/.json   weights (content-hashed)

Services are rebuilt on pull through registered **builders** (entry-point
strings -> constructor). Composed services store *references* to their
stages (recursively pulled and re-composed), so published compositions
deduplicate weights — and pulling re-runs compatibility checking, the
paper's "compatibility checking" feature.
"""
from __future__ import annotations

import json
import shutil
from pathlib import Path
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax

from repro.core.compat import CompositionError
from repro.core.service import Service, Signature, TensorSpec
from repro.training.checkpoints import load_pytree, save_pytree, tree_hash

BUILDERS: Dict[str, Callable[..., Service]] = {}


def register_builder(kind: str):
    def deco(fn):
        BUILDERS[kind] = fn
        return fn
    return deco


def _sig_to_json(sig: Signature):
    def enc(tree):
        return jax.tree.map(lambda t: t.to_json(), tree)
    return {"inputs": enc(sig.inputs), "outputs": enc(sig.outputs)}


def _sig_from_json(d):
    def dec(tree):
        if isinstance(tree, dict) and set(tree) == {"shape", "dtype"}:
            return TensorSpec.from_json(tree)
        if isinstance(tree, dict):
            return {k: dec(v) for k, v in tree.items()}
        if isinstance(tree, list):
            return [dec(v) for v in tree]
        return tree
    return Signature(dec(d["inputs"]), dec(d["outputs"]))


def _sigs_equal(a: Signature, b: Signature) -> bool:
    return _sig_to_json(a) == _sig_to_json(b)


class Registry:
    def __init__(self, root):
        self.root = Path(root)
        self.root.mkdir(parents=True, exist_ok=True)

    # ------------------------------------------------------------ #
    def _dir(self, name: str, version: str) -> Path:
        return self.root / name / version

    def list(self) -> List[Tuple[str, str, str]]:
        out = []
        for manifest in sorted(self.root.glob("*/*/manifest.json")):
            with open(manifest) as f:
                m = json.load(f)
            out.append((m["name"], m["version"], m.get("description", "")))
        return out

    def versions(self, name: str) -> List[str]:
        return sorted(p.name for p in (self.root / name).glob("*")
                      if (p / "manifest.json").exists())

    # ------------------------------------------------------------ #
    def publish(self, service: Service, *, builder: str,
                config: Optional[dict] = None,
                stage_refs: Optional[List[dict]] = None,
                overwrite: bool = False) -> dict:
        """Publish a service. Leaf services need ``builder`` + ``config``
        (how to rebuild ``fn``); composed services pass
        ``builder='composed.<combinator>'`` and stage_refs."""
        d = self._dir(service.name, service.version)
        if d.exists():
            if not overwrite:
                raise FileExistsError(f"{service.name}@{service.version} "
                                      f"already published")
            shutil.rmtree(d)
        d.mkdir(parents=True)
        manifest = {
            "name": service.name,
            "version": service.version,
            "description": service.description,
            "builder": builder,
            "config": config or {},
            "signature": _sig_to_json(service.signature),
            "metadata": {k: v for k, v in service.metadata.items()
                         if isinstance(v, (str, int, float, list, dict))},
        }
        if stage_refs is not None:
            manifest["stages"] = stage_refs
            manifest["params_hash"] = None   # weights live with the stages
        elif service.params is not None:
            manifest["params_hash"] = save_pytree(d / "params",
                                                  service.params)
        else:
            manifest["params_hash"] = None
        with open(d / "manifest.json", "w") as f:
            json.dump(manifest, f, indent=1)
        return manifest

    # ------------------------------------------------------------ #
    def pull(self, name: str, version: Optional[str] = None,
             *, verify: bool = True) -> Service:
        version = version or self.versions(name)[-1]
        d = self._dir(name, version)
        with open(d / "manifest.json") as f:
            m = json.load(f)

        if m["builder"].startswith("composed."):
            from repro.core import compose
            kind = m["builder"].split(".", 1)[1]
            stages = [self.pull(r["name"], r.get("version"), verify=verify)
                      for r in m["stages"]]
            if kind == "seq":
                svc = compose.seq(*stages, name=m["name"])
            elif kind == "ensemble":
                svc = compose.ensemble(
                    stages, combine=m["config"].get("combine", "mean"),
                    name=m["name"])
            else:
                raise KeyError(f"unknown composed builder {kind}")
        else:
            if m["builder"] not in BUILDERS:
                raise KeyError(f"no builder registered for {m['builder']!r};"
                               f" import the module that defines it")
            svc = BUILDERS[m["builder"]](**m["config"])
            if m["params_hash"] is not None:
                params = load_pytree(d / "params", verify=verify)
                if verify and tree_hash(params) != m["params_hash"]:
                    raise IOError(f"{name}@{version}: params hash mismatch")
                svc = svc.with_params(params)

        # compatibility check: rebuilt signature must match the manifest
        if verify and not _sigs_equal(svc.signature,
                                      _sig_from_json(m["signature"])):
            raise CompositionError(
                f"{name}@{version}: rebuilt signature differs from "
                f"published signature — builder/config drift")
        import dataclasses as _dc
        return _dc.replace(svc, name=m["name"], version=m["version"],
                           description=m.get("description", ""))

    # ------------------------------------------------------------ #
    def publish_composed(self, service: Service, stages: List[Service],
                         *, overwrite: bool = False) -> dict:
        """Publish a composition by reference; stages are auto-published
        if absent (weights dedup across compositions)."""
        comb = service.metadata.get("combinator")
        if comb not in ("seq", "ensemble"):
            raise ValueError(f"cannot publish combinator {comb!r} by ref")
        refs = []
        for s in stages:
            if s.version not in self.versions(s.name):
                raise FileNotFoundError(
                    f"stage {s.name}@{s.version} not published; publish it "
                    f"first (weights are stored with stages)")
            refs.append({"name": s.name, "version": s.version})
        cfg = {"combine": service.metadata.get("combine", "mean")} \
            if comb == "ensemble" else {}
        return self.publish(service, builder=f"composed.{comb}",
                            config=cfg, stage_refs=refs,
                            overwrite=overwrite)
