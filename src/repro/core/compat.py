"""Static compatibility checking for service composition — the JAX analogue
of the OCaml type checking the original Zoo relied on. Composition fails
*before* compile with a precise diagnostic, not at runtime."""
from __future__ import annotations

from typing import Any, List

import jax

from repro.core.service import Signature, TensorSpec, spec_tree_of


class CompositionError(TypeError):
    pass


def _paths(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    return {tuple(str(k) for k in path): leaf for path, leaf in flat}


def unify(producer: Any, consumer: Any, *, where: str = "") -> List[str]:
    """Check a producer's output spec tree feeds a consumer's input spec
    tree. Returns a list of human-readable mismatch strings (empty = ok)."""
    errs: List[str] = []
    p, c = _paths(producer), _paths(consumer)
    if set(p) != set(c):
        missing = sorted(set(c) - set(p))
        extra = sorted(set(p) - set(c))
        if missing:
            errs.append(f"{where}: consumer expects missing fields {missing}")
        if extra:
            errs.append(f"{where}: producer has unconsumed fields {extra}")
    for k in sorted(set(p) & set(c)):
        a, b = p[k], c[k]
        if not isinstance(a, TensorSpec) or not isinstance(b, TensorSpec):
            continue
        if not a.matches(b):
            errs.append(f"{where}: field {'/'.join(k) or '<root>'} "
                        f"produces {a.shape}:{a.dtype} but consumer needs "
                        f"{b.shape}:{b.dtype}")
    return errs


def check_composable(s1, s2) -> None:
    errs = unify(s1.signature.outputs, s2.signature.inputs,
                 where=f"{s1.name} >> {s2.name}")
    if errs:
        raise CompositionError("; ".join(errs))


def check_concrete(spec_tree: Any, value_tree: Any, *, where: str = "") -> None:
    errs = unify(spec_tree_of(value_tree), spec_tree, where=where)
    if errs:
        raise CompositionError("; ".join(errs))
