"""Deployment — the second half of the paper's service definition, kept
strictly separate from functionality: the same composed service can be
placed local, remote, or split across endpoints **without changing its
structure** (the paper's step-3 property).

Endpoints:
  * ``local``  — this process; stages fuse into a single jitted program.
  * ``mesh``   — a JAX device mesh (a pod slice); jit under that mesh.
  * ``remote`` — an endpoint behind a modelled network; compute runs here
    (the container is one machine) but latency is accounted through the
    :class:`NetworkModel`, matching how the paper measured cloud calls.

Consecutive stages on the same endpoint are grouped and compiled as ONE XLA
program — composition fusion. Transfers between endpoints are charged for
the intermediate pytree bytes.
"""
from __future__ import annotations

import dataclasses
import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

import jax

from repro.core.compose import seq
from repro.core.netmodel import NetworkModel, tree_nbytes
from repro.core.service import Service


@dataclass(frozen=True)
class Endpoint:
    name: str
    kind: str = "local"                      # local | mesh | remote
    mesh: Optional[Any] = None
    network: Optional[NetworkModel] = None   # for remote
    quantize: str = ""                       # "" | "int8" | "int4": stages
                                             # placed here hold weight-
                                             # quantized params (edge
                                             # memory profile); dequant
                                             # runs inside the stage's
                                             # jitted program


@dataclass
class StageTelemetry:
    stage: str
    endpoint: str
    compute_s: float
    transfer_s: float
    precision: str = "fp"                    # endpoint's quantize profile
    param_bytes: int = 0                     # stage params as stored


@dataclass
class Telemetry:
    stages: List[StageTelemetry] = field(default_factory=list)

    @property
    def total_s(self) -> float:
        return sum(s.compute_s + s.transfer_s for s in self.stages)

    @property
    def transfer_total_s(self) -> float:
        return sum(s.transfer_s for s in self.stages)


@dataclass(frozen=True)
class DeploymentPlan:
    """stage-name -> endpoint-name; endpoints by name."""

    endpoints: Dict[str, Endpoint]
    assignments: Dict[str, str]

    @classmethod
    def all_local(cls, service: Service) -> "DeploymentPlan":
        # map the composite's own name too: non-seq combinators
        # (ensemble/route/parallel) deploy as a single stage under it
        stages = service.metadata.get("stages", []) + [service.name]
        return cls(endpoints={"local": Endpoint("local")},
                   assignments={s: "local" for s in stages})

    @classmethod
    def all_remote(cls, service: Service,
                   network: Optional[NetworkModel] = None) -> "DeploymentPlan":
        stages = service.metadata.get("stages", []) + [service.name]
        ep = Endpoint("cloud", kind="remote",
                      network=network or NetworkModel())
        return cls(endpoints={"cloud": ep},
                   assignments={s: "cloud" for s in stages})

    @classmethod
    def split(cls, service: Service, split_at: int,
              network: Optional[NetworkModel] = None) -> "DeploymentPlan":
        """First ``split_at`` stages local, rest remote (Neurosurgeon-style
        hybrid the paper cites)."""
        stages = service.metadata.get("stages") or [service.name]
        eps = {"local": Endpoint("local"),
               "cloud": Endpoint("cloud", kind="remote",
                                 network=network or NetworkModel())}
        asg = {s: ("local" if i < split_at else "cloud")
               for i, s in enumerate(stages)}
        # a non-seq combinator deploys as ONE stage under its own name
        asg.setdefault(service.name, "local" if split_at > 0 else "cloud")
        return cls(endpoints=eps, assignments=asg)

    @classmethod
    def edge_split(cls, service: Service, split_at: int,
                   quantize: str = "int4",
                   network: Optional[NetworkModel] = None
                   ) -> "DeploymentPlan":
        """The paper's step-3 property under a memory budget: the first
        ``split_at`` stages run on a local *edge* endpoint with
        weight-quantized params (int4 by default), the rest run remote in
        full precision — placement and precision change, the composed
        service's structure doesn't."""
        stages = service.metadata.get("stages") or [service.name]
        eps = {"edge": Endpoint("edge", kind="local", quantize=quantize),
               "cloud": Endpoint("cloud", kind="remote",
                                 network=network or NetworkModel())}
        asg = {s: ("edge" if i < split_at else "cloud")
               for i, s in enumerate(stages)}
        asg.setdefault(service.name, "edge" if split_at > 0 else "cloud")
        return cls(endpoints=eps, assignments=asg)


class DeployedService:
    """A composed service bound to a deployment plan."""

    def __init__(self, service: Service, plan: DeploymentPlan,
                 stages: Optional[List[Service]] = None):
        self.service = service
        self.plan = plan
        # Recover the stage list: either supplied, or treat as one stage.
        if stages is None:
            names = service.metadata.get("stages")
            if names and service.metadata.get("combinator") == "seq":
                raise ValueError("pass the component stage services for a "
                                 "seq composition")
            stages = [service]
        self.stages = stages
        self._groups = self._group()
        self._compiled: Dict[int, Any] = {}

    # -------------------------------------------------------------- #
    def _group(self) -> List[Tuple[Endpoint, List[Service]]]:
        groups: List[Tuple[Endpoint, List[Service]]] = []
        for s in self.stages:
            ep_name = self.plan.assignments.get(s.name)
            if ep_name is not None:
                # explicit assignment: a missing endpoint is a plan bug
                ep = self.plan.endpoints[ep_name]
            elif "local" in self.plan.endpoints:
                ep = self.plan.endpoints["local"]      # historical default
            elif len(self.plan.endpoints) == 1:
                # unassigned stage, sole endpoint: unambiguous
                ep = next(iter(self.plan.endpoints.values()))
            else:
                raise KeyError(
                    f"stage {s.name!r} has no endpoint assignment and the "
                    f"plan has no 'local' endpoint to default to "
                    f"(endpoints: {sorted(self.plan.endpoints)})")
            if groups and groups[-1][0].name == ep.name:
                groups[-1][1].append(s)
            else:
                groups.append((ep, [s]))
        return groups

    def _fn_for(self, gi: int):
        if gi not in self._compiled:
            ep, stages = self._groups[gi]
            svc = stages[0] if len(stages) == 1 else seq(*stages)
            if ep.quantize and svc.params is not None:
                # store the stage's params quantized (the endpoint's
                # memory budget is what the profile models) and
                # dequantize inside the jitted program — generic over any
                # service fn, and XLA fuses the dequant into consumers
                from repro.quant import dequantize_params, quantize_params
                bits = {"int8": 8, "int4": 4}[ep.quantize]
                raw_fn = svc.fn
                svc = dataclasses.replace(
                    svc, params=quantize_params(svc.params, bits=bits),
                    fn=lambda p, x, _f=raw_fn: _f(dequantize_params(p), x))
            fn = jax.jit(svc.fn)
            nbytes = tree_nbytes(svc.params) if svc.params is not None \
                else 0
            self._compiled[gi] = (svc, fn, nbytes)
        return self._compiled[gi]

    # -------------------------------------------------------------- #
    def call(self, inputs, *, queue_position: int = 0
             ) -> Tuple[Any, Telemetry]:
        telemetry = Telemetry()
        x = inputs
        for gi, (ep, stages) in enumerate(self._groups):
            svc, fn, param_bytes = self._fn_for(gi)
            payload = tree_nbytes(x)

            def run():
                t0 = time.perf_counter()
                if ep.kind == "mesh" and ep.mesh is not None:
                    with ep.mesh:
                        y = fn(svc.params, x)
                else:
                    y = fn(svc.params, x)
                y = jax.block_until_ready(y)
                return y, time.perf_counter() - t0

            y, compute_s = run()
            transfer_s = 0.0
            if ep.kind == "remote":
                # remote latency is fully modelled (RTT + payload/bw +
                # modelled server time); the local wall time merely
                # produced the result and is not charged
                transfer_s = ep.network.request_s(
                    payload, tree_nbytes(y),
                    queue_position=queue_position)
                compute_s = 0.0
            telemetry.stages.append(StageTelemetry(
                stage="+".join(s.name for s in stages), endpoint=ep.name,
                compute_s=compute_s, transfer_s=transfer_s,
                precision=ep.quantize or "fp",
                param_bytes=param_bytes))
            x = y
        return x, telemetry


def deploy(service: Service, plan: Optional[DeploymentPlan] = None,
           stages: Optional[List[Service]] = None) -> DeployedService:
    plan = plan or DeploymentPlan.all_local(service)
    return DeployedService(service, plan, stages=stages)
