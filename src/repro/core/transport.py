"""Repository transports — the paper's step-② ("pull from the remote
repository **or from peer devices** such as machine B") made concrete.

A ``Transport`` moves service directories (manifest + params files)
between a remote root and the local cache. The container has no network,
so remote transports are modelled: byte counts are real (the actual files
are copied), latency is charged through the :class:`NetworkModel`, and a
``PeerTransport`` differs from ``RepoTransport`` only in its network
parameters (LAN-ish vs WAN-ish) — matching the paper's motivation that
edge-to-edge pulls can be cheaper than cloud pulls.

Resilience (docs/robustness.md): transfers are **atomic** (copied into a
hidden temp directory, renamed into place only when complete — a reader
never observes a half-copied service) and **retried** with bounded
exponential backoff and deterministic seeded jitter when an attempt
drops or times out. Failures surface as :class:`TransportError` after
``max_retries`` extra attempts; the attempt count rides along in
``PullReport.retries``. The ``transport_drop`` / ``transport_latency``
sites of :mod:`repro.serving.faults` hook each attempt, which is how the
chaos tests exercise this path without a real flaky network.
"""
from __future__ import annotations

import os
import shutil
import time
from dataclasses import dataclass, field
from pathlib import Path
from typing import List, Optional, Tuple

import numpy as np

from repro.core.netmodel import NetworkModel
from repro.serving.faults import NoFaults


class TransportError(IOError):
    """A transfer attempt failed (drop, timeout, or filesystem error)
    and retries were exhausted."""


@dataclass
class PullReport:
    name: str
    version: str
    nbytes: int
    seconds: float
    source: str
    cached: bool = False
    retries: int = 0        # extra attempts beyond the first


class Transport:
    """Copies <root>/<name>/<version>/* into the local cache root.

    ``timeout_s`` bounds one attempt's wall clock (modelled latency
    included); ``max_retries`` bounds extra attempts; ``backoff_s`` is
    the base of the exponential backoff schedule (attempt *k* sleeps
    ``backoff_s * 2**k``, scaled by deterministic jitter in [0.5, 1.0]
    from a generator seeded per transport instance)."""

    kind = "base"

    def __init__(self, remote_root, network: Optional[NetworkModel] = None,
                 *, timeout_s: float = 30.0, max_retries: int = 3,
                 backoff_s: float = 0.02, faults=None, jitter_seed: int = 0):
        self.remote_root = Path(remote_root)
        self.network = network
        self.timeout_s = float(timeout_s)
        self.max_retries = int(max_retries)
        self.backoff_s = float(backoff_s)
        self.faults = NoFaults() if faults is None else faults
        self._jitter = np.random.default_rng(jitter_seed)

    def list_remote(self) -> List[Tuple[str, str]]:
        return sorted(
            (p.parent.parent.name, p.parent.name)
            for p in self.remote_root.glob("*/*/manifest.json"))

    # -- the retried, atomic copy ------------------------------------- #
    def _backoff(self, attempt: int) -> float:
        scale = 0.5 + 0.5 * float(self._jitter.random())
        return self.backoff_s * (2 ** attempt) * scale

    def _transfer(self, src: Path, dst: Path, op: str, what: str) -> int:
        """Copy ``src`` -> ``dst`` atomically (temp dir + rename), with
        per-attempt fault hooks, a timeout, and retried attempts.
        Returns the number of retries (extra attempts) consumed."""
        last: Optional[BaseException] = None
        for attempt in range(self.max_retries + 1):
            tmp = dst.parent / f".{dst.name}.tmp-{os.getpid()}"
            t0 = time.perf_counter()
            try:
                injected = 0.0
                if self.faults.enabled:
                    spec = self.faults.fire("transport_latency",
                                            op=op, attempt=attempt)
                    if spec is not None:
                        injected = spec.delay_s
                    if self.faults.fire("transport_drop",
                                        op=op, attempt=attempt) is not None:
                        raise TransportError(
                            f"{self.kind} {op} {what}: connection dropped"
                            " (injected fault)")
                if tmp.exists():
                    shutil.rmtree(tmp)
                shutil.copytree(src, tmp)
                elapsed = time.perf_counter() - t0 + injected
                if elapsed > self.timeout_s:
                    raise TransportError(
                        f"{self.kind} {op} {what}: attempt took "
                        f"{elapsed:.3f}s > timeout_s={self.timeout_s}")
                tmp.rename(dst)
                return attempt
            except (TransportError, OSError) as e:
                shutil.rmtree(tmp, ignore_errors=True)
                last = e
                if attempt < self.max_retries:
                    time.sleep(self._backoff(attempt))
        raise TransportError(
            f"{self.kind} {op} {what} failed after "
            f"{self.max_retries + 1} attempts: {last}") from last

    def fetch(self, name: str, version: str, cache_root) -> PullReport:
        src = self.remote_root / name / version
        if not (src / "manifest.json").exists():
            raise FileNotFoundError(f"{name}@{version} not on {self.kind}")
        dst = Path(cache_root) / name / version
        if (dst / "manifest.json").exists():
            return PullReport(name, version, 0, 0.0, self.kind, cached=True)
        dst.parent.mkdir(parents=True, exist_ok=True)
        retries = self._transfer(src, dst, "fetch", f"{name}@{version}")
        nbytes = sum(f.stat().st_size for f in dst.rglob("*") if f.is_file())
        secs = self.network.transfer_s(nbytes) if self.network else 0.0
        return PullReport(name, version, nbytes, secs, self.kind,
                          retries=retries)

    def push(self, name: str, version: str, cache_root) -> PullReport:
        src = Path(cache_root) / name / version
        dst = self.remote_root / name / version
        if dst.exists():
            raise FileExistsError(f"{name}@{version} already on {self.kind}")
        dst.parent.mkdir(parents=True, exist_ok=True)
        retries = self._transfer(src, dst, "push", f"{name}@{version}")
        nbytes = sum(f.stat().st_size for f in dst.rglob("*") if f.is_file())
        secs = self.network.transfer_s(nbytes) if self.network else 0.0
        return PullReport(name, version, nbytes, secs, self.kind,
                          retries=retries)


class RepoTransport(Transport):
    """The central model repository (the paper's Gist server A):
    WAN-class link."""

    kind = "repo"

    def __init__(self, remote_root, network: Optional[NetworkModel] = None,
                 **kw):
        super().__init__(remote_root,
                         network or NetworkModel(bandwidth_mbps=34.0,
                                                 rtt_ms=60.0, seed=1), **kw)


class PeerTransport(Transport):
    """A peer edge device (the paper's machine B): LAN-class link."""

    kind = "peer"

    def __init__(self, remote_root, network: Optional[NetworkModel] = None,
                 **kw):
        super().__init__(remote_root,
                         network or NetworkModel(bandwidth_mbps=900.0,
                                                 rtt_ms=2.0, seed=2), **kw)


@dataclass
class SyncedRegistry:
    """A local registry backed by an ordered list of transports; pulls
    try the cache, then each transport in order (peers before the repo —
    the paper's edge-first pull)."""

    cache_root: Path
    transports: List[Transport] = field(default_factory=list)

    def __post_init__(self):
        from repro.core.registry import Registry
        self.cache_root = Path(self.cache_root)
        self.local = Registry(self.cache_root)

    def pull(self, name: str, version: Optional[str] = None,
             *, verify: bool = True):
        report = None
        versions = self.local.versions(name) \
            if (self.cache_root / name).exists() else []
        if not versions or (version and version not in versions):
            for t in self.transports:
                try:
                    remote_versions = [v for n, v in t.list_remote()
                                       if n == name]
                    if not remote_versions:
                        continue
                    v = version or sorted(remote_versions)[-1]
                    report = t.fetch(name, v, self.cache_root)
                    break
                except FileNotFoundError:
                    continue
            else:
                raise FileNotFoundError(
                    f"{name} not in cache or any transport")
            # composed services: fetch stage deps too
            import json
            man = json.loads((self.cache_root / name / report.version
                              / "manifest.json").read_text())
            for ref in man.get("stages", []) or []:
                self.pull(ref["name"], ref.get("version"), verify=verify)
        svc = self.local.pull(name, version, verify=verify)
        return svc, report

    def publish(self, service, *, builder, config=None, stage_refs=None,
                push_to: Optional[Transport] = None, overwrite=False):
        man = self.local.publish(service, builder=builder, config=config,
                                 stage_refs=stage_refs, overwrite=overwrite)
        report = None
        if push_to is not None:
            report = push_to.push(service.name, service.version,
                                  self.cache_root)
        return man, report
