"""Repository transports — the paper's step-② ("pull from the remote
repository **or from peer devices** such as machine B") made concrete.

A ``Transport`` moves service directories (manifest + params files)
between a remote root and the local cache. The container has no network,
so remote transports are modelled: byte counts are real (the actual files
are copied), latency is charged through the :class:`NetworkModel`, and a
``PeerTransport`` differs from ``RepoTransport`` only in its network
parameters (LAN-ish vs WAN-ish) — matching the paper's motivation that
edge-to-edge pulls can be cheaper than cloud pulls.
"""
from __future__ import annotations

import shutil
from dataclasses import dataclass, field
from pathlib import Path
from typing import List, Optional, Tuple

from repro.core.netmodel import NetworkModel


@dataclass
class PullReport:
    name: str
    version: str
    nbytes: int
    seconds: float
    source: str
    cached: bool = False


class Transport:
    """Copies <root>/<name>/<version>/* into the local cache root."""

    kind = "base"

    def __init__(self, remote_root, network: Optional[NetworkModel] = None):
        self.remote_root = Path(remote_root)
        self.network = network

    def list_remote(self) -> List[Tuple[str, str]]:
        return sorted(
            (p.parent.parent.name, p.parent.name)
            for p in self.remote_root.glob("*/*/manifest.json"))

    def fetch(self, name: str, version: str, cache_root) -> PullReport:
        src = self.remote_root / name / version
        if not (src / "manifest.json").exists():
            raise FileNotFoundError(f"{name}@{version} not on {self.kind}")
        dst = Path(cache_root) / name / version
        if (dst / "manifest.json").exists():
            return PullReport(name, version, 0, 0.0, self.kind, cached=True)
        dst.parent.mkdir(parents=True, exist_ok=True)
        shutil.copytree(src, dst)
        nbytes = sum(f.stat().st_size for f in dst.rglob("*") if f.is_file())
        secs = self.network.transfer_s(nbytes) if self.network else 0.0
        return PullReport(name, version, nbytes, secs, self.kind)

    def push(self, name: str, version: str, cache_root) -> PullReport:
        src = Path(cache_root) / name / version
        dst = self.remote_root / name / version
        if dst.exists():
            raise FileExistsError(f"{name}@{version} already on {self.kind}")
        dst.parent.mkdir(parents=True, exist_ok=True)
        shutil.copytree(src, dst)
        nbytes = sum(f.stat().st_size for f in dst.rglob("*") if f.is_file())
        secs = self.network.transfer_s(nbytes) if self.network else 0.0
        return PullReport(name, version, nbytes, secs, self.kind)


class RepoTransport(Transport):
    """The central model repository (the paper's Gist server A):
    WAN-class link."""

    kind = "repo"

    def __init__(self, remote_root, network: Optional[NetworkModel] = None):
        super().__init__(remote_root,
                         network or NetworkModel(bandwidth_mbps=34.0,
                                                 rtt_ms=60.0, seed=1))


class PeerTransport(Transport):
    """A peer edge device (the paper's machine B): LAN-class link."""

    kind = "peer"

    def __init__(self, remote_root, network: Optional[NetworkModel] = None):
        super().__init__(remote_root,
                         network or NetworkModel(bandwidth_mbps=900.0,
                                                 rtt_ms=2.0, seed=2))


@dataclass
class SyncedRegistry:
    """A local registry backed by an ordered list of transports; pulls
    try the cache, then each transport in order (peers before the repo —
    the paper's edge-first pull)."""

    cache_root: Path
    transports: List[Transport] = field(default_factory=list)

    def __post_init__(self):
        from repro.core.registry import Registry
        self.cache_root = Path(self.cache_root)
        self.local = Registry(self.cache_root)

    def pull(self, name: str, version: Optional[str] = None,
             *, verify: bool = True):
        report = None
        versions = self.local.versions(name) \
            if (self.cache_root / name).exists() else []
        if not versions or (version and version not in versions):
            for t in self.transports:
                try:
                    remote_versions = [v for n, v in t.list_remote()
                                       if n == name]
                    if not remote_versions:
                        continue
                    v = version or sorted(remote_versions)[-1]
                    report = t.fetch(name, v, self.cache_root)
                    break
                except FileNotFoundError:
                    continue
            else:
                raise FileNotFoundError(
                    f"{name} not in cache or any transport")
            # composed services: fetch stage deps too
            import json
            man = json.loads((self.cache_root / name / report.version
                              / "manifest.json").read_text())
            for ref in man.get("stages", []) or []:
                self.pull(ref["name"], ref.get("version"), verify=verify)
        svc = self.local.pull(name, version, verify=verify)
        return svc, report

    def publish(self, service, *, builder, config=None, stage_refs=None,
                push_to: Optional[Transport] = None, overwrite=False):
        man = self.local.publish(service, builder=builder, config=config,
                                 stage_refs=stage_refs, overwrite=overwrite)
        report = None
        if push_to is not None:
            report = push_to.push(service.name, service.version,
                                  self.cache_root)
        return man, report
