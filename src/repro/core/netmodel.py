"""Analytical network cost model for remote/hybrid deployment.

The container has no network, so the paper's cloud-API comparison (Fig. 3)
is reproduced with a parameterised model: per-request RTT + payload/bandwidth
+ server time, with jitter and a congestion term that makes batch response
time grow super-linearly — the behaviour the paper measured against the
Google Vision API over a 34 Mbps uplink.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass
class NetworkModel:
    bandwidth_mbps: float = 34.0      # paper's measured uplink
    rtt_ms: float = 60.0
    server_ms: float = 350.0          # remote per-item service time
    jitter_frac: float = 0.35         # lognormal-ish multiplicative jitter
    congestion_per_item: float = 0.04 # queueing slowdown per in-flight item
    seed: int = 0

    def __post_init__(self):
        self._rng = np.random.default_rng(self.seed)

    def transfer_s(self, nbytes: int) -> float:
        base = self.rtt_ms / 1e3 + nbytes * 8 / (self.bandwidth_mbps * 1e6)
        return base * self._jitter()

    def request_s(self, payload_bytes: int, response_bytes: int,
                  queue_position: int = 0) -> float:
        """Modelled latency of one remote request."""
        congestion = 1.0 + self.congestion_per_item * queue_position
        serve = (self.server_ms / 1e3) * congestion * self._jitter()
        return (self.transfer_s(payload_bytes) + serve
                + self.transfer_s(response_bytes))

    def _jitter(self) -> float:
        return float(np.exp(self._rng.normal(0.0, self.jitter_frac)))


def tree_nbytes(tree) -> int:
    import jax
    return sum(int(np.prod(x.shape)) * np.dtype(x.dtype).itemsize
               for x in jax.tree.leaves(tree))
