"""The paper's central abstraction: a typed, composable ML *service*.

Following the paper, a service = **functionality** (a pure computational
function with a typed interaction interface) + **deployment** (interface &
location, handled in :mod:`repro.core.deploy` — deliberately separate, so a
service can move local -> remote -> split without structural change).

A ``Signature`` is a pytree of ``TensorSpec`` (shape with ``-1`` wildcards +
dtype) for inputs and outputs — the JAX analogue of the OCaml static types
the original Zoo leaned on. Composition primitives live in
:mod:`repro.core.compose`; compatibility checking in
:mod:`repro.core.compat`.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


# --------------------------------------------------------------------- #
# typed signatures
# --------------------------------------------------------------------- #
@dataclass(frozen=True)
class TensorSpec:
    """Shape/dtype spec; -1 dims are wildcards (e.g. batch)."""

    shape: Tuple[int, ...]
    dtype: str

    @classmethod
    def of(cls, x) -> "TensorSpec":
        return cls(tuple(int(s) for s in x.shape), str(jnp.dtype(x.dtype)))

    def matches(self, other: "TensorSpec") -> bool:
        if len(self.shape) != len(other.shape):
            return False
        for a, b in zip(self.shape, other.shape):
            if a != -1 and b != -1 and a != b:
                return False
        return jnp.dtype(self.dtype) == jnp.dtype(other.dtype)

    def concretize(self, x) -> bool:
        """Does a concrete array/SDS satisfy this spec?"""
        return self.matches(TensorSpec.of(x))

    def to_json(self):
        return {"shape": list(self.shape), "dtype": self.dtype}

    @classmethod
    def from_json(cls, d):
        return cls(tuple(d["shape"]), d["dtype"])


def spec_tree_of(tree) -> Any:
    """Array/ShapeDtypeStruct pytree -> TensorSpec pytree."""
    return jax.tree.map(TensorSpec.of, tree)


@dataclass(frozen=True)
class Signature:
    inputs: Any     # pytree of TensorSpec
    outputs: Any

    def to_json(self):
        def enc(tree):
            flat, treedef = jax.tree.flatten(tree)
            return {"treedef": str(treedef),
                    "leaves": [t.to_json() for t in flat]}
        return {"inputs": enc(self.inputs), "outputs": enc(self.outputs)}


# --------------------------------------------------------------------- #
# service
# --------------------------------------------------------------------- #
@dataclass(frozen=True)
class Service:
    """functionality half of a Zoo service.

    ``fn(params, inputs) -> outputs`` must be a pure, jit-able function.
    ``params`` may be ``None`` for stateless adapter services.
    """

    name: str
    fn: Callable[[Any, Any], Any]
    signature: Signature
    params: Any = None
    version: str = "0.1.0"
    description: str = ""
    metadata: Dict[str, Any] = field(default_factory=dict)

    # -- ergonomics ---------------------------------------------------- #
    def __rshift__(self, other: "Service") -> "Service":
        from repro.core.compose import seq
        return seq(self, other)

    def __call__(self, inputs, params=None):
        return self.fn(self.params if params is None else params, inputs)

    def jitted(self) -> Callable[[Any], Any]:
        fn = self.fn
        return jax.jit(lambda params, inputs: fn(params, inputs))

    def with_params(self, params) -> "Service":
        return dataclasses.replace(self, params=params)

    def check_input(self, inputs) -> None:
        from repro.core.compat import check_concrete
        check_concrete(self.signature.inputs, inputs, where=self.name)

    @property
    def n_params(self) -> int:
        if self.params is None:
            return 0
        return sum(int(np.prod(x.shape))
                   for x in jax.tree.leaves(self.params))

    def output_eval_shape(self, inputs):
        return jax.eval_shape(self.fn, self.params, inputs)


def service_from_fn(name, fn, example_in, params=None, **kw) -> Service:
    """Build a service and derive its signature via eval_shape."""
    out = jax.eval_shape(fn, params, example_in)
    sig = Signature(spec_tree_of(example_in), spec_tree_of(out))
    return Service(name=name, fn=fn, signature=sig, params=params, **kw)
