"""Per-stage instrumentation — the paper's Owl instrumentation feature
("collecting forward computation latency of each node ... took 50 LoC"):
given a composed service's stages, time each stage's compute and the
intermediate payload sizes, without changing the service itself."""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Any, List, Sequence

import jax

from repro.core.netmodel import tree_nbytes
from repro.core.service import Service


@dataclass
class StageProfile:
    stage: str
    compute_ms: float
    output_bytes: int
    n_params: int
    compile_ms: float = 0.0   # first (tracing+XLA) call minus steady median


def profile_stages(stages: Sequence[Service], inputs: Any, *,
                   iters: int = 5) -> List[StageProfile]:
    """Run the pipeline stage by stage, timing each (median of iters).
    The first call is timed too: ``compile_ms`` is its excess over the
    steady median — the one-off trace+XLA cost a cold service pays."""
    out: List[StageProfile] = []
    x = inputs
    for s in stages:
        fn = jax.jit(s.fn)
        t0 = time.perf_counter()
        y = jax.block_until_ready(fn(s.params, x))    # compile + first run
        first_ms = (time.perf_counter() - t0) * 1e3
        times = []
        for _ in range(iters):
            t0 = time.perf_counter()
            y = jax.block_until_ready(fn(s.params, x))
            times.append(time.perf_counter() - t0)
        times.sort()
        steady_ms = times[len(times) // 2] * 1e3
        out.append(StageProfile(
            stage=s.name,
            compute_ms=steady_ms,
            output_bytes=tree_nbytes(y),
            n_params=s.n_params,
            compile_ms=max(0.0, first_ms - steady_ms)))
        x = y
    return out


def format_profile(profiles: List[StageProfile]) -> str:
    total = sum(p.compute_ms for p in profiles)
    lines = [f"{'stage':40s} {'ms':>10s} {'%':>6s} {'compile ms':>11s} "
             f"{'out bytes':>12s} {'params':>10s}"]
    for p in profiles:
        lines.append(
            f"{p.stage:40s} {p.compute_ms:10.2f} "
            f"{100 * p.compute_ms / max(total, 1e-9):5.1f}% "
            f"{p.compile_ms:11.1f} "
            f"{p.output_bytes:12,d} {p.n_params:10,d}")
    lines.append(f"{'TOTAL':40s} {total:10.2f}")
    return "\n".join(lines)
