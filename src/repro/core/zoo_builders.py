"""Builders that wrap framework models as zoo services.

These are the analogues of the paper's deployment example:
``image classifier (InceptionV3) >> label decoder`` becomes
``embedding classifier (assigned-arch backbone) >> label decoder``.
Importing this module registers the builders with the registry.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.core.registry import register_builder
from repro.core.service import (Service, Signature, TensorSpec,
                                spec_tree_of)
from repro.models import layers as L
from repro.models import transformer as T
from repro.models.model import build


@register_builder("model.lm")
def lm_service(arch: str, variant: str = "", batch: int = -1,
               seq: int = -1) -> Service:
    """Next-token-logits service: {'tokens'} -> logits (B, L, V)."""
    cfg = get_arch(arch, variant=variant)
    model = build(cfg)

    def fn(params, inputs):
        logits, _ = T.forward_train(params, cfg, inputs["tokens"])
        return logits

    sig = Signature({"tokens": TensorSpec((batch, seq), "int32")},
                    TensorSpec((batch, seq, cfg.vocab), "float32"))
    return Service(name=f"lm_{arch}", fn=fn, signature=sig,
                   description=f"next-token logits for {arch}",
                   metadata={"arch": arch, "variant": variant,
                             "builder": "model.lm"})


@register_builder("model.classifier")
def classifier_service(arch: str, n_classes: int, variant: str = "reduced",
                       n_tokens: Optional[int] = None,
                       d_embed: Optional[int] = None) -> Service:
    """Embedding classifier (the InceptionV3 analogue): consumes frontend
    patch/frame embeddings, mean-pools the backbone output, projects to
    class logits. ``init_params(key)`` hangs off the service metadata."""
    cfg = get_arch(arch, variant=variant)
    assert cfg.frontend is not None, f"{arch} has no frontend stub"
    n_tokens = n_tokens or cfg.frontend.n_tokens
    d_embed = d_embed or cfg.frontend.d_embed

    def fn(params, inputs):
        x = T.embed_inputs(params["backbone"], cfg,
                           embeddings=inputs["embeddings"])
        x, _, _ = T._scan_blocks(params["backbone"], x, cfg, mode="train")
        x = L.rms_norm(params["backbone"]["ln_f"], x, cfg.norm_eps)
        pooled = jnp.mean(x.astype(jnp.float32), axis=1)
        return L.linear(params["head"], pooled)

    def init_params(key):
        k1, k2 = jax.random.split(key)
        return {"backbone": T.init_transformer(k1, cfg),
                "head": L.init_linear(k2, cfg.d_model, n_classes,
                                      jnp.float32)}

    sig = Signature(
        {"embeddings": TensorSpec((-1, n_tokens, d_embed), str(cfg.dtype))},
        TensorSpec((-1, n_classes), "float32"))
    return Service(name=f"classify_{arch}", fn=fn, signature=sig,
                   description=f"{arch} backbone patch-embedding classifier "
                               f"({n_classes} classes)",
                   metadata={"arch": arch, "variant": variant,
                             "n_classes": n_classes,
                             "init_params": init_params,
                             "builder": "model.classifier"})


@register_builder("adapter.label_decoder")
def label_decoder(n_classes: int) -> Service:
    """The paper's 'decoding service for ImageNet': class vector ->
    {class_id, confidence} in human-consumable form."""
    def fn(_params, logits):
        probs = jax.nn.softmax(logits, axis=-1)
        return {"class_id": jnp.argmax(probs, axis=-1).astype(jnp.int32),
                "confidence": jnp.max(probs, axis=-1)}

    sig = Signature(
        TensorSpec((-1, n_classes), "float32"),
        {"class_id": TensorSpec((-1,), "int32"),
         "confidence": TensorSpec((-1,), "float32")})
    return Service(name="label_decoder", fn=fn, signature=sig,
                   description="argmax + confidence label decoding",
                   metadata={"builder": "adapter.label_decoder"})


@register_builder("adapter.topk_decoder")
def topk_decoder(n_classes: int, k: int = 5) -> Service:
    def fn(_params, logits):
        probs = jax.nn.softmax(logits, axis=-1)
        vals, idx = jax.lax.top_k(probs, k)
        return {"class_ids": idx.astype(jnp.int32), "confidences": vals}

    sig = Signature(
        TensorSpec((-1, n_classes), "float32"),
        {"class_ids": TensorSpec((-1, k), "int32"),
         "confidences": TensorSpec((-1, k), "float32")})
    return Service(name=f"top{k}_decoder", fn=fn, signature=sig,
                   metadata={"builder": "adapter.topk_decoder"})
