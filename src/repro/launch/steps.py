"""Shared step-construction for launchers and the dry-run: resolve an
(arch x input-shape) pair to (step_fn, sharded input ShapeDtypeStructs)."""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs import SHAPES, get_arch
from repro.configs.base import ModelConfig, ShapeConfig
from repro.distribution.sharding import (add_zero_sharding, batch_shardings,
                                         cache_shardings,
                                         default_activation_rules,
                                         param_shardings)
from repro.launch.mesh import batch_axes as mesh_batch_axes
from repro.models.model import Model, build
from repro.training.optimizer import AdamW, cosine_schedule
from repro.training.train_loop import make_train_step


class ShapeSkip(Exception):
    """This (arch x shape) pair is skipped by design (see DESIGN.md)."""


def resolve_config(arch: str, shape_name: str) -> ModelConfig:
    shape = SHAPES[shape_name]
    cfg = get_arch(arch)
    if shape_name == "long_500k":
        if cfg.family == "encdec":
            raise ShapeSkip("enc-dec speech decoder: 512k-token decode is "
                            "out of the model family's envelope (DESIGN.md)")
        if cfg.family in ("dense", "vlm") and not cfg.sliding_window:
            # sub-quadratic requirement: sliding-window variant
            cfg = get_arch(arch, variant="swa")
    if shape.mode == "train":
        cfg = cfg.replace(remat=True)
    return cfg


def _with_shardings(shapes_tree, shardings_tree):
    return jax.tree.map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        shapes_tree, shardings_tree)


def depth_counts(cfg: ModelConfig) -> Dict[str, int]:
    """Scan trip counts, per scan unit (used for cost extrapolation —
    XLA cost analysis counts a while-loop body once)."""
    from repro.models.transformer import n_blocks
    if cfg.family == "encdec":
        return {"enc": cfg.encoder.n_layers, "dec": cfg.n_layers}
    return {"blocks": n_blocks(cfg)}


def with_depth(cfg: ModelConfig, counts: Dict[str, int]) -> ModelConfig:
    from repro.models.transformer import block_spec
    if cfg.family == "encdec":
        return cfg.replace(
            n_layers=counts["dec"],
            encoder=dataclasses.replace(cfg.encoder,
                                        n_layers=counts["enc"]))
    return cfg.replace(n_layers=counts["blocks"] * len(block_spec(cfg)))


def apply_opts(cfg: ModelConfig, opts: Dict[str, Any]) -> ModelConfig:
    """Optimization knobs explored in §Perf (beyond the paper-faithful
    baseline)."""
    if opts.get("moe_group") and cfg.moe is not None:
        cfg = cfg.replace(moe=dataclasses.replace(cfg.moe,
                                                  group_routing=True))
    if opts.get("ssd_chunk") and cfg.ssm is not None:
        cfg = cfg.replace(ssm=dataclasses.replace(cfg.ssm,
                                                  chunk=opts["ssd_chunk"]))
    if opts.get("attn_block"):
        cfg = cfg.replace(attn_block=opts["attn_block"])
    if opts.get("kv_quant"):
        cfg = cfg.replace(kv_quant=True)
    return cfg


def build_step(arch: str, shape_name: str, mesh, *, zero: bool = False,
               microbatch: int = 0, cfg_transform=None, opts=None
               ) -> Tuple[Any, Tuple, ModelConfig, Dict[str, Any]]:
    """Returns (step_fn, sharded_arg_specs, cfg, info)."""
    opts = opts or {}
    shape = SHAPES[shape_name]
    cfg = resolve_config(arch, shape_name)
    cfg = apply_opts(cfg, opts)
    if cfg_transform is not None:
        cfg = cfg_transform(cfg)
    model = build(cfg)
    b_axes = mesh_batch_axes(mesh)
    specs = model.input_specs(shape)
    info: Dict[str, Any] = {"mode": shape.mode, "variant":
                            ("swa" if cfg.sliding_window else "")}

    # long-context batch=1: shard the KV sequence instead of batch
    seq_axis = "data" if (shape.is_decode and shape.global_batch == 1) \
        else None
    if opts.get("kv_seq_shard") and shape.is_decode:
        # §Perf: KV-sequence sharding over the (otherwise idle for the
        # cache) model axis — wins when n_kv_heads < mesh model size
        seq_axis = ("data", "model") if seq_axis else "model"

    if shape.mode == "train":
        opt = AdamW(lr=cosine_schedule(3e-4, 100, 10_000))
        step_fn = make_train_step(model, opt, microbatch=microbatch,
                                  unroll_micro=opts.get("unroll_micro",
                                                        False))
        state_shapes = jax.eval_shape(
            lambda k: {"params": model.init(k),
                       "opt": opt.init(model.init(k)),
                       "step": jnp.zeros((), jnp.int32)},
            jax.random.PRNGKey(0))
        state_sh = param_shardings(state_shapes, mesh)
        if zero:
            opt_sh = {"m": add_zero_sharding(state_sh["opt"]["m"],
                                             state_shapes["opt"]["m"], mesh,
                                             zero_axes=b_axes),
                      "v": add_zero_sharding(state_sh["opt"]["v"],
                                             state_shapes["opt"]["v"], mesh,
                                             zero_axes=b_axes),
                      "step": state_sh["opt"]["step"]}
            par_sh = add_zero_sharding(state_sh["params"],
                                       state_shapes["params"], mesh,
                                       zero_axes=b_axes)
            state_sh = {"params": par_sh, "opt": opt_sh,
                        "step": state_sh["step"]}
        batch_sh = batch_shardings(specs["batch"], mesh, b_axes)
        args = (_with_shardings(state_shapes, state_sh),
                _with_shardings(specs["batch"], batch_sh))
        return step_fn, args, cfg, info

    params_shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    rules = None
    if opts.get("flat_model") and shape.is_decode \
            and shape.global_batch == 1:
        # batch=1: the data axis is idle for params — flatten (data, model)
        # into one 256-way model axis so weights shard 16x further
        from repro.distribution.sharding import default_param_rules
        rules = default_param_rules(model_axis=tuple(mesh.axis_names))
    par_sh = param_shardings(params_shapes, mesh, rules=rules)
    params_sds = _with_shardings(params_shapes, par_sh)

    if shape.mode == "prefill":
        batch_sh = batch_shardings(specs["batch"], mesh, b_axes)
        cache_sh = cache_shardings(specs["cache"], mesh, b_axes,
                                   seq_axis=seq_axis)
        args = (params_sds,
                _with_shardings(specs["batch"], batch_sh),
                _with_shardings(specs["cache"], cache_sh))
        return model.prefill, args, cfg, info

    # decode
    token_sh = batch_shardings(specs["token"], mesh, b_axes)
    cache_sh = cache_shardings(specs["cache"], mesh, b_axes,
                               seq_axis=seq_axis)
    args = (params_sds,
            _with_shardings(specs["token"], token_sh),
            _with_shardings(specs["cache"], cache_sh))
    return model.decode_step, args, cfg, info


def activation_rules_for(mesh, shape: ShapeConfig):
    b_axes = mesh_batch_axes(mesh)
    return default_activation_rules(batch_axes=b_axes)
