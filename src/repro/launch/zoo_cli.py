"""Zoo command line — the paper's "deploy with one line of command".

  python -m repro.launch.zoo_cli init-demo  --zoo /tmp/zoo
  python -m repro.launch.zoo_cli list       --zoo /tmp/zoo
  python -m repro.launch.zoo_cli pull       --zoo /tmp/zoo --name <svc>
  python -m repro.launch.zoo_cli compose    --zoo /tmp/zoo \
        --stages classify_pixtral-12b,label_decoder --name my_pipeline
  python -m repro.launch.zoo_cli deploy     --zoo /tmp/zoo --name my_pipeline \
        [--placement local|remote|split:K] [--batch 4]

``--peer DIR`` / ``--repo DIR`` register transports (peers are tried
first, like the paper's edge-first pull).
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path

import numpy as np


def _registry(args):
    from repro.core.transport import (PeerTransport, RepoTransport,
                                      SyncedRegistry)
    transports = []
    for peer in args.peer or []:
        transports.append(PeerTransport(peer))
    for repo in args.repo or []:
        transports.append(RepoTransport(repo))
    return SyncedRegistry(Path(args.zoo), transports)


def cmd_init_demo(args):
    """Populate the zoo with the deployment-example services."""
    import jax
    import repro.core.zoo_builders as zb
    reg = _registry(args)
    clf = zb.classifier_service("pixtral-12b", n_classes=args.n_classes)
    clf = clf.with_params(
        clf.metadata["init_params"](jax.random.PRNGKey(args.seed)))
    dec = zb.label_decoder(args.n_classes)
    reg.publish(clf, builder="model.classifier",
                config={"arch": "pixtral-12b",
                        "n_classes": args.n_classes}, overwrite=True)
    reg.publish(dec, builder="adapter.label_decoder",
                config={"n_classes": args.n_classes}, overwrite=True)
    print(f"published {clf.name}@{clf.version}, {dec.name}@{dec.version} "
          f"-> {args.zoo}")


def cmd_list(args):
    reg = _registry(args)
    rows = reg.local.list()
    for t in reg.transports:
        rows += [(n, v, f"[{t.kind}]") for n, v in t.list_remote()]
    for name, version, desc in rows:
        print(f"{name:45s} {version:8s} {desc}")


def cmd_pull(args):
    import repro.core.zoo_builders  # noqa: F401  (registers builders)
    reg = _registry(args)
    svc, report = reg.pull(args.name, args.version or None)
    print(f"pulled {svc.name}@{svc.version} "
          f"({svc.n_params/1e6:.1f}M params)")
    if report and not report.cached:
        print(f"  via {report.source}: {report.nbytes/2**20:.1f} MiB, "
              f"modelled transfer {report.seconds:.2f}s")


def cmd_compose(args):
    import repro.core.zoo_builders  # noqa: F401
    from repro.core.compose import seq
    reg = _registry(args)
    stages = [reg.pull(s)[0] for s in args.stages.split(",")]
    svc = seq(*stages, name=args.name)
    reg.local.publish_composed(svc, stages, overwrite=True)
    print(f"composed {args.name} = {' >> '.join(s.name for s in stages)}; "
          f"signature checked and published")


def cmd_deploy(args):
    import jax
    import jax.numpy as jnp
    import repro.core.zoo_builders  # noqa: F401
    from repro.core.deploy import DeploymentPlan, deploy
    reg = _registry(args)
    svc, _ = reg.pull(args.name)
    # reconstruct stages for placement (composed services carry refs)
    man = json.loads((Path(args.zoo) / svc.name / svc.version
                      / "manifest.json").read_text())
    stages = [reg.pull(r["name"], r.get("version"))[0]
              for r in man.get("stages", [])] or None

    if args.placement == "local":
        plan = DeploymentPlan.all_local(svc)
    elif args.placement == "remote":
        plan = DeploymentPlan.all_remote(svc)
    elif args.placement.startswith("split:"):
        plan = DeploymentPlan.split(svc, int(args.placement.split(":")[1]))
    else:
        raise SystemExit(f"unknown placement {args.placement}")
    deployed = deploy(svc, plan, stages=stages)

    # drive it with a demo batch derived from the input signature
    spec = jax.tree.leaves(svc.signature.inputs)[0]
    shape = tuple(args.batch if d == -1 else d for d in spec.shape)
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(0, 1, shape), spec.dtype) \
        if "float" in spec.dtype else \
        jnp.asarray(rng.integers(0, 100, shape), spec.dtype)
    inputs = jax.tree.map(lambda s: x, svc.signature.inputs)
    out, tel = deployed.call(inputs)
    print(f"deployed {svc.name} [{args.placement}]")
    for s in tel.stages:
        print(f"  {s.stage:45s} @{s.endpoint:6s} "
              f"compute={s.compute_s*1e3:8.2f}ms "
              f"network={s.transfer_s*1e3:8.2f}ms")
    print(f"  total {tel.total_s*1e3:.2f}ms; outputs: "
          f"{jax.tree.map(lambda y: tuple(y.shape), out)}")


def main(argv=None):
    ap = argparse.ArgumentParser(prog="zoo")
    ap.add_argument("--zoo", default=str(Path.home() / ".repro_zoo"))
    ap.add_argument("--peer", action="append", default=[])
    ap.add_argument("--repo", action="append", default=[])
    sub = ap.add_subparsers(dest="cmd", required=True)

    p = sub.add_parser("init-demo")
    p.add_argument("--n-classes", type=int, default=1000)
    p.add_argument("--seed", type=int, default=0)
    sub.add_parser("list")
    p = sub.add_parser("pull")
    p.add_argument("--name", required=True)
    p.add_argument("--version", default="")
    p = sub.add_parser("compose")
    p.add_argument("--stages", required=True)
    p.add_argument("--name", required=True)
    p = sub.add_parser("deploy")
    p.add_argument("--name", required=True)
    p.add_argument("--placement", default="local")
    p.add_argument("--batch", type=int, default=4)

    args = ap.parse_args(argv)
    {"init-demo": cmd_init_demo, "list": cmd_list, "pull": cmd_pull,
     "compose": cmd_compose, "deploy": cmd_deploy}[args.cmd](args)


if __name__ == "__main__":
    main()
