"""Production mesh definition (TPU v5e pods).

A function, not a module-level constant — importing this module must never
touch jax device state.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; multi_pod adds a leading 2-pod axis."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def batch_axes(mesh) -> tuple:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def make_serving_mesh(spec: str = "auto"):
    """Serving mesh from a spec string — the layout the ``Engine``'s
    param/cache/decode-state shardings assume, always ("data", "model").

    * ``"auto"`` (or ``""``): all local devices on the model axis,
      shape ``(1, n_devices)`` — pure tensor parallelism, the
      memory-bound serving default (weights and KV heads split n ways);
    * ``"dp,mp"`` (e.g. ``"2,4"``; ``"2x4"`` also accepted): explicit
      (data, model) axis sizes — batch slots shard over data, weights
      and KV heads over model.
    """
    if spec in ("", "auto"):
        shape = (1, len(jax.devices()))
    else:
        parts = [int(x) for x in spec.replace("x", ",").split(",")]
        if len(parts) != 2 or any(p < 1 for p in parts):
            raise ValueError(f"mesh spec {spec!r}: want 'dp,mp', "
                             f"e.g. '2,4', or 'auto'")
        shape = tuple(parts)
    return jax.make_mesh(shape, ("data", "model"))


# TPU v5e hardware constants (per chip) used by the roofline analysis.
HW = {
    "peak_flops_bf16": 197e12,    # FLOP/s
    "hbm_bandwidth": 819e9,       # B/s
    "ici_link_bandwidth": 50e9,   # B/s per link
    "hbm_bytes": 16 * 1024**3,    # 16 GB
}
