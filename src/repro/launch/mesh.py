"""Production mesh definition (TPU v5e pods).

A function, not a module-level constant — importing this module must never
touch jax device state.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; multi_pod adds a leading 2-pod axis."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def batch_axes(mesh) -> tuple:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


# TPU v5e hardware constants (per chip) used by the roofline analysis.
HW = {
    "peak_flops_bf16": 197e12,    # FLOP/s
    "hbm_bandwidth": 819e9,       # B/s
    "ici_link_bandwidth": 50e9,   # B/s per link
    "hbm_bytes": 16 * 1024**3,    # 16 GB
}
