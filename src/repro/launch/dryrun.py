import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: prove every (architecture x input-shape x mesh)
combination lowers, SPMD-partitions, and compiles on the production mesh —
and extract the cost/memory/collective numbers the roofline analysis reads.

MUST be run as its own process (it forces 512 host platform devices before
any other jax import — do NOT set that flag globally).

Usage:
  python -m repro.launch.dryrun --arch llama3.2-1b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--zero]
"""

import argparse
import json
import time
import traceback
from pathlib import Path

import jax

from repro.configs import ARCHS, SHAPES
from repro.configs.extra import EXTRA_ARCHS
from repro.distribution.hlo_analysis import (collective_bytes,
                                             total_collective_bytes)
from repro.distribution.sharding import activation_sharding
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import ShapeSkip, activation_rules_for, build_step

OUT_DIR = Path(__file__).resolve().parents[3] / "experiments" / "dryrun"


def _cost_dict(compiled):
    try:
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):
            ca = ca[0]
        return {k: float(v) for k, v in ca.items()
                if isinstance(v, (int, float))}
    except Exception as e:  # pragma: no cover
        return {"error": str(e)}


def _memory_dict(compiled):
    out = {}
    try:
        ma = compiled.memory_analysis()
        for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                     "temp_size_in_bytes", "alias_size_in_bytes",
                     "generated_code_size_in_bytes"):
            v = getattr(ma, attr, None)
            if v is not None:
                out[attr] = int(v)
    except Exception as e:  # pragma: no cover
        out["error"] = str(e)
    return out


def _compile_and_measure(arch, shape_name, mesh, *, zero, microbatch,
                         cfg_transform=None, opts=None):
    step_fn, args, cfg, info = build_step(arch, shape_name, mesh, zero=zero,
                                          microbatch=microbatch,
                                          cfg_transform=cfg_transform,
                                          opts=opts)
    shape = SHAPES[shape_name]
    rules = activation_rules_for(mesh, shape)
    with mesh, activation_sharding(mesh, rules):
        lowered = jax.jit(step_fn).lower(*args)
        compiled = lowered.compile()
    return compiled, cfg, info


_EXTRAP_KEYS = ("flops", "bytes accessed")


def _measures_of(compiled):
    cost = _cost_dict(compiled)
    coll = collective_bytes(compiled.as_text())
    m = {k: cost.get(k, 0.0) for k in _EXTRAP_KEYS}
    for k, v in coll.items():
        m[f"coll:{k}"] = float(v)
    return m


def calibrated_costs(arch, shape_name, mesh, *, zero, microbatch,
                     opts=None):
    """XLA cost analysis counts scan bodies ONCE; recover true totals by
    compiling depth-1 and depth-2 variants and extrapolating the linear
    model  cost(depth) = a + depth·b  to the real depth (per scan unit)."""
    from repro.launch.steps import depth_counts, resolve_config, with_depth
    cfg_full = resolve_config(arch, shape_name)
    counts = depth_counts(cfg_full)
    base = {k: 1 for k in counts}

    def xform(probe):
        # unroll_layers=True: no while loop -> exact op counts at shallow
        # depth; linear in each scan unit by construction.
        return lambda c: with_depth(c, probe).replace(unroll_layers=True)

    # The microbatch accumulation scan is also counted once; treat the
    # number of microbatches as another linear unit (compile with 1 and 2
    # unrolled microbatches, extrapolate to the real count).
    opts = dict(opts or {})
    gb = SHAPES[shape_name].global_batch
    n_micro = gb // microbatch if microbatch else 0
    if microbatch:
        opts["unroll_micro"] = True
        counts = dict(counts)
        counts["__micro__"] = n_micro

    def measure(probe):
        mb = microbatch
        if microbatch:
            mb = gb // probe.get("__micro__", 1)
        depth_probe = {k: v for k, v in probe.items() if k != "__micro__"}
        compiled, _, _ = _compile_and_measure(
            arch, shape_name, mesh, zero=zero, microbatch=mb,
            cfg_transform=xform(depth_probe), opts=opts)
        return _measures_of(compiled)

    base = {k: 1 for k in counts}
    f11 = measure(base)
    keys = lambda *fs: set().union(*fs)

    if microbatch and len(counts) == 2:
        # bilinear fit f(d, m) = a + d·p + m·q + d·m·r  (the layer body
        # lives INSIDE the microbatch body, so the cross term dominates)
        (dunit,) = [u for u in counts if u != "__micro__"]
        D, M = counts[dunit], counts["__micro__"]
        f21 = measure({dunit: 2, "__micro__": 1})
        f12 = measure({dunit: 1, "__micro__": 2})
        f22 = measure({dunit: 2, "__micro__": 2})
        extrap = {}
        for k in keys(f11, f21, f12, f22):
            v11, v21 = f11.get(k, 0.0), f21.get(k, 0.0)
            v12, v22 = f12.get(k, 0.0), f22.get(k, 0.0)
            r = v22 - v21 - v12 + v11
            p = v21 - v11 - r
            q = v12 - v11 - r
            a = v11 - p - q - r
            extrap[k] = max(a + D * p + M * q + D * M * r, v11)
        return extrap, counts

    extrap = dict(f11)
    for unit in counts:
        probe = dict(base)
        probe[unit] = 2
        f2 = measure(probe)
        for k in keys(f11, f2):
            # clamp: partitioner choices can differ between depths (e.g.
            # an all-gather hoisted at depth 1 but not 2) — a negative
            # slope is an artifact, not a real per-layer saving
            slope = max(f2.get(k, 0.0) - f11.get(k, 0.0), 0.0)
            extrap[k] = extrap.get(k, 0.0) + slope * (counts[unit] - 1)
    return extrap, counts


def run_one(arch: str, shape_name: str, *, multi_pod: bool = False,
            zero: bool = False, out_dir: Path = OUT_DIR,
            tag: str = "", microbatch: int = 0, verbose: bool = True,
            calibrate: bool = True, opts=None):
    mesh_name = "2x16x16" if multi_pod else "16x16"
    label = f"{arch} x {shape_name} x {mesh_name}" + (f" [{tag}]" if tag else "")
    t0 = time.perf_counter()
    mesh = make_production_mesh(multi_pod=multi_pod)
    try:
        compiled, cfg, info = _compile_and_measure(
            arch, shape_name, mesh, zero=zero, microbatch=microbatch,
            opts=opts)
    except ShapeSkip as e:
        if verbose:
            print(f"SKIP  {label}: {e}")
        return {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                "status": "skip", "reason": str(e)}

    cost = _cost_dict(compiled)
    memory = _memory_dict(compiled)
    coll = collective_bytes(compiled.as_text())
    extrap = None
    if calibrate:
        extrap, _ = calibrated_costs(arch, shape_name, mesh, zero=zero,
                                     microbatch=microbatch, opts=opts)
    elapsed = time.perf_counter() - t0

    def pick(key, raw):
        return extrap.get(key, raw) if extrap is not None else raw

    coll_extrap = {k.split("coll:", 1)[1]: v
                   for k, v in (extrap or {}).items()
                   if k.startswith("coll:")}
    rec = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "status": "ok", "mode": info["mode"], "variant": info["variant"],
        "zero": zero, "tag": tag, "microbatch": microbatch,
        "n_devices": mesh.devices.size,
        # loop-calibrated (scan bodies × trip count) when available
        "flops_per_device": pick("flops", cost.get("flops", 0.0)),
        "bytes_per_device": pick("bytes accessed",
                                 cost.get("bytes accessed", 0.0)),
        "flops_per_device_raw": cost.get("flops", 0.0),
        "bytes_per_device_raw": cost.get("bytes accessed", 0.0),
        "cost_analysis": cost,
        "memory_analysis": memory,
        "collectives_raw": coll,
        "collectives": coll_extrap or coll,
        "collective_bytes_per_device":
            total_collective_bytes(coll_extrap or coll),
        "compile_s": elapsed,
    }
    if verbose:
        print(f"OK    {label}: flops/dev={rec['flops_per_device']:.3e} "
              f"bytes/dev={rec['bytes_per_device']:.3e} "
              f"coll/dev={rec['collective_bytes_per_device']:.3e} "
              f"compile={elapsed:.1f}s")
        if memory:
            print(f"      memory_analysis: {memory}")
    out_dir.mkdir(parents=True, exist_ok=True)
    suffix = ("__" + tag) if tag else ""
    fname = f"{arch}__{shape_name}__{mesh_name}{suffix}.json"
    with open(out_dir / fname, "w") as f:
        json.dump(rec, f, indent=1)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ARCHS) + sorted(EXTRA_ARCHS),
                    default=None)
    ap.add_argument("--shape", choices=sorted(SHAPES), default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--zero", action="store_true",
                    help="ZeRO/FSDP sharding of params+optimizer over data")
    ap.add_argument("--microbatch", type=int, default=0)
    ap.add_argument("--no-calibrate", action="store_true")
    ap.add_argument("--moe-group", action="store_true",
                    help="§Perf: data-local grouped MoE routing")
    ap.add_argument("--ssd-chunk", type=int, default=0,
                    help="§Perf: override the SSD chunk length")
    ap.add_argument("--kv-seq-shard", action="store_true",
                    help="§Perf: shard decode KV caches on sequence over "
                         "the model axis")
    ap.add_argument("--attn-block", type=int, default=0,
                    help="§Perf: chunked causal attention block size "
                         "(skips above-diagonal score blocks)")
    ap.add_argument("--kv-quant", action="store_true",
                    help="§Perf: int8 KV cache with per-slot-head scales")
    ap.add_argument("--flat-model", action="store_true",
                    help="§Perf: for batch=1 decode, flatten (data, model) "
                         "into one model axis for parameter sharding")
    ap.add_argument("--tag", default="")
    ap.add_argument("--out-dir", default=str(OUT_DIR))
    args = ap.parse_args()

    archs = sorted(ARCHS) if args.all or not args.arch else [args.arch]
    shapes = sorted(SHAPES) if args.all or not args.shape else [args.shape]
    meshes = [False, True] if (args.both_meshes or
                               (args.all and not args.multi_pod)) \
        else [args.multi_pod]

    failures = []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                try:
                    opts = {"moe_group": args.moe_group,
                            "ssd_chunk": args.ssd_chunk,
                            "kv_seq_shard": args.kv_seq_shard,
                            "attn_block": args.attn_block,
                            "kv_quant": args.kv_quant,
                            "flat_model": args.flat_model}
                    run_one(arch, shape, multi_pod=mp, zero=args.zero,
                            out_dir=Path(args.out_dir), tag=args.tag,
                            microbatch=args.microbatch,
                            calibrate=not args.no_calibrate, opts=opts)
                except Exception as e:
                    failures.append((arch, shape, mp, repr(e)))
                    print(f"FAIL  {arch} x {shape} x "
                          f"{'2x16x16' if mp else '16x16'}: {e}")
                    traceback.print_exc()
    if failures:
        raise SystemExit(f"{len(failures)} dry-run failures: {failures}")


if __name__ == "__main__":
    main()
