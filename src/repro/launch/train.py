"""End-to-end training driver.

Runs for real on whatever devices exist (CPU at reduced scale; the
production mesh on TPU). Examples:

  PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b \
      --variant reduced --steps 200 --batch 16 --seq 128
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import jax
import numpy as np

from repro.configs import ARCHS, get_arch
from repro.data.pipeline import MarkovLM, batches_for
from repro.models.model import build
from repro.training.checkpoints import save_train_state
from repro.training.optimizer import AdamW, cosine_schedule
from repro.training.train_loop import init_train_state, make_train_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ARCHS), default="llama3.2-1b")
    ap.add_argument("--variant", default="reduced")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--warmup", type=int, default=20)
    ap.add_argument("--microbatch", type=int, default=0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--save", default="")
    ap.add_argument("--metrics", default="",
                    help="JSONL metrics path (machine-readable run log)")
    args = ap.parse_args(argv)

    cfg = get_arch(args.arch, variant=args.variant)
    model = build(cfg)
    opt = AdamW(lr=cosine_schedule(args.lr, args.warmup, args.steps))
    data = batches_for(cfg, args.batch, args.seq, seed=args.seed)

    state = init_train_state(model, opt, jax.random.PRNGKey(args.seed))
    n_params = sum(int(np.prod(p.shape))
                   for p in jax.tree.leaves(state["params"]))
    floor = MarkovLM(cfg.vocab).entropy_bound()
    print(f"arch={cfg.name} params={n_params/1e6:.1f}M "
          f"devices={jax.device_count()} loss_floor~{floor:.3f}")

    from repro.training.metrics import MetricsLogger
    mlog = MetricsLogger(args.metrics or None, run_name=cfg.name)
    step_fn = jax.jit(make_train_step(model, opt,
                                      microbatch=args.microbatch))
    t0 = time.perf_counter()
    history = []
    for i in range(args.steps):
        batch = next(data)
        state, metrics = step_fn(state, batch)
        if i % args.log_every == 0 or i == args.steps - 1:
            m = {k: float(v) for k, v in metrics.items()}
            wall = time.perf_counter() - t0
            tok_s = (i + 1) * args.batch * args.seq / wall
            print(f"step {i:5d} loss={m['loss']:.4f} "
                  f"grad_norm={m['grad_norm']:.3f} lr={m['lr']:.2e} "
                  f"tok/s={tok_s:,.0f}")
            history.append({"step": i, **m, "wall_s": wall})
            mlog.log("train", step=i, tok_s=tok_s, **m)
    mlog.close()
    if args.save:
        save_train_state(args.save, args.steps, state["params"],
                         state["opt"])
        with open(Path(args.save) / "history.json", "w") as f:
            json.dump(history, f, indent=1)
        print(f"saved to {args.save}")
    return history


if __name__ == "__main__":
    main()
