"""End-to-end serving driver: batched requests through the engine.

  PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b \
      --variant reduced --requests 16 --max-new 24
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import ARCHS, get_arch
from repro.models.model import build
from repro.serving.engine import Engine
from repro.serving.faults import Faults
from repro.serving.request import Request
from repro.serving.sampler import Sampler


def _engine_kwargs(args):
    """Engine knobs shared by the single-engine and fleet paths (the
    fleet owns ``recorder``/``faults``/``trace_dir`` itself)."""
    return dict(max_batch=args.max_batch, cache_len=args.cache_len,
                sampler=Sampler(temperature=args.temperature, top_k=32),
                seed=args.seed, sync_every=args.sync_every,
                kv_cache_dtype=args.kv_cache_dtype,
                prefill_chunk=None if args.prefill_chunk < 0
                else args.prefill_chunk,
                prefix_cache_tokens=None if args.prefix_cache_tokens < 0
                else args.prefix_cache_tokens,
                paged=args.paged, page_size=args.page_size,
                num_pages=args.num_pages or None,
                mesh=args.mesh or None)


def _parse_drains(spec):
    """'rid@seconds[,rid@seconds...]' -> [(seconds, rid)] sorted."""
    plan = []
    for part in filter(None, (p.strip() for p in spec.split(","))):
        rid, sep, at = part.partition("@")
        try:
            if not sep:
                raise ValueError(part)
            plan.append((float(at), int(rid)))
        except ValueError:
            raise SystemExit(f"--drain: bad entry {part!r}, want "
                             f"'rid@seconds' (e.g. '0@2.5')")
    return sorted(plan)


def _serve_fleet(args, cfg, model, params):
    """--replicas > 1: serve through the fault-tolerant Fleet
    (docs/robustness.md). Mirrors the single-engine loop but adds the
    --drain rolling-restart schedule and fleet-level reporting."""
    from repro.serving.fleet import DRAINED, Fleet

    if cfg.frontend is not None:
        raise SystemExit("--replicas > 1 serves token-only prompts; "
                         "frontend-embedding archs need the "
                         "single-engine path")
    fl = Fleet(model, params, replicas=args.replicas,
               engine_kwargs=_engine_kwargs(args),
               hedge=args.hedge, trace=bool(args.trace_out),
               faults=(Faults.parse(args.faults, seed=args.faults_seed)
                       if args.faults else None))
    rng = np.random.default_rng(args.seed)
    t0 = time.perf_counter()
    for uid in range(args.requests):
        L = int(rng.integers(max(2, args.prompt_len // 2),
                             args.prompt_len + 1))
        fl.submit(Request(uid=uid,
                          prompt=rng.integers(0, cfg.vocab, L),
                          max_new_tokens=args.max_new,
                          deadline_s=args.deadline or None))
    logger = None
    if args.metrics_jsonl:
        from repro.training.metrics import MetricsLogger
        logger = MetricsLogger(args.metrics_jsonl,
                               run_name=f"serve-fleet-{cfg.name}")
    drains = _parse_drains(args.drain)
    draining = set()
    next_log = t0 + (args.log_every or 1.0)
    while fl.has_work:
        fl.tick(args.sync_every)
        elapsed = time.perf_counter() - t0
        while drains and elapsed >= drains[0][0]:
            _, rid = drains.pop(0)
            try:
                fl.drain(rid)
                draining.add(rid)
            except ValueError as err:   # already dead/drained: skip
                print(f"--drain: {err}")
        for rid in sorted(draining):
            if fl.replicas[rid].state == DRAINED:
                fl.rejoin(rid)          # rolling restart: fresh engine
                draining.discard(rid)
        if (args.log_every or logger is not None) \
                and time.perf_counter() >= next_log:
            snap = fl.metrics.snapshot()
            c, gz = snap["counters"], snap["gauges"]
            fields = dict(inflight=gz.get("fleet_inflight", 0),
                          queued=gz.get("fleet_queue_depth", 0),
                          dispatches=c.get("dispatches", 0),
                          failovers=c.get("failovers", 0),
                          hedges=c.get("hedges_issued", 0))
            if logger is not None:
                logger.log("fleet", **fields)
            if args.log_every:
                states = "".join(r.state[0] for r in fl.replicas)
                print(f"[{elapsed:6.1f}s] replicas={states} " +
                      " ".join(f"{k}={v}" for k, v in fields.items()))
            next_log = time.perf_counter() + (args.log_every or 1.0)
    responses = fl.responses
    wall = time.perf_counter() - t0
    stats = fl.latency_stats()
    if logger is not None:
        logger.log("final", wall_s=wall, **{
            k: v for k, v in stats.items()
            if isinstance(v, (int, float))})
        logger.close()
    if args.trace_out:
        fl.export_trace(args.trace_out)
        print(f"merged chrome trace written to {args.trace_out} "
              f"(one lane per replica + a fleet lane; open in "
              f"https://ui.perfetto.dev)")
    tokens = sum(len(r.tokens) for r in responses.values())
    n_ok = sum(1 for r in responses.values() if r.ok)
    print(f"arch={cfg.name} requests={args.requests} "
          f"replicas={args.replicas} batch={args.max_batch}"
          + (" hedge" if args.hedge else ""))
    print(f"finished={stats['n_finished']} ok={n_ok} tokens={tokens} "
          f"wall={wall:.2f}s ({tokens / wall:,.1f} tok/s)")
    g = lambda k: stats.get(k, float("nan"))  # noqa: E731
    print(f"fleet ttft ms: p50={g('fleet_ttft_ms_p50'):.1f} "
          f"p95={g('fleet_ttft_ms_p95'):.1f} "
          f"p99={g('fleet_ttft_ms_p99'):.1f}")
    print(f"routing: dispatches={stats.get('dispatches', 0)} "
          f"affinity_hits={stats.get('affinity_hits', 0)} "
          f"breaker_opens={stats.get('breaker_opens', 0)}")
    print(f"resilience: deaths={stats.get('replica_deaths', 0)} "
          f"failovers={stats.get('failovers', 0)} "
          f"migrated={stats.get('requests_migrated', 0)} "
          f"router_drops={stats.get('router_drops', 0)} "
          f"hedges won/wasted={stats.get('hedges_won', 0)}"
          f"/{stats.get('hedges_wasted', 0)} "
          f"drains={stats.get('drains', 0)} "
          f"rejoins={stats.get('rejoins', 0)} "
          f"timeouts={stats.get('fleet_timeouts', 0)}")
    for r in fl.replicas:
        ewma = f"{r.ewma_s * 1e3:.1f}ms" if r.ewma_s else "-"
        print(f"  replica {r.rid}: {r.state} ticks={r.ticks} "
              f"step_ewma={ewma}"
              + (f" ({r.death_reason})" if r.death_reason else ""))
    if args.json:
        import json
        with open(args.json, "w") as f:
            json.dump({"arch": cfg.name, "wall_s": wall,
                       **{k: v for k, v in stats.items()
                          if isinstance(v, (int, float, str))}},
                      f, indent=2)
    return responses, stats


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ARCHS), default="llama3.2-1b")
    ap.add_argument("--variant", default="reduced")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=24)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--cache-len", type=int, default=128)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.8)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--sync-every", type=int, default=8,
                    help="decode steps between finished-flag polls")
    ap.add_argument("--quant", choices=["", "none", "int8", "int4"],
                    default="",
                    help="weight-only PTQ of the served params: int8/int4 "
                         "override the config's cfg.quant knob, 'none' "
                         "forces full precision even for quantized "
                         "variants (e.g. edge), '' keeps the config's "
                         "setting")
    ap.add_argument("--kv-cache-dtype", choices=["", "int8"], default="",
                    help="int8 = quantized KV cache (edge memory profile)")
    ap.add_argument("--draft", default="",
                    help="speculative-decoding draft spec "
                         "'<prec>[@<blocks>]' (fp|int8|int4, e.g. "
                         "'int8@1' = first block, int8-quantized "
                         "self-draft) or 'ngram' (draft-free "
                         "prompt-lookup — works on every family, incl. "
                         "SSM/encdec); 'none' disables a config-set "
                         "draft (e.g. the spec variant); '' keeps the "
                         "config's cfg.draft")
    ap.add_argument("--spec-gamma", type=int, default=0,
                    help="draft tokens proposed per speculative step "
                         "(0 keeps cfg.spec_gamma; needs --draft or a "
                         "spec-variant config)")
    ap.add_argument("--prefill-chunk", type=int, default=-1,
                    help="continuous batching: fuse at most this many "
                         "prompt tokens of one admitting request into "
                         "every decode step (0 = a single max-size "
                         "chunk per admission — the whole prompt in one "
                         "fused extend; -1 keeps cfg.prefill_chunk; see "
                         "the 'continuous' variant)")
    ap.add_argument("--prefix-cache-tokens", type=int, default=-1,
                    help="shared-prefix KV reuse budget in tokens (LRU; "
                         "0 = off, -1 keeps cfg.prefix_cache_tokens; "
                         "non-speculative, attention-only stacks)")
    ap.add_argument("--paged", action="store_true",
                    help="paged KV cache: fixed page pool + per-slot "
                         "block tables with copy-on-write prefix "
                         "sharing — KV memory scales with live tokens "
                         "(attention-only stacks, token-only prompts)")
    ap.add_argument("--page-size", type=int, default=16,
                    help="tokens per KV page with --paged")
    ap.add_argument("--num-pages", type=int, default=0,
                    help="KV pool size with --paged (0 = capacity "
                         "parity with the contiguous layout + headroom)")
    ap.add_argument("--mesh", default="",
                    help="tensor-parallel serving mesh: 'dp,mp' (e.g. "
                         "'2,4' = 2-way data x 4-way model), 'auto' = "
                         "all local devices on the model axis, 'none' "
                         "forces single-device even for a sharded "
                         "variant, '' keeps cfg.mesh (see the 'sharded' "
                         "variant)")
    ap.add_argument("--json", default="",
                    help="optional path to dump latency stats as JSON")
    ap.add_argument("--trace-out", default="",
                    help="write a Chrome trace-event JSON of the run "
                         "(request-lifecycle spans; load in Perfetto — "
                         "see docs/observability.md)")
    ap.add_argument("--metrics-jsonl", default="",
                    help="append periodic registry snapshots as JSONL "
                         "(training/metrics.MetricsLogger format)")
    ap.add_argument("--trace-dir", default="",
                    help="capture a jax.profiler device trace of the "
                         "first decode steps into this directory")
    ap.add_argument("--log-every", type=float, default=0.0,
                    help="seconds between one-line progress summaries "
                         "while serving (0 = off)")
    ap.add_argument("--faults", default="",
                    help="deterministic fault schedule, e.g. "
                         "'nan_logits@12/1,page_alloc@30x2' (grammar: "
                         "site[@step][/slot][xN][+delay][%%prob]; see "
                         "repro/serving/faults.py). '' defers to the "
                         "REPRO_FAULTS env var")
    ap.add_argument("--faults-seed", type=int, default=0,
                    help="seed for the --faults schedule's dice")
    ap.add_argument("--deadline", type=float, default=0.0,
                    help="per-request deadline in seconds (0 = none); "
                         "expired requests finish with reason 'timeout'")
    ap.add_argument("--replicas", type=int, default=1,
                    help="serve through a fault-tolerant Fleet of this "
                         "many engine replicas (health-checked routing, "
                         "failover by replay, drain/rejoin — see "
                         "docs/robustness.md); 1 = single engine. "
                         "--faults may then also name fleet sites "
                         "(replica_crash/replica_hang/router_drop)")
    ap.add_argument("--hedge", action="store_true",
                    help="with --replicas > 1: duplicate slow-starting "
                         "requests to a second replica after the fleet's "
                         "p99 TTFT; first token wins, loser is cancelled")
    ap.add_argument("--drain", default="",
                    help="with --replicas > 1: rolling-restart schedule "
                         "'rid@seconds[,rid@seconds...]' — drain each "
                         "replica at that wall time, rejoin it once "
                         "drained")
    args = ap.parse_args(argv)

    cfg = get_arch(args.arch, variant=args.variant)
    if args.quant:
        cfg = cfg.replace(quant="" if args.quant == "none" else args.quant)
    if args.draft == "none":
        cfg = cfg.replace(draft="", spec_gamma=0)  # speculation fully off
    elif args.draft:
        cfg = cfg.replace(draft=args.draft)
    if args.spec_gamma:
        cfg = cfg.replace(spec_gamma=args.spec_gamma)
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))
    if cfg.quant:
        from repro.quant import quantize_for_cfg
        params = quantize_for_cfg(params, cfg)
    if args.replicas > 1:
        return _serve_fleet(args, cfg, model, params)
    engine = Engine(model, params, **_engine_kwargs(args),
                    recorder=bool(args.trace_out),
                    trace_dir=args.trace_dir,
                    faults=(Faults.parse(args.faults, seed=args.faults_seed)
                            if args.faults else None))

    rng = np.random.default_rng(args.seed)
    fe = cfg.frontend
    t0 = time.perf_counter()
    for uid in range(args.requests):
        L = int(rng.integers(max(2, args.prompt_len // 2),
                             args.prompt_len + 1))
        emb = None
        if fe is not None:
            emb = rng.normal(0, 1, (fe.n_tokens, fe.d_embed)).astype(
                np.float32)
        engine.submit(Request(uid=uid,
                              prompt=rng.integers(0, cfg.vocab, L),
                              max_new_tokens=args.max_new,
                              embeddings=emb,
                              deadline_s=args.deadline or None))
    logger = None
    if args.metrics_jsonl:
        from repro.training.metrics import MetricsLogger
        logger = MetricsLogger(args.metrics_jsonl,
                               run_name=f"serve-{cfg.name}")

    def _progress():
        snap = engine.metrics.snapshot()
        c, gz = snap["counters"], snap["gauges"]
        fields = dict(steps=c.get("steps_total", 0),
                      tokens=c.get("tokens_emitted", 0),
                      active=gz.get("active_slots", 0),
                      queued=gz.get("queue_depth", 0),
                      compiles=c.get("compiles_total", 0))
        if logger is not None:
            logger.log("serve", **fields)
        if args.log_every:
            dt = time.perf_counter() - t0
            print(f"[{dt:6.1f}s] steps={fields['steps']} "
                  f"tokens={fields['tokens']} active={fields['active']} "
                  f"queued={fields['queued']} "
                  f"compiles={fields['compiles']}")

    if args.log_every or logger is not None:
        # hand-rolled drain loop so we can emit periodic summaries
        next_log = t0 + (args.log_every or 1.0)
        while engine.has_work:
            engine.tick(args.sync_every)
            if time.perf_counter() >= next_log:
                _progress()
                next_log = time.perf_counter() + (args.log_every or 1.0)
        _progress()
    responses = engine.run()          # finalize (stops device profiler)
    wall = time.perf_counter() - t0
    stats = engine.latency_stats()
    if logger is not None:
        logger.log("final", wall_s=wall, **{
            k: v for k, v in stats.items()
            if isinstance(v, (int, float))})
        logger.close()
    if args.trace_out:
        engine.export_trace(args.trace_out)
        print(f"chrome trace written to {args.trace_out} "
              f"(open in https://ui.perfetto.dev)")
    print(f"arch={cfg.name} requests={args.requests} "
          f"batch={args.max_batch}")
    if engine.mesh is not None:
        shape = dict(zip(engine.mesh.axis_names,
                         engine.mesh.devices.shape))
        print(f"mesh: data={shape.get('data', 1)} "
              f"model={shape.get('model', 1)} "
              f"({engine.mesh.devices.size} devices)")
    print(f"finished={stats['n_finished']} "
          f"tokens={stats['tokens_generated']} wall={wall:.2f}s "
          f"({stats['tokens_generated']/wall:,.1f} tok/s)")
    # latency keys are absent when a stream produced no samples (e.g.
    # --max-new 1 never decodes): print NaN rather than fake zeros
    g = lambda k: stats.get(k, float("nan"))  # noqa: E731
    print(f"decode ms/step: mean={g('decode_ms_mean'):.2f} "
          f"p50={g('decode_ms_p50'):.2f} p99={g('decode_ms_p99'):.2f}")
    print(f"ttft ms: mean={g('ttft_ms_mean'):.1f} "
          f"p50={g('ttft_ms_p50'):.1f} p95={g('ttft_ms_p95'):.1f} "
          f"p99={g('ttft_ms_p99'):.1f}")
    print(f"itl ms: mean={g('itl_ms_mean'):.2f} "
          f"p50={g('itl_ms_p50'):.2f} p95={g('itl_ms_p95'):.2f} "
          f"p99={g('itl_ms_p99'):.2f}")
    n_ok = sum(1 for r in responses.values() if r.ok)
    if n_ok != len(responses) or stats.get("preemptions") \
            or stats.get("faults_injected"):
        print(f"resilience: ok={n_ok}/{len(responses)} "
              f"timeouts={stats.get('timeouts', 0)} "
              f"cancelled={stats.get('cancellations', 0)} "
              f"errors={stats.get('slot_errors', 0)} "
              f"preemptions={stats.get('preemptions', 0)} "
              f"faults_injected={stats.get('faults_injected', 0)}")
    line = (f"continuous batching: chunk={stats['prefill_chunk']} "
            f"chunked admissions={stats['chunked_admissions']} "
            f"fallback admissions={stats['fallback_admissions']}")
    if "prefix_hits" in stats:
        line += (f" prefix hits={stats['prefix_hits']} "
                 f"reused tokens={stats['prefix_hit_tokens']}")
    print(line)
    if engine.spec_gamma:
        print(f"speculative: gamma={stats['spec_gamma']} "
              f"accept={stats['spec_acceptance_rate']:.2f} "
              f"tokens/step={stats['spec_tokens_per_step']:.2f}")
    if args.json:
        import json
        with open(args.json, "w") as f:
            json.dump({"arch": cfg.name, "wall_s": wall, **stats}, f,
                      indent=2)
    return responses, stats


if __name__ == "__main__":
    main()
