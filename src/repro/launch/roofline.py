"""Roofline analysis over dry-run artifacts.

Reads experiments/dryrun/*.json and derives, per (arch x shape x mesh):

  compute term    = HLO_FLOPs_per_device / peak_FLOP/s        (197 TF bf16)
  memory term     = HLO_bytes_per_device / HBM_bw             (819 GB/s)
  collective term = collective_bytes_per_device / ICI link bw (50 GB/s)

All three are per-device seconds (the compiled module is the per-device
SPMD program). The dominant term is the bottleneck; MODEL_FLOPS/HLO_FLOPs
measures how much compiled compute is "useful" (6·N·D for training,
2·N·D for prefill, 2·N_active·B for one decode step).
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path
from typing import Dict, List, Optional

from repro.configs import ARCHS, SHAPES, active_param_count, param_count
from repro.launch.mesh import HW

DRYRUN_DIR = Path(__file__).resolve().parents[3] / "experiments" / "dryrun"


def model_flops(arch: str, shape_name: str, variant: str = "") -> float:
    """Global useful FLOPs for one step (6ND train / 2ND forward)."""
    cfg = ARCHS[arch]
    shape = SHAPES[shape_name]
    n_active = active_param_count(cfg)
    if shape.mode == "train":
        tokens = shape.seq_len * shape.global_batch
        return 6.0 * n_active * tokens
    if shape.mode == "prefill":
        tokens = shape.seq_len * shape.global_batch
        return 2.0 * n_active * tokens
    # decode: one token per sequence
    return 2.0 * n_active * shape.global_batch


def analyze(rec: dict) -> Optional[dict]:
    if rec.get("status") != "ok":
        return None
    chips = rec["n_devices"]
    compute_s = rec["flops_per_device"] / HW["peak_flops_bf16"]
    memory_s = rec["bytes_per_device"] / HW["hbm_bandwidth"]
    coll_s = rec["collective_bytes_per_device"] / HW["ici_link_bandwidth"]
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": coll_s}
    dominant = max(terms, key=terms.get)
    mf = model_flops(rec["arch"], rec["shape"], rec.get("variant", ""))
    hlo_total = rec["flops_per_device"] * chips
    useful = mf / hlo_total if hlo_total else 0.0
    bound_s = max(terms.values())
    # achievable-step-time model: max of the three (perfect overlap)
    mfu_at_roofline = (mf / chips / HW["peak_flops_bf16"]) / bound_s \
        if bound_s else 0.0
    temp = rec.get("memory_analysis", {}).get("temp_size_in_bytes")
    return {
        **{k: rec[k] for k in ("arch", "shape", "mesh", "mode", "variant",
                               "tag", "zero")},
        "chips": chips,
        "compute_s": compute_s,
        "memory_s": memory_s,
        "collective_s": coll_s,
        "dominant": dominant,
        "model_flops_global": mf,
        "useful_flop_ratio": useful,
        "mfu_at_roofline": mfu_at_roofline,
        "temp_bytes_per_device": temp,
        "fits_hbm": (temp or 0) <= HW["hbm_bytes"],
        "collectives": rec.get("collectives", {}),
    }


def load_all(dryrun_dir: Path = DRYRUN_DIR, tag: str = "") -> List[dict]:
    out = []
    for p in sorted(dryrun_dir.glob("*.json")):
        with open(p) as f:
            rec = json.load(f)
        if (rec.get("tag") or "") != tag:
            continue
        a = analyze(rec)
        if a:
            out.append(a)
    return out


def fmt_s(x: float) -> str:
    if x >= 1.0:
        return f"{x:7.2f}s "
    if x >= 1e-3:
        return f"{x*1e3:7.2f}ms"
    return f"{x*1e6:7.1f}us"


def lever_for(row: dict) -> str:
    """One sentence: what would move the dominant term down (per the
    brief's §Roofline requirement). Derived from dominance x mode x
    family; these map 1:1 to the --opt flags validated in §Perf."""
    arch = ARCHS.get(row["arch"])
    fam = arch.family if arch else "dense"
    dom, mode = row["dominant"], row["mode"]
    if dom == "collective":
        if fam in ("moe", "hybrid"):
            return "grouped (data-local) MoE routing removes the global dispatch gather (--moe-group)"
        return "re-layout activations to avoid cross-axis resharding"
    if dom == "compute":
        return ("chunked causal attention halves above-diagonal score work "
                "(--attn-block)" if mode != "decode" else
                "batch more sequences per step to fill the MXU")
    # memory-dominant
    if mode == "decode":
        if fam == "ssm":
            return "state already O(1): remaining bytes are weights — quantize or batch more"
        return ("shard the KV sequence over the model axis and store int8 KV "
                "(--kv-seq-shard --kv-quant)")
    if mode == "train":
        return ("microbatch + ZeRO state sharding cut resident bytes "
                "(--microbatch --zero); Pallas flash kernel removes "
                "materialised scores")
    return ("flash attention (Pallas) streams tiles instead of "
            "materialising LxL scores")


def markdown_table(rows: List[dict], lever: bool = True) -> str:
    cols = ("| arch | shape | mesh | compute | memory | collective | "
            "dominant | useful | roofline-MFU | fits 16G |")
    if lever:
        cols += " what moves the dominant term |"
    hdr = cols + "\n" + "|---" * (11 if lever else 10) + "|\n"
    lines = []
    order = {s: i for i, s in enumerate(SHAPES)}
    rows = sorted(rows, key=lambda r: (r["arch"], order.get(r["shape"], 9),
                                       r["mesh"]))
    for r in rows:
        line = (
            f"| {r['arch']}{'~' + r['variant'] if r['variant'] else ''} "
            f"| {r['shape']} | {r['mesh']} "
            f"| {fmt_s(r['compute_s'])} | {fmt_s(r['memory_s'])} "
            f"| {fmt_s(r['collective_s'])} | **{r['dominant']}** "
            f"| {r['useful_flop_ratio']*100:5.1f}% "
            f"| {r['mfu_at_roofline']*100:5.1f}% "
            f"| {'yes' if r['fits_hbm'] else 'NO'} |")
        if lever:
            line += f" {lever_for(r)} |"
        lines.append(line)
    return hdr + "\n".join(lines) + "\n"


def comparison_table(base_rows: List[dict], opt_rows: List[dict]) -> str:
    """Baseline vs optimized-pack, per pair (single mesh)."""
    opt = {(r["arch"], r["shape"]): r for r in opt_rows}
    hdr = ("| arch | shape | dominant (base) | base term | opt term | "
           "gain | fits: base→opt |\n|---|---|---|---|---|---|---|\n")
    lines = []
    order = {s: i for i, s in enumerate(SHAPES)}
    for r in sorted(base_rows, key=lambda r: (r["arch"],
                                              order.get(r["shape"], 9))):
        o = opt.get((r["arch"], r["shape"]))
        if o is None:
            continue
        dom = r["dominant"]
        b = r[f"{dom}_s"]
        a = o[f"{dom}_s"]
        gain = b / a if a else float("inf")
        lines.append(
            f"| {r['arch']} | {r['shape']} | {dom} | {fmt_s(b)} "
            f"| {fmt_s(a)} | {gain:5.1f}x "
            f"| {'yes' if r['fits_hbm'] else 'NO'}→"
            f"{'yes' if o['fits_hbm'] else 'NO'} |")
    return hdr + "\n".join(lines) + "\n"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default=str(DRYRUN_DIR))
    ap.add_argument("--tag", default="")
    ap.add_argument("--json", action="store_true")
    args = ap.parse_args()
    rows = load_all(Path(args.dir), tag=args.tag)
    if args.json:
        print(json.dumps(rows, indent=1))
    else:
        print(markdown_table(rows))


if __name__ == "__main__":
    main()
