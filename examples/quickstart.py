"""Quickstart — the paper's deployment example in <20 lines of public API.

Compose an image-classification service from two existing services
(backbone classifier ≫ label decoder, the InceptionV3 ≫ ImageNet-decode
analogue), check compatibility statically, publish both to a local zoo,
pull the composition back and run it.

  PYTHONPATH=src python examples/quickstart.py
"""
import tempfile

import jax
import jax.numpy as jnp

import repro.core.zoo_builders as zb
from repro.core.registry import Registry

# 1. build two services (params initialised here; normally pulled)
classifier = zb.classifier_service("pixtral-12b", n_classes=1000)
classifier = classifier.with_params(
    classifier.metadata["init_params"](jax.random.PRNGKey(0)))
decoder = zb.label_decoder(1000)

# 2. compose them — sequential connection, statically type-checked
service = classifier >> decoder

# 3. publish to the zoo and pull it back (weights dedup by reference)
with tempfile.TemporaryDirectory() as zoo:
    reg = Registry(zoo)
    reg.publish(classifier, builder="model.classifier",
                config={"arch": "pixtral-12b", "n_classes": 1000})
    reg.publish(decoder, builder="adapter.label_decoder",
                config={"n_classes": 1000})
    reg.publish_composed(service, [classifier, decoder])
    print("zoo contents:", *(f"\n  {n}@{v}" for n, v, _ in reg.list()))
    service = reg.pull(service.name)

# 4. run it on a batch of "images" (frontend patch embeddings)
images = {"embeddings": jnp.ones((4, 16, 64), jnp.float32)}
out = jax.jit(service.fn)(service.params, images)
print("\nclassified:", out["class_id"].tolist(),
      "confidence:", [f"{c:.3f}" for c in out["confidence"].tolist()])
