"""Composable services × multi-pod: the paper's composed service
(classifier ≫ decoder), lowered and compiled as ONE SPMD program on the
production 16×16 mesh — service composition and pod-scale distribution are
orthogonal, which is the point of separating functionality from deployment.

Run as its own process (forces placeholder devices before jax init):

  PYTHONPATH=src python examples/multipod_service.py
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import jax
import jax.numpy as jnp

import repro.core.zoo_builders as zb
from repro.distribution.sharding import (activation_sharding,
                                         batch_shardings,
                                         default_activation_rules,
                                         param_shardings)
from repro.launch.mesh import make_production_mesh

# full-size pixtral backbone classifier composed with a label decoder
clf = zb.classifier_service("pixtral-12b", n_classes=1000, variant="")
dec = zb.label_decoder(1000)
service = clf >> dec
print(f"composed service: {service.name}")

mesh = make_production_mesh()                      # 16x16 = 256 chips
params_shapes = jax.eval_shape(clf.metadata["init_params"],
                               jax.random.PRNGKey(0))
par_sh = param_shardings(params_shapes, mesh)
params_sds = jax.tree.map(
    lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
    params_shapes, par_sh)
# composed params pytree: {"stage0": classifier params, "stage1": None}
comp_params = {"stage0": params_sds, "stage1": None}

B = 256
fe = {"n": 1024, "d": 1024}
batch_shapes = {"embeddings": jax.ShapeDtypeStruct(
    (B, fe["n"], fe["d"]), jnp.bfloat16)}
batch_sds = jax.tree.map(
    lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
    batch_shapes, batch_shardings(batch_shapes, mesh, ("data",)))

rules = default_activation_rules(("data",))
with mesh, activation_sharding(mesh, rules):
    lowered = jax.jit(service.fn).lower(comp_params, batch_sds)
    compiled = lowered.compile()

ca = compiled.cost_analysis()
ca = ca[0] if isinstance(ca, (list, tuple)) else ca
ma = compiled.memory_analysis()
print(f"compiled the composed service for {mesh.devices.size} chips")
print(f"  flops/device:  {ca['flops']:.3e}")
print(f"  bytes/device:  {ca.get('bytes accessed', 0):.3e}")
print(f"  args/device:   {ma.argument_size_in_bytes/2**30:.2f} GiB")
print(f"  temp/device:   {ma.temp_size_in_bytes/2**30:.2f} GiB")
print("service composition is SPMD-transparent: one XLA program, "
      "no host round-trip between stages.")
