"""Batched serving example: a stream of differently-sized requests through
the continuous-batching engine — the runtime behind the paper's
'predictable local latency' claim (Fig. 3).

  PYTHONPATH=src python examples/serve_batched.py
"""
import time

import jax
import numpy as np

from repro.configs import get_arch
from repro.models.model import build
from repro.serving.engine import Engine
from repro.serving.request import Request
from repro.serving.sampler import Sampler

cfg = get_arch("llama3.2-1b", variant="reduced")
model = build(cfg)
params = model.init(jax.random.PRNGKey(0))

engine = Engine(model, params, max_batch=4, cache_len=96,
                sampler=Sampler(temperature=0.7, top_k=20))
rng = np.random.default_rng(0)
t0 = time.perf_counter()
for uid in range(12):
    L = int(rng.integers(4, 32))
    engine.submit(Request(uid=uid, prompt=rng.integers(0, cfg.vocab, L),
                          max_new_tokens=16))
responses = engine.run()
wall = time.perf_counter() - t0

stats = engine.latency_stats()
print(f"served {stats['n_finished']} requests, "
      f"{stats['tokens_generated']} tokens in {wall:.2f}s "
      f"({stats['tokens_generated']/wall:.0f} tok/s)")
print(f"per-step decode latency: mean={stats['decode_ms_mean']:.2f}ms "
      f"p50={stats['decode_ms_p50']:.2f}ms p99={stats['decode_ms_p99']:.2f}ms")
for uid in (0, 5, 11):
    r = responses[uid]
    print(f"  req {uid}: prompt_len={r.prompt_len} -> {r.tokens[:8]}…")
