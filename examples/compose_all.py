"""Every composition combinator in one runnable example.

The paper's step-2 claim: new services are *constructed from existing
ones*. This walks the full combinator set in ``repro.core.compose`` on a
toy feature pipeline — run it with:

  PYTHONPATH=src python examples/compose_all.py

See docs/architecture.md for the construct/compose/deploy mapping.
"""
import jax
import jax.numpy as jnp

from repro.core.compose import (adapter, cast_adapter, ensemble, map_batch,
                                parallel, route, select_adapter, seq)
from repro.core.service import TensorSpec, service_from_fn

D = 8
key = jax.random.PRNGKey(0)
x = jax.random.normal(key, (4, D))  # a batch of 4 feature vectors


def dense(name, seed, scale=1.0):
    """A tiny one-layer service with its own params."""
    w = scale * jax.random.normal(jax.random.PRNGKey(seed), (D, D)) / D**0.5
    return service_from_fn(name, lambda p, v: jnp.tanh(v @ p), x, params=w)


# 1. seq — the paper's primary primitive (also spelled `a >> b`)
pipeline = seq(dense("featurize", 0), dense("refine", 1))
y = pipeline(x)
print("seq:", y.shape, "stages:", pipeline.metadata["stages"])

# 2. ensemble — same input to N members, combined outputs
ens = ensemble([dense("m0", 2), dense("m1", 3), dense("m2", 4)],
               combine="mean")
print("ensemble(mean):", ens(x).shape)

# 3. route — data-dependent branch selection; compiles to lax.switch so
#    the choice happens on device with no host round-trip
selector = service_from_fn(
    "norm_gate", lambda p, v: (jnp.linalg.norm(v) > 5.0).astype(jnp.int32),
    x)
routed = route(selector, [dense("small_model", 5), dense("large_model", 6)])
print("route:", routed(x).shape)

# 4. parallel — independent services over a dict of independent inputs
par = parallel({"text": dense("text_enc", 7), "image": dense("img_enc", 8)})
both = par({"text": x, "image": 2.0 * x})
print("parallel:", {k: v.shape for k, v in both.items()})

# 5. map_batch — lift a per-example service over a leading batch axis
per_example = service_from_fn("score_one",
                              lambda p, v: jnp.sum(v * v), x[0])
scores = map_batch(per_example)(x)
print("map_batch:", scores.shape)

# 6. adapters — stateless glue: shape/dtype/field plumbing between stages
spec = TensorSpec((-1, D), "float32")
relu = adapter("relu", lambda v: jnp.maximum(v, 0), spec, spec)
to_bf16 = cast_adapter(spec, jnp.bfloat16)
pick = select_adapter({"text": spec, "image": spec}, "text")
glued = seq(pick, relu, dense("head", 9))
print("adapters:", glued({"text": x, "image": x}).shape,
      "| cast:", to_bf16(x).dtype)

# Composition fuses: the whole pipeline is ONE pure fn over one params
# pytree, so jit compiles it into a single XLA program (no per-stage
# dispatch — the on-device analogue of the paper cutting the cloud trip).
fused = jax.jit(glued.fn)
print("fused jit:", fused(glued.params, {"text": x, "image": x}).shape)
