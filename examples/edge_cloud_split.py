"""Deployment separation — the paper's core property: the SAME composed
service moves local -> remote -> hybrid split without any structural
change, and the framework reports where time goes under each plan.

  PYTHONPATH=src python examples/edge_cloud_split.py
"""
import jax
import jax.numpy as jnp

import repro.core.zoo_builders as zb
from repro.core.deploy import DeploymentPlan, deploy
from repro.core.netmodel import NetworkModel

classifier = zb.classifier_service("pixtral-12b", n_classes=1000)
classifier = classifier.with_params(
    classifier.metadata["init_params"](jax.random.PRNGKey(0)))
decoder = zb.label_decoder(1000)
service = classifier >> decoder
images = {"embeddings": jnp.ones((8, 16, 64), jnp.float32)}

# the paper's measured setting: 34 Mbps uplink to the cloud API
net = NetworkModel(bandwidth_mbps=34.0, rtt_ms=60.0, server_ms=350.0)

plans = {
    "all-local (edge)": DeploymentPlan.all_local(service),
    "all-remote (cloud API)": DeploymentPlan.all_remote(service, net),
    "split (backbone edge, decode cloud)":
        DeploymentPlan.split(service, 1, net),
    # precision is an endpoint property: int4 backbone on the edge
    # device, fp decode in the cloud — structure still unchanged
    "edge-split (int4 backbone edge, decode cloud)":
        DeploymentPlan.edge_split(service, 1, quantize="int4",
                                  network=net),
}

for name, plan in plans.items():
    deployed = deploy(service, plan, stages=[classifier, decoder])
    out, tel = deployed.call(images)
    print(f"\n{name}")
    for s in tel.stages:
        print(f"  stage {s.stage:45s} @{s.endpoint:6s} "
              f"[{s.precision:4s} {s.param_bytes/1e6:6.1f}MB] "
              f"compute={s.compute_s*1e3:8.2f}ms "
              f"network={s.transfer_s*1e3:8.2f}ms")
    print(f"  TOTAL {tel.total_s*1e3:8.2f}ms  "
          f"(same class_ids: {out['class_id'].tolist()[:4]}...)")

# per-stage instrumentation (the paper's Owl per-node latency feature)
from repro.core.profile import format_profile, profile_stages
print("\nper-stage profile (local):")
print(format_profile(profile_stages([classifier, decoder], images)))
