"""End-to-end training driver example: train a reduced llama3.2 on the
synthetic Markov language until loss approaches the entropy floor, then
publish the trained backbone to the zoo as a service.

  PYTHONPATH=src python examples/train_lm.py [--steps 300]
"""
import argparse
import tempfile

import jax

from repro.configs import get_arch
from repro.core.registry import Registry
from repro.core.zoo_builders import lm_service
from repro.data.pipeline import MarkovLM, batches_for
from repro.models.model import build
from repro.training.optimizer import AdamW, cosine_schedule
from repro.training.train_loop import train

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=300)
ap.add_argument("--batch", type=int, default=16)
ap.add_argument("--seq", type=int, default=128)
args = ap.parse_args()

cfg = get_arch("llama3.2-1b", variant="reduced")
model = build(cfg)
opt = AdamW(lr=cosine_schedule(3e-3, 20, args.steps))
data = batches_for(cfg, args.batch, args.seq)
floor = MarkovLM(cfg.vocab).entropy_bound()
print(f"training {cfg.name}; conditional-entropy floor ≈ {floor:.3f} nats")

state, hist = train(model, opt, data, steps=args.steps, log_every=25,
                    callback=lambda m: print(
                        f"  step {m['step']:4d} loss={m['loss']:.4f}"))
final = hist[-1]["loss"]
print(f"final loss {final:.3f} (floor {floor:.3f}, "
      f"gap {final - floor:.3f})")

# publish the trained model as a zoo service
svc = lm_service("llama3.2-1b", variant="reduced").with_params(
    state["params"])
with tempfile.TemporaryDirectory() as zoo:
    reg = Registry(zoo)
    manifest = reg.publish(svc, builder="model.lm",
                           config={"arch": "llama3.2-1b",
                                   "variant": "reduced"})
    print(f"published {manifest['name']}@{manifest['version']} "
          f"params_hash={manifest['params_hash'][:12]}…")
