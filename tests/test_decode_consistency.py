"""Prefill + incremental decode must reproduce full-sequence forward logits
— the strongest cross-cutting correctness property of the cache machinery
(KV ring buffers, SSM recurrence, cross-attention caching)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.models.model import build

FAMS = ["llama3.2-1b", "qwen2-moe-a2.7b", "mamba2-780m",
        "jamba-1.5-large-398b", "seamless-m4t-medium"]


def _inputs(cfg, B, L, rng):
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (B, L)),
                                   jnp.int32)}
    if cfg.frontend is not None:
        batch["embeddings"] = jnp.asarray(
            rng.normal(0, 1, (B, cfg.frontend.n_tokens,
                              cfg.frontend.d_embed)), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", FAMS)
def test_decode_matches_forward(arch):
    cfg = get_arch(arch, variant="reduced")
    if cfg.moe is not None:
        # disable capacity drops for exactness
        import dataclasses
        cfg = cfg.replace(moe=dataclasses.replace(
            cfg.moe, capacity_factor=8.0))
    model = build(cfg)
    rng = np.random.default_rng(7)
    params = model.init(jax.random.PRNGKey(7))
    B, L, extra = 2, 12, 4
    batch = _inputs(cfg, B, L + extra, rng)
    full_tokens = batch["tokens"]

    # full forward logits (teacher forcing)
    logits_full, _ = jax.jit(
        lambda p, b: _forward(model, cfg, p, b))(params, batch)

    # prefill on the first L tokens, then decode the rest token by token
    pre_batch = dict(batch)
    pre_batch["tokens"] = full_tokens[:, :L]
    cache = model.make_cache(B, L + extra)
    logits_p, cache = jax.jit(model.prefill)(params, pre_batch, cache)

    offset = cfg.frontend.n_tokens if (cfg.frontend is not None
                                       and cfg.family == "vlm") else 0
    np.testing.assert_allclose(
        np.asarray(logits_p[:, 0]),
        np.asarray(logits_full[:, offset + L - 1]), rtol=2e-3, atol=2e-3)

    decode = jax.jit(model.decode_step)
    for t in range(extra):
        tok = full_tokens[:, L + t][:, None]
        logits_d, cache = decode(params, tok, cache)
        np.testing.assert_allclose(
            np.asarray(logits_d[:, 0]),
            np.asarray(logits_full[:, offset + L + t]),
            rtol=2e-3, atol=2e-3,
            err_msg=f"{arch}: decode step {t} diverges from forward")


def _forward(model, cfg, params, batch):
    from repro.models import encdec as ED
    from repro.models import transformer as T
    if cfg.family == "encdec":
        return ED.forward_train(params, cfg, batch["tokens"],
                                batch["embeddings"])
    emb = batch.get("embeddings")
    return T.forward_train(params, cfg, batch["tokens"], emb)


def test_sliding_window_decode_matches_forward():
    """SWA ring-buffer decode == full forward with windowed mask."""
    cfg = get_arch("llama3.2-1b", variant="reduced").replace(
        sliding_window=8)
    model = build(cfg)
    rng = np.random.default_rng(3)
    params = model.init(jax.random.PRNGKey(3))
    B, L, extra = 1, 20, 6
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, (B, L + extra)),
                         jnp.int32)
    logits_full, _ = _forward(model, cfg, params, {"tokens": tokens})

    cache = model.make_cache(B, L + extra)   # capped to window internally
    assert jax.tree.leaves(cache)[0].shape[2] == cfg.sliding_window
    logits_p, cache = jax.jit(model.prefill)(
        params, {"tokens": tokens[:, :L]}, cache)
    np.testing.assert_allclose(np.asarray(logits_p[:, 0]),
                               np.asarray(logits_full[:, L - 1]),
                               rtol=2e-3, atol=2e-3)
    decode = jax.jit(model.decode_step)
    for t in range(extra):
        logits_d, cache = decode(params, tokens[:, L + t][:, None], cache)
        np.testing.assert_allclose(
            np.asarray(logits_d[:, 0]),
            np.asarray(logits_full[:, L + t]), rtol=2e-3, atol=2e-3,
            err_msg=f"swa decode step {t}")
