"""Property-based serving-engine invariants: arbitrary request patterns
must all finish with exactly the requested token counts, regardless of
batch size, prompt lengths, or arrival order."""
import jax
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="hypothesis not installed; "
                    "pip install -r requirements-dev.txt")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.configs import get_arch
from repro.models.model import build
from repro.serving.engine import Engine
from repro.serving.request import Request
from repro.serving.sampler import Sampler

# one model/params for the whole module (hypothesis runs many cases)
_CFG = get_arch("llama3.2-1b", variant="reduced")
_MODEL = build(_CFG)
_PARAMS = _MODEL.init(jax.random.PRNGKey(0))

requests = st.lists(
    st.tuples(st.integers(1, 24),          # prompt length
              st.integers(1, 6)),          # max_new_tokens
    min_size=1, max_size=6)


@given(requests, st.integers(1, 3))
@settings(max_examples=10, deadline=None)
def test_all_requests_finish_exactly(reqs, max_batch):
    eng = Engine(_MODEL, _PARAMS, max_batch=max_batch, cache_len=48,
                 sampler=Sampler())
    rng = np.random.default_rng(0)
    for uid, (plen, mnew) in enumerate(reqs):
        eng.submit(Request(uid=uid,
                           prompt=rng.integers(0, _CFG.vocab, plen),
                           max_new_tokens=mnew))
    resp = eng.run()
    assert len(resp) == len(reqs)
    for uid, (plen, mnew) in enumerate(reqs):
        r = resp[uid]
        assert r.finished
        assert r.n_generated == mnew, (uid, r.n_generated, mnew)
        assert all(0 <= t < _CFG.vocab for t in r.tokens)


@given(st.integers(1, 4))
@settings(max_examples=5, deadline=None)
def test_engine_deterministic_under_greedy(max_batch):
    """Greedy engine output is independent of batch width."""
    rng = np.random.default_rng(1)
    prompts = [rng.integers(0, _CFG.vocab, 7), rng.integers(0, _CFG.vocab, 13)]

    def serve(mb):
        eng = Engine(_MODEL, _PARAMS, max_batch=mb, cache_len=48,
                     sampler=Sampler())
        for uid, p in enumerate(prompts):
            eng.submit(Request(uid=uid, prompt=p, max_new_tokens=4))
        return {u: r.tokens for u, r in eng.run().items()}

    assert serve(max_batch) == serve(1)
