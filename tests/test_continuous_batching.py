"""Continuous batching invariants: the unified extend path
(``Model.extend_into_cache``), chunked prefill ≡ whole-prompt
prefill (token-identical greedy output, cache bit-equality), shared-
prefix KV reuse (hit ≡ cold path, LRU eviction under the token cap),
and the fused mixed step composing with int8 KV + speculative decoding."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.models.model import build
from repro.serving.engine import Engine
from repro.serving.prefix_cache import PrefixCache
from repro.serving.request import Request
from repro.serving.sampler import Sampler

_CFG = get_arch("llama3.2-1b", variant="reduced")
_MODEL = build(_CFG)
_PARAMS = _MODEL.init(jax.random.PRNGKey(0))
_RNG = np.random.default_rng(21)
# lengths straddle the chunk: below, equal, multiple chunks, non-multiple
_PROMPTS = [_RNG.integers(0, _CFG.vocab, L) for L in (3, 8, 11, 24, 30, 17)]


def _engine(**kw):
    kw.setdefault("max_batch", 2)
    kw.setdefault("cache_len", 64)
    kw.setdefault("sampler", Sampler())
    return Engine(_MODEL, _PARAMS, **kw)


def _run(prompts=_PROMPTS, max_new=6, **kw):
    eng = _engine(**kw)
    for uid, p in enumerate(prompts):
        eng.submit(Request(uid=uid, prompt=p, max_new_tokens=max_new))
    resp = eng.run()
    return {u: r.tokens for u, r in resp.items()}, eng


# ------------------------------------------------------------------ #
# Model.extend_into_cache (the unified extend path)
# ------------------------------------------------------------------ #
def test_extend_matches_sequential_decode_per_row_lengths():
    """One masked extend with per-row lengths [3, 1, 0] produces the same
    valid-position logits as token-by-token decode, advances each row's
    step by its own length, and leaves the length-0 row bit-untouched."""
    B, T = 3, 4
    toks = jnp.asarray(_RNG.integers(0, _CFG.vocab, (B, 6)), jnp.int32)
    cache = _MODEL.make_cache(B, 32)
    _, cache = jax.jit(_MODEL.prefill)(_PARAMS, {"tokens": toks}, cache)
    ext = jnp.asarray(_RNG.integers(0, _CFG.vocab, (B, T)), jnp.int32)
    lengths = jnp.asarray([3, 1, 0], jnp.int32)
    lo, cache_e = jax.jit(_MODEL.extend_into_cache)(_PARAMS, ext, cache,
                                                    lengths)
    assert list(np.asarray(_MODEL.cache_steps(cache_e))) == [9, 7, 6]

    step = jax.jit(_MODEL.decode_step)
    cache_s = cache
    for i in range(3):
        lo_i, cache_s = step(_PARAMS, ext[:, i:i + 1], cache_s)
        for b in range(B):
            if i < int(lengths[b]):
                np.testing.assert_allclose(
                    np.asarray(lo[b, i]), np.asarray(lo_i[b, 0]),
                    rtol=2e-5, atol=2e-5)
    # row 2 advanced by 0: its cache row is bit-identical to before
    for a, b0 in zip(jax.tree.leaves(cache_e), jax.tree.leaves(cache)):
        if a.ndim >= 2:
            assert np.array_equal(np.asarray(a)[:, 2], np.asarray(b0)[:, 2])


def test_extend_last_only_gathers_last_valid_position():
    toks = jnp.asarray(_RNG.integers(0, _CFG.vocab, (2, 5)), jnp.int32)
    cache = _MODEL.make_cache(2, 32)
    _, cache = jax.jit(_MODEL.prefill)(_PARAMS, {"tokens": toks}, cache)
    ext = jnp.asarray(_RNG.integers(0, _CFG.vocab, (2, 4)), jnp.int32)
    lengths = jnp.asarray([4, 2], jnp.int32)
    lo_full, _ = jax.jit(_MODEL.extend_into_cache)(_PARAMS, ext, cache,
                                                   lengths)
    lo_last, _ = jax.jit(
        lambda p, t, c, l: _MODEL.extend_into_cache(p, t, c, l,
                                                    last_only=True))(
        _PARAMS, ext, cache, lengths)
    np.testing.assert_array_equal(np.asarray(lo_last[0, 0]),
                                  np.asarray(lo_full[0, 3]))
    np.testing.assert_array_equal(np.asarray(lo_last[1, 0]),
                                  np.asarray(lo_full[1, 1]))


def test_extend_universal_across_families():
    """Every family exposes the extend path — it is the engine's one
    admission path (recurrent stacks flag the rollback-replay contract
    instead of opting out)."""
    for arch in ("mamba2-780m", "jamba-1.5-large-398b", "qwen2-moe-a2.7b",
                 "seamless-m4t-medium"):
        model = build(get_arch(arch, variant="reduced"))
        assert model.supports_extend, arch
        assert model.extend_into_cache is not None, arch


# ------------------------------------------------------------------ #
# chunked prefill ≡ whole-prompt admission
# ------------------------------------------------------------------ #
def test_chunked_prefill_cache_bit_equality():
    """Model level: feeding the prompt through chunked extends produces a
    bit-identical cache (K/V/pos/step) and next-token logits to one
    whole-prompt admission — chunking is a scheduling choice, not a
    numerics choice."""
    L, C, Lb, S = 13, 4, 16, 32
    prompt = _RNG.integers(0, _CFG.vocab, L)
    padded = np.zeros((1, Lb), np.int32)
    padded[0, :L] = prompt
    cache_a = _MODEL.make_cache(1, S)
    lo_a, cache_a = jax.jit(_MODEL.prefill)(
        _PARAMS, {"tokens": jnp.asarray(padded),
                  "length": jnp.asarray([L], jnp.int32)}, cache_a)

    cache_b = _MODEL.make_cache(1, S)
    ext = jax.jit(lambda p, t, c, l: _MODEL.extend_into_cache(
        p, t, c, l, last_only=True))
    for base in range(0, L, C):
        n = min(C, L - base)
        chunk = np.zeros((1, C), np.int32)
        chunk[0, :n] = prompt[base:base + n]
        lo_b, cache_b = ext(_PARAMS, jnp.asarray(chunk), cache_b,
                            jnp.asarray([n], jnp.int32))

    np.testing.assert_array_equal(np.asarray(lo_a[0, -1]),
                                  np.asarray(lo_b[0, 0]))
    for sub in cache_a:
        for key in ("k", "v", "pos", "step"):
            a = np.asarray(cache_a[sub][key])
            b = np.asarray(cache_b[sub][key])
            if key in ("k", "v"):
                a, b = a[:, :, :L], b[:, :, :L]   # padding region differs
            np.testing.assert_array_equal(a, b, err_msg=f"{sub}/{key}")


@pytest.mark.parametrize("paged", [False, True], ids=["contig", "paged"])
@pytest.mark.parametrize("chunk", [4, 16])
@pytest.mark.slow
def test_chunked_engine_matches_legacy(chunk, paged):
    """Engine level: more requests than slots, prompts shorter and longer
    than the chunk — greedy output must equal the whole-prompt engine's,
    and every admission must take the chunked path. The paged layout
    (block-table KV pool) must be bit-invisible in the token stream."""
    base, _ = _run()
    out, eng = _run(prefill_chunk=chunk, paged=paged, page_size=8)
    assert out == base
    st = eng.latency_stats()
    assert st["chunked_admissions"] == len(_PROMPTS)
    assert st["prefill_chunk"] == chunk
    if paged:
        assert st["kv_pages_live"] == 0


@pytest.mark.slow
def test_chunked_max_new_one_and_eos_free_slot():
    """max_new=1: the chunked admission emits exactly one token and never
    arms the slot; eos on the first token behaves the same way."""
    out, eng = _run(max_new=1, prefill_chunk=8)
    base, _ = _run(max_new=1)
    assert out == base
    assert all(len(t) == 1 for t in out.values())
    # eos on the first generated token
    first = base[0][0]
    eng2 = _engine(prefill_chunk=8)
    eng2.submit(Request(uid=0, prompt=_PROMPTS[0], max_new_tokens=10,
                        eos_id=int(first)))
    eng2.submit(Request(uid=1, prompt=_PROMPTS[1], max_new_tokens=3))
    resp = eng2.run()
    assert resp[0].n_generated == 1 and resp[0].finish_reason == "eos"
    assert resp[1].finished and resp[1].n_generated == 3


@pytest.mark.slow
def test_ssm_stacks_admit_through_chunked_path():
    """SSM stacks flow through the same chunked admission as attention
    stacks (the ssd_extend recurrence): chunk-size choice is invisible
    in the greedy output and nothing falls back."""
    cfg = get_arch("mamba2-780m", variant="reduced")
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))

    def run(**kw):
        eng = Engine(model, params, max_batch=2, cache_len=64,
                     sampler=Sampler(), **kw)
        for uid, p in enumerate(_PROMPTS[:3]):
            eng.submit(Request(uid=uid, prompt=p, max_new_tokens=4))
        return {u: r.tokens for u, r in eng.run().items()}, eng

    base, eng0 = run()                       # 0 = one max-size chunk
    out, eng = run(prefill_chunk=8)
    assert out == base
    assert eng0.prefill_chunk == eng0.kv_len
    assert eng.prefill_chunk == 8
    for e in (eng0, eng):
        st = e.latency_stats()
        assert st["chunked_admissions"] == 3
        assert st["fallback_admissions"] == 0


# ------------------------------------------------------------------ #
# shared-prefix KV reuse
# ------------------------------------------------------------------ #
@pytest.mark.parametrize("paged", [False, True], ids=["contig", "paged"])
@pytest.mark.slow
def test_prefix_hit_matches_cold_path(paged):
    """Requests sharing a system-prompt head: the second admission
    reuses the stored prefix instead of recomputing it, with
    token-identical greedy output — including a *partial* hit, where the
    shared head is shorter than the stored entry. Contiguous serves the
    hit with one device copy; paged serves it with a zero-copy page
    alias."""
    head = _RNG.integers(0, _CFG.vocab, 16)
    prompts = [np.concatenate([head, _RNG.integers(0, _CFG.vocab, n)])
               for n in (9, 5, 12)]
    cold, _ = _run(prompts=prompts, prefill_chunk=8)
    hot, eng = _run(prompts=prompts, prefill_chunk=8,
                    prefix_cache_tokens=256, paged=paged, page_size=8)
    assert hot == cold
    st = eng.latency_stats()
    assert st["prefix_hits"] >= 2
    assert st["prefix_hit_tokens"] >= 2 * 16
    assert st["prefix_entries"] >= 1
    if paged:
        assert st["kv_alias_pages"] >= 2 * (16 // 8)


@pytest.mark.parametrize("paged", [False, True], ids=["contig", "paged"])
@pytest.mark.slow
def test_prefix_eviction_under_token_cap(paged):
    """Distinct prefixes past the token budget evict LRU entries; stored
    tokens never exceed the cap and correctness is unaffected. In paged
    mode each eviction also releases the entry's pinned pages."""
    prompts = [np.concatenate([_RNG.integers(0, _CFG.vocab, 16),
                               _RNG.integers(0, _CFG.vocab, 4)])
               for _ in range(4)]
    cold, _ = _run(prompts=prompts, prefill_chunk=8)
    hot, eng = _run(prompts=prompts, prefill_chunk=8, paged=paged,
                    page_size=8,
                    prefix_cache_tokens=32)   # cap: two 16-token entries
    assert hot == cold
    st = eng.latency_stats()
    assert st["prefix_tokens"] <= 32
    assert st["prefix_evictions"] >= 2
    if paged:
        # evicted entries dropped their page refs; only surviving
        # entries still pin pages (streams are all harvested)
        assert eng._paged.live_pages == 2 * len(eng.prefix_cache)


def test_prefix_cache_trie_unit():
    pc = PrefixCache(capacity_tokens=64, chunk=8)
    assert pc.bucket(7) == 0 and pc.bucket(8) == 8 and pc.bucket(31) == 16
    a = list(range(40))
    assert pc.wants(a) == 32          # largest power-of-two chunk mult
    pc.insert(a, 32, kv="A")
    assert pc.wants(a) == 0           # already stored
    # exact-prefix hit, shorter prompt
    kv, ent, q = pc.lookup(a[:33])
    assert (kv, ent, q) == ("A", 32, 32)
    # partial hit: only 20 tokens shared -> bucket 16 of entry A
    kv, ent, q = pc.lookup(a[:20] + [999] * 30)
    assert (kv, ent, q) == ("A", 32, 16)
    # no hit below one chunk
    assert pc.lookup([999, 998])[0] is None
    # prompt must keep >= 1 token to prefill: a 32-token prompt can only
    # use a shorter bucket of the stored 32-token entry
    kv, ent, q = pc.lookup(a[:32])
    assert q == 16
    # LRU eviction under the cap: A's last touch predates B's insert,
    # so A is the least recently used and goes first
    b = [1000 + i for i in range(40)]
    pc.insert(b, 32, kv="B")          # 64 tokens stored, at cap
    c = [2000 + i for i in range(24)]
    pc.insert(c, 16, kv="C")          # 80 > 64 -> evict LRU
    assert pc.tokens <= 64 and pc.evictions >= 1
    assert pc.lookup(a[:33])[0] is None
    assert pc.lookup(b)[0] == "B" and pc.lookup(c[:17])[0] == "C"


# ------------------------------------------------------------------ #
# composition: mixed step + int8 KV + speculative decoding
# ------------------------------------------------------------------ #
@pytest.mark.parametrize("paged", [False, True], ids=["contig", "paged"])
@pytest.mark.slow
def test_chunked_composes_with_int8_kv(paged):
    base, _ = _run(kv_cache_dtype="int8")
    out, eng = _run(kv_cache_dtype="int8", prefill_chunk=8,
                    prefix_cache_tokens=256, paged=paged, page_size=8)
    assert out == base
    assert eng.latency_stats()["chunked_admissions"] == len(_PROMPTS)


@pytest.mark.parametrize("paged", [False, True], ids=["contig", "paged"])
@pytest.mark.slow
def test_chunked_composes_with_speculative_decoding(paged):
    """Chunked admission runs as its own extend program right before the
    fused spec step; greedy output stays token-identical to the plain
    engine (the speculative contract) while admissions are chunked. In
    paged mode the target cache is the page pool — speculative rollback
    rides on pos/step exactly as in the contiguous layout."""
    base, _ = _run(max_new=10)
    out, eng = _run(max_new=10, draft="int8@1", spec_gamma=3,
                    prefill_chunk=8, paged=paged, page_size=8)
    assert out == base
    st = eng.latency_stats()
    assert st["chunked_admissions"] == len(_PROMPTS)
    assert st["spec_gamma"] == 3
    # prefix reuse is target-cache-only; spec mode must disable it
    assert eng.prefix_cache is None


@pytest.mark.parametrize("paged", [False, True], ids=["contig", "paged"])
@pytest.mark.slow
def test_chunked_spec_with_int8_kv(paged):
    base, _ = _run(max_new=8, kv_cache_dtype="int8")
    out, _ = _run(max_new=8, kv_cache_dtype="int8", draft="int8@1",
                  spec_gamma=3, prefill_chunk=8, paged=paged, page_size=8)
    assert out == base


# ------------------------------------------------------------------ #
# latency stats + open-loop driving
# ------------------------------------------------------------------ #
def test_latency_stats_percentiles_and_tick():
    eng = _engine(prefill_chunk=8, sync_every=4)
    for uid, p in enumerate(_PROMPTS[:3]):
        eng.submit(Request(uid=uid, prompt=p, max_new_tokens=8))
    total = 0
    while eng.has_work and total < 500:
        total += max(1, eng.tick(4))
    assert all(r.finished for r in eng.responses.values())
    st = eng.latency_stats()
    for key in ("ttft_ms_p50", "ttft_ms_p95", "ttft_ms_p99",
                "itl_ms_mean", "itl_ms_p50", "itl_ms_p95", "itl_ms_p99"):
        assert key in st and st[key] >= 0.0
    assert st["itl_ms_p50"] > 0.0
    # reset_stats keeps programs + prefix entries, clears history
    eng.reset_stats()
    assert eng.step_times == [] and eng.latency_stats()["n_finished"] == 0
