"""Paged KV cache correctness (``serving/paged_kv.py``).

Three layers of proof, mirroring the module's split of responsibilities:

* **Allocator property tests** — random interleavings of the full host
  op vocabulary (extend / snapshot / fork-alias / release / evict /
  rollback-shrink) with ``check_invariants`` after every op: page
  conservation (live + free == pool), refcount/block-table agreement,
  no double free, CoW isolation, exhaustion atomicity and free-list
  determinism. Runs under ``hypothesis`` when installed (it is in
  requirements-dev.txt) and falls back to seeded-random fuzzing of the
  same interpreter otherwise.
* **View bit-equality** — the gathered paged view of a chunk-fed cache
  is bit-identical to the contiguous cache at the same logical
  positions, for fp and int8 KV (``layers.paged_kv_view`` gathers then
  dequantizes, elementwise-identical to the contiguous read).
* **Engine lifecycle** — paged greedy output equals the contiguous
  engine's; admission backpressure queues (never corrupts) under page
  exhaustion; LRU prefix reclaim fires under pressure and evicting an
  entry whose pages a live stream still aliases leaves the stream
  unharmed; prefix hits alias pages with zero KV copies (the
  materialize/extract slot programs are never built).
"""
import jax
import numpy as np
import pytest

from repro.configs import get_arch
from repro.models import layers as L
from repro.models.model import build
from repro.serving import paged_kv
from repro.serving.engine import Engine
from repro.serving.paged_kv import PagedKVState, PagePoolExhausted
from repro.serving.prefix_cache import PrefixCache
from repro.serving.request import Request
from repro.serving.sampler import Sampler

try:
    from hypothesis import given, settings
    from hypothesis import strategies as hst
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

_CFG = get_arch("llama3.2-1b", variant="reduced")
_MODEL = build(_CFG)
_PARAMS = _MODEL.init(jax.random.PRNGKey(0))
_RNG = np.random.default_rng(42)


# ------------------------------------------------------------------ #
# allocator property tests
# ------------------------------------------------------------------ #
# The interpreter drives a PagedKVState through the same op vocabulary
# the engine uses, from an opaque stream of (op, a, n) integer triples —
# deterministic given the stream, so hypothesis and the seeded fallback
# share it and a failing stream is its own reproducer.
_B, _KV_LEN, _PS, _POOL = 3, 32, 8, 9


def _apply_ops(ops, B=_B, kv_len=_KV_LEN, ps=_PS, pool=_POOL):
    st = PagedKVState(B, kv_len, ps, pool)
    depths = [None] * B        # None = slot free, else provisioned depth
    entries = []               # published prefix entries (page lists)
    for op, a, n in ops:
        b = a % B
        if op == 0:            # start/extend a stream (engine: _provision)
            if depths[b] is None:
                depths[b] = 0
            before = (st.free_pages, st.alloc.refcount.copy(),
                      st.block_tables.copy())
            try:
                st.prepare_write(b, depths[b], n + 1)
                depths[b] += n + 1
            except PagePoolExhausted:
                # exhaustion must be atomic: nothing allocated, nothing
                # split, the block table untouched
                assert st.free_pages == before[0]
                assert np.array_equal(st.alloc.refcount, before[1])
                assert np.array_equal(st.block_tables, before[2])
        elif op == 1:          # publish a page-aligned prefix entry
            d = depths[b]
            if d is not None and d >= ps:
                k = min(a % (d // ps) + 1, st.n_blocks)
                entries.append(st.snapshot_prefix(b, k * ps))
        elif op == 2:          # fork: alias an entry into a free slot
            free = [i for i in range(B) if depths[i] is None]
            if entries and free:
                e = entries[a % len(entries)]
                st.alias_prefix(free[0], e)
                depths[free[0]] = len(e) * ps
        elif op == 3:          # stream finished
            if depths[b] is not None:
                st.release_slot(b)
                depths[b] = None
        elif op == 4:          # prefix entry evicted (maybe while aliased)
            if entries:
                st.release_pages(entries.pop(a % len(entries)))
        elif op == 5:          # spec-decode rollback: rewind then shrink
            if depths[b]:
                depths[b] = max(0, depths[b] - (n % (2 * ps)))
                st.shrink(b, depths[b])
        st.check_invariants(entries)
        assert st.free_pages + st.live_pages == pool
    return st, entries


def _random_ops(seed, steps=250):
    rng = np.random.default_rng(seed)
    return [(int(rng.integers(0, 6)), int(rng.integers(0, 8)),
             int(rng.integers(0, 16))) for _ in range(steps)]


if HAVE_HYPOTHESIS:
    @given(hst.lists(hst.tuples(hst.integers(0, 5), hst.integers(0, 7),
                                hst.integers(0, 15)), max_size=150))
    @settings(max_examples=60, deadline=None)
    def test_allocator_property_fuzz(ops):
        _apply_ops(ops)
else:
    @pytest.mark.parametrize("seed", range(25))
    def test_allocator_property_fuzz(seed):
        _apply_ops(_random_ops(seed))


def test_allocator_determinism():
    """The free list is LIFO and every op host-ordered: replaying an op
    stream reproduces the block tables and free list exactly (prefill
    replays land on identical pages -> bit-equal caches)."""
    ops = _random_ops(7)
    s1, _ = _apply_ops(ops)
    s2, _ = _apply_ops(ops)
    assert np.array_equal(s1.block_tables, s2.block_tables)
    assert s1.alloc._free == s2.alloc._free
    assert np.array_equal(s1.alloc.refcount, s2.alloc.refcount)


def test_double_free_and_retain_guards():
    st = PagedKVState(1, 16, 8, 4)
    st.prepare_write(0, 0, 8)
    page = int(st.block_tables[0, 0])
    st.release_slot(0)
    with pytest.raises(AssertionError, match="double free"):
        st.alloc.release(page)
    with pytest.raises(AssertionError, match="retain of unallocated"):
        st.alloc.retain(page)


def test_prepare_write_exhaustion_is_atomic():
    """A request the pool cannot cover raises before any allocation —
    the engine's backpressure path retries the identical call later."""
    st = PagedKVState(2, 32, 8, 3)
    st.prepare_write(0, 0, 24)                 # 3 pages: pool drained
    bt = st.block_tables.copy()
    with pytest.raises(PagePoolExhausted):
        st.prepare_write(1, 0, 16)             # needs 2, 0 free
    assert np.array_equal(st.block_tables, bt)
    assert st.free_pages == 0
    st.check_invariants()


def test_cow_split_isolates_aliases():
    """Writes through one alias of a shared page are never visible
    through the other: prepare_write splits the page first and returns
    the (src, dst) copy the engine replays on device. Simulated here
    with a host payload pool standing in for kp/vp."""
    st = PagedKVState(2, 32, 8, 8)
    st.prepare_write(0, 0, 16)                 # slot 0: blocks 0, 1
    payload = np.zeros((st.num_pages + 1, st.page_size), np.int32)
    for p in range(16):
        payload[st.block_tables[0, p // 8], p % 8] = 100 + p

    pages = st.snapshot_prefix(0, 16)          # publish as an entry
    st.alias_prefix(1, pages)                  # fork: refcount bumps only
    assert np.array_equal(st.block_tables[1, :2], st.block_tables[0, :2])
    assert st.alias_pages == 2 and st.cow_splits == 0

    copies = st.prepare_write(1, 3, 1)         # slot 1 overwrites pos 3
    assert len(copies) == 1
    for src, dst in copies:                    # device-side page copy
        payload[dst] = payload[src]
    assert st.block_tables[1, 0] != st.block_tables[0, 0]
    assert st.cow_splits == 1
    payload[st.block_tables[1, 0], 3] = -1     # the write itself
    # donor slot and entry still see the original byte
    assert payload[st.block_tables[0, 0], 3] == 103
    assert payload[pages[0], 3] == 103
    st.check_invariants([pages])


def test_shrink_reallocates_same_pages():
    """Releasing the provisioning overshoot and re-extending draws the
    same pages back off the LIFO free list — depth corrections at poll
    boundaries cannot perturb later block tables."""
    st = PagedKVState(1, 32, 8, 6)
    st.prepare_write(0, 0, 20)                 # blocks 0..2
    tail = int(st.block_tables[0, 2])
    st.shrink(0, 14)                           # true depth 14: block 2 freed
    assert st.block_tables[0, 2] == st.sentinel
    st.prepare_write(0, 14, 4)                 # re-extend across block 2
    assert int(st.block_tables[0, 2]) == tail
    st.check_invariants()


# ------------------------------------------------------------------ #
# prefix-cache wants(): coverage, not exact-key (regression)
# ------------------------------------------------------------------ #
def test_prefix_wants_covered_by_longer_entry():
    """A prompt whose prefix is served by a *longer* stored entry must
    not be re-stored: ``wants`` checks trie coverage, not exact keys.
    (Regression: the old exact-key check re-extracted and re-stored a
    prefix of the donor on every partial hit, double-counting its
    tokens against the LRU budget until eviction.)"""
    pc = PrefixCache(capacity_tokens=256, chunk=8)
    a = list(range(40))
    pc.insert(a, 32, kv="A")
    # prompt covered by A via a partial hit -> nothing to store
    assert pc.wants(a[:24] + [999]) == 0
    # and the hit itself still serves A
    assert pc.lookup(a[:24] + [999]) == ("A", 32, 16)
    # an uncovered prompt still wants storage
    assert pc.wants([7] * 40) == 32
    # token accounting: a second insert for the covered prompt is the
    # bug's signature; wants()==0 means the engine never attempts it
    assert pc.tokens == 32 and len(pc) == 1


def test_prefix_on_evict_fires_with_entry():
    released = []
    pc = PrefixCache(capacity_tokens=16, chunk=8,
                     on_evict=lambda e: released.append(e["kv"]))
    pc.insert(list(range(20)), 16, kv=[3, 4])
    pc.insert([100 + i for i in range(20)], 16, kv=[5, 6])
    assert pc.evictions == 1 and released == [[3, 4]]
    assert pc.drop_lru() and released == [[3, 4], [5, 6]]
    assert not pc.drop_lru()


# ------------------------------------------------------------------ #
# paged view bit-equality (model level)
# ------------------------------------------------------------------ #
def _drive_paged_cache(model, prompt, S, ps, pool, chunk=8):
    """Feed ``prompt`` through chunked paged extends exactly as the
    engine does: provision pages host-side, push the block table, run
    the masked extend. Returns (last logits, cache, state)."""
    st = PagedKVState(1, S, ps, pool)
    cache = model.make_paged_cache(1, S, page_size=ps, num_pages=pool)
    ext = jax.jit(lambda p, t, c, l: model.extend_into_cache(
        p, t, c, l, last_only=True))
    lo = None
    for base in range(0, len(prompt), chunk):
        n = min(chunk, len(prompt) - base)
        assert st.prepare_write(0, base, n) == []   # cold: no CoW copies
        cache = paged_kv.walk_attn(cache, lambda nd: {
            **nd, "bt": np.broadcast_to(st.block_tables, nd["bt"].shape)})
        buf = np.zeros((1, chunk), np.int32)
        buf[0, :n] = prompt[base:base + n]
        lo, cache = ext(_PARAMS, jax.numpy.asarray(buf), cache,
                        jax.numpy.asarray([n], np.int32))
    return lo, cache, st


def _drive_contiguous_cache(model, prompt, S, chunk=8):
    cache = model.make_cache(1, S)
    ext = jax.jit(lambda p, t, c, l: model.extend_into_cache(
        p, t, c, l, last_only=True))
    lo = None
    for base in range(0, len(prompt), chunk):
        n = min(chunk, len(prompt) - base)
        buf = np.zeros((1, chunk), np.int32)
        buf[0, :n] = prompt[base:base + n]
        lo, cache = ext(_PARAMS, jax.numpy.asarray(buf), cache,
                        jax.numpy.asarray([n], np.int32))
    return lo, cache


@pytest.mark.parametrize("quant", [False, True], ids=["fp", "int8"])
def test_paged_view_bit_equality_after_admission(quant):
    """After identical chunked admission, gathering the page pool
    through the block table reproduces the contiguous cache bit for bit
    (raw int8 payloads and scales included), and the next-token logits
    match exactly."""
    cfg = _CFG.replace(kv_quant=True) if quant else _CFG
    model = build(cfg) if quant else _MODEL
    Lp, S, ps = 13, 32, 8
    prompt = _RNG.integers(0, cfg.vocab, Lp)
    lo_p, cache_p, st = _drive_paged_cache(model, prompt, S, ps, pool=8)
    lo_c, cache_c = _drive_contiguous_cache(model, prompt, S)
    np.testing.assert_array_equal(np.asarray(lo_p[0, 0]),
                                  np.asarray(lo_c[0, 0]))
    raw = {"k": "kp", "v": "vp", "k_scale": "kp_scale",
           "v_scale": "vp_scale"}
    for sub in cache_c:
        node_p, node_c = cache_p[sub], cache_c[sub]
        nb = node_c["pos"].shape[0]
        for i in range(nb):                    # per scanned block layer
            bt = np.asarray(node_p["bt"][i])
            for ck, pk in raw.items():
                if ck not in node_c:
                    continue
                pool = np.asarray(node_p[pk][i])
                got = pool[bt].reshape((bt.shape[0], -1)
                                       + pool.shape[2:])[:, :Lp]
                want = np.asarray(node_c[ck][i])[:, :Lp]
                np.testing.assert_array_equal(got, want,
                                              err_msg=f"{sub}[{i}]/{ck}")
            for mk in ("pos", "step"):
                np.testing.assert_array_equal(np.asarray(node_p[mk][i]),
                                              np.asarray(node_c[mk][i]))
            if not quant:                      # the dequantized read view
                kv_view = L.paged_kv_view(
                    {k: np.asarray(v[i]) for k, v in node_p.items()},
                    np.asarray(node_c["k"][i]).dtype)
                np.testing.assert_array_equal(
                    kv_view[0][:, :Lp], np.asarray(node_c["k"][i])[:, :Lp])
    assert st.cow_splits == 0


# ------------------------------------------------------------------ #
# engine lifecycle
# ------------------------------------------------------------------ #
def _run(prompts, max_new=4, **kw):
    kw.setdefault("max_batch", 2)
    kw.setdefault("cache_len", 32)
    kw.setdefault("sampler", Sampler())
    eng = Engine(_MODEL, _PARAMS, **kw)
    for uid, p in enumerate(prompts):
        eng.submit(Request(uid=uid, prompt=p, max_new_tokens=max_new))
    resp = eng.run()
    return {u: r.tokens for u, r in resp.items()}, eng


_SMALL = [_RNG.integers(0, _CFG.vocab, n) for n in (3, 11, 7)]


def test_paged_engine_matches_contiguous():
    """Greedy output is token-identical to the contiguous engine, and
    the pool fully drains once every stream is harvested."""
    base, _ = _run(_SMALL)
    out, eng = _run(_SMALL, paged=True, page_size=8)
    assert out == base
    st = eng.latency_stats()
    assert st["kv_pages_live"] == 0
    assert st["kv_pages_free"] == st["kv_pages_total"]
    assert st["kv_pages_released"] > 0
    # the engine enforces the one-full-stream floor at construction
    with pytest.raises(ValueError, match="cannot hold one full stream"):
        _run(_SMALL, paged=True, page_size=8, num_pages=2)


def test_page_exhaustion_backpressure():
    """A pool sized for one stream serves two big requests by queueing
    the second until the first releases its pages — output identical to
    the contiguous engine, no mid-decode corruption."""
    prompts = [_RNG.integers(0, _CFG.vocab, 20) for _ in range(2)]
    base, _ = _run(prompts)
    # n_blocks = 4 (cache_len 32 / page 8): both streams can never be
    # resident at once, so admission backpressure must fire
    out, eng = _run(prompts, paged=True, page_size=8, num_pages=4)
    assert out == base
    assert all(len(t) == 4 for t in out.values())
    assert eng.latency_stats()["kv_pages_live"] == 0


def test_lru_reclaim_and_eviction_while_shared():
    """Page pressure reclaims LRU prefix entries; evicting an entry
    whose pages the donor stream still references must not perturb that
    stream (refcounts keep the pages alive until it finishes)."""
    pa = _RNG.integers(0, _CFG.vocab, 20)
    pb = _RNG.integers(0, _CFG.vocab, 24)
    base, _ = _run([pa, pb], prefill_chunk=8)
    # pool of 5: A's admission leaves too few free pages for B, the
    # reclaim loop evicts A's just-published 2-page entry (still aliased
    # by A itself), and B waits for A's release
    out, eng = _run([pa, pb], prefill_chunk=8, prefix_cache_tokens=64,
                    paged=True, page_size=8, num_pages=5)
    assert out == base
    st = eng.latency_stats()
    assert st["prefix_evictions"] >= 1
    # the surviving entries (B's own published prefix) pin the only
    # still-live pages; dropping them drains the pool completely
    while eng.prefix_cache.drop_lru():
        pass
    assert eng._paged.live_pages == 0


def test_prefix_hit_aliases_pages_zero_copy():
    """A shared-head hit bumps refcounts instead of copying KV: alias
    pages are counted, and the contiguous path's materialize/extract
    slot programs are never even built."""
    head = _RNG.integers(0, _CFG.vocab, 16)
    prompts = [np.concatenate([head, _RNG.integers(0, _CFG.vocab, n)])
               for n in (6, 4, 9)]
    cold, _ = _run(prompts, prefill_chunk=8, cache_len=64)
    hot, eng = _run(prompts, prefill_chunk=8, cache_len=64,
                    prefix_cache_tokens=256, paged=True, page_size=8)
    assert hot == cold
    st = eng.latency_stats()
    assert st["prefix_hits"] >= 2
    assert st["kv_alias_pages"] >= 2 * (16 // 8)
    assert not any(k[0] in ("materialize", "extract")
                   for k in eng._slot_jits)
    # entries release their pinned pages with the engine's drain
    while eng.prefix_cache.drop_lru():
        pass
    assert eng._paged.live_pages == 0


def test_paged_submit_rejects_oversized_prompt():
    eng = Engine(_MODEL, _PARAMS, max_batch=1, cache_len=32,
                 sampler=Sampler(), paged=True, page_size=8)
    with pytest.raises(ValueError, match="KV capacity"):
        eng.submit(Request(uid=0, prompt=_RNG.integers(0, _CFG.vocab, 40),
                           max_new_tokens=2))
