"""Training substrate: optimizer behaviour, gradient accumulation
equivalence, checkpoint state roundtrip, loss decrease end-to-end."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.data.pipeline import batches_for
from repro.models.model import build
from repro.training.optimizer import AdamW, constant_schedule, global_norm
from repro.training.train_loop import (init_train_state, make_train_step,
                                       train)


def test_adamw_minimizes_quadratic():
    opt = AdamW(lr=constant_schedule(0.1), weight_decay=0.0)
    params = {"x": jnp.asarray([5.0, -3.0])}
    state = opt.init(params)
    for _ in range(200):
        grads = {"x": 2 * params["x"]}
        params, state = opt.update(grads, state, params)
    assert float(jnp.max(jnp.abs(params["x"]))) < 1e-2


def test_grad_clipping_bounds_update():
    opt = AdamW(lr=constant_schedule(1.0), clip_norm=1.0, weight_decay=0.0)
    params = {"x": jnp.zeros(4)}
    state = opt.init(params)
    huge = {"x": jnp.full((4,), 1e9)}
    new_params, _ = opt.update(huge, state, params)
    assert bool(jnp.all(jnp.isfinite(new_params["x"])))


def test_microbatch_equals_full_batch_grads():
    """Gradient accumulation must be numerically equivalent (fp32 model)."""
    cfg = get_arch("llama3.2-1b", variant="reduced")
    model = build(cfg)
    opt = AdamW(lr=constant_schedule(1e-3))
    state = init_train_state(model, opt, jax.random.PRNGKey(0))
    data = batches_for(cfg, batch=8, seq_len=32)
    batch = next(data)
    s_full, m_full = jax.jit(make_train_step(model, opt))(state, batch)
    s_micro, m_micro = jax.jit(make_train_step(model, opt, microbatch=2))(
        state, batch)
    np.testing.assert_allclose(float(m_full["loss"]),
                               float(m_micro["loss"]), rtol=1e-5)
    for a, b in zip(jax.tree.leaves(s_full["params"]),
                    jax.tree.leaves(s_micro["params"])):
        # accumulation reassociates the batch-mean sum; allow a few ulps
        # of f32 slack on top of the optimizer-step magnitude
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=5e-5)


def test_loss_decreases_end_to_end():
    cfg = get_arch("llama3.2-1b", variant="reduced")
    model = build(cfg)
    from repro.training.optimizer import cosine_schedule
    opt = AdamW(lr=cosine_schedule(3e-3, 5, 80))
    data = batches_for(cfg, batch=8, seq_len=64, seed=1)
    _, hist = train(model, opt, data, steps=80, log_every=79)
    assert hist[-1]["loss"] < hist[0]["loss"] - 0.5, hist


def test_train_state_checkpoint_roundtrip(tmp_path):
    from repro.training.checkpoints import (load_train_state,
                                            save_train_state)
    cfg = get_arch("mamba2-780m", variant="reduced")
    model = build(cfg)
    opt = AdamW(lr=constant_schedule(1e-3))
    state = init_train_state(model, opt, jax.random.PRNGKey(0))
    save_train_state(tmp_path, 7, state["params"], state["opt"])
    step, params, opt_state = load_train_state(tmp_path)
    assert step == 7
    for a, b in zip(jax.tree.leaves(state["params"]),
                    jax.tree.leaves(params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_global_norm():
    t = {"a": jnp.asarray([3.0]), "b": jnp.asarray([4.0])}
    assert abs(float(global_norm(t)) - 5.0) < 1e-6


def test_checkpoint_load_fails_fast_on_truncation(tmp_path):
    """Regression for the truncated-checkpoint fault site: a crash
    mid-write (simulated by chopping the payload) must surface as a
    named CheckpointError at load, never as a shape error later."""
    from repro.serving.faults import truncate_file
    from repro.training.checkpoints import (CheckpointError, load_pytree,
                                            save_pytree)
    tree = {"w": np.arange(64, dtype=np.float32).reshape(8, 8),
            "b": {"x": np.ones(3, np.float32)}}
    save_pytree(tmp_path / "ck", tree)
    truncate_file(tmp_path / "ck.npz", 0.5)
    with pytest.raises(CheckpointError, match="truncated or corrupt"):
        load_pytree(tmp_path / "ck")


def test_checkpoint_manifest_validates_structure(tmp_path):
    import json
    from repro.training.checkpoints import (CheckpointError, load_pytree,
                                            save_pytree)
    tree = {"w": np.ones((4, 4), np.float32)}
    save_pytree(tmp_path / "ck", tree)
    man = json.loads((tmp_path / "ck.json").read_text())
    man["leaves"]["w"]["shape"] = [2, 2]
    (tmp_path / "ck.json").write_text(json.dumps(man))
    with pytest.raises(CheckpointError, match="shape"):
        load_pytree(tmp_path / "ck")
    man["leaves"]["w"]["shape"] = [4, 4]
    man["leaves"]["w"]["dtype"] = "float64"
    (tmp_path / "ck.json").write_text(json.dumps(man))
    with pytest.raises(CheckpointError, match="dtype"):
        load_pytree(tmp_path / "ck")
    man["leaves"]["ghost"] = {"shape": [1], "dtype": "float32"}
    (tmp_path / "ck.json").write_text(json.dumps(man))
    with pytest.raises(CheckpointError, match="disagree with manifest"):
        load_pytree(tmp_path / "ck")


def test_checkpoint_save_is_atomic(tmp_path):
    """No temp litter, and a re-save replaces in place (os.replace)."""
    from repro.training.checkpoints import load_pytree, save_pytree
    save_pytree(tmp_path / "ck", {"w": np.zeros(4, np.float32)})
    save_pytree(tmp_path / "ck", {"w": np.ones(4, np.float32)})
    assert [p.name for p in tmp_path.iterdir()
            if p.name.startswith(".")] == []
    np.testing.assert_array_equal(load_pytree(tmp_path / "ck")["w"],
                                  np.ones(4, np.float32))
