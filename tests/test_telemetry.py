"""Serving telemetry contracts: registry primitives, percentile
helpers, Chrome trace schema + per-request spans, counter/engine
agreement across serving modes, no-op recorder invisibility, artifact
schema validation, and the recompile watchdog."""
import json
import warnings

import jax
import numpy as np
import pytest

from benchmarks import schema
from repro.configs import get_arch
from repro.models.model import build
from repro.serving import telemetry, tracing
from repro.serving.engine import Engine
from repro.serving.request import Request
from repro.serving.sampler import Sampler

_CFG = get_arch("llama3.2-1b", variant="reduced")
_MODEL = build(_CFG)
_PARAMS = _MODEL.init(jax.random.PRNGKey(0))


def _engine(**kw):
    kw.setdefault("max_batch", 2)
    kw.setdefault("cache_len", 64)
    kw.setdefault("sampler", Sampler())
    return Engine(_MODEL, _PARAMS, **kw)


def _stream(eng, n=4, max_new=5, seed=0):
    rng = np.random.default_rng(seed)
    for uid in range(n):
        L = int(rng.integers(3, 20))
        eng.submit(Request(uid=uid, prompt=rng.integers(0, _CFG.vocab, L),
                           max_new_tokens=max_new))
    return eng.run()


# ------------------------------------------------------------------ #
# registry primitives (no model)
# ------------------------------------------------------------------ #
def test_percentile_and_pct_stats_contract():
    xs = [0.001 * i for i in range(1, 101)]            # 1..100 ms
    assert telemetry.percentile(xs, 50) == pytest.approx(0.0505)
    st = {}
    telemetry.pct_stats(st, "lat_ms", xs, (50, 99))
    assert set(st) == {"lat_ms_mean", "lat_ms_p50", "lat_ms_p99"}
    assert st["lat_ms_p50"] == pytest.approx(50.5)     # seconds -> ms
    empty = {}
    telemetry.pct_stats(empty, "lat_ms", [], (50,))
    assert empty == {}                                  # no fake zeros
    with pytest.raises(Exception):
        telemetry.percentile([], 50)


def test_registry_reset_and_persist():
    reg = telemetry.MetricsRegistry()
    reg.counter("tokens").inc(5)
    reg.counter("compiles", persist=True).inc(2)
    reg.gauge("active").set(3)
    reg.histogram("ttft").observe(0.5)
    reg.get_series("wall").append(1.0)
    reg.reset()
    snap = reg.snapshot()
    assert snap["counters"]["tokens"] == 0
    assert snap["counters"]["compiles"] == 2            # persists
    assert snap["gauges"]["active"] == 0.0
    assert snap["histograms"]["ttft"]["count"] == 0
    json.dumps(snap)                                    # serializable


def test_histogram_reservoir_bounded_and_deterministic():
    h1 = telemetry.Histogram(cap=64)
    h2 = telemetry.Histogram(cap=64)
    for i in range(1000):
        h1.observe(float(i))
        h2.observe(float(i))
    assert h1.count == 1000 and len(h1.samples) == 64
    assert h1.samples == h2.samples                     # seeded
    assert "p50" in h1.summary((50,))


def test_validate_payload():
    pl = schema.payload("x", run={"smoke": True},
                        metrics=[schema.metric("a", "u", 1.0)],
                        data={}, telemetry={"counters": {}, "gauges": {},
                                            "histograms": {}})
    assert schema.validate_payload(pl) == []
    assert pl["schema_version"] == 2
    v1 = {"bench": "x", "schema_version": 1, "run": {}, "metrics": [],
          "data": {}}
    assert schema.validate_payload(v1) == []            # v1 still valid
    bad = dict(pl, telemetry={"counters": []})
    assert schema.validate_payload(bad)
    assert schema.validate_payload({"bench": ""})


def test_watchdog_arms_and_warns():
    reg = telemetry.MetricsRegistry()
    wd = telemetry.CompileWatchdog(reg, telemetry.Recorder())
    with warnings.catch_warnings():
        warnings.simplefilter("error")                  # warmup is silent
        wd.record("step", 0.1, step=0, ts=0.0)
    wd.arm()
    with pytest.warns(telemetry.RecompileWarning, match="mixed"):
        wd.record("mixed", 0.2, step=5, ts=1.0)
    snap = reg.snapshot()
    assert snap["counters"]["compiles_total"] == 2
    assert snap["counters"]["steady_compiles"] == 1
    logged = reg.get_series("compiles").values
    assert [e["steady"] for e in logged] == [False, True]


# ------------------------------------------------------------------ #
# engine integration
# ------------------------------------------------------------------ #
def test_trace_schema_and_request_spans(tmp_path):
    eng = _engine(recorder=True, prefill_chunk=4)
    resp = _stream(eng, n=4, max_new=5)
    path = str(tmp_path / "trace.json")
    eng.export_trace(path)
    assert tracing.validate_chrome_trace(path) == []
    with open(path) as f:
        trace = json.load(f)
    spans = tracing.complete_spans(trace)
    assert len(spans) == 4                      # one complete span/request
    for uid, r in resp.items():
        span = spans[f"req {uid}"]
        assert span["args"]["generated"] == len(r.tokens)
        assert span["args"]["finish"] == r.finish_reason
    kinds = {e["name"] for e in trace["traceEvents"]
             if e.get("tid") == tracing.STEP_TID and e["ph"] == "X"}
    assert kinds <= {"plain", "mixed", "admit"} and kinds
    assert any(e["ph"] == "C" and e["name"] == "active_slots"
               for e in trace["traceEvents"])


def test_export_trace_requires_recorder():
    eng = _engine()
    with pytest.raises(RuntimeError, match="recorder=True"):
        eng.export_trace()


@pytest.mark.parametrize("kw", [
    {},                                                   # plain
    {"prefill_chunk": 4},                                 # chunked
    {"prefill_chunk": 4, "prefix_cache_tokens": 256},     # prefix
    {"paged": True, "page_size": 8},                      # paged
    {"draft": "fp@1", "spec_gamma": 2},                   # speculative
], ids=["plain", "chunked", "prefix", "paged", "spec"])
def test_registry_counters_match_engine_outputs(kw):
    eng = _engine(max_batch=1 if "draft" in kw else 2, **kw)
    resp = _stream(eng, n=3, max_new=4)
    st = eng.latency_stats()
    c = eng.metrics.snapshot()["counters"]
    assert c["tokens_emitted"] == st["tokens_generated"] \
        == sum(len(r.tokens) for r in resp.values())
    assert c["steps_total"] == eng._steps == sum(
        v for k, v in c.items() if k.startswith("steps_")
        and k != "steps_total")
    if eng.prefill_chunk:
        assert c["chunked_admissions"] == st["chunked_admissions"] > 0
    if eng.spec_gamma:
        assert c["spec_tokens_emitted"] > 0
        assert st["spec_tokens_per_step"] == pytest.approx(
            c["spec_tokens_emitted"] / c["spec_active_steps"])
    collected = eng.metrics.snapshot()["collected"]
    if eng.paged:
        assert collected["kv_pages_live"] == 0           # all harvested
    if "prefix_cache_tokens" in kw:
        assert "prefix_hits" in collected


def test_noop_recorder_is_invisible():
    """Default (no-op) telemetry must not change greedy output or the
    set/size of compiled programs vs a tracing engine."""
    out, progs = [], []
    for rec in (None, True):
        eng = _engine(prefill_chunk=4, recorder=rec)
        resp = _stream(eng, n=3, max_new=4, seed=3)
        out.append({u: list(r.tokens) for u, r in resp.items()})
        progs.append(eng.program_cache_sizes())
    assert out[0] == out[1]
    assert progs[0] == progs[1]


def test_latency_stats_keys_preserved():
    eng = _engine(prefill_chunk=4)
    _stream(eng, n=3, max_new=4)
    st = eng.latency_stats()
    for k in ("n_finished", "tokens_generated", "decode_steps",
              "fallback_admissions", "chunked_admissions",
              "decode_ms_mean", "decode_ms_p50", "decode_ms_p99",
              "ttft_ms_mean", "ttft_ms_p50", "ttft_ms_p95", "ttft_ms_p99",
              "itl_ms_mean", "itl_ms_p50", "itl_ms_p95", "itl_ms_p99"):
        assert k in st, k
    # token-id requests never leave the fast path
    assert st["fallback_admissions"] == 0


def test_steady_state_recompile_warns():
    """After reset_stats() (the warmed-bench boundary) the first request
    to hit a still-cold program — here the ``materialize`` slot program
    a prefix-cache hit compiles on first use — must raise
    RecompileWarning and count as a steady compile."""
    eng = _engine(max_batch=1, prefix_cache_tokens=64, prefill_chunk=4)
    prompt = np.arange(12) % _CFG.vocab
    eng.submit(Request(uid=0, prompt=prompt, max_new_tokens=3))
    eng.run()                  # warm the step/mixed/reset programs and
    eng.reset_stats()          # publish the prefix; arm the watchdog
    eng.submit(Request(uid=1, prompt=prompt, max_new_tokens=3))
    with pytest.warns(telemetry.RecompileWarning, match="materialize"):
        eng.run()              # prefix hit -> cold materialize program
    c = eng.metrics.snapshot()["counters"]
    assert c["steady_compiles"] >= 1
    assert c["compiles_total"] > c["steady_compiles"]   # warmup counted too
