"""Serving resilience: deadlines, cancellation, preemptive requeue.

The lifecycle contract (docs/robustness.md): every submitted request
reaches a terminal ``finish_reason``; "cancelled"/"timeout" free the
slot and KV pages immediately while keeping partial output; a preempted
stream resumes token-identical to an unpreempted run.
"""
import time

import jax
import numpy as np
import pytest

from repro.configs import get_arch
from repro.models.model import build
from repro.serving import faults as faults_mod
from repro.serving.engine import Engine
from repro.serving.faults import Faults, NoFaults, from_env
from repro.serving.request import FINISH_REASONS, Request
from repro.serving.sampler import Sampler

_CFG = get_arch("llama3.2-1b", variant="reduced")
_MODEL = build(_CFG)
_PARAMS = _MODEL.init(jax.random.PRNGKey(0))
_RNG = np.random.default_rng(31)

# engine-construction kwargs per serving mode (see docs/serving.md)
MODES = {
    "plain": dict(prefill_chunk=0),
    "chunked": dict(prefill_chunk=8),
    "prefix": dict(prefill_chunk=8, prefix_cache_tokens=256),
    "paged": dict(prefill_chunk=8, paged=True, page_size=8),
    "spec": dict(draft="fp@1", spec_gamma=4),
}


def _engine(mode="plain", **kw):
    base = dict(MODES[mode])
    base.update(kw)
    base.setdefault("max_batch", 2)
    base.setdefault("cache_len", 64)
    base.setdefault("sampler", Sampler())
    return Engine(_MODEL, _PARAMS, **base)


def _prompts(n, lo=4, hi=12, rng=_RNG):
    return [rng.integers(0, _CFG.vocab, int(rng.integers(lo, hi)))
            for _ in range(n)]


# ------------------------------------------------------------------ #
# fault-registry unit tests (no engine)
# ------------------------------------------------------------------ #
def test_faults_parse_grammar():
    f = Faults.parse("nan_logits@12/1,page_alloc@30x2,"
                     "slow_step+0.05,transport_drop x-1 %0.5".replace(
                         " ", ""), seed=3)
    sites = [s.site for s in f.specs]
    assert sites == ["nan_logits", "page_alloc", "slow_step",
                     "transport_drop"]
    assert f.specs[0].step == 12 and f.specs[0].slot == 1
    assert f.specs[1].times == 2
    assert f.specs[2].delay_s == pytest.approx(0.05)
    assert f.specs[3].times == -1 and f.specs[3].p == pytest.approx(0.5)
    with pytest.raises(ValueError, match="unknown fault site"):
        Faults.parse("warp_core_breach")
    with pytest.raises(ValueError, match="bad fault spec"):
        Faults.parse("nan_logits@@3")


def test_faults_fire_filters_and_exhaustion():
    f = Faults(seed=0).on("page_alloc", step=3, times=2)
    assert f.fire("page_alloc", step=1) is None
    assert f.fire("nan_logits", step=3) is None
    assert f.fire("page_alloc", step=3) is not None
    assert f.fire("page_alloc", step=3) is not None
    assert f.fire("page_alloc", step=3) is None          # exhausted
    assert f.stats() == {"faults_fired_total": 2,
                         "faults_fired_page_alloc": 2}


def test_faults_probabilistic_replay_is_deterministic():
    def seq(seed):
        f = Faults(seed=seed).on("transport_drop", times=-1, p=0.5)
        return [f.fire("transport_drop") is not None for _ in range(64)]
    assert seq(7) == seq(7)
    assert seq(7) != seq(8)
    assert any(seq(7)) and not all(seq(7))


def test_faults_from_env():
    assert isinstance(from_env({}), NoFaults)
    f = from_env({faults_mod.ENV_VAR: "slow_step@2+0.1",
                  faults_mod.ENV_VAR + "_SEED": "9"})
    assert isinstance(f, Faults) and f.seed == 9
    assert f.specs[0].site == "slow_step"


def test_truncate_file(tmp_path):
    p = tmp_path / "blob"
    p.write_bytes(b"x" * 100)
    assert faults_mod.truncate_file(p, 0.3) == 30
    assert p.stat().st_size == 30


# ------------------------------------------------------------------ #
# submit validation
# ------------------------------------------------------------------ #
@pytest.mark.parametrize("mode", ["plain", "chunked", "paged", "spec"])
def test_submit_validation(mode):
    eng = _engine(mode, cache_len=32)
    ok = Request(uid=0, prompt=np.asarray([1, 2, 3]), max_new_tokens=4)
    eng.submit(ok)
    with pytest.raises(ValueError, match="non-empty 1-D"):
        eng.submit(Request(uid=1, prompt=np.asarray([], np.int32)))
    with pytest.raises(ValueError, match="non-empty 1-D"):
        eng.submit(Request(uid=1, prompt=np.zeros((2, 2), np.int32)))
    with pytest.raises(ValueError, match="integer token"):
        eng.submit(Request(uid=1, prompt=np.asarray([0.5, 1.5])))
    with pytest.raises(ValueError, match="max_new_tokens"):
        eng.submit(Request(uid=1, prompt=np.asarray([1]),
                           max_new_tokens=0))
    with pytest.raises(ValueError, match="deadline_s"):
        eng.submit(Request(uid=1, prompt=np.asarray([1]),
                           deadline_s=-1.0))
    # uid 0 is queued (in flight): resubmission must be rejected
    with pytest.raises(ValueError, match="already in flight"):
        eng.submit(Request(uid=0, prompt=np.asarray([1, 2])))
    # prompt longer than the KV ring: every admission path rejects it
    # up front with the same capacity wording
    long = _RNG.integers(0, _CFG.vocab, 40)
    with pytest.raises(ValueError, match="exceeds the KV capacity"):
        eng.submit(Request(uid=1, prompt=long, max_new_tokens=2))
    # embeddings on a frontend-less stack are rejected before any
    # shape or mode check — there is nothing to consume them
    with pytest.raises(ValueError, match="no frontend"):
        eng.submit(Request(uid=1, prompt=np.asarray([1]),
                           embeddings=np.zeros((2, 3, 4), np.float32)))


# ------------------------------------------------------------------ #
# deadlines
# ------------------------------------------------------------------ #
def test_expired_queued_request_times_out_without_admission():
    eng = _engine("plain", max_batch=1)
    pa, pb = _prompts(2)
    eng.submit(Request(uid=0, prompt=pa, max_new_tokens=6))
    eng.submit(Request(uid=1, prompt=pb, max_new_tokens=6,
                       deadline_s=1e-6))
    time.sleep(0.01)
    resp = eng.run()
    assert resp[0].finish_reason in ("eos", "length")
    assert resp[1].finish_reason == "timeout"
    assert resp[1].finished and resp[1].n_generated == 0
    assert eng.latency_stats()["timeouts"] == 1


def test_midstream_deadline_keeps_partial_output():
    # an injected host stall blows the budget after the first tokens
    f = Faults(seed=0).on("slow_step", step=2, delay_s=0.2)
    eng = _engine("plain", max_batch=1, faults=f)
    eng.submit(Request(uid=0, prompt=_prompts(1)[0], max_new_tokens=64,
                       deadline_s=0.05))
    resp = eng.run()
    r = resp[0]
    assert r.finished and r.finish_reason == "timeout"
    assert r.n_generated < 64
    assert not r.ok
    assert eng.latency_stats()["timeouts"] == 1
    assert not eng.has_work


# ------------------------------------------------------------------ #
# cancellation
# ------------------------------------------------------------------ #
def test_cancel_queued_and_unknown():
    eng = _engine("plain", max_batch=1)
    pa, pb = _prompts(2)
    eng.submit(Request(uid=0, prompt=pa, max_new_tokens=4))
    eng.submit(Request(uid=1, prompt=pb, max_new_tokens=4))
    assert eng.cancel(1)                    # still queued
    assert not eng.cancel(99)               # unknown uid
    resp = eng.run()
    assert resp[0].ok
    assert resp[1].finish_reason == "cancelled"
    assert resp[1].n_generated == 0
    assert not eng.cancel(0)                # already finished
    assert eng.latency_stats()["cancellations"] == 1


def test_cancel_active_slot_frees_it_for_the_queue():
    clean = _engine("plain", max_batch=1)
    pa, pb = _prompts(2)
    clean.submit(Request(uid=1, prompt=pb, max_new_tokens=6))
    want = {u: r.tokens for u, r in clean.run().items()}

    eng = _engine("plain", max_batch=1)
    eng.submit(Request(uid=0, prompt=pa, max_new_tokens=64))
    eng.submit(Request(uid=1, prompt=pb, max_new_tokens=6))
    for _ in range(3):
        eng.tick()
    assert eng.cancel(0)
    resp = eng.run()
    assert resp[0].finish_reason == "cancelled"
    assert 0 < resp[0].n_generated < 64     # partial output kept
    # the freed slot served the queued request, token-identically
    assert resp[1].ok and resp[1].tokens == want[1]


def test_cancel_races_chunked_admission_mid_preemption():
    """A high-priority long prompt displaces a live stream and starts a
    multi-chunk admission; cancelling the admitting request mid-chunk
    must yield exactly one terminal "cancelled" response (idempotent on
    repeat) while the preempted victim resumes token-identical."""
    pa = _RNG.integers(0, _CFG.vocab, 8)
    pb = _RNG.integers(0, _CFG.vocab, 30)       # several 8-token chunks

    def alone(p, max_new):
        eng = _engine("chunked", max_batch=1)
        return _serve(eng, [Request(uid=0, prompt=p,
                                    max_new_tokens=max_new)])[0].tokens

    eng = _engine("chunked", max_batch=1)
    eng.submit(Request(uid=0, prompt=pa, max_new_tokens=24, priority=0))
    for _ in range(2):
        eng.tick(2)                             # A live mid-stream
    eng.submit(Request(uid=1, prompt=pb, max_new_tokens=4, priority=5))
    eng.tick(1)                # B preempts A, B's admission in flight
    assert eng._admit is not None and eng._admit.req.uid == 1
    assert eng.requests[0].preemptions >= 1
    assert eng.cancel(1)
    assert not eng.cancel(1)                    # idempotent second call
    resp = eng.run()
    assert resp[1].finished and resp[1].finish_reason == "cancelled"
    assert resp[1].n_generated == 0
    # the displaced victim resumed and matches an undisturbed run
    assert resp[0].ok and resp[0].tokens == alone(pa, 24)
    assert eng.latency_stats()["cancellations"] == 1
    assert not eng.cancel(0)                    # finished: False, no raise


@pytest.mark.parametrize("mode", ["chunked", "paged"])
def test_cancel_during_chunked_admission(mode):
    eng = _engine(mode, max_batch=1)
    prompt = _RNG.integers(0, _CFG.vocab, 30)   # several 8-token chunks
    eng.submit(Request(uid=0, prompt=prompt, max_new_tokens=4))
    eng.step()                                  # admission in flight
    assert eng._admit is not None
    assert eng.cancel(0)
    resp = eng.responses[0]
    assert resp.finished and resp.finish_reason == "cancelled"
    assert eng._admit is None and not eng.has_work
    if mode == "paged":
        # every page allocated during the aborted admission came back
        assert eng._paged.live_pages == 0
        eng._paged.check_invariants()
    # the engine still serves fresh work afterwards
    eng.submit(Request(uid=1, prompt=_prompts(1)[0], max_new_tokens=3))
    assert eng.run()[1].ok


# ------------------------------------------------------------------ #
# preemptive requeue
# ------------------------------------------------------------------ #
def _serve(eng, reqs):
    for r in reqs:
        eng.submit(r)
    return eng.run()


def test_pool_pressure_preempts_and_resumes_token_identical():
    rng = np.random.default_rng(1)
    prompts = [rng.integers(0, _CFG.vocab, 12),
               rng.integers(0, _CFG.vocab, 13)]

    def run(**kw):
        eng = _engine("chunked", cache_len=32, **kw)
        resp = _serve(eng, [Request(uid=u, prompt=p, max_new_tokens=12)
                            for u, p in enumerate(prompts)])
        return {u: r.tokens for u, r in resp.items()}, eng

    base, _ = run()
    # pool of 5 pages x 8: both streams admit, then outgrow the pool
    # mid-decode -> one must be preempted and later resumed
    out, eng = run(paged=True, page_size=8, num_pages=5)
    assert out == base
    st = eng.latency_stats()
    assert st["preemptions"] >= 1
    assert st["kv_pages_live"] == 0
    assert st["kv_pages_free"] == st["kv_pages_total"]
    eng._paged.check_invariants()
    assert sum(r.preemptions for r in eng.requests.values()) \
        == st["preemptions"]


def test_priority_displaces_running_stream():
    pa, pb = _prompts(2)

    def alone(p, max_new):
        eng = _engine("chunked", max_batch=1)
        return _serve(eng, [Request(uid=0, prompt=p,
                                    max_new_tokens=max_new)])[0].tokens

    eng = _engine("chunked", max_batch=1)
    eng.submit(Request(uid=0, prompt=pa, max_new_tokens=24, priority=0))
    for _ in range(2):
        eng.tick(2)                         # A is live mid-stream
    eng.submit(Request(uid=1, prompt=pb, max_new_tokens=4, priority=5))
    resp = eng.run()
    assert resp[1].ok
    assert resp[0].ok and eng.requests[0].preemptions >= 1
    # the displaced stream resumed token-identical to an undisturbed run
    assert resp[0].tokens == alone(pa, 24)
    assert resp[1].tokens == alone(pb, 4)
    assert eng.latency_stats()["preemptions"] >= 1


def test_preempt_while_prefix_pages_shared():
    """Preempting a stream whose head pages are aliased by the prefix
    cache (CoW sharing) must keep refcounts exact: invariants hold and
    the pool conserves pages through evict + resume."""
    rng = np.random.default_rng(5)
    head = rng.integers(0, _CFG.vocab, 16)
    prompts = [np.concatenate([head, rng.integers(0, _CFG.vocab, n)])
               for n in (4, 6)]

    def run(**kw):
        eng = _engine("prefix", cache_len=48, **kw)
        resp = _serve(eng, [Request(uid=u, prompt=p, max_new_tokens=14)
                            for u, p in enumerate(prompts)])
        return {u: r.tokens for u, r in resp.items()}, eng

    base, _ = run()
    out, eng = run(paged=True, page_size=8, num_pages=6)
    assert out == base
    st = eng.latency_stats()
    assert st["preemptions"] >= 1           # the pool forced a victim
    assert st["prefix_hits"] >= 1           # the head really was shared
    # full conservation: dropping surviving prefix entries drains it all
    while eng.prefix_cache.drop_lru():
        pass
    assert eng._paged.live_pages == 0
    assert eng._paged.free_pages == eng._paged.num_pages
    eng._paged.check_invariants()


def test_finish_reasons_are_canonical():
    assert set(FINISH_REASONS) == {"eos", "length", "cancelled",
                                   "timeout", "error"}
