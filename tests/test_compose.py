"""Composable-services core: combinators, compatibility checking,
adapters — the paper's contribution."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.compat import CompositionError, check_concrete, unify
from repro.core.compose import (adapter, cast_adapter, ensemble, map_batch,
                                parallel, route, seq)
from repro.core.service import (Service, Signature, TensorSpec,
                                service_from_fn, spec_tree_of)


def _linear_service(name, d_in, d_out, key=0):
    k = jax.random.PRNGKey(key)
    params = {"w": jax.random.normal(k, (d_in, d_out)) * 0.1}
    return service_from_fn(
        name, lambda p, x: x @ p["w"],
        jax.ShapeDtypeStruct((4, d_in), jnp.float32), params=params)


def test_seq_composes_and_fuses():
    a = _linear_service("a", 8, 16, 0)
    b = _linear_service("b", 16, 4, 1)
    s = a >> b
    x = jnp.ones((4, 8))
    out = jax.jit(s.fn)(s.params, x)
    expect = (x @ a.params["w"]) @ b.params["w"]
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=1e-5)
    assert s.metadata["stages"] == ["a", "b"]


def test_seq_rejects_incompatible():
    a = _linear_service("a", 8, 16)
    c = _linear_service("c", 32, 4)
    with pytest.raises(CompositionError) as ei:
        _ = a >> c
    assert "16" in str(ei.value) and "32" in str(ei.value)


def test_seq_rejects_dtype_mismatch():
    a = _linear_service("a", 8, 16)
    b = Service(name="int_only", fn=lambda p, x: x,
                signature=Signature(TensorSpec((-1, 16), "int32"),
                                    TensorSpec((-1, 16), "int32")))
    with pytest.raises(CompositionError):
        _ = a >> b
    fixed = a >> cast_adapter(a.signature.outputs, "int32") >> b
    assert fixed is not None


def test_wildcard_batch_dims_match():
    spec1 = TensorSpec((-1, 16), "float32")
    spec2 = TensorSpec((4, 16), "float32")
    assert spec1.matches(spec2) and spec2.matches(spec1)
    assert not TensorSpec((3, 16), "float32").matches(spec2)


def test_parallel_combinator():
    a = _linear_service("a", 8, 4, 0)
    b = _linear_service("b", 6, 2, 1)
    p = parallel({"l": a, "r": b})
    out = p({"l": jnp.ones((4, 8)), "r": jnp.ones((4, 6))})
    assert out["l"].shape == (4, 4) and out["r"].shape == (4, 2)


def test_ensemble_mean_and_stack():
    ms = [_linear_service(f"m{i}", 8, 4, i) for i in range(3)]
    e = ensemble(ms, combine="mean")
    x = jnp.ones((2, 8))
    out = e(x)
    expect = sum(x @ m.params["w"] for m in ms) / 3
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=1e-5)
    st = ensemble(ms, combine="stack")
    assert st(x).shape == (3, 2, 4)
    assert st.signature.outputs.shape[0] == 3


def test_ensemble_rejects_mismatched_members():
    with pytest.raises(CompositionError):
        ensemble([_linear_service("a", 8, 4), _linear_service("b", 8, 5)])


def test_route_switches_on_device():
    small = _linear_service("small", 8, 4, 0)
    big = _linear_service("big", 8, 4, 1)
    sel = Service(name="sel",
                  fn=lambda p, x: (jnp.mean(x) > 0).astype(jnp.int32),
                  signature=Signature(small.signature.inputs,
                                      TensorSpec((), "int32")))
    r = route(sel, [small, big])
    xpos = jnp.ones((4, 8))
    xneg = -jnp.ones((4, 8))
    np.testing.assert_allclose(np.asarray(r(xpos)),
                               np.asarray(xpos @ big.params["w"]),
                               rtol=1e-5)
    np.testing.assert_allclose(np.asarray(r(xneg)),
                               np.asarray(xneg @ small.params["w"]),
                               rtol=1e-5)


def test_map_batch_lifts_signature():
    per = service_from_fn("norm", lambda p, x: x / jnp.linalg.norm(x),
                          jax.ShapeDtypeStruct((8,), jnp.float32))
    lifted = map_batch(per)
    out = lifted(jnp.ones((5, 8)))
    assert out.shape == (5, 8)
    assert lifted.signature.inputs.shape == (-1, 8)


def test_check_concrete_reports_field_path():
    spec = {"tokens": TensorSpec((-1, 16), "int32")}
    with pytest.raises(CompositionError) as ei:
        check_concrete(spec, {"tokens": jnp.zeros((2, 8), jnp.int32)},
                       where="svc")
    assert "tokens" in str(ei.value)


def test_unify_reports_missing_fields():
    errs = unify({"a": TensorSpec((1,), "float32")},
                 {"a": TensorSpec((1,), "float32"),
                  "b": TensorSpec((1,), "float32")}, where="x")
    assert errs and "missing" in errs[0]


def test_seq_associativity():
    a = _linear_service("a", 4, 8, 0)
    b = _linear_service("b", 8, 6, 1)
    c = _linear_service("c", 6, 2, 2)
    x = jnp.ones((3, 4))
    left = (a >> b) >> c
    right = a >> (b >> c)
    np.testing.assert_allclose(np.asarray(left(x)), np.asarray(right(x)),
                               rtol=1e-5)
