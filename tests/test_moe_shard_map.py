"""Explicit shard_map expert parallelism: numerical equivalence with the
pjit MoE path, on one device and on a real 8-device mesh (subprocess)."""
import dataclasses
import subprocess
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.models.moe import init_moe, moe_block
from repro.models.moe_shard_map import moe_block_shard_map

REPO = Path(__file__).resolve().parents[1]


def _cfg(cap=8.0):
    cfg = get_arch("qwen2-moe-a2.7b", variant="reduced")
    return cfg.replace(moe=dataclasses.replace(cfg.moe,
                                               capacity_factor=cap))


def test_shard_map_moe_single_device_equivalence():
    cfg = _cfg()
    p = init_moe(jax.random.PRNGKey(0), cfg)
    x = jnp.asarray(np.random.default_rng(0).normal(
        size=(2, 16, cfg.d_model)), jnp.float32)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    y0, a0 = jax.jit(lambda p, x: moe_block(p, x, cfg))(p, x)
    with mesh:
        y1, a1 = jax.jit(
            lambda p, x: moe_block_shard_map(p, x, cfg, mesh))(p, x)
    np.testing.assert_allclose(np.asarray(y0), np.asarray(y1),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(float(a0), float(a1), rtol=1e-5)


def test_shard_map_moe_multi_device_subprocess():
    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import dataclasses
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import get_arch
from repro.models.moe import init_moe, moe_block
from repro.models.moe_shard_map import moe_block_shard_map

cfg = get_arch("qwen2-moe-a2.7b", variant="reduced")
cfg = cfg.replace(moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
p = init_moe(jax.random.PRNGKey(0), cfg)
x = jnp.asarray(np.random.default_rng(0).normal(size=(4, 16, cfg.d_model)),
                jnp.float32)
mesh = jax.make_mesh((2, 4), ("data", "model"))   # E=4 experts, E_loc=1
y0, a0 = jax.jit(lambda p, x: moe_block(p, x, cfg))(p, x)
with mesh:
    psh = jax.tree.map(lambda a: jax.device_put(a, NamedSharding(mesh, P())), p)
    for kk in ("wi", "wg", "wo"):
        psh[kk] = jax.device_put(p[kk], NamedSharding(mesh, P("model", None, None)))
    xs = jax.device_put(x, NamedSharding(mesh, P("data", None, None)))
    y1, a1 = jax.jit(lambda p, x: moe_block_shard_map(p, x, cfg, mesh))(psh, xs)
np.testing.assert_allclose(np.asarray(y0), np.asarray(y1), rtol=2e-4, atol=2e-4)
np.testing.assert_allclose(float(a0), float(a1), rtol=1e-4)
print("OK multi-device shard_map MoE")
"""
    import os
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src")
    r = subprocess.run([sys.executable, "-c", code], env=env,
                       capture_output=True, text=True, timeout=420)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "OK multi-device" in r.stdout
