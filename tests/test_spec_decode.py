"""Speculative-decoding correctness: greedy spec output must be
token-identical to the non-speculative engine across the acceptance path,
the rejection-resample path, eos inside the draft window, and
max_new_tokens landing mid-window — for fp and quantized self-drafts.
Plus model-level verify/rollback invariants and the accept-rule math."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.models.model import build
from repro.quant.self_draft import make_self_draft, parse_draft_spec
from repro.serving.engine import Engine
from repro.serving.request import Request
from repro.serving.sampler import Sampler

_CFG = get_arch("llama3.2-1b", variant="reduced")
_MODEL = build(_CFG)
_PARAMS = _MODEL.init(jax.random.PRNGKey(0))

_RNG = np.random.default_rng(11)
# prompt lengths exercise the L=1 draft-prefill edge case and several
# buckets; max_new=10 with gamma=4 makes the final window land mid-draft
_PROMPTS = [_RNG.integers(0, _CFG.vocab, L) for L in (1, 3, 9, 17)]


def _run(max_new=10, prompts=_PROMPTS, sampler=None, **kw):
    eng = Engine(_MODEL, _PARAMS, max_batch=2, cache_len=64,
                 sampler=sampler or Sampler(), **kw)
    for uid, p in enumerate(prompts):
        eng.submit(Request(uid=uid, prompt=p, max_new_tokens=max_new))
    resp = eng.run()
    return {u: r.tokens for u, r in resp.items()}, eng


# ------------------------------------------------------------------ #
# greedy token-identity (the speculative-decoding contract)
# ------------------------------------------------------------------ #
@pytest.mark.parametrize("paged", [False, True], ids=["contig", "paged"])
@pytest.mark.parametrize("draft", ["fp@1", "int8@1", "int8"])
@pytest.mark.slow
def test_greedy_identity(draft, paged):
    """The speculative contract holds over both KV layouts: the paged
    target cache (block-table page pool) rolls back through pos/step
    exactly like the contiguous ring."""
    base, _ = _run()
    out, eng = _run(draft=draft, spec_gamma=4, paged=paged)
    assert out == base
    st = eng.latency_stats()
    assert st["spec_gamma"] == 4
    # speculation actually happened: fewer fused steps than tokens
    assert st["decode_steps"] < sum(len(t) - 1 for t in base.values())
    if paged:
        assert st["kv_pages_live"] == 0


@pytest.mark.slow
def test_rejection_resample_path_is_exercised():
    """A truncated (half-depth) draft disagrees with the target on this
    stream, so both the accept and the reject-resample paths run — and
    the output is still exactly the greedy baseline."""
    base, _ = _run(max_new=24)
    out, eng = _run(max_new=24, draft="fp@1", spec_gamma=4)
    assert out == base
    acc = eng.latency_stats()["spec_acceptance_rate"]
    assert 0.0 < acc < 1.0, f"need both paths exercised, got {acc}"


@pytest.mark.slow
def test_eos_inside_draft_window():
    """eos produced mid-window must cut generation exactly there, even
    though the fused step speculates past it."""
    base, _ = _run(max_new=12, prompts=_PROMPTS[:1])
    first = base[0]
    idx = next((i for i, t in enumerate(first)
                if i >= 1 and t not in first[:i]), None)
    if idx is None:
        pytest.skip("greedy trajectory collapsed to a single token")
    eos = int(first[idx])
    outs = {}
    for spec in ({}, {"draft": "int8@1", "spec_gamma": 4}):
        eng = Engine(_MODEL, _PARAMS, max_batch=2, cache_len=64,
                     sampler=Sampler(), **spec)
        eng.submit(Request(uid=0, prompt=_PROMPTS[0], max_new_tokens=12,
                           eos_id=eos))
        r = eng.run()[0]
        assert r.n_generated == idx + 1 and r.finish_reason == "eos"
        outs[bool(spec)] = r.tokens
    assert outs[True] == outs[False]


@pytest.mark.slow
def test_max_new_tokens_lands_mid_window():
    """max_new that is not a multiple of the per-step emit count must be
    honoured exactly (the device overshoots; harvest truncates)."""
    for mn in (2, 3, 6, 7):
        base, _ = _run(max_new=mn, prompts=_PROMPTS[:2])
        out, _ = _run(max_new=mn, prompts=_PROMPTS[:2], draft="int8@1",
                      spec_gamma=4)
        assert out == base
        assert all(len(t) == mn for t in out.values())


@pytest.mark.slow
def test_spec_with_int8_kv_cache():
    """Speculative decoding composes with the quantized KV cache (verify
    writes quantize-on-write like prefill/decode)."""
    base, _ = _run(kv_cache_dtype="int8")
    out, _ = _run(kv_cache_dtype="int8", draft="int8@1", spec_gamma=4)
    assert out == base


@pytest.mark.slow
def test_stochastic_spec_completes():
    """Sampled (non-greedy) speculative decoding: every emitted token is
    an exact target-distribution sample by the accept/resample rule, so
    here we check the serving contract — full-length, finished output."""
    out, eng = _run(sampler=Sampler(temperature=0.9, top_k=16),
                    draft="int8@1", spec_gamma=3)
    assert all(len(t) == 10 for t in out.values())
    assert all(r.finished for r in eng.responses.values())


# ------------------------------------------------------------------ #
# engine gating
# ------------------------------------------------------------------ #
def test_model_draft_requires_no_replay_caches():
    """Recurrent targets support speculation (verify/rollback exist) but
    only through the n-gram drafter: a *model* draft needs both caches
    to rewind without replay, and the error says to use ngram."""
    cfg = get_arch("mamba2-780m", variant="reduced")
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    assert model.supports_speculative and model.rollback_needs_replay
    with pytest.raises(ValueError, match="ngram"):
        Engine(model, params, max_batch=1, cache_len=32,
               draft="fp@1", spec_gamma=2)
    # the ngram drafter builds fine on the same stack
    eng = Engine(model, params, max_batch=1, cache_len=32,
                 draft="ngram", spec_gamma=2)
    assert eng.spec_gamma == 2 and eng.draft_cache is None


def test_gamma_without_draft_raises():
    with pytest.raises(ValueError, match="no draft"):
        Engine(_MODEL, _PARAMS, max_batch=1, cache_len=32, spec_gamma=2)


def test_spec_variant_and_draft_spec_parsing():
    cfg = get_arch("llama3.2-1b", variant="reduced+spec")
    assert cfg.spec_gamma == 4 and cfg.draft == "int8@1"
    assert parse_draft_spec("int4@2") == ("int4", 2)
    assert parse_draft_spec("fp") == ("fp", None)
    with pytest.raises(ValueError):
        parse_draft_spec("int2@1")
    # 'ngram' is an engine-level drafter, not a self-draft spec
    with pytest.raises(ValueError, match="prompt-lookup"):
        parse_draft_spec("ngram")


def test_self_draft_shares_weights():
    dm, dp = make_self_draft(_MODEL, _PARAMS, "fp@1")
    assert dp["embed"]["table"] is _PARAMS["embed"]["table"]
    nb = jax.tree.leaves(dp["blocks"])[0].shape[0]
    assert nb == 1 < jax.tree.leaves(_PARAMS["blocks"])[0].shape[0]


# ------------------------------------------------------------------ #
# model-level verify / rollback invariants
# ------------------------------------------------------------------ #
def test_verify_step_matches_sequential_decode():
    """One masked multi-token verify forward produces the same logits as
    token-by-token decode, and advances each row's step by T."""
    toks = jnp.asarray(_RNG.integers(0, _CFG.vocab, (1, 8)), jnp.int32)
    seq = jnp.asarray(_RNG.integers(0, _CFG.vocab, (1, 4)), jnp.int32)

    cache_a = _MODEL.make_cache(1, 32)
    _, cache_a = jax.jit(_MODEL.prefill)(_PARAMS, {"tokens": toks}, cache_a)
    lo_v, cache_a = jax.jit(_MODEL.verify_step)(_PARAMS, seq, cache_a)

    cache_b = _MODEL.make_cache(1, 32)
    _, cache_b = jax.jit(_MODEL.prefill)(_PARAMS, {"tokens": toks}, cache_b)
    step = jax.jit(_MODEL.decode_step)
    for i in range(4):
        lo_i, cache_b = step(_PARAMS, seq[:, i:i + 1], cache_b)
        np.testing.assert_allclose(np.asarray(lo_v[:, i]),
                                   np.asarray(lo_i[:, 0]),
                                   rtol=2e-5, atol=2e-5)
    assert int(_MODEL.cache_steps(cache_a)[0]) == 12


def test_rollback_then_decode_matches_clean_cache():
    """After rolling the per-row step back past speculated writes, decode
    behaves exactly as if the speculated tokens were never written (stale
    entries stay causally invisible and are overwritten in place)."""
    toks = jnp.asarray(_RNG.integers(0, _CFG.vocab, (1, 8)), jnp.int32)
    junk = jnp.asarray(_RNG.integers(0, _CFG.vocab, (1, 5)), jnp.int32)
    nxt = jnp.asarray([[3]], jnp.int32)

    cache_a = _MODEL.make_cache(1, 32)
    _, cache_a = jax.jit(_MODEL.prefill)(_PARAMS, {"tokens": toks}, cache_a)
    _, cache_spec = jax.jit(_MODEL.verify_step)(_PARAMS, junk, cache_a)
    cache_rb = _MODEL.rollback(cache_spec, jnp.asarray([8], jnp.int32))
    lo_rb, _ = jax.jit(_MODEL.decode_step)(_PARAMS, nxt, cache_rb)

    cache_c = _MODEL.make_cache(1, 32)
    _, cache_c = jax.jit(_MODEL.prefill)(_PARAMS, {"tokens": toks}, cache_c)
    lo_clean, _ = jax.jit(_MODEL.decode_step)(_PARAMS, nxt, cache_c)
    np.testing.assert_allclose(np.asarray(lo_rb), np.asarray(lo_clean),
                               rtol=2e-5, atol=2e-5)


# ------------------------------------------------------------------ #
# accept/resample rule
# ------------------------------------------------------------------ #
def test_speculative_accept_greedy_rule():
    s = Sampler()
    V = 8
    tgt = np.full((1, 4, V), -10.0, np.float32)
    for i, t in enumerate((2, 5, 1, 6)):       # target argmax per position
        tgt[0, i, t] = 10.0
    draft = jnp.asarray([[2, 5, 3]])           # diverges at position 2
    block, n_acc = s.speculative(jax.random.PRNGKey(0), draft,
                                 jnp.zeros((1, 3, V)), jnp.asarray(tgt))
    assert int(n_acc[0]) == 2
    assert list(np.asarray(block[0])) == [2, 5, 1, 6]


def test_speculative_accept_identical_dists_accepts_all():
    """Stochastic rule: draft distribution == target distribution =>
    p/q = 1 and every proposal is accepted, bonus token appended."""
    s = Sampler(temperature=1.0)
    logits = jnp.asarray(
        np.random.default_rng(0).normal(0, 1, (2, 4, 16)), jnp.float32)
    draft_logits = logits[:, :3]
    draft = jnp.argmax(draft_logits, axis=-1).astype(jnp.int32)
    _, n_acc = s.speculative(jax.random.PRNGKey(1), draft, draft_logits,
                             logits)
    assert np.all(np.asarray(n_acc) == 3)
