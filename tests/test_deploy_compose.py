"""Compose combinators under deployment (the paper's step-3 property):
``route``/``ensemble`` services deployed through endpoints produce the
same outputs as the undeployed service and record per-stage telemetry;
quantized edge endpoints change precision and bytes, not structure."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.compose import ensemble, route, seq
from repro.core.deploy import (DeploymentPlan, Endpoint, deploy)
from repro.core.netmodel import NetworkModel, tree_nbytes
from repro.core.service import Service, Signature, TensorSpec, \
    service_from_fn


def _linear_service(name, d_in, d_out, key=0):
    k = jax.random.PRNGKey(key)
    params = {"w": jax.random.normal(k, (d_in, d_out)) * 0.1}
    return service_from_fn(
        name, lambda p, x: x @ p["w"],
        jax.ShapeDtypeStruct((4, d_in), jnp.float32), params=params)


def _quiet_net():
    return NetworkModel(jitter_frac=0.0, seed=0)


# ------------------------------------------------------------------ #
# ensemble / route under deployment
# ------------------------------------------------------------------ #
@pytest.mark.parametrize("plan_kind", ["local", "remote"])
def test_deployed_ensemble_matches_undeployed(plan_kind):
    members = [_linear_service(f"m{i}", 8, 4, i) for i in range(3)]
    ens = ensemble(members, combine="mean")
    x = jnp.asarray(np.random.default_rng(0).normal(0, 1, (4, 8)),
                    jnp.float32)
    expect = ens(x)

    plan = DeploymentPlan.all_local(ens) if plan_kind == "local" else \
        DeploymentPlan.all_remote(ens, network=_quiet_net())
    out, tel = deploy(ens, plan).call(x)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=1e-6)
    # per-stage telemetry is recorded with the endpoint it ran on
    assert len(tel.stages) == 1
    st = tel.stages[0]
    assert st.endpoint == ("local" if plan_kind == "local" else "cloud")
    if plan_kind == "remote":
        assert st.transfer_s > 0 and st.compute_s == 0.0
    else:
        assert st.compute_s > 0 and st.transfer_s == 0.0
    assert st.param_bytes == tree_nbytes(ens.params)


@pytest.mark.parametrize("plan_kind", ["local", "remote"])
def test_deployed_route_matches_undeployed(plan_kind):
    small = _linear_service("small", 8, 4, 0)
    big = _linear_service("big", 8, 4, 1)
    sel = Service(name="sel",
                  fn=lambda p, x: (jnp.mean(x) > 0).astype(jnp.int32),
                  signature=Signature(small.signature.inputs,
                                      TensorSpec((), "int32")))
    r = route(sel, [small, big])
    plan = DeploymentPlan.all_local(r) if plan_kind == "local" else \
        DeploymentPlan.all_remote(r, network=_quiet_net())
    dep = deploy(r, plan)
    for sign in (+1.0, -1.0):                  # exercise both branches
        x = sign * jnp.ones((4, 8))
        out, tel = dep.call(x)
        np.testing.assert_allclose(np.asarray(out), np.asarray(r(x)),
                                   rtol=1e-6)
        assert len(tel.stages) == 1 and tel.total_s > 0


def test_deployed_seq_split_per_stage_telemetry():
    a = _linear_service("a", 8, 16, 0)
    b = _linear_service("b", 16, 4, 1)
    pipe = a >> b
    plan = DeploymentPlan.split(pipe, split_at=1, network=_quiet_net())
    x = jnp.ones((4, 8))
    out, tel = deploy(pipe, plan, stages=[a, b]).call(x)
    np.testing.assert_allclose(np.asarray(out), np.asarray(pipe(x)),
                               rtol=1e-6)
    assert [(s.stage, s.endpoint) for s in tel.stages] == \
        [("a", "local"), ("b", "cloud")]
    assert tel.transfer_total_s > 0


# ------------------------------------------------------------------ #
# quantized edge endpoints (precision changes, structure doesn't)
# ------------------------------------------------------------------ #
def test_edge_split_quantizes_edge_stage_only():
    a = _linear_service("a", 64, 64, 0)
    b = _linear_service("b", 64, 8, 1)
    pipe = a >> b
    plan = DeploymentPlan.edge_split(pipe, split_at=1, quantize="int4",
                                     network=_quiet_net())
    dep = deploy(pipe, plan, stages=[a, b])
    x = jnp.asarray(np.random.default_rng(1).normal(0, 1, (4, 64)),
                    jnp.float32)
    out, tel = dep.call(x)
    # structure unchanged: same stages, same output shape, close output
    assert [(s.stage, s.endpoint, s.precision) for s in tel.stages] == \
        [("a", "edge", "int4"), ("b", "cloud", "fp")]
    expect = np.asarray(pipe(x))
    got = np.asarray(out)
    assert got.shape == expect.shape
    rel = np.max(np.abs(got - expect)) / (np.max(np.abs(expect)) + 1e-9)
    assert rel < 0.25, f"int4 edge stage drifted {rel:.3f}"
    # the edge stage's stored params really shrank (int4-packed + scales)
    assert tel.stages[0].param_bytes < tree_nbytes(a.params) / 3
    assert tel.stages[1].param_bytes == tree_nbytes(b.params)


def test_edge_split_on_non_seq_combinator_quantizes():
    """A non-seq combinator deploys as ONE stage under its own name; the
    edge_split plan must still route (and quantize) it, not fall through
    to an implicit fp endpoint."""
    members = [_linear_service(f"m{i}", 64, 16, i) for i in range(2)]
    ens = ensemble(members, combine="mean")
    plan = DeploymentPlan.edge_split(ens, split_at=1, quantize="int4",
                                     network=_quiet_net())
    out, tel = deploy(ens, plan).call(jnp.ones((4, 64)))
    assert tel.stages[0].endpoint == "edge"
    assert tel.stages[0].precision == "int4"
    assert tel.stages[0].param_bytes < tree_nbytes(ens.params) / 3


def test_assignment_to_missing_endpoint_raises():
    a = _linear_service("a", 8, 4, 0)
    plan = DeploymentPlan(
        endpoints={"cloud": Endpoint("cloud", kind="remote",
                                     network=_quiet_net()),
                   "edge": Endpoint("edge")},
        assignments={"a": "cloudd"})              # typo'd endpoint
    with pytest.raises(KeyError):
        deploy(a, plan)


def test_quantized_endpoint_ensemble_runs():
    members = [_linear_service(f"m{i}", 64, 16, i) for i in range(2)]
    ens = ensemble(members, combine="mean")
    plan = DeploymentPlan(
        endpoints={"edge": Endpoint("edge", quantize="int8")},
        assignments={ens.name: "edge"})
    out, tel = deploy(ens, plan).call(jnp.ones((4, 64)))
    rel = np.max(np.abs(np.asarray(out) - np.asarray(ens(jnp.ones((4, 64))))))
    assert rel < 0.05
    assert tel.stages[0].precision == "int8"
