import os
import sys
from pathlib import Path

# src layout import without install; repo root for the benchmarks package
# (tests share helpers with the CI bench smokes, e.g. bench_quant)
sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))
sys.path.insert(1, str(Path(__file__).resolve().parents[1]))

# NOTE: do NOT set xla_force_host_platform_device_count here — smoke tests
# and benches must see 1 device. Multi-device dry-run tests spawn their own
# subprocess with the flag set.

import jax  # noqa: E402

jax.config.update("jax_enable_x64", False)
