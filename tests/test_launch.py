"""Launch-layer units: config resolution, depth calibration helpers,
input specs, mesh constants — all single-device testable."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, SHAPES, get_arch
from repro.launch.steps import (ShapeSkip, apply_opts, depth_counts,
                                resolve_config, with_depth)
from repro.models.model import build


def test_resolve_long500k_dense_uses_swa():
    cfg = resolve_config("internlm2-20b", "long_500k")
    assert cfg.sliding_window == 4096 and cfg.name.endswith("-swa")
    # ssm/hybrid archs stay native
    assert resolve_config("mamba2-780m", "long_500k").sliding_window == 0
    assert resolve_config("jamba-1.5-large-398b",
                          "long_500k").sliding_window == 0


def test_resolve_train_enables_remat():
    assert resolve_config("llama3.2-1b", "train_4k").remat
    assert not resolve_config("llama3.2-1b", "decode_32k").remat


def test_depth_counts_and_with_depth_roundtrip():
    for arch in ARCHS:
        cfg = ARCHS[arch]
        counts = depth_counts(cfg)
        shallow = with_depth(cfg, {k: 1 for k in counts})
        assert all(v == 1 for v in depth_counts(shallow).values())
        restored = with_depth(shallow, counts)
        assert restored.n_layers == cfg.n_layers
        if cfg.family == "encdec":
            assert restored.encoder.n_layers == cfg.encoder.n_layers


def test_with_depth_preserves_block_structure():
    cfg = ARCHS["jamba-1.5-large-398b"]
    one = with_depth(cfg, {"blocks": 1})
    assert one.n_layers == cfg.attn_every  # one full super-block


def test_input_specs_decode_cache_lengths():
    for arch, shape_name, expect_len in [
        ("llama3.2-1b", "decode_32k", 32_768),
        ("internlm2-20b", "long_500k", 4096),      # swa window cap
        ("jamba-1.5-large-398b", "long_500k", 524_288),
    ]:
        cfg = resolve_config(arch, shape_name)
        model = build(cfg)
        specs = model.input_specs(SHAPES[shape_name])
        ks = [l for p, l in
              jax.tree_util.tree_flatten_with_path(specs["cache"])[0]
              if str(p[-1].key) == "k" or str(getattr(p[-1], "key", "")) == "k"]
        if ks:
            assert ks[0].shape[2] == expect_len, (arch, ks[0].shape)


def test_decode_specs_are_one_token():
    for arch in ARCHS:
        for shape_name in ("decode_32k", "long_500k"):
            try:
                cfg = resolve_config(arch, shape_name)
            except ShapeSkip:
                continue
            model = build(cfg)
            specs = model.input_specs(SHAPES[shape_name])
            assert specs["token"].shape == (SHAPES[shape_name].global_batch,
                                            1)


def test_hw_constants_match_brief():
    from repro.launch.mesh import HW
    assert HW["peak_flops_bf16"] == 197e12
    assert HW["hbm_bandwidth"] == 819e9
    assert HW["ici_link_bandwidth"] == 50e9


def test_mesh_shapes():
    # make_production_mesh touches device state -> only verify the shape
    # logic via the documented contract (the dry-run exercises the real
    # thing in its own process)
    import inspect
    from repro.launch import mesh as mesh_mod
    src = inspect.getsource(mesh_mod.make_production_mesh)
    assert "(2, 16, 16)" in src and "(16, 16)" in src
    assert '"pod", "data", "model"' in src


def test_ssd_chunk_padding_path():
    """SSD pads non-multiple sequence lengths; outputs must match an
    explicitly padded run."""
    from repro.models.ssm import init_ssm, ssm_block
    cfg = get_arch("mamba2-780m", variant="reduced")
    p = init_ssm(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    u = jnp.asarray(rng.normal(size=(2, 23, cfg.d_model)), jnp.float32)
    y, _ = ssm_block(p, u, cfg)
    assert y.shape == u.shape and bool(jnp.all(jnp.isfinite(y)))
    # prefix consistency: running the first 17 tokens alone gives the
    # same outputs (causality across the pad boundary)
    y2, _ = ssm_block(p, u[:, :17], cfg)
    np.testing.assert_allclose(np.asarray(y[:, :17]), np.asarray(y2),
                               rtol=2e-4, atol=2e-4)
