"""Property-based tests (hypothesis) on the system's invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="hypothesis not installed; "
                    "pip install -r requirements-dev.txt")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.compat import unify
from repro.core.service import TensorSpec
from repro.data.pipeline import MarkovLM, pack_documents
from repro.training.checkpoints import (load_pytree, save_pytree,
                                        tree_hash)

# ------------------------------------------------------------------ #
# TensorSpec unification algebra
# ------------------------------------------------------------------ #
dims = st.one_of(st.just(-1), st.integers(1, 8))
shapes = st.lists(dims, min_size=0, max_size=4).map(tuple)
dtypes = st.sampled_from(["float32", "int32", "bfloat16"])
specs = st.builds(TensorSpec, shapes, dtypes)


@given(specs)
def test_spec_matches_reflexive(s):
    assert s.matches(s)


@given(specs, specs)
def test_spec_matches_symmetric(a, b):
    assert a.matches(b) == b.matches(a)


@given(shapes, dtypes)
def test_wildcard_absorbs_any_concrete(shape, dtype):
    wild = TensorSpec(tuple(-1 for _ in shape), dtype)
    conc = TensorSpec(tuple(abs(d) for d in shape), dtype)
    assert wild.matches(conc)


@given(specs, specs)
def test_unify_messages_iff_mismatch(a, b):
    errs = unify(a, b, where="t")
    assert (len(errs) == 0) == a.matches(b)


# ------------------------------------------------------------------ #
# checkpoint roundtrip on random pytrees
# ------------------------------------------------------------------ #
leaf_shapes = st.lists(st.integers(1, 5), min_size=0, max_size=3).map(tuple)


@st.composite
def pytrees(draw, depth=2):
    if depth == 0 or draw(st.booleans()):
        shape = draw(leaf_shapes)
        seed = draw(st.integers(0, 2**16))
        return np.random.default_rng(seed).normal(size=shape).astype(
            np.float32)
    n = draw(st.integers(1, 3))
    return {f"k{i}": draw(pytrees(depth=depth - 1)) for i in range(n)}


@settings(max_examples=25, deadline=None)
@given(tree=pytrees())
def test_checkpoint_roundtrip_hash(tree):
    import tempfile
    with tempfile.TemporaryDirectory() as d:
        h = save_pytree(f"{d}/ckpt", tree)
        back = load_pytree(f"{d}/ckpt")
        assert tree_hash(back) == h
        for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
            np.testing.assert_array_equal(a, b)


@given(tree=pytrees())
@settings(max_examples=25, deadline=None)
def test_tree_hash_detects_any_leaf_change(tree):
    leaves = jax.tree.leaves(tree)
    if not leaves or all(l.size == 0 for l in leaves):
        return
    h0 = tree_hash(tree)
    mutated = jax.tree.map(lambda x: x, tree)  # copy structure
    flat, treedef = jax.tree.flatten(mutated)
    idx = next(i for i, l in enumerate(flat) if l.size)
    flat[idx] = flat[idx] + 1.0
    assert tree_hash(jax.tree.unflatten(treedef, flat)) != h0


# ------------------------------------------------------------------ #
# data pipeline invariants
# ------------------------------------------------------------------ #
@given(st.integers(16, 256), st.integers(2, 16), st.integers(1, 64))
@settings(max_examples=20, deadline=None)
def test_markov_lm_tokens_in_vocab(vocab, branching, length):
    lm = MarkovLM(vocab, branching=branching, seed=1)
    toks = lm.sample(np.random.default_rng(0), length)
    assert toks.min() >= 0 and toks.max() < vocab
    assert 0.0 < lm.entropy_bound() <= np.log(branching) + 1e-9


@given(st.lists(st.integers(1, 50), min_size=1, max_size=10),
       st.integers(2, 32))
@settings(max_examples=20, deadline=None)
def test_pack_documents_shape_and_content(doc_lens, seq_len):
    docs = [np.arange(n) for n in doc_lens]
    packed = pack_documents(docs, seq_len)
    total = sum(doc_lens)
    assert packed.shape == (total // seq_len, seq_len)
    flat = np.concatenate(docs)[: packed.size]
    np.testing.assert_array_equal(packed.reshape(-1), flat)


# ------------------------------------------------------------------ #
# attention invariants
# ------------------------------------------------------------------ #
@given(st.integers(1, 3), st.integers(1, 4), st.integers(2, 24),
       st.sampled_from([8, 16]))
@settings(max_examples=15, deadline=None)
def test_attention_rows_are_convex_combinations(B, H, L, hd):
    """Causal attention output at pos t lies in the convex hull of
    v[:t+1] -> max |out| <= max |v|."""
    from repro.models.layers import gqa_attention
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(B, L, H, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, L, H, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, L, H, hd)), jnp.float32)
    out = gqa_attention(q, k, v, causal=True)
    assert float(jnp.max(jnp.abs(out))) <= float(jnp.max(jnp.abs(v))) + 1e-4


@given(st.integers(1, 6))
@settings(max_examples=10, deadline=None)
def test_causal_first_position_copies_v0(L):
    from repro.models.layers import gqa_attention
    rng = np.random.default_rng(1)
    B, H, hd = 1, 2, 8
    q = jnp.asarray(rng.normal(size=(B, L, H, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, L, H, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, L, H, hd)), jnp.float32)
    out = gqa_attention(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out[:, 0]), np.asarray(v[:, 0]),
                               rtol=1e-5, atol=1e-5)


# ------------------------------------------------------------------ #
# MoE invariants
# ------------------------------------------------------------------ #
@given(st.integers(2, 4), st.integers(4, 16), st.integers(1, 2))
@settings(max_examples=10, deadline=None)
def test_moe_aux_loss_bounded_and_output_finite(E, T, k):
    from repro.configs import get_arch
    from repro.models.moe import init_moe, moe_block
    import dataclasses
    cfg = get_arch("qwen2-moe-a2.7b", variant="reduced")
    cfg = cfg.replace(moe=dataclasses.replace(cfg.moe, n_experts=E, top_k=k))
    p = init_moe(jax.random.PRNGKey(0), cfg)
    x = jnp.asarray(np.random.default_rng(0).normal(
        size=(1, T, cfg.d_model)), jnp.float32)
    y, aux = moe_block(p, x, cfg)
    assert y.shape == x.shape
    assert bool(jnp.all(jnp.isfinite(y)))
    # switch aux loss is >= weight (perfect balance) within fp tolerance
    assert float(aux) >= cfg.moe.aux_loss_weight * 0.99
