"""Quantization subsystem: QTensor format round-trips, param-tree walks,
per-family quantized forward passes, the int8-weight + int8-KV greedy
decode match (the edge-deployment accuracy contract), engine integration,
and checkpoint save/load."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.models.model import build
from repro.quant import (dequantize_params, dequantize_tensor, is_qtensor,
                         load_quantized, pack_int4, quantize_for_cfg,
                         quantize_params, quantize_tensor, quantized_stats,
                         save_quantized, unpack_int4)

# shared with the CI quant smoke so the accuracy contract asserted here
# and the one asserted in CI are literally the same helper and prompt
# (margin-checked: the fp greedy trajectory's smallest top-1/top-2 logit
# gap on the reduced llama config is ~0.4, ~20x the int8 error)
from benchmarks.bench_quant import PROMPT_LEN, PROMPT_SEED, _greedy

rng = np.random.default_rng(0)


def _w(shape, scale=0.05):
    return jnp.asarray(rng.normal(0, scale, shape), jnp.float32)


# ------------------------------------------------------------------ #
# QTensor format
# ------------------------------------------------------------------ #
def test_pack_unpack_int4_roundtrip():
    q = jnp.asarray(rng.integers(-8, 8, (2, 64, 16)), jnp.int32)
    packed = pack_int4(q)
    assert packed.dtype == jnp.int8 and packed.shape == (2, 32, 16)
    np.testing.assert_array_equal(np.asarray(unpack_int4(packed)),
                                  np.asarray(q))


@pytest.mark.parametrize("shape", [(64, 48), (3, 64, 48), (128, 256)])
def test_int8_quantize_error_bound(shape):
    w = _w(shape)
    qt = quantize_tensor(w, bits=8)
    assert qt["q"].dtype == jnp.int8
    assert qt["scale"].shape == shape[:-2] + (shape[-1],)
    deq = dequantize_tensor(qt)
    # round-to-nearest: elementwise error <= scale/2 per output channel
    bound = 0.5 * np.asarray(qt["scale"])[..., None, :] + 1e-7
    assert np.all(np.abs(np.asarray(w) - np.asarray(deq)) <= bound)


@pytest.mark.parametrize("gs", [16, 32, 64])
def test_int4_quantize_error_bound(gs):
    w = _w((64, 48))
    qt = quantize_tensor(w, bits=4, group_size=gs)
    assert qt["q4"].shape == (32, 48)
    assert qt["scale"].shape == (64 // gs, 48)
    deq = dequantize_tensor(qt)
    scale = np.asarray(qt["scale"])          # (ng, N)
    bound = 0.5 * np.repeat(scale, gs, axis=0) + 1e-7
    assert np.all(np.abs(np.asarray(w) - np.asarray(deq)) <= bound)


def test_int4_group_size_falls_back_to_divisor():
    qt = quantize_tensor(_w((48, 16)), bits=4, group_size=32)
    # 32 does not divide 48 -> largest divisor <= 32 is 24
    assert qt["scale"].shape == (2, 16)


# ------------------------------------------------------------------ #
# param-tree walk
# ------------------------------------------------------------------ #
def test_quantize_params_structure():
    cfg = get_arch("qwen2-moe-a2.7b", variant="reduced")
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    qp = quantize_params(params, bits=8)
    blocks = qp["blocks"]["sub0"]
    # attention projections quantized, with the stacked block axis intact
    assert is_qtensor(blocks["attn"]["wq"]["w"])
    nb = params["blocks"]["sub0"]["attn"]["wq"]["w"].shape[0]
    assert blocks["attn"]["wq"]["w"]["q"].shape[0] == nb
    # router skipped (a flipped top-k is a routing error, not a rounding
    # error), expert einsum weights and embeddings left dense
    assert not is_qtensor(blocks["moe"]["router"]["w"])
    assert not isinstance(blocks["moe"]["wi"], dict)
    assert not isinstance(qp["embed"]["table"], dict)
    stats = quantized_stats(qp)
    assert stats["n_quantized"] > 0
    assert stats["weight_bytes"] < quantized_stats(params)["weight_bytes"]


def test_dequantize_params_inverts_structure():
    cfg = get_arch("llama3.2-1b", variant="reduced")
    params = build(cfg).init(jax.random.PRNGKey(0))
    qp = quantize_params(params, bits=8)
    dq = dequantize_params(qp)
    assert jax.tree.structure(dq) == jax.tree.structure(params)
    w = params["blocks"]["sub0"]["attn"]["wq"]["w"]
    wd = dq["blocks"]["sub0"]["attn"]["wq"]["w"]
    np.testing.assert_allclose(np.asarray(w), np.asarray(wd), atol=1e-2)


def test_quantize_for_cfg_knob():
    cfg = get_arch("llama3.2-1b", variant="reduced")
    params = build(cfg).init(jax.random.PRNGKey(0))
    assert quantize_for_cfg(params, cfg) is params          # quant=""
    qp = quantize_for_cfg(params, cfg.replace(quant="int4"))
    assert is_qtensor(qp["blocks"]["sub0"]["attn"]["wq"]["w"])
    assert "q4" in qp["blocks"]["sub0"]["attn"]["wq"]["w"]


def test_edge_variant_profile():
    cfg = get_arch("llama3.2-1b", variant="reduced+edge")
    assert cfg.quant == "int4" and cfg.kv_quant
    assert cfg.name.endswith("-edge")
    assert cfg.d_model <= 256                               # reduced applied


# ------------------------------------------------------------------ #
# quantized forwards across families
# ------------------------------------------------------------------ #
@pytest.mark.parametrize("arch", ["llama3.2-1b", "mamba2-780m",
                                  "qwen2-moe-a2.7b", "seamless-m4t-medium"])
@pytest.mark.parametrize("bits", [8, 4])
def test_families_run_quantized(arch, bits):
    """Transformer / SSM / MoE / enc-dec prefill+decode all work with a
    quantized param tree, staying close to the fp logits."""
    cfg = get_arch(arch, variant="reduced")
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    qp = quantize_params(params, bits=bits)
    r = np.random.default_rng(1)
    batch = {"tokens": jnp.asarray(r.integers(0, cfg.vocab, (2, 16)),
                                   jnp.int32)}
    if cfg.frontend is not None:
        fe = cfg.frontend
        batch["embeddings"] = jnp.asarray(
            r.normal(0, 1, (2, fe.n_tokens, fe.d_embed)), jnp.float32)
    lo_fp, cache_fp = jax.jit(model.prefill)(params, batch,
                                             model.make_cache(2, 32))
    lo_q, cache_q = jax.jit(model.prefill)(qp, batch,
                                           model.make_cache(2, 32))
    assert bool(jnp.all(jnp.isfinite(lo_q)))
    tol = 0.3 if bits == 8 else 1.5
    assert float(jnp.max(jnp.abs(lo_fp - lo_q))) < tol
    tok = jnp.argmax(lo_q[:, -1], -1).astype(jnp.int32)[:, None]
    lo_q, _ = jax.jit(model.decode_step)(qp, tok, cache_q)
    assert bool(jnp.all(jnp.isfinite(lo_q)))


# ------------------------------------------------------------------ #
# the edge accuracy contract: int8 weights + int8 KV greedy match
# ------------------------------------------------------------------ #
def test_int8_weights_int8_kv_match_fp_greedy_32():
    cfg = get_arch("llama3.2-1b", variant="reduced")
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompt = np.random.default_rng(PROMPT_SEED).integers(
        0, cfg.vocab, PROMPT_LEN)
    g_fp = _greedy(model, params, prompt, 33)
    model_q = build(cfg.replace(kv_quant=True))
    g_q = _greedy(model_q, quantize_params(params, bits=8), prompt, 33)
    assert g_fp == g_q


def test_int4_stays_within_logit_bound():
    """int4's documented contract is a bounded max-abs logit error (not a
    greedy match): < 0.6 on the tiny config (see docs/quantization.md)."""
    cfg = get_arch("llama3.2-1b", variant="reduced")
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    q4 = quantize_params(params, bits=4, group_size=cfg.quant_group)
    toks = jnp.asarray(np.random.default_rng(PROMPT_SEED).integers(
        0, cfg.vocab, (1, PROMPT_LEN)), jnp.int32)
    lo_fp, _ = jax.jit(model.prefill)(params, {"tokens": toks},
                                      model.make_cache(1, 64))
    lo_q4, _ = jax.jit(model.prefill)(q4, {"tokens": toks},
                                      model.make_cache(1, 64))
    assert float(jnp.max(jnp.abs(lo_fp - lo_q4))) < 0.6


# ------------------------------------------------------------------ #
# serving engine integration
# ------------------------------------------------------------------ #
def test_engine_quantized_params_int8_kv_matches_fp_engine():
    from repro.serving.engine import Engine
    from repro.serving.request import Request
    from repro.serving.sampler import Sampler

    cfg = get_arch("llama3.2-1b", variant="reduced")
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompt = np.random.default_rng(PROMPT_SEED).integers(
        0, cfg.vocab, PROMPT_LEN)

    def serve(p, kv_dtype):
        eng = Engine(model, p, max_batch=2, cache_len=64,
                     sampler=Sampler(), kv_cache_dtype=kv_dtype)
        eng.submit(Request(uid=0, prompt=prompt, max_new_tokens=33))
        return eng.run()[0].tokens

    toks_fp = serve(params, "")
    toks_q = serve(quantize_params(params, bits=8), "int8")
    assert len(toks_q) == 33
    assert toks_fp == toks_q


def test_encdec_kv_quant_cache_is_int8():
    """kv_quant reaches the enc-dec self-attention ring (the growing KV
    cost); cross-attention memory keys stay in model dtype."""
    cfg = get_arch("seamless-m4t-medium", variant="reduced").replace(
        kv_quant=True)
    model = build(cfg)
    cache = model.make_cache(2, 32)
    assert cache["self"]["k"].dtype == jnp.int8
    assert "k_scale" in cache["self"]
    params = model.init(jax.random.PRNGKey(0))
    r = np.random.default_rng(1)
    fe = cfg.frontend
    batch = {"tokens": jnp.asarray(r.integers(0, cfg.vocab, (2, 8)),
                                   jnp.int32),
             "embeddings": jnp.asarray(
                 r.normal(0, 1, (2, fe.n_tokens, fe.d_embed)), jnp.float32)}
    lo, cache = jax.jit(model.prefill)(params, batch, cache)
    tok = jnp.argmax(lo[:, -1], -1).astype(jnp.int32)[:, None]
    lo, _ = jax.jit(model.decode_step)(params, tok, cache)
    assert bool(jnp.all(jnp.isfinite(lo)))


def test_engine_rejects_unknown_kv_cache_dtype():
    from repro.serving.engine import Engine
    cfg = get_arch("llama3.2-1b", variant="reduced")
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    with pytest.raises(ValueError):
        Engine(model, params, kv_cache_dtype="int4")


# ------------------------------------------------------------------ #
# save / load round-trip
# ------------------------------------------------------------------ #
def test_save_load_quantized_roundtrip(tmp_path):
    cfg = get_arch("llama3.2-1b", variant="reduced")
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    qp = quantize_params(params, bits=4, group_size=cfg.quant_group)
    save_quantized(tmp_path / "q", qp, extra={"bits": 4})
    loaded = load_quantized(tmp_path / "q")
    # int8 storage and structure survive the npz round-trip...
    w = loaded["blocks"]["sub0"]["attn"]["wq"]["w"]
    assert is_qtensor(w) and w["q4"].dtype == np.int8
    # ...and the reloaded tree decodes identically
    prompt = np.random.default_rng(PROMPT_SEED).integers(
        0, cfg.vocab, PROMPT_LEN)
    assert _greedy(model, qp, prompt, 9) == _greedy(model, loaded,
                                                    prompt, 9)
