"""Stage profiler (the paper's per-node instrumentation) + metrics log."""
import jax
import jax.numpy as jnp

import repro.core.zoo_builders as zb
from repro.core.profile import format_profile, profile_stages
from repro.training.metrics import MetricsLogger, read_jsonl


def test_profile_stages_accounts_whole_pipeline():
    clf = zb.classifier_service("pixtral-12b", n_classes=10)
    clf = clf.with_params(clf.metadata["init_params"](jax.random.PRNGKey(0)))
    dec = zb.label_decoder(10)
    x = {"embeddings": jnp.ones((2, 16, 64), jnp.float32)}
    profs = profile_stages([clf, dec], x, iters=3)
    assert [p.stage for p in profs] == [clf.name, dec.name]
    assert profs[0].compute_ms > 0 and profs[0].n_params == clf.n_params
    assert profs[1].output_bytes > 0
    txt = format_profile(profs)
    assert "TOTAL" in txt and clf.name in txt


def test_metrics_logger_roundtrip(tmp_path):
    p = tmp_path / "run.jsonl"
    with MetricsLogger(str(p), run_name="t") as log:
        log.log("train", step=1, loss=jnp.asarray(2.5))
        log.log("train", step=2, loss=2.25)
    rows = read_jsonl(p)
    assert len(rows) == 2
    assert rows[0]["loss"] == 2.5 and rows[0]["run"] == "t"
    assert rows[1]["step"] == 2
