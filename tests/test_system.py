"""End-to-end behaviour tests for the paper's system: train a backbone,
wrap it as a zoo service, compose, publish, pull, deploy, serve —
the full Zoo lifecycle on one reduced model."""
import subprocess
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

REPO = Path(__file__).resolve().parents[1]


def test_full_zoo_lifecycle(tmp_path):
    import repro.core.zoo_builders as zb
    from repro.configs import get_arch
    from repro.core.deploy import DeploymentPlan, deploy
    from repro.core.registry import Registry
    from repro.data.pipeline import batches_for
    from repro.models.model import build
    from repro.training.optimizer import AdamW, cosine_schedule
    from repro.training.train_loop import train

    # 1. train (briefly) — loss must move
    cfg = get_arch("llama3.2-1b", variant="reduced")
    model = build(cfg)
    opt = AdamW(lr=cosine_schedule(3e-3, 5, 40))
    state, hist = train(model, opt, batches_for(cfg, 8, 48), steps=40,
                        log_every=39)
    assert hist[-1]["loss"] < hist[0]["loss"]

    # 2. wrap as a service with the trained params, publish
    svc = zb.lm_service("llama3.2-1b", variant="reduced").with_params(
        state["params"])
    reg = Registry(tmp_path)
    reg.publish(svc, builder="model.lm",
                config={"arch": "llama3.2-1b", "variant": "reduced"})

    # 3. pull and verify identical behaviour
    pulled = reg.pull(svc.name)
    x = {"tokens": jnp.ones((2, 16), jnp.int32)}
    np.testing.assert_allclose(np.asarray(svc(x)), np.asarray(pulled(x)),
                               rtol=1e-5, atol=1e-5)

    # 4. deploy the pulled service locally and call it
    d = deploy(pulled, DeploymentPlan.all_local(pulled))
    out, tel = d.call(x)
    assert out.shape == (2, 16, cfg.vocab)
    assert tel.total_s > 0

    # 5. serve generation with the trained weights
    from repro.serving.engine import Engine
    from repro.serving.request import Request
    eng = Engine(model, state["params"], max_batch=2, cache_len=64)
    eng.submit(Request(uid=0, prompt=np.asarray([1, 2, 3]),
                       max_new_tokens=5))
    resp = eng.run()
    assert resp[0].finished and resp[0].n_generated == 5


def test_dryrun_small_mesh_subprocess():
    """Multi-device lower+compile in a subprocess (8 fake devices) —
    validates the sharding rules end-to-end without the 512-device cost."""
    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
from repro.configs import SHAPES
from repro.launch.steps import build_step, activation_rules_for
from repro.distribution.sharding import activation_sharding

mesh = jax.make_mesh((2, 4), ("data", "model"))
for arch, shape in [("llama3.2-1b", "decode_32k"),
                    ("qwen2-moe-a2.7b", "train_4k"),
                    ("mamba2-780m", "prefill_32k")]:
    step_fn, args, cfg, info = build_step(arch, shape, mesh)
    rules = activation_rules_for(mesh, SHAPES[shape])
    with mesh, activation_sharding(mesh, rules):
        compiled = jax.jit(step_fn).lower(*args).compile()
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    assert ca["flops"] > 0
    print("OK", arch, shape)
"""
    import os
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src")
    r = subprocess.run([sys.executable, "-c", code], env=env,
                       capture_output=True, text=True, timeout=540)
    assert r.returncode == 0, r.stderr[-3000:]
    assert r.stdout.count("OK") == 3


def test_shape_skip_table_matches_design():
    """The only skipped (arch x shape) pair is the documented one."""
    from repro.configs import ARCHS, SHAPES
    from repro.launch.steps import ShapeSkip, resolve_config
    skips = []
    for arch in sorted(ARCHS):
        for shape in sorted(SHAPES):
            try:
                resolve_config(arch, shape)
            except ShapeSkip:
                skips.append((arch, shape))
    assert skips == [("seamless-m4t-medium", "long_500k")]
