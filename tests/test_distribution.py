"""Distribution layer: param/cache sharding rules, HLO collective parser,
roofline arithmetic — all testable without multiple devices."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.distribution.hlo_analysis import (collective_bytes,
                                             total_collective_bytes)
from repro.distribution.sharding import (_fit_spec, default_param_rules,
                                         spec_for_path)

AXES = {"data": 16, "model": 16, "pod": 2}


def _spec(path, shape):
    return tuple(spec_for_path(path, shape, default_param_rules(), AXES))


def test_attention_param_rules():
    assert _spec("blocks/sub0/attn/wq/w", (6144, 6144)) == (None, "model")
    assert _spec("blocks/sub0/attn/wo/w", (6144, 6144)) == ("model", None)
    # stacked layer axis is padded with None
    assert _spec("blocks/sub0/attn/wq/w", (48, 6144, 6144)) \
        == (None, None, "model")


def test_non_divisible_dims_are_replicated():
    # vocab 49155 % 16 != 0 -> replicated embedding
    assert _spec("embed/table", (49155, 1536)) == (None, None)
    assert _spec("embed/table", (92544, 6144)) == ("model", None)


def test_moe_expert_parallel_with_fallback():
    # 16 experts divide the model axis: expert parallelism
    assert _spec("blocks/sub0/moe/wi", (16, 8192, 24576)) \
        == ("model", None, None)
    # 60 experts don't: falls back to tensor-parallel experts
    assert _spec("blocks/sub0/moe/wi", (60, 2048, 1408)) \
        == (None, None, "model")
    assert _spec("blocks/sub0/moe/wo", (60, 1408, 2048)) \
        == (None, "model", None)


def test_qtensor_leaves_inherit_weight_rule():
    """Quantized weights (QTensor q/q4/scale under a linear's w) shard
    like the full-precision weight they replace."""
    # int8 q: same shape as w -> same spec
    assert _spec("blocks/sub0/attn/wq/w/q", (48, 6144, 6144)) \
        == (None, None, "model")
    assert _spec("blocks/sub0/attn/wo/w/q", (6144, 6144)) == ("model", None)
    # int4 q4: K halved by packing, N intact -> output-dim sharding holds
    assert _spec("blocks/sub0/mlp/wi/w/q4", (3072, 24576)) \
        == (None, "model")
    # per-output-channel scale: last dim follows w's output dim
    assert _spec("blocks/sub0/attn/wq/w/scale", (6144,)) == ("model",)
    assert _spec("blocks/sub0/mlp/wi/w/scale", (192, 24576)) \
        == (None, "model")
    # wo shards its input dim -> scale (per output channel) replicates
    assert _spec("blocks/sub0/attn/wo/w/scale", (6144,)) == (None,)
    # rms-norm 'scale' is NOT a qtensor leaf: replicated by the default
    assert _spec("blocks/sub0/ln1/scale", (6144,)) == (None,)


def test_optimizer_state_paths_match():
    # opt state mirrors params under m/ and v/ prefixes
    assert _spec("opt/m/blocks/sub0/mlp/wi/w", (2048, 8192)) \
        == (None, "model")


def test_fit_spec_clamps_rank():
    fixed, ok = _fit_spec(("model",), (7,), AXES)
    assert fixed == (None,) and not ok


def test_cache_shardings_seq_vs_batch():
    import os
    # single-device mesh is enough to check the specs we request
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    from repro.distribution.sharding import cache_shardings
    shapes = {"sub0": {
        "k": jax.ShapeDtypeStruct((16, 8, 1024, 8, 128), jnp.bfloat16),
        "pos": jax.ShapeDtypeStruct((16, 8, 1024), jnp.int32),
        "step": jax.ShapeDtypeStruct((16, 8), jnp.int32),
    }}
    sh = cache_shardings(shapes, mesh, ("data",))
    assert sh["sub0"]["k"].spec[1] == "data"
    sh2 = cache_shardings(shapes, mesh, ("data",), seq_axis="model")
    assert sh2["sub0"]["k"].spec[2] == "model"
    assert sh2["sub0"]["k"].spec[3] is None  # heads must not reuse model


# ------------------------------------------------------------------ #
# HLO collective parsing
# ------------------------------------------------------------------ #
HLO = """
  %ag = bf16[4,128]{1,0} all-gather(bf16[1,128]{1,0} %p), dimensions={0}
  %ar = f32[256]{0} all-reduce(f32[256]{0} %x), to_apply=%add
  %rs = (f32[8,8]{1,0}, f32[8,8]{1,0}) reduce-scatter(f32[64,8]{1,0} %y, f32[64,8]{1,0} %z)
  %cp = u8[100]{0} collective-permute(u8[100]{0} %w)
  %a2a = s32[16,16]{1,0} all-to-all(s32[16,16]{1,0} %q)
  %dot = f32[4,4]{1,0} dot(f32[4,8]{1,0} %a, f32[8,4]{1,0} %b)
"""


def test_collective_bytes_parses_each_kind():
    stats = collective_bytes(HLO)
    assert stats["all-gather"] == 4 * 128 * 2
    assert stats["all-reduce"] == 256 * 4
    assert stats["reduce-scatter"] == 2 * 8 * 8 * 4
    assert stats["collective-permute"] == 100
    assert stats["all-to-all"] == 16 * 16 * 4
    assert stats["n_all-gather"] == 1
    # dot is not a collective
    assert total_collective_bytes(stats) == (4 * 128 * 2 + 1024 + 512
                                             + 100 + 1024)


def test_collective_bytes_real_module():
    """Parse an actual compiled module with a psum."""
    mesh = jax.make_mesh((1,), ("x",))
    from jax.sharding import NamedSharding
    x = jax.ShapeDtypeStruct((8, 8), jnp.float32,
                             sharding=NamedSharding(mesh, P("x")))
    compiled = jax.jit(lambda a: a.sum()).lower(x).compile()
    stats = collective_bytes(compiled.as_text())  # 1 device: no collectives
    assert isinstance(stats, dict)


# ------------------------------------------------------------------ #
# roofline arithmetic
# ------------------------------------------------------------------ #
def test_roofline_terms_and_dominance():
    from repro.launch.roofline import analyze
    rec = {
        "status": "ok", "arch": "llama3.2-1b", "shape": "train_4k",
        "mesh": "16x16", "mode": "train", "variant": "", "tag": "",
        "zero": False, "n_devices": 256,
        "flops_per_device": 197e12,      # exactly 1 s of compute
        "bytes_per_device": 819e9 * 2,   # 2 s of memory
        "collective_bytes_per_device": 50e9 * 0.5,
        "memory_analysis": {"temp_size_in_bytes": 10 * 2**30},
        "collectives": {},
    }
    a = analyze(rec)
    assert abs(a["compute_s"] - 1.0) < 1e-9
    assert abs(a["memory_s"] - 2.0) < 1e-9
    assert abs(a["collective_s"] - 0.5) < 1e-9
    assert a["dominant"] == "memory"
    assert a["fits_hbm"]


def test_model_flops_modes():
    from repro.launch.roofline import model_flops
    t = model_flops("llama3.2-1b", "train_4k")
    p = model_flops("llama3.2-1b", "prefill_32k")
    d = model_flops("llama3.2-1b", "decode_32k")
    assert t > p > d > 0
    # train is 3x forward at equal token counts (6ND vs 2ND)
    assert abs(t / (6 * 4096 * 256) - p / (2 * 32768 * 32)) < 1e-6


def test_moe_active_params_lower_than_total():
    from repro.configs import active_param_count, param_count, ARCHS
    for name in ("qwen2-moe-a2.7b", "granite-moe-3b-a800m",
                 "jamba-1.5-large-398b"):
        assert active_param_count(ARCHS[name]) < param_count(ARCHS[name])


def test_param_count_magnitudes():
    """Analytic parameter counts are in the right ballpark of the
    models' nameplate sizes."""
    from repro.configs import ARCHS, param_count
    expect = {"internlm2-20b": 20e9, "starcoder2-15b": 15e9,
              "qwen2.5-14b": 14e9, "llama3.2-1b": 1.3e9,
              "mamba2-780m": 0.78e9, "jamba-1.5-large-398b": 398e9,
              "pixtral-12b": 12e9}
    for name, n in expect.items():
        got = param_count(ARCHS[name])
        assert 0.5 * n < got < 1.8 * n, (name, got, n)
