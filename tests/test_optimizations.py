"""§Perf optimization paths must be numerically faithful to the baseline:
chunked causal attention, grouped MoE routing, microbatch accumulation,
and the opt-knob plumbing."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="hypothesis not installed; "
                    "pip install -r requirements-dev.txt")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.configs import get_arch
from repro.models.layers import chunked_causal_attention, gqa_attention
from repro.models.moe import init_moe, moe_block


@given(st.sampled_from([16, 32]), st.sampled_from([32, 64, 96]),
       st.sampled_from([(4, 2), (2, 2), (8, 1)]), st.sampled_from([0, 40]))
@settings(max_examples=12, deadline=None)
def test_chunked_attention_equals_full(block, L, heads, window):
    if L % block:
        return
    Hq, Hkv = heads
    rng = np.random.default_rng(L + block + Hq + window)
    B, hd = 2, 16
    q = jnp.asarray(rng.normal(size=(B, L, Hq, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, L, Hkv, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, L, Hkv, hd)), jnp.float32)
    a = chunked_causal_attention(q, k, v, block=block, window=window)
    b = gqa_attention(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=1e-5, atol=1e-5)


def test_grouped_routing_equals_global_at_full_capacity():
    cfg = get_arch("granite-moe-3b-a800m", variant="reduced")
    cfg = cfg.replace(moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    p = init_moe(jax.random.PRNGKey(1), cfg)
    x = jnp.asarray(np.random.default_rng(1).normal(
        size=(3, 24, cfg.d_model)), jnp.float32)
    y0, a0 = jax.jit(lambda p, x: moe_block(p, x, cfg))(p, x)
    cfg_g = cfg.replace(moe=dataclasses.replace(cfg.moe,
                                                group_routing=True))
    y1, a1 = jax.jit(lambda p, x: moe_block(p, x, cfg_g))(p, x)
    np.testing.assert_allclose(np.asarray(y0), np.asarray(y1),
                               rtol=2e-4, atol=2e-4)
    assert abs(float(a0) - float(a1)) < 1e-3


def test_grouped_routing_decode_falls_back_to_global():
    """L==1 (decode) uses the flat path even with group_routing on."""
    cfg = get_arch("qwen2-moe-a2.7b", variant="reduced")
    cfg = cfg.replace(moe=dataclasses.replace(cfg.moe, group_routing=True))
    p = init_moe(jax.random.PRNGKey(0), cfg)
    x = jnp.asarray(np.random.default_rng(0).normal(
        size=(4, 1, cfg.d_model)), jnp.float32)
    y, aux = jax.jit(lambda p, x: moe_block(p, x, cfg))(p, x)
    assert y.shape == x.shape and bool(jnp.all(jnp.isfinite(y)))


def test_capacity_drop_preserves_residual_scale():
    """With tight capacity some tokens are dropped (zero MoE output), but
    outputs stay finite and bounded."""
    cfg = get_arch("granite-moe-3b-a800m", variant="reduced")
    cfg = cfg.replace(moe=dataclasses.replace(
        cfg.moe, capacity_factor=0.5, group_routing=True))
    p = init_moe(jax.random.PRNGKey(2), cfg)
    x = jnp.asarray(np.random.default_rng(2).normal(
        size=(2, 64, cfg.d_model)), jnp.float32)
    y, _ = jax.jit(lambda p, x: moe_block(p, x, cfg))(p, x)
    assert bool(jnp.all(jnp.isfinite(y)))


def test_attn_block_config_changes_train_loss_not():
    """attn_block is a pure execution-strategy knob: same loss."""
    from repro.models.model import build
    cfg = get_arch("llama3.2-1b", variant="reduced")
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    toks = jnp.asarray(np.random.default_rng(0).integers(
        0, cfg.vocab, (2, 64)), jnp.int32)
    l0, _ = jax.jit(model.train_loss)(params, {"tokens": toks})
    cfg_b = cfg.replace(attn_block=16)
    model_b = build(cfg_b)
    l1, _ = jax.jit(model_b.train_loss)(params, {"tokens": toks})
    np.testing.assert_allclose(float(l0), float(l1), rtol=1e-5)


def test_apply_opts_plumbing():
    from repro.launch.steps import apply_opts
    cfg = get_arch("jamba-1.5-large-398b")
    out = apply_opts(cfg, {"moe_group": True, "ssd_chunk": 64,
                           "attn_block": 512})
    assert out.moe.group_routing and out.ssm.chunk == 64 \
        and out.attn_block == 512
    dense = get_arch("llama3.2-1b")
    out2 = apply_opts(dense, {"moe_group": True, "ssd_chunk": 64})
    assert out2.moe is None and out2.ssm is None


def test_kv_quant_decode_agrees_with_fp():
    """int8 KV cache: top-1 decode agreement with the fp path."""
    from repro.models.model import build
    cfg = get_arch("llama3.2-1b", variant="reduced")
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    B, L = 2, 24
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (B, L + 4)), jnp.int32)

    def run(quant):
        m = build(cfg.replace(kv_quant=quant))
        cache = m.make_cache(B, L + 4)
        lo, cache = jax.jit(m.prefill)(params, {"tokens": toks[:, :L]},
                                       cache)
        outs = [lo]
        step = jax.jit(m.decode_step)
        for t in range(4):
            lo, cache = step(params, toks[:, L + t][:, None], cache)
            outs.append(lo)
        return jnp.concatenate(outs, 1)

    a, b = run(False), run(True)
    cos = jnp.sum(a * b) / (jnp.linalg.norm(a) * jnp.linalg.norm(b))
    assert float(cos) > 0.999
    assert bool(jnp.all(jnp.argmax(a, -1) == jnp.argmax(b, -1)))


def test_unrolled_layers_match_scanned():
    """unroll_layers (calibration mode) is numerically identical."""
    from repro.models.model import build
    cfg = get_arch("jamba-1.5-large-398b", variant="reduced")
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    toks = jnp.asarray(np.random.default_rng(3).integers(
        0, cfg.vocab, (2, 32)), jnp.int32)
    l0, _ = jax.jit(model.train_loss)(params, {"tokens": toks})
    model_u = build(cfg.replace(unroll_layers=True))
    l1, _ = jax.jit(model_u.train_loss)(params, {"tokens": toks})
    np.testing.assert_allclose(float(l0), float(l1), rtol=2e-5, atol=2e-5)
