"""Sampler masking-rule consistency: ``filtered_logits`` must describe
exactly the distribution ``__call__`` samples from, including when the
k-th logit is tied — the speculative accept/resample rule consumes
``filtered_logits`` as q/p, so any disagreement breaks the "every emitted
token is an exact sample from the target" guarantee."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.serving.sampler import NEG_INF, Sampler


def _kept(filtered):
    return np.flatnonzero(np.asarray(filtered[0]) > NEG_INF / 2)


def test_topk_tie_at_kth_value_keeps_exactly_k():
    """A 5-way tie spanning the k-th value must survive as exactly k
    entries (the old ">= kth" rule kept all 6 tied-or-better logits)."""
    s = Sampler(temperature=1.0, top_k=4)
    logits = jnp.asarray([[3.0, 2.0, 1.0, 1.0, 1.0, 1.0, 1.0, 0.0]])
    kept = _kept(s.filtered_logits(logits))
    assert len(kept) == 4
    # and it is the *same* k entries lax.top_k selects (stable tie-break)
    _, idx = jax.lax.top_k(logits, 4)
    assert set(kept) == set(np.asarray(idx[0]).tolist())


def test_topk_call_samples_only_from_filtered_support():
    """Every token ``__call__`` can emit lies in ``filtered_logits``'s
    support, and the two induced distributions agree (shared masking
    rule) — checked on an all-tied row, the worst case for ties."""
    s = Sampler(temperature=1.0, top_k=3)
    logits = jnp.ones((1, 8))                     # fully tied
    filt = s.filtered_logits(logits)
    kept = _kept(filt)
    assert len(kept) == 3
    seen = {int(s(jax.random.PRNGKey(i), logits)[0]) for i in range(64)}
    assert seen <= set(kept.tolist())
    # q from filtered_logits: uniform over the kept set, zero elsewhere
    q = np.asarray(jax.nn.softmax(filt, axis=-1)[0])
    np.testing.assert_allclose(q[kept], 1.0 / 3, rtol=1e-6)
    assert q[[i for i in range(8) if i not in kept]].max() < 1e-9


def test_topk_without_ties_unchanged():
    s = Sampler(temperature=0.7, top_k=2)
    logits = jnp.asarray([[0.5, 3.0, -1.0, 2.0]])
    kept = _kept(s.filtered_logits(logits))
    assert set(kept.tolist()) == {1, 3}
    filt = np.asarray(s.filtered_logits(logits)[0])
    np.testing.assert_allclose(filt[[1, 3]],
                               np.asarray([3.0, 2.0]) / 0.7, rtol=1e-6)


def test_speculative_greedy_tie_rows_still_prefix_exact():
    """Greedy speculative accept (argmax path) is unaffected by the
    masking rule but must keep working alongside it."""
    s = Sampler()
    draft = jnp.asarray([[5, 7]], jnp.int32)
    tgt = jnp.zeros((1, 3, 10)).at[0, 0, 5].set(1.0).at[0, 1, 7].set(1.0) \
        .at[0, 2, 1].set(1.0)
    block, n_acc = s.speculative(jax.random.PRNGKey(0), draft,
                                 jnp.zeros((1, 2, 10)), tgt)
    assert int(n_acc[0]) == 2
    assert np.asarray(block[0]).tolist() == [5, 7, 1]
