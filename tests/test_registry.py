"""Zoo registry: publish / pull / verify / composed-by-reference."""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.core.zoo_builders as zb
from repro.core.compat import CompositionError
from repro.core.registry import Registry
from repro.core.service import service_from_fn


@pytest.fixture
def clf_dec():
    clf = zb.classifier_service("pixtral-12b", n_classes=10)
    clf = clf.with_params(clf.metadata["init_params"](jax.random.PRNGKey(0)))
    dec = zb.label_decoder(10)
    return clf, dec


def test_publish_pull_roundtrip(tmp_path, clf_dec):
    clf, _ = clf_dec
    reg = Registry(tmp_path)
    reg.publish(clf, builder="model.classifier",
                config={"arch": "pixtral-12b", "n_classes": 10})
    svc = reg.pull(clf.name)
    x = {"embeddings": jnp.ones((2, 16, 64), jnp.float32)}
    np.testing.assert_allclose(np.asarray(clf(x)), np.asarray(svc(x)),
                               rtol=1e-6)


def test_pull_detects_tampered_params(tmp_path, clf_dec):
    clf, _ = clf_dec
    reg = Registry(tmp_path)
    m = reg.publish(clf, builder="model.classifier",
                    config={"arch": "pixtral-12b", "n_classes": 10})
    # tamper with the weights file
    pdir = tmp_path / clf.name / clf.version
    data = dict(np.load(pdir / "params.npz"))
    key0 = sorted(data)[0]
    data[key0] = data[key0] + 1.0
    np.savez(pdir / "params.npz", **data)
    with pytest.raises(IOError):
        reg.pull(clf.name)


def test_pull_detects_signature_drift(tmp_path, clf_dec):
    clf, _ = clf_dec
    reg = Registry(tmp_path)
    reg.publish(clf, builder="model.classifier",
                config={"arch": "pixtral-12b", "n_classes": 10})
    mpath = tmp_path / clf.name / clf.version / "manifest.json"
    m = json.loads(mpath.read_text())
    m["config"]["n_classes"] = 12   # drifted config -> different signature
    mpath.write_text(json.dumps(m))
    with pytest.raises((CompositionError, IOError)):
        reg.pull(clf.name)


def test_composed_by_reference_dedups_weights(tmp_path, clf_dec):
    clf, dec = clf_dec
    reg = Registry(tmp_path)
    reg.publish(clf, builder="model.classifier",
                config={"arch": "pixtral-12b", "n_classes": 10})
    reg.publish(dec, builder="adapter.label_decoder",
                config={"n_classes": 10})
    svc = clf >> dec
    reg.publish_composed(svc, [clf, dec])
    # no params.npz stored for the composition
    assert not (tmp_path / svc.name / svc.version / "params.npz").exists()
    pulled = reg.pull(svc.name)
    x = {"embeddings": jnp.ones((2, 16, 64), jnp.float32)}
    a = svc(x)
    b = pulled(x)
    np.testing.assert_allclose(np.asarray(a["confidence"]),
                               np.asarray(b["confidence"]), rtol=1e-6)


def test_publish_composed_requires_stages_published(tmp_path, clf_dec):
    clf, dec = clf_dec
    reg = Registry(tmp_path)
    svc = clf >> dec
    with pytest.raises(FileNotFoundError):
        reg.publish_composed(svc, [clf, dec])


def test_versioning_and_list(tmp_path):
    reg = Registry(tmp_path)
    s1 = service_from_fn("s", lambda p, x: x * 2,
                         jax.ShapeDtypeStruct((2,), jnp.float32))
    zb.register_builder("test.double")(
        lambda: service_from_fn("s", lambda p, x: x * 2,
                                jax.ShapeDtypeStruct((2,), jnp.float32)))
    reg.publish(s1, builder="test.double", config={})
    import dataclasses
    s2 = dataclasses.replace(s1, version="0.2.0")
    reg.publish(s2, builder="test.double", config={})
    assert reg.versions("s") == ["0.1.0", "0.2.0"]
    assert reg.pull("s").version == "0.2.0"  # latest by default
    assert len(reg.list()) == 2
