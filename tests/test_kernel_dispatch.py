"""Central kernel dispatch: backend defaults + REPRO_FORCE_* overrides."""
import numpy as np
import pytest

from repro.kernels import dispatch


def test_defaults_off_tpu(monkeypatch):
    monkeypatch.delenv("REPRO_FORCE_REF", raising=False)
    monkeypatch.delenv("REPRO_FORCE_PALLAS", raising=False)
    monkeypatch.setattr(dispatch, "backend", lambda: "cpu")
    assert dispatch.resolve() == (False, True)
    assert dispatch.resolve(use_pallas=True) == (True, True)
    assert dispatch.resolve(use_pallas=True, interpret=False) == \
        (True, False)


def test_defaults_on_tpu(monkeypatch):
    monkeypatch.delenv("REPRO_FORCE_REF", raising=False)
    monkeypatch.delenv("REPRO_FORCE_PALLAS", raising=False)
    monkeypatch.setattr(dispatch, "backend", lambda: "tpu")
    assert dispatch.resolve() == (True, False)
    assert dispatch.resolve(use_pallas=False) == (False, False)


def test_force_ref_overrides_everything(monkeypatch):
    monkeypatch.setenv("REPRO_FORCE_REF", "1")
    monkeypatch.setattr(dispatch, "backend", lambda: "tpu")
    assert dispatch.resolve() == (False, False)
    assert dispatch.resolve(use_pallas=True)[0] is False


def test_force_pallas(monkeypatch):
    monkeypatch.delenv("REPRO_FORCE_REF", raising=False)
    monkeypatch.setenv("REPRO_FORCE_PALLAS", "1")
    monkeypatch.setattr(dispatch, "backend", lambda: "cpu")
    assert dispatch.resolve() == (True, True)  # interpret off-TPU


def test_force_pallas_overrides_explicit_false(monkeypatch):
    """Symmetric with REPRO_FORCE_REF: the force env wins over an
    explicit call-site ``use_pallas=False``."""
    monkeypatch.delenv("REPRO_FORCE_REF", raising=False)
    monkeypatch.setenv("REPRO_FORCE_PALLAS", "1")
    monkeypatch.setattr(dispatch, "backend", lambda: "cpu")
    assert dispatch.resolve(use_pallas=False)[0] is True


def test_force_ref_wins_when_both_envs_set(monkeypatch):
    """REF is the ground truth the Pallas path is validated against."""
    monkeypatch.setenv("REPRO_FORCE_REF", "1")
    monkeypatch.setenv("REPRO_FORCE_PALLAS", "1")
    monkeypatch.setattr(dispatch, "backend", lambda: "tpu")
    assert dispatch.resolve()[0] is False
    assert dispatch.resolve(use_pallas=True)[0] is False


def test_sharded_fallback_beats_everything(monkeypatch):
    """With a model axis > 1 active, every op takes the reference path —
    even over an explicit use_pallas=True or REPRO_FORCE_PALLAS."""
    monkeypatch.setenv("REPRO_FORCE_PALLAS", "1")
    monkeypatch.setattr(dispatch, "backend", lambda: "tpu")
    monkeypatch.setattr(dispatch, "sharded_ref_fallback", lambda: True)
    assert dispatch.resolve()[0] is False
    assert dispatch.resolve(use_pallas=True)[0] is False


def test_sharded_fallback_inactive_outside_context():
    """No activation-sharding context -> the fallback never triggers (the
    single-device engine is unaffected)."""
    assert dispatch.sharded_ref_fallback() is False


def test_ssd_routes_through_dispatch(monkeypatch):
    """The ssd-only module override is retired: ``set_use_pallas`` is a
    deprecation-warning no-op, and ``ssd_extend`` obeys the same
    dispatch contract as every other op (env force == explicit
    use_pallas=False, bit-for-bit)."""
    monkeypatch.setenv("REPRO_FORCE_REF", "1")
    from repro.kernels.ssd_scan import ops as ssd_ops
    with pytest.warns(DeprecationWarning, match="dispatch"):
        ssd_ops.set_use_pallas(True)
    rng = np.random.default_rng(1)
    b, t, h, g, p, n = 2, 4, 4, 2, 8, 8
    state = rng.normal(0, 1, (b, h, p, n)).astype(np.float32)
    x = rng.normal(0, 1, (b, t, h, p)).astype(np.float32)
    dt = rng.uniform(0.1, 0.9, (b, t, h)).astype(np.float32)
    A = -rng.uniform(0.1, 1.0, (h,)).astype(np.float32)
    B = rng.normal(0, 1, (b, t, g, n)).astype(np.float32)
    C = rng.normal(0, 1, (b, t, g, n)).astype(np.float32)
    ya, sa = ssd_ops.ssd_extend(state, x, dt, A, B, C)
    yb, sb = ssd_ops.ssd_extend(state, x, dt, A, B, C, use_pallas=False)
    np.testing.assert_array_equal(np.asarray(ya), np.asarray(yb))
    np.testing.assert_array_equal(np.asarray(sa), np.asarray(sb))


def test_ops_route_through_dispatch(monkeypatch):
    """With the env forcing the reference path, an op called with
    defaults must match an explicit use_pallas=False call bit-for-bit."""
    monkeypatch.setenv("REPRO_FORCE_REF", "1")
    from repro.kernels.rmsnorm.ops import fused_rmsnorm
    rng = np.random.default_rng(0)
    x = rng.normal(0, 1, (4, 32)).astype(np.float32)
    r = rng.normal(0, 1, (4, 32)).astype(np.float32)
    s = rng.normal(0, 1, (32,)).astype(np.float32)
    ya, ra = fused_rmsnorm(x, r, s)
    yb, rb = fused_rmsnorm(x, r, s, use_pallas=False)
    np.testing.assert_array_equal(np.asarray(ya), np.asarray(yb))
    np.testing.assert_array_equal(np.asarray(ra), np.asarray(rb))
