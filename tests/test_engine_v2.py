"""Engine v2 invariants: bounded jit program count under the one chunked
admission path, slot eviction/refill correctness against a sequential
no-batching reference, device-resident decode state, and the
immediate-finish (max_new_tokens <= 1) branch."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.models.model import build
from repro.serving.engine import Engine
from repro.serving.request import Request
from repro.serving.sampler import Sampler

_CFG = get_arch("llama3.2-1b", variant="reduced")
_MODEL = build(_CFG)
_PARAMS = _MODEL.init(jax.random.PRNGKey(0))


def _engine(**kw):
    kw.setdefault("max_batch", 2)
    kw.setdefault("cache_len", 64)
    kw.setdefault("sampler", Sampler())
    return Engine(_MODEL, _PARAMS, **kw)


def _sequential_reference(prompt, max_new, cache_len=64):
    """Unbatched prefill + token-by-token decode via the raw model API."""
    cache = _MODEL.make_cache(1, cache_len)
    logits, cache = jax.jit(_MODEL.prefill)(
        _PARAMS, {"tokens": jnp.asarray(prompt, jnp.int32)[None]}, cache)
    toks = [int(jnp.argmax(logits[0, -1]))]
    step = jax.jit(_MODEL.decode_step)
    for _ in range(max_new - 1):
        logits, cache = step(_PARAMS,
                             jnp.asarray([[toks[-1]]], jnp.int32), cache)
        toks.append(int(jnp.argmax(logits[0, -1])))
    return toks


# ------------------------------------------------------------------ #
# one admission path, O(1) compiled programs
# ------------------------------------------------------------------ #
def test_admission_program_count_is_constant():
    """10 distinct prompt lengths all admit through the chunked path:
    no per-length prefill programs exist at all (the mixed step and the
    slot reset are the only admission programs), and nothing falls back
    to a monolithic prefill."""
    eng = _engine(max_batch=2, cache_len=64)
    rng = np.random.default_rng(0)
    for uid, L in enumerate([1, 3, 5, 7, 9, 13, 17, 23, 29, 31]):
        eng.submit(Request(uid=uid, prompt=rng.integers(0, _CFG.vocab, L),
                           max_new_tokens=2))
    resp = eng.run()
    assert all(r.finished for r in resp.values())
    st = eng.latency_stats()
    assert st["fallback_admissions"] == 0
    assert st["chunked_admissions"] == 10
    # jit programs: the fused step/mixed pair plus the slot reset —
    # independent of how many distinct prompt lengths were served
    assert len(eng._slot_jits) == 1 and ("reset", 0) in eng._slot_jits


# ------------------------------------------------------------------ #
# eviction / refill correctness
# ------------------------------------------------------------------ #
@pytest.mark.slow
def test_slot_refill_matches_sequential_reference():
    """More requests than slots -> every slot is recycled at least once;
    greedy output must equal the unbatched model-API reference, proving the
    refill fully resets the slot (no stale keys from the evicted request)."""
    rng = np.random.default_rng(7)
    prompts = [rng.integers(0, _CFG.vocab, int(rng.integers(2, 24)))
               for _ in range(6)]
    eng = _engine(max_batch=2, cache_len=48)
    for uid, p in enumerate(prompts):
        eng.submit(Request(uid=uid, prompt=p, max_new_tokens=6))
    resp = eng.run()
    for uid, p in enumerate(prompts):
        assert resp[uid].tokens == _sequential_reference(p, 6, cache_len=48)
        assert resp[uid].finish_reason == "length"


# ------------------------------------------------------------------ #
# device-resident decode state
# ------------------------------------------------------------------ #
def test_decode_state_stays_on_device_between_steps():
    """Steady-state decode never moves sampled tokens to the host: the
    engine's token/remaining/active state and the per-step trace are all
    device arrays."""
    eng = _engine(max_batch=2, cache_len=64, sync_every=4)
    rng = np.random.default_rng(1)
    for uid in range(2):
        eng.submit(Request(uid=uid, prompt=rng.integers(0, _CFG.vocab, 6),
                           max_new_tokens=12))
    eng._fill_free_slots()
    for _ in range(5):
        eng.step()
    for name in ("tokens", "remaining", "active", "eos"):
        assert isinstance(getattr(eng, name), jax.Array), name
    assert len(eng._trace) == 5
    # trace entries are device arrays (plain steps) or tuples of device
    # arrays (mixed/admit steps: block + emit count) — never host ints
    for t in eng._trace:
        parts = t if isinstance(t, tuple) else (t,)
        assert all(isinstance(p, jax.Array) for p in parts)
    # nothing harvested yet: responses hold no tokens until a poll
    assert all(r.n_generated == 0 for r in eng.responses.values())
    resp = eng.run()
    assert all(r.finished and r.n_generated == 12 for r in resp.values())


def test_eos_finishes_between_polls():
    """eos hit mid-burst (device-side) is truncated correctly at harvest."""
    eng = _engine(max_batch=1, cache_len=64, sync_every=8)
    eng.submit(Request(uid=0, prompt=np.asarray([1, 2, 3]),
                       max_new_tokens=10))
    first = eng.run()[0].tokens
    # eos = a token whose first occurrence is mid-sequence -> generation
    # must cut exactly there even though decode bursts overshoot it
    idx = next((i for i, t in enumerate(first)
                if i >= 1 and t not in first[:i]), None)
    if idx is None:
        pytest.skip("greedy trajectory collapsed to a single token")
    eng2 = _engine(max_batch=1, cache_len=64, sync_every=8)
    eng2.submit(Request(uid=0, prompt=np.asarray([1, 2, 3]),
                        max_new_tokens=10, eos_id=int(first[idx])))
    r = eng2.run()[0]
    assert r.n_generated == idx + 1 and r.finish_reason == "eos"


# ------------------------------------------------------------------ #
# immediate finish (max_new_tokens <= 1)
# ------------------------------------------------------------------ #
def test_max_new_tokens_one_finishes_at_admission():
    """The slot is never armed: the admission's final chunk samples one
    token, the device marks the row done, and no plain decode step ever
    runs for it."""
    eng = _engine(max_batch=2, cache_len=64)
    rng = np.random.default_rng(2)
    for uid in range(5):
        eng.submit(Request(uid=uid, prompt=rng.integers(0, _CFG.vocab, 5),
                           max_new_tokens=1))
    resp = eng.run()
    assert all(r.finished and r.n_generated == 1 for r in resp.values())
    assert eng.active_slots == 0
    st = eng.latency_stats()
    assert st["fallback_admissions"] == 0
    assert st["chunked_admissions"] == 5
    # every admission went through the fused mixed step
    assert eng.step_kinds.count("mixed") >= 5


def test_latency_stats_empty_streams_omit_keys():
    """A stream that produced no samples contributes no keys — a fresh
    engine must not fabricate 0.0 percentiles (they used to flow into
    benchmark artifacts as fake zero latencies)."""
    eng = _engine()
    st = eng.latency_stats()
    assert not [k for k in st if k.startswith(("decode_ms", "ttft_ms",
                                               "itl_ms"))]
    assert st["n_finished"] == 0
    # max_new=1: finishes at the admission chunk — TTFT and the step
    # series exist (admission is a fused step), ITL never ran
    eng.submit(Request(uid=0, prompt=np.asarray([1, 2, 3]),
                       max_new_tokens=1))
    eng.run()
    st = eng.latency_stats()
    assert "ttft_ms_p50" in st and st["ttft_ms_p50"] > 0.0
    assert "itl_ms_p50" not in st
    assert st["n_finished"] == 1


def test_eos_on_first_token_frees_slot():
    eng = _engine(max_batch=2, cache_len=64)
    eng.submit(Request(uid=0, prompt=np.asarray([1, 2, 3]),
                       max_new_tokens=10))
    first = eng.run()[0].tokens[0]
    eng2 = _engine(max_batch=2, cache_len=64)
    eng2.submit(Request(uid=0, prompt=np.asarray([1, 2, 3]),
                        max_new_tokens=10, eos_id=int(first)))
    eng2.submit(Request(uid=1, prompt=np.asarray([4, 5]),
                        max_new_tokens=3))
    resp = eng2.run()
    assert resp[0].n_generated == 1 and resp[0].finish_reason == "eos"
    assert resp[1].finished and resp[1].n_generated == 3


# ------------------------------------------------------------------ #
# masked prefill equals exact prefill (model level)
# ------------------------------------------------------------------ #
@pytest.mark.parametrize("arch", ["llama3.2-1b", "mamba2-780m"])
def test_masked_prefill_matches_exact(arch):
    """Right-padded prefill with batch['length'] produces the same logits
    and an equivalent cache state as exact-length prefill — for attention
    (pos masking) and SSM (dt masking + conv-tail gather) stacks alike.
    (The serving engine itself admits through the chunked extend path;
    masked prefill remains the batch/offline API.)"""
    cfg = get_arch(arch, variant="reduced")
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(3)
    L, Lb = 11, 16
    toks = rng.integers(0, cfg.vocab, L)
    padded = np.zeros((1, Lb), np.int32)
    padded[0, :L] = toks

    cache_e = model.make_cache(1, 32)
    lo_e, cache_e = jax.jit(model.prefill)(
        params, {"tokens": jnp.asarray(toks, jnp.int32)[None]}, cache_e)
    cache_m = model.make_cache(1, 32)
    lo_m, cache_m = jax.jit(model.prefill)(
        params, {"tokens": jnp.asarray(padded),
                 "length": jnp.asarray([L], jnp.int32)}, cache_m)
    np.testing.assert_allclose(np.asarray(lo_e), np.asarray(lo_m),
                               rtol=1e-5, atol=1e-5)
    steps = model.cache_steps(cache_m)
    assert steps is None or int(steps[0]) == L
    # decode one token from each cache: identical logits
    step = jax.jit(model.decode_step)
    nxt = jnp.asarray([[int(jnp.argmax(lo_e[0, -1]))]], jnp.int32)
    d_e, _ = step(params, nxt, cache_e)
    d_m, _ = step(params, nxt, cache_m)
    np.testing.assert_allclose(np.asarray(d_e), np.asarray(d_m),
                               rtol=1e-5, atol=1e-5)


def test_decode_kernel_path_matches_default():
    """cfg.use_decode_kernel routes cached decode attention through
    kernels/decode_attention with identical results."""
    model_k = build(_CFG.replace(use_decode_kernel=True))
    rng = np.random.default_rng(4)
    toks = jnp.asarray(rng.integers(0, _CFG.vocab, (1, 12)), jnp.int32)
    for m in (_MODEL, model_k):
        cache = m.make_cache(1, 32)
        _, cache = jax.jit(m.prefill)(_PARAMS, {"tokens": toks}, cache)
        lo, _ = jax.jit(m.decode_step)(
            _PARAMS, jnp.asarray([[7]], jnp.int32), cache)
        if m is _MODEL:
            ref = lo
    np.testing.assert_allclose(np.asarray(ref), np.asarray(lo),
                               rtol=1e-5, atol=1e-5)
