"""Multi-replica fleet serving: health, failover, hedging, drain.

The fleet contract (docs/robustness.md): every submitted request
reaches a terminal ``finish_reason`` even when replicas die mid-run;
requests migrated off a dead replica resume by replay, so greedy output
is token-identical to an undisturbed single-engine run; hedged requests
deliver every token exactly once.
"""
import jax
import numpy as np
import pytest

from repro.configs import get_arch
from repro.models.model import build
from repro.serving import faults as faults_mod
from repro.serving.engine import Engine
from repro.serving.faults import Faults
from repro.serving.fleet import (DEAD, DEGRADED, DRAINED, DRAINING,
                                 FLEET_SITES, HEALTHY, Fleet)
from repro.serving.request import Request
from repro.serving.router import CircuitBreaker, Router
from repro.serving.sampler import Sampler

_CFG = get_arch("llama3.2-1b", variant="reduced")
_MODEL = build(_CFG)
_PARAMS = _MODEL.init(jax.random.PRNGKey(0))
_RNG = np.random.default_rng(11)

_EK = dict(max_batch=2, cache_len=64, sampler=Sampler(),
           prefill_chunk=8, prefix_cache_tokens=256,
           paged=True, page_size=8)


def _fleet(replicas=2, **kw):
    kw.setdefault("engine_kwargs", _EK)
    return Fleet(_MODEL, _PARAMS, replicas=replicas, **kw)


def _workload(n=4, max_new=12, uid0=0, shared_head=True):
    rng = np.random.default_rng(23)
    head = rng.integers(0, _CFG.vocab, 16)
    reqs = []
    for i in range(n):
        body = rng.integers(0, _CFG.vocab, int(rng.integers(4, 12)))
        prompt = (np.concatenate([head, body])
                  if shared_head and i % 2 else body)
        reqs.append(Request(uid=uid0 + i, prompt=prompt,
                            max_new_tokens=max_new))
    return reqs


def _expected(reqs):
    eng = Engine(_MODEL, _PARAMS, **_EK)
    for r in reqs:
        eng.submit(Request(uid=r.uid, prompt=r.prompt,
                           max_new_tokens=r.max_new_tokens,
                           eos_id=r.eos_id))
    return {u: list(r.tokens) for u, r in eng.run().items()}


# ------------------------------------------------------------------ #
# router / breaker units (no engine)
# ------------------------------------------------------------------ #
def test_circuit_breaker_state_machine():
    b = CircuitBreaker(failure_threshold=2, cooldown_ticks=3)
    assert b.allows and b.state == b.CLOSED
    b.record_failure()
    assert b.allows                      # below threshold
    b.record_failure()
    assert not b.allows and b.state == b.OPEN and b.opens == 1
    for _ in range(3):
        b.tick()
    assert b.state == b.HALF_OPEN and b.allows
    b.record_failure()                   # probe failed: reopen
    assert b.state == b.OPEN and b.opens == 2
    for _ in range(3):
        b.tick()
    b.record_success()                   # probe succeeded: close
    assert b.state == b.CLOSED and b.allows


def test_router_affinity_then_least_loaded():
    r = Router(affinity_tokens=4)
    prompt = np.asarray([1, 2, 3, 4, 9, 9])
    cands = [(0, 0, 3), (1, 0, 1), (2, 1, 0)]
    # no affinity yet: least-loaded healthy replica wins (rank first)
    assert r.route(prompt, cands) == 1
    r.note_dispatch(prompt, 0)
    assert r.route(prompt, cands) == 0   # affinity overrides load
    assert r.affinity_hits == 1
    # same head, different tail: still the affinity replica
    assert r.route(np.asarray([1, 2, 3, 4, 7]), cands) == 0
    # excluded (already holds a copy): falls back to least-loaded
    assert r.route(prompt, cands, exclude=[0]) == 1
    r.forget_replica(0)
    assert r.route(prompt, cands) == 1


def test_router_sheds_when_breakers_open():
    r = Router()
    r.breaker(0).force_open()
    r.breaker(1).force_open()
    assert r.route(np.asarray([1]), [(0, 0, 0), (1, 0, 0)]) is None
    assert r.sheds == 1
    for _ in range(r.breaker(0).cooldown_ticks):
        r.tick()
    assert r.route(np.asarray([1]), [(0, 0, 0), (1, 0, 0)]) == 0


def test_fleet_sites_registered_and_nearest_site_hint():
    for s in FLEET_SITES:
        assert s in faults_mod.SITES
    Faults.parse("replica_crash@3/1,replica_hang@2,router_drop")
    with pytest.raises(ValueError, match="did you mean 'nan_logits'"):
        Faults.parse("nan_logit@3")
    with pytest.raises(ValueError, match="did you mean 'replica_crash'"):
        Faults(seed=0).on("replica_crush")


def test_request_identity_equality_in_containers():
    # eq=False: two distinct requests sharing a uid must not raise
    # "ambiguous truth value" from array comparison in deque ops
    from collections import deque
    a = Request(uid=1, prompt=np.asarray([1, 2, 3]))
    b = Request(uid=1, prompt=np.asarray([4, 5]))
    q = deque([a])
    assert b not in q and a in q
    q.remove(a)
    assert not q


# ------------------------------------------------------------------ #
# clean fleet serving
# ------------------------------------------------------------------ #
def test_fleet_matches_single_engine_greedy():
    reqs = _workload(4)
    want = _expected(reqs)
    fl = _fleet(replicas=2)
    for r in reqs:
        fl.submit(r)
    resp = fl.run()
    assert all(r.ok for r in resp.values())
    assert {u: list(r.tokens) for u, r in resp.items()} == want
    st = fl.latency_stats()
    assert st["dispatches"] == 4
    assert st["replica_deaths"] == 0
    # follow-ups with a shared head routed back to their prefix replica
    assert fl.router.affinity_hits >= 1


def test_fleet_submit_validation_and_cancel_edges():
    fl = _fleet(replicas=1)
    with pytest.raises(ValueError, match="non-empty 1-D"):
        fl.submit(Request(uid=0, prompt=np.asarray([], np.int32)))
    with pytest.raises(ValueError, match="max_new_tokens"):
        fl.submit(Request(uid=0, prompt=np.asarray([1]),
                          max_new_tokens=0))
    fl.submit(Request(uid=0, prompt=np.asarray([1, 2]),
                      max_new_tokens=2))
    with pytest.raises(ValueError, match="already in flight"):
        fl.submit(Request(uid=0, prompt=np.asarray([3])))
    assert not fl.cancel(99)             # unknown uid
    assert fl.cancel(0)                  # queued, never dispatched
    assert not fl.cancel(0)              # idempotent second call
    assert fl.responses[0].finish_reason == "cancelled"
    resp = fl.run()
    assert resp[0].finish_reason == "cancelled"


# ------------------------------------------------------------------ #
# failover / health
# ------------------------------------------------------------------ #
def test_crash_failover_no_loss_token_identical():
    reqs = _workload(6, max_new=20)
    want = _expected(reqs)
    fl = _fleet(replicas=3, faults="replica_crash@2/0")
    for r in reqs:
        fl.submit(r)
    resp = fl.run()
    assert all(r.finished for r in resp.values())       # zero losses
    assert all(r.ok for r in resp.values())
    assert {u: list(r.tokens) for u, r in resp.items()} == want
    st = fl.latency_stats()
    assert st["replica_deaths"] == 1 and st["failovers"] == 1
    assert st["requests_migrated"] >= 1
    assert fl.replicas[0].state == DEAD
    assert fl.replicas[0].death_reason == "crash"
    assert st["gauge_replica_0_health"] == 2


@pytest.mark.slow
def test_replica_hang_watchdog_kills_and_migrates():
    reqs = _workload(4, max_new=20)
    want = _expected(reqs)
    fl = _fleet(replicas=2, hang_ticks=3,
                faults="replica_hang@2/0")
    for r in reqs:
        fl.submit(r)
    resp = fl.run()
    assert all(r.ok for r in resp.values())
    assert {u: list(r.tokens) for u, r in resp.items()} == want
    assert fl.replicas[0].state == DEAD
    assert fl.replicas[0].death_reason == "hang"
    assert fl.latency_stats()["requests_migrated"] >= 1


def test_router_drop_is_detected_and_redispatched():
    reqs = _workload(3, max_new=8)
    want = _expected(reqs)
    fl = _fleet(replicas=2, faults="router_drop@1")
    for r in reqs:
        fl.submit(r)
    resp = fl.run()
    assert all(r.ok for r in resp.values())
    assert {u: list(r.tokens) for u, r in resp.items()} == want
    st = fl.latency_stats()
    assert st["router_drops"] == 1 and st["redispatches"] == 1


def test_all_replicas_dead_fails_loudly_not_forever():
    fl = _fleet(replicas=1, faults="replica_crash@1/0", hang_ticks=2)
    for r in _workload(2, max_new=8):
        fl.submit(r)
    resp = fl.run(max_steps=500)
    assert all(r.finished for r in resp.values())
    assert all(r.finish_reason == "error" for r in resp.values())


# ------------------------------------------------------------------ #
# hedging
# ------------------------------------------------------------------ #
def test_hedge_wins_when_primary_hangs():
    reqs = _workload(1, max_new=8)
    want = _expected(reqs)
    fl = _fleet(replicas=2, hedge=True, hedge_delay_s=0.0,
                hang_ticks=4, faults="replica_hang@1/0")
    for r in reqs:
        fl.submit(r)
    resp = fl.run()
    assert resp[0].ok and list(resp[0].tokens) == want[0]
    st = fl.latency_stats()
    assert st["hedges_issued"] == 1
    assert st["hedges_won"] == 1         # the hedge produced first


@pytest.mark.slow
def test_hedge_loser_cancelled_tokens_exactly_once():
    reqs = _workload(2, max_new=10)
    want = _expected(reqs)
    fl = _fleet(replicas=2, hedge=True, hedge_delay_s=0.0)
    for r in reqs:
        fl.submit(r)
    # single-step ticks keep first tokens several ticks away, so the
    # zero-delay hedge window opens before anything binds
    for _ in range(1000):
        if not fl.has_work:
            break
        fl.tick(1)
    resp = fl.responses
    assert all(r.ok for r in resp.values())
    # exactly-once delivery: token streams identical, no duplication
    assert {u: list(r.tokens) for u, r in resp.items()} == want
    st = fl.latency_stats()
    assert st["hedges_issued"] >= 1
    assert st["hedges_won"] + st["hedges_wasted"] == st["hedges_issued"]


# ------------------------------------------------------------------ #
# drain / rejoin
# ------------------------------------------------------------------ #
@pytest.mark.slow
def test_drain_finishes_streams_then_rejoin_serves_again():
    fl = _fleet(replicas=2)
    for r in _workload(4, max_new=10):
        fl.submit(r)
    fl.tick()                            # streams live on both replicas
    fl.drain(0)
    assert fl.replicas[0].state == DRAINING
    resp = fl.run()
    assert all(r.ok for r in resp.values())     # drain is graceful
    assert fl.replicas[0].state == DRAINED
    st = fl.latency_stats()
    assert st["drains"] == 1
    # rejoin: fresh engine, healthy again, serves new work
    fl.rejoin(0)
    assert fl.replicas[0].state == HEALTHY
    fl.submit(Request(uid=100, prompt=np.asarray([3, 1, 4, 1, 5]),
                      max_new_tokens=4))
    out = fl.run()
    assert out[100].ok
    assert fl.latency_stats()["rejoins"] == 1


# ------------------------------------------------------------------ #
# fleet-queue deadline (satellite: never admitted to any replica)
# ------------------------------------------------------------------ #
def test_deadline_expires_in_fleet_queue_never_admitted():
    import time
    fl = _fleet(replicas=1, max_outstanding=1)
    long_req = _workload(1, max_new=24)[0]
    fl.submit(long_req)
    fl.tick()                            # replica is at capacity
    fl.submit(Request(uid=50, prompt=np.asarray([1, 2, 3]),
                      max_new_tokens=4, deadline_s=1e-6))
    time.sleep(0.01)
    resp = fl.run()
    r = resp[50]
    assert r.finished and r.finish_reason == "timeout"
    assert r.n_generated == 0
    # exactly one terminal response, and no replica ever saw the uid
    assert fl.latency_stats()["fleet_timeouts"] == 1
    for rep in fl.replicas:
        assert 50 not in rep.engine.responses
    assert resp[long_req.uid].ok         # the long stream was untouched


# ------------------------------------------------------------------ #
# fleet trace export
# ------------------------------------------------------------------ #
@pytest.mark.slow
def test_fleet_trace_merges_per_replica_lanes(tmp_path):
    from repro.serving.tracing import validate_chrome_trace
    fl = _fleet(replicas=2, trace=True, faults="replica_crash@2/0")
    for r in _workload(3, max_new=8):
        fl.submit(r)
    fl.run()
    out = tmp_path / "fleet_trace.json"
    trace = fl.export_trace(str(out))
    assert out.exists()
    assert validate_chrome_trace(trace) == []
    names = {(e["pid"], e["args"]["name"])
             for e in trace["traceEvents"]
             if e.get("ph") == "M" and e["name"] == "process_name"}
    assert (100, "replica 0") in names
    assert (101, "replica 1") in names
    assert (99, "fleet") in names
    fleet_lane = [e["name"] for e in trace["traceEvents"]
                  if e.get("pid") == 99 and e.get("ph") == "i"]
    assert "replica_dead" in fleet_lane and "failover" in fleet_lane
