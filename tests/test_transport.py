"""Transports + CLI: the paper's pull-from-repo-or-peer workflow."""
import jax
import numpy as np
import pytest

import repro.core.zoo_builders as zb
from repro.core.registry import Registry
from repro.core.transport import (PeerTransport, RepoTransport,
                                  SyncedRegistry)


@pytest.fixture
def remote(tmp_path):
    """A populated remote repository."""
    root = tmp_path / "remote"
    reg = Registry(root)
    clf = zb.classifier_service("pixtral-12b", n_classes=10)
    clf = clf.with_params(clf.metadata["init_params"](jax.random.PRNGKey(0)))
    dec = zb.label_decoder(10)
    reg.publish(clf, builder="model.classifier",
                config={"arch": "pixtral-12b", "n_classes": 10})
    reg.publish(dec, builder="adapter.label_decoder",
                config={"n_classes": 10})
    svc = clf >> dec
    reg.publish_composed(svc, [clf, dec])
    return root, svc.name


def test_pull_through_transport_charges_bytes(remote, tmp_path):
    root, _ = remote
    sreg = SyncedRegistry(tmp_path / "cache",
                          [RepoTransport(root)])
    svc, report = sreg.pull("classify_pixtral-12b")
    assert report is not None and report.nbytes > 0
    assert report.seconds > 0 and report.source == "repo"
    # second pull is a cache hit
    _, report2 = sreg.pull("classify_pixtral-12b")
    assert report2 is None or report2.cached


def test_peer_preferred_over_repo(remote, tmp_path):
    root, _ = remote
    peer = PeerTransport(root)
    repo = RepoTransport(root)
    sreg = SyncedRegistry(tmp_path / "cache", [peer, repo])
    _, report = sreg.pull("label_decoder")
    assert report.source == "peer"
    # peer (LAN) is modelled faster than repo (WAN) for the same bytes
    assert peer.network.transfer_s(10_000_000) \
        < repo.network.transfer_s(10_000_000)


def test_composed_pull_fetches_stage_deps(remote, tmp_path):
    root, comp_name = remote
    sreg = SyncedRegistry(tmp_path / "cache", [RepoTransport(root)])
    svc, _ = sreg.pull(comp_name)
    # stages landed in the cache too
    assert (tmp_path / "cache" / "classify_pixtral-12b").exists()
    assert (tmp_path / "cache" / "label_decoder").exists()
    import jax.numpy as jnp
    out = svc({"embeddings": jnp.ones((2, 16, 64), jnp.float32)})
    assert out["class_id"].shape == (2,)


def test_push_to_remote(remote, tmp_path):
    root, _ = remote
    other = tmp_path / "other_remote"
    sreg = SyncedRegistry(tmp_path / "cache", [RepoTransport(root)])
    sreg.pull("label_decoder")
    dst = RepoTransport(other)
    report = dst.push("label_decoder", "0.1.0", tmp_path / "cache")
    assert (other / "label_decoder/0.1.0/manifest.json").exists()
    assert report.nbytes > 0


def test_cli_roundtrip(tmp_path):
    from repro.launch.zoo_cli import main
    peer = str(tmp_path / "peer")
    zoo = str(tmp_path / "zoo")
    main(["--zoo", peer, "init-demo", "--n-classes", "10"])
    main(["--zoo", zoo, "--peer", peer, "pull",
          "--name", "classify_pixtral-12b"])
    main(["--zoo", zoo, "--peer", peer, "compose",
          "--stages", "classify_pixtral-12b,label_decoder",
          "--name", "pipe"])
    main(["--zoo", zoo, "deploy", "--name", "pipe",
          "--placement", "local", "--batch", "2"])
    main(["--zoo", zoo, "deploy", "--name", "pipe",
          "--placement", "split:1", "--batch", "2"])
