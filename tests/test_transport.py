"""Transports + CLI: the paper's pull-from-repo-or-peer workflow."""
import jax
import numpy as np
import pytest

import repro.core.zoo_builders as zb
from repro.core.registry import Registry
from repro.core.transport import (PeerTransport, RepoTransport,
                                  SyncedRegistry)


@pytest.fixture
def remote(tmp_path):
    """A populated remote repository."""
    root = tmp_path / "remote"
    reg = Registry(root)
    clf = zb.classifier_service("pixtral-12b", n_classes=10)
    clf = clf.with_params(clf.metadata["init_params"](jax.random.PRNGKey(0)))
    dec = zb.label_decoder(10)
    reg.publish(clf, builder="model.classifier",
                config={"arch": "pixtral-12b", "n_classes": 10})
    reg.publish(dec, builder="adapter.label_decoder",
                config={"n_classes": 10})
    svc = clf >> dec
    reg.publish_composed(svc, [clf, dec])
    return root, svc.name


def test_pull_through_transport_charges_bytes(remote, tmp_path):
    root, _ = remote
    sreg = SyncedRegistry(tmp_path / "cache",
                          [RepoTransport(root)])
    svc, report = sreg.pull("classify_pixtral-12b")
    assert report is not None and report.nbytes > 0
    assert report.seconds > 0 and report.source == "repo"
    # second pull is a cache hit
    _, report2 = sreg.pull("classify_pixtral-12b")
    assert report2 is None or report2.cached


def test_peer_preferred_over_repo(remote, tmp_path):
    root, _ = remote
    peer = PeerTransport(root)
    repo = RepoTransport(root)
    sreg = SyncedRegistry(tmp_path / "cache", [peer, repo])
    _, report = sreg.pull("label_decoder")
    assert report.source == "peer"
    # peer (LAN) is modelled faster than repo (WAN) for the same bytes
    assert peer.network.transfer_s(10_000_000) \
        < repo.network.transfer_s(10_000_000)


def test_composed_pull_fetches_stage_deps(remote, tmp_path):
    root, comp_name = remote
    sreg = SyncedRegistry(tmp_path / "cache", [RepoTransport(root)])
    svc, _ = sreg.pull(comp_name)
    # stages landed in the cache too
    assert (tmp_path / "cache" / "classify_pixtral-12b").exists()
    assert (tmp_path / "cache" / "label_decoder").exists()
    import jax.numpy as jnp
    out = svc({"embeddings": jnp.ones((2, 16, 64), jnp.float32)})
    assert out["class_id"].shape == (2,)


def test_push_to_remote(remote, tmp_path):
    root, _ = remote
    other = tmp_path / "other_remote"
    sreg = SyncedRegistry(tmp_path / "cache", [RepoTransport(root)])
    sreg.pull("label_decoder")
    dst = RepoTransport(other)
    report = dst.push("label_decoder", "0.1.0", tmp_path / "cache")
    assert (other / "label_decoder/0.1.0/manifest.json").exists()
    assert report.nbytes > 0


def test_cli_roundtrip(tmp_path):
    from repro.launch.zoo_cli import main
    peer = str(tmp_path / "peer")
    zoo = str(tmp_path / "zoo")
    main(["--zoo", peer, "init-demo", "--n-classes", "10"])
    main(["--zoo", zoo, "--peer", peer, "pull",
          "--name", "classify_pixtral-12b"])
    main(["--zoo", zoo, "--peer", peer, "compose",
          "--stages", "classify_pixtral-12b,label_decoder",
          "--name", "pipe"])
    main(["--zoo", zoo, "deploy", "--name", "pipe",
          "--placement", "local", "--batch", "2"])
    main(["--zoo", zoo, "deploy", "--name", "pipe",
          "--placement", "split:1", "--batch", "2"])


# ------------------------------------------------------------------ #
# resilience: retries, timeouts, atomicity (docs/robustness.md)
# ------------------------------------------------------------------ #
def test_fetch_retries_injected_drops(remote, tmp_path):
    from repro.serving.faults import Faults
    root, _ = remote
    f = Faults(seed=0).on("transport_drop", op="fetch", times=2)
    t = RepoTransport(root, backoff_s=0.001, faults=f)
    report = t.fetch("label_decoder", "0.1.0", tmp_path / "cache")
    assert report.retries == 2
    assert report.nbytes > 0
    assert (tmp_path / "cache/label_decoder/0.1.0/manifest.json").exists()


def test_fetch_exhausts_retries_and_leaves_no_partial(remote, tmp_path):
    from repro.core.transport import TransportError
    from repro.serving.faults import Faults
    root, _ = remote
    f = Faults(seed=0).on("transport_drop", op="fetch", times=-1)
    t = RepoTransport(root, backoff_s=0.001, max_retries=2, faults=f)
    with pytest.raises(TransportError, match="after 3 attempts"):
        t.fetch("label_decoder", "0.1.0", tmp_path / "cache")
    # atomic: a failed transfer never leaves a half-copied service that
    # a later pull would mistake for a cache hit
    assert not (tmp_path / "cache/label_decoder/0.1.0").exists()
    report = RepoTransport(root).fetch("label_decoder", "0.1.0",
                                       tmp_path / "cache")
    assert not report.cached and report.retries == 0


def test_injected_latency_trips_timeout_then_recovers(remote, tmp_path):
    from repro.serving.faults import Faults
    root, _ = remote
    f = Faults(seed=0).on("transport_latency", op="fetch",
                          delay_s=0.2, times=1)
    t = RepoTransport(root, timeout_s=0.05, backoff_s=0.001, faults=f)
    report = t.fetch("label_decoder", "0.1.0", tmp_path / "cache")
    assert report.retries == 1          # attempt 0 timed out, 1 landed


def test_push_retries_injected_drop(remote, tmp_path):
    from repro.serving.faults import Faults
    root, _ = remote
    RepoTransport(root).fetch("label_decoder", "0.1.0", tmp_path / "cache")
    f = Faults(seed=0).on("transport_drop", op="push", times=1)
    t = RepoTransport(tmp_path / "other", backoff_s=0.001, faults=f)
    report = t.push("label_decoder", "0.1.0", tmp_path / "cache")
    assert report.retries == 1
    assert (tmp_path / "other/label_decoder/0.1.0/manifest.json").exists()


def test_backoff_is_deterministic_and_bounded():
    t1 = RepoTransport("/nonexistent", backoff_s=0.01, jitter_seed=3)
    t2 = RepoTransport("/nonexistent", backoff_s=0.01, jitter_seed=3)
    seq1 = [t1._backoff(k) for k in range(4)]
    seq2 = [t2._backoff(k) for k in range(4)]
    assert seq1 == seq2                 # seeded jitter replays
    for k, d in enumerate(seq1):        # exponential envelope, jittered
        assert 0.5 * 0.01 * 2 ** k <= d <= 0.01 * 2 ** k
