"""Cross-family serving identity: every zoo family — pure SSM, hybrid
attention/SSM, MoE, encoder-decoder — admits through the one fused
chunked path with zero fallback admissions, chunk size is a scheduling
choice (chunked output == whole-prompt output), and the family-agnostic
n-gram drafter is greedy token-identical to the plain engine. Plus the
SSM checkpoint-rollback replay contract at the model level."""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.models.model import build
from repro.serving.engine import Engine
from repro.serving.request import Request
from repro.serving.sampler import Sampler

FAMILIES = [
    "mamba2-780m",            # pure SSM (replay rollback)
    pytest.param("jamba-1.5-large-398b",
                 marks=pytest.mark.slow),  # hybrid attn/SSM + MoE
    "qwen2-moe-a2.7b",        # MoE (dense routing in extend)
    "seamless-m4t-medium",    # encoder-decoder (frozen cross-attn KV)
]


@functools.lru_cache(maxsize=None)
def _stack(arch, vocab=0):
    cfg = get_arch(arch, variant="reduced")
    if vocab:
        cfg = cfg.replace(vocab=vocab)
    model = build(cfg)
    return cfg, model, model.init(jax.random.PRNGKey(0))


def _requests(cfg, lengths, max_new, seed=5):
    """Token prompts (+ frontend frames for encdec stacks)."""
    rng = np.random.default_rng(seed)
    reqs = []
    for uid, L in enumerate(lengths):
        emb = None
        if cfg.frontend is not None:
            fe = cfg.frontend
            emb = rng.normal(size=(fe.n_tokens, fe.d_embed)) \
                .astype(np.float32)
        reqs.append(Request(uid=uid,
                            prompt=rng.integers(0, cfg.vocab, L),
                            max_new_tokens=max_new, embeddings=emb))
    return reqs

def _serve(model, params, reqs, **kw):
    kw.setdefault("max_batch", 2)
    kw.setdefault("cache_len", 64)
    eng = Engine(model, params, sampler=Sampler(), **kw)
    for r in reqs:
        eng.submit(r)
    resp = eng.run()
    assert all(r.finished for r in resp.values())
    return {u: r.tokens for u, r in resp.items()}, eng.latency_stats()


# ------------------------------------------------------------------ #
# chunk size is a scheduling choice, never a numerics choice
# ------------------------------------------------------------------ #
@pytest.mark.parametrize("arch", FAMILIES)
def test_chunked_equals_whole_prompt(arch):
    """Admitting in 8-token chunks produces exactly the whole-prompt
    (single max-size chunk) greedy output, and nothing falls back to a
    monolithic path — there is none left to fall back to."""
    cfg, model, params = _stack(arch)
    lengths = (3, 11, 17)
    whole, st_w = _serve(model, params, _requests(cfg, lengths, 6))
    chunk, st_c = _serve(model, params, _requests(cfg, lengths, 6),
                         prefill_chunk=8)
    assert chunk == whole
    for st in (st_w, st_c):
        assert st["fallback_admissions"] == 0
        assert st["chunked_admissions"] == len(lengths)


@pytest.mark.parametrize("arch", FAMILIES)
def test_admission_cache_bits_chunked_vs_whole(arch):
    """Driving one prompt through 8-token chunks leaves slot 0 with the
    same cache bits as a single max-size chunk — K/V up to the prompt
    depth, pos/step rows, and SSM/cross-attention state alike. The
    ``*_ckpt`` leaves are excluded: they snapshot the state before the
    *most recent* advance, which legitimately differs with chunking."""
    cfg, model, params = _stack(arch)
    L = 13
    caches = {}
    for tag, kw in (("chunked", {"prefill_chunk": 8}), ("whole", {})):
        eng = Engine(model, params, max_batch=2, cache_len=64,
                     sampler=Sampler(), **kw)
        eng.submit(_requests(cfg, (L,), 4)[0])
        eng._fill_free_slots()
        while eng._admit is not None:
            eng.step()
        caches[tag] = jax.tree.map(np.asarray, eng.cache)
    fa = jax.tree_util.tree_flatten_with_path(caches["chunked"])[0]
    fb = jax.tree.leaves(caches["whole"])
    for (path, la), lb in zip(fa, fb):
        key = getattr(path[-1], "key", "")
        if key.endswith("_ckpt"):
            continue
        if key in ("k", "v", "k_scale", "v_scale"):
            la, lb = la[:, 0, :L], lb[:, 0, :L]   # written prompt span
        else:
            la, lb = la[:, 0], lb[:, 0]           # slot row, full state
        np.testing.assert_array_equal(la, lb, err_msg=str(key))


# ------------------------------------------------------------------ #
# family-agnostic n-gram speculation (ISSUE acceptance criterion)
# ------------------------------------------------------------------ #
@pytest.mark.parametrize("arch", [
    "mamba2-780m",
    "qwen2-moe-a2.7b",
    pytest.param("seamless-m4t-medium", marks=pytest.mark.slow),
])
def test_ngram_spec_greedy_identity(arch):
    """The prompt-lookup drafter needs no second model and no
    replay-free cache: greedy output is token-identical to the plain
    engine on SSM, MoE and encoder-decoder stacks alike."""
    cfg, model, params = _stack(arch)
    reqs = lambda: _requests(cfg, (3, 9, 14), 8, seed=9)  # noqa: E731
    base, _ = _serve(model, params, reqs())
    out, st = _serve(model, params, reqs(), draft="ngram", spec_gamma=3)
    assert out == base
    assert st["fallback_admissions"] == 0
    assert st["spec_gamma"] == 3


@pytest.mark.parametrize("arch", ["mamba2-780m", "qwen2-moe-a2.7b"])
def test_ngram_spec_accepts_on_repetitive_stream(arch):
    """A tiny vocabulary forces repeated n-grams, so drafts actually
    match and the accept/commit path (checkpoint rollback + replay on
    SSM stacks) is genuinely exercised — with identity still holding
    and fewer fused steps than emitted tokens."""
    cfg, model, params = _stack(arch, vocab=8)
    reqs = lambda: _requests(cfg, (6, 13), 16, seed=2)  # noqa: E731
    base, _ = _serve(model, params, reqs())
    out, st = _serve(model, params, reqs(), draft="ngram", spec_gamma=3)
    assert out == base
    assert st["spec_acceptance_rate"] > 0.0
    assert st["decode_steps"] < sum(len(t) - 1 for t in base.values())


# ------------------------------------------------------------------ #
# encoder-decoder admission contract
# ------------------------------------------------------------------ #
def test_encdec_rejects_token_only_requests():
    """Cross-attention memory is encoded at admission, so an encdec
    request without frontend frames cannot be served."""
    cfg, model, params = _stack("seamless-m4t-medium")
    eng = Engine(model, params, max_batch=1, cache_len=64,
                 sampler=Sampler())
    with pytest.raises(ValueError, match="embeddings"):
        eng.submit(Request(uid=0, prompt=np.asarray([1, 2, 3]),
                           max_new_tokens=2))


# ------------------------------------------------------------------ #
# SSM rollback contract (model level)
# ------------------------------------------------------------------ #
def test_ssm_rollback_replay_matches_clean():
    """``rollback_needs_replay`` stacks restore the checkpoint taken
    before the most recent advance; rolling back a speculative verify
    and re-extending the accepted prefix must land in exactly the state
    a clean (never-speculated) cache reaches — the engine's replay
    commit flow."""
    cfg, model, params = _stack("mamba2-780m")
    assert model.rollback_needs_replay
    rng = np.random.default_rng(13)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (1, 8)), jnp.int32)
    junk = jnp.asarray(rng.integers(0, cfg.vocab, (1, 5)), jnp.int32)
    nxt = jnp.asarray([[3]], jnp.int32)
    three = jnp.asarray([3], jnp.int32)
    ext = jax.jit(lambda p, t, c, n: model.extend_into_cache(
        p, t, c, n, last_only=True))

    cache = model.make_cache(1, 32)
    _, cache = jax.jit(model.prefill)(params, {"tokens": toks}, cache)
    _, cache = jax.jit(model.verify_step)(params, junk, cache)
    # accept the first 3 of the 5 speculated tokens: rewind to the
    # pre-verify checkpoint, then replay exactly the accepted prefix
    cache = model.rollback(cache, jnp.asarray([8], jnp.int32))
    _, cache = ext(params, junk[:, :3], cache, three)

    clean = model.make_cache(1, 32)
    _, clean = jax.jit(model.prefill)(params, {"tokens": toks}, clean)
    _, clean = ext(params, junk[:, :3], clean, three)

    assert int(model.cache_steps(cache)[0]) == 11
    lo_r, _ = jax.jit(model.decode_step)(params, nxt, cache)
    lo_c, _ = jax.jit(model.decode_step)(params, nxt, clean)
    np.testing.assert_allclose(np.asarray(lo_r), np.asarray(lo_c),
                               rtol=2e-5, atol=2e-5)
