"""Per-architecture smoke tests (deliverable f): every assigned arch, as a
REDUCED variant of the same family, runs one forward/train step and a
prefill+decode step on CPU with correct shapes and no NaNs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_arch
from repro.models.model import build

from repro.configs.extra import EXTRA_ARCHS

ALL_ARCHS = sorted(ARCHS) + sorted(EXTRA_ARCHS)


def _batch_for(cfg, B, L, rng):
    fe = cfg.frontend
    batch = {}
    if cfg.family == "vlm":
        batch["tokens"] = jnp.asarray(
            rng.integers(0, cfg.vocab, (B, L - fe.n_tokens)), jnp.int32)
        batch["embeddings"] = jnp.asarray(
            rng.normal(0, 1, (B, fe.n_tokens, fe.d_embed)), jnp.float32)
    elif cfg.family == "encdec":
        batch["tokens"] = jnp.asarray(
            rng.integers(0, cfg.vocab, (B, L)), jnp.int32)
        batch["embeddings"] = jnp.asarray(
            rng.normal(0, 1, (B, fe.n_tokens, fe.d_embed)), jnp.float32)
    else:
        batch["tokens"] = jnp.asarray(
            rng.integers(0, cfg.vocab, (B, L)), jnp.int32)
    return batch


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_train_step_smoke(arch):
    cfg = get_arch(arch, variant="reduced")
    assert cfg.n_layers <= max(2, cfg.attn_every) and cfg.d_model <= 512
    if cfg.moe:
        assert cfg.moe.n_experts <= 4
    model = build(cfg)
    rng = np.random.default_rng(0)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch_for(cfg, B=2, L=32, rng=rng)
    loss, metrics = jax.jit(model.train_loss)(params, batch)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), f"{arch}: non-finite loss"
    assert float(loss) > 0.5  # ~log(vocab) at init


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_prefill_decode_smoke(arch):
    cfg = get_arch(arch, variant="reduced")
    model = build(cfg)
    rng = np.random.default_rng(1)
    params = model.init(jax.random.PRNGKey(1))
    B, L = 2, 16
    batch = _batch_for(cfg, B=B, L=L, rng=rng)
    cache = model.make_cache(B, 32)
    logits, cache = jax.jit(model.prefill)(params, batch, cache)
    assert logits.shape == (B, 1, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))
    tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
    for _ in range(3):
        logits, cache = jax.jit(model.decode_step)(params, tok, cache)
        assert logits.shape == (B, 1, cfg.vocab)
        assert bool(jnp.all(jnp.isfinite(logits)))
        tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_input_specs_cover_all_shapes(arch):
    """input_specs produces pure ShapeDtypeStructs (no allocation) for all
    applicable shapes."""
    from repro.configs import SHAPES
    from repro.launch.steps import ShapeSkip, resolve_config
    for shape in SHAPES.values():
        try:
            cfg = resolve_config(arch, shape.name)
        except ShapeSkip:
            assert arch == "seamless-m4t-medium" and shape.name == "long_500k"
            continue
        model = build(cfg)
        specs = model.input_specs(shape)
        for leaf in jax.tree.leaves(specs):
            assert isinstance(leaf, jax.ShapeDtypeStruct)
