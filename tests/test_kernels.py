"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs pure-jnp
oracle (deliverable c)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

rng = np.random.default_rng(42)


def _arr(shape, dtype=jnp.float32, scale=1.0):
    return jnp.asarray(rng.normal(0, scale, shape), dtype)


# ------------------------------------------------------------------ #
# flash attention
# ------------------------------------------------------------------ #
@pytest.mark.parametrize("B,H,L,hd", [
    (1, 1, 128, 64), (2, 4, 256, 64), (1, 2, 512, 32), (2, 1, 128, 128),
])
@pytest.mark.parametrize("causal,window", [(True, 0), (False, 0), (True, 64)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention(B, H, L, hd, causal, window, dtype):
    from repro.kernels.flash_attention.kernel import flash_attention_pallas
    from repro.kernels.flash_attention.ref import attention_reference
    q, k, v = (_arr((B, H, L, hd), dtype) for _ in range(3))
    out = flash_attention_pallas(q, k, v, causal=causal, window=window,
                                 bq=64, bk=64, interpret=True)
    ref = attention_reference(q, k, v, causal=causal, window=window)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol)


def test_flash_attention_gqa_wrapper():
    from repro.kernels.flash_attention.ops import gqa_flash
    from repro.models.layers import gqa_attention
    B, L, Hq, Hkv, hd = 2, 128, 8, 2, 64
    q = _arr((B, L, Hq, hd))
    k = _arr((B, L, Hkv, hd))
    v = _arr((B, L, Hkv, hd))
    out = gqa_flash(q, k, v, causal=True, use_pallas=True, bq=64, bk=64)
    ref = gqa_attention(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("B,Hq,Hkv,L,hd", [
    (2, 8, 2, 256, 64), (1, 4, 1, 128, 32), (2, 6, 3, 128, 64),
])
@pytest.mark.parametrize("causal,window", [(True, 0), (True, 48),
                                           (False, 0)])
def test_flash_attention_gqa_native_kernel(B, Hq, Hkv, L, hd, causal,
                                           window):
    """GQA-native kernel (KV tiles staged once per group) vs expanded
    reference."""
    from repro.kernels.flash_attention.kernel import (
        flash_attention_gqa_pallas)
    from repro.kernels.flash_attention.ref import attention_reference
    q = _arr((B, Hq, L, hd))
    k = _arr((B, Hkv, L, hd))
    v = _arr((B, Hkv, L, hd))
    out = flash_attention_gqa_pallas(q, k, v, causal=causal, window=window,
                                     bq=64, bk=64, interpret=True)
    rep = Hq // Hkv
    ref = attention_reference(q, jnp.repeat(k, rep, 1),
                              jnp.repeat(v, rep, 1), causal=causal,
                              window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


# ------------------------------------------------------------------ #
# decode attention
# ------------------------------------------------------------------ #
@pytest.mark.parametrize("B,Hq,Hkv,S,hd", [
    (2, 8, 2, 256, 64), (1, 4, 4, 128, 32), (2, 4, 1, 512, 64),
])
@pytest.mark.parametrize("window", [0, 128])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention(B, Hq, Hkv, S, hd, window, dtype):
    from repro.kernels.decode_attention.kernel import decode_attention_pallas
    from repro.kernels.decode_attention.ref import (
        decode_attention_reference)
    step = S - S // 3
    q = _arr((B, Hq, hd), dtype)
    k = _arr((B, Hkv, S, hd), dtype)
    v = _arr((B, Hkv, S, hd), dtype)
    pos = np.full((B, S), -1, np.int32)
    for b in range(B):
        n = min(step + 1, S)
        ps = np.arange(step + 1 - n, step + 1)
        pos[b, ps % S] = ps
    pos = jnp.asarray(pos)
    qp = jnp.full((B,), step, jnp.int32)
    out = decode_attention_pallas(q, k, v, pos, qp, window=window, bk=64,
                                  interpret=True)
    ref = decode_attention_reference(q, k, v, pos, qp, window=window)
    tol = 3e-2 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("T,B,Hq,Hkv,S,hd", [
    (4, 2, 8, 2, 256, 64), (5, 1, 4, 4, 128, 32),
])
@pytest.mark.parametrize("window", [0, 64])
def test_decode_attention_multi_query(T, B, Hq, Hkv, S, hd, window):
    """Multi-query rows (speculative verify / chunked-prefill extend):
    T query tokens per row, each masked at its own absolute position,
    against the same per-slot cache region."""
    from repro.kernels.decode_attention.kernel import decode_attention_pallas
    from repro.kernels.decode_attention.ref import (
        decode_attention_reference)
    step = S - S // 3
    q = _arr((B, T, Hq, hd))
    k = _arr((B, Hkv, S, hd))
    v = _arr((B, Hkv, S, hd))
    pos = np.full((B, S), -1, np.int32)
    for b in range(B):
        n = min(step + T, S)
        ps = np.arange(step + T - n, step + T)
        pos[b, ps % S] = ps
    pos = jnp.asarray(pos)
    qp = jnp.broadcast_to(step + jnp.arange(T, dtype=jnp.int32), (B, T))
    out = decode_attention_pallas(q, k, v, pos, qp, window=window, bk=64,
                                  interpret=True)
    ref = decode_attention_reference(q, k, v, pos, qp, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


def test_cached_decode_attention_multi_query_matches_gqa():
    """The ops wrapper's (B, T) form == the model's gqa_attention with
    per-row query positions (what extend_into_cache routes through when
    cfg.use_decode_kernel is set)."""
    from repro.kernels.decode_attention.ops import cached_decode_attention
    from repro.models.layers import gqa_attention
    B, T, S, Hq, Hkv, hd = 2, 3, 64, 4, 2, 32
    base = S - 8
    q = _arr((B, T, Hq, hd))
    k_cache = _arr((B, S, Hkv, hd))
    v_cache = _arr((B, S, Hkv, hd))
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    q_pos = base + jnp.arange(T, dtype=jnp.int32)[None] \
        + jnp.zeros((B, 1), jnp.int32)
    out = cached_decode_attention(q, k_cache, v_cache, pos, q_pos,
                                  use_pallas=True, bk=32)
    ref = gqa_attention(q, k_cache, v_cache, q_positions=q_pos,
                        k_positions=pos, causal=True, k_valid=pos >= 0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)
    # (B,) base-position form == explicit per-query positions
    out2 = cached_decode_attention(q, k_cache, v_cache, pos,
                                   jnp.full((B,), base, jnp.int32),
                                   use_pallas=True, bk=32)
    np.testing.assert_allclose(np.asarray(out2), np.asarray(out),
                               rtol=1e-6, atol=1e-6)


def test_decode_attention_matches_model_path():
    """Kernel == the model's gqa_attention on a populated cache."""
    from repro.kernels.decode_attention.ops import cached_decode_attention
    from repro.models.layers import gqa_attention
    B, S, Hq, Hkv, hd = 2, 64, 4, 2, 32
    q = _arr((B, 1, Hq, hd))
    k_cache = _arr((B, S, Hkv, hd))
    v_cache = _arr((B, S, Hkv, hd))
    step = jnp.full((B,), S - 1, jnp.int32)
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    out = cached_decode_attention(q, k_cache, v_cache, pos, step,
                                  use_pallas=True, bk=32)
    ref = gqa_attention(q, k_cache, v_cache,
                        q_positions=step[:, None], k_positions=pos,
                        causal=True, k_valid=pos >= 0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


# ------------------------------------------------------------------ #
# SSD scan
# ------------------------------------------------------------------ #
@pytest.mark.parametrize("b,l,h,p,g,n,chunk", [
    (2, 128, 4, 32, 1, 32, 32), (1, 256, 8, 64, 2, 128, 64),
    (2, 64, 2, 16, 2, 16, 16), (1, 128, 6, 32, 3, 64, 64),
])
def test_ssd_scan(b, l, h, p, g, n, chunk):
    from repro.kernels.ssd_scan.kernel import ssd_pallas
    from repro.kernels.ssd_scan.ref import ssd_reference
    x = _arr((b, l, h, p))
    dt = jnp.asarray(rng.uniform(1e-3, 0.1, (b, l, h)), jnp.float32)
    A = jnp.asarray(-rng.uniform(0.5, 2.0, (h,)), jnp.float32)
    Bm = _arr((b, l, g, n))
    Cm = _arr((b, l, g, n))
    D = _arr((h,))
    y, s = ssd_pallas(x, dt, A, Bm, Cm, D, chunk=chunk, interpret=True)
    yr, sr = ssd_reference(x, dt, A, Bm, Cm, D, chunk=chunk)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(np.asarray(s), np.asarray(sr),
                               rtol=1e-3, atol=1e-3)


def test_ssd_decode_step_matches_scan():
    """Recurrent decode steps reproduce the chunked scan outputs."""
    from repro.kernels.ssd_scan.ref import ssd_decode_step, ssd_reference
    b, l, h, p, g, n = 1, 32, 2, 16, 1, 16
    x = _arr((b, l, h, p))
    dt = jnp.asarray(rng.uniform(1e-3, 0.1, (b, l, h)), jnp.float32)
    A = jnp.asarray(-rng.uniform(0.5, 2.0, (h,)), jnp.float32)
    Bm = _arr((b, l, g, n))
    Cm = _arr((b, l, g, n))
    y_scan, s_scan = ssd_reference(x, dt, A, Bm, Cm, None, chunk=16)
    state = jnp.zeros((b, h, p, n), jnp.float32)
    for t in range(l):
        y_t, state = ssd_decode_step(state, x[:, t], dt[:, t], A,
                                     Bm[:, t], Cm[:, t], None)
        np.testing.assert_allclose(np.asarray(y_t),
                                   np.asarray(y_scan[:, t]),
                                   rtol=1e-3, atol=1e-3,
                                   err_msg=f"t={t}")
    np.testing.assert_allclose(np.asarray(state), np.asarray(s_scan),
                               rtol=1e-3, atol=1e-3)


# ------------------------------------------------------------------ #
# fused dequantize-matmul
# ------------------------------------------------------------------ #
@pytest.mark.parametrize("M,K,N,bm,bn", [
    (128, 256, 128, 64, 64), (64, 128, 256, 64, 128), (128, 64, 128, 128, 64),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_quant_matmul_int8_kernel(M, K, N, bm, bn, dtype):
    from repro.kernels.quant_matmul.kernel import quant_matmul_int8_pallas
    from repro.kernels.quant_matmul.ref import quant_matmul_int8_reference
    from repro.quant import quantize_tensor
    x = _arr((M, K), dtype, scale=0.5)
    qt = quantize_tensor(_arr((K, N), scale=0.05), bits=8)
    out = quant_matmul_int8_pallas(x, qt["q"], qt["scale"], bm=bm, bn=bn,
                                   interpret=True)
    ref = quant_matmul_int8_reference(x, qt["q"], qt["scale"])
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("M,K,N,gs", [
    (64, 128, 128, 32), (128, 256, 64, 64), (64, 64, 128, 16),
])
def test_quant_matmul_int4_kernel(M, K, N, gs):
    from repro.kernels.quant_matmul.kernel import quant_matmul_int4_pallas
    from repro.kernels.quant_matmul.ref import quant_matmul_int4_reference
    from repro.quant import quantize_tensor
    x = _arr((M, K), scale=0.5)
    qt = quantize_tensor(_arr((K, N), scale=0.05), bits=4, group_size=gs)
    out = quant_matmul_int4_pallas(x, qt["q4"], qt["scale"], bm=64, bn=64,
                                   interpret=True)
    ref = quant_matmul_int4_reference(x, qt["q4"], qt["scale"])
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


def test_quant_matmul_matches_dense_dequant():
    """The fused op == dense matmul against the dequantized weight — the
    dispatch path models/layers.linear takes for quantized projections."""
    from repro.kernels.quant_matmul.ops import quant_matmul
    from repro.quant import dequantize_tensor, quantize_tensor
    x = _arr((2, 16, 96), scale=0.5)                  # rank-3 activations
    for bits in (8, 4):
        qt = quantize_tensor(_arr((96, 64), scale=0.05), bits=bits,
                             group_size=32)
        out = quant_matmul(x, qt)
        ref = x @ dequantize_tensor(qt)
        assert out.shape == (2, 16, 64)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-5, atol=1e-5)


def test_quant_matmul_pallas_path_matches_ref():
    from repro.kernels.quant_matmul.ops import quant_matmul
    from repro.quant import quantize_tensor
    x = _arr((128, 128), scale=0.5)
    for bits in (8, 4):
        qt = quantize_tensor(_arr((128, 128), scale=0.05), bits=bits,
                             group_size=32)
        out_p = quant_matmul(x, qt, use_pallas=True, interpret=True)
        out_r = quant_matmul(x, qt, use_pallas=False)
        np.testing.assert_allclose(np.asarray(out_p), np.asarray(out_r),
                                   rtol=1e-5, atol=1e-5)


# ------------------------------------------------------------------ #
# fused rmsnorm
# ------------------------------------------------------------------ #
@pytest.mark.parametrize("N,d,bn", [(256, 128, 128), (128, 512, 64),
                                    (64, 64, 64)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fused_rmsnorm(N, d, bn, dtype):
    from repro.kernels.rmsnorm.ops import fused_rmsnorm
    x = _arr((N, d), dtype)
    r = _arr((N, d), dtype)
    s = _arr((d,))
    yp, rp = fused_rmsnorm(x, r, s, use_pallas=True, bn=bn)
    yr, rr = fused_rmsnorm(x, r, s, use_pallas=False)
    tol = 3e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(np.asarray(yp, np.float32),
                               np.asarray(yr, np.float32), rtol=tol,
                               atol=tol)
    np.testing.assert_allclose(np.asarray(rp, np.float32),
                               np.asarray(rr, np.float32), rtol=tol,
                               atol=tol)
