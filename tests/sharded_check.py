"""Multi-device sharded-serving equivalence checks.

MUST run as its own process: it forces 8 host-platform devices before
jax initialises (same pattern as ``repro.launch.dryrun``). Prints one
JSON object consumed by ``tests/test_sharded_serving.py``; every check
is also runnable standalone:

  python tests/sharded_check.py

Checks:
* per-mode greedy token identity, sharded (2 data x 4 model) engine vs
  single-device engine: plain, chunked prefill, prefix-cache reuse,
  int8 KV cache, speculative decoding, int8 weights (QTensor leaves
  shard like the w they replace) — plus plain on a pure-TP 1x8 mesh
  (kv heads don't divide 8: the heads dim falls back to replicated,
  output must still match);
* cache equality after admission on the mesh: different chunk sizes
  write bit-identical K/V/pos/step, and chunked matches whole-prompt
  (single max-size chunk) admission to float tolerance — the max-size
  chunk pads its extend to ``kv_len``, where XLA picks a different
  matmul vectorization, so parity across *pad widths* is numerical
  (1-2 ulp), while parity across chunk sizes at small pads is bitwise;
* compiled-program-count flatness: serving a second request stream
  compiles nothing new (no resharding-induced recompiles; on-demand
  prefix ``materialize`` programs are excluded — a repeat stream hits
  *deeper* bucketed prefixes than the cold stream could, drawn from a
  bounded O(log) set).
"""
import os

os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=8")
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import json  # noqa: E402
import sys  # noqa: E402
from pathlib import Path  # noqa: E402

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro.configs import get_arch  # noqa: E402
from repro.models.model import build  # noqa: E402
from repro.serving.engine import Engine  # noqa: E402
from repro.serving.request import Request  # noqa: E402

CFG = get_arch("llama3.2-1b", variant="reduced")
MODEL = build(CFG)
PARAMS = MODEL.init(jax.random.PRNGKey(0))
from repro.quant import quantize_for_cfg  # noqa: E402
QPARAMS = quantize_for_cfg(PARAMS, CFG.replace(quant="int8"))
RNG = np.random.default_rng(21)
# shared 12-token head (prefix-cache hits) + varied tails straddling the
# chunk size; lengths cover below/at/above multiple chunks
HEAD = list(RNG.integers(0, CFG.vocab, 12))
PROMPTS = [np.asarray(HEAD + list(RNG.integers(0, CFG.vocab, L)))
           for L in (3, 8, 11, 17)]

MODES = {
    "plain": {},
    "chunked": {"prefill_chunk": 8},
    "prefix": {"prefill_chunk": 8, "prefix_cache_tokens": 256},
    "int8kv": {"kv_cache_dtype": "int8"},
    "spec": {"draft": "fp@1", "spec_gamma": 2},
    # int8 weights: the QTensor q/scale leaves must shard like the
    # full-precision w they replace (param_shardings qtensor rules)
    "int8w": {"_quant": True},
}


def _engine(mesh, _quant=False, **kw):
    return Engine(MODEL, QPARAMS if _quant else PARAMS, max_batch=4,
                  cache_len=64, mesh=mesh, **kw)


def _serve(eng, prompts=PROMPTS, max_new=8, uid0=0):
    for i, p in enumerate(prompts):
        eng.submit(Request(uid=uid0 + i, prompt=p, max_new_tokens=max_new))
    resp = eng.run()
    return {u: r.tokens for u, r in resp.items() if u >= uid0}


def check_mode(name, mesh="2,4"):
    kw = MODES[name]
    single = _serve(_engine(None, **kw))
    eng = _engine(mesh, **kw)
    sharded = _serve(eng)
    sizes0 = dict(eng.program_cache_sizes())
    # prefix materialize programs are warmed on demand from a bounded
    # O(log) bucket set: a repeat stream hits its own full-length
    # entries, i.e. deeper buckets than any cold stream could, so those
    # keys may legitimately appear here — everything else must be flat
    slot_keys = lambda: {k for k in eng._slot_jits  # noqa: E731
                         if k[0] != "materialize"}
    slots0 = slot_keys()
    # a second stream through the warm engine must compile nothing new;
    # its expected tokens are the first stream's under shifted uids (the
    # engine state is stream-independent after drain)
    sharded2 = _serve(eng, uid0=100)
    single2 = {u + 100: t for u, t in single.items()}
    return {
        "identical": single == sharded,
        "identical_second_stream": single2 == sharded2,
        "programs_flat": sizes0 == dict(eng.program_cache_sizes())
        and slots0 == slot_keys(),
        "program_sizes": dict(eng.program_cache_sizes()),
    }


def check_admission_cache_bits(mesh="2,4"):
    """On the mesh: chunk size is a scheduling choice, not a numerics
    choice. 8- and 16-token chunked admission leave slot 0 bit-identical
    (K/V/pos/step); the single max-size chunk (prefill_chunk=0) pads its
    extend to ``kv_len``, where XLA's matmul vectorization changes, so
    chunked-vs-whole K/V parity is to float tolerance (1-2 ulp) with
    pos/step still exact — greedy token identity across all three is
    asserted by check_mode("chunked")."""
    prompt = PROMPTS[2]
    L = len(prompt)
    out = {}
    caches = {}
    for tag, kw in (("chunk8", {"prefill_chunk": 8}),
                    ("chunk16", {"prefill_chunk": 16}),
                    ("whole", {})):
        eng = _engine(mesh, **kw)
        eng.submit(Request(uid=0, prompt=prompt, max_new_tokens=1))
        # drive admission only — stop at the arming step so no decode
        # step has touched the cache in either engine
        eng._fill_free_slots()
        while eng._admit is not None:
            eng.step()
        caches[tag] = jax.tree.map(np.asarray, eng.cache)

    def compare(a, b, exact):
        flat_a = jax.tree_util.tree_flatten_with_path(a)[0]
        flat_b = jax.tree.leaves(b)
        ok = True
        for (path, la), lb in zip(flat_a, flat_b):
            key = path[-1].key
            if key in ("k", "v", "k_scale", "v_scale"):
                la, lb = la[:, 0, :L], lb[:, 0, :L]
                ok &= (np.array_equal(la, lb) if exact else
                       np.allclose(la, lb, rtol=1e-5, atol=1e-5))
            elif key in ("pos", "step"):
                ok &= np.array_equal(la[:, 0], lb[:, 0])
        return bool(ok)

    out["cache_bits_equal"] = compare(caches["chunk8"], caches["chunk16"],
                                      exact=True)
    out["cache_close_to_whole"] = compare(caches["chunk8"],
                                          caches["whole"], exact=False)
    return out


def main():
    assert len(jax.devices()) == 8, jax.devices()
    result = {"n_devices": len(jax.devices()), "modes": {}}
    for name in MODES:
        result["modes"][name] = check_mode(name)
    result["plain_1x8"] = check_mode("plain", mesh="1,8")
    result.update(check_admission_cache_bits())
    print(json.dumps(result, indent=1))
    ok = all(m["identical"] and m["identical_second_stream"]
             and m["programs_flat"] for m in result["modes"].values()) \
        and result["plain_1x8"]["identical"] and result["cache_bits_equal"]
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
