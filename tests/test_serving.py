"""Serving engine: continuous batching, greedy determinism, deployment."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.core.deploy import DeploymentPlan, deploy
from repro.core.netmodel import NetworkModel
from repro.models.model import build
from repro.serving.engine import Engine
from repro.serving.request import Request
from repro.serving.sampler import Sampler


def _model_params(arch="llama3.2-1b", seed=0):
    cfg = get_arch(arch, variant="reduced")
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(seed))
    return cfg, model, params


def test_engine_finishes_all_mixed_length_requests():
    cfg, model, params = _model_params()
    eng = Engine(model, params, max_batch=3, cache_len=64,
                 sampler=Sampler())
    rng = np.random.default_rng(0)
    for uid in range(7):
        L = int(rng.integers(3, 20))
        eng.submit(Request(uid=uid, prompt=rng.integers(0, cfg.vocab, L),
                           max_new_tokens=8))
    resp = eng.run()
    assert len(resp) == 7
    assert all(r.finished and r.n_generated == 8 for r in resp.values())


@pytest.mark.slow
def test_engine_greedy_matches_single_request_decode():
    """A request served in a shared batch must produce the same greedy
    tokens as served alone — slot isolation."""
    cfg, model, params = _model_params(seed=3)
    rng = np.random.default_rng(5)
    prompts = [rng.integers(0, cfg.vocab, int(rng.integers(4, 12)))
               for _ in range(4)]

    def serve(prompts, max_batch):
        eng = Engine(model, params, max_batch=max_batch, cache_len=48,
                     sampler=Sampler())  # greedy
        for uid, p in enumerate(prompts):
            eng.submit(Request(uid=uid, prompt=p, max_new_tokens=6))
        return {uid: r.tokens for uid, r in eng.run().items()}

    together = serve(prompts, max_batch=4)
    alone = {}
    for uid, p in enumerate(prompts):
        alone.update({uid: serve([p], max_batch=1)[0]})
    for uid in range(4):
        assert together[uid] == alone[uid], (uid, together[uid], alone[uid])


def test_engine_eos_stops_early():
    cfg, model, params = _model_params()
    eng = Engine(model, params, max_batch=2, cache_len=64,
                 sampler=Sampler())
    # pick eos = the first greedy token so generation stops immediately
    eng.submit(Request(uid=0, prompt=np.asarray([1, 2, 3]),
                       max_new_tokens=10))
    resp = eng.run()
    first = resp[0].tokens[0]
    eng2 = Engine(model, params, max_batch=2, cache_len=64,
                  sampler=Sampler())
    eng2.submit(Request(uid=0, prompt=np.asarray([1, 2, 3]),
                        max_new_tokens=10, eos_id=int(first)))
    resp2 = eng2.run()
    assert resp2[0].n_generated == 1


def test_deployment_local_remote_same_result():
    """Deployment placement must not change results (paper's separation of
    functionality and deployment)."""
    import repro.core.zoo_builders as zb
    clf = zb.classifier_service("pixtral-12b", n_classes=10)
    clf = clf.with_params(clf.metadata["init_params"](jax.random.PRNGKey(0)))
    dec = zb.label_decoder(10)
    svc = clf >> dec
    x = {"embeddings": jnp.ones((2, 16, 64), jnp.float32)}
    outs = []
    for plan in [DeploymentPlan.all_local(svc),
                 DeploymentPlan.all_remote(svc, NetworkModel(seed=1)),
                 DeploymentPlan.split(svc, 1, NetworkModel(seed=2))]:
        d = deploy(svc, plan, stages=[clf, dec])
        y, tel = d.call(x)
        outs.append(np.asarray(y["class_id"]))
        assert tel.total_s > 0
    np.testing.assert_array_equal(outs[0], outs[1])
    np.testing.assert_array_equal(outs[0], outs[2])


def test_remote_deployment_charges_network():
    import repro.core.zoo_builders as zb
    clf = zb.classifier_service("pixtral-12b", n_classes=10)
    clf = clf.with_params(clf.metadata["init_params"](jax.random.PRNGKey(0)))
    dec = zb.label_decoder(10)
    svc = clf >> dec
    x = {"embeddings": jnp.ones((2, 16, 64), jnp.float32)}
    d_local = deploy(svc, DeploymentPlan.all_local(svc), stages=[clf, dec])
    d_remote = deploy(svc, DeploymentPlan.all_remote(
        svc, NetworkModel(seed=0)), stages=[clf, dec])
    _, tl = d_local.call(x)
    _, tr = d_remote.call(x)
    assert tl.transfer_total_s == 0.0
    assert tr.transfer_total_s > 0.0
