"""Chaos suite: deterministic fault injection against the live engine.

Two contracts (ISSUE: graceful degradation):

* **No-op invisibility** — an engine built with an empty/absent fault
  registry has bit-identical greedy outputs *and compiled-program
  counts* to a plain engine: the fault hooks must never perturb program
  shapes (the NaN site rides the always-present poison input).
* **Containment** — when a fault does fire, only the targeted stream
  degrades (finish_reason "error"/"timeout"); every surviving stream's
  greedy tokens are identical to the fault-free run, and the allocator
  finishes drained with its invariants intact.
"""
import jax
import numpy as np
import pytest

from repro.configs import get_arch
from repro.models.model import build
from repro.serving.engine import Engine
from repro.serving.faults import Faults
from repro.serving.request import Request
from repro.serving.sampler import Sampler

_CFG = get_arch("llama3.2-1b", variant="reduced")
_MODEL = build(_CFG)
_PARAMS = _MODEL.init(jax.random.PRNGKey(0))
_RNG = np.random.default_rng(41)

MODES = {
    "plain": dict(prefill_chunk=0),
    "chunked": dict(prefill_chunk=8),
    "prefix": dict(prefill_chunk=8, prefix_cache_tokens=256),
    "paged": dict(prefill_chunk=8, paged=True, page_size=8),
    "spec": dict(draft="fp@1", spec_gamma=4),
}
_PROMPTS = [_RNG.integers(0, _CFG.vocab, L) for L in (5, 9, 12, 7)]


def _run(mode, n=4, max_new=8, **kw):
    base = dict(MODES[mode])
    base.update(kw)
    base.setdefault("max_batch", 2)
    base.setdefault("cache_len", 64)
    base.setdefault("sampler", Sampler())
    eng = Engine(_MODEL, _PARAMS, **base)
    for uid, p in enumerate(_PROMPTS[:n]):
        eng.submit(Request(uid=uid, prompt=p, max_new_tokens=max_new))
    return eng.run(), eng


# ------------------------------------------------------------------ #
# no-op invisibility
# ------------------------------------------------------------------ #
@pytest.mark.parametrize("mode", ["plain", "paged"])
def test_empty_fault_registry_is_invisible(mode):
    resp0, eng0 = _run(mode)
    resp1, eng1 = _run(mode, faults=Faults(seed=0))     # armed, empty
    assert {u: r.tokens for u, r in resp0.items()} \
        == {u: r.tokens for u, r in resp1.items()}
    assert eng1.program_cache_sizes() == eng0.program_cache_sizes()
    assert eng1.latency_stats()["faults_injected"] == 0


@pytest.mark.slow
@pytest.mark.parametrize("mode", ["chunked", "prefix", "spec"])
def test_empty_fault_registry_is_invisible_slow(mode):
    resp0, eng0 = _run(mode)
    resp1, eng1 = _run(mode, faults=Faults(seed=0))
    assert {u: r.tokens for u, r in resp0.items()} \
        == {u: r.tokens for u, r in resp1.items()}
    assert eng1.program_cache_sizes() == eng0.program_cache_sizes()


# ------------------------------------------------------------------ #
# NaN containment
# ------------------------------------------------------------------ #
def _assert_contained(resp, clean, eng, eng0, n_err=1):
    errs = [u for u, r in resp.items() if r.finish_reason == "error"]
    assert len(errs) == n_err, resp
    for u, r in resp.items():
        if r.ok:
            assert r.tokens == clean[u].tokens, u
    # injection must not have recompiled anything
    assert eng.program_cache_sizes() == eng0.program_cache_sizes()
    st = eng.latency_stats()
    assert st["slot_errors"] == n_err
    assert st["faults_injected"] >= n_err


@pytest.mark.parametrize("mode", ["plain", "paged"])
def test_nan_logits_contained_to_poisoned_slot(mode):
    clean, eng0 = _run(mode, n=2)
    f = Faults(seed=0).on("nan_logits", step=3, slot=0)
    resp, eng = _run(mode, n=2, faults=f)
    _assert_contained(resp, clean, eng, eng0)
    if mode == "paged":
        assert eng._paged.live_pages == 0   # errored slot released pages
        eng._paged.check_invariants()


@pytest.mark.slow
@pytest.mark.parametrize("mode", ["chunked", "spec"])
def test_nan_logits_contained_to_poisoned_slot_slow(mode):
    clean, eng0 = _run(mode, n=2)
    # spec emits up to gamma+1 tokens per step, so strike early
    step = 1 if mode == "spec" else 3
    f = Faults(seed=0).on("nan_logits", step=step, slot=0)
    resp, eng = _run(mode, n=2, faults=f)
    _assert_contained(resp, clean, eng, eng0)


# ------------------------------------------------------------------ #
# allocator-exhaustion degradation
# ------------------------------------------------------------------ #
def test_injected_page_exhaustion_degrades_not_crashes():
    clean, _ = _run("paged")
    f = Faults(seed=0).on("page_alloc", step=4, times=2)
    resp, eng = _run("paged", faults=f)
    # degradation, not a crash: every stream still finishes normally
    # with fault-free greedy tokens (preemption replay is exact)
    assert all(r.ok for r in resp.values())
    assert {u: r.tokens for u, r in resp.items()} \
        == {u: r.tokens for u, r in clean.items()}
    st = eng.latency_stats()
    assert st["faults_injected"] >= 1
    assert st["kv_pages_live"] == 0
    eng._paged.check_invariants()


# ------------------------------------------------------------------ #
# multi-fault chaos run
# ------------------------------------------------------------------ #
def test_chaos_schedule_survivors_identical():
    """Mixed schedule (NaN + forced exhaustion + host stall) against the
    paged+prefix engine: non-targeted streams finish with fault-free
    greedy output; the pool conserves pages; nothing leaks."""
    clean, _ = _run("prefix", paged=True, page_size=8, max_new=10)
    f = (Faults(seed=0)
         .on("nan_logits", step=6, slot=1)
         .on("page_alloc", step=9, times=2)
         .on("slow_step", step=4, delay_s=0.002))
    resp, eng = _run("prefix", paged=True, page_size=8, max_new=10,
                     faults=f)
    assert sum(1 for r in resp.values()
               if r.finish_reason == "error") == 1
    for u, r in resp.items():
        if r.ok:
            assert r.tokens == clean[u].tokens, u
    st = eng.latency_stats()
    assert st["faults_injected"] >= 3
    while eng.prefix_cache.drop_lru():
        pass
    assert eng._paged.live_pages == 0
    eng._paged.check_invariants()
    # registry counters surfaced through the metrics collector
    snap = eng.metrics.snapshot()["collected"]
    assert snap.get("faults_fired_total", 0) >= 3


def test_env_var_schedule_reaches_engine(monkeypatch):
    from repro.serving import faults as fm
    monkeypatch.setenv(fm.ENV_VAR, "nan_logits@3/0")
    monkeypatch.setenv(fm.ENV_VAR + "_SEED", "4")
    clean, eng0 = _run("plain", n=2)
    resp, eng = _run("plain", n=2)          # faults=None -> env pickup
    assert eng.faults.enabled and eng.faults.seed == 4
    _assert_contained(resp, clean, eng, eng0)
