"""Tensor-parallel sharded serving: multi-device equivalence suite.

The heavy lifting happens in ``tests/sharded_check.py``, spawned ONCE as
a subprocess with ``--xla_force_host_platform_device_count=8`` (this
process keeps its single device — see ``conftest.py``). The checks:
per-mode greedy token identity sharded-vs-single-device (plain /
chunked / prefix-cache / int8-KV / speculative), cache-bit equality of
chunked admission vs whole-prompt admission on the mesh, and a flat
compiled-program count across request streams (no resharding-induced
recompiles).

Runs in the dedicated ``-m sharded`` CI step, not in default tier-1
(``pytest.ini`` deselects the marker): one subprocess compiles ~20
sharded XLA programs and takes minutes on CPU.
"""
import json
import subprocess
import sys
from pathlib import Path

import pytest

pytestmark = pytest.mark.sharded

_SCRIPT = Path(__file__).resolve().parent / "sharded_check.py"
_RESULT = {}


def _result():
    if not _RESULT:
        proc = subprocess.run(
            [sys.executable, str(_SCRIPT)], capture_output=True,
            text=True, timeout=1800)
        try:
            _RESULT.update(json.loads(proc.stdout))
        except json.JSONDecodeError:
            raise AssertionError(
                f"sharded_check produced no JSON (rc={proc.returncode}):"
                f"\n{proc.stdout}\n{proc.stderr}") from None
    return _RESULT


@pytest.mark.parametrize("mode", ["plain", "chunked", "prefix", "int8kv",
                                  "spec", "int8w"])
def test_sharded_greedy_token_identity(mode):
    m = _result()["modes"][mode]
    assert m["identical"], m
    assert m["identical_second_stream"], m


@pytest.mark.parametrize("mode", ["plain", "chunked", "prefix", "int8kv",
                                  "spec", "int8w"])
def test_no_resharding_recompiles(mode):
    """A second request stream through the warm sharded engine must not
    compile a single new program: every step program stays at one
    specialization and the prefill jit cache stops growing."""
    m = _result()["modes"][mode]
    assert m["programs_flat"], m
    assert all(v == 1 for v in m["program_sizes"].values()), m


def test_pure_tensor_parallel_mesh():
    """1x8 mesh: 4 KV heads don't divide 8 — the heads dim falls back to
    replicated but output must still match single-device."""
    m = _result()["plain_1x8"]
    assert m["identical"] and m["programs_flat"], m


def test_admission_cache_bit_equality_on_mesh():
    """Chunk sizes (8 vs 16) are bit-identical after admission; the
    whole-prompt single max-size chunk pads its extend to kv_len where
    XLA vectorizes matmuls differently, so it matches to 1-2 ulp."""
    r = _result()
    assert r["cache_bits_equal"]
    assert r["cache_close_to_whole"]
