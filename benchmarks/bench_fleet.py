"""Fleet serving benchmark: goodput-under-SLO through a replica failure.

  PYTHONPATH=src python -m benchmarks.bench_fleet [--smoke] \
      [--out BENCH_fleet.json]

A multi-tenant Poisson trace (each tenant's prompts share that tenant's
system head, so prefix-affinity routing has something to exploit) is
served twice by an N-replica :class:`~repro.serving.fleet.Fleet` over
the *same* arrival schedule:

* ``clean`` — no faults: the baseline goodput-under-SLO;
* ``chaos`` — the same trace with ``replica_crash`` injected once half
  the arrivals are in: the dead replica's in-flight requests fail over
  to survivors by replay, and the report adds a goodput *timeline* so
  the failure window is visible — the acceptance bar is graceful
  degradation (goodput dips, never collapses to zero, and every request
  still reaches a terminal state).

Every request carries the same SLO deadline (calibrated once from a
warmed probe: ``slo_frac x (TTFT + max_new x step p50)``); goodput
counts only tokens of streams that finished normally within it. The
artifact (``BENCH_fleet.json``, unified envelope of
``benchmarks/schema.py``) is consumed by ``benchmarks/check_fleet.py
--bench`` as the graceful-degradation CI gate.
"""
from __future__ import annotations

import argparse
import time
from typing import Dict, List, Optional, Tuple

import jax
import numpy as np

from benchmarks import schema
from repro.configs import get_arch
from repro.models.model import build
from repro.serving import telemetry
from repro.serving.faults import Faults
from repro.serving.fleet import Fleet
from repro.serving.request import Request
from repro.serving.sampler import Sampler

WINDOW_S = 0.5          # goodput timeline bucket width
FAIL_WINDOW_S = 2.0     # "failure window": this long after the kill


def make_tenant_workload(cfg, n_requests: int, tenants: int, seed: int,
                         rate_hz: float, max_new: int,
                         head_len: int = 16, body_len=(4, 14)):
    """Merged multi-tenant Poisson trace: arrival times plus prompts,
    where every prompt starts with its tenant's shared head (the
    affinity/prefix-reuse signal)."""
    rng = np.random.default_rng(seed)
    arrivals = np.cumsum(rng.exponential(1.0 / rate_hz, n_requests))
    heads = [rng.integers(0, cfg.vocab, head_len) for _ in range(tenants)]
    tenant = rng.integers(0, tenants, n_requests)
    prompts = [np.concatenate([heads[tenant[i]],
                               rng.integers(0, cfg.vocab,
                                            int(rng.integers(*body_len)))])
               for i in range(n_requests)]
    return arrivals, prompts, [int(t) for t in tenant], max_new


def warm_fleet(fl: Fleet, cfg, prompts, max_new: int) -> None:
    """Compile every program the timed stream can hit, on **every**
    replica: the actual workload prompts (so shared-tenant prefix
    *hits* occur during warm — the slot-reset program is keyed on the
    hit length), plus a replay-length variant per distinct length
    (prompt + generated suffix — the shape failover re-admits). Ends
    with ``reset_stats()``, which arms each replica's recompile
    watchdog."""
    rng = np.random.default_rng(321)
    donors, seen = [], set()
    for p in prompts:
        key = np.asarray(p).tobytes()
        if key not in seen:
            seen.add(key)
            donors.append(np.asarray(p))
    by_len = {len(p): p for p in donors}
    donors += [np.concatenate([p, rng.integers(0, cfg.vocab, max_new)])
               for p in by_len.values()]
    for rep in fl.replicas:
        uid = -1
        for p in donors:
            rep.engine.submit(Request(uid=uid, prompt=p,
                                      max_new_tokens=4))
            uid -= 1
        rep.engine.run()
    fl.reset_stats()


def calibrate_slo(fl: Fleet, prompt, max_new: int,
                  slo_frac: float) -> float:
    """One warmed probe on replica 0: SLO = slo_frac x (probe TTFT +
    max_new decode steps at the warmed p50)."""
    eng = fl.replicas[0].engine
    probe = Request(uid=-99, prompt=np.asarray(prompt[:8], np.int32),
                    max_new_tokens=max_new)
    eng.submit(probe)
    eng.run()
    p50 = telemetry.percentile(eng.step_times, 50) \
        if eng.step_times else 0.0
    ttft = probe.first_token_s - probe.submitted_s
    fl.reset_stats()
    return slo_frac * (ttft + max_new * p50)


def serve_fleet_stream(fl: Fleet, arrivals, prompts, max_new: int,
                       deadline_s: float,
                       kill: Optional[Tuple[float, int]] = None) -> Dict:
    """Open-loop driver against the fleet facade. ``kill=(frac, rid)``
    schedules a ``replica_crash`` on ``rid`` for the tick after
    ``frac`` of the arrivals are submitted — armed only once ``rid``
    actually holds in-flight work, so the kill always migrates live
    streams instead of landing on an idle replica."""
    t0 = time.perf_counter()
    i, n = 0, len(prompts)
    kill_s, kill_idx = None, (int(kill[0] * n) if kill else None)

    def _victim_busy(rid: int) -> bool:
        return any(not e.resp.finished
                   and any(a.rid == rid for a in e.live)
                   for e in fl._entries.values())

    while i < n or fl.has_work:
        now = time.perf_counter() - t0
        while i < n and arrivals[i] <= now:
            fl.submit(Request(uid=i, prompt=prompts[i],
                              max_new_tokens=max_new,
                              deadline_s=deadline_s))
            i += 1
        if kill_idx is not None and i >= kill_idx \
                and (_victim_busy(kill[1]) or i >= n):
            fl.faults.on("replica_crash", step=fl._ticks + 1,
                         slot=kill[1])
            kill_s, kill_idx = time.perf_counter() - t0, None
        if not fl.has_work:
            time.sleep(min(0.002, max(0.0, arrivals[i] - now)))
            continue
        fl.tick()
    wall = time.perf_counter() - t0

    resp = fl.responses
    good = [r for u, r in resp.items() if u >= 0 and r.ok]
    reasons: Dict[str, int] = {}
    for u, r in resp.items():
        if u >= 0:
            reasons[r.finish_reason] = reasons.get(r.finish_reason, 0) + 1
    # goodput timeline: good tokens bucketed by finish time
    n_win = int(np.ceil(wall / WINDOW_S)) or 1
    timeline = [0.0] * n_win
    for u, r in resp.items():
        if u < 0 or not r.ok:
            continue
        t_fin = fl._entries[u].req.finished_s - t0
        w = min(n_win - 1, max(0, int(t_fin / WINDOW_S)))
        timeline[w] += r.n_generated
    timeline = [round(t / WINDOW_S, 2) for t in timeline]

    st = fl.latency_stats()
    out = {
        "wall_s": wall,
        "n_requests": n,
        "n_finished": sum(1 for u, r in resp.items()
                          if u >= 0 and r.finished),
        "n_terminal_missing": sum(1 for u, r in resp.items()
                                  if u >= 0 and not r.finished),
        "reasons": reasons,
        "deadline_s": deadline_s,
        "deadline_met_frac": len(good) / n if n else 0.0,
        "goodput_tok_per_s": (sum(r.n_generated for r in good) / wall
                              if wall else 0.0),
        "goodput_timeline_tok_per_s": timeline,
        "window_s": WINDOW_S,
        "kill_s": kill_s,
        "outputs": {u: list(r.tokens) for u, r in resp.items()
                    if u >= 0 and r.ok},
        "replica_states": {r.rid: r.state for r in fl.replicas},
    }
    for k in ("dispatches", "failovers", "requests_migrated",
              "replica_deaths", "hedges_issued", "hedges_won",
              "hedges_wasted", "router_drops", "redispatches",
              "fleet_timeouts", "fleet_errors", "affinity_hits"):
        out[k] = st.get(k, 0)
    out["affinity_hits"] = fl.router.affinity_hits
    telemetry.pct_stats(out, "fleet_ttft_ms", fl._ttft.samples,
                        (50, 95, 99))
    if kill_s is not None:
        lo, hi = kill_s, kill_s + FAIL_WINDOW_S
        toks = 0.0
        for u, r in resp.items():
            if u < 0 or not r.ok:
                continue
            t_fin = fl._entries[u].req.finished_s - t0
            if lo <= t_fin < hi:
                toks += r.n_generated
        out["failure_window_goodput_tok_per_s"] = toks / FAIL_WINDOW_S
    return out


def run(n_requests: int = 36, tenants: int = 3, replicas: int = 3,
        rate_hz: float = 6.0, max_new: int = 16, slo_frac: float = 6.0,
        hedge: bool = False, seed: int = 0,
        kill_frac: float = 0.5, kill_rid: int = 0) -> Dict:
    cfg = get_arch("llama3.2-1b", variant="reduced")
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    arrivals, prompts, tenant, max_new = make_tenant_workload(
        cfg, n_requests, tenants, seed, rate_hz, max_new)
    ek = dict(max_batch=2, cache_len=96, sampler=Sampler(),
              prefill_chunk=8, prefix_cache_tokens=512,
              paged=True, page_size=8, sync_every=4)

    rows: List[Dict] = []
    deadline_s = None
    for name, kill in (("clean", None),
                       ("chaos", (kill_frac, kill_rid))):
        # both runs carry an (initially empty) schedule; the chaos run's
        # driver adds the replica_crash once half the arrivals are in
        fl = Fleet(model, params, replicas=replicas, engine_kwargs=ek,
                   hedge=hedge, faults=Faults(seed=seed))
        warm_fleet(fl, cfg, prompts, max_new)
        if deadline_s is None:
            deadline_s = calibrate_slo(fl, prompts[0], max_new, slo_frac)
        row = serve_fleet_stream(fl, arrivals, prompts, max_new,
                                 deadline_s, kill=kill)
        row["mode"] = name
        # per-replica recompiles-after-warm; killed replicas excluded
        # (their replacement engine is a fresh compile universe)
        row["steady_compiles"] = sum(
            n for rid, n in fl.steady_compiles().items()
            if fl.replicas[rid].state != "dead")
        rows.append(row)

    # survivors of both runs must be token-identical: failover replay
    # and hedging dedup are scheduling changes, not model changes
    a, b = rows[0]["outputs"], rows[1]["outputs"]
    diverged = [u for u in set(a) & set(b) if a[u] != b[u]]
    for row in rows:
        row["greedy_match"] = not diverged
        row.pop("outputs")
    assert not diverged, f"chaos run diverged on uids {diverged}"

    return {
        "workload": {"n_requests": n_requests, "tenants": tenants,
                     "replicas": replicas, "rate_hz": rate_hz,
                     "max_new": max_new, "slo_frac": slo_frac,
                     "deadline_s": deadline_s, "hedge": hedge,
                     "seed": seed, "kill_frac": kill_frac,
                     "kill_rid": kill_rid, "window_s": WINDOW_S,
                     "failure_window_s": FAIL_WINDOW_S},
        "rows": rows,
    }


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI mode: small trace, 3 replicas")
    ap.add_argument("--out", default="BENCH_fleet.json",
                    help="JSON output path ('' to skip)")
    ap.add_argument("--replicas", type=int, default=3)
    ap.add_argument("--hedge", action="store_true",
                    help="enable tail-latency hedging in both runs")
    args = ap.parse_args(argv)

    if args.smoke:
        data = run(n_requests=12, tenants=2, replicas=args.replicas,
                   rate_hz=8.0, max_new=12, hedge=args.hedge)
    else:
        data = run(replicas=args.replicas, hedge=args.hedge)

    by = {r["mode"]: r for r in data["rows"]}
    print(f"fleet benchmark: {data['workload']['replicas']} replicas, "
          f"{data['workload']['tenants']} tenants, SLO "
          f"{data['workload']['deadline_s'] * 1e3:.0f}ms")
    for r in data["rows"]:
        print(f"  {r['mode']:>6s}: goodput {r['goodput_tok_per_s']:7.1f} "
              f"tok/s, met {r['deadline_met_frac'] * 100:5.1f}%, "
              f"migrated={r['requests_migrated']}, "
              f"deaths={r['replica_deaths']}, "
              f"affinity_hits={r['affinity_hits']}, "
              f"reasons={r['reasons']}")
    ch = by["chaos"]
    if ch.get("failure_window_goodput_tok_per_s") is not None:
        print(f"  failure window ({data['workload']['failure_window_s']}s "
              f"after kill at {ch['kill_s']:.1f}s): "
              f"{ch['failure_window_goodput_tok_per_s']:.1f} tok/s good")
    print(f"  goodput timeline (chaos, {ch['window_s']}s windows): "
          f"{ch['goodput_timeline_tok_per_s']}")

    if args.out:
        metrics = [
            schema.metric("goodput_tok_per_s_clean", "tok/s",
                          by["clean"]["goodput_tok_per_s"]),
            schema.metric("goodput_tok_per_s_chaos", "tok/s",
                          by["chaos"]["goodput_tok_per_s"]),
            schema.metric("deadline_met_frac_clean", "frac",
                          by["clean"]["deadline_met_frac"]),
            schema.metric("deadline_met_frac_chaos", "frac",
                          by["chaos"]["deadline_met_frac"]),
            schema.metric("requests_migrated", "requests",
                          by["chaos"]["requests_migrated"]),
            schema.metric("failure_window_goodput_tok_per_s", "tok/s",
                          by["chaos"].get(
                              "failure_window_goodput_tok_per_s", 0.0)),
            schema.metric("affinity_hits_clean", "hits",
                          by["clean"]["affinity_hits"]),
        ]
        schema.write(args.out, schema.payload(
            "fleet", run=schema.run_meta(smoke=args.smoke,
                                         arch="llama3.2-1b-reduced",
                                         greedy=True),
            metrics=metrics, data=data,
            # gated by check_telemetry: a steady-state recompile on a
            # surviving replica means chaos changed a program shape
            telemetry={"counters": {"steady_compiles": sum(
                r["steady_compiles"] for r in data["rows"])},
                "gauges": {}, "histograms": {}}))
    return data


if __name__ == "__main__":
    main()
