"""Quantization benchmark: weight bytes, decode throughput, accuracy.

  PYTHONPATH=src python -m benchmarks.bench_quant [--smoke] \
      [--out BENCH_quant.json]

Reports, for the tiny test config (llama3.2-1b reduced):

* bytes-moved: projection-weight bytes fp vs int8 vs int4 (the decode
  roofline is weight + KV traffic) and KV-cache bytes fp vs int8;
* tokens/s through the serving engine for each precision;
* accuracy: max-abs logit error vs fp, and greedy 32-token decode match
  for int8 weights + int8 KV (asserted — this doubles as the CI quant
  smoke: quantize -> decode -> bounded error).

Emits machine-readable JSON in the unified artifact schema
(``benchmarks/schema.py``) so CI can archive one comparable perf
artifact per bench.
"""
from __future__ import annotations

import argparse
import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import schema
from repro.configs import get_arch
from repro.models.model import build
from repro.quant import quantize_params, quantized_stats
from repro.serving.engine import Engine
from repro.serving.request import Request
from repro.serving.sampler import Sampler

# margin-checked prompt (see tests/test_quant.py): the fp greedy
# trajectory's smallest top-1/top-2 logit gap is ~0.4, ~20x the int8
# quantization error, so the 32-token greedy match is robust
PROMPT_SEED = 15
PROMPT_LEN = 12

# documented max-abs logit error bounds vs fp on the tiny config
# (observed ~0.017 int8 / ~0.25 int4; see docs/quantization.md)
INT8_LOGIT_BOUND = 0.1
INT4_LOGIT_BOUND = 0.6


def _prompt(cfg):
    rng = np.random.default_rng(PROMPT_SEED)
    return rng.integers(0, cfg.vocab, PROMPT_LEN)


def _greedy(model, params, prompt, n, cache_len=64):
    cache = model.make_cache(1, cache_len)
    lo, cache = jax.jit(model.prefill)(
        params, {"tokens": jnp.asarray(prompt, jnp.int32)[None]}, cache)
    out = [int(jnp.argmax(lo[0, -1]))]
    step = jax.jit(model.decode_step)
    for _ in range(n - 1):
        lo, cache = step(params, jnp.asarray([[out[-1]]], jnp.int32),
                         cache)
        out.append(int(jnp.argmax(lo[0, -1])))
    return out


def _engine_toks_per_s(model, params, cfg, *, kv_cache_dtype, n_requests,
                       max_new):
    eng = Engine(model, params, max_batch=4, cache_len=96,
                 sampler=Sampler(), kv_cache_dtype=kv_cache_dtype)
    rng = np.random.default_rng(0)
    t0 = time.perf_counter()
    for uid in range(n_requests):
        L = int(rng.integers(4, 24))
        eng.submit(Request(uid=uid, prompt=rng.integers(0, cfg.vocab, L),
                           max_new_tokens=max_new))
    eng.run()
    wall = time.perf_counter() - t0
    tps = eng.latency_stats()["tokens_generated"] / wall
    return tps, eng.metrics.snapshot()


def run(n_requests: int = 8, max_new: int = 16) -> Dict:
    cfg = get_arch("llama3.2-1b", variant="reduced")
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    q8 = quantize_params(params, bits=8)
    q4 = quantize_params(params, bits=4, group_size=cfg.quant_group)

    # ---- bytes ------------------------------------------------------- #
    s_fp, s_8, s_4 = (quantized_stats(p) for p in (params, q8, q4))
    kv_fp = model.make_cache(1, 64)
    kv_q = build(cfg.replace(kv_quant=True)).make_cache(1, 64)
    from repro.core.netmodel import tree_nbytes
    kv = {"fp_bytes": tree_nbytes(kv_fp), "int8_bytes": tree_nbytes(kv_q)}

    # ---- accuracy ---------------------------------------------------- #
    prompt = _prompt(cfg)
    toks = jnp.asarray(prompt, jnp.int32)[None]

    def logits(p):
        cache = model.make_cache(1, 64)
        lo, _ = jax.jit(model.prefill)(p, {"tokens": toks}, cache)
        return lo

    lo_fp = logits(params)
    err8 = float(jnp.max(jnp.abs(lo_fp - logits(q8))))
    err4 = float(jnp.max(jnp.abs(lo_fp - logits(q4))))

    g_fp = _greedy(model, params, prompt, 33)
    model_kv = build(cfg.replace(kv_quant=True))
    g_8 = _greedy(model_kv, q8, prompt, 33)
    g_4 = _greedy(model_kv, q4, prompt, 33)
    match8 = sum(a == b for a, b in zip(g_fp, g_8))
    match4 = sum(a == b for a, b in zip(g_fp, g_4))

    # ---- CI quant smoke asserts -------------------------------------- #
    assert err8 < INT8_LOGIT_BOUND, f"int8 logit err {err8}"
    assert err4 < INT4_LOGIT_BOUND, f"int4 logit err {err4}"
    assert match8 >= 32, f"int8+int8KV greedy match only {match8}/33"
    red8 = s_fp["weight_bytes"] / s_8["weight_bytes"]
    red4 = s_fp["weight_bytes"] / s_4["weight_bytes"]
    assert red8 >= 2.0, f"int8 weight-bytes reduction {red8:.2f}x"
    assert red4 >= 3.5, f"int4 weight-bytes reduction {red4:.2f}x"

    # ---- serving throughput ------------------------------------------ #
    rows: List[Dict] = []
    snap = None
    for tag, p, kvd in (("fp", params, ""), ("int8", q8, "int8"),
                        ("int4", q4, "int8")):
        tps, snap = _engine_toks_per_s(
            model, p, cfg, kv_cache_dtype=kvd,
            n_requests=n_requests, max_new=max_new)
        rows.append({
            "precision": tag,
            "kv_cache_dtype": kvd or str(cfg.dtype),
            "tok_per_s": tps,
            "weight_bytes": (s_fp if tag == "fp" else
                             s_8 if tag == "int8" else s_4)["weight_bytes"],
        })

    return {
        "arch": cfg.name,
        "weight_bytes": {"fp": s_fp["weight_bytes"],
                         "int8": s_8["weight_bytes"],
                         "int4": s_4["weight_bytes"],
                         "reduction_int8": red8, "reduction_int4": red4},
        "total_param_bytes": {"fp": s_fp["total_bytes"],
                              "int8": s_8["total_bytes"],
                              "int4": s_4["total_bytes"]},
        "kv_cache_bytes": kv,
        "max_abs_logit_err": {"int8": err8, "int4": err4,
                              "bound_int8": INT8_LOGIT_BOUND,
                              "bound_int4": INT4_LOGIT_BOUND},
        "greedy_match_33": {"int8_int8kv": match8, "int4_int8kv": match4},
        "rows": rows,
        # final registry snapshot of the last serving engine; popped into
        # the artifact envelope's telemetry section by main()
        "telemetry": snap,
    }


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI mode: fewer serving requests")
    ap.add_argument("--out", default="BENCH_quant.json",
                    help="JSON output path ('' to skip)")
    args = ap.parse_args(argv)

    payload = run(n_requests=4, max_new=8) if args.smoke else run()

    wb = payload["weight_bytes"]
    print("quantization: weight bytes fp "
          f"{wb['fp']} -> int8 {wb['int8']} ({wb['reduction_int8']:.2f}x) "
          f"-> int4 {wb['int4']} ({wb['reduction_int4']:.2f}x)")
    kv = payload["kv_cache_bytes"]
    print(f"kv cache bytes fp {kv['fp_bytes']} -> int8 {kv['int8_bytes']}")
    err = payload["max_abs_logit_err"]
    print(f"max-abs logit err: int8 {err['int8']:.4f}  "
          f"int4 {err['int4']:.4f}")
    gm = payload["greedy_match_33"]
    print(f"greedy 33-token match vs fp: int8+int8kv "
          f"{gm['int8_int8kv']}/33  int4+int8kv {gm['int4_int8kv']}/33")
    print(f"{'precision':>9s} {'kv dtype':>9s} {'tok/s':>10s} "
          f"{'w bytes':>9s}")
    for r in payload["rows"]:
        print(f"{r['precision']:>9s} {r['kv_cache_dtype']:>9s} "
              f"{r['tok_per_s']:10.1f} {r['weight_bytes']:9d}")

    if args.out:
        metrics = [schema.metric("weight_bytes_reduction_int8", "x",
                                 wb["reduction_int8"]),
                   schema.metric("weight_bytes_reduction_int4", "x",
                                 wb["reduction_int4"]),
                   schema.metric("kv_bytes_reduction_int8", "x",
                                 kv["fp_bytes"] / kv["int8_bytes"]),
                   schema.metric("max_abs_logit_err_int8", "logit",
                                 err["int8"]),
                   schema.metric("greedy_match_33_int8_int8kv", "tokens",
                                 gm["int8_int8kv"])]
        schema.write(args.out, schema.payload(
            "quantization",
            run=schema.run_meta(smoke=args.smoke,
                                arch=payload["arch"]),
            metrics=metrics, data=payload,
            telemetry=payload.pop("telemetry", None)))
    return payload


if __name__ == "__main__":
    main()
