"""Paged-KV memory-scaling benchmark: streams served at fixed KV memory.

  PYTHONPATH=src python -m benchmarks.bench_paged [--smoke] \
      [--out BENCH_paged.json]

The contiguous engine pays ``max_batch x cache_len`` tokens of KV
whether or not anyone is using them, so a fixed KV budget of M token
slots caps concurrency at ``M // cache_len`` streams. The paged engine
(``serving/paged_kv.py``) allocates pages as positions are written and
releases them at harvest, so the same budget sustains as many streams
as actually-live tokens fit — this bench drives both layouts through an
identical workload under one budget and reports

* greedy token-identity paged vs contiguous (asserted, not just noted),
* peak concurrent streams under the budget (paged must beat contiguous),
* allocated KV bytes per live token at peak occupancy,
* peak pool utilization and page-lifecycle counters.

Emits the unified artifact schema (``benchmarks/schema.py``).
"""
from __future__ import annotations

import argparse
import time
from typing import Dict, List, Tuple

import jax
import numpy as np

from benchmarks import schema
from repro.configs import get_arch
from repro.models.model import build
from repro.serving import paged_kv
from repro.serving.engine import Engine
from repro.serving.request import Request
from repro.serving.sampler import Sampler

CACHE_LEN = 64
PAGE_SIZE = 8
KV_BUDGET = 256       # token slots of KV memory shared by both layouts


def _kv_bytes(cache) -> int:
    """Allocated K/V bytes (payload + scales) of an engine cache."""
    total = 0

    def count(node):
        for k in ("k", "v", "k_scale", "v_scale") + paged_kv.POOL_KEYS:
            if k in node:
                total_ref[0] += node[k].nbytes
        return node
    total_ref = [0]
    paged_kv.walk_attn(cache, count)
    total = total_ref[0]
    return total


def _drive(eng: Engine, prompts, max_new: int) -> Tuple[Dict, Dict]:
    """Submit everything up front and drain with ticks, sampling peak
    concurrency and (paged) peak pool occupancy along the way."""
    for uid, p in enumerate(prompts):
        eng.submit(Request(uid=uid, prompt=p, max_new_tokens=max_new))
    peak_streams = peak_pages = peak_tokens = 0
    t0 = time.perf_counter()
    guard = 0
    while eng.has_work and guard < 100_000:
        guard += max(1, eng.tick(2))
        peak_streams = max(peak_streams, eng.active_slots)
        live = sum(len(r.prompt) + len(eng.responses[r.uid].tokens)
                   for r in eng.slots if r is not None)
        peak_tokens = max(peak_tokens, live)
        if eng.paged:
            peak_pages = max(peak_pages, eng._paged.live_pages)
    wall = time.perf_counter() - t0
    out = {u: r.tokens for u, r in eng.responses.items()}
    st = eng.latency_stats()
    return out, {"peak_streams": peak_streams, "peak_pages": peak_pages,
                 "peak_live_tokens": peak_tokens, "wall_s": wall,
                 "tokens_generated": st["tokens_generated"],
                 **{k: v for k, v in st.items() if k.startswith("kv_")}}


def run(n_requests: int = 12, max_new: int = 8,
        paged_slots: int = 8) -> Dict:
    cfg = get_arch("llama3.2-1b", variant="reduced")
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab, int(rng.integers(12, 28)))
               for _ in range(n_requests)]

    contig_cap = KV_BUDGET // CACHE_LEN
    eng_c = Engine(model, params, max_batch=contig_cap,
                   cache_len=CACHE_LEN, sampler=Sampler())
    out_c, row_c = _drive(eng_c, prompts, max_new)
    row_c["max_batch"] = contig_cap
    row_c["kv_bytes"] = _kv_bytes(eng_c.cache)

    num_pages = KV_BUDGET // PAGE_SIZE
    eng_p = Engine(model, params, max_batch=paged_slots,
                   cache_len=CACHE_LEN, sampler=Sampler(), paged=True,
                   page_size=PAGE_SIZE, num_pages=num_pages)
    out_p, row_p = _drive(eng_p, prompts, max_new)
    row_p["max_batch"] = paged_slots
    pool_bytes = _kv_bytes(eng_p.cache)
    # per-page cost excludes the trash page (a fixed +1 overhead)
    row_p["kv_bytes"] = pool_bytes * num_pages // (num_pages + 1)

    # the layout must be bit-invisible in the token stream
    assert out_p == out_c, "paged output diverged from contiguous"

    # the headline claim: same KV budget, more concurrent streams —
    # allocated-on-demand pages vs always-resident per-slot rings
    assert row_p["peak_streams"] > contig_cap, \
        (row_p["peak_streams"], contig_cap)

    bpt_c = row_c["kv_bytes"] / max(row_c["peak_live_tokens"], 1)
    page_bytes = pool_bytes / (num_pages + 1)
    bpt_p = page_bytes * row_p["peak_pages"] \
        / max(row_p["peak_live_tokens"], 1)
    return {"contiguous": row_c, "paged": row_p,
            "kv_bytes_per_live_token_contig": bpt_c,
            "kv_bytes_per_live_token_paged": bpt_p,
            "pool_utilization_peak": row_p["peak_pages"] / num_pages,
            "kv_budget_tokens": KV_BUDGET,
            # final registry snapshot of the paged engine; popped into
            # the artifact envelope's telemetry section by main()
            "telemetry": eng_p.metrics.snapshot()}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="~30s CI mode: fewer requests")
    ap.add_argument("--out", default="BENCH_paged.json",
                    help="JSON output path ('' to skip)")
    args = ap.parse_args(argv)

    res = run(n_requests=8, max_new=6) if args.smoke else run()
    rc, rp = res["contiguous"], res["paged"]
    print(f"paged KV: fixed budget of {res['kv_budget_tokens']} KV token "
          f"slots (cache_len={CACHE_LEN}, page={PAGE_SIZE})")
    print(f"{'layout':>10s} {'streams':>8s} {'B/live-tok':>11s} "
          f"{'tok/s':>8s}")
    for name, row, bpt in (
            ("contig", rc, res["kv_bytes_per_live_token_contig"]),
            ("paged", rp, res["kv_bytes_per_live_token_paged"])):
        print(f"{name:>10s} {row['peak_streams']:8d} {bpt:11.0f} "
              f"{row['tokens_generated'] / row['wall_s']:8.1f}")
    print(f"pool utilization peak: {res['pool_utilization_peak']:.2f}, "
          f"cow splits: {rp['kv_cow_splits']}, "
          f"pages released: {rp['kv_pages_released']}")

    if args.out:
        metrics = [
            schema.metric("streams_at_fixed_mem_paged", "streams",
                          rp["peak_streams"]),
            schema.metric("streams_at_fixed_mem_contig", "streams",
                          rc["peak_streams"]),
            schema.metric("kv_bytes_per_live_token_paged", "B/tok",
                          res["kv_bytes_per_live_token_paged"]),
            schema.metric("kv_bytes_per_live_token_contig", "B/tok",
                          res["kv_bytes_per_live_token_contig"]),
            schema.metric("pool_utilization_peak", "frac",
                          res["pool_utilization_peak"]),
        ]
        schema.write(args.out, schema.payload(
            "paged_kv", run=schema.run_meta(
                smoke=args.smoke, arch="llama3.2-1b-reduced",
                kv_budget_tokens=KV_BUDGET, cache_len=CACHE_LEN,
                page_size=PAGE_SIZE),
            metrics=metrics, data=res,
            telemetry=res.pop("telemetry", None)))
    return res


if __name__ == "__main__":
    main()
