"""Benchmark harness: one entry per paper table/figure + framework benches.

  PYTHONPATH=src python -m benchmarks.run [--only fig2,fig3,...]

Emits ``name,us_per_call,derived`` CSV lines per the harness contract,
followed by human-readable sections.
"""
from __future__ import annotations

import argparse
import sys
import traceback


def bench_fig2(csv):
    from benchmarks.fig2_inference_time import run
    rows = run(iters=30)
    for r in rows:
        csv.append((f"fig2_{r['model']}_fused", r["fused_ms"] * 1e3,
                    f"{r['speedup']:.2f}x_vs_naive"))
    print(f"\n== fig2: fused vs per-stage dispatch ==")
    for r in rows:
        print(f"  {r['model']:18s} fused={r['fused_ms']:8.2f}ms "
              f"naive={r['naive_ms']:8.2f}ms speedup={r['speedup']:.2f}x")


def bench_fig3(csv):
    from benchmarks.fig3_local_vs_cloud import check_claims, run
    rows = run(repeats=5)
    claims = check_claims(rows)
    print(f"\n== fig3: local vs modelled cloud ==")
    for r in rows:
        csv.append((f"fig3_local_n{r['n_images']}",
                    r["local_mean_s"] * 1e6,
                    f"cloud={r['cloud_mean_s']:.2f}s"))
        print(f"  n={r['n_images']:3d} local={r['local_mean_s']:.3f}s"
              f"±{r['local_std_s']:.3f} cloud={r['cloud_mean_s']:.3f}s"
              f"±{r['cloud_std_s']:.3f}")
    for k, v in claims.items():
        print(f"  claim {k:22s}: {'REPRODUCED' if v else 'NOT reproduced'}")


def bench_kernels(csv):
    from benchmarks.bench_kernels import run
    print(f"\n== kernel reference microbenches (CPU) ==")
    for r in run():
        csv.append((r["name"], r["us_per_call"], r["derived"]))
        print(f"  {r['name']:24s} {r['us_per_call']:12.1f}us "
              f"{r['derived']}")


def bench_serving(csv):
    from benchmarks.bench_serving import run
    print(f"\n== serving engine throughput ==")
    rows, _ = run()
    for r in rows:
        csv.append((f"serve_b{r['max_batch']}",
                    r["decode_ms_p50"] * 1e3,
                    f"{r['tok_per_s']:.1f}tok/s"))
        print(f"  batch={r['max_batch']} tok/s={r['tok_per_s']:8.1f} "
              f"p50={r['decode_ms_p50']:.2f}ms p99={r['decode_ms_p99']:.2f}ms")


def bench_paged(csv):
    from benchmarks.bench_paged import run
    print(f"\n== paged KV: streams at fixed KV memory ==")
    res = run(n_requests=8, max_new=6)
    rc, rp = res["contiguous"], res["paged"]
    csv.append(("paged_streams", rp["peak_streams"],
                f"contig={rc['peak_streams']}"))
    print(f"  contig streams={rc['peak_streams']} "
          f"paged streams={rp['peak_streams']} "
          f"(budget {res['kv_budget_tokens']} KV tokens, "
          f"pool peak {res['pool_utilization_peak']:.2f})")


def bench_roofline(csv):
    """Summarise dry-run roofline artifacts if present."""
    from repro.launch.roofline import load_all
    rows = load_all()
    if not rows:
        print("\n== roofline: no dry-run artifacts (run "
              "repro.launch.dryrun) ==")
        return
    print(f"\n== roofline summary ({len(rows)} dry-run combos) ==")
    by_dom = {}
    for r in rows:
        by_dom.setdefault(r["dominant"], []).append(r)
    for dom, rs in sorted(by_dom.items()):
        print(f"  {dom}-bound: {len(rs)} combos")
    for r in rows:
        csv.append((f"roofline_{r['arch']}_{r['shape']}_{r['mesh']}",
                    max(r['compute_s'], r['memory_s'],
                        r['collective_s']) * 1e6,
                    f"dom={r['dominant']}"))


ALL = {"fig2": bench_fig2, "fig3": bench_fig3, "kernels": bench_kernels,
       "serving": bench_serving, "paged": bench_paged,
       "roofline": bench_roofline}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="")
    args = ap.parse_args()
    names = [n for n in args.only.split(",") if n] or list(ALL)
    csv = []
    failed = []
    for name in names:
        try:
            ALL[name](csv)
        except Exception:
            failed.append(name)
            traceback.print_exc()
    print("\nname,us_per_call,derived")
    for name, us, derived in csv:
        print(f"{name},{us:.1f},{derived}")
    if failed:
        sys.exit(f"benchmarks failed: {failed}")


if __name__ == "__main__":
    main()
