"""Unified `BENCH_*.json` artifact schema.

Every benchmark in this repo emits the same envelope so the perf
trajectory is machine-comparable across PRs without per-bench parsing:

```json
{
  "bench": "<name>",              // load / serving_engine / quantization / ...
  "schema_version": 1,
  "run": {                        // where/when/how the numbers were made
    "timestamp": "...Z", "backend": "cpu", "jax": "...",
    "python": "3.11", "smoke": false, "trials": 3, ...
  },
  "metrics": [                    // headline numbers, one unit each
    {"name": "decode_tok_per_s", "unit": "tok/s", "value": 394.1,
     "trials": [361.8, 394.1, 407.9]},   // per-trial values when repeated
    ...
  ],
  "data": { ... },                // bench-specific detail (rows, sweeps)
  "telemetry": { ... }            // optional: final MetricsRegistry
                                  // snapshot of the bench's engine
                                  // (serving/telemetry.py — counters,
                                  // gauges, histograms, series,
                                  // collected component stats)
}
```

`metrics` is the cross-PR comparison surface: a dashboard (or the next
PR's reviewer) can diff `BENCH_x.json["metrics"]` without knowing the
bench. `data` keeps each bench's full row-level output. Schema v2 added
the optional `telemetry` section; v1 artifacts (no telemetry) remain
valid — `validate_payload` accepts both.
"""
from __future__ import annotations

import datetime
import platform
import sys
from typing import Any, Dict, List, Optional

SCHEMA_VERSION = 2


def run_meta(smoke: bool = False, **extra) -> Dict[str, Any]:
    import jax
    meta = {
        "timestamp": datetime.datetime.now(datetime.timezone.utc)
        .strftime("%Y-%m-%dT%H:%M:%SZ"),
        "backend": jax.default_backend(),
        "jax": jax.__version__,
        "python": platform.python_version(),
        "smoke": bool(smoke),
    }
    meta.update(extra)
    return meta


def metric(name: str, unit: str, value,
           trials: Optional[List] = None) -> Dict[str, Any]:
    m: Dict[str, Any] = {"name": name, "unit": unit, "value": value}
    if trials is not None:
        m["trials"] = list(trials)
    return m


def payload(bench: str, *, run: Dict[str, Any],
            metrics: List[Dict[str, Any]],
            data: Dict[str, Any],
            telemetry: Optional[Dict[str, Any]] = None) -> Dict[str, Any]:
    pl = {"bench": bench, "schema_version": SCHEMA_VERSION,
          "run": run, "metrics": metrics, "data": data}
    if telemetry is not None:
        pl["telemetry"] = telemetry
    return pl


def validate_payload(pl: Any) -> List[str]:
    """Structural validation of one BENCH_*.json payload (or a path to
    one): returns a list of problems, empty when the artifact matches
    the envelope (v1 or v2). Used by ``benchmarks/check_telemetry.py``
    in CI and by ``tests/test_telemetry.py``."""
    if isinstance(pl, str):
        import json
        with open(pl) as f:
            pl = json.load(f)
    errs: List[str] = []
    if not isinstance(pl, dict):
        return ["payload is not an object"]
    if not isinstance(pl.get("bench"), str) or not pl.get("bench"):
        errs.append("missing/empty 'bench'")
    if pl.get("schema_version") not in (1, SCHEMA_VERSION):
        errs.append(f"unknown schema_version "
                    f"{pl.get('schema_version')!r}")
    if not isinstance(pl.get("run"), dict):
        errs.append("'run' is not an object")
    metrics = pl.get("metrics")
    if not isinstance(metrics, list):
        errs.append("'metrics' is not a list")
    else:
        for i, m in enumerate(metrics):
            if not isinstance(m, dict) or not all(
                    k in m for k in ("name", "unit", "value")):
                errs.append(f"metric {i}: needs name/unit/value")
    if not isinstance(pl.get("data"), dict):
        errs.append("'data' is not an object")
    tel = pl.get("telemetry")
    if tel is not None:
        if not isinstance(tel, dict):
            errs.append("'telemetry' is not an object")
        else:
            for sec in ("counters", "gauges", "histograms"):
                if not isinstance(tel.get(sec), dict):
                    errs.append(f"telemetry.{sec} missing/not an object")
    return errs


def write(path: str, pl: Dict[str, Any]) -> None:
    import json
    with open(path, "w") as f:
        json.dump(pl, f, indent=2)
    print(f"wrote {path}")
