"""Unified `BENCH_*.json` artifact schema.

Every benchmark in this repo emits the same envelope so the perf
trajectory is machine-comparable across PRs without per-bench parsing:

```json
{
  "bench": "<name>",              // load / serving_engine / quantization / ...
  "schema_version": 1,
  "run": {                        // where/when/how the numbers were made
    "timestamp": "...Z", "backend": "cpu", "jax": "...",
    "python": "3.11", "smoke": false, "trials": 3, ...
  },
  "metrics": [                    // headline numbers, one unit each
    {"name": "decode_tok_per_s", "unit": "tok/s", "value": 394.1,
     "trials": [361.8, 394.1, 407.9]},   // per-trial values when repeated
    ...
  ],
  "data": { ... }                 // bench-specific detail (rows, sweeps)
}
```

`metrics` is the cross-PR comparison surface: a dashboard (or the next
PR's reviewer) can diff `BENCH_x.json["metrics"]` without knowing the
bench. `data` keeps each bench's full row-level output.
"""
from __future__ import annotations

import datetime
import platform
import sys
from typing import Any, Dict, List, Optional

SCHEMA_VERSION = 1


def run_meta(smoke: bool = False, **extra) -> Dict[str, Any]:
    import jax
    meta = {
        "timestamp": datetime.datetime.now(datetime.timezone.utc)
        .strftime("%Y-%m-%dT%H:%M:%SZ"),
        "backend": jax.default_backend(),
        "jax": jax.__version__,
        "python": platform.python_version(),
        "smoke": bool(smoke),
    }
    meta.update(extra)
    return meta


def metric(name: str, unit: str, value,
           trials: Optional[List] = None) -> Dict[str, Any]:
    m: Dict[str, Any] = {"name": name, "unit": unit, "value": value}
    if trials is not None:
        m["trials"] = list(trials)
    return m


def payload(bench: str, *, run: Dict[str, Any],
            metrics: List[Dict[str, Any]],
            data: Dict[str, Any]) -> Dict[str, Any]:
    return {"bench": bench, "schema_version": SCHEMA_VERSION,
            "run": run, "metrics": metrics, "data": data}


def write(path: str, pl: Dict[str, Any]) -> None:
    import json
    with open(path, "w") as f:
        json.dump(pl, f, indent=2)
    print(f"wrote {path}")
