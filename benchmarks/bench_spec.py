"""Speculative-decoding benchmark: fused draft–verify vs plain decode.

  PYTHONPATH=src python -m benchmarks.bench_spec [--smoke] \
      [--out BENCH_spec.json]

Runs the same greedy request stream through the non-speculative engine
and through speculative engines (weight-sharing self-draft variants,
gamma sweep) at batch 1 — the paper's single-user edge-latency setting —
asserts token-identical greedy output, and reports acceptance rate and
decode tokens/s (decode phase only, prefill excluded; engines are warmed
first so XLA compilation never lands in the timed wall). Each config is
measured ``--trials`` times and the median reported, since per-token
wall times at smoke scale are at the mercy of machine noise. Emits
machine-readable JSON in the unified artifact schema
(``benchmarks/schema.py``) so the per-token-latency trajectory (the
paper's user-facing response-time metric) is tracked across PRs.
"""
from __future__ import annotations

import argparse
import time
from typing import Dict, List

import jax
import numpy as np

from benchmarks import schema
from repro.configs import get_arch
from repro.models.model import build
from repro.serving.engine import Engine
from repro.serving.request import Request
from repro.serving.sampler import Sampler


def _one_run(model, params, cfg, n_requests, max_new, **kw):
    """Warm an engine (compile the fused step + every prefill bucket the
    timed stream hits), then run the timed stream. Returns the timed
    responses, decode-phase seconds, and the engine's stats."""
    eng = Engine(model, params, max_batch=1, cache_len=96,
                 sampler=Sampler(), **kw)
    rngw = np.random.default_rng(99)
    for i, L in enumerate((5, 12, 20)):
        eng.submit(Request(uid=-1 - i,
                           prompt=rngw.integers(0, cfg.vocab, L),
                           max_new_tokens=4))
    eng.run()
    warm_t, warm_steps = sum(eng.step_times), eng._steps

    rng = np.random.default_rng(0)
    for uid in range(n_requests):
        L = int(rng.integers(4, 24))
        eng.submit(Request(uid=uid, prompt=rng.integers(0, cfg.vocab, L),
                           max_new_tokens=max_new))
    t0 = time.perf_counter()
    resp = eng.run()
    wall = time.perf_counter() - t0
    decode_s = sum(eng.step_times) - warm_t
    timed = {u: list(r.tokens) for u, r in resp.items() if u >= 0}
    st = eng.latency_stats()
    st["decode_s"] = decode_s
    st["steps"] = eng._steps - warm_steps
    st["wall_s"] = wall
    return timed, st, eng.metrics.snapshot()


def run(n_requests: int = 12, max_new: int = 16, trials: int = 3,
        gammas=(2, 4), drafts=("int8@1",), extra=("fp@1",)):
    cfg = get_arch("llama3.2-1b", variant="reduced")
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rows: List[Dict] = []
    baseline_tokens = None
    snap = None

    def bench(label, **kw):
        nonlocal baseline_tokens, snap
        runs = []
        for _ in range(trials):
            timed, st, snap = _one_run(model, params, cfg, n_requests,
                                       max_new, **kw)
            n_tok = sum(len(t) for t in timed.values())
            runs.append((n_tok / st["decode_s"], st))
            if baseline_tokens is None:
                baseline_tokens = timed
            else:
                # greedy speculative output must be token-identical
                assert timed == baseline_tokens, \
                    f"greedy output diverged for {label}"
        runs.sort(key=lambda r: r[0])
        tok_s, st = runs[len(runs) // 2]               # median trial
        rows.append({
            "config": label,
            "spec_gamma": st.get("spec_gamma", 0),
            "decode_tok_per_s": tok_s,
            "decode_tok_per_s_runs": [round(r[0], 1) for r in runs],
            # latency keys are absent when a stream had no samples
            "decode_ms_p50": st.get("decode_ms_p50", float("nan")),
            "decode_ms_p99": st.get("decode_ms_p99", float("nan")),
            "decode_steps": st["steps"],
            "acceptance_rate": st.get("spec_acceptance_rate", 1.0),
            "tokens_per_step": st.get("spec_tokens_per_step", 1.0),
            "greedy_match": True,
        })

    bench("baseline")
    for d in drafts:
        for g in gammas:
            bench(f"spec draft={d} gamma={g}", draft=d, spec_gamma=g)
    for d in extra:
        bench(f"spec draft={d} gamma=4", draft=d, spec_gamma=4)
    return rows, snap


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="~90s CI mode: fewer requests/trials, one gamma")
    ap.add_argument("--out", default="BENCH_spec.json",
                    help="JSON output path ('' to skip)")
    ap.add_argument("--min-speedup", type=float, default=0.0,
                    help="assert the gamma=4 self-draft decode tok/s >= "
                         "this multiple of baseline (0 = report only)")
    args = ap.parse_args(argv)

    if args.smoke:
        rows, snap = run(n_requests=4, max_new=12, trials=1, gammas=(2,),
                         extra=())
    else:
        rows, snap = run()

    print("speculative decoding: fused draft-verify vs plain decode "
          "(batch=1, greedy)")
    print(f"{'config':>28s} {'tok/s':>9s} {'p50 ms':>8s} {'p99 ms':>8s} "
          f"{'accept':>7s} {'tok/step':>8s} {'steps':>6s}")
    base = rows[0]["decode_tok_per_s"]
    for r in rows:
        print(f"{r['config']:>28s} {r['decode_tok_per_s']:9.1f} "
              f"{r['decode_ms_p50']:8.2f} {r['decode_ms_p99']:8.2f} "
              f"{r['acceptance_rate']:7.2f} {r['tokens_per_step']:8.2f} "
              f"{r['decode_steps']:6d}")
        r["speedup_vs_baseline"] = r["decode_tok_per_s"] / base
    for r in rows[1:]:
        print(f"  {r['config']}: {r['speedup_vs_baseline']:.2f}x baseline "
              f"decode tokens/s")
    if args.min_speedup:
        target = [r for r in rows[1:] if r["spec_gamma"] == 4]
        assert target, "no gamma=4 row to check --min-speedup against"
        got = max(r["speedup_vs_baseline"] for r in target)
        assert got >= args.min_speedup, \
            f"gamma=4 speedup {got:.2f}x < required {args.min_speedup}x"

    if args.out:
        best = max(rows[1:], key=lambda r: r["speedup_vs_baseline"],
                   default=rows[0])
        metrics = [schema.metric("decode_tok_per_s_baseline", "tok/s",
                                 rows[0]["decode_tok_per_s"],
                                 trials=rows[0]["decode_tok_per_s_runs"]),
                   schema.metric("decode_tok_per_s_best", "tok/s",
                                 best["decode_tok_per_s"],
                                 trials=best["decode_tok_per_s_runs"]),
                   schema.metric("speedup_vs_baseline_best", "x",
                                 best["speedup_vs_baseline"]),
                   schema.metric("acceptance_rate_best", "ratio",
                                 best["acceptance_rate"])]
        schema.write(args.out, schema.payload(
            "speculative_decoding",
            run=schema.run_meta(smoke=args.smoke,
                                arch="llama3.2-1b-reduced", greedy=True,
                                max_batch=1),
            metrics=metrics, data={"rows": rows}, telemetry=snap))
    return rows


if __name__ == "__main__":
    main()
