"""Microbenchmarks: jnp reference paths on CPU (wall time) — honest CPU
numbers; TPU performance is analysed structurally via the dry-run
roofline, not measured here.

  PYTHONPATH=src python -m benchmarks.bench_kernels [--out BENCH_kernels.json]

Emits the same machine-readable JSON shape as bench_serving so CI can
archive one unified perf artifact across benches.
"""
from __future__ import annotations

import argparse
import json
import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np


def _bench(fn, *args, iters=10):
    jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.mean(ts))


def run() -> List[Dict]:
    rng = np.random.default_rng(0)
    rows = []

    # attention reference (prefill path)
    from repro.kernels.flash_attention.ref import attention_reference
    B, H, L, hd = 1, 8, 1024, 64
    q, k, v = (jnp.asarray(rng.normal(size=(B, H, L, hd)), jnp.float32)
               for _ in range(3))
    fn = jax.jit(lambda q, k, v: attention_reference(q, k, v, causal=True))
    t = _bench(fn, q, k, v)
    flops = 4 * B * H * L * L * hd
    rows.append({"name": "attention_ref_1k", "us_per_call": t * 1e6,
                 "derived": f"{flops/t/1e9:.1f}GF/s"})

    # SSD scan reference
    from repro.kernels.ssd_scan.ref import ssd_reference
    b, l, h, p, g, n = 1, 2048, 8, 64, 1, 128
    x = jnp.asarray(rng.normal(size=(b, l, h, p)), jnp.float32)
    dt = jnp.asarray(rng.uniform(1e-3, 0.1, (b, l, h)), jnp.float32)
    A = jnp.asarray(-np.ones(h), jnp.float32)
    Bm = jnp.asarray(rng.normal(size=(b, l, g, n)), jnp.float32)
    Cm = jnp.asarray(rng.normal(size=(b, l, g, n)), jnp.float32)
    fn = jax.jit(lambda *a: ssd_reference(*a, chunk=256)[0])
    t = _bench(fn, x, dt, A, Bm, Cm)
    rows.append({"name": "ssd_ref_2k", "us_per_call": t * 1e6,
                 "derived": f"{l*b/t:,.0f}tok/s"})

    # decode attention reference over a 32k cache
    from repro.kernels.decode_attention.ref import (
        decode_attention_reference)
    B2, Hq, Hkv, S = 4, 8, 2, 32768
    q2 = jnp.asarray(rng.normal(size=(B2, Hq, hd)), jnp.float32)
    k2 = jnp.asarray(rng.normal(size=(B2, Hkv, S, hd)), jnp.float32)
    v2 = jnp.asarray(rng.normal(size=(B2, Hkv, S, hd)), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B2, S))
    qp = jnp.full((B2,), S - 1, jnp.int32)
    fn = jax.jit(lambda *a: decode_attention_reference(*a))
    t = _bench(fn, q2, k2, v2, pos, qp)
    bytes_read = B2 * Hkv * S * hd * 4 * 2
    rows.append({"name": "decode_attn_ref_32k", "us_per_call": t * 1e6,
                 "derived": f"{bytes_read/t/1e9:.1f}GB/s"})

    # MoE block
    from repro.configs import get_arch
    from repro.models.moe import init_moe, moe_block
    cfg = get_arch("qwen2-moe-a2.7b", variant="reduced")
    pmoe = init_moe(jax.random.PRNGKey(0), cfg)
    xm = jnp.asarray(rng.normal(size=(2, 256, cfg.d_model)), jnp.float32)
    fn = jax.jit(lambda p, x: moe_block(p, x, cfg)[0])
    t = _bench(fn, pmoe, xm)
    rows.append({"name": "moe_block_512tok", "us_per_call": t * 1e6,
                 "derived": f"{512/t:,.0f}tok/s"})

    # fused dequantize-matmul (weight-only quantized decode projection)
    from repro.kernels.quant_matmul.ops import quant_matmul
    from repro.quant import qtensor_nbytes, quantize_tensor
    M, K, N = 8, 1024, 4096
    xq = jnp.asarray(rng.normal(size=(M, K)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(K, N)), jnp.float32)
    for bits, tag in ((8, "int8"), (4, "int4")):
        qt = quantize_tensor(w, bits=bits, group_size=64)
        fn = jax.jit(lambda x, q=qt: quant_matmul(x, q))
        t = _bench(fn, xq)
        wbytes = qtensor_nbytes(qt)
        rows.append({"name": f"quant_matmul_{tag}_1kx4k",
                     "us_per_call": t * 1e6,
                     "derived": f"{wbytes/t/1e9:.1f}GB/s"})
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="",
                    help="JSON output path ('' = CSV to stdout only)")
    args = ap.parse_args(argv)

    rows = run()
    for r in rows:
        print(f"{r['name']},{r['us_per_call']:.1f},{r['derived']}")
    if args.out:
        payload = {"bench": "kernels", "backend": jax.default_backend(),
                   "rows": rows}
        with open(args.out, "w") as f:
            json.dump(payload, f, indent=2)
        print(f"wrote {args.out}")
    return rows


if __name__ == "__main__":
    main()
