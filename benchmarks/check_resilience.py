"""CI resilience gate: a deterministic chaos smoke against the engine.

  PYTHONPATH=src python -m benchmarks.check_resilience

One fixed-seed scenario on the paged + prefix-cache engine:

* a fault-free run records the expected greedy tokens;
* the chaos run is warmed (programs compiled, ``reset_stats()`` arms the
  recompile watchdog), then replays the same workload under an injected
  schedule — a NaN strike on one slot, repeated forced page-pool
  exhaustions, a host stall — plus a request with an expired deadline.

Gate conditions (exit 1 on any violation, printed to stderr):

* exactly one stream errors (the NaN target), exactly one times out;
* every surviving stream's greedy tokens match the fault-free run
  (preemption replay and NaN containment are exact);
* nothing leaks: no active slots, empty queue, zero live KV pages after
  draining the prefix cache, allocator invariants hold;
* ``steady_compiles == 0`` — injection must never recompile a program
  (the no-op-invisibility contract of serving/faults.py).
"""
from __future__ import annotations

import sys
from typing import Dict, List

import jax
import numpy as np

from repro.configs import get_arch
from repro.models.model import build
from repro.serving.engine import Engine
from repro.serving.faults import Faults
from repro.serving.request import Request
from repro.serving.sampler import Sampler

SEED = 0
NAN_SLOT = 1


def _workload(cfg, uid0: int, deadline_uid: bool):
    rng = np.random.default_rng(SEED + 7)
    head = rng.integers(0, cfg.vocab, 16)
    reqs = []
    for i, n in enumerate((5, 9, 12, 7)):
        body = rng.integers(0, cfg.vocab, n)
        prompt = np.concatenate([head, body]) if i % 2 else body
        reqs.append(Request(uid=uid0 + i, prompt=prompt,
                            max_new_tokens=10))
    if deadline_uid:
        # expires before admission: the deterministic timeout case
        reqs.append(Request(uid=uid0 + 90,
                            prompt=rng.integers(0, cfg.vocab, 6),
                            max_new_tokens=4, deadline_s=1e-6))
    return reqs


def _engine(model, params, **kw):
    return Engine(model, params, max_batch=2, cache_len=64,
                  sampler=Sampler(), prefill_chunk=8,
                  prefix_cache_tokens=256, paged=True, page_size=8, **kw)


def main(argv=None) -> int:
    cfg = get_arch("llama3.2-1b", variant="reduced")
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(SEED))

    # -- expected tokens: the fault-free run ------------------------- #
    clean = _engine(model, params)
    for r in _workload(cfg, 0, deadline_uid=False):
        clean.submit(r)
    want = {u: list(r.tokens) for u, r in clean.run().items()}

    # -- chaos run: warm, arm the watchdog, inject ------------------- #
    eng = _engine(model, params, faults=Faults(seed=SEED))
    for r in _workload(cfg, 1000, deadline_uid=False):   # warm pass
        eng.submit(r)
    eng.run()
    eng.reset_stats()                   # compile from here = failure
    (eng.faults
     .on("nan_logits", step=eng._steps + 4, slot=NAN_SLOT)
     .on("page_alloc", step=eng._steps + 7, times=4)
     .on("slow_step", step=eng._steps + 2, delay_s=0.002))
    for r in _workload(cfg, 0, deadline_uid=True):
        eng.submit(r)
    resp = eng.run()

    errs: List[str] = []
    by_reason: Dict[str, int] = {}
    for r in resp.values():
        by_reason[r.finish_reason] = by_reason.get(r.finish_reason, 0) + 1
    if by_reason.get("error", 0) != 1:
        errs.append(f"expected exactly 1 errored stream (NaN target), "
                    f"got finish reasons {by_reason}")
    if by_reason.get("timeout", 0) != 1:
        errs.append(f"expected exactly 1 timeout, got {by_reason}")
    for u, r in resp.items():
        if r.ok and r.tokens != want.get(u):
            errs.append(f"survivor uid {u} diverged from the fault-free "
                        f"run: {r.tokens} != {want.get(u)}")

    st = eng.latency_stats()
    if st.get("faults_injected", 0) < 3:
        errs.append(f"schedule under-fired: faults_injected="
                    f"{st.get('faults_injected')} < 3")
    if eng.has_work or any(s is not None for s in eng.slots):
        errs.append("engine leaked work: queue or slot table non-empty")
    while eng.prefix_cache.drop_lru():
        pass
    if eng._paged.live_pages != 0:
        errs.append(f"leaked KV pages: {eng._paged.live_pages} live "
                    "after drain")
    try:
        eng._paged.check_invariants()
    except AssertionError as e:
        errs.append(f"allocator invariants violated: {e}")
    steady = eng.metrics.snapshot()["counters"].get("steady_compiles", 0)
    if steady:
        errs.append(f"{steady} steady-state recompile(s) during chaos — "
                    "fault injection changed a program shape")

    if errs:
        for e in errs:
            print(f"check_resilience: {e}", file=sys.stderr)
        return 1
    print(f"check_resilience: chaos smoke clean — "
          f"{sum(1 for r in resp.values() if r.ok)} survivors "
          f"token-identical, reasons={by_reason}, "
          f"preemptions={st.get('preemptions', 0)}, "
          f"faults_injected={st.get('faults_injected', 0)}, "
          f"0 leaked pages/slots, steady_compiles=0")
    return 0


if __name__ == "__main__":
    sys.exit(main())
