"""Per-family serving benchmark over the one chunked admission path.

  PYTHONPATH=src python -m benchmarks.bench_families [--smoke] \
      [--out BENCH_families.json]

Every model family in the zoo — pure SSM, hybrid attention/SSM + MoE,
MoE, encoder-decoder, vision-frontend — is served by the same engine
through the same fused mixed step. For each family this bench times a
chunked stream with the n-gram drafter off and on, and records the two
facts ``check_families.py`` gates on:

* ``fallback_admissions == 0`` — no admission left the fused path;
* ``greedy_match`` — chunked output is token-identical to whole-prompt
  admission (spec off) / to the non-speculative engine (spec on).

Emits machine-readable JSON (per-family decode tok/s, p99 ITL) in the
unified artifact schema (``benchmarks/schema.py``)."""
from __future__ import annotations

import argparse
import time
from typing import Dict, List

import jax
import numpy as np

from benchmarks import schema
from repro.configs import get_arch
from repro.models.model import build
from repro.serving.engine import Engine
from repro.serving.request import Request
from repro.serving.sampler import Sampler

FAMILIES = (
    ("mamba2-780m", "ssm"),
    ("jamba-1.5-large-398b", "hybrid+moe"),
    ("qwen2-moe-a2.7b", "moe"),
    ("seamless-m4t-medium", "encdec"),
    ("pixtral-12b", "vlm"),
)


def _requests(cfg, n: int, max_new: int, uid0: int = 0, seed: int = 3):
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(n):
        emb = None
        if cfg.frontend is not None:
            fe = cfg.frontend
            emb = rng.normal(size=(fe.n_tokens, fe.d_embed)) \
                .astype(np.float32)
        L = int(rng.integers(4, 20))
        reqs.append(Request(uid=uid0 + i,
                            prompt=rng.integers(0, cfg.vocab, L),
                            max_new_tokens=max_new, embeddings=emb))
    return reqs


def _serve(eng: Engine, reqs) -> Dict[int, List[int]]:
    for r in reqs:
        eng.submit(r)
    return {u: r.tokens for u, r in eng.run().items()}


def _engine(model, params, **kw):
    eng = Engine(model, params, max_batch=2, cache_len=96,
                 sampler=Sampler(), **kw)
    # warm: compile the fused step/mixed (and spec) programs the timed
    # stream hits, then drop compile time from the stats
    cfg = model.cfg
    _serve(eng, _requests(cfg, 2, 4, uid0=-10, seed=77))
    eng.reset_stats()
    return eng


def run(n_requests: int = 8, max_new: int = 16):
    rows: List[Dict] = []
    snap = None
    for arch, kind in FAMILIES:
        cfg = get_arch(arch, variant="reduced")
        model = build(cfg)
        params = model.init(jax.random.PRNGKey(0))

        # ground truth: whole-prompt admission (a single max-size chunk)
        base = _serve(_engine(model, params),
                      _requests(cfg, n_requests, max_new))

        for spec, kw in (("off", {}),
                         ("on", {"draft": "ngram", "spec_gamma": 3})):
            eng = _engine(model, params, prefill_chunk=8, **kw)
            t0 = time.perf_counter()
            out = _serve(eng, _requests(cfg, n_requests, max_new))
            wall = time.perf_counter() - t0
            st = eng.latency_stats()
            decode_s = sum(eng.step_times)
            g = lambda k: st.get(k, float("nan"))  # noqa: E731
            rows.append({
                "family": arch, "kind": kind, "ngram_spec": spec,
                "greedy_match": out == base,
                "fallback_admissions": st["fallback_admissions"],
                "chunked_admissions": st["chunked_admissions"],
                "decode_tok_per_s": st["tokens_generated"] / decode_s
                if decode_s else 0.0,
                "itl_ms_p99": g("itl_ms_p99"),
                "spec_acceptance_rate": g("spec_acceptance_rate"),
                "decode_steps": st["decode_steps"],
                "wall_s": wall,
            })
            snap = eng.metrics.snapshot()
    return rows, snap


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI mode: every family, tiny stream")
    ap.add_argument("--out", default="BENCH_families.json",
                    help="JSON output path ('' to skip)")
    args = ap.parse_args(argv)

    if args.smoke:
        rows, snap = run(n_requests=3, max_new=6)
    else:
        rows, snap = run()

    print("one engine, every family: chunked admission + n-gram spec")
    print(f"{'family':>22s} {'spec':>4s} {'tok/s':>8s} {'p99 itl':>8s} "
          f"{'fallb':>5s} {'match':>5s}")
    for r in rows:
        print(f"{r['family']:>22s} {r['ngram_spec']:>4s} "
              f"{r['decode_tok_per_s']:8.1f} {r['itl_ms_p99']:8.2f} "
              f"{r['fallback_admissions']:5d} "
              f"{str(r['greedy_match']):>5s}")

    if args.out:
        metrics = []
        for r in rows:
            if r["ngram_spec"] == "off":
                metrics.append(schema.metric(
                    f"{r['family']}_decode_tok_per_s", "tok/s",
                    r["decode_tok_per_s"]))
                metrics.append(schema.metric(
                    f"{r['family']}_itl_ms_p99", "ms", r["itl_ms_p99"]))
        schema.write(args.out, schema.payload(
            "families", run=schema.run_meta(
                smoke=args.smoke, variant="reduced"),
            metrics=metrics, data={"rows": rows}, telemetry=snap))
    return rows


if __name__ == "__main__":
    main()
