"""Tensor-parallel sharded serving benchmark: mesh layouts vs
single-device on an 8-way host-platform mesh.

  PYTHONPATH=src python -m benchmarks.bench_sharded [--smoke] \
      [--out BENCH_sharded.json]

MUST run as its own process: it forces 8 host-platform devices before
jax initialises (the dry-run pattern) so the mesh exists on CPU-only CI.
Runs the same greedy request stream through the single-device engine and
through sharded engines (pure tensor-parallel 1x8 and mixed 2x4
data x model layouts), asserts token-identical greedy output per layout,
and reports decode tokens/s plus the per-device parameter-bytes cut —
the number that decides whether a 15B-398B config fits device HBM at
all. On host-platform devices the throughput columns measure dispatch
overhead only (collectives are emulated on one CPU); the bytes column
and the identity assertion are the portable signal. Emits the unified
artifact schema (``benchmarks/schema.py``).
"""
from __future__ import annotations

import os

os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=8")

import argparse  # noqa: E402
import time  # noqa: E402
from typing import Dict, List  # noqa: E402

import jax  # noqa: E402
import numpy as np  # noqa: E402

from benchmarks import schema  # noqa: E402
from repro.configs import get_arch  # noqa: E402
from repro.models.model import build  # noqa: E402
from repro.serving.engine import Engine  # noqa: E402
from repro.serving.request import Request  # noqa: E402
from repro.serving.sampler import Sampler  # noqa: E402


def _param_bytes_per_device(eng: Engine) -> int:
    """Max per-device bytes across the param tree (replicated leaves
    count fully on every device; sharded leaves count their shard)."""
    total = 0
    for leaf in jax.tree.leaves(eng.params):
        n_shards = 1
        if eng.mesh is not None:
            spec = leaf.sharding.spec
            sizes = dict(zip(eng.mesh.axis_names, eng.mesh.devices.shape))
            for ax in spec:
                for a in (ax if isinstance(ax, tuple) else (ax,)):
                    if a is not None:
                        n_shards *= sizes[a]
        total += leaf.nbytes // n_shards
    return total


def _one_run(model, params, cfg, mesh, n_requests, max_new,
             prefill_chunk=0) -> Dict:
    eng = Engine(model, params, max_batch=4, cache_len=96,
                 sampler=Sampler(), mesh=mesh,
                 prefill_chunk=prefill_chunk)
    rngw = np.random.default_rng(99)
    for i, L in enumerate((5, 12, 20)):          # warm compile
        eng.submit(Request(uid=-1 - i,
                           prompt=rngw.integers(0, cfg.vocab, L),
                           max_new_tokens=4))
    eng.run()
    eng.reset_stats()
    rng = np.random.default_rng(0)
    for uid in range(n_requests):
        L = int(rng.integers(4, 24))
        eng.submit(Request(uid=uid, prompt=rng.integers(0, cfg.vocab, L),
                           max_new_tokens=max_new))
    t0 = time.perf_counter()
    resp = eng.run()
    wall = time.perf_counter() - t0
    st = eng.latency_stats()
    decode_s = sum(eng.step_times)
    return {
        "tokens": {u: list(r.tokens) for u, r in resp.items() if u >= 0},
        "decode_tok_per_s": st["tokens_generated"] / decode_s
        if decode_s else 0.0,
        "decode_ms_p50": st.get("decode_ms_p50", 0.0),
        "wall_s": wall,
        "param_bytes_per_device": _param_bytes_per_device(eng),
        "programs": eng.program_cache_sizes(),
        "telemetry": eng.metrics.snapshot(),
    }


def run(n_requests: int = 8, max_new: int = 16,
        layouts=("1,8", "2,4")):
    cfg = get_arch("llama3.2-1b", variant="reduced")
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rows = []
    skip = ("tokens", "telemetry")
    base = _one_run(model, params, cfg, None, n_requests, max_new)
    rows.append({"mesh": "single", **{k: v for k, v in base.items()
                                      if k not in skip}})
    snap = base["telemetry"]
    for layout in layouts:
        r = _one_run(model, params, cfg, layout, n_requests, max_new)
        assert r["tokens"] == base["tokens"], \
            f"greedy output diverged on mesh {layout}"
        assert all(v == 1 for v in r["programs"].values()), \
            f"step program recompiled on mesh {layout}: {r['programs']}"
        rows.append({"mesh": layout, "greedy_match": True,
                     **{k: v for k, v in r.items() if k not in skip}})
        snap = r["telemetry"]
    return rows, snap


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="~60s CI mode: fewer requests, one layout")
    ap.add_argument("--out", default="BENCH_sharded.json",
                    help="JSON output path ('' to skip)")
    args = ap.parse_args(argv)

    if args.smoke:
        rows, snap = run(n_requests=4, max_new=8, layouts=("2,4",))
    else:
        rows, snap = run()

    print("sharded serving: mesh layouts vs single device "
          f"({len(jax.devices())} host-platform devices, greedy)")
    print(f"{'mesh':>8s} {'tok/s':>9s} {'p50 ms':>8s} "
          f"{'param MiB/dev':>14s}")
    for r in rows:
        print(f"{r['mesh']:>8s} {r['decode_tok_per_s']:9.1f} "
              f"{r['decode_ms_p50']:8.2f} "
              f"{r['param_bytes_per_device'] / 2**20:14.2f}")
    cut = rows[0]["param_bytes_per_device"] / \
        max(min(r["param_bytes_per_device"] for r in rows[1:]), 1)
    print(f"  best per-device param-bytes cut: {cut:.2f}x")

    if args.out:
        metrics = [
            schema.metric("decode_tok_per_s_single", "tok/s",
                          rows[0]["decode_tok_per_s"]),
            schema.metric("decode_tok_per_s_sharded_best", "tok/s",
                          max(r["decode_tok_per_s"] for r in rows[1:])),
            schema.metric("param_bytes_cut_best", "x", cut),
            schema.metric("greedy_match", "bool", True),
        ]
        schema.write(args.out, schema.payload(
            "sharded_serving",
            run=schema.run_meta(smoke=args.smoke,
                                arch="llama3.2-1b-reduced", greedy=True,
                                n_devices=len(jax.devices()),
                                max_batch=4),
            metrics=metrics, data={"rows": rows}, telemetry=snap))
    return rows


if __name__ == "__main__":
    main()
