"""Poisson-arrival load benchmark: tail latency under concurrent
long-prompt arrivals — the failure mode continuous batching removes.

  PYTHONPATH=src python -m benchmarks.bench_load [--smoke] \
      [--out BENCH_load.json]

An open-loop Poisson request stream (mixed prompt lengths: mostly short
chats plus a fraction of long documents, optionally sharing a system-
prompt head) is driven through the engine with ``Engine.tick`` in three
modes over the *same* arrival schedule:

* ``stall``          — whole-prompt admission (``prefill_chunk=0``: a
                       single max-size chunk): a long prompt's chunk
                       monopolises the fused step while every active
                       decode slot waits, so p99 inter-token latency
                       (ITL) spikes exactly when load arrives;
* ``chunked``        — the fused mixed step (Sarathi-style chunked
                       prefill): decode never stalls, prompts advance
                       ``prefill_chunk`` tokens per step;
* ``chunked+prefix`` — chunked plus shared-prefix KV reuse: prompts
                       sharing the system head skip its recomputation.

Greedy outputs are asserted token-identical across all modes (continuous
batching is a scheduling change, not a model change). Engines are warmed
through every program/bucket the timed stream hits, then
``Engine.reset_stats()`` isolates the measured phase. Reported: p50/p99
TTFT and ITL plus decode tokens/s, in the unified artifact schema
(``benchmarks/schema.py``).
"""
from __future__ import annotations

import argparse
import time
from typing import Dict, List, Optional

import jax
import numpy as np

from benchmarks import schema
from repro.configs import get_arch
from repro.models.model import build
from repro.serving import telemetry
from repro.serving.engine import Engine
from repro.serving.request import Request
from repro.serving.sampler import Sampler


def make_workload(cfg, n_requests: int, seed: int, long_frac: float,
                  short_len=(4, 16), long_len=(96, 160),
                  shared_head: int = 64, shared_frac: float = 0.5,
                  rate_hz: float = 6.0, max_new: int = 24):
    """Arrival times (Poisson) + prompts (mixed lengths; ``shared_frac``
    of them start with one common ``shared_head``-token system prompt)."""
    rng = np.random.default_rng(seed)
    arrivals = np.cumsum(rng.exponential(1.0 / rate_hz, n_requests))
    head = rng.integers(0, cfg.vocab, shared_head)
    prompts = []
    for i in range(n_requests):
        if rng.random() < long_frac:
            L = int(rng.integers(*long_len))
        else:
            L = int(rng.integers(*short_len))
        body = rng.integers(0, cfg.vocab, L)
        if L > shared_head and rng.random() < shared_frac:
            body = np.concatenate([head, body[shared_head:]])
        prompts.append(body)
    return arrivals, prompts, max_new


def serve_stream(eng: Engine, arrivals, prompts, max_new: int,
                 deadline_s: Optional[float] = None) -> Dict:
    """Open-loop driver: submit each request at its arrival time, advance
    the engine with ``tick`` in between. Wall clock is real — queueing
    delay lands in TTFT exactly as a user would see it. With
    ``deadline_s`` every request carries that budget and the report adds
    goodput-under-deadline: only streams that finished normally before
    expiry count (docs/robustness.md)."""
    t0 = time.perf_counter()
    i, n = 0, len(prompts)
    while i < n or eng.has_work:
        now = time.perf_counter() - t0
        while i < n and arrivals[i] <= now:
            eng.submit(Request(uid=i, prompt=prompts[i],
                               max_new_tokens=max_new,
                               deadline_s=deadline_s))
            i += 1
        if not eng.has_work:
            time.sleep(min(0.002, max(0.0, arrivals[i] - now)))
            continue
        eng.tick()
    wall = time.perf_counter() - t0
    st = eng.latency_stats()
    st["wall_s"] = wall
    decode_s = sum(eng.step_times)
    st["decode_tok_per_s"] = st["tokens_generated"] / decode_s \
        if decode_s else 0.0
    st["wall_tok_per_s"] = st["tokens_generated"] / wall if wall else 0.0
    if deadline_s is not None:
        ok = [r for u, r in eng.responses.items() if u >= 0 and r.ok]
        st["deadline_s"] = deadline_s
        st["deadline_met_frac"] = len(ok) / n if n else 0.0
        st["goodput_tok_per_s"] = (
            sum(r.n_generated for r in ok) / wall if wall else 0.0)
    return st


def _warm(eng: Engine, cfg, long_len, shared_head: int,
          max_new: int) -> None:
    """Compile every program the timed stream can hit: all prefill
    buckets (stall mode), the plain fused step, the mixed step + slot
    reset (chunked), and — in prefix mode — extract at every entry
    bucket plus materialize and the partial-hit slice at the shared-head
    bucket. Anything left cold would land its compile spike in the
    measured ITL tail."""
    rng = np.random.default_rng(123)
    uid = -1
    donors = []
    for L in (4, 12, long_len[0] + 8, long_len[1] - 1):
        prompt = rng.integers(0, cfg.vocab, L)
        donors.append(prompt)
        eng.submit(Request(uid=uid, prompt=prompt, max_new_tokens=max_new))
        uid -= 1
    eng.run()
    if eng.prefix_cache is not None:
        # hit path: prompts sharing a bit more than the head bucket with
        # each stored donor warm materialize + the entry slice per bucket
        Q = eng.prefix_cache.bucket(shared_head)
        for donor in donors:
            if len(donor) <= Q + 8:
                continue
            var = np.concatenate([donor[:Q + 8],
                                  rng.integers(0, cfg.vocab, 8)])
            eng.submit(Request(uid=uid, prompt=var, max_new_tokens=4))
            uid -= 1
            eng.run()
    eng.reset_stats()


def steady_decode(model, params, cfg, chunk: int, trials: int = 3) -> Dict:
    """Closed-loop check on ``bench_serving``'s exact configuration
    (batch 4, cache 96, same request stream) but with chunked admission
    enabled: decode tok/s must match BENCH_serving's, proving continuous
    batching does not slow steady decode (the plain-step program is the
    same jitted function; ``step_kinds`` isolates its p50). Median of
    ``trials`` runs — single-shot per-step medians are at the mercy of
    machine noise at smoke scale. ``sync_every=1`` times every step
    individually: burst averaging would smear an admission step's cost
    over the plain entries sharing its burst."""
    from benchmarks.bench_serving import warm_engine
    eng = Engine(model, params, max_batch=4, cache_len=96,
                 sampler=Sampler(), sync_every=1, prefill_chunk=chunk)
    warm_engine(eng, cfg)
    p50s, incl, admissions = [], [], 0
    for t in range(trials):
        eng.reset_stats()
        rng = np.random.default_rng(0)
        for uid in range(12):
            L = int(rng.integers(4, 24))
            eng.submit(Request(uid=uid + 100 * t,
                               prompt=rng.integers(0, cfg.vocab, L),
                               max_new_tokens=16))
        eng.run()
        st = eng.latency_stats()
        decode_s = sum(eng.step_times)
        plain = [tt for tt, k in zip(eng.step_times, eng.step_kinds)
                 if k == "plain"]
        if plain:
            p50s.append(telemetry.percentile(plain, 50))
        if decode_s:
            incl.append(st["tokens_generated"] / decode_s)
        admissions += st["chunked_admissions"]
    p50 = float(np.median(p50s)) if p50s else 0.0
    return {
        # full-batch tokens over the plain-step p50: same basis as
        # BENCH_serving's decode_ms_p50 -> tok/s at batch 4
        "steady_decode_tok_per_s": 4 / p50 if p50 else 0.0,
        "plain_step_ms_p50": p50 * 1e3,
        "plain_step_ms_p50_trials": [round(x * 1e3, 2) for x in p50s],
        # informational: includes the admission (chunk) steps' time,
        # which the stall engine keeps outside step_times
        "decode_tok_per_s_incl_admission":
            float(np.median(incl)) if incl else 0.0,
        "chunked_admissions": admissions}


def run(n_requests: int = 48, long_frac: float = 0.3,
        rate_hz: float = 5.0, max_new: int = 24, chunk: int = 32,
        prefix_tokens: int = 4096, max_batch: int = 4,
        cache_len: int = 384, seed: int = 0,
        deadline_frac: float = 0.0) -> Dict:
    cfg = get_arch("llama3.2-1b", variant="reduced")
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    long_len = (160, min(320, cache_len - max_new - 1))
    arrivals, prompts, max_new = make_workload(
        cfg, n_requests, seed, long_frac, long_len=long_len,
        rate_hz=rate_hz, max_new=max_new)

    modes = [("stall", dict(prefill_chunk=0)),
             ("chunked", dict(prefill_chunk=chunk)),
             ("chunked+prefix", dict(prefill_chunk=chunk,
                                     prefix_cache_tokens=prefix_tokens))]
    rows: List[Dict] = []
    outputs: Dict[str, Dict[int, List[int]]] = {}
    snap, deadline_s = None, None
    for name, kw in modes:
        eng = Engine(model, params, max_batch=max_batch,
                     cache_len=cache_len, sampler=Sampler(),
                     sync_every=4, **kw)
        _warm(eng, cfg, long_len, 64, max_new)
        if deadline_frac and deadline_s is None:
            # calibrate once, on the first warmed engine, so every mode
            # races the SAME absolute budget: deadline = frac x
            # (probe TTFT + max_new decode steps at the warmed p50)
            probe = Request(uid=-99,
                            prompt=np.asarray(prompts[0][:8], np.int32),
                            max_new_tokens=max_new)
            eng.submit(probe)
            eng.run()
            p50 = telemetry.percentile(eng.step_times, 50) \
                if eng.step_times else 0.0
            ttft = probe.first_token_s - probe.submitted_s
            deadline_s = deadline_frac * (ttft + max_new * p50)
            eng.reset_stats()
        st = serve_stream(eng, arrivals, prompts, max_new,
                          deadline_s=deadline_s)
        snap = eng.metrics.snapshot()
        # under deadlines, modes legitimately time out different
        # requests: the greedy-identity gate compares survivors only
        outputs[name] = {u: list(r.tokens)
                        for u, r in eng.responses.items()
                        if u >= 0 and (deadline_s is None or r.ok)}
        # latency key groups are absent when a stream had no samples
        row = {"mode": name, **{k: st.get(k, float("nan")) for k in (
            "ttft_ms_p50", "ttft_ms_p95", "ttft_ms_p99",
            "itl_ms_mean", "itl_ms_p50", "itl_ms_p95", "itl_ms_p99",
            "decode_ms_p50", "decode_ms_p99", "decode_tok_per_s",
            "wall_tok_per_s", "tokens_generated", "n_finished",
            "decode_steps", "wall_s", "chunked_admissions")}}
        for k in ("prefix_hits", "prefix_hit_tokens", "prefix_entries",
                  "prefix_tokens", "deadline_s", "deadline_met_frac",
                  "goodput_tok_per_s", "timeouts", "preemptions"):
            if k in st:
                row[k] = st[k]
        rows.append(row)
    # like-for-like steady A/B in one process: the chunked engine's plain
    # decode step vs the stall engine's, on bench_serving's config
    steady = steady_decode(model, params, cfg, chunk)
    steady_stall = steady_decode(model, params, cfg, 0)
    steady["plain_step_ratio_vs_stall"] = (
        steady["plain_step_ms_p50"] / steady_stall["plain_step_ms_p50"]
        if steady_stall["plain_step_ms_p50"] else 0.0)
    steady["stall_plain_step_ms_p50"] = steady_stall["plain_step_ms_p50"]

    # continuous batching is a scheduling change, not a model change:
    # greedy outputs must be token-identical in every mode (under a
    # deadline, over the requests that met it in both modes)
    for name in ("chunked", "chunked+prefix"):
        if deadline_s is None:
            assert outputs[name] == outputs["stall"], \
                f"greedy output diverged in mode {name!r}"
        else:
            for u in set(outputs[name]) & set(outputs["stall"]):
                assert outputs[name][u] == outputs["stall"][u], \
                    f"greedy output diverged in mode {name!r}, uid {u}"
    for row in rows:
        row["greedy_match"] = True
    return {
        "workload": {"n_requests": n_requests, "rate_hz": rate_hz,
                     "long_frac": long_frac, "long_len": list(long_len),
                     "max_new": max_new, "max_batch": max_batch,
                     "cache_len": cache_len, "prefill_chunk": chunk,
                     "prefix_cache_tokens": prefix_tokens, "seed": seed,
                     "deadline_frac": deadline_frac,
                     "deadline_s": deadline_s},
        "rows": rows,
        "steady": steady,
        # final registry snapshot of the last mode's engine; popped into
        # the artifact envelope's telemetry section by main()
        "telemetry": snap,
    }


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI mode: 2 arrivals, tiny stream")
    ap.add_argument("--out", default="BENCH_load.json",
                    help="JSON output path ('' to skip)")
    ap.add_argument("--min-itl-p99-improvement", type=float, default=0.0,
                    help="assert chunked p99 ITL is at least this factor "
                         "below the stall baseline (0 = report only)")
    ap.add_argument("--deadline-frac", type=float, default=0.0,
                    help="give every request a deadline of this fraction "
                         "of its estimated unloaded service time (probe "
                         "TTFT + max_new x warmed step p50) and report "
                         "goodput-under-deadline per mode (0 = off)")
    args = ap.parse_args(argv)

    if args.smoke:
        data = run(n_requests=2, long_frac=1.0, rate_hz=20.0, max_new=6,
                   deadline_frac=args.deadline_frac)
    else:
        data = run(deadline_frac=args.deadline_frac)

    print("load benchmark: Poisson arrivals, mixed prompt lengths "
          "(stall vs chunked prefill)")
    print(f"{'mode':>15s} {'ttft p50':>9s} {'ttft p99':>9s} "
          f"{'itl p50':>8s} {'itl p99':>8s} {'dec tok/s':>10s} "
          f"{'hits':>5s}")
    for r in data["rows"]:
        print(f"{r['mode']:>15s} {r['ttft_ms_p50']:9.1f} "
              f"{r['ttft_ms_p99']:9.1f} {r['itl_ms_p50']:8.2f} "
              f"{r['itl_ms_p99']:8.2f} {r['decode_tok_per_s']:10.1f} "
              f"{r.get('prefix_hits', 0):5d}")
    by = {r["mode"]: r for r in data["rows"]}
    imp = by["stall"]["itl_ms_p99"] / max(by["chunked"]["itl_ms_p99"],
                                          1e-9)
    print(f"  p99 ITL improvement (stall -> chunked): {imp:.2f}x")
    if args.deadline_frac:
        dl = data["workload"]["deadline_s"]
        print(f"  goodput under a {dl * 1e3:.0f}ms deadline "
              f"({args.deadline_frac}x unloaded service time):")
        for r in data["rows"]:
            print(f"    {r['mode']:>15s}: "
                  f"{r['goodput_tok_per_s']:8.1f} tok/s good, "
                  f"met {r['deadline_met_frac'] * 100:5.1f}%, "
                  f"timeouts={r['timeouts']}")
    print(f"  steady decode (serving config, chunk on): "
          f"{data['steady']['steady_decode_tok_per_s']:.1f} tok/s "
          f"(plain-step p50 {data['steady']['plain_step_ms_p50']:.2f}ms, "
          f"{data['steady']['plain_step_ratio_vs_stall']:.3f}x the stall "
          f"engine's) — compare BENCH_serving decode tok/s at batch 4")
    if args.min_itl_p99_improvement:
        assert imp >= args.min_itl_p99_improvement, \
            f"p99 ITL improvement {imp:.2f}x < " \
            f"required {args.min_itl_p99_improvement}x"

    if args.out:
        metrics = [schema.metric("itl_ms_p99_stall", "ms",
                                 by["stall"]["itl_ms_p99"]),
                   schema.metric("itl_ms_p99_chunked", "ms",
                                 by["chunked"]["itl_ms_p99"]),
                   schema.metric("itl_p99_improvement", "x", imp),
                   schema.metric("ttft_ms_p99_chunked", "ms",
                                 by["chunked"]["ttft_ms_p99"]),
                   schema.metric("decode_tok_per_s_chunked", "tok/s",
                                 by["chunked"]["decode_tok_per_s"]),
                   schema.metric("steady_decode_tok_per_s_chunked",
                                 "tok/s",
                                 data["steady"]["steady_decode_tok_per_s"],
                                 trials=data["steady"]
                                 ["plain_step_ms_p50_trials"]),
                   schema.metric("steady_plain_step_ratio_vs_stall", "x",
                                 data["steady"]
                                 ["plain_step_ratio_vs_stall"]),
                   schema.metric(
                       "prefix_hit_tokens", "tokens",
                       by["chunked+prefix"].get("prefix_hit_tokens", 0))]
        if args.deadline_frac:
            metrics += [
                schema.metric("goodput_tok_per_s_chunked", "tok/s",
                              by["chunked"]["goodput_tok_per_s"]),
                schema.metric("deadline_met_frac_chunked", "frac",
                              by["chunked"]["deadline_met_frac"])]
        schema.write(args.out, schema.payload(
            "load", run=schema.run_meta(smoke=args.smoke,
                                        arch="llama3.2-1b-reduced",
                                        greedy=True),
            metrics=metrics, data=data,
            telemetry=data.pop("telemetry", None)))
    return data


if __name__ == "__main__":
    main()
