"""CI telemetry gate: validate bench artifacts + serving trace.

  PYTHONPATH=src python -m benchmarks.check_telemetry \
      BENCH_serving.json [BENCH_*.json ...] [--trace trace.json]

For every BENCH_*.json argument:

* the envelope must pass ``schema.validate_payload`` (v1 or v2);
* when a ``telemetry`` section is present, its
  ``counters["steady_compiles"]`` must be 0 — a steady-state recompile
  in a warmed bench means an input shape escaped its bucket or a jitted
  program picked up a fresh signature mid-stream (the recompile
  watchdog, docs/observability.md#recompile-watchdog).

With ``--trace`` the Chrome trace-event JSON must pass
``serving/tracing.validate_chrome_trace`` and contain at least one
complete per-request span (``req <uid>``).

Exit code 0 = all clean; 1 = any violation (printed to stderr).
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import List

from benchmarks import schema
from repro.serving import tracing


def check_artifact(path: str) -> List[str]:
    errs = [f"{path}: {e}" for e in schema.validate_payload(path)]
    with open(path) as f:
        pl = json.load(f)
    tel = pl.get("telemetry")
    if isinstance(tel, dict):
        steady = tel.get("counters", {}).get("steady_compiles", 0)
        if steady:
            errs.append(f"{path}: {steady} steady-state recompile(s) — "
                        "a jitted program compiled after warmup "
                        "(see docs/observability.md#recompile-watchdog)")
    return errs


def check_trace(path: str) -> List[str]:
    errs = [f"{path}: {e}" for e in tracing.validate_chrome_trace(path)]
    if errs:
        return errs
    with open(path) as f:
        trace = json.load(f)
    spans = tracing.complete_spans(trace)
    if not spans:
        errs.append(f"{path}: no complete per-request spans "
                    "('req <uid>' X events)")
    return errs


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("artifacts", nargs="*",
                    help="BENCH_*.json paths to validate")
    ap.add_argument("--trace", default="",
                    help="Chrome trace-event JSON to validate")
    args = ap.parse_args(argv)

    errs: List[str] = []
    for path in args.artifacts:
        errs += check_artifact(path)
    if args.trace:
        errs += check_trace(args.trace)

    if errs:
        for e in errs:
            print(f"check_telemetry: {e}", file=sys.stderr)
        return 1
    n = len(args.artifacts) + bool(args.trace)
    print(f"check_telemetry: {n} artifact(s) clean "
          "(schema valid, no steady-state recompiles)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
