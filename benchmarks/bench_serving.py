"""Serving-engine throughput/latency benchmark (continuous batching) —
the runtime behind the paper's 'predictable local service latency' claim.

  PYTHONPATH=src python -m benchmarks.bench_serving [--smoke] \
      [--out BENCH_serving.json]

Emits machine-readable JSON (decode p50/p99 ms, tokens/s, prefill
jit-cache entries) so the perf trajectory is tracked across PRs.
"""
from __future__ import annotations

import argparse
import json
import time
from typing import Dict, List

import jax
import numpy as np

from repro.configs import get_arch
from repro.models.model import build
from repro.serving.engine import Engine
from repro.serving.request import Request
from repro.serving.sampler import Sampler


def run(n_requests: int = 12, max_new: int = 16,
        batch_sizes=(1, 2, 4, 8)) -> List[Dict]:
    cfg = get_arch("llama3.2-1b", variant="reduced")
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rows = []
    for max_batch in batch_sizes:
        eng = Engine(model, params, max_batch=max_batch, cache_len=96,
                     sampler=Sampler())
        rng = np.random.default_rng(0)
        t0 = time.perf_counter()
        for uid in range(n_requests):
            L = int(rng.integers(4, 24))
            eng.submit(Request(uid=uid,
                               prompt=rng.integers(0, cfg.vocab, L),
                               max_new_tokens=max_new))
        eng.run()
        wall = time.perf_counter() - t0
        st = eng.latency_stats()
        rows.append({"max_batch": max_batch,
                     "tok_per_s": st["tokens_generated"] / wall,
                     "decode_ms_p50": st["decode_ms_p50"],
                     "decode_ms_p99": st["decode_ms_p99"],
                     "ttft_ms_mean": st["ttft_ms_mean"],
                     "prefill_jit_entries": st["prefill_jit_entries"],
                     "decode_steps": st["decode_steps"],
                     "wall_s": wall})
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="~30s CI mode: fewer requests, one batch size")
    ap.add_argument("--out", default="BENCH_serving.json",
                    help="JSON output path ('' to skip)")
    args = ap.parse_args(argv)

    if args.smoke:
        rows = run(n_requests=6, max_new=8, batch_sizes=(4,))
    else:
        rows = run()

    print("serving engine v2: continuous batching throughput")
    print(f"{'batch':>5s} {'tok/s':>10s} {'p50 ms':>8s} {'p99 ms':>8s} "
          f"{'ttft ms':>8s} {'jits':>5s}")
    for r in rows:
        print(f"{r['max_batch']:5d} {r['tok_per_s']:10.1f} "
              f"{r['decode_ms_p50']:8.2f} {r['decode_ms_p99']:8.2f} "
              f"{r['ttft_ms_mean']:8.1f} {r['prefill_jit_entries']:5d}")

    if args.out:
        payload = {"bench": "serving_engine_v2",
                   "smoke": bool(args.smoke),
                   "backend": jax.default_backend(),
                   "rows": rows}
        with open(args.out, "w") as f:
            json.dump(payload, f, indent=2)
        print(f"wrote {args.out}")
    return rows


if __name__ == "__main__":
    main()
