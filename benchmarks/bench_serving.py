"""Serving-engine throughput/latency benchmark (continuous batching) —
the runtime behind the paper's 'predictable local service latency' claim.

  PYTHONPATH=src python -m benchmarks.bench_serving [--smoke] \
      [--out BENCH_serving.json]

Emits machine-readable JSON (decode p50/p99 ms, tokens/s, fallback
admission count) in the unified artifact schema
(``benchmarks/schema.py``) so the perf trajectory is tracked across PRs.
"""
from __future__ import annotations

import argparse
import time
from typing import Dict, List

import jax
import numpy as np

from benchmarks import schema
from repro.configs import get_arch
from repro.models.model import build
from repro.serving.engine import Engine
from repro.serving.request import Request
from repro.serving.sampler import Sampler


def warm_engine(eng: Engine, cfg) -> None:
    """Compile the fused step/mixed programs the timed stream hits,
    then reset stats (compile time used to land in the wall — and in
    ttft_ms — making rows incomparable across machines and PRs).
    Shared with ``bench_load.steady_decode``, whose cross-artifact
    comparison depends on warming the exact same configuration."""
    rngw = np.random.default_rng(99)
    for i, L in enumerate((5, 12, 20)):
        eng.submit(Request(uid=-1 - i,
                           prompt=rngw.integers(0, cfg.vocab, L),
                           max_new_tokens=4))
    eng.run()
    eng.reset_stats()


def run(n_requests: int = 12, max_new: int = 16,
        batch_sizes=(1, 2, 4, 8), trace_out: str = ""):
    cfg = get_arch("llama3.2-1b", variant="reduced")
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rows: List[Dict] = []
    snap = None
    eng = None
    for max_batch in batch_sizes:
        eng = Engine(model, params, max_batch=max_batch, cache_len=96,
                     sampler=Sampler(), recorder=bool(trace_out))
        warm_engine(eng, cfg)
        rng = np.random.default_rng(0)
        t0 = time.perf_counter()
        for uid in range(n_requests):
            L = int(rng.integers(4, 24))
            eng.submit(Request(uid=uid,
                               prompt=rng.integers(0, cfg.vocab, L),
                               max_new_tokens=max_new))
        eng.run()
        wall = time.perf_counter() - t0
        st = eng.latency_stats()
        decode_s = sum(eng.step_times)
        # latency key groups are absent when a stream had no samples
        g = lambda k: st.get(k, float("nan"))  # noqa: E731
        rows.append({"max_batch": max_batch,
                     "tok_per_s": st["tokens_generated"] / wall,
                     "decode_tok_per_s": st["tokens_generated"] / decode_s
                     if decode_s else 0.0,
                     "decode_ms_p50": g("decode_ms_p50"),
                     "decode_ms_p99": g("decode_ms_p99"),
                     "ttft_ms_mean": g("ttft_ms_mean"),
                     "itl_ms_p50": g("itl_ms_p50"),
                     "itl_ms_p99": g("itl_ms_p99"),
                     "fallback_admissions": st["fallback_admissions"],
                     "decode_steps": st["decode_steps"],
                     "wall_s": wall})
        # final registry snapshot (last engine measured) rides along in
        # the artifact's telemetry section — steady_compiles must be 0
        snap = eng.metrics.snapshot()
    if trace_out and eng is not None:
        eng.export_trace(trace_out)
        print(f"wrote {trace_out}")
    return rows, snap


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="~30s CI mode: fewer requests, one batch size")
    ap.add_argument("--out", default="BENCH_serving.json",
                    help="JSON output path ('' to skip)")
    ap.add_argument("--trace-out", default="",
                    help="export a Chrome trace-event JSON of the last "
                         "measured engine (open at ui.perfetto.dev)")
    args = ap.parse_args(argv)

    if args.smoke:
        rows, snap = run(n_requests=6, max_new=8, batch_sizes=(4,),
                         trace_out=args.trace_out)
    else:
        rows, snap = run(trace_out=args.trace_out)

    print("serving engine v2: continuous batching throughput")
    print(f"{'batch':>5s} {'tok/s':>10s} {'p50 ms':>8s} {'p99 ms':>8s} "
          f"{'ttft ms':>8s} {'fallb':>5s}")
    for r in rows:
        print(f"{r['max_batch']:5d} {r['tok_per_s']:10.1f} "
              f"{r['decode_ms_p50']:8.2f} {r['decode_ms_p99']:8.2f} "
              f"{r['ttft_ms_mean']:8.1f} {r['fallback_admissions']:5d}")

    if args.out:
        best = max(rows, key=lambda r: r["tok_per_s"])
        metrics = [schema.metric("tok_per_s_best", "tok/s",
                                 best["tok_per_s"]),
                   schema.metric("decode_tok_per_s_best", "tok/s",
                                 best["decode_tok_per_s"]),
                   schema.metric("decode_ms_p50_best_batch", "ms",
                                 best["decode_ms_p50"]),
                   schema.metric("decode_ms_p99_best_batch", "ms",
                                 best["decode_ms_p99"]),
                   schema.metric("ttft_ms_mean_best_batch", "ms",
                                 best["ttft_ms_mean"])]
        schema.write(args.out, schema.payload(
            "serving_engine", run=schema.run_meta(
                smoke=args.smoke, arch="llama3.2-1b-reduced"),
            metrics=metrics, data={"rows": rows}, telemetry=snap))
    return rows


if __name__ == "__main__":
    main()
