"""Serving-engine throughput/latency benchmark (continuous batching) —
the runtime behind the paper's 'predictable local service latency' claim.
"""
from __future__ import annotations

import time
from typing import Dict, List

import jax
import numpy as np

from repro.configs import get_arch
from repro.models.model import build
from repro.serving.engine import Engine
from repro.serving.request import Request
from repro.serving.sampler import Sampler


def run(n_requests: int = 12, max_new: int = 16) -> List[Dict]:
    cfg = get_arch("llama3.2-1b", variant="reduced")
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rows = []
    for max_batch in (1, 2, 4, 8):
        eng = Engine(model, params, max_batch=max_batch, cache_len=96,
                     sampler=Sampler())
        rng = np.random.default_rng(0)
        t0 = time.perf_counter()
        for uid in range(n_requests):
            L = int(rng.integers(4, 24))
            eng.submit(Request(uid=uid,
                               prompt=rng.integers(0, cfg.vocab, L),
                               max_new_tokens=max_new))
        eng.run()
        wall = time.perf_counter() - t0
        st = eng.latency_stats()
        rows.append({"max_batch": max_batch,
                     "tok_per_s": st["tokens_generated"] / wall,
                     "decode_ms_p50": st["decode_ms_p50"],
                     "decode_ms_p99": st["decode_ms_p99"],
                     "wall_s": wall})
    return rows


def main():
    print("serving engine: continuous batching throughput")
    print(f"{'batch':>5s} {'tok/s':>10s} {'p50 ms':>8s} {'p99 ms':>8s}")
    for r in run():
        print(f"{r['max_batch']:5d} {r['tok_per_s']:10.1f} "
              f"{r['decode_ms_p50']:8.2f} {r['decode_ms_p99']:8.2f}")


if __name__ == "__main__":
    main()
