"""CI family-coverage gate over the ``bench_families`` artifact.

  PYTHONPATH=src python -m benchmarks.check_families \
      [--bench BENCH_families.json]

Gate conditions (exit 1 on any violation, printed to stderr):

* the artifact matches the unified schema envelope;
* every zoo family (SSM, hybrid, MoE, encoder-decoder, VLM) has a row
  with the n-gram drafter off AND on — no family silently dropped;
* ``fallback_admissions == 0`` on every row: no admission left the one
  fused chunked path (there is no monolithic path to fall back to, so
  a nonzero count means a request was rejected at admission);
* ``chunked_admissions > 0`` on every row — the path actually ran;
* ``greedy_match`` on every row: chunked output is token-identical to
  whole-prompt admission, and n-gram speculation is token-identical to
  the non-speculative engine.
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import List

from benchmarks.bench_families import FAMILIES


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--bench", default="BENCH_families.json",
                    help="bench_families artifact to gate on")
    args = ap.parse_args(argv)

    errs: List[str] = []
    from benchmarks import schema
    problems = schema.validate_payload(args.bench)
    errs.extend(f"{args.bench}: {p}" for p in problems)
    if not problems:
        with open(args.bench) as f:
            pl = json.load(f)
        rows = {(r["family"], r["ngram_spec"]): r
                for r in pl["data"]["rows"]}
        for arch, kind in FAMILIES:
            for spec in ("off", "on"):
                r = rows.get((arch, spec))
                if r is None:
                    errs.append(f"{arch} ({kind}): no ngram_spec={spec} "
                                "row — family dropped from the bench")
                    continue
                tag = f"{arch} spec={spec}"
                if r.get("fallback_admissions", 1) != 0:
                    errs.append(
                        f"{tag}: {r.get('fallback_admissions')} "
                        "admission(s) fell out of the fused chunked "
                        "path")
                if r.get("chunked_admissions", 0) <= 0:
                    errs.append(f"{tag}: chunked admission never ran")
                if not r.get("greedy_match", False):
                    errs.append(f"{tag}: greedy output diverged from "
                                "the baseline engine")

    if errs:
        for e in errs:
            print(f"check_families: {e}", file=sys.stderr)
        return 1
    print(f"check_families: {len(FAMILIES)} families x ngram on/off "
          "all served through the fused chunked path — 0 fallback "
          "admissions, greedy token-identity holds everywhere")
    return 0


if __name__ == "__main__":
    sys.exit(main())
