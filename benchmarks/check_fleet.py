"""CI fleet gate: seeded replica-kill chaos on a 3-replica fleet.

  PYTHONPATH=src python -m benchmarks.check_fleet [--bench BENCH_fleet.json]

One fixed-seed scenario:

* a fault-free single **engine** run records the expected greedy tokens
  (greedy output is scheduling-invariant, so one engine is the ground
  truth for any fleet arrangement);
* a 3-replica fleet is warmed per replica (``reset_stats()`` arms every
  recompile watchdog), then serves the same workload under an injected
  schedule — ``replica_crash`` mid-run on the busiest replica plus a
  ``router_drop`` on the failover re-dispatch itself — so live requests
  really are migrated, and one migrated request is additionally lost in
  flight and recovered by the probe.

Gate conditions (exit 1 on any violation, printed to stderr):

* **zero lost requests**: every submitted uid reaches a terminal state;
* **survivors token-identical**: every normally-finished stream matches
  the fault-free engine run — including the migrated ones (failover
  resume-by-replay is exact);
* ``requests_migrated >= 1`` (the kill actually hit in-flight work) and
  the dead replica stays dead;
* nothing leaks on the survivors: no queued/active work, zero live KV
  pages after draining prefix caches, allocator invariants hold;
* ``steady_compiles == 0`` **per replica** — chaos recompiled nothing.

With ``--bench BENCH_fleet.json`` it additionally validates the bench
artifact (schema envelope) and the graceful-degradation claim: goodput
under SLO in the failure window stays above zero and every chaos-run
request reached a terminal state.
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Dict, List

import jax
import numpy as np

from repro.configs import get_arch
from repro.models.model import build
from repro.serving.engine import Engine
from repro.serving.faults import Faults
from repro.serving.fleet import DEAD, Fleet
from repro.serving.request import Request
from repro.serving.sampler import Sampler

SEED = 0
KILL_RID = 0
KILL_TICK = 2

_EK = dict(max_batch=2, cache_len=64, sampler=Sampler(),
           prefill_chunk=8, prefix_cache_tokens=256,
           paged=True, page_size=8)


def _workload(cfg, uid0: int = 0):
    rng = np.random.default_rng(SEED + 7)
    head = rng.integers(0, cfg.vocab, 16)
    reqs = []
    for i, n in enumerate((5, 9, 12, 7, 10, 6)):
        body = rng.integers(0, cfg.vocab, n)
        prompt = np.concatenate([head, body]) if i % 2 else body
        reqs.append(Request(uid=uid0 + i, prompt=prompt,
                            max_new_tokens=20))
    return reqs


def _warm(fl: Fleet, cfg) -> None:
    """Per replica: run every workload prompt shape plus its
    replay-length variant, then arm the watchdogs."""
    rng = np.random.default_rng(SEED + 99)
    donors = []
    for r in _workload(cfg):
        donors.append(np.asarray(r.prompt))
        donors.append(np.concatenate(
            [np.asarray(r.prompt),
             rng.integers(0, cfg.vocab, r.max_new_tokens)]))
    for rep in fl.replicas:
        uid = -1
        for p in donors:
            rep.engine.submit(Request(uid=uid, prompt=p,
                                      max_new_tokens=4))
            uid -= 1
        rep.engine.run()
    fl.reset_stats()


def check_bench(path: str, errs: List[str]) -> None:
    from benchmarks import schema
    problems = schema.validate_payload(path)
    errs.extend(f"{path}: {p}" for p in problems)
    if problems:
        return
    with open(path) as f:
        pl = json.load(f)
    rows = {r["mode"]: r for r in pl["data"]["rows"]}
    ch = rows.get("chaos")
    if ch is None:
        errs.append(f"{path}: no chaos row")
        return
    if ch.get("n_terminal_missing", 1) != 0:
        errs.append(f"{path}: chaos run lost "
                    f"{ch.get('n_terminal_missing')} request(s)")
    if ch.get("replica_deaths", 0) < 1:
        errs.append(f"{path}: chaos run killed no replica")
    fw = ch.get("failure_window_goodput_tok_per_s")
    if fw is None or fw <= 0:
        errs.append(f"{path}: goodput collapsed to zero in the failure "
                    f"window (got {fw}) — degradation is not graceful")
    if not ch.get("greedy_match", False):
        errs.append(f"{path}: chaos survivors diverged from the clean "
                    "run")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--bench", default="",
                    help="also validate a BENCH_fleet.json artifact's "
                         "graceful-degradation claim")
    args = ap.parse_args(argv)

    cfg = get_arch("llama3.2-1b", variant="reduced")
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(SEED))

    # -- expected tokens: the fault-free single-engine run ----------- #
    clean = Engine(model, params, **_EK)
    for r in _workload(cfg, uid0=1000):
        clean.submit(Request(uid=r.uid - 1000, prompt=r.prompt,
                             max_new_tokens=r.max_new_tokens))
    want = {u: list(r.tokens) for u, r in clean.run().items()}

    # -- chaos fleet: warm, arm watchdogs, inject --------------------- #
    faults = (Faults(seed=SEED)
              .on("replica_crash", step=KILL_TICK, slot=KILL_RID)
              .on("router_drop", step=KILL_TICK))
    fl = Fleet(model, params, replicas=3, engine_kwargs=_EK,
               faults=faults)
    _warm(fl, cfg)
    for r in _workload(cfg):
        fl.submit(r)
    resp = fl.run()

    errs: List[str] = []
    st = fl.latency_stats()

    missing = [u for u, r in resp.items() if not r.finished]
    if missing:
        errs.append(f"lost requests (no terminal state): {missing}")
    not_ok = {u: r.finish_reason for u, r in resp.items() if not r.ok}
    if not_ok:
        errs.append(f"requests finished abnormally: {not_ok}")
    for u, r in resp.items():
        if r.ok and list(r.tokens) != want.get(u):
            errs.append(f"uid {u} diverged from the fault-free run: "
                        f"{r.tokens} != {want.get(u)}")

    if st.get("replica_deaths", 0) != 1:
        errs.append(f"expected exactly 1 replica death, got "
                    f"{st.get('replica_deaths')}")
    if fl.replicas[KILL_RID].state != DEAD:
        errs.append(f"killed replica {KILL_RID} is "
                    f"{fl.replicas[KILL_RID].state}, want dead")
    if st.get("requests_migrated", 0) < 1:
        errs.append("the kill migrated no in-flight request — the "
                    "scenario under-fired")
    if st.get("router_drops", 0) != 1:
        errs.append(f"expected 1 detected router_drop, got "
                    f"{st.get('router_drops')}")

    # survivors leak nothing
    for rep in fl.replicas:
        if rep.state == DEAD:
            continue
        eng = rep.engine
        if eng.has_work or any(s is not None for s in eng.slots):
            errs.append(f"replica {rep.rid} leaked work: queue or "
                        "slot table non-empty")
        while eng.prefix_cache.drop_lru():
            pass
        if eng._paged.live_pages != 0:
            errs.append(f"replica {rep.rid} leaked KV pages: "
                        f"{eng._paged.live_pages} live after drain")
        try:
            eng._paged.check_invariants()
        except AssertionError as e:
            errs.append(f"replica {rep.rid} allocator invariants "
                        f"violated: {e}")

    steady = fl.steady_compiles()
    for rid, n in sorted(steady.items()):
        if n and fl.replicas[rid].state != DEAD:
            errs.append(f"replica {rid}: {n} steady-state recompile(s) "
                        "during chaos — injection changed a program "
                        "shape")

    if args.bench:
        check_bench(args.bench, errs)

    if errs:
        for e in errs:
            print(f"check_fleet: {e}", file=sys.stderr)
        return 1
    print(f"check_fleet: chaos gate clean — "
          f"{sum(1 for r in resp.values() if r.ok)}/{len(resp)} requests "
          f"token-identical after a replica kill, "
          f"migrated={st.get('requests_migrated')}, "
          f"router_drops={st.get('router_drops')}, "
          f"0 leaked pages/slots, steady_compiles=0 per replica"
          + (f", bench artifact {args.bench} degrades gracefully"
             if args.bench else ""))
    return 0


if __name__ == "__main__":
    sys.exit(main())
